"""Train a small LM with the full training substrate: AdamW, remat,
microbatching, checkpointing + restart.

By default trains a ~6M-param qwen2-family model for 200 steps (CPU-friendly);
``--full-100m`` selects a ~100M config (12L x 512d x 50k vocab) for real
hardware — the code path is identical, only dims change.

Run:  PYTHONPATH=src python examples/train_small_lm.py [--steps 200]
"""
import argparse

from repro.launch import train as train_launcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_small_lm")
    args = ap.parse_args()

    argv = ["--arch", "qwen2-0.5b", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--lr", "1e-3",
            "--microbatches", "2", "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "100"]
    if not args.full_100m:
        argv.append("--reduced")
    train_launcher.main(argv)
    print("\ncheckpoints in", args.ckpt_dir,
          "\nresume with: python -m repro.launch.train --arch qwen2-0.5b "
          f"--reduced --resume --ckpt-dir {args.ckpt_dir} --steps "
          f"{args.steps * 2}")


if __name__ == "__main__":
    main()
