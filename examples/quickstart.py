"""Quickstart: a fleet of FCPO iAgents learning to serve under an SLO.

Spins up 8 simulated inference replicas (heterogeneous devices), attaches an
iAgent to each, and runs ~200 episodes of Federated Continual RL: online CRL
updates through the loss gate, diversity-buffered experiences, and an
agent-specific FL aggregation every 2nd episode.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.fcpo import FCPOConfig
from repro.core.fleet import fleet_init, train_fleet
from repro.data.workload import fleet_traces


def main():
    cfg = FCPOConfig()
    n_agents = 8
    fleet = fleet_init(cfg, n_agents, jax.random.PRNGKey(0), n_pods=2)
    traces = fleet_traces(jax.random.PRNGKey(1), n_agents,
                          200 * cfg.n_steps)

    print(f"fleet: {n_agents} iAgents, 2 pods, SLO={cfg.slo_s * 1e3:.0f}ms")
    fleet, hist = train_fleet(cfg, fleet, traces)

    k = 20
    print(f"\n{'':14s}{'first 20 eps':>14s}{'last 20 eps':>14s}")
    for key, scale, unit in (("reward", 1, ""), ("throughput", 1, "/s"),
                             ("effective_throughput", 1, "/s"),
                             ("latency", 1e3, "ms")):
        a, b = hist[key][:k].mean() * scale, hist[key][-k:].mean() * scale
        print(f"{key:22s}{a:10.2f}{unit:3s}{b:10.2f}{unit}")
    print("\nThe agents learned batch/resolution/concurrency configurations"
          "\nthat hold latency under the SLO while tracking the request rate.")


if __name__ == "__main__":
    main()
