"""Large-scale federated fleet with failures: hierarchical FL across pods,
stragglers every round, a mid-run crash + checkpoint restart, and elastic
rescale (restore 32 agents' shared knowledge into a 64-agent fleet).

This is the FCPO control plane exactly as it would run across pods: the agent
axis is one stacked pytree; Algorithm 1 executes as segment-means per pod;
pods exchange base networks every ``hierarchical_period`` rounds.

Run:  PYTHONPATH=src python examples/federated_fleet.py
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.fcpo import FCPOConfig
from repro.core.fleet import fleet_init, train_fleet
from repro.data.workload import fleet_traces
from repro.training import checkpoint as ckpt


def main():
    cfg = FCPOConfig(fl_every=1)
    n, pods = 32, 4
    fleet = fleet_init(cfg, n, jax.random.PRNGKey(0), n_pods=pods)
    traces = fleet_traces(jax.random.PRNGKey(1), n, 120 * cfg.n_steps)

    print(f"phase 1: {n} agents / {pods} pods, 30% stragglers per FL round")
    fleet, h1 = train_fleet(cfg, fleet, traces[:, :60 * cfg.n_steps],
                            straggler_prob=0.3)
    print(f"  reward {h1['reward'][:10].mean():+.3f} -> "
          f"{h1['reward'][-10:].mean():+.3f}")

    ckpt_dir = tempfile.mkdtemp(prefix="fcpo_fleet_")
    ckpt.save(ckpt_dir, 60, {"params": fleet.astate.params,
                             "base": fleet.base_params})
    print(f"phase 2: simulated crash -> restart from {ckpt_dir}")

    fleet2 = fleet_init(cfg, n, jax.random.PRNGKey(99), n_pods=pods)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        {"params": fleet2.astate.params,
                         "base": fleet2.base_params})
    restored, _ = ckpt.restore(ckpt_dir, 60, like)
    fleet2 = fleet2._replace(
        astate=fleet2.astate._replace(params=restored["params"]),
        base_params=restored["base"])
    fleet2, h2 = train_fleet(cfg, fleet2, traces[:, 60 * cfg.n_steps:],
                             straggler_prob=0.3)
    print(f"  reward {h2['reward'][:10].mean():+.3f} -> "
          f"{h2['reward'][-10:].mean():+.3f} (no cold start after restart)")

    print("phase 3: elastic rescale 32 -> 64 agents "
          "(new agents warm-start from the pods' base networks)")
    big = fleet_init(cfg, 2 * n, jax.random.PRNGKey(7), n_pods=pods)
    base = restored["base"]
    warm = jax.tree.map(lambda b: b[np.asarray(big.pod_ids) % pods], base)
    big = big._replace(astate=big.astate._replace(params=warm),
                       base_params=base)
    tr2 = fleet_traces(jax.random.PRNGKey(3), 2 * n, 30 * cfg.n_steps)
    big, h3 = train_fleet(cfg, big, tr2, straggler_prob=0.3)
    cold = fleet_init(cfg, 2 * n, jax.random.PRNGKey(8), n_pods=pods)
    _, h3c = train_fleet(cfg, cold, tr2, straggler_prob=0.3)
    print(f"  warm-started 64-fleet first-10-ep reward "
          f"{h3['reward'][:10].mean():+.3f} vs cold {h3c['reward'][:10].mean():+.3f}")


if __name__ == "__main__":
    main()
