"""End-to-end driver (the paper's kind: inference serving): FCPO-controlled
serving of a small LM with batched requests.

A real ServingEngine (jit-compiled prefill/decode with a KV cache, bucketed
executables) serves Zipf-random requests; its measured batching curve
calibrates the MDP; iAgents pick (batch bucket, seq bucket, concurrency)
every control interval; requests flow through a bounded queue with a 250 ms
SLO and effective throughput is tracked exactly as in the paper.

Run:  PYTHONPATH=src python examples/serve_fcpo.py [--episodes 20]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.configs.fcpo import FCPOConfig
from repro.core.fleet import fleet_episode, fleet_init, fl_round
from repro.data.pipeline import request_stream
from repro.data.workload import fleet_traces
from repro.launch.serve import calibrate_env_from_engine
from repro.models.registry import get_model
from repro.serving.engine import ServingEngine
from repro.serving.slo import BoundedQueue, Request, SLOTracker


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=20)
    ap.add_argument("--arch", default="qwen2-0.5b")
    args = ap.parse_args()

    cfg_m = get_config(args.arch).reduced()
    model = get_model(cfg_m)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_cache_len=64,
                           batch_buckets=(1, 2, 4, 8), seq_buckets=(16, 32))

    cfg = FCPOConfig()
    n = 2  # two replica agents share this host
    fleet = fleet_init(cfg, n, jax.random.PRNGKey(1))
    env_params = calibrate_env_from_engine(engine, cfg)
    fleet = fleet._replace(env_params=jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n,)), env_params))
    print(f"engine calibrated: t0={float(env_params.t0) * 1e3:.1f}ms "
          f"t1={float(env_params.t1) * 1e6:.0f}us/request")

    traces = fleet_traces(jax.random.PRNGKey(2), n,
                          args.episodes * cfg.n_steps, base_rate=20.0)
    queue = BoundedQueue(capacity=64)
    slo = SLOTracker(slo_s=cfg.slo_s)
    reqs = request_stream(cfg_m, np.asarray(traces[0] / 10), max_len=16)

    for e in range(args.episodes):
        rates = traces[:, e * cfg.n_steps:(e + 1) * cfg.n_steps]
        fleet, rollouts, metrics = fleet_episode(cfg, fleet, rates)
        if (e + 1) % cfg.fl_every == 0:
            fleet, _, _ = fl_round(cfg, fleet, rollouts)

        # serve REAL batched requests at the agent's chosen configuration
        a = np.asarray(rollouts.actions[0, -1])
        bs = min(cfg.bs_values[int(a[1])], max(engine.batch_buckets))
        now = time.perf_counter()
        for rid, toks in next(reqs, []):
            queue.push(Request(rid, arrival_t=now, size=1))
        batch_reqs = queue.pop_batch(bs)
        if batch_reqs:
            tokens = jnp.zeros((len(batch_reqs), 16), jnp.int32)
            engine.generate(tokens, steps=2)
            slo.complete(batch_reqs, time.perf_counter())
        thr, eff, lat = slo.window(time.perf_counter(), horizon=60.0)
        print(f"ep {e + 1:3d} agent_reward {float(metrics['reward'].mean()):+.3f} "
              f"sim_lat {float(metrics['latency'].mean()) * 1e3:6.1f}ms | "
              f"real: served bs={bs:2d} queue={len(queue):3d} "
              f"drops={queue.drops:3d} eff_thr={eff:.1f}/min", flush=True)

    print(f"\nengine stats: {engine.stats}")


if __name__ == "__main__":
    main()
