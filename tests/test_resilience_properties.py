"""Hypothesis property tests for the robust aggregation statistics
(skipped, like test_properties.py, when hypothesis is not installed —
tests/test_resilience.py carries a deterministic slice of the same
invariant)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from test_resilience import _robust_within_honest_range  # noqa: E402

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def honest_and_byzantine(draw):
    honest = draw(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                           min_size=2, max_size=6))
    f = draw(st.integers(0, len(honest) - 1))
    byz = [draw(st.sampled_from([-1e9, -1e6, 1e6, 1e9])) for _ in range(f)]
    return honest, byz


@settings(**SETTINGS)
@given(honest_and_byzantine())
def test_trimmed_and_median_within_honest_range(hb):
    """Coordinate-wise robustness: byzantine values (any magnitude, any
    sign) cannot drag the trimmed mean or median outside the honest
    values' [min, max] as long as the trim budget covers them."""
    honest, byz = hb
    _robust_within_honest_range([float(np.float32(h)) for h in honest], byz)
