"""Flight-recorder tests: span tracing, request attribution, live-metrics
resilience.

Four invariant families:

  * tracing changes NOTHING numeric — a traced ``train_fleet_scan`` run is
    bit-identical to the untraced one (span callbacks never feed the
    numerics), and with no tracer the compiled program is the exact
    pre-observability one (tests/test_golden.py pins that run; here the
    traced twin is compared leaf-for-leaf against it transitively);
  * the exported timeline is well-formed — Chrome trace-event schema
    round-trips through JSON, span timestamps are monotone and properly
    nested, sampling thins emission without recompiling;
  * request attribution is a lossless decomposition — per-request stage
    stamps reconstructed from the twin's monotone counters conserve the
    twin's own aggregate counts/latency-sum/histogram EXACTLY (including a
    hypothesis sweep over random workloads), and the per-segment delays
    telescope to the total latency;
  * the live-metrics tap survives kills — ``MetricsSink(resume=True)``
    validates the meta header and appends (torn tails healed), and
    ``launch/watch.py`` degrades gracefully on meta-only files and unknown
    metric keys.
"""
import json
import os
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.fcpo import FCPOConfig
from repro.core.fleet import _scan_fn, fleet_init, train_fleet_scan
from repro.eval.stream import MetricsSink, read_metrics
from repro.kernels.ref import (CAP_BATCH, CAP_POST, CAP_PRE, CAP_QCAP,
                               CAP_SLO, CAP_TBATCH)
from repro.launch import watch
from repro.obs import Tracer, validate_chrome_trace
from repro.obs import trace as obs_trace
from repro.obs.requests import SEGMENTS, attribute_agent, attribute_run, \
    conservation_report, records_to_chrome, stage_decomposition
from repro.sim import SimParams, make_scenario, simulate_fleet
from repro.sim.state import sim_init
from repro.sim.step import sim_interval_recorded

A, EPISODES, SEED = 4, 4, 0


# ---------------------------------------------------------------------------
# One traced/untraced run pair shared by the span tests (two scan compiles)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def traced_runs():
    cfg = FCPOConfig()
    fleet = fleet_init(cfg, A, jax.random.PRNGKey(SEED))
    traces = make_scenario("nominal", jax.random.PRNGKey(SEED + 1), A,
                           EPISODES * cfg.n_steps)
    kw = dict(seed=SEED, donate=False)
    off = train_fleet_scan(cfg, fleet, traces, **kw)
    t1 = Tracer()
    on = train_fleet_scan(cfg, fleet, traces, tracer=t1, **kw)
    ev_full = t1.chrome_events()
    t1.close()
    size_after_first = _scan_fn(False)._cache_size()
    t2 = Tracer(span_sample_every=2)
    on2 = train_fleet_scan(cfg, fleet, traces, tracer=t2, **kw)
    ev_sparse = t2.chrome_events()
    t2.close()
    size_after_second = _scan_fn(False)._cache_size()
    return {"cfg": cfg, "off": off, "on": on, "on2": on2,
            "ev_full": ev_full, "ev_sparse": ev_sparse,
            "cache_sizes": (size_after_first, size_after_second)}


class TestSpanTracing:
    def test_traced_run_bit_identical(self, traced_runs):
        """Span emission must never change the numerics — tracing ON (at
        any sampling) computes the same bits as OFF. (OFF vs the pre-PR
        program is pinned by tests/test_golden.py.)"""
        for other in ("on", "on2"):
            for a, b in zip(jax.tree.leaves(traced_runs["off"]),
                            jax.tree.leaves(traced_runs[other])):
                assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_tracer_swap_does_not_recompile(self, traced_runs):
        """Trace-id and sampling period are operands, not statics: a second
        tracer with a different sampling rate reuses the executable."""
        first, second = traced_runs["cache_sizes"]
        assert second == first

    def test_span_names_and_counts(self, traced_runs):
        counts = Counter(e["name"] for e in traced_runs["ev_full"]
                         if e["ph"] == "X")
        assert counts["episode"] == EPISODES
        # fl_every=2 -> rounds complete on episodes 1 and 3
        assert counts["fl_round"] == 2
        for phase in ("fl/uplink", "fl/aggregate", "fl/finetune"):
            assert counts[phase] == 2, counts
        # every begin found its end: no unmatched/open anomaly markers
        bad = [e for e in traced_runs["ev_full"]
               if e.get("cat", "").endswith("-open")
               or e.get("cat") == "unmatched-end"]
        assert not bad, bad

    def test_spans_monotone_and_nested(self, traced_runs):
        ev = [e for e in traced_runs["ev_full"] if e["ph"] == "X"]
        eps = sorted((e for e in ev if e["name"] == "episode"),
                     key=lambda e: e["ts"])
        # episodes are sequential, non-overlapping, non-negative duration
        for e in eps:
            assert e["dur"] >= 0
        for prev, nxt in zip(eps, eps[1:]):
            assert nxt["ts"] >= prev["ts"] + prev["dur"]
        # every FL phase span nests inside some fl_round span
        rounds = [e for e in ev if e["name"] == "fl_round"]
        for e in ev:
            if not e["name"].startswith("fl/"):
                continue
            assert any(r["ts"] <= e["ts"] and
                       e["ts"] + e["dur"] <= r["ts"] + r["dur"]
                       for r in rounds), (e, rounds)

    def test_sampling_thins_emission(self, traced_runs):
        counts = Counter(e["name"] for e in traced_runs["ev_sparse"]
                         if e["ph"] == "X")
        # sample_every=2 keeps episodes 0 and 2; FL rounds land on the
        # sampled-out episodes 1 and 3, so no fl spans at all
        assert counts["episode"] == EPISODES // 2
        assert counts["fl_round"] == 0

    def test_kernel_spans_opt_in(self):
        """Kernel wrappers emit only under an active kernel_spans tracer,
        and the traced call returns the same values."""
        from repro.kernels.ops import pack
        tok = jnp.ones((16, 8), jnp.float32)
        idx = jnp.asarray([0, 3, -1, 5], jnp.int32)
        base = np.asarray(pack(tok, idx)[0])
        with Tracer(kernel_spans=True) as tr, obs_trace.activate(tr):
            out = np.asarray(pack(tok, idx)[0])
        ev = tr.chrome_events()
        assert [e["name"] for e in ev if e["ph"] == "X"] == ["kernel/pack"]
        assert np.array_equal(base, out)
        with Tracer(kernel_spans=False) as quiet, obs_trace.activate(quiet):
            pack(tok, idx)
        assert quiet.chrome_events() == []


class TestChromeTraceSchema:
    def test_export_roundtrip(self, tmp_path):
        tr = Tracer(pid=7)
        with tr.span("compile", cat="host"):
            with tr.span("lower", cat="host"):
                pass
        tr.instant("ckpt-written")
        tr.add_complete("req0/infer", ts_us=10.0, dur_us=5.0, pid=1000,
                        tid=2, args={"agent": 0})
        path = tr.export(str(tmp_path / "trace.json"))
        tr.close()
        with open(path) as f:
            trace = json.load(f)
        assert validate_chrome_trace(trace) == []
        ev = trace["traceEvents"]
        assert len(ev) == 4
        names = {e["name"] for e in ev}
        assert names == {"compile", "lower", "ckpt-written", "req0/infer"}
        inner = next(e for e in ev if e["name"] == "lower")
        outer = next(e for e in ev if e["name"] == "compile")
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]

    def test_validator_catches_malformed(self):
        assert validate_chrome_trace([1, 2]) != []
        assert validate_chrome_trace({"nope": []}) != []
        assert validate_chrome_trace({"traceEvents": "x"}) != []
        ok = {"name": "a", "ph": "X", "ts": 0.0, "dur": 1.0,
              "pid": 1, "tid": 0}
        assert validate_chrome_trace({"traceEvents": [ok]}) == []
        for bad in (
            {k: v for k, v in ok.items() if k != "pid"},   # missing key
            dict(ok, ph="Z"),                               # unknown phase
            dict(ok, ts=-1.0),                              # negative ts
            {k: v for k, v in ok.items() if k != "dur"},   # X without dur
            "not-an-object",
        ):
            assert validate_chrome_trace({"traceEvents": [bad]}) != []

    def test_interrupted_span_drains_as_instant(self):
        tr = Tracer()
        tr._begin("episode", "phase")  # begin with no matching end
        trace = tr.chrome_trace()
        tr.close()
        assert validate_chrome_trace(trace) == []
        (ev,) = trace["traceEvents"]
        assert ev["ph"] == "i" and ev["cat"].endswith("-open")


# ---------------------------------------------------------------------------
# Request-grade latency attribution
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def recorded_run():
    cfg = FCPOConfig()
    sp = SimParams()
    a, t = 2, 8
    fleet = fleet_init(cfg, a, jax.random.PRNGKey(SEED))
    traces = make_scenario("steady", jax.random.PRNGKey(SEED + 2), a, t)
    args = (cfg, sp, fleet.astate.params, fleet.masks, fleet.env_params,
            traces, jax.random.PRNGKey(SEED + 3))
    state_plain, _, summ_plain = simulate_fleet(*args)
    state, history, summ = simulate_fleet(*args, record_ticks=True)
    return {"sp": sp, "state_plain": state_plain, "state": state,
            "history": history, "summ": summ, "summ_plain": summ_plain}


class TestRequestAttribution:
    def test_recording_is_bit_identical(self, recorded_run):
        for a, b in zip(jax.tree.leaves(recorded_run["state_plain"]),
                        jax.tree.leaves(recorded_run["state"])):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_conservation_against_twin_aggregates(self, recorded_run):
        out = attribute_run(recorded_run["history"], recorded_run["state"])
        for rep in out["conservation"]:
            assert rep["ok"], rep

    def test_segments_telescope_to_latency(self, recorded_run):
        out = attribute_run(recorded_run["history"], recorded_run["state"])
        for attr in out["agents"]:
            done = attr["completed"]
            total = sum(attr[s + "_ticks"][done] for s in SEGMENTS)
            assert np.array_equal(total, attr["latency_ticks"][done])

    def test_stage_decomposition_shape(self, recorded_run):
        out = attribute_run(recorded_run["history"], recorded_run["state"])
        dec = stage_decomposition(out["agents"], recorded_run["sp"].dt)
        assert set(dec) == set(SEGMENTS)
        for stats in dec.values():
            assert set(stats) == {"mean_s", "p50_s", "p99_s",
                                  "p99_tail_mean_s"}
            assert all(v >= 0.0 for v in stats.values())

    def test_records_export_to_valid_chrome_slices(self, recorded_run):
        out = attribute_run(recorded_run["history"], recorded_run["state"],
                            sample_every=4)
        with Tracer() as tr:
            n = records_to_chrome(tr, out["records"], recorded_run["sp"].dt)
            trace = tr.chrome_trace()
        assert n > 0 and validate_chrome_trace(trace) == []
        assert sum(1 for e in trace["traceEvents"] if e["ph"] == "X") == n

    def test_sampling_thins_records_not_conservation(self, recorded_run):
        full = attribute_run(recorded_run["history"], recorded_run["state"],
                             sample_every=1)
        thin = attribute_run(recorded_run["history"], recorded_run["state"],
                             sample_every=8)
        assert 0 < len(thin["records"]) < len(full["records"])
        for rep in thin["conservation"]:
            assert rep["ok"]


class TestAttributionProperty:
    """Conservation holds on arbitrary workloads, not just policy-driven
    ones: random arrivals and caps through the real microtick kernel."""

    def _caps(self, rng):
        caps = np.zeros(6, np.float32)
        caps[CAP_PRE] = rng.uniform(0.2, 4.0)
        caps[CAP_POST] = rng.uniform(0.2, 4.0)
        caps[CAP_BATCH] = rng.integers(1, 7)
        caps[CAP_TBATCH] = rng.integers(1, 7)
        caps[CAP_QCAP] = rng.integers(2, 13)
        caps[CAP_SLO] = rng.integers(1, 15)
        return caps

    def _check(self, seed, n_intervals, k_ticks=8):
        rng = np.random.default_rng(seed)
        sp = SimParams(dt=0.05, k_ticks=k_ticks, ring=64, hist_n=16)
        step = jax.jit(sim_interval_recorded)
        state = sim_init(sp)
        seqs, caps_seq = [], []
        for _ in range(n_intervals):
            caps = self._caps(rng)
            arrivals = rng.integers(0, 7, size=k_ticks)
            state, ticks = step(state, jnp.asarray(arrivals, jnp.int32),
                                jnp.asarray(caps))
            seqs.append(np.asarray(ticks))
            caps_seq.append(caps)
        seq = np.concatenate(seqs)
        attr = attribute_agent(seq, np.asarray(caps_seq), k_ticks)
        rep = conservation_report(attr, seq[-1],
                                  float(np.asarray(state.lat_sum)),
                                  np.asarray(state.hist))
        assert rep["ok"], (seed, rep)

    def test_random_workloads_conserve(self):
        hyp = pytest.importorskip("hypothesis")
        st = pytest.importorskip("hypothesis.strategies")

        @hyp.settings(max_examples=20, deadline=None)
        @hyp.given(seed=st.integers(0, 2**32 - 1),
                   n_intervals=st.integers(1, 6))
        def prop(seed, n_intervals):
            self._check(seed, n_intervals)

        prop()

    def test_deterministic_slice(self):
        """Hypothesis-free slice of the property (runs even without the
        optional dependency)."""
        for seed in (0, 1, 2, 3):
            self._check(seed, n_intervals=4)


# ---------------------------------------------------------------------------
# Live-metrics resilience: sink resume + watcher degradation
# ---------------------------------------------------------------------------
META = {"agents": 4, "episodes": 8, "seed": 0}


class TestSinkResume:
    def test_resume_appends_after_kill(self, tmp_path):
        path = str(tmp_path / "run.jsonl")
        with MetricsSink(path, meta=META) as sink:
            for e in range(3):
                sink.append({"episode": e, "reward": 0.1 * e})
        with MetricsSink(path, meta=META, resume=True) as sink:
            assert sink.n_records == 3
            for e in range(3, 5):
                sink.append({"episode": e, "reward": 0.1 * e})
        meta, records = read_metrics(path)
        assert meta == META
        assert [r["episode"] for r in records] == [0, 1, 2, 3, 4]

    def test_resume_heals_torn_tail(self, tmp_path):
        path = str(tmp_path / "run.jsonl")
        with MetricsSink(path, meta=META) as sink:
            sink.append({"episode": 0, "reward": 0.5})
        with open(path, "a") as f:
            f.write('{"episode": 1, "rew')  # killed mid-write, no newline
        with MetricsSink(path, meta=META, resume=True) as sink:
            assert sink.n_records == 1  # torn line dropped, not counted
            sink.append({"episode": 1, "reward": 0.6})
        _, records = read_metrics(path)
        # the resumed record must not merge into the torn line
        assert [r["episode"] for r in records] == [0, 1]

    def test_resume_meta_mismatch_raises(self, tmp_path):
        path = str(tmp_path / "run.jsonl")
        MetricsSink(path, meta=META).close()
        with pytest.raises(ValueError, match="meta mismatch"):
            MetricsSink(path, meta=dict(META, agents=8), resume=True)

    def test_resume_headerless_file_raises(self, tmp_path):
        path = str(tmp_path / "run.jsonl")
        with open(path, "w") as f:
            f.write('{"episode": 0, "reward": 0.5}\n')
        with pytest.raises(ValueError, match="header"):
            MetricsSink(path, meta=META, resume=True)

    def test_resume_missing_file_is_fresh_start(self, tmp_path):
        path = str(tmp_path / "new.jsonl")
        with MetricsSink(path, meta=META, resume=True) as sink:
            assert sink.n_records == 0
            sink.append({"episode": 0, "reward": 0.1})
        meta, records = read_metrics(path)
        assert meta == META and len(records) == 1


class TestWatchDegradation:
    def test_meta_only_file_renders_no_records_line(self, tmp_path):
        path = str(tmp_path / "run.jsonl")
        MetricsSink(path, meta=META).close()  # killed before episode 0
        text = watch.render(path, tail_k=5)
        assert "no records yet" in text
        assert "run:" in text

    def test_unknown_and_non_numeric_keys_skipped(self, tmp_path):
        path = str(tmp_path / "run.jsonl")
        with MetricsSink(path, meta=META) as sink:
            sink.append({"episode": 0, "reward": 1.0,
                         "brand_new_metric": 2.0, "note": "hello"})
            sink.append({"episode": 1, "reward": "oops-a-string"})
        text = watch.render(path, tail_k=5)
        assert "reward" in text
        assert "brand_new_metric" not in text
        assert "note" not in text
