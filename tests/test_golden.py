"""Golden-metrics regression test: a tiny fixed-seed ``train_fleet_scan``
run pinned against a checked-in history JSON.

Silent numerics drift in core/fleet.py (a reordered reduction, a changed
default, an accidental extra RNG split) shifts these numbers immediately —
this test makes that a tier-1 failure instead of a surprise three PRs later.
The tolerance is the repo's float32 fusion band (rtol=1e-4, atol=1e-5, same
as the scan-vs-reference equivalence tests): loose enough for XLA version /
CPU instruction-set differences, tight enough that any algorithmic change
trips it.

Regenerate (ONLY for an intentional, reviewed numerics change):
  PYTHONPATH=src python tests/test_golden.py --regen
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.configs.fcpo import FCPOConfig
from repro.core.fleet import fleet_init, train_fleet_scan
from repro.sim import make_scenario

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden", "fleet_history_golden.json")
A, EPISODES, SEED = 4, 2, 0
RTOL, ATOL = 1e-4, 1e-5


def run_pinned():
    """The pinned run: A=4 agents, 2 episodes of the full cadence (one FL
    round at fl_every=2), nominal scenario, default fluid backend and FL
    transport, fixed seeds everywhere."""
    cfg = FCPOConfig()
    fleet = fleet_init(cfg, A, jax.random.PRNGKey(SEED))
    traces = make_scenario("nominal", jax.random.PRNGKey(SEED + 1), A,
                           EPISODES * cfg.n_steps)
    _, hist = train_fleet_scan(cfg, fleet, traces, seed=SEED, donate=False)
    return {k: [float(x) for x in np.asarray(v).ravel()]
            for k, v in sorted(hist.items())}


def test_history_matches_golden():
    assert os.path.exists(GOLDEN_PATH), \
        f"missing {GOLDEN_PATH} — regenerate with " \
        f"PYTHONPATH=src python tests/test_golden.py --regen"
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    hist = run_pinned()
    assert set(hist) == set(golden["history"]), \
        "history metric keys changed — intentional? regenerate the golden"
    for k, want in golden["history"].items():
        got = hist[k]
        np.testing.assert_allclose(
            got, want, rtol=RTOL, atol=ATOL,
            err_msg=f"history[{k!r}] drifted from the golden run "
                    f"(regenerate ONLY for an intentional numerics change)")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--regen", action="store_true",
                    help="rewrite the golden JSON from the current code")
    args = ap.parse_args()
    if not args.regen:
        ap.error("run under pytest, or pass --regen to rewrite the golden")
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    payload = {
        "pinned": {"agents": A, "episodes": EPISODES, "seed": SEED,
                   "scenario": "nominal", "backend": "fluid",
                   "codec": "float32"},
        "jax_version": jax.__version__,
        "history": run_pinned(),
    }
    with open(GOLDEN_PATH, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {GOLDEN_PATH}")
