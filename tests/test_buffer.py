"""Streaming-moment diversity buffer: slot-for-slot equivalence against the
recompute oracle, batch/kernel/single-step agreement, and sufficient-
statistic invariants. (tests the Eq. 6 engine behind benchmarks/
fig_buffer_perf.py's ≥3x claim)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.fcpo import FCPOConfig
from repro.core.buffer import (buffer_clear, buffer_init, buffer_insert,
                               buffer_insert_batch, buffer_insert_reference,
                               buffer_resync, mahalanobis)
from repro.core.crl import run_episode, run_episode_reference
from repro.core.fleet import fleet_init
from repro.data.workload import fleet_traces
from repro.kernels import ref as kref

KEY = jax.random.PRNGKey(0)


def random_candidates(key, cfg, t, scale=3.0):
    na = cfg.n_res + cfg.n_bs + cfg.n_mt
    ks = jax.random.split(key, 6)
    return dict(
        states=jax.random.normal(ks[0], (t, cfg.state_dim)) * scale,
        actions=jax.random.randint(ks[1], (t, 3), 0, 4),
        logp=-jnp.abs(jax.random.normal(ks[2], (t,))),
        rewards=jnp.tanh(jax.random.normal(ks[3], (t,))),
        values=jax.random.normal(ks[4], (t,)) * 0.1,
        probs=jax.nn.softmax(jax.random.normal(ks[5], (t, na)), -1),
    )


def insert_seq(insert_fn, cfg, buf, cand):
    fn = jax.jit(lambda b, *a: insert_fn(cfg, b, *a))
    for t in range(cand["states"].shape[0]):
        buf = fn(buf, cand["states"][t], cand["actions"][t], cand["logp"][t],
                 cand["rewards"][t], cand["values"][t], cand["probs"][t])
    return buf


def finite(x):
    return np.nan_to_num(np.asarray(x), posinf=0.0, neginf=0.0)


def assert_buffers_match(a, b, score_tol=1e-4):
    """Same slots evicted (exact payload identity) and scores within tol."""
    np.testing.assert_array_equal(np.asarray(a.filled), np.asarray(b.filled))
    np.testing.assert_array_equal(np.asarray(a.states), np.asarray(b.states))
    np.testing.assert_array_equal(np.asarray(a.actions), np.asarray(b.actions))
    np.testing.assert_array_equal(np.asarray(a.probs), np.asarray(b.probs))
    assert np.max(np.abs(finite(a.score) - finite(b.score))) < score_tol


class TestStreamingVsReference:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_randomized_sequences_same_evictions(self, seed):
        """Streaming single-insert chain == recompute-oracle chain: identical
        eviction decisions over a long randomized sequence, scores within
        1e-4 (float32 cancellation is the only difference)."""
        cfg = FCPOConfig(buffer_size=8)
        cand = random_candidates(jax.random.PRNGKey(seed), cfg, 48)
        b_ref = insert_seq(buffer_insert_reference, cfg, buffer_init(cfg), cand)
        b_str = insert_seq(buffer_insert, cfg, buffer_init(cfg), cand)
        assert_buffers_match(b_str, b_ref)
        assert int(b_str.n_filled) == int(np.asarray(b_ref.filled).sum())

    def test_batch_matches_sequential_stream(self):
        """buffer_insert_batch == T chained buffer_insert calls (same math,
        different schedule)."""
        cfg = FCPOConfig(buffer_size=8)
        cand = random_candidates(jax.random.PRNGKey(7), cfg, 40)
        b_seq = insert_seq(buffer_insert, cfg, buffer_init(cfg), cand)
        b_bat = jax.jit(lambda b: buffer_insert_batch(
            cfg, b, cand["states"], cand["actions"], cand["logp"],
            cand["rewards"], cand["values"], cand["probs"]))(buffer_init(cfg))
        assert_buffers_match(b_bat, b_seq, score_tol=1e-5)
        np.testing.assert_array_equal(np.asarray(b_bat.logp),
                                      np.asarray(b_seq.logp))
        np.testing.assert_array_equal(np.asarray(b_bat.rewards),
                                      np.asarray(b_seq.rewards))
        assert int(b_bat.count) == int(b_seq.count) == 40

    def test_reference_built_buffer_feeds_streaming(self):
        """buffer_insert_reference maintains the moments, so a reference-built
        buffer is a valid streaming-engine input mid-sequence."""
        cfg = FCPOConfig(buffer_size=8)
        cand = random_candidates(jax.random.PRNGKey(3), cfg, 30)
        half = {k: v[:15] for k, v in cand.items()}
        rest = {k: v[15:] for k, v in cand.items()}
        b_mixed = insert_seq(buffer_insert, cfg,
                             insert_seq(buffer_insert_reference, cfg,
                                        buffer_init(cfg), half), rest)
        b_ref = insert_seq(buffer_insert_reference, cfg, buffer_init(cfg),
                           cand)
        assert_buffers_match(b_mixed, b_ref)


class TestStreamingMoments:
    def test_moments_match_recomputed_statistics(self):
        """Property: after any insert/evict/clear history the running
        sufficient statistics equal the statistics recomputed from the
        stored slots, and the covariance they imply matches the
        recompute-oracle covariance."""
        cfg = FCPOConfig(buffer_size=6)
        cand = random_candidates(jax.random.PRNGKey(11), cfg, 25)
        buf = insert_seq(buffer_insert, cfg, buffer_init(cfg), cand)
        buf = buffer_clear(buf)  # mid-history reset must zero the moments
        assert int(buf.n_filled) == 0
        assert float(jnp.abs(buf.s_outer).max()) == 0.0
        cand2 = random_candidates(jax.random.PRNGKey(12), cfg, 25)
        buf = insert_seq(buffer_insert, cfg, buf, cand2)

        w = np.asarray(buf.filled, np.float32)
        states = np.asarray(buf.states)
        probs = np.asarray(buf.probs)
        assert int(buf.n_filled) == int(w.sum())
        np.testing.assert_allclose(np.asarray(buf.s_sum),
                                   (states * w[:, None]).sum(0), atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(buf.s_outer),
            np.einsum("nd,ne,n->de", states, states, w), atol=1e-3)
        np.testing.assert_allclose(np.asarray(buf.p_sum),
                                   (probs * w[:, None]).sum(0), atol=1e-5)

        # implied covariance == oracle covariance
        n = max(w.sum(), 1.0)
        mu = np.asarray(buf.s_sum) / n
        cov_stream = np.asarray(buf.s_outer) / n - np.outer(mu, mu)
        diff = (states - mu) * w[:, None]
        cov_oracle = diff.T @ diff / n
        np.testing.assert_allclose(cov_stream, cov_oracle, atol=1e-3)

    def test_resync_restores_exact_statistics_after_long_history(self):
        """buffer_resync (called on the FL-round cadence by fl_round) snaps
        the rank-1-updated moments back to the exact slot statistics, so
        float32 add/subtract drift cannot accumulate across a training
        run."""
        cfg = FCPOConfig(buffer_size=4)
        buf = buffer_init(cfg)
        for chunk in range(8):  # 8 x 32 = 256 insert/evict cycles
            cand = random_candidates(jax.random.PRNGKey(chunk), cfg, 32,
                                     scale=5.0)
            buf = insert_seq(buffer_insert, cfg, buf, cand)
            buf = jax.jit(buffer_resync)(buf)
            w = np.asarray(buf.filled, np.float32)
            states = np.asarray(buf.states)
            np.testing.assert_allclose(
                np.asarray(buf.s_sum), (states * w[:, None]).sum(0),
                rtol=1e-6, atol=1e-6)
            np.testing.assert_allclose(
                np.asarray(buf.s_outer),
                np.einsum("nd,ne,n->de", states, states, w),
                rtol=1e-5, atol=1e-5)
            assert int(buf.n_filled) == int(w.sum())

    def test_score_from_moments_matches_mahalanobis_oracle(self):
        cfg = FCPOConfig(buffer_size=8)
        cand = random_candidates(jax.random.PRNGKey(5), cfg, 20)
        buf = insert_seq(buffer_insert, cfg, buffer_init(cfg), cand)
        probe = jnp.linspace(-2.0, 2.0, cfg.state_dim)
        d_oracle = mahalanobis(probe, buf.states, buf.filled)
        na = cfg.n_res + cfg.n_bs + cfg.n_mt
        d_stream = kref.diversity_score_from_moments(
            probe, jnp.full((na,), 1.0 / na), buf.s_sum, buf.s_outer,
            buf.p_sum, buf.n_filled, alpha=1.0, beta=0.0)
        np.testing.assert_allclose(float(d_stream), float(d_oracle), atol=1e-4)


@pytest.mark.pallas
class TestPallasKernel:
    def test_kernel_matches_jnp_oracle(self):
        """Fused diversity_insert kernel (interpret mode on CPU) ==
        diversity_insert_ref, bit-for-bit over a batched fleet."""
        from repro.kernels import ops as kops

        cfg = FCPOConfig(buffer_size=8)
        na = cfg.n_res + cfg.n_bs + cfg.n_mt
        a, t = 4, 20
        k1, k2 = jax.random.split(KEY)
        cs = jax.random.normal(k1, (a, t, cfg.state_dim)) * 2.0
        cp = jax.nn.softmax(jax.random.normal(k2, (a, t, na)), -1)
        buf = buffer_init(cfg)
        batched = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (a,) + x.shape),
            (buf.states, buf.probs, buf.score, buf.filled, buf.s_sum,
             buf.s_outer, buf.p_sum, buf.n_filled))

        out_pal = kops.diversity_insert(*batched, cs, cp, alpha=cfg.alpha,
                                        beta=cfg.beta)
        out_ref = jax.vmap(lambda *xs: kref.diversity_insert_ref(
            *xs, alpha=cfg.alpha, beta=cfg.beta))(*batched, cs, cp)
        for name, pal, ref in zip(
                ("states", "probs", "score", "filled", "s_sum", "s_outer",
                 "p_sum", "n_filled", "slot", "do", "d"), out_pal, out_ref):
            np.testing.assert_allclose(
                finite(pal.astype(jnp.float32)),
                finite(ref.astype(jnp.float32)), atol=1e-5, err_msg=name)

    def test_batch_insert_use_pallas_end_to_end(self):
        cfg = FCPOConfig(buffer_size=8)
        cand = random_candidates(jax.random.PRNGKey(9), cfg, 16)
        args = (cand["states"], cand["actions"], cand["logp"],
                cand["rewards"], cand["values"], cand["probs"])
        b_jnp = buffer_insert_batch(cfg, buffer_init(cfg), *args)
        b_pal = buffer_insert_batch(cfg, buffer_init(cfg), *args,
                                    use_pallas=True)
        assert_buffers_match(b_pal, b_jnp, score_tol=1e-5)


class TestEpisodeTrajectoryEquivalence:
    def test_run_episode_matches_per_step_reference_inserts(self):
        """The acceptance gate behind benchmarks/fig_buffer_perf.py: the
        restructured episode loop (scan = env+policy, one batch insert)
        produces the same trajectory AND the same buffer (slots evicted
        identical, scores within 1e-4) as the seed loop with per-step
        recompute-oracle inserts (``run_episode_reference`` — the same
        definition the benchmark A/Bs)."""
        cfg = FCPOConfig(buffer_size=16)
        n_agents, t_steps = 4, 32
        fleet = fleet_init(cfg, n_agents, KEY)
        rates = fleet_traces(jax.random.PRNGKey(1), n_agents, t_steps)

        ref_state, ref_roll, _ = jax.jit(jax.vmap(
            lambda ep, st, r, m: run_episode_reference(cfg, ep, st, r, m)))(
            fleet.env_params, fleet.astate, rates, fleet.masks)
        new_state, rollout, _ = jax.jit(jax.vmap(
            lambda ep, st, r, m: run_episode(cfg, ep, st, r, m)))(
            fleet.env_params, fleet.astate, rates, fleet.masks)

        np.testing.assert_array_equal(np.asarray(rollout.states),
                                      np.asarray(ref_roll.states))
        np.testing.assert_array_equal(np.asarray(rollout.rewards),
                                      np.asarray(ref_roll.rewards))
        assert_buffers_match(new_state.buffer, ref_state.buffer)
        np.testing.assert_array_equal(np.asarray(new_state.rng),
                                      np.asarray(ref_state.rng))
