"""State dtype policies (``repro.core.dtypes``): storage-only precision.

The contract: a policy changes where the fleet's state LIVES (bf16
optimizer/env/transport leaves, int8 replay payloads, bf16 params), never
what the training math computes — every hot path upcasts to float32, steps,
and writes back at the stored dtype. So scan==reference must hold under
every policy, the default (None / "float32") must trace the exact pre-policy
program bit-for-bit, and the lean policy must halve stored bytes per agent
at scale.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.fcpo import FCPOConfig
from repro.core import dtypes as dtp
from repro.core.fleet import (fleet_cast, fleet_init, fleet_state_bytes,
                              train_fleet_reference, train_fleet_scan)
from repro.data.workload import fleet_traces
from repro.training import checkpoint as ckpt_mod

CFG = FCPOConfig()
KEY = jax.random.PRNGKey(0)
POLICY_NAMES = tuple(dtp.POLICIES)


class TestPolicyTable:
    def test_default_policy_is_all_float32(self):
        pol = dtp.get_policy(None)
        assert pol.name == "float32"
        assert {pol.opt, pol.env, pol.transport, pol.buffer,
                pol.model} == {"float32"}

    def test_lean_policy_families(self):
        pol = dtp.get_policy("lean")
        assert pol.buffer == "int8"
        assert pol.opt == pol.model == "bfloat16"

    def test_quant8_is_idempotent(self):
        x = jnp.linspace(-5.0, 5.0, 257)
        q = dtp.quant8(x, dtp.STATE_SCALE)
        rq = dtp.quant8(dtp.dequant8(q, dtp.STATE_SCALE), dtp.STATE_SCALE)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(rq))
        assert q.dtype == jnp.int8

    def test_cast_floats_leaves_ints_alone(self):
        tree = {"f": jnp.ones(3), "i": jnp.arange(3, dtype=jnp.int32),
                "b": jnp.zeros(2, jnp.bool_)}
        out = dtp.cast_floats(tree, "bfloat16")
        assert out["f"].dtype == jnp.bfloat16
        assert out["i"].dtype == jnp.int32
        assert out["b"].dtype == jnp.bool_


class TestFleetCast:
    def test_leaf_count_is_policy_invariant(self):
        """Fixed-scale int8 quantization adds no per-tensor scale leaves, so
        the donation audit's leaf count holds under every policy."""
        f32 = fleet_init(CFG, 4, KEY, n_pods=2)
        lean = fleet_init(CFG, 4, KEY, n_pods=2, state_policy="lean")
        assert len(jax.tree.leaves(f32)) == len(jax.tree.leaves(lean))

    def test_float32_cast_is_identity(self):
        fleet = fleet_init(CFG, 4, KEY, n_pods=2)
        cast = fleet_cast(fleet, "float32")
        for a, b in zip(jax.tree.leaves(fleet), jax.tree.leaves(cast)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_precision_critical_buffer_parts_stay_f32(self):
        """Eviction scores (argmin) and the Cholesky moments must never
        quantize — Eq. 6 selection order and diversity stats are exact."""
        lean = fleet_init(CFG, 4, KEY, n_pods=2, state_policy="lean")
        buf = lean.astate.buffer
        assert buf.states.dtype == jnp.int8
        assert buf.probs.dtype == jnp.int8
        for leaf in (buf.score, buf.s_sum, buf.s_outer, buf.p_sum):
            assert leaf.dtype == jnp.float32
        for leaf in jax.tree.leaves(lean.astate.opt["m"]):
            assert leaf.dtype == jnp.bfloat16

    def test_lean_state_ratio_at_scale(self):
        """The scaling gate's invariant at a tier-1-affordable shape: lean
        storage must be >= 2x smaller per agent than float32 (measured
        2.03x at A=256/P=8 — base networks amortize at scale)."""
        a, p = 256, 8
        f32 = fleet_state_bytes(fleet_init(CFG, a, KEY, n_pods=p))
        lean = fleet_state_bytes(
            fleet_init(CFG, a, KEY, n_pods=p, state_policy="lean"))
        assert f32["per_agent"] / lean["per_agent"] >= 2.0


class TestScanReferenceEquivalence:
    @pytest.mark.parametrize("policy", POLICY_NAMES)
    def test_scan_matches_reference_per_policy(self, policy):
        """The same low-precision carry goes through both drivers: any
        missing write-back cast would diverge them within a few episodes."""
        n, eps = 4, 8
        traces = fleet_traces(jax.random.PRNGKey(1), n, eps * CFG.n_steps)
        kw = dict(straggler_prob=0.3, seed=7)
        rf, rh = train_fleet_reference(
            CFG, fleet_init(CFG, n, KEY, n_pods=2, state_policy=policy),
            traces, **kw)
        sf, sh = train_fleet_scan(
            CFG, fleet_init(CFG, n, KEY, n_pods=2, state_policy=policy),
            traces, **kw)
        for k in rh:
            np.testing.assert_allclose(sh[k], rh[k], rtol=1e-4, atol=1e-5,
                                       err_msg=f"{policy}:{k}")
        for a, b in zip(jax.tree.leaves(rf.astate.params),
                        jax.tree.leaves(sf.astate.params)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=1e-4, atol=1e-5)

    def test_default_config_is_bit_identical_to_explicit_f32(self):
        """state_policy=None must trace the exact pre-policy program: the
        all-float32 astype write-backs are identities, so the compiled
        computation — and every number — is unchanged."""
        n, eps = 4, 6
        traces = fleet_traces(jax.random.PRNGKey(1), n, eps * CFG.n_steps)
        f_none, h_none = train_fleet_scan(
            CFG, fleet_init(CFG, n, KEY, n_pods=2), traces, seed=7)
        f_f32, h_f32 = train_fleet_scan(
            CFG, fleet_init(CFG, n, KEY, n_pods=2, state_policy="float32"),
            traces, seed=7)
        for k in h_none:
            np.testing.assert_array_equal(np.asarray(h_none[k]),
                                          np.asarray(h_f32[k]), err_msg=k)
        for a, b in zip(jax.tree.leaves(f_none), jax.tree.leaves(f_f32)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_lean_history_close_to_f32(self):
        """Storage precision shifts trajectories only marginally: the first
        episodes are identical-ish and rewards stay at parity."""
        n, eps = 4, 8
        traces = fleet_traces(jax.random.PRNGKey(1), n, eps * CFG.n_steps)
        _, h32 = train_fleet_scan(
            CFG, fleet_init(CFG, n, KEY, n_pods=2), traces, seed=7)
        _, hl = train_fleet_scan(
            CFG, fleet_init(CFG, n, KEY, n_pods=2, state_policy="lean"),
            traces, seed=7)
        tail = max(eps // 4, 2)
        gap = abs(float(np.mean(hl["reward"][-tail:]))
                  - float(np.mean(h32["reward"][-tail:])))
        assert gap < 0.1, f"lean reward diverged from f32 by {gap}"


class TestCheckpointDtypes:
    @pytest.mark.parametrize("policy", POLICY_NAMES)
    def test_roundtrip_exact_per_policy(self, policy):
        """np.savez stores bf16 as raw void bytes; the manifest's dtype map
        views them back exactly (int8 and f32 round-trip natively)."""
        fleet = fleet_init(CFG, 3, KEY, n_pods=1, state_policy=policy)
        with tempfile.TemporaryDirectory() as d:
            ckpt_mod.save(d, 1, fleet)
            restored, manifest = ckpt_mod.restore(d, 1, fleet)
        assert "dtypes" in manifest
        for a, b in zip(jax.tree.leaves(fleet), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_cross_policy_restore_widens_bf16(self):
        """Elastic restore across state policies: a lean checkpoint restores
        into a float32 fleet structure — bf16 leaves widen exactly."""
        lean = fleet_init(CFG, 3, KEY, n_pods=1, state_policy="lean")
        f32_like = fleet_cast(lean, "float32")
        with tempfile.TemporaryDirectory() as d:
            ckpt_mod.save(d, 1, lean)
            restored, _ = ckpt_mod.restore(d, 1, f32_like)
        for a, b in zip(jax.tree.leaves(lean.astate.params),
                        jax.tree.leaves(restored.astate.params)):
            assert b.dtype == jnp.float32
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b))

    def test_resume_reproduces_uninterrupted_lean_run(self):
        """Kill-and-resume under the lean policy: restore-then-continue must
        reproduce the uninterrupted run (the checkpoint holds the exact
        stored-precision leaves, not widened copies)."""
        n, eps = 3, 6
        traces = fleet_traces(jax.random.PRNGKey(1), n, eps * CFG.n_steps)
        mk = lambda: fleet_init(CFG, n, KEY, n_pods=1, state_policy="lean")
        full, hf = train_fleet_scan(CFG, mk(), traces, seed=7,
                                    total_episodes=eps)
        half1, _ = train_fleet_scan(CFG, mk(),
                                    traces[:, :3 * CFG.n_steps], seed=7,
                                    total_episodes=eps)
        with tempfile.TemporaryDirectory() as d:
            ckpt_mod.save(d, 3, half1)
            restored, _ = ckpt_mod.restore(d, 3, mk())
        half2, _ = train_fleet_scan(CFG, restored,
                                    traces[:, 3 * CFG.n_steps:], seed=7,
                                    episode_offset=3, total_episodes=eps)
        for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(half2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
