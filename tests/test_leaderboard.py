"""Standing eval harness (repro.eval): leaderboard cell determinism, the
regression gate, envelope provenance, checkpoint restore, and the streaming
metrics round-trip through launch/watch.py."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs.fcpo import FCPOConfig
from repro.core.fleet import fleet_init, train_fleet_scan
from repro.eval.leaderboard import (Cell, GATE_METRICS, attach_deltas,
                                    cell_seed, check_regressions,
                                    evaluate_cell, grid_cells, load_fleet,
                                    run_leaderboard)
from repro.eval.stream import (MetricsSink, fl_round_summary, read_metrics,
                               tail_summary)
from repro.launch import train_fleet as train_fleet_cli
from repro.launch import watch
from repro.training import checkpoint as ckpt_mod

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)  # benchmarks/ is a repo-root namespace package

from benchmarks import leaderboard as lb_cli  # noqa: E402
from benchmarks.common import git_sha, load_bench, save_bench  # noqa: E402

CFG = FCPOConfig()
# tiny-but-real cell kwargs shared by every compute test in this module (the
# jit cache makes repeat evaluations cheap once the first cell compiled)
TINY = dict(episodes=2, eval_intervals=8, replicates=1, seed=0)


@pytest.fixture(scope="module")
def fleet():
    return fleet_init(CFG, 2, jax.random.PRNGKey(0))


def _assert_rows_identical(a, b):
    assert a.keys() == b.keys()
    for k in a:
        assert a[k] == b[k], f"{k}: {a[k]} != {b[k]}"


class TestDeterminism:
    def test_cell_metrics_bit_identical_across_runs(self, fleet):
        cell = Cell("steady", "fluid", "int8")
        r1 = evaluate_cell(CFG, fleet, cell, **TINY)
        r2 = evaluate_cell(CFG, fleet, cell, **TINY)
        _assert_rows_identical(r1, r2)

    def test_rows_independent_of_n_jobs_ordering(self, fleet):
        cells = [Cell("steady", "fluid", "int8"),
                 Cell("ood", "fluid", "float32"),
                 Cell("steady", "fluid", "float32")]
        seq = run_leaderboard(CFG, fleet, cells, n_jobs=1, **TINY)
        striped = run_leaderboard(CFG, fleet, cells, n_jobs=2, **TINY)
        assert [r["name"] for r in seq] == [c.name for c in cells]
        for a, b in zip(seq, striped):
            _assert_rows_identical(a, b)

    def test_cell_seed_is_stable_and_per_cell(self):
        c1 = Cell("steady", "fluid", "int8")
        c2 = Cell("steady", "twin", "int8")
        # crc32, not salted hash(): the value must be reproducible across
        # processes — pin one
        assert cell_seed(0, c1, 0) == cell_seed(0, c1, 0)
        seeds = {cell_seed(0, c, r) for c in (c1, c2) for r in (0, 1)}
        assert len(seeds) == 4  # distinct per (cell, replicate)
        assert cell_seed(0, c1, 0, "eval") != cell_seed(0, c1, 0)

    def test_grid_is_dense_and_ordered(self):
        cells = grid_cells()
        assert len(cells) == 9 * 2 * 3
        assert len({c.name for c in cells}) == len(cells)
        assert cells[0].scenario == cells[5].scenario  # scenario-major


class TestGate:
    def _rows(self):
        return [{"name": "leaderboard_steady_fluid_int8",
                 "reward_mean": 0.5, "eval_eff_mean": 40.0},
                {"name": "leaderboard_ood_twin_topk",
                 "reward_mean": -0.2, "eval_eff_mean": 20.0}]

    def test_attach_deltas_and_pass_within_tol(self):
        rows = self._rows()
        prev = {"results": [dict(r) for r in rows]}
        attach_deltas(rows, prev)
        for r in rows:
            for m in GATE_METRICS:
                assert r[f"prev_{m}"] == r[m] and r[f"delta_{m}"] == 0.0
        assert check_regressions(rows) == []

    def test_regression_beyond_tol_fails_per_cell(self):
        rows = self._rows()
        prev = {"results": [dict(r) for r in rows]}
        rows[0]["eval_eff_mean"] = 30.0  # 25% drop > 10% tol
        attach_deltas(rows, prev)
        fails = check_regressions(rows)
        assert len(fails) == 1 and "eval_eff_mean" in fails[0]
        assert "leaderboard_steady_fluid_int8" in fails[0]

    def test_improvement_and_new_cells_never_fail(self):
        rows = self._rows()
        prev = {"results": [dict(rows[0])]}  # second cell is new
        rows[0]["eval_eff_mean"] = 80.0  # improvement
        attach_deltas(rows, prev)
        assert "prev_reward_mean" not in rows[1]
        assert check_regressions(rows) == []

    def test_absolute_floor_absorbs_near_zero_noise(self):
        rows = [{"name": "c", "reward_mean": -0.003, "eval_eff_mean": 1.0,
                 "prev_reward_mean": 0.001, "prev_eval_eff_mean": 1.0}]
        # drop of 0.004 < tol * floor(0.05) = 0.005 -> not a regression
        assert check_regressions(rows) == []

    def test_per_cell_tolerance_override(self):
        rows = [{"name": "c", "reward_mean": 0.8, "eval_eff_mean": 40.0,
                 "prev_reward_mean": 1.0, "prev_eval_eff_mean": 40.0}]
        assert check_regressions(rows, tol=0.10)  # 20% drop fails at 10%
        assert check_regressions(rows, tolerances={"c": 0.5}) == []


class TestGateCLI:
    """`benchmarks/leaderboard.py --gate` exits non-zero on an injected
    regression — the acceptance criterion, end-to-end through the CLI."""
    ARGS = ["--scenarios", "steady", "--backends", "fluid",
            "--codecs", "int8", "--agents", "2", "--episodes", "2",
            "--eval-intervals", "8", "--replicates", "1", "--gate"]

    def test_gate_passes_then_fails_on_injected_regression(self, tmp_path):
        out = ["--out-dir", str(tmp_path)]
        assert lb_cli.main(self.ARGS + out) == 0  # first run: no prev
        assert lb_cli.main(self.ARGS + out) == 0  # identical run: pass
        env_path = tmp_path / "BENCH_leaderboard.json"
        env = json.loads(env_path.read_text())
        row = env["results"][0]
        assert "delta_reward_mean" in row and row["delta_reward_mean"] == 0.0
        # inject: pretend the previous run was much better
        for r in env["results"]:
            r["reward_mean"] += 1.0
            r["eval_eff_mean"] *= 2.0
        env_path.write_text(json.dumps(env))
        assert lb_cli.main(self.ARGS + out) == 1

    def test_envelope_has_grid_and_provenance(self, tmp_path):
        assert lb_cli.main(self.ARGS + ["--out-dir", str(tmp_path)]) == 0
        env = json.loads((tmp_path / "BENCH_leaderboard.json").read_text())
        assert env["grid"] == {"scenarios": ["steady"],
                               "backends": ["fluid"], "codecs": ["int8"]}
        assert env["git_sha"] == git_sha()
        assert env["jax_version"] == jax.__version__
        row = env["results"][0]
        for k in ("reward_mean", "reward_std", "eval_eff_mean",
                  "eval_p99_mean", "eval_slo_mean", "fl_payload_bytes"):
            assert k in row


class TestEnvelopeProvenance:
    def test_save_bench_stamps_sha_jax_backend(self, tmp_path):
        path = save_bench("prov", [{"name": "x", "v": 1.0}],
                          out_dir=str(tmp_path))
        env = json.loads(open(path).read())
        sha = git_sha()
        assert env["git_sha"] == sha and len(sha) == 40
        assert env["jax_version"] == jax.__version__
        assert env["backend"] == jax.default_backend()
        assert env["results"] == [{"name": "x", "v": 1.0}]

    def test_load_bench_roundtrip_and_missing(self, tmp_path):
        assert load_bench("prov", out_dir=str(tmp_path)) is None
        save_bench("prov", [{"name": "x"}], out_dir=str(tmp_path),
                   extra={"note": "hi"})
        env = load_bench("prov", out_dir=str(tmp_path))
        assert env["note"] == "hi" and env["name"] == "prov"

    def test_git_sha_matches_head(self):
        head = subprocess.run(["git", "rev-parse", "HEAD"], cwd=ROOT,
                              capture_output=True, text=True).stdout.strip()
        assert git_sha() == head


class TestCheckpointEval:
    def test_restored_fleet_scores_identically(self, fleet, tmp_path):
        ckpt_mod.save(str(tmp_path), 7, fleet)
        restored = load_fleet(CFG, str(tmp_path), n_agents=2)
        cell = Cell("steady", "fluid", "float32")
        _assert_rows_identical(evaluate_cell(CFG, fleet, cell, **TINY),
                               evaluate_cell(CFG, restored, cell, **TINY))

    def test_load_fleet_empty_dir_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError, match="no checkpoint"):
            load_fleet(CFG, str(tmp_path), n_agents=2)


class TestStreamingMetrics:
    def test_scan_stream_matches_returned_history(self, fleet, tmp_path):
        from repro.core.backends import get_backend
        from repro.sim import make_scenario
        path = str(tmp_path / "m.jsonl")
        traces = make_scenario("steady", jax.random.PRNGKey(1), 2,
                               3 * CFG.n_steps)
        with MetricsSink(path, meta={"driver": "scan"}) as sink:
            _, hist = train_fleet_scan(CFG, fleet, traces, seed=0,
                                       donate=False,
                                       env_backend=get_backend("fluid"),
                                       metrics_sink=sink)
        meta, records = read_metrics(path)
        assert meta == {"driver": "scan"} and len(records) == 3
        for e, rec in enumerate(records):
            assert rec["episode"] == e
            for k, v in rec.items():
                if k != "episode":
                    assert v == float(np.asarray(hist[k][e]))
        assert tail_summary(records)["reward"]["last"] == \
            float(np.asarray(hist["reward"][-1]))

    def test_cli_metrics_out_roundtrips_through_watch(self, tmp_path, capsys):
        path = str(tmp_path / "run.jsonl")
        train_fleet_cli.main(["--agents", "2", "--episodes", "4",
                              "--fl-codec", "int8", "--metrics-out", path])
        capsys.readouterr()
        watch.main([path, "--tail", "2"])
        out = capsys.readouterr().out
        assert "episodes recorded: 4" in out
        assert "fl_codec=int8" in out and "reward" in out
        assert "FL:" in out and "KB/round" in out
        meta, records = read_metrics(path)
        assert meta["agents"] == 2 and meta["driver"] == "scan"
        fl = fl_round_summary(records)
        assert fl is not None and fl["rounds"] == 2  # fl_every=2, 4 episodes

    def test_read_metrics_tolerates_torn_tail(self, tmp_path):
        path = str(tmp_path / "torn.jsonl")
        with MetricsSink(path, meta={"a": 1}) as sink:
            sink.append({"episode": 0, "reward": 1.0})
        with open(path, "a") as f:
            f.write('{"episode": 1, "rew')  # writer mid-append
        meta, records = read_metrics(path)
        assert meta == {"a": 1}
        assert len(records) == 1 and records[0]["episode"] == 0

    def test_watch_render_without_fl_rounds(self, tmp_path):
        path = str(tmp_path / "nofl.jsonl")
        with MetricsSink(path) as sink:
            for e in range(3):
                sink.append({"episode": e, "reward": 0.1 * e,
                             "fl_payload_bytes": 0.0})
        text = watch.render(path, tail_k=2)
        assert "episodes recorded: 3" in text and "FL:" not in text


class TestGracefulDegradation:
    """A corrupt/truncated/incompatible previous envelope degrades to
    "no baseline" with a warning — it must never take the gate down."""

    def _rows(self):
        return [{"name": "leaderboard_steady_fluid_int8", "agents": 4,
                 "episodes": 6, "eval_intervals": 30, "replicates": 3,
                 "seed": 0, "reward_mean": 0.5, "eval_eff_mean": 40.0}]

    def test_sanitize_rejects_non_envelopes(self):
        from repro.eval.leaderboard import sanitize_envelope
        warns = []
        assert sanitize_envelope(None) is None
        for bad in ([1, 2, 3], "truncated", {"no_results": 1},
                    {"results": "not-a-list"}):
            assert sanitize_envelope(bad, warn=warns.append) is None
        assert len(warns) == 4
        good = {"results": []}
        assert sanitize_envelope(good) is good

    def test_sanitize_refuses_cross_backend_envelopes(self):
        import jax

        from repro.eval.leaderboard import sanitize_envelope
        here = {"backend": jax.default_backend(),
                "device_count": jax.device_count()}
        # same backend + device count (what save_bench stamps): usable
        same = {"results": [], **here}
        assert sanitize_envelope(same) is same
        # legacy envelope without the stamps: nothing to refuse on
        legacy = {"results": []}
        assert sanitize_envelope(legacy) is legacy
        # a baseline measured on different hardware is refused with a warning
        for key, other in (("backend", "tpu-imaginary"),
                           ("device_count", here["device_count"] + 8)):
            warns = []
            bad = {"results": [], **dict(here, **{key: other})}
            assert sanitize_envelope(bad, warn=warns.append) is None
            assert len(warns) == 1 and key in warns[0]

    def test_attach_deltas_survives_garbage_envelope(self):
        rows = self._rows()
        attach_deltas(rows, {"results": [None, 17, "x", {"noname": 1}]})
        assert not any(k.startswith(("prev_", "delta_")) for k in rows[0])
        assert check_regressions(rows) == []

    def test_incompatible_grid_skips_cell_with_warning(self):
        rows = self._rows()
        prev_row = dict(rows[0], agents=8, eval_eff_mean=400.0)
        warns = []
        attach_deltas(rows, {"results": [prev_row]}, warn=warns.append)
        assert "prev_eval_eff_mean" not in rows[0]
        assert len(warns) == 1 and "agents" in warns[0]
        assert check_regressions(rows) == []

    def test_non_numeric_and_non_finite_prev_values_skip_metric(self):
        rows = self._rows()
        prev_row = dict(rows[0])
        prev_row["reward_mean"] = "NaN-ish garbage"
        prev_row["eval_eff_mean"] = float("nan")
        attach_deltas(rows, {"results": [prev_row]})
        assert "prev_reward_mean" not in rows[0]
        assert "prev_eval_eff_mean" not in rows[0]
        assert check_regressions(rows) == []

    def test_check_regressions_skips_malformed_rows(self):
        rows = [None, "x", {"reward_mean": 1.0},
                {"name": "c", "reward_mean": 0.1, "eval_eff_mean": 1.0,
                 "prev_reward_mean": "garbage",
                 "prev_eval_eff_mean": float("inf")}]
        assert check_regressions(rows) == []

    def test_cli_survives_corrupt_previous_envelope(self, tmp_path):
        """End-to-end: a truncated BENCH json on disk -> warning + no
        baseline, exit 0."""
        out = tmp_path / "BENCH_leaderboard_smoke.json"
        out.write_text('{"results": [{"name": "lead')  # torn write
        rc = lb_cli.main(["--smoke", "--gate", "--scenarios", "steady",
                          "--backends", "fluid", "--codecs", "float32",
                          "--agents", "2", "--episodes", "2",
                          "--eval-intervals", "8", "--replicates", "1",
                          "--out-dir", str(tmp_path)])
        assert rc == 0
        # and the fresh envelope it wrote IS parseable
        assert json.load(open(out))["results"]


@pytest.mark.slow
class TestFullGrid:
    """Full 9 x 2 x 3 grid (RUN_SLOW=1): every cell evaluates and the
    envelope covers the whole grid."""

    def test_full_grid_evaluates_every_cell(self, fleet):
        rows = run_leaderboard(CFG, fleet, grid_cells(), **TINY)
        assert len(rows) == 54
        assert len({r["name"] for r in rows}) == 54
        for r in rows:
            assert np.isfinite([r["reward_mean"], r["eval_eff_mean"],
                                r["eval_p99_mean"], r["eval_slo_mean"]]).all()
