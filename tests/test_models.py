"""Per-architecture smoke tests (reduced configs, same code paths) +
cache-consistency checks for every decode-capable family."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.registry import get_config, get_model, input_specs, list_archs
from repro.configs.base import SHAPES, shape_applicable
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step

ARCHS = list_archs()


def tiny_batch(cfg, key, b=2, s=32, train=False):
    batch = {}
    if cfg.frontend == "frames":
        batch["embeds"] = jax.random.normal(key, (b, s, cfg.frontend_dim))
        if train:
            batch["mask"] = jax.random.bernoulli(key, 0.3, (b, s))
            batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        return batch
    batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.frontend == "patches":
        batch["patches"] = jax.random.normal(key, (b, cfg.n_patches, cfg.frontend_dim))
    if train:
        batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = tiny_batch(cfg, key)
    logits, cache, aux = model.apply(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert cache is None
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux["moe_aux"]).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(1)
    state = init_train_state(model, key)
    step = make_train_step(model, AdamWConfig(warmup_steps=1, total_steps=10),
                           remat=True)
    batch = tiny_batch(cfg, key, train=True)
    state2, metrics = jax.jit(step)(state, batch)
    assert float(metrics["loss"]) > 0 and not bool(jnp.isnan(metrics["loss"]))
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         state["params"], state2["params"])
    assert max(jax.tree.leaves(moved)) > 0


DECODE_ARCHS = [a for a in ARCHS
                if shape_applicable(get_config(a), "decode_32k")[0]]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        cfg = cfg.replace(capacity_factor=8.0)  # disable drops for exactness
    model = get_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    b, s, max_len = 2, 32, 48
    tokens = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    full, _, _ = model.apply(params, {"tokens": tokens})
    cache = jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                         model.cache_spec(b, max_len, jnp.float32))
    pre, cache, _ = model.apply(params, {"tokens": tokens[:, :s]}, cache)
    dec, cache, _ = model.apply(params, {"tokens": tokens[:, s:]}, cache)
    assert jnp.allclose(dec[:, 0], full[:, s], atol=2e-3), arch
    assert int(cache["offset"]) == s + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_microbatched_train_step(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(3)
    state = init_train_state(model, key)
    step = make_train_step(model, AdamWConfig(), microbatches=2, remat=False)
    batch = tiny_batch(cfg, key, b=4, train=True)
    _, metrics = jax.jit(step)(state, batch)
    assert not bool(jnp.isnan(metrics["loss"]))


def test_input_specs_cover_grid():
    for arch in ARCHS:
        cfg = get_config(arch)
        for name, shape in SHAPES.items():
            ok, reason = shape_applicable(cfg, name)
            if not ok:
                assert reason
                continue
            specs = input_specs(cfg, shape)
            assert specs, (arch, name)
            for leaf in jax.tree.leaves(specs):
                assert leaf.shape[0] == shape.global_batch


def test_moe_capacity_drops_counted():
    cfg = get_config("granite-moe-3b-a800m").reduced().replace(
        capacity_factor=0.5)
    model = get_model(cfg)
    key = jax.random.PRNGKey(4)
    params = model.init(key)
    logits, _, aux = model.apply(params, tiny_batch(cfg, key))
    assert not bool(jnp.isnan(logits).any())  # drops must not produce NaNs
