"""Training substrate: optimizer convergence, checkpoint/restart (incl.
elastic restore), gradient compression error feedback."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_config, get_model
from repro.training import checkpoint as ckpt
from repro.training.compression import (compress_psum, dequantize_int8, ef_init,
                                        quantize_int8)
from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                      clip_by_global_norm, lr_schedule)
from repro.training.train_step import init_train_state, make_train_step


def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200)
    for _ in range(150):
        grads = jax.tree.map(lambda p: 2 * p, params)  # d/dp ||p||^2
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_lr_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0, abs=0.01)
    assert lrs[3] < lrs[2] and lrs[4] < lrs[3]
    assert lrs[4] >= 0.099  # floor at 10%


def test_grad_clip():
    grads = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000.0), rel=1e-5)
    cn = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
    assert cn == pytest.approx(1.0, rel=1e-4)


def test_small_lm_loss_decreases():
    """A few steps of real training on a tiny qwen2-style model."""
    cfg = get_config("qwen2-0.5b").reduced().replace(n_layers=2, vocab_size=128)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    state = init_train_state(model, key)
    step = jax.jit(make_train_step(
        model, AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=40),
        remat=False))
    tokens = jax.random.randint(key, (4, 64), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    losses = []
    for _ in range(25):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::6]


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("xlstm-125m").reduced()
    model = get_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), 7, state, extra={"arch": cfg.name})
    assert ckpt.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, manifest = ckpt.restore(str(tmp_path), 7, like)
    assert manifest["extra"]["arch"] == cfg.name
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_restart_training_continues(tmp_path):
    """Crash/restart: restore mid-run and keep training — loss keeps the
    trajectory (fault-tolerance contract)."""
    cfg = get_config("qwen2-0.5b").reduced().replace(n_layers=1, vocab_size=64)
    model = get_model(cfg)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3), remat=False))
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}

    state = init_train_state(model, key)
    for _ in range(3):
        state, _ = step(state, batch)
    ckpt.save(str(tmp_path), 3, state)
    state_a, _ = step(state, batch)  # uninterrupted step 4

    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, _ = ckpt.restore(str(tmp_path), 3, like)
    state_b, _ = step(restored, batch)  # step 4 after "restart"
    for a, b in zip(jax.tree.leaves(state_a["params"]),
                    jax.tree.leaves(state_b["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    state = {"w": jnp.zeros((4, 4))}
    ckpt.save(str(tmp_path), 0, state)
    like = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
    with pytest.raises(ValueError, match="mismatch"):
        ckpt.restore(str(tmp_path), 0, like)


def test_checkpoint_elastic_resharding(tmp_path):
    """Restore onto a different sharding layout (elastic rescale): the mesh
    at restore time re-applies the sharding rules — values are identical."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt.save(str(tmp_path), 1, state)
    like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    shd = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = ckpt.restore(str(tmp_path), 1, like, shardings=shd)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["w"].sharding == shd["w"]


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------
def test_int8_quantization_bounded_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) / 2 + 1e-6


def test_compress_psum_error_feedback_single_device():
    """With axis size 1, compressed psum == dequantized grad and the residual
    carries the quantization error (bias correction over steps)."""
    from jax.sharding import Mesh
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("dp",))
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (64,))}
    res = ef_init(grads)

    f = shard_map(partial(compress_psum, axis_name="dp"),
                  mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
    mean, new_res = f(grads, res)
    np.testing.assert_allclose(np.asarray(mean["w"] + new_res["w"]),
                               np.asarray(grads["w"]), atol=1e-5)
