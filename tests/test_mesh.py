"""Real multi-device mesh execution of the fleet (simulated host devices).

Run the multi-device cases with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI mesh job
does): 8 simulated CPU devices, a (pod=2, data=4) fleet mesh, and the full
scanned driver executing SPMD. The contract under test is the tentpole of
the scaling work: the meshed run must match the single-device run to
reduction-order ULPs (the sharding hints and collectives are placement,
not math), with the fleet state actually partitioned across devices.

Spec-only cases (no multi-device requirement) always run, so the default
single-device tier-1 suite still covers the sharding rules.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.fcpo import FCPOConfig
from repro.core.fleet import (fleet_device_bytes, fleet_init,
                              fleet_shardings, train_fleet_scan)
from repro.data.workload import fleet_traces
from repro.distributed import sharding as shd
from repro.launch.mesh import make_fleet_mesh

CFG = FCPOConfig()
KEY = jax.random.PRNGKey(0)

multi_device = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


class TestFleetMeshFactory:
    def test_pod_by_data_factorization(self):
        if jax.device_count() < 8:
            pytest.skip("needs 8 devices")
        mesh = make_fleet_mesh(8, 2)
        assert dict(mesh.shape) == {"pod": 2, "data": 4}

    def test_indivisible_pods_fall_back_to_data_only(self):
        mesh = make_fleet_mesh(jax.device_count(), 3)
        if jax.device_count() % 3 == 0:
            assert mesh.shape["pod"] == 3
        else:
            assert mesh.shape["pod"] == 1
            assert mesh.shape["data"] == jax.device_count()


class _SpecMesh:
    """Shape-only stand-in for a Mesh: ``greedy_spec`` and the fleet spec
    rules read nothing but ``mesh.shape``, so the placement logic is
    testable on any device count."""
    shape = {"pod": 2, "data": 4}


class TestFleetShardingSpecs:
    """Placement rules — valid on any device count (specs are symbolic)."""

    def test_agent_leaves_shard_over_pod_data(self):
        mesh = _SpecMesh()
        assert shd.agent_spec((8, 31), mesh) == P(("pod", "data"))
        # A=4 does not fill pod*data=8 -> falls through to data alone
        assert shd.agent_spec((4, 31), mesh) == P("data")
        # A=3 divides nothing -> replicated
        assert shd.agent_spec((3, 31), mesh) == P()

    def test_pod_leaves_ride_the_fl_hierarchy_axis(self):
        mesh = _SpecMesh()
        # the pod axis is tried first and wins whenever P divides it
        assert shd.pod_spec((2, 31), mesh) == P("pod")
        assert shd.pod_spec((4, 31), mesh) == P("pod")
        # indivisible P -> replicated (always valid for the small base nets)
        assert shd.pod_spec((3, 31), mesh) == P()

    def test_pod_leaves_fall_back_to_data_without_a_pod_axis(self):
        class _DataMesh:
            shape = {"data": 4}
        assert shd.pod_spec((4, 31), _DataMesh()) == P("data")
        assert shd.pod_spec((2, 31), _DataMesh()) == P()

    @multi_device
    def test_fleet_shardings_field_placement(self):
        mesh = make_fleet_mesh(8, 2)
        fleet = fleet_init(CFG, 8, KEY, n_pods=2)
        shards = fleet_shardings(fleet, mesh)
        agent = P(("pod", "data"))
        for leaf in jax.tree.leaves(shards.astate.params):
            assert leaf.spec == agent
        for leaf in jax.tree.leaves(shards.astate.buffer):
            assert leaf.spec == agent
        for leaf in jax.tree.leaves(shards.residuals):
            assert leaf.spec == agent
        # per-pod base networks + partition timer ride the FL hierarchy
        for leaf in jax.tree.leaves(shards.base_params):
            assert leaf.spec == P("pod")
        assert shards.partition_timer.spec == P("pod")
        # the scalar episode counter is replicated
        assert shards.episode.spec == P()


@multi_device
class TestMeshedTraining:
    def test_meshed_scan_matches_single_device(self):
        """The tentpole contract: agents over (pod, data), pods over the FL
        hierarchy, Alg. 1 + pod-merge as real collectives — and the numbers
        do not move beyond reduction-order ULPs. Per-agent math is
        elementwise (identical under any placement); cross-agent means
        become partitioned collectives whose float accumulation order
        depends on the device split, and that ULP drift compounds through
        the training feedback loop — observed max absolute drift 4e-6
        after 8 episodes on 8 devices, so the contract is tight numeric
        equivalence (atol 1e-5), not bitwise equality."""
        n, eps = 16, 8
        traces = fleet_traces(jax.random.PRNGKey(1), n, eps * CFG.n_steps)
        kw = dict(straggler_prob=0.3, seed=7)

        f0 = fleet_init(CFG, n, KEY, n_pods=2)
        sf, sh = train_fleet_scan(CFG, f0, traces, **kw)

        mesh = make_fleet_mesh(8, 2)
        f1 = fleet_init(CFG, n, KEY, n_pods=2, mesh=mesh)
        mf, mh = train_fleet_scan(CFG, f1, traces, mesh=mesh, **kw)

        tol = dict(rtol=1e-5, atol=1e-5)
        for k in sh:
            np.testing.assert_allclose(np.asarray(sh[k], dtype=np.float32),
                                       np.asarray(mh[k], dtype=np.float32),
                                       err_msg=k, **tol)
        for a, b in zip(jax.tree.leaves(sf), jax.tree.leaves(mf)):
            a, b = np.asarray(a), np.asarray(b)
            if np.issubdtype(a.dtype, np.floating):
                np.testing.assert_allclose(a.astype(np.float32),
                                           b.astype(np.float32), **tol)
            else:
                np.testing.assert_array_equal(a, b)

    def test_meshed_outputs_are_sharded(self):
        """The result must actually live distributed — a run that silently
        de-shards to replicated would pass the equality test while scaling
        nowhere."""
        n, eps = 16, 2
        mesh = make_fleet_mesh(8, 2)
        traces = fleet_traces(jax.random.PRNGKey(1), n, eps * CFG.n_steps)
        fleet = fleet_init(CFG, n, KEY, n_pods=2, mesh=mesh)
        out, _ = train_fleet_scan(CFG, fleet, traces, mesh=mesh)
        leaf = jax.tree.leaves(out.astate.params)[0]
        assert isinstance(leaf.sharding, NamedSharding)
        assert leaf.sharding.spec == P(("pod", "data"))
        assert len(leaf.sharding.device_set) == 8
        # per-device accounting sees a balanced split of the fleet state
        per = fleet_device_bytes(out)
        assert len(per) == 8
        vals = sorted(per.values())
        assert vals[-1] <= 2.0 * vals[0]

    def test_meshed_stream_contents_match_history(self, tmp_path):
        """The metrics tap under SPMD: on a mesh the per-episode callback
        switches to an unordered one (ordered callbacks are single-device-
        only in XLA), but the scan's sequential data dependence still fires
        it once per episode. The stream must be complete, in episode order,
        and carry the same numbers the returned history does — a dropped or
        duplicated record would silently corrupt every live watcher."""
        from repro.eval.stream import MetricsSink, read_metrics
        n, eps = 16, 6
        mesh = make_fleet_mesh(8, 2)
        traces = fleet_traces(jax.random.PRNGKey(1), n, eps * CFG.n_steps)
        fleet = fleet_init(CFG, n, KEY, n_pods=2, mesh=mesh)
        path = str(tmp_path / "run.jsonl")
        with MetricsSink(path, meta={"agents": n}) as sink:
            _, hist = train_fleet_scan(CFG, fleet, traces, mesh=mesh,
                                       metrics_sink=sink, seed=3)
        meta, records = read_metrics(path)
        assert meta["agents"] == n
        assert [r["episode"] for r in records] == list(range(eps))
        for e, rec in enumerate(records):
            for k, v in rec.items():
                if k == "episode" or k not in hist:
                    continue
                np.testing.assert_allclose(
                    v, float(np.asarray(hist[k])[e]), rtol=1e-6, atol=1e-7,
                    err_msg=f"{k}@{e}")

    def test_meshed_run_with_lean_state_and_transport(self):
        """Mesh x dtype-policy x FL-codec composition: the lean fleet trains
        SPMD with the int8 transport codec and stays finite."""
        from repro.fl import TransportConfig
        n, eps = 16, 6
        mesh = make_fleet_mesh(8, 2)
        traces = fleet_traces(jax.random.PRNGKey(1), n, eps * CFG.n_steps)
        fleet = fleet_init(CFG, n, KEY, n_pods=2, mesh=mesh,
                           state_policy="lean")
        out, hist = train_fleet_scan(
            CFG, fleet, traces, mesh=mesh,
            transport=TransportConfig(codec="int8"))
        assert np.isfinite(np.asarray(hist["reward"])).all()
        assert jax.tree.leaves(out.astate.opt["m"])[0].dtype == jnp.bfloat16
