"""End-to-end behaviour tests: serving engine, FCPO-controlled serving,
warm start, and CRL adaptation — the paper's system-level claims in miniature."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.fcpo import FCPOConfig
from repro.core.fleet import fleet_init, fleet_episode, fl_round, train_fleet
from repro.data.workload import fleet_traces, ood_traces, switching_traces
from repro.models.registry import get_config, get_model
from repro.serving.engine import ServingEngine
from repro.serving.slo import BoundedQueue, Request, SLOTracker

KEY = jax.random.PRNGKey(0)


class TestServingEngine:
    def _engine(self, **kw):
        cfg = get_config("qwen2-0.5b").reduced().replace(n_layers=2,
                                                         vocab_size=128)
        model = get_model(cfg)
        params = model.init(KEY)
        return ServingEngine(model, params, max_cache_len=128,
                             batch_buckets=(2, 4), seq_buckets=(16, 32), **kw)

    def test_generate_deterministic_and_shaped(self):
        eng = self._engine()
        tokens = jax.random.randint(KEY, (2, 12), 0, 128)
        out1 = eng.generate(tokens, steps=5)
        out2 = eng.generate(tokens, steps=5)
        assert out1.shape == (2, 5)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))

    def test_bucketing_pads_and_unpads(self):
        eng = self._engine()
        tokens = jax.random.randint(KEY, (3, 20), 0, 128)  # -> bucket (4, 32)
        logits, cache, info = eng.prefill(tokens)
        assert info["bucket"] == (4, 32)
        assert logits.shape[0] == 3
        assert eng.stats["padded_tokens"] > 0

    def test_oversized_request_raises_clear_error(self):
        """Regression: sizes beyond the largest compiled bucket used to fall
        through to buckets[-1], drive the pad amounts negative, and crash
        inside jnp.pad with an opaque error. They must raise a clear
        ValueError instead."""
        eng = self._engine()
        too_many = jax.random.randint(KEY, (5, 8), 0, 128)  # b=5 > max 4
        with pytest.raises(ValueError, match="bucket"):
            eng.prefill(too_many)
        with pytest.raises(ValueError, match="bucket"):
            eng.generate(too_many, steps=2)
        too_long = jax.random.randint(KEY, (2, 40), 0, 128)  # s=40 > max 32
        with pytest.raises(ValueError, match="bucket"):
            eng.prefill(too_long)

    def test_prefill_decode_agree_with_plain_forward(self):
        eng = self._engine(cache_dtype=jnp.float32)
        cfg = eng.model.cfg
        tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
        logits, _, _ = eng.prefill(tokens)
        full, _, _ = eng.model.apply(eng.params, {"tokens": tokens})
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, -1]), atol=1e-4)


class TestSLOQueue:
    def test_bounded_queue_drops(self):
        q = BoundedQueue(capacity=2)
        for i in range(4):
            q.push(Request(i, arrival_t=0.0))
        assert len(q) == 2 and q.drops == 2

    def test_effective_throughput_counts_only_on_time(self):
        tr = SLOTracker(slo_s=0.25)
        reqs = [Request(0, arrival_t=0.0), Request(1, arrival_t=0.9)]
        tr.complete(reqs, now=1.0)  # latencies 1.0s and 0.1s
        thr, eff, lat = tr.window(now=1.0)
        assert thr == 2.0 and eff == 1.0


class TestFCPOSystem:
    def test_warm_start_beats_cold_start(self):
        """Fig. 10 in miniature: a pre-trained fleet dropped into an OOD
        workload outperforms a blank fleet on early episodes."""
        cfg = FCPOConfig()
        n = 4
        warm = fleet_init(cfg, n, KEY)
        traces = fleet_traces(jax.random.PRNGKey(1), n, 1500)
        warm, _ = train_fleet(cfg, warm, traces)

        ood = ood_traces(jax.random.PRNGKey(2), n, 300)
        warm2, hw = train_fleet(cfg, warm, ood)
        cold = fleet_init(cfg, n, jax.random.PRNGKey(3))
        cold2, hc = train_fleet(cfg, cold, ood)
        assert hw["reward"][:10].mean() > hc["reward"][:10].mean()

    def test_crl_adapts_after_context_switch(self):
        """Fig. 13 in miniature: learning fleet beats a frozen copy on a
        switching workload."""
        cfg = FCPOConfig()
        n = 4
        fleet = fleet_init(cfg, n, KEY)
        fleet, _ = train_fleet(cfg, fleet,
                               fleet_traces(jax.random.PRNGKey(1), n, 1200))
        switch = switching_traces(jax.random.PRNGKey(2), n, 800, segment=50)
        learn_fleet, h_learn = train_fleet(cfg, fleet, switch)
        frozen_fleet, h_frozen = train_fleet(cfg, fleet, switch, learn=False,
                                             federated=False)
        assert h_learn["reward"][-30:].mean() >= h_frozen["reward"][-30:].mean()

    def test_federated_round_is_fault_tolerant(self):
        """Stragglers every round; training must proceed and stay finite."""
        cfg = FCPOConfig(fl_every=1)
        n = 6
        fleet = fleet_init(cfg, n, KEY, n_pods=2)
        traces = fleet_traces(jax.random.PRNGKey(4), n, 400)
        fleet, hist = train_fleet(cfg, fleet, traces, straggler_prob=0.5)
        assert np.isfinite(hist["reward"]).all()
        for x in jax.tree.leaves(fleet.astate.params):
            assert np.isfinite(np.asarray(x)).all()

    def test_heterogeneous_action_spaces_in_one_fleet(self):
        """Two agent groups with different BS ranges coexist; aggregation
        keeps them inside their own group (Alg. 1 line 8)."""
        from repro.core.agent import ActionMask
        cfg = FCPOConfig(fl_every=1)
        n = 4
        masks = ActionMask(
            res=jnp.ones((n, cfg.n_res), bool),
            bs=jnp.stack([jnp.arange(cfg.n_bs) < (4 if i % 2 == 0 else 7)
                          for i in range(n)]),
            mt=jnp.ones((n, cfg.n_mt), bool),
        )
        fleet = fleet_init(cfg, n, KEY, masks=masks)
        assert fleet.group_counts["head_bs"] == 2
        traces = fleet_traces(jax.random.PRNGKey(5), n, 200)
        fleet, rollouts, _ = fleet_episode(cfg, fleet, traces[:, :cfg.n_steps])
        fleet2, sel, _ = fl_round(cfg, fleet, rollouts)
        # constrained agents never act outside their mask
        fleet3, rollouts3, _ = fleet_episode(
            cfg, fleet2, traces[:, cfg.n_steps:2 * cfg.n_steps])
        bs_actions = np.asarray(rollouts3.actions[:, :, 1])
        assert bs_actions[0].max() < 4 and bs_actions[2].max() < 4
