"""Scanned fleet driver: equivalence with the reference Python loop,
sharding wiring, dispatch/recompile regressions."""
import jax
import numpy as np

from repro.configs.fcpo import FCPOConfig
from repro.core import federated as fed
from repro.core.fleet import (fleet_episode, fleet_init, fleet_shardings,
                              train_fleet_reference, train_fleet_scan,
                              _scan_fn)
from repro.data.workload import fleet_traces
from repro.launch.mesh import make_debug_mesh

CFG = FCPOConfig()
KEY = jax.random.PRNGKey(0)


def _pair(n=4, n_pods=2):
    """Two identically-initialized fleets (scan donates nothing on CPU, but
    keep the inputs independent anyway)."""
    return (fleet_init(CFG, n, KEY, n_pods=n_pods),
            fleet_init(CFG, n, KEY, n_pods=n_pods))


class TestScanEquivalence:
    def test_matches_reference_through_fl_and_pod_merge(self):
        """20 episodes @ fl_every=2, hierarchical_period=4, 2 pods: the run
        contains 10 FL rounds and 2 cross-pod merges, with straggler masking.
        Same seeds -> same availability draws -> identical trajectories."""
        n = 4
        f_ref, f_scan = _pair(n)
        traces = fleet_traces(jax.random.PRNGKey(1), n, 20 * CFG.n_steps)
        kw = dict(straggler_prob=0.3, seed=7)
        rf, rh = train_fleet_reference(CFG, f_ref, traces, **kw)
        sf, sh = train_fleet_scan(CFG, f_scan, traces, **kw)

        assert sorted(rh) == sorted(sh)
        for k in rh:
            np.testing.assert_allclose(sh[k], rh[k], rtol=1e-4, atol=1e-5,
                                       err_msg=k)
        for a, b in zip(jax.tree.leaves(rf.astate.params),
                        jax.tree.leaves(sf.astate.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        for a, b in zip(jax.tree.leaves(rf.base_params),
                        jax.tree.leaves(sf.base_params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        assert int(sf.episode) == int(rf.episode) == 20

    def test_matches_reference_frozen(self):
        n = 2
        f_ref, f_scan = _pair(n, n_pods=1)
        traces = fleet_traces(jax.random.PRNGKey(2), n, 6 * CFG.n_steps)
        _, rh = train_fleet_reference(CFG, f_ref, traces, learn=False,
                                      federated=False)
        _, sh = train_fleet_scan(CFG, f_scan, traces, learn=False,
                                 federated=False)
        for k in rh:
            np.testing.assert_allclose(sh[k], rh[k], rtol=1e-4, atol=1e-5,
                                       err_msg=k)

    def test_availability_draws_match_reference_order(self):
        """The pre-drawn bits consume the SAME rng stream as the reference
        driver's lazy per-round draws."""
        schedule = fed.fl_schedule(CFG, 10)
        avail = np.asarray(fed.draw_availability(schedule, 5, 0.5, seed=3))
        rng = np.random.default_rng(3)
        for e in range(10):
            if schedule[e]:
                np.testing.assert_array_equal(avail[e], rng.random(5) >= 0.5)
            else:
                assert avail[e].all()

    def test_history_is_per_episode(self):
        n, eps = 2, 8
        _, f = _pair(n, n_pods=1)
        traces = fleet_traces(jax.random.PRNGKey(1), n, eps * CFG.n_steps)
        _, hist = train_fleet_scan(CFG, f, traces)
        assert all(v.shape == (eps,) for v in hist.values())


class TestShardingWiring:
    def test_fleet_shardings_cover_every_leaf(self):
        mesh = make_debug_mesh(1, 1)
        fleet = fleet_init(CFG, 4, KEY, n_pods=2)
        sh = fleet_shardings(fleet, mesh)
        leaves, treedef = jax.tree.flatten(fleet)
        sh_leaves, sh_treedef = jax.tree.flatten(sh)
        assert treedef == sh_treedef
        assert all(hasattr(s, "spec") for s in sh_leaves)

    def test_mesh_path_runs_and_matches(self):
        mesh = make_debug_mesh(1, 1)
        n = 4
        f_ref, _ = _pair(n)
        f_scan = fleet_init(CFG, n, KEY, n_pods=2, mesh=mesh)
        traces = fleet_traces(jax.random.PRNGKey(1), n, 6 * CFG.n_steps)
        _, rh = train_fleet_reference(CFG, f_ref, traces, seed=5)
        _, sh = train_fleet_scan(CFG, f_scan, traces, seed=5, mesh=mesh)
        np.testing.assert_allclose(sh["reward"], rh["reward"], rtol=1e-4,
                                   atol=1e-5)


class TestDispatchRegression:
    def test_fleet_episode_recompiles_at_most_once(self):
        """The per-episode entry point must hit the jit cache across episodes
        (a recompile per episode is the exact failure the scan driver and
        this regression guard exist to prevent)."""
        n = 2
        fleet = fleet_init(CFG, n, KEY)
        traces = fleet_traces(jax.random.PRNGKey(1), n, 5 * CFG.n_steps)
        before = fleet_episode._cache_size()
        for e in range(5):
            rates = traces[:, e * CFG.n_steps:(e + 1) * CFG.n_steps]
            fleet, _, _ = fleet_episode(CFG, fleet, rates)
        assert fleet_episode._cache_size() - before <= 1

    def test_scan_driver_compiles_once_across_runs(self):
        """Whole-run O(1) dispatch: two same-shaped runs share one executable
        (the second run adds no cache entry)."""
        n, eps = 2, 4
        traces = fleet_traces(jax.random.PRNGKey(1), n, eps * CFG.n_steps)
        fn = _scan_fn(False)
        train_fleet_scan(CFG, fleet_init(CFG, n, KEY), traces, donate=False)
        size = fn._cache_size()
        train_fleet_scan(CFG, fleet_init(CFG, n, KEY), traces, donate=False)
        assert fn._cache_size() == size
