"""Pluggable environment backends: observation unification, twin-backed
training equivalence (scan vs reference, jnp vs Pallas), and the
fluid-vs-twin fidelity envelope asserted in tier-1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.fcpo import FCPOConfig
from repro.core import env as env_mod
from repro.core.backends import (BACKENDS, FLUID, FluidBackend, TwinBackend,
                                 TwinEnvState, get_backend)
from repro.core.fleet import (fleet_init, train_fleet, train_fleet_reference,
                              train_fleet_scan)
from repro.sim import SimParams, make_scenario, sim_observe, simulate_fleet
from repro.sim.state import effective_queue_cap

CFG = FCPOConfig()
KEY = jax.random.PRNGKey(0)
SP = SimParams(dt=0.05, k_ticks=8, ring=64, hist_n=32)


class TestInterface:
    def test_get_backend_resolution(self):
        assert get_backend(None) is FLUID
        assert get_backend("fluid") is FLUID
        tw = get_backend("twin", sim_params=SP, use_pallas=True)
        assert isinstance(tw, TwinBackend) and tw.sp == SP and tw.use_pallas
        assert get_backend(tw) is tw
        with pytest.raises(ValueError, match="unknown env backend"):
            get_backend("nope")
        assert set(BACKENDS) == {"fluid", "twin"}

    def test_backends_are_hashable_jit_statics(self):
        assert hash(FluidBackend()) == hash(FluidBackend())
        assert hash(TwinBackend(sp=SP)) == hash(TwinBackend(sp=SP))
        assert TwinBackend(sp=SP) != TwinBackend(sp=SP, use_pallas=True)


class TestObservationUnification:
    """The 8-dim state vector has ONE definition (env.observe_vector)."""

    def test_fluid_backend_observe_is_env_observe(self):
        ep = env_mod.default_env_params()
        s = env_mod.EnvState(
            pre_q=jnp.float32(17.0), post_q=jnp.float32(4.0),
            drops=jnp.float32(3.0),
            cur_action=jnp.asarray([2, 5, 1], jnp.int32),
            ema_lat=jnp.float32(0.1), t=jnp.int32(9))
        rate = jnp.float32(42.0)
        np.testing.assert_array_equal(
            np.asarray(FLUID.observe(CFG, ep, s, rate)),
            np.asarray(env_mod.observe(CFG, ep, s, rate)))

    def test_twin_backend_observe_matches_sim_observe_fieldwise(self):
        """The training-side twin observation and the evaluation harness's
        ``sim_observe`` read the same normalizations — field for field."""
        be = TwinBackend(sp=SP)
        ep = env_mod.default_env_params()
        state = be.init(CFG)
        rng = jax.random.PRNGKey(1)
        for i in range(4):  # drive to a non-trivial queue state
            rng, k = jax.random.split(rng)
            action = jax.random.randint(k, (3,), 0, 3)
            state, _, _ = be.step(CFG, ep, state, action, jnp.float32(80.0))
        obs_backend = be.observe(CFG, ep, state, jnp.float32(55.0))
        obs_harness = sim_observe(CFG, SP, ep, state.sim, state.drops_prev,
                                  state.cur_action, jnp.float32(55.0))
        np.testing.assert_array_equal(np.asarray(obs_backend),
                                      np.asarray(obs_harness))
        assert obs_backend.shape == (CFG.state_dim,)

    def test_twin_and_fluid_share_normalization_constants(self):
        """Same raw readings => same observation, whichever backend
        normalized them (the queue term uses the twin's effective cap)."""
        ep = env_mod.default_env_params()
        raw = dict(rate=jnp.float32(70.0),
                   cur_action=jnp.asarray([1, 3, 2], jnp.int32),
                   drops=jnp.float32(7.0), pre_q=jnp.float32(12.0),
                   post_q=jnp.float32(5.0), slo_s=ep.slo_s)
        a = env_mod.observe_vector(CFG, queue_cap=ep.queue_cap, **raw)
        b = env_mod.observe_vector(
            CFG, queue_cap=effective_queue_cap(SP, ep), **raw)
        # only the two queue-occupancy fields may differ (different caps)
        np.testing.assert_array_equal(np.asarray(a[:5]), np.asarray(b[:5]))
        np.testing.assert_array_equal(np.asarray(a[7]), np.asarray(b[7]))


class TestTwinStep:
    def test_step_conserves_and_rewards_in_range(self):
        be = TwinBackend(sp=SP)
        ep = env_mod.default_env_params()
        state = be.init(CFG)
        rng = jax.random.PRNGKey(2)
        for _ in range(6):
            rng, k = jax.random.split(rng)
            action = jax.random.randint(k, (3,), 0, 3)
            state, r, info = be.step(CFG, ep, state, action, jnp.float32(120.0))
            assert -1.0 <= float(r) <= 1.0
            assert float(info["effective_throughput"]) <= \
                float(info["throughput"]) + 1e-6
        sim = state.sim
        assert int(sim.arrived) == int(sim.dropped) + int(sim.completed) \
            + int(sim.in_flight)
        assert int(sim.completed) > 0
        # fl_round's Eq. 7 memory stat reads env_state.pre_q on any backend
        assert float(state.pre_q) == float(sim.pre_q)

    def test_phase_carry_admits_fractional_rates(self):
        """The fractional-arrival phase carries across control intervals, so
        a steady fractional rate is admitted on average (no floor deficit)."""
        be = TwinBackend(sp=SP)
        ep = env_mod.default_env_params()
        state = be.init(CFG)
        rate = jnp.float32(30.9)
        n_int = 25
        for _ in range(n_int):
            state, _, _ = be.step(CFG, ep, state,
                                  jnp.zeros((3,), jnp.int32), rate)
        expect = float(rate) * SP.interval_s * n_int
        assert abs(int(state.sim.arrived) - expect) <= 1.0


class TestTrainingEquivalence:
    def _fleet(self, be, n=3, n_pods=2):
        return fleet_init(CFG, n, KEY, n_pods=n_pods, env_backend=be)

    def test_twin_scan_matches_reference(self):
        """The twin-backed scanned driver == the Python-loop oracle through
        FL rounds, pod merges, and straggler masking."""
        be = TwinBackend(sp=SP)
        n = 3
        traces = make_scenario("dynamic", jax.random.PRNGKey(1), n,
                               8 * CFG.n_steps)
        kw = dict(straggler_prob=0.3, seed=7, env_backend=be)
        rf, rh = train_fleet_reference(CFG, self._fleet(be, n), traces, **kw)
        sf, sh = train_fleet_scan(CFG, self._fleet(be, n), traces, **kw)
        assert sorted(rh) == sorted(sh)
        for k in rh:
            np.testing.assert_allclose(sh[k], rh[k], rtol=1e-4, atol=1e-5,
                                       err_msg=k)
        for a, b in zip(jax.tree.leaves(rf.astate.params),
                        jax.tree.leaves(sf.astate.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        # the twin env state itself must match exactly (integer counters)
        for a, b in zip(jax.tree.leaves(rf.astate.env_state),
                        jax.tree.leaves(sf.astate.env_state)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)

    @pytest.mark.pallas
    def test_twin_pallas_training_matches_jnp(self):
        """Training through the fused Pallas queue_advance kernel is
        bit-identical to the jnp microtick scan (same keys => same
        trajectories => same updates)."""
        n = 2
        traces = make_scenario("dynamic", jax.random.PRNGKey(1), n,
                               4 * CFG.n_steps)
        outs = []
        for use_pallas in (False, True):
            be = TwinBackend(sp=SP, use_pallas=use_pallas)
            fleet, hist = train_fleet(CFG, self._fleet(be, n, n_pods=1),
                                      traces, env_backend=be)
            outs.append((fleet, hist))
        (fj, hj), (fp, hp) = outs
        for k in hj:
            np.testing.assert_allclose(hp[k], hj[k], rtol=1e-5, atol=1e-6,
                                       err_msg=k)
        for a, b in zip(jax.tree.leaves(fj.astate.env_state),
                        jax.tree.leaves(fp.astate.env_state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestFidelityEnvelope:
    def test_fluid_vs_twin_total_throughput_gap_under_2pct_on_steady(self):
        """The PR 3 fidelity envelope, asserted in tier-1: both planes move
        the same total flow on the steady scenario (<2% relative gap) — the
        backends model the same pipeline, they differ in request-grade
        accounting, not in bulk throughput."""
        a = 4
        sp = SimParams()  # production geometry: ring 512 fits queue_cap 128
        fleet = fleet_init(CFG, a, KEY)
        traces = make_scenario("steady", jax.random.PRNGKey(2), a,
                               2 * CFG.n_steps)
        _, hist = train_fleet(CFG, fleet, traces, learn=False,
                              federated=False)
        _, _, summ = simulate_fleet(CFG, sp, fleet.astate.params, fleet.masks,
                                    fleet.env_params, traces,
                                    jax.random.PRNGKey(3))
        thr_fluid = float(np.mean(hist["throughput"]))
        thr_twin = float(np.asarray(summ["throughput"]).mean())
        gap = abs(thr_fluid - thr_twin) / max(abs(thr_fluid), 1e-9)
        assert gap < 0.02, (thr_fluid, thr_twin, gap)
