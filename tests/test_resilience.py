"""Chaos layer (repro.resilience): fault plans, robust aggregation
invariants, self-healing updates, and scan/reference equivalence under
injected faults."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.fcpo import FCPOConfig
from repro.core import federated as fed
from repro.core.agent import agent_init, full_mask
from repro.core.fleet import fleet_init, train_fleet_reference, train_fleet_scan
from repro.core.ppo import Rollout, agent_opt_init, agent_update
from repro.data.workload import fleet_traces
from repro.fl import CODECS, TransportConfig
from repro.resilience import (DEFAULT_GUARDS, NO_FAULTS, FaultConfig,
                              GuardConfig, draw_fault_plan, finite_mask)
from repro.resilience.guards import clip_deltas

CFG = FCPOConfig()
KEY = jax.random.PRNGKey(0)

CHAOS = FaultConfig(crash_prob=0.2, crash_recovery=2,
                    byzantine_frac=0.3, byzantine_mode="sign_flip",
                    byzantine_scale=5.0, partition_prob=0.5,
                    partition_merges=1, seed=3)
ROBUST = GuardConfig(agg="trimmed", trim_frac=0.25, clip_factor=3.0)


def _schedule(n_eps):
    return np.asarray([1 if (e + 1) % CFG.fl_every == 0 else 0
                       for e in range(n_eps)], dtype=np.int64)


class TestFaultConfig:
    def test_no_faults_inactive(self):
        assert not NO_FAULTS.active

    def test_validation(self):
        with pytest.raises(ValueError):
            FaultConfig(crash_prob=1.5)
        with pytest.raises(ValueError):
            FaultConfig(byzantine_mode="nope")

    def test_jit_static(self):
        # static argnames hash and compare by value
        assert hash(CHAOS) == hash(FaultConfig(**{
            f.name: getattr(CHAOS, f.name)
            for f in CHAOS.__dataclass_fields__.values()}))


class TestFaultPlan:
    def test_deterministic_in_seed(self):
        import dataclasses
        sch = _schedule(12)
        p1 = draw_fault_plan(sch, 4, 2, CHAOS)
        p2 = draw_fault_plan(sch, 4, 2, CHAOS)
        p3 = draw_fault_plan(sch, 4, 2,
                             dataclasses.replace(CHAOS, seed=CHAOS.seed + 1))
        for a, b in zip(p1, p2):
            np.testing.assert_array_equal(a, b)
        assert any(not np.array_equal(a, b) for a, b in zip(p1, p3))

    def test_byzantine_and_partition_only_on_fl_episodes(self):
        sch = _schedule(12)
        plan = draw_fault_plan(sch, 4, 2, CHAOS)
        off = sch == 0
        assert not plan.byzantine[off].any()
        assert not plan.partition[off].any()
        # crashes can hit ANY episode
        assert plan.crash.shape == (12, 4)


def _robust_within_honest_range(honest, byz):
    """Shared oracle with test_resilience_properties: with f byzantine
    among n valid values, the trimmed mean (per-side trim t >= f) and the
    median (f <= (n-1)//2, which f < n_honest guarantees) stay inside
    [honest min, honest max]."""
    vals = np.asarray(honest + byz, dtype=np.float32)
    n, f = len(vals), len(byz)
    # pad with garbage that MUST be masked out
    vals = np.concatenate([vals, np.full((2,), 7e7, np.float32)])
    valid = np.asarray([True] * n + [False] * 2)
    v = jnp.asarray(vals)[None, :]
    m = jnp.asarray(valid)[None, :]

    trim_frac = min((f + 0.25) / n, 0.4999)
    lo, hi = min(honest), max(honest)
    tr = float(fed._robust_stat(v, m, "trimmed", trim_frac)[0])
    assert lo - 1e-3 <= tr <= hi + 1e-3, (honest, byz, tr)
    if f <= (n - 1) // 2:
        md = float(fed._robust_stat(v, m, "median", 0.0)[0])
        assert lo - 1e-3 <= md <= hi + 1e-3, (honest, byz, md)


class TestRobustStat:
    def test_trimmed_and_median_within_honest_range_cases(self):
        """Deterministic slice of the hypothesis property (which lives in
        test_resilience_properties.py and is skipped when hypothesis is
        absent): random honest sets with up to n_honest-1 byzantine
        outliers at +-1e9."""
        rng = np.random.default_rng(0)
        for _ in range(50):
            n_h = int(rng.integers(2, 7))
            honest = list(rng.uniform(-100, 100, n_h).astype(np.float32))
            f = int(rng.integers(0, n_h))
            byz = list(rng.choice([-1e9, -1e6, 1e6, 1e9], f))
            _robust_within_honest_range(honest, byz)

    def test_all_methods_equal_on_identical_values(self):
        for n in (1, 2, 5):
            v = jnp.full((1, n), 3.5)
            m = jnp.ones((1, n), bool)
            for method, tf in (("trimmed", 0.3), ("median", 0.0)):
                got = float(fed._robust_stat(v, m, method, tf)[0])
                np.testing.assert_allclose(got, 3.5, rtol=1e-6)


class TestGuards:
    def test_finite_mask_flags_poisoned_agents(self):
        tree = {"w": jnp.ones((3, 4)).at[1, 2].set(jnp.nan),
                "b": jnp.zeros((3, 2))}
        np.testing.assert_array_equal(np.asarray(finite_mask(tree)),
                                      [True, False, True])

    def test_clip_deltas_bounds_outliers_only(self):
        contrib = {"w": jnp.ones((4, 8)).at[0].mul(100.0)}
        sel = jnp.ones((4,), bool)
        clipped, n_clip = clip_deltas(contrib, sel, 3.0)
        norms = np.sqrt(np.sum(np.square(np.asarray(clipped["w"])), -1))
        med = np.sqrt(8.0)  # median honest leaf norm
        assert norms[0] <= 3.0 * med * (1 + 1e-5)
        np.testing.assert_allclose(norms[1:], med, rtol=1e-6)
        assert float(n_clip) == 1.0


class TestSelfHealing:
    def test_ppo_rejects_nonfinite_update_keeps_params_and_opt(self):
        cfg = FCPOConfig(loss_gate=0.0)
        p = agent_init(cfg, KEY)
        opt = agent_opt_init(p)
        t = cfg.n_steps
        ks = jax.random.split(KEY, 3)
        bad = Rollout(
            states=jax.random.normal(ks[0], (t, cfg.state_dim)),
            actions=jnp.zeros((t, 3), jnp.int32),
            logp_old=jnp.zeros((t,)),
            rewards=jnp.full((t,), jnp.nan),  # poisoned reward stream
            values_old=jnp.zeros((t,)))
        p2, opt2, m = agent_update(cfg, p, opt, bad, full_mask(cfg))
        assert float(m["update_rejected"]) == 1.0
        for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(opt2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_train_step_rejects_nonfinite_update(self):
        from repro.models.registry import get_config, get_model
        from repro.training.optimizer import AdamWConfig
        from repro.training.train_step import (init_train_state,
                                               make_train_step)
        cfg = get_config("qwen2-0.5b").reduced().replace(n_layers=1,
                                                         vocab_size=64)
        model = get_model(cfg)
        state = init_train_state(model, jax.random.PRNGKey(1))
        step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3),
                                       remat=False))
        tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                    cfg.vocab_size)
        batch = {"tokens": tokens, "labels": tokens}
        state, m = step(state, batch)
        assert float(m["update_rejected"]) == 0.0  # healthy step passes

        # poison one param leaf -> NaN loss -> the WHOLE update is rejected
        # and the optimizer state (incl. the step count) does not advance
        leaves, td = jax.tree_util.tree_flatten(state["params"])
        leaves[0] = leaves[0].at[...].set(jnp.nan)
        poisoned = {"params": jax.tree_util.tree_unflatten(td, leaves),
                    "opt": state["opt"]}
        out, m = step(poisoned, batch)
        assert float(m["update_rejected"]) == 1.0
        for a, b in zip(jax.tree.leaves(poisoned), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestNaNRejectionPerCodec:
    @pytest.mark.parametrize("codec", CODECS)
    def test_nan_uploads_rejected(self, codec):
        """NaN poison is applied POST-codec, so every wire format must be
        caught by the non-finite guard: rejections counted, params finite."""
        n, eps = 4, 6
        faults = FaultConfig(byzantine_frac=0.6, byzantine_mode="nan",
                             seed=1)
        fleet = fleet_init(CFG, n, KEY, n_pods=1)
        traces = fleet_traces(jax.random.PRNGKey(2), n, eps * CFG.n_steps)
        fleet, hist = train_fleet_scan(CFG, fleet, traces, faults=faults,
                                       transport=TransportConfig(codec=codec))
        assert float(np.asarray(hist["fl_rejected"]).sum()) > 0
        for leaf in jax.tree.leaves(fleet.astate.params):
            assert np.isfinite(np.asarray(leaf)).all()


class TestScanEquivalenceUnderChaos:
    def test_scan_matches_reference_with_all_faults(self):
        """Crashes + byzantine + partitions + stragglers + robust trimmed
        aggregation + clipping: the jitted scan and the Python reference
        loop must still produce identical trajectories."""
        n, eps = 4, 8
        traces = fleet_traces(jax.random.PRNGKey(1), n, eps * CFG.n_steps)
        kw = dict(straggler_prob=0.2, seed=7, faults=CHAOS, guards=ROBUST)
        f_ref = fleet_init(CFG, n, KEY, n_pods=2)
        f_scan = fleet_init(CFG, n, KEY, n_pods=2)
        rf, rh = train_fleet_reference(CFG, f_ref, traces, **kw)
        sf, sh = train_fleet_scan(CFG, f_scan, traces, **kw)
        assert sorted(rh) == sorted(sh)
        for k in rh:
            np.testing.assert_allclose(sh[k], rh[k], rtol=1e-4, atol=1e-5,
                                       err_msg=k)
        for a, b in zip(jax.tree.leaves(rf.astate.params),
                        jax.tree.leaves(sf.astate.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_default_guards_are_identity(self):
        """guards=None and the explicit defaults hit the same jit cache AND
        the same numbers (the bit-identity contract's config half)."""
        n, eps = 2, 4
        traces = fleet_traces(jax.random.PRNGKey(3), n, eps * CFG.n_steps)
        f1 = fleet_init(CFG, n, KEY, n_pods=1)
        f2 = fleet_init(CFG, n, KEY, n_pods=1)
        _, h1 = train_fleet_scan(CFG, f1, traces)
        _, h2 = train_fleet_scan(CFG, f2, traces, faults=NO_FAULTS,
                                 guards=DEFAULT_GUARDS)
        for k in h1:
            np.testing.assert_array_equal(np.asarray(h1[k]),
                                          np.asarray(h2[k]), err_msg=k)


class TestChunkedResume:
    def test_offset_chunks_match_straight_run_under_faults(self):
        """episode_offset/total_episodes resume: running [0,3) then [3,8)
        with the same total reproduces the straight 8-episode run exactly —
        fault plans, straggler draws, and merge cadence all follow the
        absolute episode index."""
        n, eps, cut = 4, 8, 3
        traces = fleet_traces(jax.random.PRNGKey(1), n, eps * CFG.n_steps)
        kw = dict(straggler_prob=0.2, seed=7, faults=CHAOS, guards=ROBUST)
        f_straight = fleet_init(CFG, n, KEY, n_pods=2)
        f_chunk = fleet_init(CFG, n, KEY, n_pods=2)
        f_straight, hs = train_fleet_scan(CFG, f_straight, traces, **kw)
        f_chunk, h1 = train_fleet_scan(
            CFG, f_chunk, traces[:, :cut * CFG.n_steps],
            episode_offset=0, total_episodes=eps, **kw)
        f_chunk, h2 = train_fleet_scan(
            CFG, f_chunk, traces[:, cut * CFG.n_steps:],
            episode_offset=cut, total_episodes=eps, **kw)
        for k in hs:
            got = np.concatenate([np.asarray(h1[k]), np.asarray(h2[k])])
            np.testing.assert_allclose(got, np.asarray(hs[k]), rtol=1e-5,
                                       atol=1e-6, err_msg=k)
        for a, b in zip(jax.tree.leaves(f_straight.astate.params),
                        jax.tree.leaves(f_chunk.astate.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
