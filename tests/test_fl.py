"""Federated transport subsystem: delta codec (ref + Pallas kernel),
communication model, staleness semantics, driver equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.fcpo import FCPOConfig
from repro.core import federated as fed
from repro.core.agent import agent_init, full_mask
from repro.core.fleet import (_scan_fn, fl_round, fleet_episode, fleet_init,
                              train_fleet_reference, train_fleet_scan)
from repro.data.workload import fleet_traces
from repro.fl import (TransportConfig, agent_payload_bytes, codec_roundtrip,
                      downlink_bytes, full_param_bytes, pending_init,
                      uplink_seconds)
from repro.kernels import ref
from repro.kernels.delta_codec import delta_codec
from repro.training import compression

CFG = FCPOConfig()
KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Codec math: shared single definition + oracle behavior
# ---------------------------------------------------------------------------
class TestCodecMath:
    def test_int8_single_definition(self):
        """Satellite: training/compression.py and the fl codec share ONE
        int8 definition (the scalar math lives in kernels/ref.py)."""
        assert compression.quantize_int8 is ref.quantize_int8
        assert compression.dequantize_int8 is ref.dequantize_int8

    def test_int8_roundtrip_matches_quantize_dequantize_bitwise(self):
        x = jax.random.normal(KEY, (513,)) * 7.3
        q, s = ref.quantize_int8(x)
        via_int8 = ref.dequantize_int8(q, s)
        dec, s2 = ref.int8_roundtrip(x)
        np.testing.assert_array_equal(np.asarray(via_int8), np.asarray(dec))
        assert float(s) == float(s2)

    def test_float32_codec_is_lossless(self):
        d = jax.random.normal(KEY, (64,))
        dec, nr = ref.delta_codec_ref(d, jnp.zeros_like(d), codec="float32")
        np.testing.assert_array_equal(np.asarray(dec), np.asarray(d))
        assert float(jnp.abs(nr).max()) == 0.0

    def test_codec_identity_decoded_plus_residual(self):
        """decoded + new_residual == delta + residual — the telescoping
        identity error feedback relies on (bit-exact for topk, within one
        ulp of the quantization scale for int8)."""
        k1, k2 = jax.random.split(KEY)
        d = jax.random.normal(k1, (300,)) * 3
        r = jax.random.normal(k2, (300,)) * 0.1
        dec, nr = ref.delta_codec_ref(d, r, codec="topk", k=15)
        np.testing.assert_array_equal(np.asarray(dec + nr), np.asarray(d + r))
        dec, nr = ref.delta_codec_ref(d, r, codec="int8")
        np.testing.assert_allclose(np.asarray(dec + nr), np.asarray(d + r),
                                   atol=1e-6 * float(jnp.abs(d + r).max()),
                                   rtol=0)

    def test_topk_exact_k_and_preserved_coords(self):
        d = jax.random.normal(KEY, (200,))
        for k in (1, 7, 200):
            dec, nr = ref.delta_codec_ref(d, jnp.zeros_like(d),
                                          codec="topk", k=k)
            mask = np.asarray(ref.topk_mask(jnp.abs(d), k))
            assert mask.sum() == min(k, 200)
            # kept coordinates survive bit-exact, the rest are zero
            np.testing.assert_array_equal(np.asarray(dec)[mask],
                                          np.asarray(d)[mask])
            assert np.abs(np.asarray(dec)[~mask]).max(initial=0.0) == 0.0

    def test_topk_mask_breaks_ties_by_index(self):
        mag = jnp.asarray([1.0, 2.0, 2.0, 2.0, 0.5])
        mask = np.asarray(ref.topk_mask(mag, 2))
        np.testing.assert_array_equal(mask, [False, True, True, False, False])

    def test_int8_error_feedback_telescopes(self):
        """N compressed rounds of the same frozen delta: the cumulative
        decoded sum equals N*g up to the (bounded) final residual."""
        g = jax.random.normal(KEY, (128,)) * 2.0
        r = jnp.zeros_like(g)
        total = jnp.zeros_like(g)
        for _ in range(10):
            dec, r = ref.delta_codec_ref(g, r, codec="int8")
            total = total + dec
        drift = np.abs(np.asarray(total + r - 10 * g)).max()
        assert drift < 1e-4                       # fp summation noise only
        # int8 EF residual is bounded by ~one quantization step
        assert float(jnp.abs(r).max()) < 2 * float(jnp.abs(g).max()) / 127


# ---------------------------------------------------------------------------
# Pallas kernel == jnp oracle (bit-identical, incl. under vmap)
# ---------------------------------------------------------------------------
@pytest.mark.pallas
class TestDeltaCodecKernel:
    CASES = [("float32", 1, (4, 64)), ("int8", 1, (4, 64)),
             ("topk", 7, (4, 64)), ("int8", 1, (2, 1)),
             ("topk", 1, (2, 1)), ("int8", 1, (8, 3121)),
             ("topk", 156, (8, 3121))]

    @pytest.mark.parametrize("codec,k,shape", CASES)
    def test_kernel_bit_identical_to_ref(self, codec, k, shape):
        k1, k2 = jax.random.split(jax.random.PRNGKey(3))
        d = jax.random.normal(k1, shape) * 4
        r = jax.random.normal(k2, shape) * 0.2
        dec_k, nr_k = delta_codec(d, r, codec=codec, k=k, interpret=True)
        dec_r, nr_r = jax.vmap(lambda x, y: ref.delta_codec_ref(
            x, y, codec=codec, k=k))(d, r)
        np.testing.assert_array_equal(np.asarray(dec_k), np.asarray(dec_r))
        np.testing.assert_array_equal(np.asarray(nr_k), np.asarray(nr_r))

    def test_kernel_bit_identical_under_vmap(self):
        """vmap of the single-agent kernel call == the batched grid call ==
        vmap of the oracle."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(4))
        d = jax.random.normal(k1, (5, 96)) * 2
        r = jax.random.normal(k2, (5, 96)) * 0.1
        batched = delta_codec(d, r, codec="int8", interpret=True)
        vmapped = jax.vmap(lambda x, y: delta_codec(
            x, y, codec="int8", interpret=True))(d, r)
        oracle = jax.vmap(lambda x, y: ref.delta_codec_ref(
            x, y, codec="int8"))(d, r)
        for b, v, o in zip(batched, vmapped, oracle):
            np.testing.assert_array_equal(np.asarray(b), np.asarray(v))
            np.testing.assert_array_equal(np.asarray(b), np.asarray(o))

    def test_codec_roundtrip_pallas_path_matches_jnp(self):
        """The fleet-pytree wrapper: use_pallas routes every leaf through
        the kernel with identical results."""
        params = jax.vmap(lambda k: agent_init(CFG, k))(
            jax.random.split(KEY, 3))
        delta = jax.tree.map(lambda p: p * 0.01, params)
        res = jax.tree.map(jnp.zeros_like, params)
        for codec in ("int8", "topk"):
            t_j = TransportConfig(codec=codec, use_pallas=False)
            t_p = TransportConfig(codec=codec, use_pallas=True)
            dec_j, nr_j = codec_roundtrip(delta, res, t_j)
            dec_p, nr_p = codec_roundtrip(delta, res, t_p)
            for a, b in zip(jax.tree.leaves((dec_j, nr_j)),
                            jax.tree.leaves((dec_p, nr_p))):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Aggregation degenerate case (documented, previously untested)
# ---------------------------------------------------------------------------
class TestAggregateEmptySelection:
    def test_empty_selection_degenerates_to_base(self):
        """fed.aggregate's docstring: aggregation "is defined for any
        subset, including the empty one, which degenerates to keeping the
        base network". Backbone/value collapse to the pod base; head groups
        with no contributor keep each agent's own head; the base itself is
        unchanged."""
        n = 4
        params = jax.vmap(lambda k: agent_init(CFG, k))(
            jax.random.split(KEY, n))
        base_one = agent_init(CFG, jax.random.PRNGKey(9))
        base = jax.tree.map(lambda x: x[None], base_one)
        masks = jax.tree.map(lambda m: jnp.broadcast_to(m, (n,) + m.shape),
                             full_mask(CFG))
        groups = fed.head_group_ids(masks)
        sel = jnp.zeros((n,), bool)
        newp, newb = fed.aggregate(CFG, params, base, sel,
                                   jnp.zeros((n, 3)), groups,
                                   jnp.zeros((n,), jnp.int32), 1)
        from repro.core.agent import BACKBONE_KEYS, HEAD_KEYS
        for key in BACKBONE_KEYS:
            for a, b in zip(jax.tree.leaves(newp[key]),
                            jax.tree.leaves(base_one[key])):
                np.testing.assert_allclose(
                    np.asarray(a), np.broadcast_to(np.asarray(b), a.shape),
                    atol=1e-7)
        for key in HEAD_KEYS:
            for a, b in zip(jax.tree.leaves(newp[key]),
                            jax.tree.leaves(params[key])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(newb), jax.tree.leaves(base)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-7)


# ---------------------------------------------------------------------------
# Transport model: emergent stragglers, payload metrics
# ---------------------------------------------------------------------------
def _fleet(n=4, bandwidth=None, cfg=CFG, n_pods=1):
    return fleet_init(cfg, n, KEY, n_pods=n_pods,
                      bandwidth=None if bandwidth is None
                      else jnp.asarray(bandwidth))


def _episode(cfg, fleet, seed=1):
    traces = fleet_traces(jax.random.PRNGKey(seed),
                          fleet.pod_ids.shape[0], cfg.n_steps)
    return fleet_episode(cfg, fleet, traces)


class TestTransportModel:
    def test_deadline_makes_stragglers_emergent(self):
        """Slow links miss the round: they drop out of selection and are
        counted in fl_missed; fast links are unaffected."""
        cfg = FCPOConfig(clients_per_round=1.0)
        fleet = _fleet(4, bandwidth=[100.0, 100.0, 0.01, 0.01], cfg=cfg)
        fleet, rollouts, _ = _episode(cfg, fleet)
        t = TransportConfig(codec="int8", deadline_s=0.05)
        _, sel, flm = fl_round(cfg, fleet, rollouts, transport=t)
        sel = np.asarray(sel)
        assert sel[:2].all() and not sel[2:].any()
        assert float(flm["fl_missed"]) == 2.0

    def test_legacy_bernoulli_composes_with_deadline(self):
        """An agent participates iff Bernoulli-available AND on time."""
        cfg = FCPOConfig(clients_per_round=1.0)
        fleet = _fleet(4, bandwidth=[100.0, 100.0, 100.0, 0.01], cfg=cfg)
        fleet, rollouts, _ = _episode(cfg, fleet)
        avail = jnp.asarray([True, False, True, True])
        t = TransportConfig(codec="int8", deadline_s=0.05)
        _, sel, flm = fl_round(cfg, fleet, rollouts, avail, transport=t)
        np.testing.assert_array_equal(np.asarray(sel),
                                      [True, False, True, False])
        assert float(flm["fl_missed"]) == 1.0   # only the slow AVAILABLE one

    def test_history_payload_matches_static_accounting(self):
        cfg = FCPOConfig()
        n = 4
        fleet = _fleet(n, cfg=cfg)
        traces = fleet_traces(jax.random.PRNGKey(2), n, 4 * cfg.n_steps)
        t = TransportConfig(codec="int8")
        _, hist = train_fleet_scan(cfg, fleet, traces, transport=t)
        up = agent_payload_bytes(
            jax.tree.map(lambda x: x[0], fleet.astate.params), t)
        full = full_param_bytes(
            jax.tree.map(lambda x: x[0], fleet.astate.params))
        n_sel = max(1, int(round(cfg.clients_per_round * n)))
        expect = n_sel * up + downlink_bytes(t, n, 1, up, full)
        fl_eps = np.flatnonzero(hist["fl_payload_bytes"])
        np.testing.assert_array_equal(fl_eps, [1, 3])   # fl_every = 2
        np.testing.assert_allclose(hist["fl_payload_bytes"][fl_eps], expect,
                                   rtol=1e-6)
        assert (hist["fl_payload_bytes"][[0, 2]] == 0).all()
        # uplink seconds surface too and agree with the link model
        np.testing.assert_allclose(
            hist["fl_uplink_s"][fl_eps].mean(),
            float(np.sort(np.asarray(uplink_seconds(up, fleet.bandwidth)))
                  .mean()), rtol=0.5)   # selection picks a subset of links

    def test_default_transport_keeps_residuals_and_pending_untouched(self):
        fleet = _fleet(4)
        fleet, rollouts, _ = _episode(CFG, fleet)
        fleet2, _, flm = fl_round(CFG, fleet, rollouts)
        for x in jax.tree.leaves(fleet2.residuals):
            assert float(jnp.abs(x).max()) == 0.0
        assert not bool(fleet2.pending.has.any())
        assert float(flm["fl_stale_used"]) == 0.0


# ---------------------------------------------------------------------------
# Staleness-tolerant (async) rounds
# ---------------------------------------------------------------------------
class TestStaleness:
    def test_miss_parks_then_joins_discounted(self):
        """Round 1: the slow agent's upload parks. Round 2: the parked
        delta is consumed (staleness-discounted) while a fresh one parks
        again."""
        cfg = FCPOConfig(clients_per_round=1.0)
        fleet = _fleet(2, bandwidth=[100.0, 0.01], cfg=cfg)
        t = TransportConfig(codec="int8", deadline_s=0.05, async_rounds=True)

        fleet, rollouts, _ = _episode(cfg, fleet, seed=1)
        fleet, sel, flm = fl_round(cfg, fleet, rollouts, transport=t)
        # slow agent selected (async keeps it selectable) but not aggregated
        np.testing.assert_array_equal(np.asarray(sel), [True, False])
        np.testing.assert_array_equal(np.asarray(fleet.pending.has),
                                      [False, True])
        assert int(fleet.pending.staleness[1]) == 1
        assert float(flm["fl_stale_used"]) == 0.0
        assert float(flm["fl_missed"]) == 1.0
        parked = jax.tree.leaves(fleet.pending.delta)
        assert any(float(jnp.abs(x[1]).max()) > 0 for x in parked)

        fleet, rollouts, _ = _episode(cfg, fleet, seed=2)
        fleet, sel, flm = fl_round(cfg, fleet, rollouts, transport=t)
        # parked delta consumed: the slow agent now joins the aggregate
        np.testing.assert_array_equal(np.asarray(sel), [True, True])
        assert float(flm["fl_stale_used"]) == 1.0
        # ...and its new fresh miss parked again with staleness reset to 1
        np.testing.assert_array_equal(np.asarray(fleet.pending.has),
                                      [False, True])
        assert int(fleet.pending.staleness[1]) == 1

    def test_unselected_pending_ages(self):
        """A pending delta whose owner is not selected stays parked and its
        staleness grows."""
        cfg = FCPOConfig(clients_per_round=0.5)   # top-1 of 2
        fleet = _fleet(2, bandwidth=[100.0, 0.01], cfg=cfg)
        t = TransportConfig(codec="int8", deadline_s=0.05, async_rounds=True,
                            staleness_decay=0.5)
        # force agent 1 parked by hand, then run a round where it loses
        # selection to the fast agent (bandwidth enters Eq. 7 utility).
        pend = pending_init(fleet.astate.params)
        pend = pend._replace(has=jnp.asarray([False, True]),
                             staleness=jnp.asarray([0, 1], jnp.int32))
        fleet = fleet._replace(pending=pend)
        fleet, rollouts, _ = _episode(cfg, fleet, seed=3)
        fleet, sel, flm = fl_round(cfg, fleet, rollouts, transport=t)
        np.testing.assert_array_equal(np.asarray(sel), [True, False])
        assert bool(fleet.pending.has[1])
        assert int(fleet.pending.staleness[1]) == 2
        assert float(flm["fl_stale_used"]) == 0.0

    def test_on_time_but_unselected_owner_keeps_pending(self):
        """Losing Eq. 7 selection is not an upload: an on-time owner's
        parked delta must survive (and age), not be silently dropped."""
        cfg = FCPOConfig(clients_per_round=0.5)   # top-1 of 2
        fleet = _fleet(2, bandwidth=[100.0, 50.0], cfg=cfg)
        t = TransportConfig(codec="int8", deadline_s=0.05, async_rounds=True)
        pend = pending_init(fleet.astate.params)
        pend = pend._replace(has=jnp.asarray([False, True]),
                             staleness=jnp.asarray([0, 1], jnp.int32))
        fleet = fleet._replace(pending=pend)
        fleet, rollouts, _ = _episode(cfg, fleet, seed=6)
        fleet, sel, flm = fl_round(cfg, fleet, rollouts, transport=t)
        # agent 1 is on time (fast link) but loses selection to agent 0
        np.testing.assert_array_equal(np.asarray(sel), [True, False])
        assert bool(fleet.pending.has[1])
        assert int(fleet.pending.staleness[1]) == 2
        assert float(flm["fl_stale_used"]) == 0.0

    def test_unselected_agents_enter_aggregation_uncompressed(self):
        """A lossy codec must only distort what actually crossed the wire:
        with an empty selection the round must equal the float32 round
        (Alg. 1's no-contributor fallback keeps TRUE heads, not a lossy
        reconstruction whose error feedback was never committed)."""
        cfg = FCPOConfig()
        f_int8 = _fleet(4, cfg=cfg)
        f_int8, rollouts, _ = _episode(cfg, f_int8)
        none = jnp.zeros((4,), bool)
        out8, _, _ = fl_round(cfg, f_int8, rollouts, none,
                              transport=TransportConfig(codec="int8"))
        out32, _, _ = fl_round(cfg, f_int8, rollouts, none)
        for a, b in zip(jax.tree.leaves(out8.astate.params),
                        jax.tree.leaves(out32.astate.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_fresh_arrival_supersedes_pending(self):
        cfg = FCPOConfig(clients_per_round=1.0)
        fleet = _fleet(2, bandwidth=[100.0, 100.0], cfg=cfg)
        t = TransportConfig(codec="int8", deadline_s=0.05, async_rounds=True)
        pend = pending_init(fleet.astate.params)
        pend = pend._replace(has=jnp.asarray([False, True]),
                             staleness=jnp.asarray([0, 3], jnp.int32))
        fleet = fleet._replace(pending=pend)
        fleet, rollouts, _ = _episode(cfg, fleet, seed=4)
        fleet, sel, flm = fl_round(cfg, fleet, rollouts, transport=t)
        np.testing.assert_array_equal(np.asarray(sel), [True, True])
        assert not bool(fleet.pending.has.any())     # superseded, dropped
        assert float(flm["fl_stale_used"]) == 0.0


# ---------------------------------------------------------------------------
# Driver equivalence + compile-once with transport enabled
# ---------------------------------------------------------------------------
class TestScanEquivalenceWithTransport:
    TRANSPORT = TransportConfig(codec="int8", deadline_s=0.02,
                                async_rounds=True)

    def test_scan_matches_reference_with_transport(self):
        """10 episodes, int8 codec + deadline + async staleness + Bernoulli
        stragglers, 2 pods: scan == reference trajectory-for-trajectory,
        including the new fl_* history keys and the transport state."""
        n = 4
        cfg = FCPOConfig()
        f_ref = fleet_init(cfg, n, KEY, n_pods=2)
        f_scan = fleet_init(cfg, n, KEY, n_pods=2)
        traces = fleet_traces(jax.random.PRNGKey(1), n, 10 * cfg.n_steps)
        kw = dict(straggler_prob=0.3, seed=7, transport=self.TRANSPORT)
        rf, rh = train_fleet_reference(cfg, f_ref, traces, **kw)
        sf, sh = train_fleet_scan(cfg, f_scan, traces, **kw)
        assert sorted(rh) == sorted(sh)
        assert any(k.startswith("fl_") for k in sh)
        for k in rh:
            np.testing.assert_allclose(sh[k], rh[k], rtol=1e-4, atol=1e-5,
                                       err_msg=k)
        for a, b in zip(jax.tree.leaves(rf.astate.params),
                        jax.tree.leaves(sf.astate.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        for a, b in zip(jax.tree.leaves((rf.residuals, rf.pending)),
                        jax.tree.leaves((sf.residuals, sf.pending))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_scan_compiles_once_with_codec(self):
        """Any codec keeps the whole cadence ONE cached jitted scan."""
        n, eps = 2, 4
        cfg = FCPOConfig()
        traces = fleet_traces(jax.random.PRNGKey(1), n, eps * cfg.n_steps)
        t = TransportConfig(codec="topk")
        fn = _scan_fn(False)
        train_fleet_scan(cfg, fleet_init(cfg, n, KEY), traces, donate=False,
                         transport=t)
        size = fn._cache_size()
        train_fleet_scan(cfg, fleet_init(cfg, n, KEY), traces, donate=False,
                         transport=t)
        assert fn._cache_size() == size
