"""Sharding-rule unit tests: divisibility fallbacks, path rules, cache specs.

These run against an *abstract* 16x16 / 2x16x16 mesh built on CPU only for
spec computation (AbstractMesh — no devices needed), so they validate the
rules without the 512-device override."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models.registry import get_config, get_model, input_specs
from repro.configs.base import SHAPES

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH3 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


class TestGreedySpec:
    def test_divisible_takes_first_candidate(self):
        spec = shd.greedy_spec((128, 4096), [["data"], ["model"]], MESH)
        assert spec == P("data", "model")

    def test_indivisible_falls_through(self):
        # 40 experts don't divide model=16 -> replicated; ff 512 does
        spec = shd.greedy_spec((40, 1536, 512),
                               [["model"], ["data"], ["model"]], MESH)
        assert spec == P(None, "data", "model")

    def test_axis_used_once(self):
        spec = shd.greedy_spec((64, 64), [["model"], ["model"]], MESH)
        assert spec == P("model")  # second dim replicated, trailing None dropped

    def test_composite_batch_axis(self):
        spec = shd.greedy_spec((256, 4096), [[("pod", "data"), "data"], []], MESH3)
        assert spec == P(("pod", "data"))

    def test_composite_falls_back_to_single(self):
        # batch 16 not divisible by 32 -> falls to data(16)
        spec = shd.greedy_spec((16, 4096), [[("pod", "data"), "data"], []], MESH3)
        assert spec == P("data")

    def test_priority_order(self):
        # both dims want model; priority gives it to dim 2 (kv heads)
        spec = shd.greedy_spec((8, 32768, 16, 128),
                               [[], ["model"], ["model"], []], MESH,
                               priority=[0, 2, 1, 3])
        assert spec == P(None, None, "model")


class TestParamRules:
    def test_all_archs_all_params_get_valid_specs(self):
        for arch in ("qwen2-7b", "granite-moe-3b-a800m", "deepseek-v2-lite-16b",
                     "zamba2-1.2b", "xlstm-125m", "hubert-xlarge", "gemma-7b"):
            cfg = get_config(arch)
            model = get_model(cfg)
            specs = jax.eval_shape(model.init, jax.random.PRNGKey(0))

            def check(path, leaf):
                p = shd.param_spec(shd._path_str(path), leaf.shape, MESH)
                # every named axis must divide its dim
                flat = []
                for i, entry in enumerate(p):
                    if entry is None:
                        continue
                    axes = entry if isinstance(entry, tuple) else (entry,)
                    prod = 1
                    for a in axes:
                        prod *= MESH.shape[a]
                    assert leaf.shape[i] % prod == 0, (arch, shd._path_str(path),
                                                       leaf.shape, p)

            jax.tree_util.tree_map_with_path(check, specs)

    def test_embedding_vocab_sharded(self):
        spec = shd.param_spec("embed/table", (152064, 3584), MESH)
        assert spec == P("model", "data")

    def test_granite_odd_vocab_replicates_vocab_dim(self):
        spec = shd.param_spec("embed/table", (49155, 1536), MESH)
        assert spec[0] is None  # 49155 = 3*5*29*113: nothing divides

    def test_moe_expert_parallel_when_divisible(self):
        # deepseek: 64 experts / model=16 OK
        spec = shd.param_spec("blocks/moe/gate", (27, 64, 2048, 1408), MESH)
        assert spec == P(None, "model", "data")

    def test_moe_tensor_parallel_fallback(self):
        # granite: 40 experts don't divide -> ff TP
        spec = shd.param_spec("blocks/moe/gate", (32, 40, 1536, 512), MESH)
        assert spec == P(None, None, "data", "model")

    def test_stacked_layer_dim_never_sharded(self):
        spec = shd.param_spec("blocks/attn/wq/w", (28, 3584, 3584), MESH)
        assert spec[0] is None


class TestCacheRules:
    def test_gqa_cache_heads_sharded_when_divisible(self):
        cfg = get_config("gemma-7b")
        model = get_model(cfg)
        cache = model.cache_spec(128, 32768)
        sh = shd.cache_shardings(cache, MESH)
        assert sh["layers"]["k"].spec == P(None, "data", None, "model")

    def test_qwen2_7b_kv4_falls_to_sequence(self):
        cfg = get_config("qwen2-7b")
        model = get_model(cfg)
        cache = model.cache_spec(128, 32768)
        sh = shd.cache_shardings(cache, MESH)
        # 4 kv heads don't divide model=16 -> sequence-sharded cache
        assert sh["layers"]["k"].spec == P(None, "data", "model")

    def test_long_context_batch1_uses_model_on_heads(self):
        cfg = get_config("zamba2-1.2b")
        model = get_model(cfg)
        cache = model.cache_spec(1, 524288)
        sh = shd.cache_shardings(cache, MESH)
        assert sh["attn"]["k"].spec == P(None, None, None, "model")

    def test_offset_replicated(self):
        cfg = get_config("qwen2-0.5b")
        model = get_model(cfg)
        sh = shd.cache_shardings(model.cache_spec(8, 128), MESH)
        assert sh["offset"].spec == P()


class TestInputRules:
    @pytest.mark.parametrize("shape_name", list(SHAPES))
    def test_inputs_shard_batch(self, shape_name):
        cfg = get_config("qwen2-0.5b")
        shape = SHAPES[shape_name]
        specs = input_specs(cfg, shape)
        sh = shd.input_shardings(specs, MESH3)
        for leaf, s in zip(jax.tree.leaves(specs), jax.tree.leaves(sh)):
            if s.spec and s.spec[0]:
                axes = s.spec[0] if isinstance(s.spec[0], tuple) else (s.spec[0],)
                prod = 1
                for a in axes:
                    prod *= MESH3.shape[a]
                assert leaf.shape[0] % prod == 0
