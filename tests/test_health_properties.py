"""Hypothesis property tests for the health observatory's sketches and
detectors (skipped, like the other *_properties modules, when hypothesis
is not installed — tests/test_health.py carries deterministic slices of
the same invariants)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.health.drift import drift_init, drift_update  # noqa: E402
from repro.health.sketch import (hist_init, hist_quantile,  # noqa: E402
                                 hist_update_batch)

pytestmark = pytest.mark.health

SETTINGS = dict(max_examples=25, deadline=None)
DK = dict(k=0.5, h=10.0, ph_delta=0.2, ph_lambda=25.0, ema_slow=0.02,
          ema_fast=0.3, warmup=20, zclip=8.0, var_floor=1e-3)


@settings(**SETTINGS)
@given(st.lists(st.floats(-1.0, 1.0, allow_nan=False, width=32),
                min_size=8, max_size=200),
       st.sampled_from([0.1, 0.25, 0.5, 0.75, 0.9]),
       st.sampled_from([8, 16, 32]))
def test_hist_quantile_within_one_bin_width(xs, p, bins):
    """The sketch's quantile is within one bin width of the exact
    inverted-CDF empirical quantile, for any in-range stream, any
    resolution, any probe point — the accuracy contract
    docs/observability.md states."""
    counts = hist_update_batch(hist_init(bins), jnp.asarray(xs, jnp.float32),
                               -1.0, 1.0)
    est = float(hist_quantile(counts, p, -1.0, 1.0))
    exact = float(np.quantile(np.asarray(xs, np.float32), p,
                              method="inverted_cdf"))
    assert abs(est - exact) <= 2.0 / bins + 1e-5


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1),
       st.floats(-5.0, 5.0, allow_nan=False),
       st.floats(0.1, 3.0, allow_nan=False))
def test_drift_never_fires_on_iid(seed, mu, sd):
    """CUSUM/Page-Hinkley false-alarm invariant: on an i.i.d. Gaussian
    stream — any location, any scale — the detector stays silent. The
    defaults put the per-run false-alarm probability near exp(-2kh) ~
    5e-5; standardization makes the bound location/scale free, which is
    exactly what hypothesis probes here."""
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(mu, sd, size=300), jnp.float32)

    def step(s, x):
        s = drift_update(s, x, **DK)
        return s, s.flag

    _, flags = jax.lax.scan(step, drift_init(), xs)
    assert float(jnp.max(flags)) == 0.0
