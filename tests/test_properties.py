"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.fcpo import FCPOConfig
from repro.core import env as env_mod
from repro.core import federated as fed
from repro.core.agent import ActionMask, agent_init, full_mask, sample_actions
from repro.core.buffer import buffer_init, buffer_insert
from repro.core.ppo import gae, returns, Rollout
from repro.kernels import ref

CFG = FCPOConfig()
SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# Buffer invariants
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(st.lists(st.floats(-50, 50), min_size=1, max_size=30),
       st.integers(2, 8))
def test_buffer_never_exceeds_capacity(vals, cap):
    cfg = FCPOConfig(buffer_size=cap)
    buf = buffer_init(cfg)
    na = cfg.n_res + cfg.n_bs + cfg.n_mt
    probs = jnp.full((na,), 1.0 / na)
    for v in vals:
        buf = buffer_insert(cfg, buf, jnp.full((8,), v),
                            jnp.zeros((3,), jnp.int32), 0.0, 0.0, 0.0, probs)
    assert int(buf.filled.sum()) <= cap
    assert int(buf.filled.sum()) == min(len(vals), cap) or int(buf.filled.sum()) == cap
    # scores of filled slots are finite
    assert np.isfinite(np.asarray(buf.score)[np.asarray(buf.filled)]).all()


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1))
def test_buffer_count_tracks_attempts(seed):
    cfg = FCPOConfig(buffer_size=4)
    buf = buffer_init(cfg)
    na = cfg.n_res + cfg.n_bs + cfg.n_mt
    probs = jnp.full((na,), 1.0 / na)
    k = jax.random.PRNGKey(seed)
    n = int(jax.random.randint(k, (), 1, 10))
    for i in range(n):
        buf = buffer_insert(cfg, buf, jax.random.normal(jax.random.fold_in(k, i), (8,)),
                            jnp.zeros((3,), jnp.int32), 0.0, 0.0, 0.0, probs)
    assert int(buf.count) == n


# ---------------------------------------------------------------------------
# Request-level twin invariants (repro.sim)
# ---------------------------------------------------------------------------
from repro.sim import SimParams, sim_init  # noqa: E402

SIM_SP = SimParams(dt=0.05, k_ticks=1, ring=32, hist_n=16)
_sim_tick = jax.jit(lambda s, n, caps: ref.sim_microtick(*s, n, caps))


@settings(**SETTINGS)
@given(st.lists(st.integers(0, 10), min_size=1, max_size=40),
       st.sampled_from([0.5, 1.0, 1.5, 2.5]),
       st.sampled_from([1.0, 2.0, 4.0]),
       st.integers(1, 8), st.integers(1, 3))
def test_sim_microtick_conservation(arrivals, c_pre, c_post, batch, t_batch):
    """At EVERY microtick: arrivals == completed + dropped + in-flight, the
    stage pointers stay ordered and within ring capacity (so no request can
    complete after its slot is recycled), and no completion is recorded
    with a sub-tick latency (histogram bucket 0 stays empty)."""
    caps = jnp.asarray([c_pre, c_post, batch, t_batch, 8.0, 4.0], jnp.float32)
    state = tuple(sim_init(SIM_SP))
    for n in arrivals:
        state = _sim_tick(state, jnp.asarray(n, jnp.int32), caps)
        c = np.asarray(state[1])
        in_flight = c[ref.SIM_TAIL] - c[ref.SIM_HEAD]
        assert c[ref.SIM_ARRIVED] == (c[ref.SIM_DROPPED]
                                      + c[ref.SIM_COMPLETED] + in_flight)
        assert (c[ref.SIM_HEAD] <= c[ref.SIM_PINF] <= c[ref.SIM_LAUNCH]
                <= c[ref.SIM_PPRE] <= c[ref.SIM_TAIL])
        assert 0 <= in_flight <= SIM_SP.ring
        assert c[ref.SIM_EFFECTIVE] <= c[ref.SIM_COMPLETED]
    assert int(np.asarray(state[4])[0]) == 0  # no zero-tick completions


# ---------------------------------------------------------------------------
# FL transport codec invariants (repro.fl / kernels.ref)
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(st.lists(st.floats(-20, 20), min_size=4, max_size=64),
       st.sampled_from(["int8", "topk"]), st.integers(2, 8),
       st.integers(1, 6))
def test_error_feedback_residuals_telescope(vals, codec, n_rounds, k):
    """After N compressed rounds with frozen inputs, the cumulative decoded
    deltas approach the uncompressed sum: Σ decoded + r_N == N·g + r_0 up to
    float summation noise (the per-round identity decoded + r' == g + r is
    bit-exact), and the residual stays bounded (no drift blow-up)."""
    g = jnp.asarray(vals, jnp.float32)
    k = min(k, g.shape[0])
    r = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(n_rounds):
        r_old = r
        dec, r = ref.delta_codec_ref(g, r, codec=codec, k=k)
        # per-round identity decoded + r' == g + r (bit-exact for topk,
        # one ulp of the quantization scale for int8)
        np.testing.assert_allclose(np.asarray(dec + r),
                                   np.asarray(g + r_old),
                                   atol=1e-5 * max(float(jnp.abs(g).max()),
                                                   1.0), rtol=0)
        total = total + dec
    gmax = max(float(jnp.abs(g).max()), 1e-6)
    drift = np.abs(np.asarray(total + r - n_rounds * g)).max()
    assert drift <= 1e-4 * n_rounds * max(gmax, 1.0)
    # bounded residual: int8 error is ~one quantization step; top-k error
    # feedback accumulates at most the untransmitted mass of one round
    # on top of the previous residual, which stays O((n/k)·|g|).
    bound = (2 * gmax / 127 if codec == "int8"
             else (g.shape[0] / k + 1) * gmax)
    assert float(jnp.abs(r).max()) <= bound + 1e-5


@settings(**SETTINGS)
@given(st.lists(st.floats(-50, 50), min_size=2, max_size=64),
       st.integers(1, 64))
def test_topk_roundtrip_preserves_selected_coordinates(vals, k):
    """top-k encode/decode keeps EXACTLY k coordinates, bit-exact, and the
    residual is exactly the untransmitted mass."""
    g = jnp.asarray(vals, jnp.float32)
    k = min(k, g.shape[0])
    dec, r = ref.delta_codec_ref(g, jnp.zeros_like(g), codec="topk", k=k)
    mask = np.asarray(ref.topk_mask(jnp.abs(g), k))
    assert int(mask.sum()) == k
    np.testing.assert_array_equal(np.asarray(dec)[mask], np.asarray(g)[mask])
    assert np.abs(np.asarray(dec)[~mask]).max(initial=0.0) == 0.0
    np.testing.assert_array_equal(np.asarray(dec + r), np.asarray(g))


# ---------------------------------------------------------------------------
# Aggregation invariants
# ---------------------------------------------------------------------------
def _mini_fleet(n, seed=0):
    key = jax.random.PRNGKey(seed)
    params = jax.vmap(lambda k: agent_init(CFG, k))(jax.random.split(key, n))
    base = jax.tree.map(lambda x: x[None] * 0 + 0.5, jax.tree.map(lambda x: x[0], params))
    masks = jax.tree.map(lambda m: jnp.broadcast_to(m, (n,) + m.shape),
                         full_mask(CFG))
    groups = fed.head_group_ids(masks)
    return params, base, groups


@settings(**SETTINGS)
@given(st.integers(2, 6), st.integers(0, 100))
def test_aggregation_permutation_invariant(n, seed):
    """Shuffling client order must not change the aggregate (no ordering
    dependence — unlike the paper's literal accumulating pseudo-code)."""
    params, base, groups = _mini_fleet(n, seed)
    sel = jnp.ones((n,), bool)
    rng = np.random.default_rng(seed)
    hl = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
    pod = jnp.zeros((n,), jnp.int32)
    newp1, newb1 = fed.aggregate(CFG, params, base, sel, hl, groups, pod, 1)

    perm = jnp.asarray(rng.permutation(n))
    params_p = jax.tree.map(lambda x: x[perm], params)
    hl_p = hl[perm]
    newp2, newb2 = fed.aggregate(CFG, params_p, base, sel, hl_p, groups, pod, 1)
    for a, b in zip(jax.tree.leaves(newb1), jax.tree.leaves(newb2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@settings(**SETTINGS)
@given(st.integers(2, 6), st.integers(0, 100))
def test_aggregation_preserves_structure_and_finiteness(n, seed):
    params, base, groups = _mini_fleet(n, seed)
    sel = jnp.asarray(np.random.default_rng(seed).random(n) < 0.7)
    hl = jnp.zeros((n, 3))
    newp, newb = fed.aggregate(CFG, params, base, sel, hl, groups,
                               jnp.zeros((n,), jnp.int32), 1)
    assert jax.tree_util.tree_structure(newp) == jax.tree_util.tree_structure(params)
    for x in jax.tree.leaves(newp) + jax.tree.leaves(newb):
        assert np.isfinite(np.asarray(x)).all()


@settings(**SETTINGS)
@given(st.integers(0, 1000))
def test_identical_clients_aggregate_to_themselves(seed):
    """If every client AND the base are identical, Alg. 1 is a fixed point."""
    key = jax.random.PRNGKey(seed)
    one = agent_init(CFG, key)
    n = 3
    params = jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), one)
    base = jax.tree.map(lambda x: x[None], one)
    masks = jax.tree.map(lambda m: jnp.broadcast_to(m, (n,) + m.shape),
                         full_mask(CFG))
    groups = fed.head_group_ids(masks)
    newp, newb = fed.aggregate(CFG, params, base, jnp.ones((n,), bool),
                               jnp.zeros((n, 3)), groups,
                               jnp.zeros((n,), jnp.int32), 1)
    for a, b in zip(jax.tree.leaves(newp), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# Env / reward invariants
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(st.integers(0, 3), st.integers(0, 6), st.integers(0, 3),
       st.floats(1.0, 400.0), st.floats(0.25, 2.0))
def test_reward_always_normalized(a_res, a_bs, a_mt, rate, speed):
    ep = env_mod.default_env_params(speed=speed)
    s = env_mod.env_init(CFG)
    for _ in range(5):
        s, r, info = env_mod.env_step(
            CFG, ep, s, jnp.asarray([a_res, a_bs, a_mt], jnp.int32), rate)
        assert -1.0 <= float(r) <= 1.0
        assert float(info["effective_throughput"]) <= float(info["throughput"]) + 1e-6
        assert float(s.pre_q) >= 0 and float(s.post_q) >= 0


@settings(**SETTINGS)
@given(st.lists(st.floats(-1, 1), min_size=1, max_size=20))
def test_gae_and_returns_finite_and_bounded(rs):
    r = jnp.asarray(rs, jnp.float32)
    v = jnp.zeros_like(r)
    adv = gae(CFG, r, v)
    ret = returns(CFG, r)
    assert np.isfinite(np.asarray(adv)).all()
    # γ=0.1 geometric bound: |returns| <= max|r| / (1-γ)
    assert float(jnp.max(jnp.abs(ret))) <= (max(abs(x) for x in rs) + 1e-6) / 0.9


# ---------------------------------------------------------------------------
# Sampling respects masks (heterogeneous action spaces)
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(st.integers(1, CFG.n_bs), st.integers(1, CFG.n_mt), st.integers(0, 10_000))
def test_sampling_respects_arbitrary_masks(nb, nm, seed):
    key = jax.random.PRNGKey(seed)
    params = agent_init(CFG, key)
    mask = ActionMask(
        jnp.ones(CFG.n_res, bool),
        jnp.arange(CFG.n_bs) < nb,
        jnp.arange(CFG.n_mt) < nm,
    )
    state = jax.random.normal(key, (32, 8))
    actions, logp, _ = sample_actions(CFG, params, state, mask, key)
    assert int(actions[:, 1].max()) < nb
    assert int(actions[:, 2].max()) < nm
    assert np.isfinite(np.asarray(logp)).all()


# ---------------------------------------------------------------------------
# Packing is a (partial) permutation: no token lost or duplicated
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(st.lists(st.integers(-1, 31), min_size=1, max_size=64))
def test_pack_ref_is_exact_gather(idx_list):
    tok = jnp.arange(32 * 4, dtype=jnp.float32).reshape(32, 4)
    idx = jnp.asarray(idx_list, jnp.int32)
    out = ref.pack_ref(tok, idx)
    for i, j in enumerate(idx_list):
        if j >= 0:
            np.testing.assert_array_equal(np.asarray(out[i]), np.asarray(tok[j]))
        else:
            assert float(jnp.abs(out[i]).sum()) == 0.0
