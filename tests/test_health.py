"""Fleet health observatory: sketch accuracy, drift detection, FL
contribution attribution, driver wiring (scan == reference, off-mode
bit-identity), alert rules, and the watch CLI rendering.

Deterministic tier-1 slice; tests/test_health_properties.py carries the
hypothesis generalizations of the sketch/detector invariants. Deselect
the whole observatory with ``-m "not health"``.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.fcpo import FCPOConfig
from repro.core import federated as fed
from repro.core.fleet import (fleet_init, train_fleet_reference,
                              train_fleet_scan)
from repro.data.workload import (drift_traces, fleet_traces,
                                 flash_crowd_traces, switching_traces)
from repro.health import (HEALTH_METRIC_KEYS, HealthConfig, health_init,
                          update_episode)
from repro.health.alerts import (AlertEngine, AlertRule, DEFAULT_RULES,
                                 read_alerts)
from repro.health.attribution import (_masked_lower_median,
                                      attribution_scores,
                                      robust_reference_weights)
from repro.health.drift import drift_init, drift_reset_episode, drift_update
from repro.health.sketch import (hist_init, hist_merge, hist_quantile,
                                 hist_update, hist_update_batch, p2_init,
                                 p2_update, p2_value)
from repro.launch.watch import render
from repro.resilience import GuardConfig
from repro.resilience.guards import suspicion_gate

pytestmark = pytest.mark.health

CFG = FCPOConfig()
KEY = jax.random.PRNGKey(0)
DK = dict(k=0.5, h=10.0, ph_delta=0.2, ph_lambda=25.0, ema_slow=0.02,
          ema_fast=0.3, warmup=20, zclip=8.0, var_floor=1e-3)


# ---------------------------------------------------------------------------
# Sketches
# ---------------------------------------------------------------------------
class TestHistogram:
    def test_quantile_within_one_bin_width(self):
        rng = np.random.default_rng(0)
        xs = rng.uniform(-1.0, 1.0, size=500).astype(np.float32)
        counts = hist_update_batch(hist_init(16), jnp.asarray(xs), -1.0, 1.0)
        width = 2.0 / 16
        for p in (0.1, 0.5, 0.9):
            est = float(hist_quantile(counts, p, -1.0, 1.0))
            exact = float(np.quantile(xs, p, method="inverted_cdf"))
            assert abs(est - exact) <= width + 1e-6, (p, est, exact)

    def test_batch_update_matches_sequential(self):
        rng = np.random.default_rng(1)
        xs = rng.normal(0.0, 0.7, size=64).astype(np.float32)
        seq = hist_init(8)
        for x in xs:
            seq = hist_update(seq, x, -1.0, 1.0)
        batch = hist_update_batch(hist_init(8), jnp.asarray(xs), -1.0, 1.0)
        np.testing.assert_array_equal(np.asarray(seq), np.asarray(batch))
        # out-of-range values clamp to edge bins: the count stays exact
        assert float(jnp.sum(batch)) == len(xs)

    def test_merge_is_additive(self):
        a = hist_update_batch(hist_init(8), jnp.linspace(-0.9, 0.0, 10),
                              -1.0, 1.0)
        b = hist_update_batch(hist_init(8), jnp.linspace(0.0, 0.9, 10),
                              -1.0, 1.0)
        merged = hist_merge(jnp.stack([a, b]))
        np.testing.assert_allclose(np.asarray(merged), np.asarray(a + b))


class TestP2:
    def test_median_converges(self):
        rng = np.random.default_rng(2)
        xs = rng.normal(0.2, 0.3, size=600).astype(np.float32)
        s = p2_init(0.5)
        for x in xs:
            s = p2_update(s, x, 0.5)
        est = float(p2_value(s))
        assert abs(est - float(np.median(xs))) < 0.05

    def test_warmup_is_exact(self):
        s = p2_init(0.5)
        for x in (0.3, -0.5, 0.1):
            s = p2_update(s, x, 0.5)
        assert float(p2_value(s)) == pytest.approx(0.1)  # median of 3


# ---------------------------------------------------------------------------
# Drift detectors
# ---------------------------------------------------------------------------
def _run_detector(xs):
    def step(s, x):
        s = drift_update(s, x, **DK)
        return s, (s.flag, s.score)
    _, (flags, _) = jax.lax.scan(step, drift_init(),
                                 jnp.asarray(xs, jnp.float32))
    return np.asarray(flags)


class TestDrift:
    def test_silent_on_iid(self):
        rng = np.random.default_rng(3)
        flags = _run_detector(rng.normal(0.0, 1.0, size=400))
        assert flags.max() == 0.0

    def test_fires_on_step_shift(self):
        rng = np.random.default_rng(4)
        xs = np.concatenate([rng.normal(0.0, 1.0, size=200),
                             rng.normal(3.0, 1.0, size=100)])
        flags = _run_detector(xs)
        assert flags[:200].max() == 0.0
        fired = np.nonzero(flags[200:])[0]
        assert fired.size > 0 and fired[0] <= 50

    def test_reset_clears_episode_accumulators_not_baseline(self):
        rng = np.random.default_rng(5)
        s = drift_init()
        for x in rng.normal(0.0, 1.0, size=100):
            s = drift_update(s, float(x), **DK)
        r = drift_reset_episode(s)
        assert float(r.flag) == 0.0 and float(r.score) == 0.0
        np.testing.assert_allclose(float(r.mu), float(s.mu))


# ---------------------------------------------------------------------------
# Attribution
# ---------------------------------------------------------------------------
def _deltas(rows):
    return {"w": jnp.asarray(np.stack(rows), jnp.float32)}


class TestAttribution:
    def test_sign_flip_byzantine_ranks_top(self):
        rng = np.random.default_rng(6)
        honest = rng.normal(size=(5, 32)).astype(np.float32) * 0.05
        honest += honest.mean(axis=0)  # coherent fleet direction
        byz = -25.0 * honest[0]
        deltas = _deltas(list(honest) + [byz])
        sel = jnp.ones((6,), jnp.float32)
        susp = np.asarray(attribution_scores(deltas, sel)["susp"])
        assert susp.argmax() == 5
        assert susp[5] > 2 * susp[:5].max()

    def test_half_byzantine_selection_still_ranks(self):
        """The 2-of-4 regression: with half the *selected* set byzantine,
        the interpolated median norm averages an honest and an attacker
        norm and the clip stops vanishing — the lower median keeps the
        clip scale honest and the attackers on top."""
        rng = np.random.default_rng(7)
        base = rng.normal(size=32).astype(np.float32)
        mk = lambda: (0.6 * base + rng.normal(size=32) * 0.4).astype(
            np.float32) * 0.05
        h0, h1, b0, b1 = mk(), mk(), mk(), mk()
        deltas = _deltas([h0, h1, 0 * h0, -25.0 * b0,
                          0 * h0, 0 * h0, -25.0 * b1, 0 * h0])
        sel = jnp.asarray([1, 1, 0, 1, 0, 0, 1, 0], jnp.float32)
        out = attribution_scores(deltas, sel)
        susp = np.asarray(out["susp"])
        assert min(susp[3], susp[6]) > max(susp[0], susp[1])
        assert susp[2] == susp[4] == 0.0  # unselected never score

    def test_lower_median_ignores_inflated_half(self):
        norms = jnp.asarray([1.0, 1.1, 25.0, 26.0], jnp.float32)
        mask = jnp.ones((4,), bool)
        assert float(_masked_lower_median(norms, mask)) == pytest.approx(1.1)
        w = robust_reference_weights(
            jnp.asarray([1.0, 1.1, 25.0, 26.0, 99.0], jnp.float32),
            jnp.asarray([1, 1, 1, 1, 0], jnp.float32))
        assert float(w[4]) == 0.0                       # unselected
        assert float(w[0]) == 1.0                       # honest full weight
        assert float(w[2]) < (1.1 / 25.0) ** 2 * 1.01   # squared clip


class TestSuspicionGating:
    def test_gate_drops_suspects(self):
        sel = jnp.asarray([True, True, True, False])
        susp = jnp.asarray([0.9, 0.2, 0.6, 0.95])
        gated, n = suspicion_gate(sel, susp, 0.5)
        np.testing.assert_array_equal(np.asarray(gated),
                                      [False, True, False, False])
        assert float(n) == 2.0  # already-unselected suspect not counted

    def test_select_clients_refills_freed_slots(self):
        a = 4
        stats = fed.ClientStats(
            mem_avail=jnp.full((a,), 0.5) + jnp.arange(a) * 0.1,
            compute_avail=jnp.full((a,), 0.5),
            diversity=jnp.full((a,), 1.0),
            bandwidth=jnp.full((a,), 10.0),
            available=jnp.ones((a,), bool))
        plain = fed.select_clients(CFG, stats)
        k = int(np.asarray(plain).sum())
        susp = jnp.where(plain, 0.9, 0.0)  # everyone chosen is suspect
        gated = fed.select_clients(CFG, stats, suspicion=susp,
                                   susp_threshold=0.5)
        assert int(np.asarray(gated).sum()) == k  # slots refilled
        assert not bool(np.asarray(gated & plain).any())


# ---------------------------------------------------------------------------
# Driver wiring
# ---------------------------------------------------------------------------
class TestDriverWiring:
    def test_scan_matches_reference_with_health(self):
        n, eps = 3, 6
        health = HealthConfig()
        traces = fleet_traces(jax.random.PRNGKey(1), n, eps * CFG.n_steps)
        f_ref = fleet_init(CFG, n, KEY, health=health)
        f_scan = fleet_init(CFG, n, KEY, health=health)
        kw = dict(straggler_prob=0.3, seed=7, health=health)
        _, rh = train_fleet_reference(CFG, f_ref, traces, **kw)
        sf, sh = train_fleet_scan(CFG, f_scan, traces, **kw)
        assert sorted(rh) == sorted(sh)
        for k in HEALTH_METRIC_KEYS:
            assert k in sh
        for k in rh:
            np.testing.assert_allclose(np.asarray(sh[k]), np.asarray(rh[k]),
                                       rtol=1e-4, atol=1e-5, err_msg=k)

    def test_health_off_is_bit_identical(self):
        n, eps = 3, 6
        health = HealthConfig()
        traces = fleet_traces(jax.random.PRNGKey(1), n, eps * CFG.n_steps)
        kw = dict(straggler_prob=0.3, seed=7)
        f_off, h_off = train_fleet_scan(
            CFG, fleet_init(CFG, n, KEY), traces, **kw)
        f_on, h_on = train_fleet_scan(
            CFG, fleet_init(CFG, n, KEY, health=health), traces,
            health=health, **kw)
        for k in h_off:
            np.testing.assert_array_equal(np.asarray(h_off[k]),
                                          np.asarray(h_on[k]), err_msg=k)
        for a, b in zip(jax.tree.leaves(f_off),
                        jax.tree.leaves(f_on._replace(health=None))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert len(jax.tree.leaves(f_on)) > len(jax.tree.leaves(f_off))

    def test_update_episode_rejects_indivisible_stride(self):
        health = HealthConfig(stride=3)
        state = health_init(health, 2, 4)
        bad = jnp.zeros((2, 10))
        with pytest.raises(ValueError, match="stride"):
            update_episode(health, state, bad, bad,
                           jnp.zeros((2, 10, 4)), bad)


class TestDriftScenarios:
    """The detectors flag the paper's non-stationary workloads (Fig. 13
    regimes) and stay quiet on a narrow stationary trace — end-to-end
    through the jitted scan, frozen policy so the workload is the only
    change-point source."""
    N, EPS = 2, 10
    HEALTH = HealthConfig(stride=1, warmup=30)
    KW = dict(learn=False, federated=False)

    def _flags(self, traces):
        health = self.HEALTH
        fleet = fleet_init(CFG, self.N, KEY, health=health)
        _, hist = train_fleet_scan(CFG, fleet, traces, health=health,
                                   **self.KW)
        return np.asarray(hist["health_drift_flag"])

    def test_stationary_is_quiet(self):
        # a constant arrival rate: the only variation left is the env's
        # own sampling noise, which the standardized residual absorbs
        traces = jnp.full((self.N, self.EPS * CFG.n_steps), 30.0)
        assert self._flags(traces).max() == 0.0

    @pytest.mark.parametrize("gen", [switching_traces, flash_crowd_traces,
                                     drift_traces],
                             ids=["switching", "flash_crowd", "drift"])
    def test_nonstationary_fires(self, gen):
        traces = gen(jax.random.PRNGKey(11), self.N, self.EPS * CFG.n_steps)
        assert self._flags(traces).max() > 0.0


# ---------------------------------------------------------------------------
# Alerts + watch
# ---------------------------------------------------------------------------
class _ListSink:
    def __init__(self):
        self.records, self.closed = [], False
        self.n_records = 0

    def append(self, r):
        self.records.append(r)
        self.n_records += 1

    def close(self):
        self.closed = True


class TestAlerts:
    RULES = (AlertRule("hot", "temp", "gt", 0.5, window=2),)

    def test_fire_latches_and_resolves(self, tmp_path):
        path = str(tmp_path / "ALERTS.jsonl")
        with AlertEngine(path, rules=self.RULES) as eng:
            for i, v in enumerate([0.1, 0.9, 0.9, 0.9, 0.2, 0.9, 0.9]):
                eng.append({"episode": i, "temp": v})
        alerts = read_alerts(path)
        kinds = [(a["kind"], a["episode"]) for a in alerts]
        # window=2: fires at ep2, one line while latched, resolves at ep4,
        # re-fires at ep6
        assert kinds == [("alert", 2), ("resolve", 4), ("alert", 6)]

    def test_tee_forwards_and_skips_foreign_records(self, tmp_path):
        path = str(tmp_path / "ALERTS.jsonl")
        sink = _ListSink()
        with AlertEngine(path, rules=self.RULES, forward=sink) as eng:
            eng.append({"episode": 0, "temp": 0.9})
            eng.append({"devices": 8})          # no metric: rule untouched
            eng.append({"episode": 1, "temp": 0.9})
        assert len(sink.records) == 3 and sink.closed
        assert eng.n_records == 3
        # the device record did not advance the window-2 streak
        assert [a["episode"] for a in read_alerts(path)] == [1]

    def test_default_rules_validate(self):
        assert any(r.metric == "health_drift_flag" for r in DEFAULT_RULES)
        with pytest.raises(ValueError):
            AlertRule("bad", "m", "ge", 0.0)
        with pytest.raises(ValueError):
            AlertRule("bad", "m", "gt", 0.0, severity="loud")

    def test_read_alerts_tolerates_torn_tail(self, tmp_path):
        path = str(tmp_path / "ALERTS.jsonl")
        with open(path, "w") as f:
            f.write(json.dumps({"kind": "alert", "rule": "r"}) + "\n")
            f.write('{"kind": "alert", "ru')  # torn mid-append
        assert len(read_alerts(path)) == 1
        assert read_alerts(str(tmp_path / "missing.jsonl")) == []


class TestWatchRender:
    def _write(self, path, rows, meta=None):
        with open(path, "w") as f:
            f.write(json.dumps({"kind": "meta", **(meta or {})}) + "\n")
            for r in rows:
                f.write(json.dumps(r) + "\n")

    def test_mixed_schema_renders_health_digest(self, tmp_path):
        """Half the records predate the observatory (no health keys) — the
        digest reduces to the episodes that carry them, the table renders,
        nothing crashes."""
        path = str(tmp_path / "run.jsonl")
        rows = [{"episode": e, "reward": 0.1 * e} for e in range(3)]
        rows += [{"episode": e, "reward": 0.1 * e,
                  "health_drift_score": 0.2, "health_drift_flag": 1.0,
                  "health_reward_p50": 0.4, "health_miss_p90": 0.1,
                  "health_susp": 0.7} for e in range(3, 6)]
        self._write(path, rows, meta={"agents": 2})
        out = render(path, tail_k=4)
        assert "episodes recorded: 6" in out
        assert "health: 3 episodes, drift flags on 3" in out
        assert "health_susp" in out

    def test_no_health_keys_renders_as_before(self, tmp_path):
        path = str(tmp_path / "run.jsonl")
        self._write(path, [{"episode": 0, "reward": 0.5}])
        out = render(path, tail_k=4)
        assert "health:" not in out and "alerts:" not in out

    def test_alerts_tail(self, tmp_path):
        path = str(tmp_path / "run.jsonl")
        self._write(path, [{"episode": 0, "reward": 0.5}])
        apath = str(tmp_path / "ALERTS.jsonl")
        with AlertEngine(apath, rules=(
                AlertRule("hot", "reward", "gt", 0.1, severity="crit"),)) \
                as eng:
            eng.append({"episode": 0, "reward": 0.5})
        out = render(path, tail_k=4, alerts_path=apath)
        assert "alerts: 1 fired" in out
        assert "[CRIT" in out and "hot: reward gt 0.1" in out
        # a missing alerts file renders an empty tail, not a crash
        out = render(path, tail_k=4,
                     alerts_path=str(tmp_path / "nope.jsonl"))
        assert "alerts: 0 fired" in out
