import os

# Smoke tests and benches must see the single real CPU device (the 512-device
# override is ONLY for launch/dryrun.py, per the multi-pod dry-run contract).
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
    "dry-run device-count override must not leak into tests"

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
