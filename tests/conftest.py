import os

# Smoke tests and benches must see the single real CPU device (the 512-device
# override is ONLY for launch/dryrun.py, per the multi-pod dry-run contract).
# Exception: the mesh suite (tests/test_mesh.py) opts in explicitly with
# REPRO_MULTIDEVICE=1 + an 8-device override, as the CI `mesh` job does.
if os.environ.get("REPRO_MULTIDEVICE") != "1":
    assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
        "dry-run device-count override must not leak into tests (set REPRO_MULTIDEVICE=1 to opt in)"

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_collection_modifyitems(config, items):
    # tier-1 stays fast: @pytest.mark.slow tests (full leaderboard grids,
    # long horizons) only run when explicitly requested with RUN_SLOW=1.
    if os.environ.get("RUN_SLOW") == "1":
        return
    skip = pytest.mark.skip(reason="slow: set RUN_SLOW=1 to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
