"""FCPO core unit tests: agent network, losses, buffer, aggregation, CRL."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.fcpo import FCPOConfig
from repro.core import env as env_mod
from repro.core import federated as fed
from repro.core.agent import (ActionMask, agent_forward, agent_init, full_mask,
                              num_params, param_bytes, sample_actions)
from repro.core.buffer import (buffer_init, buffer_insert, buffer_memory_bytes,
                               diversity)
from repro.core.crl import AgentState, crl_episode
from repro.core.fleet import fleet_episode, fleet_init, fl_round, train_fleet
from repro.core.ppo import (Rollout, agent_opt_init, agent_update, fcpo_loss,
                            finetune_heads, gae, returns)
from repro.data.workload import fleet_traces, switching_traces

CFG = FCPOConfig()
KEY = jax.random.PRNGKey(0)


def make_rollout(key, cfg=CFG, t=None):
    t = t or cfg.n_steps
    ks = jax.random.split(key, 5)
    return Rollout(
        states=jax.random.normal(ks[0], (t, cfg.state_dim)),
        actions=jnp.stack([
            jax.random.randint(ks[1], (t,), 0, cfg.n_res),
            jax.random.randint(ks[2], (t,), 0, cfg.n_bs),
            jax.random.randint(ks[3], (t,), 0, cfg.n_mt)], -1),
        logp_old=-jnp.abs(jax.random.normal(ks[4], (t,))),
        rewards=jnp.tanh(jax.random.normal(ks[0], (t,))),
        values_old=jax.random.normal(ks[1], (t,)) * 0.1,
    )


class TestAgent:
    def test_architecture_dims(self):
        """Fig. 4: input 8, hidden 64, features 48, value + 3 cascaded heads."""
        p = agent_init(CFG, KEY)
        assert p["backbone"]["l1"]["w"].shape == (8, 64)
        assert p["backbone"]["l2"]["w"].shape == (64, 48)
        assert p["value"]["w"].shape == (48, 1)
        assert p["head_res"]["w"].shape == (48, CFG.n_res)
        # cascade: bs/mt heads consume backbone features ++ res distribution
        assert p["head_bs"]["w"].shape == (48 + CFG.n_res, CFG.n_bs)
        assert p["head_mt"]["w"].shape == (48 + CFG.n_res, CFG.n_mt)

    def test_lightweight(self):
        """Paper: iAgent ≈ 53 KB. Ours must stay the same order (< 64 KB)."""
        p = agent_init(CFG, KEY)
        assert param_bytes(p) < 64 * 1024
        assert num_params(p) < 16_000

    def test_masked_actions_never_sampled(self):
        p = agent_init(CFG, KEY)
        mask = ActionMask(jnp.ones(CFG.n_res, bool),
                          jnp.asarray([True] * 4 + [False] * 3),  # bs <= 8
                          jnp.ones(CFG.n_mt, bool))
        state = jax.random.normal(KEY, (64, 8))
        actions, _, out = sample_actions(CFG, p, state, mask,
                                         jax.random.PRNGKey(7))
        assert int(actions[:, 1].max()) <= 3
        assert bool(jnp.all(out["bs"][:, 4:] < -1e20))

    def test_cascade_feeds_res_into_bs(self):
        """Changing only the res head's params must change the bs policy."""
        p = agent_init(CFG, KEY)
        s = jax.random.normal(KEY, (8,))
        out1 = agent_forward(CFG, p, s, full_mask(CFG))
        p2 = jax.tree.map(lambda x: x, p)
        # perturb one res option's logit (a uniform shift would be
        # softmax-invariant and correctly leave the cascade unchanged)
        p2["head_res"] = dict(p2["head_res"],
                              b=p2["head_res"]["b"].at[0].add(3.0))
        out2 = agent_forward(CFG, p2, s, full_mask(CFG))
        assert not jnp.allclose(out1["bs"], out2["bs"])
        assert jnp.allclose(out1["value"], out2["value"])  # value unaffected


class TestPPO:
    def test_gae_matches_manual(self):
        cfg = CFG
        r = jnp.asarray([1.0, 0.0, -1.0])
        v = jnp.asarray([0.5, 0.2, 0.1])
        adv = gae(cfg, r, v)
        d2 = -1.0 + 0.0 - 0.1
        d1 = 0.0 + cfg.gamma * 0.1 - 0.2
        d0 = 1.0 + cfg.gamma * 0.2 - 0.5
        g = cfg.gamma * cfg.lam
        exp = jnp.asarray([d0 + g * (d1 + g * d2), d1 + g * d2, d2])
        np.testing.assert_allclose(np.asarray(adv), np.asarray(exp), rtol=1e-5)

    def test_returns_discounted(self):
        r = jnp.asarray([1.0, 1.0, 1.0])
        rets = returns(CFG, r)
        np.testing.assert_allclose(np.asarray(rets),
                                   [1.11, 1.1, 1.0], rtol=1e-6)

    def test_loss_components_finite(self):
        p = agent_init(CFG, KEY)
        total, m = fcpo_loss(CFG, p, make_rollout(KEY), full_mask(CFG))
        for k in ("l_p", "l_v", "l_pen", "loss"):
            assert np.isfinite(float(m[k])), k
        # Eq. 3: total is exactly the sum of its parts
        np.testing.assert_allclose(float(total),
                                   float(m["l_p"] + m["l_v"] + m["l_pen"]),
                                   rtol=1e-6)

    def test_loss_gate_skips_update(self):
        cfg = FCPOConfig(loss_gate=1e9)  # gate everything
        p = agent_init(cfg, KEY)
        opt = agent_opt_init(p)
        p2, opt2, m = agent_update(cfg, p, opt, make_rollout(KEY),
                                   full_mask(cfg))
        assert float(m["gated"]) == 1.0
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p, p2)
        assert max(jax.tree.leaves(diffs)) == 0.0

    def test_update_moves_params(self):
        cfg = FCPOConfig(loss_gate=0.0)
        p = agent_init(cfg, KEY)
        p2, _, m = agent_update(cfg, p, agent_opt_init(p), make_rollout(KEY),
                                full_mask(cfg))
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p, p2)
        assert max(jax.tree.leaves(diffs)) > 0.0
        assert float(m["gated"]) == 0.0

    def test_finetune_freezes_backbone_and_value(self):
        p = agent_init(CFG, KEY)
        p2, _ = finetune_heads(CFG, p, agent_opt_init(p), make_rollout(KEY),
                               full_mask(CFG), steps=3)
        for k in ("backbone", "value"):
            d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                             p[k], p2[k])
            assert max(jax.tree.leaves(d)) == 0.0, k
        moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                             p["head_res"], p2["head_res"])
        assert max(jax.tree.leaves(moved)) > 0.0


class TestBuffer:
    def test_memory_bounded(self):
        """Paper Fig. 11: fixed-size buffer bounds memory (vs 5000+ exps)."""
        assert buffer_memory_bytes(CFG) < 64 * 1024

    def test_insert_until_full_then_evict_least_diverse(self):
        cfg = FCPOConfig(buffer_size=4)
        buf = buffer_init(cfg)
        na = cfg.n_res + cfg.n_bs + cfg.n_mt
        probs = jnp.full((na,), 1.0 / na)
        for i in range(4):
            buf = buffer_insert(cfg, buf, jnp.full((8,), float(i)),
                                jnp.zeros((3,), jnp.int32), 0.0, 0.0, 0.0, probs)
        assert bool(buf.filled.all())
        # a maximally-novel state must displace something
        far = jnp.full((8,), 100.0)
        buf2 = buffer_insert(cfg, buf, far, jnp.zeros((3,), jnp.int32),
                             0.0, 0.0, 0.0, probs)
        assert bool((buf2.states == 100.0).any())
        assert bool(buf2.filled.all())  # still exactly capacity

    def test_duplicate_state_not_inserted_when_full(self):
        cfg = FCPOConfig(buffer_size=4)
        buf = buffer_init(cfg)
        na = cfg.n_res + cfg.n_bs + cfg.n_mt
        probs = jnp.full((na,), 1.0 / na)
        for i in range(4):
            buf = buffer_insert(cfg, buf, jnp.full((8,), float(i) * 10),
                                jnp.zeros((3,), jnp.int32), 0.0, 0.0, 0.0, probs)
        mean_state = buf.states.mean(0)  # centroid: lowest possible novelty
        buf2 = buffer_insert(cfg, buf, mean_state, jnp.zeros((3,), jnp.int32),
                             0.0, 0.0, 0.0, probs)
        assert not bool(jnp.any(jnp.all(buf2.states == mean_state, axis=-1)))


class TestFederated:
    def _fleet(self, n=6, n_pods=1):
        return fleet_init(CFG, n, KEY, n_pods=n_pods)

    def test_backbone_equal_aggregation(self):
        """After Alg. 1, every selected/unselected agent shares one backbone
        per pod, equal to (base + Σ clients)/(|M|+1)."""
        n = 4
        fleet = self._fleet(n)
        params = fleet.astate.params
        sel = jnp.ones((n,), bool)
        hl = jnp.zeros((n, 3))
        newp, newb = fed.aggregate(CFG, params, fleet.base_params, sel, hl,
                                   fleet.head_groups, fleet.pod_ids, 1)
        w = params["backbone"]["l1"]["w"]
        expected = (fleet.base_params["backbone"]["l1"]["w"][0]
                    + w.sum(0)) / (n + 1)
        np.testing.assert_allclose(np.asarray(newp["backbone"]["l1"]["w"][0]),
                                   np.asarray(expected), rtol=1e-5)
        for i in range(1, n):
            np.testing.assert_allclose(
                np.asarray(newp["backbone"]["l1"]["w"][i]),
                np.asarray(newp["backbone"]["l1"]["w"][0]), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(newb["backbone"]["l1"]["w"][0]),
                                   np.asarray(expected), rtol=1e-5)

    def test_equal_losses_reduce_to_equal_weighting(self):
        n = 4
        fleet = self._fleet(n)
        params = fleet.astate.params
        sel = jnp.ones((n,), bool)
        hl = jnp.ones((n, 3)) * 0.7  # identical losses
        newp, _ = fed.aggregate(CFG, params, fleet.base_params, sel, hl,
                                fleet.head_groups, fleet.pod_ids, 1)
        w = params["head_bs"]["w"]
        expected = (fleet.base_params["head_bs"]["w"][0] + w.sum(0)) / (n + 1)
        # atol floor: near-zero weights see float32 segment-sum reassociation
        np.testing.assert_allclose(np.asarray(newp["head_bs"]["w"][0]),
                                   np.asarray(expected), rtol=1e-5, atol=1e-8)

    def test_lower_loss_head_gets_more_weight(self):
        n = 2
        fleet = self._fleet(n)
        params = jax.tree.map(jnp.copy, fleet.astate.params)
        # make the two agents' bs heads distinguishable
        params["head_bs"]["w"] = params["head_bs"]["w"].at[0].set(1.0)
        params["head_bs"]["w"] = params["head_bs"]["w"].at[1].set(-1.0)
        base = jax.tree.map(jnp.zeros_like, fleet.base_params)
        sel = jnp.ones((n,), bool)
        hl = jnp.asarray([[0.0, 0.0, 0.0], [0.0, 1.0, 0.0]])  # agent0 better bs
        newp, _ = fed.aggregate(CFG, params, base, sel, hl,
                                fleet.head_groups, fleet.pod_ids, 1)
        agg = np.asarray(newp["head_bs"]["w"][0])
        assert agg.mean() > 0  # pulled toward the low-loss (+1) head

    def test_unavailable_clients_excluded(self):
        n = 6
        fleet = self._fleet(n)
        stats = fed.ClientStats(
            mem_avail=jnp.ones(n), compute_avail=jnp.ones(n),
            diversity=jnp.ones(n), bandwidth=jnp.full((n,), 10.0),
            available=jnp.asarray([True, True, False, True, False, True]))
        sel = fed.select_clients(CFG, stats)
        assert not bool(sel[2]) and not bool(sel[4])
        assert int(sel.sum()) == max(1, round(CFG.clients_per_round * n))

    def test_bandwidth_raises_utility(self):
        n = 4
        stats = fed.ClientStats(
            mem_avail=jnp.ones(n), compute_avail=jnp.ones(n),
            diversity=jnp.ones(n),
            bandwidth=jnp.asarray([1.0, 10.0, 40.0, 90.0]),
            available=jnp.ones(n, bool))
        u = fed.total_utility(stats)
        assert bool(jnp.all(jnp.diff(u) > 0))

    def test_empty_selection_keeps_base(self):
        """Total straggler round: aggregation degenerates gracefully."""
        n = 4
        fleet = self._fleet(n)
        rates = fleet_traces(KEY, n, CFG.n_steps)
        fleet2, rollouts, _ = fleet_episode(CFG, fleet, rates)
        fleet3, sel, _ = fl_round(CFG, fleet2, rollouts,
                                  available=jnp.zeros((n,), bool))
        assert int(sel.sum()) == 0
        assert all(np.isfinite(np.asarray(x)).all()
                   for x in jax.tree.leaves(fleet3.astate.params))


class TestEnv:
    def test_reward_bounded(self):
        ep = env_mod.default_env_params()
        s = env_mod.env_init(CFG)
        for a in ([0, 0, 0], [3, 6, 3], [0, 6, 0], [2, 3, 1]):
            s2, r, info = env_mod.env_step(CFG, ep, s,
                                           jnp.asarray(a, jnp.int32), 50.0)
            assert -1.0 <= float(r) <= 1.0
            assert float(info["throughput"]) >= 0

    def test_bigger_batch_higher_batch_latency(self):
        ep = env_mod.default_env_params()
        s = env_mod.env_init(CFG)
        _, _, i_small = env_mod.env_step(CFG, ep, s, jnp.asarray([0, 0, 0]), 50.0)
        _, _, i_big = env_mod.env_step(CFG, ep, s, jnp.asarray([0, 6, 0]), 50.0)
        assert float(i_big["batch_latency"]) > float(i_small["batch_latency"])

    def test_queue_drops_bounded_by_capacity(self):
        ep = env_mod.default_env_params(speed=0.25)
        s = env_mod.env_init(CFG)
        for _ in range(20):
            s, _, info = env_mod.env_step(CFG, ep, s, jnp.asarray([0, 0, 0]),
                                          400.0)
        assert float(s.pre_q) <= float(ep.queue_cap) + 1e-5


class TestLearning:
    def test_fleet_learns_on_stationary_workload(self):
        cfg = FCPOConfig()
        fleet = fleet_init(cfg, 4, KEY)
        traces = fleet_traces(jax.random.PRNGKey(1), 4, 2000)
        _, hist = train_fleet(cfg, fleet, traces)
        first, last = hist["reward"][:20].mean(), hist["reward"][-20:].mean()
        assert last > first + 0.2, (first, last)

    def test_frozen_agent_does_not_change(self):
        cfg = FCPOConfig()
        fleet = fleet_init(cfg, 2, KEY)
        traces = fleet_traces(jax.random.PRNGKey(1), 2, 100)
        before = jax.tree.map(jnp.copy, fleet.astate.params)
        fleet, hist = train_fleet(cfg, fleet, traces, learn=False,
                                  federated=False)
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                             before, fleet.astate.params)
        assert max(jax.tree.leaves(diffs)) == 0.0
