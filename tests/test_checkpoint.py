"""Checkpoint round-trips for the Fleet pytree (static aux + stacked
leaves) through training/checkpoint.py — twin-trained fleets must
save/restore losslessly."""
import jax
import numpy as np
import pytest

from repro.configs.fcpo import FCPOConfig
from repro.core.backends import TwinBackend
from repro.core.fleet import Fleet, fleet_init, train_fleet
from repro.sim import SimParams, make_scenario
from repro.training import checkpoint as ckpt

CFG = FCPOConfig()
KEY = jax.random.PRNGKey(0)
SP = SimParams(dt=0.05, k_ticks=8, ring=64, hist_n=32)


def _roundtrip(tmp_path, fleet, step=3):
    ckpt.save(str(tmp_path), step, fleet, extra={"kind": "fleet"})
    assert ckpt.latest_step(str(tmp_path)) == step
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype),
        fleet)
    restored, manifest = ckpt.restore(str(tmp_path), step, like)
    assert manifest["extra"] == {"kind": "fleet"}
    return restored


def _assert_fleet_equal(a: Fleet, b: Fleet):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb  # static aux (n_pods, group_counts) survives via `like`
    assert a.n_pods == b.n_pods and a.group_counts == b.group_counts
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestFleetCheckpoint:
    def test_fluid_fleet_roundtrip(self, tmp_path):
        fleet = fleet_init(CFG, 4, KEY, n_pods=2)
        _assert_fleet_equal(fleet, _roundtrip(tmp_path, fleet))

    def test_twin_fleet_roundtrip_after_training(self, tmp_path):
        """A twin-backed fleet mid-training (non-trivial ring/counters/
        histogram state in ``astate.env_state``) restores bit-for-bit."""
        be = TwinBackend(sp=SP)
        fleet = fleet_init(CFG, 3, KEY, n_pods=1, env_backend=be)
        traces = make_scenario("dynamic", jax.random.PRNGKey(1), 3,
                               3 * CFG.n_steps)
        fleet, _ = train_fleet(CFG, fleet, traces, env_backend=be)
        env = fleet.astate.env_state
        assert int(np.asarray(env.sim.completed).sum()) > 0  # real state
        restored = _roundtrip(tmp_path, fleet, step=7)
        _assert_fleet_equal(fleet, restored)

    def test_restored_twin_fleet_resumes_identically(self, tmp_path):
        """Save -> restore -> train must equal train straight through (the
        checkpoint is a faithful resume point, not just equal leaves)."""
        be = TwinBackend(sp=SP)
        traces = make_scenario("dynamic", jax.random.PRNGKey(2), 2,
                               4 * CFG.n_steps)
        fleet = fleet_init(CFG, 2, KEY, n_pods=1, env_backend=be)
        fleet, _ = train_fleet(CFG, fleet, traces[:, :2 * CFG.n_steps],
                               env_backend=be)
        restored = _roundtrip(tmp_path, fleet)
        f_direct, h_direct = train_fleet(CFG, fleet,
                                         traces[:, 2 * CFG.n_steps:],
                                         env_backend=be)
        f_resumed, h_resumed = train_fleet(CFG, restored,
                                           traces[:, 2 * CFG.n_steps:],
                                           env_backend=be)
        for k in h_direct:
            np.testing.assert_allclose(h_resumed[k], h_direct[k], rtol=1e-6,
                                       atol=1e-7, err_msg=k)
        _assert_fleet_equal(f_direct, f_resumed)

    def test_shape_mismatch_raises(self, tmp_path):
        fleet = fleet_init(CFG, 2, KEY)
        ckpt.save(str(tmp_path), 1, fleet)
        wrong = fleet_init(CFG, 3, jax.random.PRNGKey(1))
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(np.shape(x),
                                           np.asarray(x).dtype), wrong)
        with pytest.raises(ValueError, match="shape mismatch"):
            ckpt.restore(str(tmp_path), 1, like)


class TestHardening:
    """Torn writes, half-deleted dirs, and corrupt files must degrade to
    clear errors (restore) or silent skips (latest_step/keep_last) — a
    crashed run's leftovers can't wedge auto-resume."""

    def _save_steps(self, tmp_path, steps):
        fleet = fleet_init(CFG, 2, KEY)
        for s in steps:
            ckpt.save(str(tmp_path), s, fleet)
        return fleet

    def test_latest_step_skips_broken_npz(self, tmp_path):
        self._save_steps(tmp_path, [1, 2])
        (tmp_path / "step_00000002.npz").write_bytes(b"torn write!")
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_latest_step_skips_manifest_without_arrays(self, tmp_path):
        self._save_steps(tmp_path, [1, 2])
        (tmp_path / "step_00000002.npz").unlink()  # half-deleted
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_latest_step_skips_garbage_manifest(self, tmp_path):
        self._save_steps(tmp_path, [1])
        (tmp_path / "step_00000009.json").write_text("{not json")
        (tmp_path / "step_woops.json").write_text("{}")
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_keep_last_prunes_oldest_complete(self, tmp_path):
        self._save_steps(tmp_path, [1, 2, 3, 4, 5])
        assert ckpt.keep_last(str(tmp_path), 3) == 2
        assert ckpt.latest_step(str(tmp_path)) == 5
        assert not (tmp_path / "step_00000001.npz").exists()
        assert not (tmp_path / "step_00000002.json").exists()
        assert (tmp_path / "step_00000003.npz").exists()
        assert ckpt.keep_last(str(tmp_path), 3) == 0  # idempotent
        with pytest.raises(ValueError, match=">= 1"):
            ckpt.keep_last(str(tmp_path), 0)
        assert ckpt.keep_last(str(tmp_path / "nope"), 2) == 0

    def _like(self, fleet):
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(np.shape(x),
                                           np.asarray(x).dtype), fleet)

    def test_restore_missing_manifest_names_latest(self, tmp_path):
        fleet = self._save_steps(tmp_path, [3])
        with pytest.raises(FileNotFoundError, match="latest complete step: 3"):
            ckpt.restore(str(tmp_path), 7, self._like(fleet))

    def test_restore_corrupt_manifest_raises_value_error(self, tmp_path):
        fleet = self._save_steps(tmp_path, [1])
        (tmp_path / "step_00000001.json").write_text("{torn")
        with pytest.raises(ValueError, match="corrupt checkpoint manifest"):
            ckpt.restore(str(tmp_path), 1, self._like(fleet))
        (tmp_path / "step_00000001.json").write_text('{"step": 1}')
        with pytest.raises(ValueError, match="missing 'arrays'"):
            ckpt.restore(str(tmp_path), 1, self._like(fleet))

    def test_restore_corrupt_arrays_names_file(self, tmp_path):
        fleet = self._save_steps(tmp_path, [1])
        (tmp_path / "step_00000001.npz").write_bytes(b"PK\x03\x04 nope")
        with pytest.raises(ValueError, match="corrupt checkpoint arrays"):
            ckpt.restore(str(tmp_path), 1, self._like(fleet))

    def test_restore_missing_arrays_file_raises(self, tmp_path):
        fleet = self._save_steps(tmp_path, [1])
        (tmp_path / "step_00000001.npz").unlink()
        with pytest.raises(ValueError, match="missing"):
            ckpt.restore(str(tmp_path), 1, self._like(fleet))
