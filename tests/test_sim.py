"""Request-level twin: Pallas kernel vs jnp oracle bit-identity, twin vs the
Python slo.py data plane (request-for-request), and the closed-loop
``simulate_fleet`` harness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.fcpo import FCPOConfig
from repro.core.fleet import fleet_init
from repro.data.workload import fleet_traces
from repro.kernels import ref as kref
from repro.kernels.queue_advance import queue_advance
from repro.sim import (SimParams, SimState, action_caps, hist_percentile,
                       sim_init, sim_interval, sim_interval_ref,
                       simulate_fleet, spread_arrivals)
from repro.sim.oracle import simulate_python_agent

KEY = jax.random.PRNGKey(0)
SP = SimParams(dt=0.05, k_ticks=8, ring=32, hist_n=16)
CAPS = jnp.asarray([2.5, 3.0, 4.0, 2.0, 8.0, 5.0], jnp.float32)


def _batched_state(a):
    return jax.vmap(lambda _: sim_init(SP))(jnp.arange(a))


def _random_args(a, key):
    k1, k2 = jax.random.split(key)
    arrivals = jax.random.randint(k1, (a, SP.k_ticks), 0, 7)
    jitter = jax.random.randint(k2, (a, 6), 0, 3).astype(jnp.float32)
    caps = CAPS[None] + jitter * jnp.asarray([0.5, 0.5, 1.0, 1.0, 0.0, 0.0])
    return arrivals, caps


class TestQueueAdvanceKernel:
    pytestmark = pytest.mark.pallas

    def test_kernel_matches_oracle_batched_bit_identical(self):
        """Fused kernel (interpret mode on CPU) == vmap'd jnp oracle,
        bit-for-bit, chained over several control intervals."""
        a = 4
        state = _batched_state(a)
        for i in range(5):
            arrivals, caps = _random_args(a, jax.random.fold_in(KEY, i))
            out_pal = queue_advance(*state, arrivals, caps, interpret=True)
            out_ref = jax.vmap(kref.queue_advance_ref)(*state, arrivals, caps)
            for name, p, r in zip(SimState._fields, out_pal, out_ref):
                np.testing.assert_array_equal(np.asarray(p), np.asarray(r),
                                              err_msg=f"{name} @ interval {i}")
            state = SimState(*out_pal)
        assert int(state.completed.sum()) > 0  # the chain did real work

    def test_kernel_bit_identical_under_vmap(self):
        """vmap of the single-agent kernel call == the batched grid call ==
        vmap of the oracle."""
        a = 3
        state = _batched_state(a)
        arrivals, caps = _random_args(a, KEY)
        out_batch = queue_advance(*state, arrivals, caps, interpret=True)
        out_vmap = jax.vmap(
            lambda *xs: queue_advance(*xs, interpret=True))(*state, arrivals,
                                                            caps)
        out_ref = jax.vmap(kref.queue_advance_ref)(*state, arrivals, caps)
        for name, b, v, r in zip(SimState._fields, out_batch, out_vmap,
                                 out_ref):
            np.testing.assert_array_equal(np.asarray(b), np.asarray(v),
                                          err_msg=name)
            np.testing.assert_array_equal(np.asarray(b), np.asarray(r),
                                          err_msg=name)


class TestPythonOracleEquivalence:
    def test_twin_matches_slo_reference_request_for_request(self):
        """Tensorized twin == serving/slo.py data plane on a single-agent
        config: same completions, drops, effective throughput, and summed
        latency (integer-representable caps => exact)."""
        t_ints = 12
        arrivals = np.asarray(
            jax.random.randint(jax.random.PRNGKey(3), (t_ints, SP.k_ticks),
                               0, 7))
        rng = np.random.default_rng(0)
        caps = np.stack([
            rng.choice([1.5, 2.0, 2.5, 3.0], t_ints),
            rng.choice([2.0, 3.0, 4.0], t_ints),
            rng.choice([2.0, 4.0, 8.0], t_ints),
            rng.choice([1.0, 2.0, 3.0], t_ints),
            np.full(t_ints, 8.0),
            np.full(t_ints, 5.0),
        ], axis=1).astype(np.float32)

        s = sim_init(SP)
        for t in range(t_ints):
            s = sim_interval_ref(s, jnp.asarray(arrivals[t]),
                                 jnp.asarray(caps[t]))
        py = simulate_python_agent(arrivals, caps, SP)

        assert int(s.arrived) == py["arrived"]
        assert int(s.dropped) == py["dropped"]
        assert int(s.completed) == py["completed"]
        assert int(s.effective) == py["effective"]
        assert float(s.lat_sum) == py["lat_sum"]
        assert int(s.in_flight) == py["in_flight"]
        assert py["dropped"] > 0 and py["completed"] > 0  # both regimes hit


class TestHarness:
    def _fleet(self, a):
        cfg = FCPOConfig()
        fleet = fleet_init(cfg, a, KEY)
        traces = fleet_traces(jax.random.PRNGKey(1), a, 6)
        return cfg, fleet, traces

    def test_simulate_fleet_runs_jitted_and_conserves(self):
        cfg, fleet, traces = self._fleet(3)
        state, hist, summ = simulate_fleet(
            cfg, SP, fleet.astate.params, fleet.masks, fleet.env_params,
            traces, jax.random.PRNGKey(2))
        assert hist["throughput"].shape == (6, 3)
        conserved = (state.arrived
                     == state.dropped + state.completed + state.in_flight)
        assert bool(conserved.all())
        for k in ("throughput", "effective_throughput", "p50_latency_s",
                  "p99_latency_s", "drop_rate"):
            assert np.isfinite(np.asarray(summ[k])).all(), k
        assert (np.asarray(summ["effective"])
                <= np.asarray(summ["completed"])).all()

    @pytest.mark.pallas
    def test_pallas_harness_matches_jnp_harness(self):
        """Same key, same traces: the kernel-backed closed loop must be
        bit-identical to the jnp one (actions depend on twin state, so any
        data-plane divergence compounds — exact equality is the gate)."""
        cfg, fleet, traces = self._fleet(2)
        out_j = simulate_fleet(cfg, SP, fleet.astate.params, fleet.masks,
                               fleet.env_params, traces,
                               jax.random.PRNGKey(2))
        out_p = simulate_fleet(cfg, SP, fleet.astate.params, fleet.masks,
                               fleet.env_params, traces,
                               jax.random.PRNGKey(2), use_pallas=True)
        for name, j, p in zip(SimState._fields, out_j[0], out_p[0]):
            np.testing.assert_array_equal(np.asarray(j), np.asarray(p),
                                          err_msg=name)


class TestStateAndMetrics:
    def test_spread_arrivals_totals_and_bounds(self):
        for rate in (0.0, 1.0, 17.3, 399.9):
            arr, phase = spread_arrivals(SP, jnp.float32(rate))
            arr = np.asarray(arr)
            assert arr.shape == (SP.k_ticks,) and (arr >= 0).all()
            assert arr.sum() == int(np.floor(np.float32(rate) * SP.k_ticks
                                             * np.float32(SP.dt)))
            assert 0.0 <= float(phase) < 1.0

    def test_spread_arrivals_phase_carry_removes_rounding_bias(self):
        """Chaining intervals with the phase carry admits the fractional
        request rate on average (floor-per-interval would lose it)."""
        rate = jnp.float32(30.9)  # 12.36 requests per 8-tick interval
        total, phase = 0, jnp.float32(0.0)
        n_int = 50
        for _ in range(n_int):
            arr, phase = spread_arrivals(SP, rate, phase)
            total += int(np.asarray(arr).sum())
        expect = float(rate) * SP.k_ticks * SP.dt * n_int
        assert abs(total - expect) <= 1.0  # not floor()*n_int = -18 deficit

    def test_action_caps_are_positive_and_discrete(self):
        cfg = FCPOConfig()
        from repro.core.env import default_env_params
        ep = default_env_params()
        for a in ([0, 0, 0], [3, 6, 3], [1, 4, 2]):
            caps = np.asarray(action_caps(cfg, SP, ep,
                                          jnp.asarray(a, jnp.int32)))
            assert caps.shape == (kref.SIM_NCAPS,)
            assert (caps > 0).all()
            for i in (kref.CAP_BATCH, kref.CAP_TBATCH, kref.CAP_QCAP,
                      kref.CAP_SLO):
                assert caps[i] == int(caps[i])  # integer-valued
            assert caps[kref.CAP_QCAP] <= SP.ring // 3

    def test_hist_percentile(self):
        hist = jnp.asarray([0, 10, 0, 0, 0, 0, 0, 1])
        assert int(hist_percentile(hist, 0.5)) == 1
        assert int(hist_percentile(hist, 0.99)) == 7
        assert int(hist_percentile(jnp.zeros(8, jnp.int32), 0.5)) == 0

    def test_sim_interval_batched_equals_single(self):
        a = 3
        state = _batched_state(a)
        arrivals, caps = _random_args(a, KEY)
        out = sim_interval(state, arrivals, caps)
        one = sim_interval_ref(jax.tree.map(lambda x: x[1], state),
                               arrivals[1], caps[1])
        for name, b, s in zip(SimState._fields, out, one):
            np.testing.assert_array_equal(np.asarray(b[1]), np.asarray(s),
                                          err_msg=name)
