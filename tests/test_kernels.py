"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU — same kernel body as the TPU target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.packing import pack

pytestmark = pytest.mark.pallas

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


FLASH_CASES = [
    # (b, sq, sk, hq, hkv, d, dtype, causal)
    (2, 128, 128, 4, 4, 64, jnp.float32, True),     # MHA
    (2, 128, 128, 4, 2, 64, jnp.float32, True),     # GQA 2:1
    (1, 256, 256, 8, 1, 64, jnp.float32, True),     # MQA
    (1, 128, 128, 4, 4, 128, jnp.bfloat16, True),   # bf16
    (1, 128, 128, 2, 2, 256, jnp.float32, True),    # gemma head_dim
    (2, 128, 128, 4, 4, 80, jnp.float32, False),    # encoder (hubert dim)
    (1, 384, 384, 7, 1, 64, jnp.float32, True),     # qwen2 7:1 group
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case):
    b, sq, sk, hq, hkv, d, dtype, causal = case
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = rand(k1, (b, sq, hq, d), dtype)
    k = rand(k2, (b, sk, hkv, d), dtype)
    v = rand(k3, (b, sk, hkv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, bq=64, bk=64, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


DECODE_CASES = [
    # (b, hq, hkv, d, s_max, kv_len, dtype)
    (2, 4, 4, 64, 256, 256, jnp.float32),
    (2, 4, 2, 64, 512, 300, jnp.float32),
    (1, 8, 2, 128, 512, 77, jnp.float32),
    (1, 14, 2, 64, 512, 500, jnp.float32),          # qwen2-0.5b ratios
    (1, 4, 4, 128, 256, 128, jnp.bfloat16),
    (2, 16, 16, 256, 256, 199, jnp.float32),        # gemma-ish
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_matches_ref(case):
    b, hq, hkv, d, s_max, kv_len, dtype = case
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = rand(k1, (b, 1, hq, d), dtype)
    kc = rand(k2, (b, s_max, hkv, d), dtype)
    vc = rand(k3, (b, s_max, hkv, d), dtype)
    out = decode_attention(q, kc, vc, kv_len, bk=128, interpret=True)
    exp = ref.decode_attention_ref(q, kc, vc, kv_len)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


def test_decode_attention_ignores_invalid_tail():
    """Garbage beyond kv_len must not affect the result (the kernel skips
    invalid blocks — this is the bandwidth guarantee for long_500k)."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = rand(k1, (1, 1, 4, 64), jnp.float32)
    kc = rand(k2, (1, 512, 4, 64), jnp.float32)
    vc = rand(k3, (1, 512, 4, 64), jnp.float32)
    out1 = decode_attention(q, kc, vc, 200, bk=128, interpret=True)
    kc2 = kc.at[:, 200:].set(1e9)
    vc2 = vc.at[:, 200:].set(-1e9)
    out2 = decode_attention(q, kc2, vc2, 200, bk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_pack_matches_ref(dtype):
    tok = (jax.random.normal(KEY, (64, 128)) * 10).astype(dtype)
    idx = jnp.asarray([0, 63, -1, 5, 5, -1, 17, 2], jnp.int32)
    out = pack(tok, idx, interpret=True)
    exp = ref.pack_ref(tok, idx)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32))


def test_flash_attention_in_model_path_matches_sdpa():
    """use_pallas=True end-to-end equals the jnp path (dry-run equivalence)."""
    from repro.models.registry import get_config, get_model
    cfg = get_config("qwen2-0.5b").reduced().replace(n_layers=1)
    model = get_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (2, 64), 0, cfg.vocab_size)
    ref_logits, _, _ = model.apply(params, {"tokens": tokens}, use_pallas=False)
    pal_logits, _, _ = model.apply(params, {"tokens": tokens}, use_pallas=True)
    np.testing.assert_allclose(np.asarray(pal_logits), np.asarray(ref_logits),
                               atol=3e-4, rtol=1e-3)
