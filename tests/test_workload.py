"""Workload-trace generators (data/workload.py): shapes, clip bounds,
switching segment structure, and OOD statistics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.workload import (DYNAMIC, PROFILING, fleet_traces,
                                 make_trace, ood_traces, switching_traces)

KEY = jax.random.PRNGKey(0)


class TestMakeTrace:
    def test_shape_dtype_and_bounds(self):
        tr = np.asarray(make_trace(KEY, 500))
        assert tr.shape == (500,) and tr.dtype == np.float32
        assert (tr >= 1.0).all() and (tr <= 400.0).all()

    def test_clips_at_upper_bound_under_extreme_bursts(self):
        tr = np.asarray(make_trace(KEY, 200, base_rate=100.0,
                                   burst_prob=1.0, burst_scale=1000.0))
        assert tr.max() == 400.0

    def test_clips_at_lower_bound_for_tiny_base(self):
        tr = np.asarray(make_trace(KEY, 200, base_rate=0.01))
        assert tr.min() == 1.0

    def test_profiling_regime_is_narrower_than_dynamic(self):
        prof = np.asarray(make_trace(KEY, 600, **PROFILING))
        dyn = np.asarray(make_trace(KEY, 600, **DYNAMIC))
        assert np.std(prof) / np.mean(prof) < np.std(dyn) / np.mean(dyn)


class TestFleetTraces:
    def test_shape_bounds_and_heterogeneity(self):
        a, n = 8, 300
        tr = np.asarray(fleet_traces(KEY, a, n, heterogeneity=0.9))
        assert tr.shape == (a, n)
        assert (tr >= 1.0).all() and (tr <= 400.0).all()
        means = tr.mean(axis=1)
        assert means.max() / means.min() > 1.5  # per-agent base rates differ

    def test_trace_kwargs_flow_through(self):
        calm = np.asarray(fleet_traces(KEY, 4, 300, **PROFILING))
        wild = np.asarray(fleet_traces(KEY, 4, 300, **DYNAMIC))
        assert np.std(calm, axis=1).mean() < np.std(wild, axis=1).mean()


class TestSwitchingTraces:
    def test_shape_and_bounds(self):
        tr = np.asarray(switching_traces(KEY, 4, 310, segment=50))
        assert tr.shape == (4, 310)
        assert (tr >= 1.0).all() and (tr <= 400.0).all()

    def test_segment_boundaries_hold_a_single_source(self):
        """Within one segment the underlying base rate is constant (only
        AR(1) noise on top, whose stationary spread is ~7%), so every
        segment mean must sit near ONE of the source rates — and with
        sources 16x apart the nearest-base classification is unambiguous."""
        bases = (15.0, 240.0)
        seg = 50
        tr = np.asarray(switching_traces(KEY, 4, 400, segment=seg,
                                         base_rates=bases))
        labels = set()
        for agent in tr:
            for s in range(400 // seg):
                mean = agent[s * seg:(s + 1) * seg].mean()
                rel = [abs(mean / b - 1.0) for b in bases]
                assert min(rel) < 0.5, f"segment mean {mean} near no source"
                labels.add(int(np.argmin(rel)))
        assert labels == {0, 1}  # both sources actually appear

    def test_within_segment_variation_is_noise_scale(self):
        tr = np.asarray(switching_traces(KEY, 4, 400, segment=50,
                                         base_rates=(15.0, 240.0)))
        for agent in tr:
            for s in range(8):
                win = agent[s * 50:(s + 1) * 50]
                assert win.max() / win.min() < 4.0  # no hidden source switch


class TestOODTraces:
    def test_shape_bounds_and_statistics(self):
        a, n = 16, 400
        tr = np.asarray(ood_traces(KEY, a, n))
        assert tr.shape == (a, n)
        assert (tr >= 1.0).all() and (tr <= 400.0).all()
        # base 60 with ±0.8 heterogeneity: fleet mean stays in a wide band
        assert 30.0 < tr.mean() < 110.0

    def test_ood_is_burstier_than_profiling_distribution(self):
        prof = np.asarray(fleet_traces(KEY, 8, 400, base_rate=60.0,
                                       **PROFILING))
        ood = np.asarray(ood_traces(KEY, 8, 400))
        cv = lambda x: (np.std(x, axis=1) / np.mean(x, axis=1)).mean()
        assert cv(ood) > 2.0 * cv(prof)
