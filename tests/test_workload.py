"""Workload-trace generators (data/workload.py): shapes, clip bounds,
switching segment structure, OOD statistics, and the scenario library."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.workload import (BURST, DYNAMIC, PROFILING, diurnal_traces,
                                 drift_traces, fleet_traces,
                                 flash_crowd_traces, make_trace, ood_traces,
                                 switching_traces)
from repro.sim.scenarios import SCENARIOS, make_scenario

KEY = jax.random.PRNGKey(0)


class TestMakeTrace:
    def test_shape_dtype_and_bounds(self):
        tr = np.asarray(make_trace(KEY, 500))
        assert tr.shape == (500,) and tr.dtype == np.float32
        assert (tr >= 1.0).all() and (tr <= 400.0).all()

    def test_clips_at_upper_bound_under_extreme_bursts(self):
        tr = np.asarray(make_trace(KEY, 200, base_rate=100.0,
                                   burst_prob=1.0, burst_scale=1000.0))
        assert tr.max() == 400.0

    def test_clips_at_lower_bound_for_tiny_base(self):
        tr = np.asarray(make_trace(KEY, 200, base_rate=0.01))
        assert tr.min() == 1.0

    def test_profiling_regime_is_narrower_than_dynamic(self):
        prof = np.asarray(make_trace(KEY, 600, **PROFILING))
        dyn = np.asarray(make_trace(KEY, 600, **DYNAMIC))
        assert np.std(prof) / np.mean(prof) < np.std(dyn) / np.mean(dyn)


class TestFleetTraces:
    def test_shape_bounds_and_heterogeneity(self):
        a, n = 8, 300
        tr = np.asarray(fleet_traces(KEY, a, n, heterogeneity=0.9))
        assert tr.shape == (a, n)
        assert (tr >= 1.0).all() and (tr <= 400.0).all()
        means = tr.mean(axis=1)
        assert means.max() / means.min() > 1.5  # per-agent base rates differ

    def test_trace_kwargs_flow_through(self):
        calm = np.asarray(fleet_traces(KEY, 4, 300, **PROFILING))
        wild = np.asarray(fleet_traces(KEY, 4, 300, **DYNAMIC))
        assert np.std(calm, axis=1).mean() < np.std(wild, axis=1).mean()


class TestSwitchingTraces:
    def test_shape_and_bounds(self):
        tr = np.asarray(switching_traces(KEY, 4, 310, segment=50))
        assert tr.shape == (4, 310)
        assert (tr >= 1.0).all() and (tr <= 400.0).all()

    def test_segment_boundaries_hold_a_single_source(self):
        """Within one segment the underlying base rate is constant (only
        AR(1) noise on top, whose stationary spread is ~7%), so every
        segment mean must sit near ONE of the source rates — and with
        sources 16x apart the nearest-base classification is unambiguous."""
        bases = (15.0, 240.0)
        seg = 50
        tr = np.asarray(switching_traces(KEY, 4, 400, segment=seg,
                                         base_rates=bases))
        labels = set()
        for agent in tr:
            for s in range(400 // seg):
                mean = agent[s * seg:(s + 1) * seg].mean()
                rel = [abs(mean / b - 1.0) for b in bases]
                assert min(rel) < 0.5, f"segment mean {mean} near no source"
                labels.add(int(np.argmin(rel)))
        assert labels == {0, 1}  # both sources actually appear

    def test_within_segment_variation_is_noise_scale(self):
        tr = np.asarray(switching_traces(KEY, 4, 400, segment=50,
                                         base_rates=(15.0, 240.0)))
        for agent in tr:
            for s in range(8):
                win = agent[s * 50:(s + 1) * 50]
                assert win.max() / win.min() < 4.0  # no hidden source switch


class TestOODTraces:
    def test_shape_bounds_and_statistics(self):
        a, n = 16, 400
        tr = np.asarray(ood_traces(KEY, a, n))
        assert tr.shape == (a, n)
        assert (tr >= 1.0).all() and (tr <= 400.0).all()
        # base 60 with ±0.8 heterogeneity: fleet mean stays in a wide band
        assert 30.0 < tr.mean() < 110.0

    def test_ood_is_burstier_than_profiling_distribution(self):
        prof = np.asarray(fleet_traces(KEY, 8, 400, base_rate=60.0,
                                       **PROFILING))
        ood = np.asarray(ood_traces(KEY, 8, 400))
        cv = lambda x: (np.std(x, axis=1) / np.mean(x, axis=1)).mean()
        assert cv(ood) > 2.0 * cv(prof)


class TestScenarioLibrary:
    @pytest.mark.parametrize("name", SCENARIOS)
    def test_every_scenario_produces_valid_traces(self, name):
        tr = np.asarray(make_scenario(name, KEY, 3, 120))
        assert tr.shape == (3, 120) and tr.dtype == np.float32
        assert (tr >= 1.0).all() and (tr <= 400.0).all()
        assert np.isfinite(tr).all()

    def test_unknown_scenario_raises(self):
        with pytest.raises(ValueError, match="unknown scenario"):
            make_scenario("rush-hour", KEY, 2, 10)

    def test_burst_is_spikier_than_steady(self):
        burst = np.asarray(fleet_traces(KEY, 8, 400, **BURST))
        calm = np.asarray(fleet_traces(KEY, 8, 400, **PROFILING))
        peak = lambda x: (x.max(axis=1) / np.median(x, axis=1)).mean()
        assert peak(burst) > 2.0 * peak(calm)

    def test_diurnal_has_deep_cycle_and_agent_phases(self):
        tr = np.asarray(diurnal_traces(KEY, 6, 360))
        assert tr.shape == (6, 360)
        # deep swing: per-agent max/min well beyond the AR-noise band
        assert ((tr.max(axis=1) / tr.min(axis=1)) > 2.5).all()
        # phase offsets: the argmax interval differs across agents
        assert len(set(tr.argmax(axis=1) // 30)) > 1

    def test_flash_crowd_surge_is_sustained_and_multiplied(self):
        tr = np.asarray(flash_crowd_traces(KEY, 6, 400, base_rate=25.0,
                                           surge_mult=6.0, surge_frac=0.25))
        for agent in tr:
            hi = agent > 3.0 * np.median(agent)
            assert hi.sum() >= 80  # ~a quarter of the horizon is surging
        # and the surge onsets differ per agent
        onsets = [int(np.argmax(a > 3.0 * np.median(a))) for a in tr]
        assert len(set(onsets)) > 1

    def test_drift_ramps_monotonically_in_trend(self):
        tr = np.asarray(drift_traces(KEY, 6, 400, start_rate=15.0,
                                     end_rate=90.0))
        thirds = tr.reshape(6, 4, 100).mean(axis=2)
        assert (np.diff(thirds, axis=1) > 0).all()  # quarter means rise
        assert (thirds[:, -1] / thirds[:, 0] > 2.0).all()
