"""Workload-trace generators (data/workload.py): shapes, clip bounds,
switching segment structure, OOD statistics, and the scenario library."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.workload import (BURST, DYNAMIC, PROFILING, diurnal_traces,
                                 drift_traces, fleet_traces,
                                 flash_crowd_traces, make_trace, ood_traces,
                                 switching_traces)
from repro.sim.scenarios import SCENARIOS, make_scenario

KEY = jax.random.PRNGKey(0)


class TestMakeTrace:
    def test_shape_dtype_and_bounds(self):
        tr = np.asarray(make_trace(KEY, 500))
        assert tr.shape == (500,) and tr.dtype == np.float32
        assert (tr >= 1.0).all() and (tr <= 400.0).all()

    def test_clips_at_upper_bound_under_extreme_bursts(self):
        tr = np.asarray(make_trace(KEY, 200, base_rate=100.0,
                                   burst_prob=1.0, burst_scale=1000.0))
        assert tr.max() == 400.0

    def test_clips_at_lower_bound_for_tiny_base(self):
        tr = np.asarray(make_trace(KEY, 200, base_rate=0.01))
        assert tr.min() == 1.0

    def test_profiling_regime_is_narrower_than_dynamic(self):
        prof = np.asarray(make_trace(KEY, 600, **PROFILING))
        dyn = np.asarray(make_trace(KEY, 600, **DYNAMIC))
        assert np.std(prof) / np.mean(prof) < np.std(dyn) / np.mean(dyn)


class TestFleetTraces:
    def test_shape_bounds_and_heterogeneity(self):
        a, n = 8, 300
        tr = np.asarray(fleet_traces(KEY, a, n, heterogeneity=0.9))
        assert tr.shape == (a, n)
        assert (tr >= 1.0).all() and (tr <= 400.0).all()
        means = tr.mean(axis=1)
        assert means.max() / means.min() > 1.5  # per-agent base rates differ

    def test_trace_kwargs_flow_through(self):
        calm = np.asarray(fleet_traces(KEY, 4, 300, **PROFILING))
        wild = np.asarray(fleet_traces(KEY, 4, 300, **DYNAMIC))
        assert np.std(calm, axis=1).mean() < np.std(wild, axis=1).mean()


class TestSwitchingTraces:
    def test_shape_and_bounds(self):
        tr = np.asarray(switching_traces(KEY, 4, 310, segment=50))
        assert tr.shape == (4, 310)
        assert (tr >= 1.0).all() and (tr <= 400.0).all()

    def test_segment_boundaries_hold_a_single_source(self):
        """Within one segment the underlying base rate is constant (only
        AR(1) noise on top, whose stationary spread is ~7%), so every
        segment mean must sit near ONE of the source rates — and with
        sources 16x apart the nearest-base classification is unambiguous."""
        bases = (15.0, 240.0)
        seg = 50
        tr = np.asarray(switching_traces(KEY, 4, 400, segment=seg,
                                         base_rates=bases))
        labels = set()
        for agent in tr:
            for s in range(400 // seg):
                mean = agent[s * seg:(s + 1) * seg].mean()
                rel = [abs(mean / b - 1.0) for b in bases]
                assert min(rel) < 0.5, f"segment mean {mean} near no source"
                labels.add(int(np.argmin(rel)))
        assert labels == {0, 1}  # both sources actually appear

    def test_within_segment_variation_is_noise_scale(self):
        tr = np.asarray(switching_traces(KEY, 4, 400, segment=50,
                                         base_rates=(15.0, 240.0)))
        for agent in tr:
            for s in range(8):
                win = agent[s * 50:(s + 1) * 50]
                assert win.max() / win.min() < 4.0  # no hidden source switch


class TestOODTraces:
    def test_shape_bounds_and_statistics(self):
        a, n = 16, 400
        tr = np.asarray(ood_traces(KEY, a, n))
        assert tr.shape == (a, n)
        assert (tr >= 1.0).all() and (tr <= 400.0).all()
        # base 60 with ±0.8 heterogeneity: fleet mean stays in a wide band
        assert 30.0 < tr.mean() < 110.0

    def test_ood_is_burstier_than_profiling_distribution(self):
        prof = np.asarray(fleet_traces(KEY, 8, 400, base_rate=60.0,
                                       **PROFILING))
        ood = np.asarray(ood_traces(KEY, 8, 400))
        cv = lambda x: (np.std(x, axis=1) / np.mean(x, axis=1)).mean()
        assert cv(ood) > 2.0 * cv(prof)


class TestScenarioLibrary:
    @pytest.mark.parametrize("name", SCENARIOS)
    def test_every_scenario_produces_valid_traces(self, name):
        tr = np.asarray(make_scenario(name, KEY, 3, 120))
        assert tr.shape == (3, 120) and tr.dtype == np.float32
        assert (tr >= 1.0).all() and (tr <= 400.0).all()
        assert np.isfinite(tr).all()

    def test_unknown_scenario_raises(self):
        with pytest.raises(ValueError, match="unknown scenario"):
            make_scenario("rush-hour", KEY, 2, 10)

    def test_burst_is_spikier_than_steady(self):
        burst = np.asarray(fleet_traces(KEY, 8, 400, **BURST))
        calm = np.asarray(fleet_traces(KEY, 8, 400, **PROFILING))
        peak = lambda x: (x.max(axis=1) / np.median(x, axis=1)).mean()
        assert peak(burst) > 2.0 * peak(calm)

    def test_diurnal_has_deep_cycle_and_agent_phases(self):
        tr = np.asarray(diurnal_traces(KEY, 6, 360))
        assert tr.shape == (6, 360)
        # deep swing: per-agent max/min well beyond the AR-noise band
        assert ((tr.max(axis=1) / tr.min(axis=1)) > 2.5).all()
        # phase offsets: the argmax interval differs across agents
        assert len(set(tr.argmax(axis=1) // 30)) > 1

    def test_flash_crowd_surge_is_sustained_and_multiplied(self):
        tr = np.asarray(flash_crowd_traces(KEY, 6, 400, base_rate=25.0,
                                           surge_mult=6.0, surge_frac=0.25))
        for agent in tr:
            hi = agent > 3.0 * np.median(agent)
            assert hi.sum() >= 80  # ~a quarter of the horizon is surging
        # and the surge onsets differ per agent
        onsets = [int(np.argmax(a > 3.0 * np.median(a))) for a in tr]
        assert len(set(onsets)) > 1

    def test_drift_ramps_monotonically_in_trend(self):
        tr = np.asarray(drift_traces(KEY, 6, 400, start_rate=15.0,
                                     end_rate=90.0))
        thirds = tr.reshape(6, 4, 100).mean(axis=2)
        assert (np.diff(thirds, axis=1) > 0).all()  # quarter means rise
        assert (thirds[:, -1] / thirds[:, 0] > 2.0).all()


# ---------------------------------------------------------------------------
# Hypothesis property tests: the 4 newer generators (burst, diurnal,
# flash-crowd, drift) across random seeds AND parameters — not just the one
# fixed key above. Parameter ranges are chosen so the structural invariant
# dominates the AR(1) noise band (stationary sd ~= 0.23 * scale, i.e. ~6%
# of the rate level) and stays clear of the [1, 400] clip.
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - hypothesis is in requirements-dev
    st = None

if st is not None:
    HYP = dict(max_examples=15, deadline=None)

    class TestGeneratorProperties:
        @settings(**HYP)
        @given(seed=st.integers(0, 2 ** 31 - 1),
               base=st.floats(10.0, 80.0),
               burst_scale=st.floats(2.0, 8.0))
        def test_burst_bounded_finite_and_spiking(self, seed, base,
                                                  burst_scale):
            key = jax.random.PRNGKey(seed)
            kw = dict(BURST, burst_scale=burst_scale)
            tr = np.asarray(fleet_traces(key, 4, 240, base_rate=base, **kw))
            assert tr.shape == (4, 240) and np.isfinite(tr).all()
            assert (tr >= 1.0).all() and (tr <= 400.0).all()

        @settings(**HYP)
        @given(seed=st.integers(0, 2 ** 31 - 1))
        def test_burst_prob_one_saturates_at_clip(self, seed):
            tr = np.asarray(fleet_traces(jax.random.PRNGKey(seed), 2, 120,
                                         base_rate=200.0, heterogeneity=0.0,
                                         burst_prob=1.0, burst_scale=100.0))
            assert tr.max() == 400.0  # every step bursts into the clip

        @settings(**HYP)
        @given(seed=st.integers(0, 2 ** 31 - 1),
               base=st.floats(30.0, 80.0),
               amp=st.floats(0.5, 0.85),
               cycles=st.sampled_from([1.0, 2.0, 4.0]))
        def test_diurnal_swing_and_periodicity(self, seed, base, amp,
                                               cycles):
            key = jax.random.PRNGKey(seed)
            tr = np.asarray(diurnal_traces(key, 4, 240, base_rate=base,
                                           amplitude=amp, cycles=cycles))
            assert (tr >= 1.0).all() and (tr <= 400.0).all()
            # swing depth tracks amplitude: the sinusoid's (1+a)/(1-a)
            # peak/trough ratio, halved for noise/clip headroom
            ratio = tr.max(axis=1) / tr.min(axis=1)
            assert (ratio > 0.5 * (1 + amp) / (1 - amp)).all()
            # periodicity: the dominant non-DC Fourier bin IS the cycle
            # count (phase offsets move power between bins' real/imag
            # parts, never off the cycle frequency)
            spec = np.abs(np.fft.rfft(tr - tr.mean(axis=1, keepdims=True),
                                      axis=1))
            assert (spec[:, 1:].argmax(axis=1) + 1 == int(cycles)).all()

        @settings(**HYP)
        @given(seed=st.integers(0, 2 ** 31 - 1),
               mult=st.floats(4.0, 8.0),
               frac=st.floats(0.15, 0.35))
        def test_flash_crowd_surge_segment_structure(self, seed, mult, frac):
            n = 320
            key = jax.random.PRNGKey(seed)
            tr = np.asarray(flash_crowd_traces(key, 4, n, base_rate=25.0,
                                               surge_mult=mult,
                                               surge_frac=frac))
            assert (tr >= 1.0).all() and (tr <= 400.0).all()
            surge_len = int(n * frac)
            for agent in tr:
                # mult >= 4x with ~6% noise vs a ~1x baseline: 2x the
                # trace median cleanly separates surge from base steps
                hi = agent > 2.0 * np.median(agent)
                assert 0.7 * surge_len <= hi.sum() <= 1.3 * surge_len
                # ONE sustained surge, not scattered spikes
                assert (np.diff(hi.astype(int)) == 1).sum() <= 2

        @settings(**HYP)
        @given(seed=st.integers(0, 2 ** 31 - 1),
               start=st.floats(5.0, 25.0),
               end_mult=st.floats(4.0, 10.0))
        def test_drift_quarter_means_ramp_monotonically(self, seed, start,
                                                        end_mult):
            key = jax.random.PRNGKey(seed)
            tr = np.asarray(drift_traces(key, 4, 320, start_rate=start,
                                         end_rate=start * end_mult))
            assert (tr >= 1.0).all() and (tr <= 400.0).all()
            quarters = tr.reshape(4, 4, 80).mean(axis=2)
            assert (np.diff(quarters, axis=1) > 0).all()
            # total ramp magnitude survives the noise (per-agent jitter is
            # a constant multiplier, so it cancels in the ratio)
            assert (quarters[:, -1] / quarters[:, 0] > 0.3 * end_mult).all()
else:  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_generator_properties():
        pass
