"""Diversity-buffer engine A/B: recompute-oracle inserts vs the
streaming-moment engine on the CRL episode hot path.

Three drivers over identical fleets/traces/seeds (A agents × T control
intervals, buffer capacity N):

  * ``reference`` — the seed implementation: ``buffer_insert_reference``
    inside the episode ``lax.scan``, rebuilding the N×D covariance and
    running a dense ``linalg.solve`` every step, vmapped over the fleet.
  * ``streaming`` — the production path: scan body is env+policy only,
    one ``buffer_insert_batch`` (jnp streaming scan, O(D²)/candidate,
    LAPACK-free Cholesky) ingests the whole episode afterwards.
  * ``pallas`` — same, routed through the fused ``diversity_insert`` kernel
    (interpret mode on CPU, so this row is informational off-TPU).

Reported: warm wall clock per episode batch, speedup vs reference, and the
equivalence drift (identical evicted slots; max |score| difference) between
the reference and streaming buffers — the acceptance gate mirrored by
tests/test_buffer.py.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import load_rows, save_bench, save_rows, time_call
from repro.configs.fcpo import FCPOConfig
from repro.core.crl import run_episode, run_episode_reference
from repro.core.fleet import fleet_init
from repro.data.workload import fleet_traces


def _drivers(cfg):
    def vm(fn):
        return jax.jit(jax.vmap(
            lambda ep, st, r, m: fn(cfg, ep, st, r, m)[:2]))

    return {
        "reference": vm(run_episode_reference),
        "streaming": vm(run_episode),
        "pallas": vm(lambda c, ep, st, r, m: run_episode(
            c, ep, st, r, m, use_pallas=True)),
    }


def run_ab(n_agents=256, t_steps=64, buffer_n=64, iters=10, with_pallas=True):
    cfg = FCPOConfig(buffer_size=buffer_n)
    fleet = fleet_init(cfg, n_agents, jax.random.PRNGKey(0))
    rates = fleet_traces(jax.random.PRNGKey(1), n_agents, t_steps)
    drivers = _drivers(cfg)
    if not with_pallas:
        drivers.pop("pallas")

    rows, bufs = [], {}
    for name, fn in drivers.items():
        us = time_call(fn, fleet.env_params, fleet.astate, rates, fleet.masks,
                       iters=iters)
        out = fn(fleet.env_params, fleet.astate, rates, fleet.masks)
        bufs[name] = jax.device_get(out[0].buffer)
        rows.append({"name": f"buffer_{name}", "us_per_call": us,
                     "agents": n_agents, "steps": t_steps,
                     "buffer_size": buffer_n})

    ref = bufs["reference"]
    for row in rows:
        b = bufs[row["name"].removeprefix("buffer_")]
        finite = lambda x: np.nan_to_num(x, posinf=0.0, neginf=0.0)
        row["same_slots"] = bool((b.states == ref.states).all()
                                 & (b.filled == ref.filled).all())
        row["score_drift"] = float(
            np.max(np.abs(finite(b.score) - finite(ref.score))))
        row["speedup_vs_reference"] = rows[0]["us_per_call"] / row["us_per_call"]
    return rows


def run(quick: bool = True, smoke: bool = False, fresh: bool = False):
    """Raw benchmark rows. ``smoke``: tiny CI shapes, never cached.
    ``fresh``: bypass the artifact cache (a regression gate must measure
    this run, not a stale artifact). ``quick=False`` triples the timing
    iterations for a stabler median at the same A/T/N acceptance shapes."""
    if smoke:
        return run_ab(n_agents=8, t_steps=8, buffer_n=8, iters=3)
    if not fresh:
        cached = load_rows("fig_buffer_perf")
        if cached:
            return cached
    rows = run_ab(iters=10 if quick else 30)
    save_rows("fig_buffer_perf", rows)
    return rows


def format_rows(rows):
    return [{
        "name": r["name"],
        "us_per_call": f"{r['us_per_call']:.0f}",
        "derived": (f"A={r['agents']} T={r['steps']} N={r['buffer_size']} "
                    f"speedup={r['speedup_vs_reference']:.2f}x "
                    f"same_slots={r['same_slots']} "
                    f"score_drift={r['score_drift']:.1e}"),
    } for r in rows]


def _run_and_save(quick: bool = True, smoke: bool = False,
                  fresh: bool = False):
    rows = run(quick, smoke=smoke, fresh=fresh)
    save_bench("buffer_perf" + ("_smoke" if smoke else ""), rows)
    return rows


def main(quick: bool = True, smoke: bool = False):
    return format_rows(_run_and_save(quick, smoke=smoke))


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit_csv

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI perf-path regression checks")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="exit nonzero unless streaming beats reference by "
                         "this factor (always re-measures; never gates on "
                         "cached rows)")
    args = ap.parse_args()
    raw = _run_and_save(smoke=args.smoke,
                        fresh=args.min_speedup is not None)
    emit_csv(format_rows(raw))
    if args.min_speedup is not None:
        stream = next(r for r in raw if r["name"] == "buffer_streaming")
        speedup = stream["speedup_vs_reference"]
        assert speedup >= args.min_speedup, (
            f"streaming speedup {speedup:.2f}x < required "
            f"{args.min_speedup:.2f}x")
