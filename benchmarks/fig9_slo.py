"""Fig. 9: effective throughput under increasingly strict SLOs
(250 -> 200 -> 100 ms) for FCPO vs the non-adaptive baselines."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import load_rows, save_rows
from repro.configs.fcpo import FCPOConfig
from repro.core.baselines import run_distream, run_octopinf
from repro.core.fleet import fleet_init, train_fleet
from repro.data.workload import DYNAMIC, fleet_traces


def run(quick: bool = True, n: int = 8):
    cached = load_rows("fig9")
    if cached:
        return cached
    episodes = 200 if quick else 600
    rows = []
    for slo_ms in (250, 200, 100):
        cfg = FCPOConfig(slo_s=slo_ms / 1000.0)
        key = jax.random.PRNGKey(0)
        traces = fleet_traces(jax.random.PRNGKey(1), n, episodes * cfg.n_steps,
                              **DYNAMIC)
        fleet = fleet_init(cfg, n, key, slo_s=cfg.slo_s)
        _, h = train_fleet(cfg, fleet, traces)
        h_oct = run_octopinf(n, traces, 0, cfg=cfg)
        h_dis = run_distream(n, traces, 0, cfg=cfg)
        tail = max(episodes // 3, 10)
        for name, hh in (("fcpo", h), ("octopinf", h_oct), ("distream", h_dis)):
            rows.append({
                "name": f"fig9_{name}_slo{slo_ms}",
                "slo_ms": slo_ms,
                "effective_throughput":
                    float(np.mean(hh["effective_throughput"][-tail:])),
                "latency_ms": float(np.mean(hh["latency"][-tail:]) * 1e3),
            })
    save_rows("fig9", rows)
    return rows


def main(quick: bool = True):
    return [{
        "name": r["name"], "us_per_call": "",
        "derived": (f"eff_thr={r['effective_throughput']:.1f}/s "
                    f"lat={r['latency_ms']:.0f}ms"),
    } for r in run(quick)]


if __name__ == "__main__":
    from benchmarks.common import emit_csv
    emit_csv(main())
