"""Fig. 13: impact of continual learning — a trained-then-frozen fleet vs a
continually-learning fleet on concatenated 5-min segments from different
sources (drastic context switches)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import load_rows, save_rows
from repro.configs.fcpo import FCPOConfig
from repro.core.fleet import fleet_init, train_fleet
from repro.data.workload import fleet_traces, switching_traces


def run(quick: bool = True, n: int = 8):
    cached = load_rows("fig13")
    if cached:
        return cached
    cfg = FCPOConfig()
    pre_eps = 150 if quick else 500
    sw_eps = 150 if quick else 400
    key = jax.random.PRNGKey(0)
    fleet = fleet_init(cfg, n, key)
    fleet, _ = train_fleet(cfg, fleet, fleet_traces(jax.random.PRNGKey(1), n,
                                                    pre_eps * cfg.n_steps))
    switch = switching_traces(jax.random.PRNGKey(2), n, sw_eps * cfg.n_steps,
                              segment=50)
    _, h_crl = train_fleet(cfg, fleet, switch)
    _, h_frozen = train_fleet(cfg, fleet, switch, learn=False, federated=False)

    rows = []
    for name, h in (("crl", h_crl), ("frozen", h_frozen)):
        eff = np.asarray(h["effective_throughput"])
        rows.append({
            "name": f"fig13_{name}",
            "effective_throughput": float(eff.mean()),
            "eff_thr_last_third": float(eff[-len(eff) // 3:].mean()),
            "reward": float(np.mean(h["reward"])),
            "curve_eff": [float(x) for x in eff],
        })
    save_rows("fig13", rows)
    return rows


def main(quick: bool = True):
    return [{
        "name": r["name"], "us_per_call": "",
        "derived": (f"eff_thr={r['effective_throughput']:.1f}/s "
                    f"(last3rd {r['eff_thr_last_third']:.1f}) "
                    f"reward={r['reward']:+.2f}"),
    } for r in run(quick)]


if __name__ == "__main__":
    from benchmarks.common import emit_csv
    emit_csv(main())
