"""Fig. 7: end-to-end throughput / effective throughput / latency —
FCPO vs BCEdge-like, OctopInf-like, Distream-like on identical traces."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import load_rows, save_rows
from repro.configs.fcpo import FCPOConfig
from repro.core.baselines import run_bcedge, run_distream, run_octopinf
from repro.core.fleet import fleet_init, train_fleet
from repro.data.workload import DYNAMIC, fleet_traces


def run(quick: bool = True, n: int = 8, seed: int = 0):
    cached = load_rows("fig7")
    if cached:
        return cached
    episodes = 700 if quick else 1400
    cfg = FCPOConfig()
    key = jax.random.PRNGKey(seed)
    traces = fleet_traces(jax.random.PRNGKey(seed + 1), n,
                          episodes * cfg.n_steps, **DYNAMIC)

    fleet = fleet_init(cfg, n, key, n_pods=2)
    _, h_fcpo = train_fleet(cfg, fleet, traces)
    h_bce = run_bcedge(n, traces, key,
                       offline_episodes=60 if quick else 150)
    h_oct = run_octopinf(n, traces, seed)
    h_dis = run_distream(n, traces, seed)

    rows = []
    tail = max(episodes // 3, 10)  # converged regime
    for name, h in (("fcpo", h_fcpo), ("bcedge", h_bce),
                    ("octopinf", h_oct), ("distream", h_dis)):
        rows.append({
            "name": f"fig7_{name}",
            "throughput": float(np.mean(h["throughput"][-tail:])),
            "effective_throughput":
                float(np.mean(h["effective_throughput"][-tail:])),
            "latency_ms": float(np.mean(h["latency"][-tail:]) * 1e3),
            "reward": float(np.mean(h["reward"][-tail:])),
            "curve_reward": [float(x) for x in h["reward"]],
            "curve_eff": [float(x) for x in h["effective_throughput"]],
            "curve_latency": [float(x) for x in h["latency"]],
        })
    save_rows("fig7", rows)
    return rows


def main(quick: bool = True):
    rows = run(quick)
    out = []
    for r in rows:
        out.append({
            "name": r["name"],
            "us_per_call": "",
            "derived": (f"eff_thr={r['effective_throughput']:.1f}/s "
                        f"thr={r['throughput']:.1f}/s "
                        f"lat={r['latency_ms']:.0f}ms"),
        })
    return out


if __name__ == "__main__":
    from benchmarks.common import emit_csv
    emit_csv(main())
