"""Fig. 8: learning performance (averaged loss and reward curves) —
FCPO fluctuates-and-adapts vs offline-converged BCEdge."""
from __future__ import annotations

import numpy as np

from benchmarks.common import load_rows, save_rows
from benchmarks.fig7_end2end import run as run_fig7


def run(quick: bool = True):
    cached = load_rows("fig8")
    if cached:
        return cached
    fig7 = run_fig7(quick)
    rows = []
    for r in fig7:
        if r["name"] not in ("fig7_fcpo", "fig7_bcedge"):
            continue
        curve = np.asarray(r["curve_reward"])
        k = max(len(curve) // 10, 1)
        rows.append({
            "name": r["name"].replace("fig7", "fig8"),
            "reward_start": float(curve[:k].mean()),
            "reward_end": float(curve[-k:].mean()),
            "reward_improvement": float(curve[-k:].mean() - curve[:k].mean()),
            # adaptation signature: online learner keeps fluctuating
            "reward_std_tail": float(curve[-3 * k:].std()),
        })
    save_rows("fig8", rows)
    return rows


def main(quick: bool = True):
    return [{
        "name": r["name"], "us_per_call": "",
        "derived": (f"reward {r['reward_start']:+.2f}->{r['reward_end']:+.2f} "
                    f"(+{r['reward_improvement']:.2f})"),
    } for r in run(quick)]


if __name__ == "__main__":
    from benchmarks.common import emit_csv
    emit_csv(main())
