"""Digital-twin benchmark: twin vs the host-side Python data plane, and the
fluid-MDP vs request-level fidelity gap.

Two measurements over identical arrivals/caps/seeds:

  * ``speed`` — the tensorized twin (one jitted ``lax.scan``, vmapped over
    A=64 agents; jnp path and the fused Pallas ``queue_advance`` kernel)
    against the ``serving/slo.py`` Python oracle (``repro.sim.oracle``, the
    deque/list data plane driven agent-by-agent from the host). Service
    capacities are integer-representable so the two paths must also agree
    request-for-request — the ``totals_match`` column is an equivalence
    gate, not an approximation.
  * ``fidelity`` — a fluid-MDP-trained fleet evaluated on BOTH planes over
    the same traces: per-interval effective throughput from ``core/env.py``
    (Little's-law latency surface) vs the twin's per-request deadline
    accounting, reported as a relative gap plus the twin-only request-grade
    metrics (p50/p99 latency, drops) the fluid model cannot produce.

Reported: warm wall clock per simulated run, twin speedup vs the Python
path (acceptance: >= 5x at A=64 on CPU), and the fluid-vs-twin gap.
``--min-speedup`` is the CI regression gate (smoke shapes).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import load_rows, save_bench, save_rows, time_call
from repro.configs.fcpo import FCPOConfig
from repro.core.fleet import fleet_init, train_fleet
from repro.data.workload import fleet_traces
from repro.sim import SimParams, sim_init, sim_interval, simulate_fleet, \
    spread_arrivals
from repro.sim.oracle import simulate_python_fleet

# Integer-representable caps (pre/tick, post/tick, batch, t_batch ticks,
# qcap, slo ticks): exact in float32 and float64, so twin == slo.py exactly.
SPEED_CAPS = (6.0, 8.0, 16.0, 2.0, 21.0, 5.0)


@partial(jax.jit, static_argnames=("use_pallas",))
def _twin_run(state, arr_seq, caps, use_pallas=False):
    def body(s, arr):
        return sim_interval(s, arr, caps, use_pallas), None
    s, _ = jax.lax.scan(body, state, arr_seq)
    return s


def run_speed(n_agents=64, n_intervals=10, ring=64, hist_n=16, iters=7,
              with_pallas=True):
    """Data-plane-only A/B on a fixed action schedule (policy cost excluded
    on every path so the comparison is queue dynamics vs queue dynamics)."""
    sp = SimParams(dt=0.05, k_ticks=20, ring=ring, hist_n=hist_n)
    rng = np.random.default_rng(0)
    rates = rng.uniform(150, 350, (n_agents, n_intervals)).astype(np.float32)
    arrivals = np.asarray(jax.vmap(jax.vmap(
        lambda r: spread_arrivals(sp, r)[0]))(jnp.asarray(rates)))  # (A,T,K)
    caps = jnp.broadcast_to(jnp.asarray(SPEED_CAPS, jnp.float32),
                            (n_agents, 6))
    state0 = jax.vmap(lambda _: sim_init(sp))(jnp.arange(n_agents))
    arr_seq = jnp.asarray(arrivals.transpose(1, 0, 2))  # (T, A, K)

    import time as _time
    py_caps = np.broadcast_to(np.asarray(caps[0]),
                              (n_agents, n_intervals, 6)).copy()
    py_ts = []
    for _ in range(3):
        t0 = _time.perf_counter()
        py = simulate_python_fleet(arrivals, py_caps, sp)
        py_ts.append(_time.perf_counter() - t0)
    py_us = float(np.median(py_ts)) * 1e6
    totals = {k: sum(p[k] for p in py)
              for k in ("completed", "dropped", "effective", "lat_sum")}

    shape = {"agents": n_agents, "intervals": n_intervals,
             "microticks": n_intervals * sp.k_ticks, "ring": ring}
    rows = [{"name": "sim_python_oracle", "us_per_call": py_us, **shape,
             "speedup_vs_python": 1.0, "totals_match": True}]
    drivers = [("sim_twin_jnp", False)]
    if with_pallas:
        drivers.append(("sim_twin_pallas", True))
    for name, use_pallas in drivers:
        us = time_call(partial(_twin_run, use_pallas=use_pallas),
                       state0, arr_seq, caps, iters=iters)
        out = _twin_run(state0, arr_seq, caps, use_pallas=use_pallas)
        match = (int(out.completed.sum()) == totals["completed"]
                 and int(out.dropped.sum()) == totals["dropped"]
                 and int(out.effective.sum()) == totals["effective"]
                 and float(out.lat_sum.sum()) == totals["lat_sum"])
        rows.append({"name": name, "us_per_call": us, **shape,
                     "speedup_vs_python": py_us / us,
                     "totals_match": bool(match)})
    return rows


def run_fidelity(n_agents=8, train_episodes=40, eval_intervals=40, seed=0):
    """Fluid-vs-twin effective-throughput gap for a trained policy."""
    cfg = FCPOConfig()
    sp = SimParams()
    fleet = fleet_init(cfg, n_agents, jax.random.PRNGKey(seed))
    if train_episodes > 0:
        warmup = fleet_traces(jax.random.PRNGKey(seed + 1), n_agents,
                              train_episodes * cfg.n_steps)
        fleet, _ = train_fleet(cfg, fleet, warmup)

    n_eps = max(eval_intervals // cfg.n_steps, 1)
    traces = fleet_traces(jax.random.PRNGKey(seed + 2), n_agents,
                          n_eps * cfg.n_steps)
    _, hist_fluid = train_fleet(cfg, fleet, traces, learn=False,
                                federated=False)
    _, _, summ = simulate_fleet(cfg, sp, fleet.astate.params, fleet.masks,
                                fleet.env_params, traces,
                                jax.random.PRNGKey(seed + 3))
    eff_fluid = float(np.mean(hist_fluid["effective_throughput"]))
    eff_twin = float(np.asarray(summ["effective_throughput"]).mean())
    thr_fluid = float(np.mean(hist_fluid["throughput"]))
    thr_twin = float(np.asarray(summ["throughput"]).mean())
    return [{
        "name": "sim_fidelity_fluid_vs_twin",
        "us_per_call": 0.0,
        "agents": n_agents,
        "train_episodes": train_episodes,
        "thr_fluid": thr_fluid,
        "thr_twin": thr_twin,
        "thr_gap": abs(thr_fluid - thr_twin) / max(abs(thr_fluid), 1e-9),
        "eff_fluid": eff_fluid,
        "eff_twin": eff_twin,
        "eff_gap": abs(eff_fluid - eff_twin) / max(abs(eff_fluid), 1e-9),
        "twin_p50_s": float(np.asarray(summ["p50_latency_s"]).mean()),
        "twin_p99_s": float(np.asarray(summ["p99_latency_s"]).mean()),
        "twin_drop_rate": float(np.asarray(summ["drop_rate"]).mean()),
    }]


def run(quick: bool = True, smoke: bool = False, fresh: bool = False):
    """Raw benchmark rows. ``smoke``: tiny CI shapes, never cached.
    ``fresh``: bypass the artifact cache (a regression gate must measure
    this run, not a stale artifact)."""
    if smoke:
        return (run_speed(n_agents=4, n_intervals=3, iters=3)
                + run_fidelity(n_agents=2, train_episodes=2,
                               eval_intervals=10))
    if not fresh:
        cached = load_rows("fig_sim_fidelity")
        if cached:
            return cached
    rows = (run_speed(iters=7 if quick else 21)
            + run_fidelity(train_episodes=40 if quick else 120))
    save_rows("fig_sim_fidelity", rows)
    return rows


def format_rows(rows):
    out = []
    for r in rows:
        if "eff_gap" in r:
            derived = (f"A={r['agents']} "
                       f"thr_gap={r['thr_gap'] * 100:.1f}% "
                       f"eff_fluid={r['eff_fluid']:.2f}/s "
                       f"eff_twin={r['eff_twin']:.2f}/s "
                       f"eff_gap={r['eff_gap'] * 100:.1f}% "
                       f"p50={r['twin_p50_s'] * 1e3:.0f}ms "
                       f"p99={r['twin_p99_s'] * 1e3:.0f}ms "
                       f"drops={r['twin_drop_rate'] * 100:.1f}%")
        else:
            derived = (f"A={r['agents']} ticks={r['microticks']} "
                       f"ring={r['ring']} "
                       f"speedup={r['speedup_vs_python']:.1f}x "
                       f"totals_match={r['totals_match']}")
        out.append({"name": r["name"],
                    "us_per_call": f"{r['us_per_call']:.0f}",
                    "derived": derived})
    return out


def _run_and_save(quick: bool = True, smoke: bool = False,
                  fresh: bool = False):
    rows = run(quick, smoke=smoke, fresh=fresh)
    save_bench("sim_fidelity" + ("_smoke" if smoke else ""), rows)
    return rows


def main(quick: bool = True, smoke: bool = False):
    return format_rows(_run_and_save(quick, smoke=smoke))


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit_csv

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI perf-path regression checks")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="exit nonzero unless the jnp twin beats the Python "
                         "slo.py path by this factor (always re-measures)")
    args = ap.parse_args()
    raw = _run_and_save(smoke=args.smoke,
                        fresh=args.min_speedup is not None)
    emit_csv(format_rows(raw))
    if args.min_speedup is not None:
        for r in raw:
            if r["name"].startswith("sim_twin"):
                assert r["totals_match"], \
                    f"{r['name']} diverged from the slo.py oracle"
        twin = next(r for r in raw if r["name"] == "sim_twin_jnp")
        speedup = twin["speedup_vs_python"]
        assert speedup >= args.min_speedup, (
            f"twin speedup {speedup:.2f}x < required "
            f"{args.min_speedup:.2f}x")
