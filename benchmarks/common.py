"""Shared benchmark infrastructure: timing, CSV rows, artifact cache."""
from __future__ import annotations

import json
import os
import platform
import subprocess
import time
from typing import Callable, Dict, List, Optional

import numpy as np

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BENCH_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def cache_path(name: str) -> str:
    os.makedirs(BENCH_DIR, exist_ok=True)
    return os.path.join(BENCH_DIR, f"{name}.json")


def save_rows(name: str, rows: List[Dict]):
    with open(cache_path(name), "w") as f:
        json.dump(rows, f, indent=1, default=float)


def load_rows(name: str):
    p = cache_path(name)
    if os.path.exists(p):
        with open(p) as f:
            return json.load(f)
    return None


def git_sha() -> str:
    """Commit SHA of the working tree — ``git rev-parse`` first, then the CI
    env (``GITHUB_SHA``), else ``"unknown"``. Never raises: envelopes must
    still be writable from an exported (non-git) tree."""
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=REPO_ROOT,
                             capture_output=True, text=True, timeout=10)
        if out.returncode == 0 and out.stdout.strip():
            return out.stdout.strip()
    except OSError:
        pass
    return os.environ.get("GITHUB_SHA", "unknown")


def save_bench(name: str, results: List[Dict], extra: Optional[Dict] = None,
               out_dir: Optional[str] = None) -> str:
    """Machine-readable benchmark artifact: ``BENCH_<name>.json`` at the repo
    root (or ``out_dir``), for CI trend tracking and regression gates.
    ``results`` is the same row list the figure scripts cache/emit; the
    envelope stamps provenance — git SHA, jax version, backend, platform,
    timestamp — so artifacts from different hosts/commits are comparable
    (leaderboard deltas are meaningless without it). ``extra`` merges
    top-level keys into the envelope (reserved keys win)."""
    import jax

    path = os.path.join(out_dir or REPO_ROOT, f"BENCH_{name}.json")
    payload = dict(extra or {})
    payload.update({
        "name": name,
        "git_sha": git_sha(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "platform": platform.platform(),
        "timestamp": time.time(),
        "results": results,
    })
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float, sort_keys=True)
    return path


def load_bench(name: str, out_dir: Optional[str] = None) -> Optional[Dict]:
    """Read back a ``save_bench`` envelope (the previous run's, for
    leaderboard deltas); None when it does not exist yet."""
    path = os.path.join(out_dir or REPO_ROOT, f"BENCH_{name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in microseconds (blocks on jax arrays)."""
    import jax

    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit_csv(rows: List[Dict]):
    """Print ``name,us_per_call,derived`` CSV lines."""
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},{r.get('derived', '')}",
              flush=True)
