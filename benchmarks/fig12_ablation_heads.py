"""Fig. 12: ablation — cascaded three-head iAgent vs FCPO-reduced (one joint
action head) on identical traces."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import load_rows, save_rows
from repro.configs.fcpo import FCPOConfig
from repro.core.fleet import fleet_init, train_fleet
from repro.data.workload import fleet_traces


def run(quick: bool = True, n: int = 8):
    cached = load_rows("fig12")
    if cached:
        return cached
    episodes = 250 if quick else 600
    rows = []
    for name, cfg in (("cascaded", FCPOConfig()),
                      ("reduced_single_head", FCPOConfig(single_head=True))):
        key = jax.random.PRNGKey(0)
        traces = fleet_traces(jax.random.PRNGKey(1), n, episodes * cfg.n_steps)
        fleet = fleet_init(cfg, n, key)
        _, h = train_fleet(cfg, fleet, traces)
        tail = max(episodes // 3, 10)
        rows.append({
            "name": f"fig12_{name}",
            "reward": float(np.mean(h["reward"][-tail:])),
            "effective_throughput":
                float(np.mean(h["effective_throughput"][-tail:])),
            "latency_ms": float(np.mean(h["latency"][-tail:]) * 1e3),
        })
    save_rows("fig12", rows)
    return rows


def main(quick: bool = True):
    return [{
        "name": r["name"], "us_per_call": "",
        "derived": (f"reward={r['reward']:+.2f} "
                    f"eff_thr={r['effective_throughput']:.1f}/s "
                    f"lat={r['latency_ms']:.0f}ms"),
    } for r in run(quick)]


if __name__ == "__main__":
    from benchmarks.common import emit_csv
    emit_csv(main())
