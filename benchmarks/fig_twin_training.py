"""Twin-training benchmark: "train where you serve" A/B.

Two measurements:

  * ``ab`` — per scenario, two fleets with identical seeds/masks/devices are
    trained over the same traces, one on the fluid MDP backend and one on
    the request-level twin backend (``core.backends``), then BOTH are
    evaluated in the twin on ``eval_reps`` held-out trace/key replicates of
    the same scenario (workload draws are high-variance; the mean over
    replicates is the comparison, the per-replicate win count is reported
    alongside). Reported: twin effective throughput, p99 latency, and drop
    rate per training backend, and the twin-trained margin. The twin
    backend's reward is request-grade (per-request deadline misses +
    admission drops) instead of the fluid binary interval cutoff — the A/B
    quantifies how much of the ~80% fidelity gap
    (benchmarks/fig_sim_fidelity.py) training in the twin claws back.
    Acceptance: twin-trained beats fluid-trained on twin effective
    throughput on the ``switching`` and ``ood`` scenarios.
  * ``overhead`` — warm wall clock per training episode for the scanned
    driver on each backend (the twin nests K microticks per control
    interval, so its episode is strictly more work), plus two measured
    gates: the twin-backed scan must COMPILE ONCE (a second same-shaped run
    adds no executable) and must run as ONE jitted scan — a degradation to
    a host-side episode/microtick loop would compile the per-episode
    ``fleet_episode`` entry point during the measurement, so its jit-cache
    delta is asserted zero. ``--gate`` asserts both (the CI regression
    gate).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import load_rows, save_bench, save_rows, time_call
from repro.configs.fcpo import FCPOConfig
from repro.core.backends import FLUID, TwinBackend
from repro.core.fleet import _scan_fn, fleet_episode, fleet_init, train_fleet
from repro.sim import SimParams, make_scenario, simulate_fleet

AB_SCENARIOS = ("steady", "switching", "ood")


def run_ab(scenarios=AB_SCENARIOS, n_agents=8, train_episodes=60,
           eval_intervals=60, eval_reps=3, seed=0):
    """Train fluid vs twin on identical traces, evaluate both in the twin."""
    cfg = FCPOConfig()
    # hist_n=128 keeps the evaluation p99 uncensored out to 6.35 s — the
    # untrained tails on ood/switching exceed the default 3.15 s cap
    sp = SimParams(hist_n=128)
    backends = (("fluid", FLUID), ("twin", TwinBackend(sp=sp)))
    rows = []
    for scen in scenarios:
        traces = make_scenario(scen, jax.random.PRNGKey(seed + 10), n_agents,
                               train_episodes * cfg.n_steps)
        held_out = [make_scenario(scen, jax.random.PRNGKey(seed + 20 + j),
                                  n_agents, eval_intervals)
                    for j in range(eval_reps)]
        res = {}
        for name, be in backends:
            fleet = fleet_init(cfg, n_agents, jax.random.PRNGKey(seed),
                               env_backend=be)
            t0 = time.perf_counter()
            fleet, _ = train_fleet(cfg, fleet, traces, env_backend=be)
            train_s = time.perf_counter() - t0
            effs, p99s, drops = [], [], []
            for j, ev in enumerate(held_out):
                _, _, summ = simulate_fleet(
                    cfg, sp, fleet.astate.params, fleet.masks,
                    fleet.env_params, ev, jax.random.PRNGKey(seed + 3 + j))
                effs.append(
                    float(np.asarray(summ["effective_throughput"]).mean()))
                p99s.append(float(np.asarray(summ["p99_latency_s"]).mean()))
                drops.append(float(np.asarray(summ["drop_rate"]).mean()))
            res[name] = {"effs": effs, "eff": float(np.mean(effs)),
                         "p99": float(np.mean(p99s)),
                         "drops": float(np.mean(drops)), "train_s": train_s}
        f, t = res["fluid"], res["twin"]
        rows.append({
            "name": f"twin_training_ab_{scen}",
            "us_per_call": 0.0,
            "agents": n_agents,
            "train_episodes": train_episodes,
            "eval_intervals": eval_intervals,
            "eval_reps": eval_reps,
            "eff_fluid_trained": f["eff"],
            "eff_twin_trained": t["eff"],
            "twin_margin": t["eff"] / max(f["eff"], 1e-9) - 1.0,
            "twin_wins": t["eff"] > f["eff"],
            "rep_wins": sum(tw > fl for tw, fl in zip(t["effs"], f["effs"])),
            "p99_fluid_trained_s": f["p99"],
            "p99_twin_trained_s": t["p99"],
            "drops_fluid_trained": f["drops"],
            "drops_twin_trained": t["drops"],
            "train_s_fluid": f["train_s"],
            "train_s_twin": t["train_s"],
        })
    return rows


def run_overhead(n_agents=4, episodes=8, iters=5, seed=0):
    """Warm per-episode cost of the scanned driver on each backend + the
    compile-once / one-dispatch structural gate for the twin scan."""
    cfg = FCPOConfig()
    sp = SimParams()
    traces = make_scenario("dynamic", jax.random.PRNGKey(seed + 1), n_agents,
                           episodes * cfg.n_steps)
    rows = []
    for name, be in (("fluid", FLUID), ("twin", TwinBackend(sp=sp))):
        fleet = fleet_init(cfg, n_agents, jax.random.PRNGKey(seed),
                           env_backend=be)
        fn = lambda: train_fleet(cfg, fleet, traces, env_backend=be)
        ep_cache_before = fleet_episode._cache_size()
        us = time_call(lambda: fn()[0].episode, iters=iters)
        # the warmup calls above populated the cache; a further same-shaped
        # run must NOT add an executable (compile once). And the run must be
        # the scanned driver alone: if it ever degraded to a host-side
        # episode loop (one dispatch per episode — or worse, per microtick),
        # the per-episode jit entry point would have compiled during the
        # measurement, so its cache delta is the measured dispatch gate.
        size = _scan_fn(False)._cache_size()
        fn()
        compiled_once = _scan_fn(False)._cache_size() == size
        host_episode_compiles = fleet_episode._cache_size() - ep_cache_before
        rows.append({
            "name": f"twin_training_overhead_{name}",
            "us_per_call": us,
            "us_per_episode": us / episodes,
            "agents": n_agents,
            "episodes": episodes,
            "microticks_per_interval": sp.k_ticks if name == "twin" else 1,
            "host_episode_compiles": host_episode_compiles,
            "one_jitted_scan": host_episode_compiles == 0,
            "compiled_once": compiled_once,
        })
    base = rows[0]["us_per_episode"]
    for r in rows:
        r["overhead_vs_fluid"] = r["us_per_episode"] / max(base, 1e-9)
    return rows


def run(quick: bool = True, smoke: bool = False, fresh: bool = False):
    """Raw benchmark rows. ``smoke``: tiny CI shapes, never cached.
    ``fresh``: bypass the artifact cache (a regression gate must measure
    this run, not a stale artifact)."""
    if smoke:
        return (run_ab(scenarios=("steady",), n_agents=2, train_episodes=3,
                       eval_intervals=10, eval_reps=1)
                + run_overhead(n_agents=2, episodes=3, iters=2))
    if not fresh:
        cached = load_rows("fig_twin_training")
        if cached:
            return cached
    rows = (run_ab(train_episodes=60 if quick else 150)
            + run_overhead(iters=5 if quick else 11))
    save_rows("fig_twin_training", rows)
    return rows


def format_rows(rows):
    out = []
    for r in rows:
        if "eff_twin_trained" in r:
            derived = (f"A={r['agents']} eps={r['train_episodes']} "
                       f"eff_fluid={r['eff_fluid_trained']:.2f}/s "
                       f"eff_twin={r['eff_twin_trained']:.2f}/s "
                       f"margin={r['twin_margin'] * 100:+.1f}% "
                       f"reps={r['rep_wins']}/{r['eval_reps']} "
                       f"p99={r['p99_twin_trained_s'] * 1e3:.0f}ms "
                       f"drops={r['drops_twin_trained'] * 100:.1f}% "
                       f"twin_wins={r['twin_wins']}")
        else:
            derived = (f"A={r['agents']} eps={r['episodes']} "
                       f"us/episode={r['us_per_episode']:.0f} "
                       f"overhead={r['overhead_vs_fluid']:.2f}x "
                       f"one_jitted_scan={r['one_jitted_scan']} "
                       f"compiled_once={r['compiled_once']}")
        out.append({"name": r["name"],
                    "us_per_call": f"{r['us_per_call']:.0f}",
                    "derived": derived})
    return out


def _run_and_save(quick: bool = True, smoke: bool = False,
                  fresh: bool = False):
    rows = run(quick, smoke=smoke, fresh=fresh)
    save_bench("twin_training" + ("_smoke" if smoke else ""), rows)
    return rows


def main(quick: bool = True, smoke: bool = False):
    return format_rows(_run_and_save(quick, smoke=smoke))


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit_csv

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI perf-path regression checks")
    ap.add_argument("--gate", action="store_true",
                    help="exit nonzero unless the twin-backed scanned "
                         "driver compiled once and ran as one dispatch "
                         "(always re-measures)")
    args = ap.parse_args()
    raw = _run_and_save(smoke=args.smoke, fresh=args.gate)
    emit_csv(format_rows(raw))
    if args.gate:
        twin = next(r for r in raw
                    if r["name"] == "twin_training_overhead_twin")
        assert twin["compiled_once"], (
            "twin-backed scan recompiled on a same-shaped rerun — the "
            "episodes->FL->merge cadence is no longer one cached executable")
        assert twin["one_jitted_scan"], (
            f"twin-backed run touched the per-episode host entry point "
            f"({twin['host_episode_compiles']} fleet_episode compiles) — "
            f"it must run as ONE jitted scan, no host work per episode or "
            f"microtick")
