"""FL communication benchmark: payload, parity, emergent stragglers.

Three measurements over the federated transport subsystem (``repro.fl``):

  * ``payload`` — encoded bytes per FL round per codec, from the same static
    accounting the jitted round folds in as constants (uplink = one encoded
    delta per selected client; downlink = per-agent full-parameter unicast
    for the float32/parameter-server path vs ONE encoded base-delta
    broadcast per pod for the compressed codecs — the delta codecs keep a
    synchronized base on both ends, which is what makes the broadcast
    legal). Acceptance: int8 reduces round payload >= 8x vs the float32
    baseline (more for top-k) — the concrete artifact for the paper's §VI
    10x-memory claim.
  * ``parity`` — fleets with identical seeds trained through each codec on
    identical traces; the lossy codecs' error-feedback residuals must keep
    final fleet reward within 5% of the float32 baseline. The traced
    per-round ``fl_payload_bytes`` from the training history is
    cross-checked against the static accounting, and the int8 run must keep
    the whole cadence ONE jitted scan (compile-once + no per-episode host
    entry compiles — the structural gate).
  * ``stragglers`` — bandwidth-scarcity sweep at a fixed round deadline:
    scaling every agent's link down must monotonically raise the round-miss
    rate (stragglers are *emergent* — payload bits / bandwidth vs deadline —
    not coin flips).

``--smoke --gate`` is the CI regression gate: asserts the >=8x int8
reduction, reward parity, monotone miss rate, and the structural scan gate,
and writes ``BENCH_fl_comm_smoke.json``.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import load_rows, save_bench, save_rows
from repro.configs.fcpo import FCPOConfig
from repro.core.agent import agent_init
from repro.core.fleet import _scan_fn, fleet_episode, fleet_init, train_fleet
from repro.data.workload import fleet_traces
from repro.fl import (CODECS, TransportConfig, agent_payload_bytes,
                      downlink_bytes, full_param_bytes)

PARITY_TOL = 0.05          # max relative final-reward drift vs float32
SMOKE_PARITY_TOL = 0.10    # short smoke runs are noisier


def _transport(codec, **kw):
    return TransportConfig(codec=codec, **kw)


def round_bytes(params_one, codec, n_agents, n_pods, topk_frac=0.05):
    """Modeled bytes of one FL round: n_sel uplinks + the downlink."""
    cfg = FCPOConfig()
    t = _transport(codec, topk_frac=topk_frac)
    up = agent_payload_bytes(params_one, t)
    full = full_param_bytes(params_one)
    n_sel = max(1, int(round(cfg.clients_per_round * n_agents)))
    return n_sel * up + downlink_bytes(t, n_agents, n_pods, up, full), up


def run_payload(n_agents=8, n_pods=1):
    cfg = FCPOConfig()
    params = agent_init(cfg, jax.random.PRNGKey(0))
    base_total, _ = round_bytes(params, "float32", n_agents, n_pods)
    rows = []
    for codec in CODECS:
        total, up = round_bytes(params, codec, n_agents, n_pods)
        rows.append({
            "name": f"fl_comm_payload_{codec}",
            "us_per_call": 0.0,
            "agents": n_agents,
            "pods": n_pods,
            "agent_uplink_bytes": up,
            "round_bytes": total,
            "reduction_vs_float32": base_total / total,
        })
    return rows


def run_parity(n_agents=8, episodes=40, tail=10, seed=0):
    """Train one fleet per codec on identical seeds/traces; compare final
    reward. The int8 run doubles as the structural scan gate."""
    cfg = FCPOConfig()
    traces = fleet_traces(jax.random.PRNGKey(seed + 1), n_agents,
                          episodes * cfg.n_steps)
    rows, finals = [], {}
    for codec in CODECS:
        t = _transport(codec)
        fleet = fleet_init(cfg, n_agents, jax.random.PRNGKey(seed))
        ep_before = fleet_episode._cache_size()
        fleet, hist = train_fleet(cfg, fleet, traces, transport=t)
        host_compiles = fleet_episode._cache_size() - ep_before
        # the compile-once rerun doubles the most expensive stage, and only
        # the int8 row is asserted by the gate — measure it there alone
        compiled_once = None
        if codec == "int8":
            size = _scan_fn(False)._cache_size()
            fleet2 = fleet_init(cfg, n_agents, jax.random.PRNGKey(seed))
            train_fleet(cfg, fleet2, traces, transport=t)
            compiled_once = _scan_fn(False)._cache_size() == size

        finals[codec] = float(np.mean(hist["reward"][-tail:]))
        fl_eps = np.flatnonzero(hist["fl_payload_bytes"])
        measured = float(hist["fl_payload_bytes"][fl_eps].mean())
        params_one = jax.tree.map(lambda x: x[0], fleet.astate.params)
        modeled, _ = round_bytes(params_one, codec, n_agents, 1)
        rows.append({
            "name": f"fl_comm_parity_{codec}",
            "us_per_call": 0.0,
            "agents": n_agents,
            "episodes": episodes,
            "final_reward": finals[codec],
            "rel_vs_float32": finals[codec] / finals["float32"] - 1.0
            if finals["float32"] else 0.0,
            "payload_bytes_per_round": measured,
            "payload_matches_model": bool(abs(measured - modeled)
                                          < 1e-6 * max(modeled, 1.0) + 1.0),
            "compiled_once": compiled_once,
            "one_jitted_scan": host_compiles == 0,
        })
    return rows


def run_stragglers(scales=(1.0, 0.5, 0.25, 0.125), deadline_s=0.02,
                   n_agents=8, episodes=12, seed=0):
    """Bandwidth-scarcity sweep: same fleet, links scaled down, fixed
    deadline — the emergent round-miss rate must rise monotonically."""
    cfg = FCPOConfig()
    traces = fleet_traces(jax.random.PRNGKey(seed + 1), n_agents,
                          episodes * cfg.n_steps)
    # the s=1.0 baseline is whatever fleet_init actually assigns, so the
    # sweep stays coupled to the links the parity fleets train over
    base_bw = np.asarray(
        fleet_init(cfg, n_agents, jax.random.PRNGKey(seed)).bandwidth)
    t = _transport("float32", deadline_s=deadline_s)
    rows = []
    for s in scales:
        fleet = fleet_init(cfg, n_agents, jax.random.PRNGKey(seed),
                           bandwidth=np.asarray(base_bw * s))
        _, hist = train_fleet(cfg, fleet, traces, transport=t)
        fl_eps = np.flatnonzero(hist["fl_payload_bytes"])
        miss = float(hist["fl_missed"][fl_eps].mean()) / n_agents
        rows.append({
            "name": f"fl_comm_stragglers_bw_x{s:g}",
            "us_per_call": 0.0,
            "agents": n_agents,
            "bandwidth_scale": s,
            "deadline_s": deadline_s,
            "miss_rate": miss,
        })
    return rows


def run(quick: bool = True, smoke: bool = False, fresh: bool = False):
    """Raw benchmark rows. ``smoke``: tiny CI shapes, never cached.
    ``fresh``: bypass the artifact cache (the gate must measure this run)."""
    if smoke:
        # payload accounting is static and instant — keep the headline A=8
        # shape; only the training runs shrink.
        return (run_payload()
                + run_parity(n_agents=4, episodes=24, tail=8)
                + run_stragglers(n_agents=4, episodes=8))
    if not fresh:
        cached = load_rows("fig_fl_comm")
        if cached:
            return cached
    rows = (run_payload()
            + run_parity(episodes=40 if quick else 100)
            + run_stragglers(episodes=12 if quick else 40))
    save_rows("fig_fl_comm", rows)
    return rows


def format_rows(rows):
    out = []
    for r in rows:
        if "reduction_vs_float32" in r:
            derived = (f"A={r['agents']} P={r['pods']} "
                       f"uplink={r['agent_uplink_bytes'] / 1024:.2f}KB "
                       f"round={r['round_bytes'] / 1024:.1f}KB "
                       f"reduction={r['reduction_vs_float32']:.1f}x")
        elif "final_reward" in r:
            derived = (f"A={r['agents']} eps={r['episodes']} "
                       f"reward={r['final_reward']:.3f} "
                       f"rel={r['rel_vs_float32'] * 100:+.1f}% "
                       f"payload/round={r['payload_bytes_per_round'] / 1024:.1f}KB "
                       f"model_match={r['payload_matches_model']} "
                       f"one_jitted_scan={r['one_jitted_scan']}")
            if r["compiled_once"] is not None:
                derived += f" compiled_once={r['compiled_once']}"
        else:
            derived = (f"A={r['agents']} bw_x{r['bandwidth_scale']:g} "
                       f"deadline={r['deadline_s'] * 1e3:.0f}ms "
                       f"miss_rate={r['miss_rate'] * 100:.0f}%")
        out.append({"name": r["name"], "us_per_call": "0",
                    "derived": derived})
    return out


def _run_and_save(quick: bool = True, smoke: bool = False,
                  fresh: bool = False):
    rows = run(quick, smoke=smoke, fresh=fresh)
    save_bench("fl_comm" + ("_smoke" if smoke else ""), rows)
    return rows


def main(quick: bool = True, smoke: bool = False):
    return format_rows(_run_and_save(quick, smoke=smoke))


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit_csv

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI regression checks")
    ap.add_argument("--gate", action="store_true",
                    help="exit nonzero unless int8 payload reduction >= 8x, "
                         "lossy-codec reward parity holds, the miss rate is "
                         "monotone in bandwidth scarcity, and the int8 run "
                         "stayed one compiled scan (always re-measures)")
    args = ap.parse_args()
    raw = _run_and_save(smoke=args.smoke, fresh=args.gate)
    emit_csv(format_rows(raw))
    if args.gate:
        by = {r["name"]: r for r in raw}
        red = by["fl_comm_payload_int8"]["reduction_vs_float32"]
        assert red >= 8.0, (
            f"int8 round payload reduction {red:.2f}x < 8x — the delta "
            f"codec or the downlink broadcast model regressed")
        assert by["fl_comm_payload_topk"]["reduction_vs_float32"] > red, (
            "top-k must compress harder than int8")
        tol = SMOKE_PARITY_TOL if args.smoke else PARITY_TOL
        for codec in ("int8", "topk"):
            rel = by[f"fl_comm_parity_{codec}"]["rel_vs_float32"]
            assert abs(rel) <= tol, (
                f"{codec} final reward drifted {rel * 100:+.1f}% from the "
                f"float32 baseline (tol {tol * 100:.0f}%) — error feedback "
                f"is no longer keeping compressed FL convergent")
            assert by[f"fl_comm_parity_{codec}"]["payload_matches_model"], (
                f"{codec} traced fl_payload_bytes disagrees with the "
                f"static accounting")
        int8_row = by["fl_comm_parity_int8"]
        assert int8_row["compiled_once"], (
            "int8-codec scan recompiled on a same-shaped rerun — the "
            "cadence is no longer one cached executable")
        assert int8_row["one_jitted_scan"], (
            "int8-codec run touched the per-episode host entry point — it "
            "must run as ONE jitted scan")
        misses = [r["miss_rate"] for r in raw
                  if r["name"].startswith("fl_comm_stragglers")]
        assert all(b >= a - 1e-9 for a, b in zip(misses, misses[1:])), (
            f"round-miss rate {misses} not monotone in bandwidth scarcity — "
            f"stragglers are no longer emergent from the uplink model")
