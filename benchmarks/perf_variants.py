"""§Perf hillclimb harness: paper-faithful baseline vs beyond-paper optimized
variants for the three chosen cells, measured with the delta method
(full-config extrapolation from 1/2-layer unrolled lowerings).

Cells (chosen per the §Perf brief):
  * qwen2-0.5b × train_4k   — most collective-bound baseline
  * qwen2-7b  × decode_32k  — most representative of the paper (serving)
  * granite-moe-3b-a800m × prefill_32k — worst roofline fraction among
    inference cells + MoE representative

Variants:
  baseline  — reference sdpa (S² materialization), repeat_kv GQA, gathered
              CE, GSPMD-chosen activation shardings, FSDP params everywhere.
  optimized — chunked (flash-style) attention, grouped GQA, vocab-sharded CE,
              pinned activation/buffer shardings, TP-only params for serving.

Usage: python -m benchmarks.perf_variants   (run under 512-dev override)
"""
import os
import sys

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "perf")

CELLS = {
    ("qwen2-0.5b", "train_4k"): dict(
        layers=(1, 2), full=24, fsdp_opt=True,
        opt=dict(shard_activations=True, ce_impl="sharded",
                 attn_impl="chunked", gqa_impl="grouped")),
    ("qwen2-7b", "decode_32k"): dict(
        layers=(1, 2), full=28, fsdp_opt=False,
        opt=dict(shard_activations=True, gqa_impl="grouped")),
    ("granite-moe-3b-a800m", "prefill_32k"): dict(
        layers=(1, 2), full=32, fsdp_opt=False,
        opt=dict(shard_activations=True, attn_impl="chunked",
                 gqa_impl="grouped")),
}

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def measure_variant(arch, shape_name, layer_points, overrides, fsdp, mesh):
    import jax
    from repro.launch.dryrun import build_cell, collective_bytes

    pts = {}
    for n in layer_points:
        ov = dict(overrides, n_layers=n)
        fn, args, in_sh, out_sh, cfg, pspecs, shape = build_cell(
            arch, shape_name, mesh, unroll=True, overrides=ov, fsdp=fsdp)
        with mesh:
            compiled = jax.jit(fn, in_shardings=in_sh,
                               out_shardings=out_sh).lower(*args).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        ma = compiled.memory_analysis()
        pts[n] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(collective_bytes(compiled.as_text())["total"]),
            "temp": float(ma.temp_size_in_bytes),
        }
    return pts


def extrapolate(pts, l0, l1, full):
    delta_w = float(full - l0)
    out = {}
    for key in ("flops", "bytes", "coll"):
        out[key] = pts[l0][key] + delta_w * (pts[l1][key] - pts[l0][key])
    out["temp"] = pts[l1]["temp"]  # peak temp is per-layer-ish (scan reuses)
    return out


def run():
    import jax
    from repro.launch.mesh import make_production_mesh

    os.makedirs(ART, exist_ok=True)
    mesh = make_production_mesh(multi_pod=False)
    for (arch, shape_name), spec in CELLS.items():
        out_path = os.path.join(ART, f"{arch}__{shape_name}.json")
        if os.path.exists(out_path):
            print(f"cached {arch} {shape_name}")
            continue
        l0, l1 = spec["layers"]
        rec = {"arch": arch, "shape": shape_name}
        for variant, ov, fsdp in (
                ("baseline", {}, True),
                ("optimized", spec["opt"], spec["fsdp_opt"])):
            pts = measure_variant(arch, shape_name, spec["layers"], ov, fsdp,
                                  mesh)
            full = extrapolate(pts, l0, l1, spec["full"])
            rec[variant] = {
                "points": pts, **full,
                "compute_s": full["flops"] / PEAK,
                "memory_s": full["bytes"] / HBM,
                "collective_s": full["coll"] / (mesh.size * ICI),
            }
            print(f"{arch} {shape_name} {variant}: "
                  f"comp={rec[variant]['compute_s']:.2e}s "
                  f"mem={rec[variant]['memory_s']:.2e}s "
                  f"coll={rec[variant]['collective_s']:.2e}s "
                  f"temp={full['temp']:.2e}B", flush=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)


def report():
    rows = []
    if not os.path.isdir(ART):
        return rows
    for fn in sorted(os.listdir(ART)):
        with open(os.path.join(ART, fn)) as f:
            r = json.load(f)
        b, o = r["baseline"], r["optimized"]
        rows.append({
            "name": f"perf_{r['arch']}_{r['shape']}",
            "baseline": b, "optimized": o,
            "speedup_dominant":
                max(b["compute_s"], b["memory_s"], b["collective_s"])
                / max(o["compute_s"], o["memory_s"], o["collective_s"]),
        })
    return rows


if __name__ == "__main__":
    run()
    for r in report():
        print(r["name"], f"dominant-term speedup {r['speedup_dominant']:.1f}x")
