"""Fig. 10: warm vs cold start on out-of-distribution workloads (AI-City-
style switch). Warm = fleet pre-trained on the original traces; cold = blank
fleet; bcedge = offline-frozen baseline on the same OOD traces."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import load_rows, save_rows
from repro.configs.fcpo import FCPOConfig
from repro.core.baselines import run_bcedge
from repro.core.fleet import fleet_init, train_fleet
from repro.data.workload import DYNAMIC, fleet_traces, ood_traces


def run(quick: bool = True, n: int = 8):
    cached = load_rows("fig10")
    if cached:
        return cached
    cfg = FCPOConfig()
    pre_eps = 150 if quick else 500
    ood_eps = 120 if quick else 300
    key = jax.random.PRNGKey(0)

    warm = fleet_init(cfg, n, key)
    warm, _ = train_fleet(cfg, warm, fleet_traces(jax.random.PRNGKey(1), n,
                                                  pre_eps * cfg.n_steps))
    ood = ood_traces(jax.random.PRNGKey(2), n, ood_eps * cfg.n_steps)

    _, h_warm = train_fleet(cfg, warm, ood)
    cold = fleet_init(cfg, n, jax.random.PRNGKey(3))
    _, h_cold = train_fleet(cfg, cold, ood)
    h_bce = run_bcedge(n, ood, key, offline_episodes=60 if quick else 150)

    rows = []
    k = max(ood_eps // 10, 5)
    for name, h in (("warm", h_warm), ("cold", h_cold), ("bcedge", h_bce)):
        rows.append({
            "name": f"fig10_{name}",
            "eff_thr_first": float(np.mean(h["effective_throughput"][:k])),
            "eff_thr_last": float(np.mean(h["effective_throughput"][-k:])),
            "reward_first": float(np.mean(h["reward"][:k])),
            "reward_last": float(np.mean(h["reward"][-k:])),
        })
    save_rows("fig10", rows)
    return rows


def main(quick: bool = True):
    return [{
        "name": r["name"], "us_per_call": "",
        "derived": (f"eff_thr {r['eff_thr_first']:.1f}->{r['eff_thr_last']:.1f} "
                    f"reward {r['reward_first']:+.2f}->{r['reward_last']:+.2f}"),
    } for r in run(quick)]


if __name__ == "__main__":
    from benchmarks.common import emit_csv
    emit_csv(main())
