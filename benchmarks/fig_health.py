"""Fleet-health benchmark: detection delay, attribution precision, overhead.

Four measurements over the health observatory (``repro.health``):

  * ``detection`` — a scripted load step (steady 15 req/interval, then a
    sustained jump to 90 at a known episode) against the in-scan drift
    detectors (CUSUM + Page-Hinkley over the standardized reward / arrival
    streams). Gates: the fleet-mean drift flag fires within
    ``DETECT_DELAY_MAX`` episodes of the change, and never fires in the
    armed window before it (no post-warmup false alarms). The same run
    streams through an ``AlertEngine`` writing ``ALERTS[_smoke].jsonl``
    (the CI artifact) — the ``drift-detected`` rule must fire.
  * ``attribution`` — the fig_chaos fault plan (A=8, 20% sign-flip
    byzantine uploads at 25x) replayed in ``fl_every``-episode chunks so
    every FL round's raw attribution snapshot (``health.susp_last`` /
    ``sel_last``) can be read back and scored against the host-side
    ground truth (``draw_fault_plan``). Gate: mean precision@k — the k
    corrupted clients of each round ranked inside the top-k suspicion
    slots among that round's selected clients — at least
    ``PRECISION_MIN``.
  * ``overhead`` — health-on vs health-off wall time on representative
    episode lengths (same ``_min_wall_us`` estimator as fig_profile).
    Gates: overhead within ``OVERHEAD_MAX``, and the health-on cadence
    stays ONE jitted scan (no per-episode host entries, same-shaped rerun
    hits the compiled executable).
  * ``identity`` — the off-mode contract: with ``health=None`` the staged
    program IS the pre-health program (the ``Fleet.health`` subtree
    flattens away), and with health ON every non-health output — shared
    metrics and every non-health fleet leaf — must stay bit-identical to
    the health-off run. Telemetry must observe, never perturb.

``--smoke --gate`` is the CI regression gate: asserts all of the above on
tiny shapes and writes ``BENCH_health_smoke.json`` (full runs write
``BENCH_health.json``). Policy in docs/observability.md.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.common import BENCH_DIR, load_rows, save_bench, save_rows
from repro.configs.fcpo import FCPOConfig
from repro.core import federated as fed
from repro.core.fleet import (_scan_fn, fleet_episode, fleet_init,
                              train_fleet_scan)
from repro.health import HealthConfig
from repro.health.alerts import AlertEngine, read_alerts
from repro.resilience import FaultConfig, GuardConfig, draw_fault_plan

# Episodes the drift flag may lag the scripted change by. The rate channel
# standardizes against the steady-state EMA, so a 15 -> 90 step is a
# clipped-z (|z| = zclip = 8) excursion and CUSUM (k=0.5, h=10) crosses in
# ceil(10 / 7.5) = 2 stride-mean samples — inside the first post-change
# episode at the default stride; the budget leaves one episode of slack
# for coarser stride/episode ratios.
DETECT_DELAY_MAX = 2
# Mean per-round precision@k of the suspicion ranking (k = number of
# corrupted selected clients that round). Sign-flip at 25x separates by
# both magnitude and direction, so the expected score is ~1.0; 0.8 tolerates
# one swapped round in five without letting ranking quality regress.
PRECISION_MIN = 0.8
# Health-on wall-time budget relative to health-off — the sketches are
# O(bins) scatter-adds per interval, far off the env+policy critical path.
OVERHEAD_MAX = 0.05
# fig_chaos's headline fault plan (the acceptance criterion names it).
BYZ_FRAC = 0.2
BYZ_SCALE = 25.0
TRIM_FRAC = 0.4


def _paired_overhead(fn_a, fn_b, iters):
    """ABBA-paired timing -> (min_us_a, min_us_b, overhead_frac).

    CI wall clocks flap in multi-second bursts larger than the budget
    being gated, so neither blocked min-of-N (all A, then all B) nor
    min(B)/min(A) over interleaved samples is stable. Back-to-back
    samples DO share their noise environment, so per-iteration ratios
    are stable even when both raw times are inflated — but a plain A,B
    pair still aliases monotone bursts onto whichever side runs second.
    Each iteration therefore times A,B,B,A and takes the ratio
    (b1+b2)/(a1+a2): a linear drift within the iteration contributes
    equally to both sums and cancels to first order. The gate uses the
    median of the iteration ratios (the mins are reported for absolute
    context only)."""
    def clock(fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        return time.perf_counter() - t0

    ta, tb, ratios = [], [], []
    for _ in range(iters):
        a1 = clock(fn_a)
        b1 = clock(fn_b)
        b2 = clock(fn_b)
        a2 = clock(fn_a)
        ta += [a1, a2]
        tb += [b1, b2]
        ratios.append((b1 + b2) / (a1 + a2))
    ratios.sort()
    return (float(min(ta) * 1e6), float(min(tb) * 1e6),
            float(ratios[len(ratios) // 2] - 1.0))


def _step_traces(n_agents, n_eps, change_ep, n_steps, lo=15.0, hi=90.0):
    """Scripted fleet-wide load step: ``lo`` req/interval for episodes
    [0, change_ep), ``hi`` after — the cleanest possible change point, so
    the gate measures the detector, not the trace generator's noise."""
    t = np.arange(n_eps * n_steps)
    rates = np.where(t < change_ep * n_steps, lo, hi).astype(np.float32)
    return np.broadcast_to(rates, (n_agents, rates.size)).copy()


def _alerts_path(smoke: bool) -> str:
    os.makedirs(BENCH_DIR, exist_ok=True)
    return os.path.join(BENCH_DIR,
                        "ALERTS" + ("_smoke" if smoke else "") + ".jsonl")


def run_detection(n_agents=4, n_eps=16, change_ep=12, seed=0,
                  alerts_path=None):
    """Scripted step change vs the drift detectors, frozen policy.

    ``learn=False`` keeps the reward stream stationary before the change
    (a learning policy's improving reward IS drift — correct to flag, but
    it would confound the false-alarm window), so the pre-change flags
    measure detector noise alone."""
    cfg = FCPOConfig()
    health = HealthConfig()
    # the detectors arm after `warmup` stride-mean samples of EMA boot
    armed_ep = -(-(health.warmup * health.stride) // cfg.n_steps)  # ceil
    traces = _step_traces(n_agents, n_eps, change_ep, cfg.n_steps)
    fleet = fleet_init(cfg, n_agents, jax.random.PRNGKey(seed),
                       health=health)
    engine = None
    if alerts_path is not None:
        engine = AlertEngine(alerts_path)
    fleet, hist = train_fleet_scan(cfg, fleet, traces, learn=False,
                                   donate=False, health=health,
                                   metrics_sink=engine)
    if engine is not None:
        engine.close()
    flags = np.asarray(hist["health_drift_flag"], dtype=np.float64)
    false_alarm_eps = [e for e in range(armed_ep, change_ep) if flags[e] > 0]
    fired = [e for e in range(change_ep, n_eps) if flags[e] > 0]
    delay = (fired[0] - change_ep) if fired else -1
    alerts = read_alerts(alerts_path) if alerts_path is not None else []
    drift_alerts = sum(1 for a in alerts if a.get("kind") == "alert"
                       and a.get("rule") == "drift-detected")
    return [{
        "name": "health_detection",
        "us_per_call": 0.0,
        "agents": n_agents, "episodes": n_eps,
        "change_ep": change_ep, "armed_ep": armed_ep,
        "detect_delay_eps": delay,
        "false_alarms": len(false_alarm_eps),
        "drift_score_final": float(np.asarray(
            hist["health_drift_score"])[-1]),
        "drift_alerts": drift_alerts,
        "alerts_path": alerts_path or "",
    }]


def run_attribution(n_agents=8, n_eps=16, seed=0):
    """fig_chaos's sign-flip plan, chunked at the FL cadence so each
    round's raw suspicion snapshot is scored against the pre-drawn ground
    truth. Chunking at ``fl_every`` keeps the chunked run identical to the
    uninterrupted one (the checkpoint-resume contract: fault and straggler
    draws are burned per ``episode_offset``)."""
    cfg = FCPOConfig()
    health = HealthConfig()
    faults = FaultConfig(byzantine_frac=BYZ_FRAC, byzantine_mode="sign_flip",
                         byzantine_scale=BYZ_SCALE, seed=seed)
    # trimmed aggregation keeps training sane under the 25x uploads (the
    # fig_chaos defense); attribution scores the wire contribs regardless
    guards = GuardConfig(agg="trimmed", trim_frac=TRIM_FRAC)
    schedule = fed.fl_schedule(cfg, n_eps)
    plan = draw_fault_plan(schedule, n_agents, 1, faults)
    from repro.data.workload import fleet_traces
    traces = np.asarray(fleet_traces(jax.random.PRNGKey(seed + 1), n_agents,
                                     n_eps * cfg.n_steps))
    fleet = fleet_init(cfg, n_agents, jax.random.PRNGKey(seed),
                       health=health)
    chunk = cfg.fl_every
    precisions, rounds_scored = [], 0
    for off in range(0, n_eps, chunk):
        tr = traces[:, off * cfg.n_steps:(off + chunk) * cfg.n_steps]
        fleet, _ = train_fleet_scan(cfg, fleet, tr, donate=False,
                                    faults=faults, guards=guards,
                                    seed=seed, episode_offset=off,
                                    total_episodes=n_eps, health=health)
        round_ep = off + chunk - 1  # the chunk's FL episode (0-indexed)
        if not schedule[round_ep]:
            continue
        sel = np.asarray(fleet.health.sel_last) > 0
        susp = np.asarray(fleet.health.susp_last, dtype=np.float64)
        byz = plan.byzantine[round_ep] & sel
        k = int(byz.sum())
        if k == 0 or k == int(sel.sum()):
            continue  # no ranking to score this round
        # top-k suspicion among the selected clients
        sel_idx = np.flatnonzero(sel)
        order = sel_idx[np.argsort(-susp[sel_idx], kind="stable")]
        topk = set(order[:k].tolist())
        precisions.append(len(topk & set(np.flatnonzero(byz))) / k)
        rounds_scored += 1
    precision = float(np.mean(precisions)) if precisions else -1.0
    return [{
        "name": "health_attribution",
        "us_per_call": 0.0,
        "agents": n_agents, "episodes": n_eps,
        "byzantine_frac": BYZ_FRAC, "byzantine_scale": BYZ_SCALE,
        "rounds_scored": rounds_scored,
        "precision_at_k": precision,
        "susp_final_max": float(np.asarray(fleet.health.susp).max()),
    }]


def run_overhead(n_agents=4, n_eps=4, n_steps=4000, iters=7, seed=0):
    """Health-on vs health-off A/B on one fleet run: wall-time overhead,
    off-mode bit-identity of every shared output, and the structural scan
    gates. ``n_steps`` is raised above the config default for the same
    reason as fig_profile's tracing arm: the overhead *fraction* only
    means something against representative episode durations."""
    cfg = FCPOConfig(n_steps=n_steps)
    health = HealthConfig()
    from repro.data.workload import fleet_traces
    traces = fleet_traces(jax.random.PRNGKey(seed + 1), n_agents,
                          n_eps * cfg.n_steps)
    fleet_off = fleet_init(cfg, n_agents, jax.random.PRNGKey(seed))
    fleet_on = fleet_init(cfg, n_agents, jax.random.PRNGKey(seed),
                          health=health)

    # donate=False so the same fleet pytrees can be replayed for timing
    run_off = lambda: train_fleet_scan(cfg, fleet_off, traces, donate=False)
    run_on = lambda: train_fleet_scan(cfg, fleet_on, traces, donate=False,
                                      health=health)
    f0, h0 = run_off()  # also the warmup/compile for each variant
    ep_before = fleet_episode._cache_size()
    f1, h1 = run_on()
    one_jitted_scan = fleet_episode._cache_size() == ep_before

    # health must observe, never perturb: every output the two runs share
    # — the health-off metrics and every non-health fleet leaf — must be
    # bit-identical (the health-on run only ADDS the health_* keys and the
    # Fleet.health subtree)
    shared_metrics = all(
        np.array_equal(np.asarray(h0[k]), np.asarray(h1[k])) for k in h0)
    off_leaves = jax.tree.leaves(f0._replace(health=None))
    on_leaves = jax.tree.leaves(f1._replace(health=None))
    shared_state = (len(off_leaves) == len(on_leaves) and all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(off_leaves, on_leaves)))

    # a same-shaped health-on rerun must hit the compiled executable
    size = _scan_fn(False)._cache_size()
    run_on()
    compiled_once = _scan_fn(False)._cache_size() == size

    us_off, us_on, overhead_frac = _paired_overhead(run_off, run_on, iters)
    return [{
        "name": "health_overhead",
        "us_per_call": us_on,
        "agents": n_agents, "episodes": n_eps, "n_steps": n_steps,
        "iters": iters,
        "us_off": us_off, "us_on": us_on,
        "overhead_frac": overhead_frac,
        "bit_identical_metrics": bool(shared_metrics),
        "bit_identical_state": bool(shared_state),
        "one_jitted_scan": bool(one_jitted_scan),
        "compiled_once": bool(compiled_once),
        "extra_health_leaves": len(jax.tree.leaves(f1))
        - len(jax.tree.leaves(f0)),
    }]


def run(quick: bool = True, smoke: bool = False, fresh: bool = False):
    """Raw benchmark rows. ``smoke``: tiny CI shapes, never cached.
    ``fresh``: bypass the artifact cache (the gate must measure this
    run)."""
    if smoke:
        return (run_detection(alerts_path=_alerts_path(True))
                + run_attribution()
                + run_overhead())
    if not fresh:
        cached = load_rows("fig_health")
        if cached:
            return cached
    rows = (run_detection(n_eps=28, change_ep=20,
                          alerts_path=_alerts_path(False))
            + run_attribution(n_eps=32)
            + run_overhead(n_steps=4000, iters=7 if quick else 11))
    save_rows("fig_health", rows)
    return rows


def format_rows(rows):
    out = []
    for r in rows:
        derived = f"A={r['agents']} eps={r['episodes']}"
        if "detect_delay_eps" in r:
            derived += (f" delay={r['detect_delay_eps']} eps "
                        f"false_alarms={r['false_alarms']} "
                        f"alerts={r['drift_alerts']}")
        if "precision_at_k" in r:
            derived += (f" precision@k={r['precision_at_k']:.2f} "
                        f"over {r['rounds_scored']} rounds")
        if "overhead_frac" in r:
            derived += (f" overhead={r['overhead_frac'] * 100:+.1f}% "
                        f"identical={r['bit_identical_metrics'] and r['bit_identical_state']} "
                        f"one_jitted_scan={r['one_jitted_scan']} "
                        f"compiled_once={r['compiled_once']}")
        out.append({"name": r["name"], "us_per_call":
                    f"{r['us_per_call']:.0f}", "derived": derived})
    return out


def _run_and_save(quick: bool = True, smoke: bool = False,
                  fresh: bool = False):
    rows = run(quick, smoke=smoke, fresh=fresh)
    save_bench("health" + ("_smoke" if smoke else ""), rows)
    return rows


def main(quick: bool = True, smoke: bool = False):
    return format_rows(_run_and_save(quick, smoke=smoke))


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit_csv

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI regression checks")
    ap.add_argument("--gate", action="store_true",
                    help="exit nonzero unless the drift flag fires within "
                         "the delay budget with no armed-window false "
                         "alarms, the suspicion ranking isolates the "
                         "byzantine clients, health-on stays within the "
                         "overhead budget as one compiled scan, and "
                         "health-off outputs stay bit-identical "
                         "(always re-measures)")
    args = ap.parse_args()
    raw = _run_and_save(smoke=args.smoke, fresh=args.gate)
    emit_csv(format_rows(raw))
    if args.gate:
        by = {r["name"]: r for r in raw}
        det = by["health_detection"]
        assert det["detect_delay_eps"] >= 0, (
            "drift detectors never flagged the scripted 15 -> 90 load step")
        assert det["detect_delay_eps"] <= DETECT_DELAY_MAX, (
            f"drift detection lagged the change by "
            f"{det['detect_delay_eps']} episodes "
            f"(budget {DETECT_DELAY_MAX})")
        assert det["false_alarms"] == 0, (
            f"drift flag fired {det['false_alarms']} time(s) in the armed "
            f"pre-change window — the detectors are alarming on a "
            f"stationary stream")
        assert det["drift_alerts"] >= 1, (
            "the drift-detected alert rule never fired on a detected "
            "change — the AlertEngine tee is not seeing the health metrics")
        att = by["health_attribution"]
        assert att["rounds_scored"] > 0, (
            "no FL round had a scoreable byzantine/honest split — the "
            "fault plan is not injecting")
        assert att["precision_at_k"] >= PRECISION_MIN, (
            f"suspicion ranking no longer isolates the sign-flip clients: "
            f"precision@k {att['precision_at_k']:.2f} over "
            f"{att['rounds_scored']} rounds (min {PRECISION_MIN})")
        ov = by["health_overhead"]
        assert ov["bit_identical_metrics"] and ov["bit_identical_state"], (
            "health-on run perturbed a shared output — telemetry must "
            "observe, never steer (bit-identity contract)")
        assert ov["one_jitted_scan"], (
            "health-on run touched the per-episode host entry point — the "
            "sketches must stay inside the ONE jitted scan")
        assert ov["compiled_once"], (
            "health-on scan recompiled on a same-shaped rerun")
        assert ov["overhead_frac"] <= OVERHEAD_MAX, (
            f"health overhead {ov['overhead_frac'] * 100:.1f}% exceeds "
            f"the {OVERHEAD_MAX * 100:.0f}% budget")
        print("health gate: pass")
