"""Fig. 14 grown into the fleet scaling benchmark (``BENCH_frl_scaling``).

Four measurement families, one envelope:

  * ``fig14_*`` — the original figure: convergence speed vs number of
    federated pipelines, plus the driver A/B (reference Python loop vs the
    ONE-dispatch scanned driver).
  * ``scaling_weak_a<A>`` — weak scaling: fleet size A grows with fixed
    agents-per-device on the ('pod', 'data') fleet mesh (simulate devices
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``); the
    curve is per-agent step time, which must stay within
    ``WEAK_FLATNESS_MAX`` of flat.
  * ``scaling_strong_d<D>`` — strong scaling: fixed A over 1 -> 8 devices
    (1 device = no mesh, the exact legacy program).
  * ``scaling_mem_* / scaling_state_*`` — memory curves per state policy
    (``repro.core.dtypes``): XLA peak estimate + donation audit of the
    exact donated scan (``obs.profile.fleet_memory_report``), and the
    A=2048 resident-state accounting the lean-state gate reads — the lean
    policy must cut stored bytes/agent by >= ``LEAN_STATE_RATIO_MIN`` vs
    all-float32. (XLA ``peak_bytes`` shrinks less — the compute still runs
    in float32, so dequantized temporaries ride the scratch arena; the
    resident fleet state is what bounds agents-per-device, and is gated.)
  * ``scaling_parity_*`` — reward parity: the lean fleet must train to the
    same reward as float32 within ``PARITY_TOL``.

``--smoke --gate`` is the CI step: tiny shapes, assertions on flatness /
donation / lean ratio / parity, envelope ``BENCH_frl_scaling_smoke.json``.
A full run (no ``--smoke``) writes ``BENCH_frl_scaling.json`` with
``prev_*``/``delta_*`` regression fields against the previous envelope.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import load_bench, load_rows, save_bench, save_rows
from repro.configs.fcpo import FCPOConfig
from repro.core import federated as fed
from repro.core.fleet import (fleet_init, fleet_state_bytes, train_fleet,
                              train_fleet_reference, train_fleet_scan)
from repro.data.workload import fleet_traces
from repro.launch.mesh import make_fleet_mesh

WEAK_FLATNESS_MAX = 1.5     # max/min per-agent step time across the A sweep
LEAN_STATE_RATIO_MIN = 2.0  # f32/lean stored bytes per agent at A=2048
PARITY_TOL = 0.05           # |final reward(lean) - final reward(f32)|
STATE_GATE_AGENTS = 2048    # the fleet size the lean-state gate measures at

DELTA_METRICS = ("wall_warm_s", "step_time_per_agent_s", "peak_bytes",
                 "state_per_agent")


def _converge_episode(curve, frac=0.9):
    """First episode reaching ``frac`` of the final plateau improvement."""
    curve = np.asarray(curve)
    k = max(len(curve) // 10, 2)
    start, end = curve[:k].mean(), curve[-k:].mean()
    if end <= start:
        return len(curve)
    thresh = start + frac * (end - start)
    smooth = np.convolve(curve, np.ones(k) / k, mode="valid")
    hits = np.where(smooth >= thresh)[0]
    return int(hits[0]) if len(hits) else len(curve)


def _dispatch_counts(cfg, n_eps, n_pods, n_metrics):
    """Host work per driver, by construction of the two loops: the reference
    issues one ``fleet_episode`` dispatch per episode, one ``fl_round`` per
    scheduled round, one ``pod_merge`` per hierarchical period, and one
    blocking ``np.asarray`` per (episode x metric); the scanned driver issues
    exactly one dispatch and one bulk history fetch."""
    rounds = int(fed.fl_schedule(cfg, n_eps).sum())
    merges = rounds // cfg.hierarchical_period if n_pods > 1 else 0
    return {"reference": {"dispatches": n_eps + rounds + merges,
                          "host_syncs": n_eps * n_metrics},
            "scan": {"dispatches": 1, "host_syncs": 1}}


def run_driver_ab(episodes=100, n=8, n_pods=2):
    """Old-loop vs scanned-loop wall clock (cold incl. compile, then warm)
    and dispatch counts, same fleet/traces/seeds."""
    cfg = FCPOConfig()
    traces = fleet_traces(jax.random.PRNGKey(1), n, episodes * cfg.n_steps)
    runners = {
        "reference": lambda f: train_fleet_reference(cfg, f, traces, seed=7),
        "scan": lambda f: train_fleet_scan(cfg, f, traces, seed=7,
                                           donate=False),
    }
    rows = []
    hists = {}
    for name, fn in runners.items():
        walls = []
        for _ in range(2):  # cold (compile) then warm
            fleet = fleet_init(cfg, n, jax.random.PRNGKey(0), n_pods=n_pods)
            t0 = time.time()
            _, hists[name] = fn(fleet)
            walls.append(time.time() - t0)
        counts = _dispatch_counts(cfg, episodes, n_pods,
                                  len(hists[name]))[name]
        rows.append({"name": f"fig14_driver_{name}", "pipelines": n,
                     "wall_cold_s": walls[0], "wall_warm_s": walls[1],
                     **counts})
    drift = max(float(np.max(np.abs(hists["scan"][k] - hists["reference"][k])))
                for k in hists["scan"])
    for r in rows:
        r["metric_drift_vs_ref"] = drift
    return rows


# ---------------------------------------------------------------------------
# Scaling: weak / strong / memory / parity
# ---------------------------------------------------------------------------
def _fleet_mesh(devices: int, n_pods: int):
    """The scaling mesh for ``devices`` of the visible device pool; 1 device
    means no mesh at all — the exact single-device legacy program."""
    if devices <= 1:
        return None
    return make_fleet_mesh(devices, n_pods)


def _time_scan(cfg, agents, n_pods, episodes, mesh, state_policy=None,
               seed=0):
    """(cold, warm) wall clock of the scanned driver at this shape, fresh
    fleet per run (no donation — CPU can't honor it and timing must not
    depend on it)."""
    traces = fleet_traces(jax.random.PRNGKey(1), agents,
                          episodes * cfg.n_steps)
    walls = []
    for _ in range(2):
        fleet = fleet_init(cfg, agents, jax.random.PRNGKey(seed),
                           n_pods=n_pods, mesh=mesh,
                           state_policy=state_policy)
        t0 = time.time()
        out, _ = train_fleet_scan(cfg, fleet, traces, mesh=mesh, seed=7,
                                  donate=False)
        jax.block_until_ready(out)
        walls.append(time.time() - t0)
    return walls


def run_weak_scaling(agents=(256, 512, 1024, 2048), episodes=2, n_pods=2,
                     devices=None):
    """Per-agent step time as A grows at fixed agents-per-device (the mesh
    spans every visible device). On one physical host the compute is
    serialized, so the meaningful curve is wall/A — flat means the meshed
    program adds no super-linear collective/resharding cost with scale."""
    cfg = FCPOConfig()
    d = jax.device_count() if devices is None else devices
    mesh = _fleet_mesh(d, n_pods)
    rows = []
    for a in agents:
        cold, warm = _time_scan(cfg, a, n_pods, episodes, mesh)
        step = warm / episodes
        rows.append({"name": f"scaling_weak_a{a}", "agents": a,
                     "devices": d, "pods": n_pods, "episodes": episodes,
                     "agents_per_device": a / d,
                     "wall_cold_s": cold, "wall_warm_s": warm,
                     "step_time_s": step,
                     "step_time_per_agent_s": step / a})
    return rows


def run_strong_scaling(agents=256, device_counts=(1, 2, 4, 8), episodes=2,
                       n_pods=2):
    """Fixed A over growing mesh sizes. 1 device traces the exact legacy
    single-device program, so the d=1 row doubles as the no-mesh baseline
    the meshed rows are compared against."""
    cfg = FCPOConfig()
    avail = jax.device_count()
    rows = []
    for d in (x for x in device_counts if x <= avail):
        mesh = _fleet_mesh(d, n_pods if d % max(n_pods, 1) == 0 else 1)
        cold, warm = _time_scan(cfg, agents, n_pods, episodes, mesh)
        step = warm / episodes
        rows.append({"name": f"scaling_strong_d{d}", "agents": agents,
                     "devices": d, "pods": n_pods, "episodes": episodes,
                     "wall_cold_s": cold, "wall_warm_s": warm,
                     "step_time_s": step,
                     "step_time_per_agent_s": step / agents})
    return rows


def run_memory(agents=2048, n_pods=8, policies=("float32", "bf16", "lean")):
    """Compiled peak-memory + donation audit per state policy at ``agents``
    shapes: the exact donated scan, lowered and compiled
    (``obs.profile.fleet_memory_report``)."""
    from repro.obs.profile import fleet_memory_report
    cfg = FCPOConfig()
    report = fleet_memory_report(cfg, agents, n_pods=n_pods, n_episodes=2,
                                 state_policies=policies)
    return [{"name": f"scaling_mem_{pol}_a{agents}", "agents": agents,
             "policy": pol, **r} for pol, r in report.items()]


def run_state_accounting(agents=STATE_GATE_AGENTS, n_pods=8,
                         policies=("float32", "bf16", "lean")):
    """Stored-state bytes per agent at the gate shape — pure host-side
    accounting from shapes/dtypes (no compile), so it runs at A=2048 even
    in smoke mode. This is the row the lean-state gate reads."""
    cfg = FCPOConfig()
    rows = []
    for pol in policies:
        fleet = fleet_init(cfg, agents, jax.random.PRNGKey(0),
                           n_pods=n_pods, state_policy=pol)
        sb = fleet_state_bytes(fleet)
        rows.append({"name": f"scaling_state_{pol}_a{agents}",
                     "agents": agents, "policy": pol,
                     **{f"state_{k}": v for k, v in sb.items()},
                     "state_per_agent": sb["per_agent"]})
    return rows


def run_parity(agents=16, episodes=40, n_pods=2):
    """Final reward, float32 vs lean storage, same seeds/traces: the lean
    policy stores low-precision but computes in float32, so the learning
    outcome must match within ``PARITY_TOL``."""
    cfg = FCPOConfig()
    traces = fleet_traces(jax.random.PRNGKey(1), agents,
                          episodes * cfg.n_steps)
    tail = max(episodes // 5, 2)
    rows, finals = [], {}
    for pol in ("float32", "lean"):
        fleet = fleet_init(cfg, agents, jax.random.PRNGKey(0),
                           n_pods=n_pods, state_policy=pol)
        _, h = train_fleet_scan(cfg, fleet, traces, seed=7, donate=False)
        finals[pol] = float(np.mean(h["reward"][-tail:]))
        rows.append({"name": f"scaling_parity_{pol}", "agents": agents,
                     "episodes": episodes, "policy": pol,
                     "reward_final": finals[pol]})
    gap = abs(finals["lean"] - finals["float32"])
    for r in rows:
        r["parity_gap"] = gap
    return rows


def run_scaling(smoke: bool = False):
    """All scaling rows. ``smoke``: tiny fleet/compile shapes for CI — the
    A=2048 state-accounting rows still run (no compile there), so the lean
    gate always measures the real gate shape."""
    if smoke:
        rows = run_weak_scaling(agents=(16, 32), episodes=2)
        rows += run_strong_scaling(agents=16, device_counts=(1, 8))
        rows += run_memory(agents=32, n_pods=8)
        rows += run_parity(agents=4, episodes=12)
    else:
        rows = run_weak_scaling()
        rows += run_strong_scaling()
        rows += run_memory()
        rows += run_parity()
    rows += run_state_accounting()
    return rows


def run(quick: bool = True):
    """The original figure rows (cached as ``fig14``): convergence vs
    pipelines + the driver A/B."""
    cached = load_rows("fig14")
    if cached:
        return cached
    episodes = 250 if quick else 600
    rows = run_driver_ab(episodes=min(episodes, 100))
    for n in (1, 2, 4, 8, 16):
        cfg = FCPOConfig()
        key = jax.random.PRNGKey(0)
        traces = fleet_traces(jax.random.PRNGKey(1), n, episodes * cfg.n_steps)
        fleet = fleet_init(cfg, n, key)
        _, h = train_fleet(cfg, fleet, traces, federated=(n > 1))
        curve = h["reward"]
        tail = max(episodes // 5, 5)
        rows.append({
            "name": f"fig14_pipelines{n}",
            "pipelines": n,
            "reward_final": float(np.mean(curve[-tail:])),
            "converge_episode": _converge_episode(curve),
            "reward_std_tail": float(np.std(curve[-tail:])),
        })
    save_rows("fig14", rows)
    return rows


def attach_prev(rows, prev_envelope):
    """Attach ``prev_<metric>`` / ``delta_<metric>`` fields from the
    previous envelope's same-named rows (None envelope: no-op)."""
    if not prev_envelope:
        return rows
    by_name = {r.get("name"): r for r in prev_envelope.get("results", [])
               if isinstance(r, dict)}
    for r in rows:
        p = by_name.get(r.get("name"))
        if not p:
            continue
        for m in DELTA_METRICS:
            try:
                prev, new = float(p[m]), float(r[m])
            except (KeyError, TypeError, ValueError):
                continue
            r[f"prev_{m}"] = prev
            r[f"delta_{m}"] = new - prev
    return rows


def check_gates(rows):
    """The CI assertions (``--gate``). Raises AssertionError on the first
    violated gate; returns the gate report dict otherwise."""
    report = {}
    weak = sorted((r for r in rows if r["name"].startswith("scaling_weak_")),
                  key=lambda r: r["agents"])
    if len(weak) >= 2:
        per = [r["step_time_per_agent_s"] for r in weak]
        # degradation-only: the failure mode is per-agent time GROWING with
        # fleet size (super-linear collective/resharding cost); small fleets
        # amortizing their fixed per-episode overhead away is healthy
        report["weak_flatness"] = per[-1] / max(min(per), 1e-12)
        assert report["weak_flatness"] <= WEAK_FLATNESS_MAX, (
            f"weak scaling is not flat: per-agent step time at A="
            f"{weak[-1]['agents']} is {report['weak_flatness']:.2f}x the "
            f"best point of the sweep A={[r['agents'] for r in weak]} "
            f"(budget {WEAK_FLATNESS_MAX}x) — a collective or resharding "
            f"cost is growing super-linearly with fleet size")
    mem = [r for r in rows if r["name"].startswith("scaling_mem_")]
    for r in mem:
        assert r.get("donation_ok"), (
            f"donation audit failed at {r['name']}: "
            f"{r.get('aliased_args', 0):.0f} aliased outputs for "
            f"{r.get('donated_leaves', 0):.0f} donated fleet leaves — "
            f"peak training memory roughly doubles at A={r['agents']}")
    state = {r["policy"]: r for r in rows
             if r["name"].startswith("scaling_state_")}
    if "float32" in state and "lean" in state:
        report["lean_state_ratio"] = (state["float32"]["state_per_agent"]
                                      / state["lean"]["state_per_agent"])
        assert report["lean_state_ratio"] >= LEAN_STATE_RATIO_MIN, (
            f"lean state policy saves only "
            f"{report['lean_state_ratio']:.2f}x stored bytes/agent at "
            f"A={STATE_GATE_AGENTS} (gate {LEAN_STATE_RATIO_MIN}x) — a "
            f"state family fell back to float32 storage")
    parity = [r for r in rows if r["name"].startswith("scaling_parity_")]
    if parity:
        report["parity_gap"] = parity[0]["parity_gap"]
        assert report["parity_gap"] <= PARITY_TOL, (
            f"lean-state reward diverged from float32 by "
            f"{report['parity_gap']:.3f} (tol {PARITY_TOL}) — low-precision "
            f"storage is leaking into the math")
    return report


def format_rows(rows):
    out = []
    for r in rows:
        name = r["name"]
        if name.startswith(("scaling_weak_", "scaling_strong_")):
            us = r["step_time_per_agent_s"] * 1e6
            derived = (f"A={r['agents']} d={r['devices']} "
                       f"step={r['step_time_s'] * 1e3:.1f}ms "
                       f"per_agent={us:.1f}us "
                       f"warm={r['wall_warm_s']:.2f}s")
            if "delta_step_time_per_agent_s" in r:
                derived += (f" dper_agent="
                            f"{r['delta_step_time_per_agent_s'] * 1e6:+.1f}us")
            out.append({"name": name, "us_per_call": f"{us:.1f}",
                        "derived": derived})
        elif name.startswith("scaling_mem_"):
            derived = (f"A={r['agents']} peak={r['peak_bytes'] / 1e6:.1f}MB "
                       f"state/agent={r['state_per_agent'] / 1024:.1f}KB "
                       f"donation_ok={bool(r['donation_ok'])}")
            out.append({"name": name, "us_per_call": "", "derived": derived})
        elif name.startswith("scaling_state_"):
            out.append({"name": name, "us_per_call": "",
                        "derived": (f"A={r['agents']} state/agent="
                                    f"{r['state_per_agent'] / 1024:.1f}KB")})
        elif name.startswith("scaling_parity_"):
            out.append({"name": name, "us_per_call": "",
                        "derived": (f"final={r['reward_final']:+.3f} "
                                    f"gap={r['parity_gap']:.4f}")})
        elif "wall_warm_s" in r:
            out.append({
                "name": name,
                "us_per_call": f"{r['wall_warm_s'] * 1e6:.0f}",
                "derived": (f"warm={r['wall_warm_s']:.2f}s "
                            f"cold={r['wall_cold_s']:.2f}s "
                            f"dispatches={r['dispatches']} "
                            f"host_syncs={r['host_syncs']} "
                            f"drift={r['metric_drift_vs_ref']:.1e}"),
            })
        else:
            out.append({
                "name": name, "us_per_call": "",
                "derived": (f"final={r['reward_final']:+.3f} "
                            f"converge@{r['converge_episode']}ep "
                            f"std={r['reward_std_tail']:.3f}"),
            })
    return out


def _run_and_save(quick: bool = True, smoke: bool = False,
                  with_legacy: bool = True):
    from repro.eval.leaderboard import sanitize_envelope
    name = "frl_scaling" + ("_smoke" if smoke else "")
    rows = run_scaling(smoke=smoke)
    if with_legacy:
        rows = run(quick) + rows
    prev = sanitize_envelope(load_bench(name), warn=print)
    attach_prev(rows, prev)
    save_bench(name, rows)
    return rows


def main(quick: bool = True, smoke: bool = None):
    # run.py quick mode uses smoke-sized scaling rows (and the smoke
    # envelope, so the full benchmark's regression baseline is not
    # clobbered by tiny shapes); --full measures the real curves
    smoke = quick if smoke is None else smoke
    return format_rows(_run_and_save(quick, smoke=smoke))


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit_csv

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (envelope "
                         "BENCH_frl_scaling_smoke.json); the lean-state "
                         "gate still measures the real A=2048 accounting")
    ap.add_argument("--gate", action="store_true",
                    help="exit nonzero unless weak scaling is within "
                         f"{WEAK_FLATNESS_MAX}x of flat, every donation "
                         "audit passes, the lean policy saves >= "
                         f"{LEAN_STATE_RATIO_MIN}x stored bytes/agent at "
                         f"A={STATE_GATE_AGENTS}, and lean reward matches "
                         f"float32 within {PARITY_TOL}")
    ap.add_argument("--no-legacy", action="store_true",
                    help="skip the original fig14 convergence/driver rows "
                         "(scaling rows only)")
    args = ap.parse_args()
    raw = _run_and_save(smoke=args.smoke, with_legacy=not args.no_legacy)
    emit_csv(format_rows(raw))
    if args.gate:
        report = check_gates(raw)
        print("gates passed:", " ".join(
            f"{k}={v:.3f}" for k, v in sorted(report.items())))
