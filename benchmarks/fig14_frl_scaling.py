"""Fig. 14: convergence speed vs number of federated pipelines (1 disables
aggregation; more agents -> faster, smoother convergence, diminishing
returns)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import load_rows, save_rows
from repro.configs.fcpo import FCPOConfig
from repro.core.fleet import fleet_init, train_fleet
from repro.data.workload import fleet_traces


def _converge_episode(curve, frac=0.9):
    """First episode reaching ``frac`` of the final plateau improvement."""
    curve = np.asarray(curve)
    k = max(len(curve) // 10, 2)
    start, end = curve[:k].mean(), curve[-k:].mean()
    if end <= start:
        return len(curve)
    thresh = start + frac * (end - start)
    smooth = np.convolve(curve, np.ones(k) / k, mode="valid")
    hits = np.where(smooth >= thresh)[0]
    return int(hits[0]) if len(hits) else len(curve)


def run(quick: bool = True):
    cached = load_rows("fig14")
    if cached:
        return cached
    episodes = 250 if quick else 600
    rows = []
    for n in (1, 2, 4, 8, 16):
        cfg = FCPOConfig()
        key = jax.random.PRNGKey(0)
        traces = fleet_traces(jax.random.PRNGKey(1), n, episodes * cfg.n_steps)
        fleet = fleet_init(cfg, n, key)
        _, h = train_fleet(cfg, fleet, traces, federated=(n > 1))
        curve = h["reward"]
        tail = max(episodes // 5, 5)
        rows.append({
            "name": f"fig14_pipelines{n}",
            "pipelines": n,
            "reward_final": float(np.mean(curve[-tail:])),
            "converge_episode": _converge_episode(curve),
            "reward_std_tail": float(np.std(curve[-tail:])),
        })
    save_rows("fig14", rows)
    return rows


def main(quick: bool = True):
    return [{
        "name": r["name"], "us_per_call": "",
        "derived": (f"final={r['reward_final']:+.3f} "
                    f"converge@{r['converge_episode']}ep "
                    f"std={r['reward_std_tail']:.3f}"),
    } for r in run(quick)]


if __name__ == "__main__":
    from benchmarks.common import emit_csv
    emit_csv(main())
