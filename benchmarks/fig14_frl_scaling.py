"""Fig. 14: convergence speed vs number of federated pipelines (1 disables
aggregation; more agents -> faster, smoother convergence, diminishing
returns) — plus the driver A/B: the reference Python loop (one dispatch per
episode + per-metric host syncs) against the scanned driver (the entire
episodes -> FL round -> pod-merge cadence compiled into ONE program)."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import load_rows, save_bench, save_rows
from repro.configs.fcpo import FCPOConfig
from repro.core import federated as fed
from repro.core.fleet import (fleet_init, train_fleet, train_fleet_reference,
                              train_fleet_scan)
from repro.data.workload import fleet_traces


def _converge_episode(curve, frac=0.9):
    """First episode reaching ``frac`` of the final plateau improvement."""
    curve = np.asarray(curve)
    k = max(len(curve) // 10, 2)
    start, end = curve[:k].mean(), curve[-k:].mean()
    if end <= start:
        return len(curve)
    thresh = start + frac * (end - start)
    smooth = np.convolve(curve, np.ones(k) / k, mode="valid")
    hits = np.where(smooth >= thresh)[0]
    return int(hits[0]) if len(hits) else len(curve)


def _dispatch_counts(cfg, n_eps, n_pods, n_metrics):
    """Host work per driver, by construction of the two loops: the reference
    issues one ``fleet_episode`` dispatch per episode, one ``fl_round`` per
    scheduled round, one ``pod_merge`` per hierarchical period, and one
    blocking ``np.asarray`` per (episode x metric); the scanned driver issues
    exactly one dispatch and one bulk history fetch."""
    rounds = int(fed.fl_schedule(cfg, n_eps).sum())
    merges = rounds // cfg.hierarchical_period if n_pods > 1 else 0
    return {"reference": {"dispatches": n_eps + rounds + merges,
                          "host_syncs": n_eps * n_metrics},
            "scan": {"dispatches": 1, "host_syncs": 1}}


def run_driver_ab(episodes=100, n=8, n_pods=2):
    """Old-loop vs scanned-loop wall clock (cold incl. compile, then warm)
    and dispatch counts, same fleet/traces/seeds."""
    cfg = FCPOConfig()
    traces = fleet_traces(jax.random.PRNGKey(1), n, episodes * cfg.n_steps)
    runners = {
        "reference": lambda f: train_fleet_reference(cfg, f, traces, seed=7),
        "scan": lambda f: train_fleet_scan(cfg, f, traces, seed=7,
                                           donate=False),
    }
    rows = []
    hists = {}
    for name, fn in runners.items():
        walls = []
        for _ in range(2):  # cold (compile) then warm
            fleet = fleet_init(cfg, n, jax.random.PRNGKey(0), n_pods=n_pods)
            t0 = time.time()
            _, hists[name] = fn(fleet)
            walls.append(time.time() - t0)
        counts = _dispatch_counts(cfg, episodes, n_pods,
                                  len(hists[name]))[name]
        rows.append({"name": f"fig14_driver_{name}", "pipelines": n,
                     "wall_cold_s": walls[0], "wall_warm_s": walls[1],
                     **counts})
    drift = max(float(np.max(np.abs(hists["scan"][k] - hists["reference"][k])))
                for k in hists["scan"])
    for r in rows:
        r["metric_drift_vs_ref"] = drift
    return rows


def run(quick: bool = True):
    cached = load_rows("fig14")
    if cached:
        return cached
    episodes = 250 if quick else 600
    rows = run_driver_ab(episodes=min(episodes, 100))
    for n in (1, 2, 4, 8, 16):
        cfg = FCPOConfig()
        key = jax.random.PRNGKey(0)
        traces = fleet_traces(jax.random.PRNGKey(1), n, episodes * cfg.n_steps)
        fleet = fleet_init(cfg, n, key)
        _, h = train_fleet(cfg, fleet, traces, federated=(n > 1))
        curve = h["reward"]
        tail = max(episodes // 5, 5)
        rows.append({
            "name": f"fig14_pipelines{n}",
            "pipelines": n,
            "reward_final": float(np.mean(curve[-tail:])),
            "converge_episode": _converge_episode(curve),
            "reward_std_tail": float(np.std(curve[-tail:])),
        })
    save_rows("fig14", rows)
    return rows


def main(quick: bool = True):
    rows = run(quick)
    save_bench("fig14_frl_scaling", rows)
    out = []
    for r in rows:
        if "wall_warm_s" in r:
            out.append({
                "name": r["name"],
                "us_per_call": f"{r['wall_warm_s'] * 1e6:.0f}",
                "derived": (f"warm={r['wall_warm_s']:.2f}s "
                            f"cold={r['wall_cold_s']:.2f}s "
                            f"dispatches={r['dispatches']} "
                            f"host_syncs={r['host_syncs']} "
                            f"drift={r['metric_drift_vs_ref']:.1e}"),
            })
        else:
            out.append({
                "name": r["name"], "us_per_call": "",
                "derived": (f"final={r['reward_final']:+.3f} "
                            f"converge@{r['converge_episode']}ep "
                            f"std={r['reward_std_tail']:.3f}"),
            })
    return out


if __name__ == "__main__":
    from benchmarks.common import emit_csv
    emit_csv(main(quick=True))
