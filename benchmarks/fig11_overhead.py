"""Fig. 11: per-agent overheads — memory, decision latency, update latency —
FCPO iAgent vs the BCEdge-style bulky agent (measured on this host)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import load_rows, save_rows, time_call
from repro.configs.fcpo import FCPOConfig
from repro.core.agent import agent_init, full_mask, param_bytes, sample_actions
from repro.core.baselines import bcedge_config
from repro.core.buffer import buffer_init, buffer_memory_bytes
from repro.core.ppo import agent_opt_init, agent_update, Rollout


def _rollout(cfg, key):
    ks = jax.random.split(key, 4)
    t = cfg.n_steps
    return Rollout(
        states=jax.random.normal(ks[0], (t, cfg.state_dim)),
        actions=jnp.stack([jax.random.randint(ks[1], (t,), 0, cfg.n_res),
                           jax.random.randint(ks[2], (t,), 0, cfg.n_bs),
                           jax.random.randint(ks[3], (t,), 0, cfg.n_mt)], -1),
        logp_old=-jnp.ones((t,)),
        rewards=jnp.zeros((t,)),
        values_old=jnp.zeros((t,)),
    )


def run(quick: bool = True):
    cached = load_rows("fig11")
    if cached:
        return cached
    rows = []
    key = jax.random.PRNGKey(0)
    for name, cfg in (("fcpo", FCPOConfig(loss_gate=0.0)),
                      ("bcedge", bcedge_config()._replace() if False
                       else bcedge_config())):
        params = agent_init(cfg, key)
        opt = agent_opt_init(params)
        mask = full_mask(cfg)
        state = jax.random.normal(key, (cfg.state_dim,))
        decide = jax.jit(lambda p, s, k: sample_actions(cfg, p, s, mask, k)[0])
        dec_us = time_call(decide, params, state, key, iters=30)
        roll = _rollout(cfg, key)
        upd = jax.jit(lambda p, o: agent_update(cfg, p, o, roll, mask)[:2])
        upd_us = time_call(upd, params, opt, iters=10)
        mem = param_bytes(params) + buffer_memory_bytes(cfg)
        if name == "bcedge":
            # offline replay: 7000 experiences x (8 state + 3 act + misc) fp32
            mem += 7000 * (cfg.state_dim + 8) * 4
        rows.append({
            "name": f"fig11_{name}",
            "param_kb": param_bytes(params) / 1024,
            "total_mem_kb": mem / 1024,
            "decision_us": dec_us,
            "update_us": upd_us,
        })
    # derived ratios (paper: up to 10x memory, 1.5-2x decision latency)
    f, b = rows[0], rows[1]
    rows.append({
        "name": "fig11_ratios",
        "mem_ratio": b["total_mem_kb"] / f["total_mem_kb"],
        "decision_ratio": b["decision_us"] / f["decision_us"],
        "update_ratio": b["update_us"] / f["update_us"],
    })
    save_rows("fig11", rows)
    return rows


def main(quick: bool = True):
    out = []
    for r in run(quick):
        if r["name"] == "fig11_ratios":
            out.append({"name": r["name"], "us_per_call": "",
                        "derived": (f"bcedge/fcpo mem={r['mem_ratio']:.1f}x "
                                    f"decision={r['decision_ratio']:.2f}x")})
        else:
            out.append({"name": r["name"],
                        "us_per_call": f"{r['decision_us']:.0f}",
                        "derived": (f"mem={r['total_mem_kb']:.0f}KB "
                                    f"update={r['update_us']:.0f}us")})
    return out


if __name__ == "__main__":
    from benchmarks.common import emit_csv
    emit_csv(main())
