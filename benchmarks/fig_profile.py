"""Flight-recorder benchmark: XLA accounting + tracing-overhead gates.

Three measurements, one envelope (``BENCH_profile.json``):

  * ``static`` — cost/memory accounting of the EXACT compiled programs the
    training path runs: ``obs.profile.profile_fleet_scan`` lowers the same
    ``_scan_fn`` the driver dispatches (donation included) and reads XLA's
    ``cost_analysis``/``memory_analysis``; ``profile_kernels`` does the
    same for every kernel jit in ``kernels.ops.KERNEL_JITS`` at its
    canonical workload shape. The donation audit (every fleet leaf wired
    to an aliased output in the stablehlo) is a ``--gate`` assertion — a
    refactor that silently drops donation doubles training peak memory.
  * ``tracing`` — the flight recorder's two contracts, measured:
    (a) *off = free*: with no tracer the program is the pre-observability
    one; (b) *on = cheap and bit-identical*: a traced run must produce a
    bit-identical fleet + history (span callbacks never feed numerics) at
    <= ``MAX_OVERHEAD_FRAC`` warm wall-clock overhead at default sampling,
    and attaching a different tracer or sampling rate must NOT recompile
    (trace-id and sample period are operands, not statics — the jit-cache
    delta is asserted zero).
  * the Chrome trace written by the traced run must validate against the
    trace-event schema (``obs.validate_chrome_trace``) — the file is
    exported next to the envelope (``trace_profile*.json``) and uploaded
    as a CI artifact, so every push leaves an openable Perfetto timeline.

Deltas: ``flops`` / ``bytes_accessed`` / ``peak_bytes`` against the
previous envelope at the same path are attached as ``prev_*`` fields
(cross-backend baselines are refused via the leaderboard's
``sanitize_envelope`` — a CPU-vs-TPU memory diff is noise, not signal).
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.common import (BENCH_DIR, load_bench, load_rows, save_bench,
                               save_rows)
from repro.configs.fcpo import FCPOConfig
from repro.core.fleet import _scan_fn, fleet_init, train_fleet_scan
from repro.obs import Tracer, validate_chrome_trace
from repro.obs.profile import profile_fleet_scan, profile_kernels
from repro.sim import make_scenario

# Warm wall-clock overhead budget for tracing ON at default sampling
# (span_sample_every=1, kernel spans off) vs the identical untraced run.
MAX_OVERHEAD_FRAC = 0.05

DELTA_METRICS = ("flops", "bytes_accessed", "peak_bytes")


def run_static(n_agents=8, episodes=4, seed=0):
    """Cost/memory rows for the scanned fleet driver + every kernel jit."""
    cfg = FCPOConfig()
    fleet = fleet_init(cfg, n_agents, jax.random.PRNGKey(seed))
    traces = make_scenario("steady", jax.random.PRNGKey(seed + 1), n_agents,
                           episodes * cfg.n_steps)
    stats = profile_fleet_scan(cfg, fleet, traces, donate=True)
    rows = [{"name": "profile_fleet_scan", "us_per_call": 0.0,
             "agents": n_agents, "episodes": episodes, **stats}]
    for kname, ks in sorted(profile_kernels().items()):
        rows.append({"name": f"profile_kernel_{kname}",
                     "us_per_call": 0.0, **ks})
    return rows


def _min_wall_us(fn, iters):
    """Min wall time per call in microseconds (the robust estimator for an
    overhead *ratio* gate — medians of small samples flap on CI noise)."""
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(min(ts) * 1e6)


def run_tracing(n_agents=8, episodes=4, n_steps=3000, iters=5, seed=0,
                trace_path=None):
    """Traced-vs-untraced A/B on one fleet run: bit-identity, jit-cache
    stability across tracer/sampling changes, warm overhead, and the
    Chrome-trace schema check. ``trace_path``: where to export the traced
    run's timeline (None: don't write).

    ``n_steps`` is raised well above the config default (10): span emission
    costs a fixed ~0.2-0.7 ms of ``io_callback`` dispatch per span edge
    (measured; a ``lax.cond`` skip wrapper is *slower* — see
    ``obs.trace._when_operand``), so the overhead *fraction* only means
    something against a representative episode duration. Real training
    episodes run 100+ ms; a 10-step toy episode is ~1.4 ms and would gate
    on nothing but callback constants."""
    cfg = FCPOConfig(n_steps=n_steps)
    fleet = fleet_init(cfg, n_agents, jax.random.PRNGKey(seed))
    traces = make_scenario("dynamic", jax.random.PRNGKey(seed + 1), n_agents,
                           episodes * cfg.n_steps)
    # donate=False so the same fleet pytree can be replayed for timing
    run_off = lambda: train_fleet_scan(cfg, fleet, traces, donate=False)
    f0, h0 = run_off()  # also the warmup/compile for the untraced variant

    tracer = Tracer()  # defaults: every episode, no kernel spans
    run_on = lambda: train_fleet_scan(cfg, fleet, traces, donate=False,
                                      tracer=tracer)
    f1, h1 = run_on()
    bit_identical = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves((f0, h0)), jax.tree.leaves((f1, h1))))

    # a different Tracer object AND a different sampling period must reuse
    # the cached executable: both are operands, not statics
    size = _scan_fn(False)._cache_size()
    with Tracer(span_sample_every=4) as sparse:
        train_fleet_scan(cfg, fleet, traces, donate=False, tracer=sparse)
    no_recompile = _scan_fn(False)._cache_size() == size

    us_off = _min_wall_us(run_off, iters)
    us_on = _min_wall_us(run_on, iters)
    overhead_frac = us_on / max(us_off, 1e-9) - 1.0

    trace = tracer.chrome_trace()
    problems = validate_chrome_trace(trace)
    n_slices = sum(1 for e in trace["traceEvents"] if e["ph"] == "X")
    if trace_path is not None:
        tracer.export(trace_path)
    tracer.close()

    return [{
        "name": "profile_tracing_overhead",
        "us_per_call": us_on,
        "agents": n_agents,
        "episodes": episodes,
        "n_steps": n_steps,
        "iters": iters,
        "us_off": us_off,
        "us_on": us_on,
        "overhead_frac": overhead_frac,
        "bit_identical": bool(bit_identical),
        "no_recompile": bool(no_recompile),
        "trace_slices": n_slices,
        "trace_problems": len(problems),
        "trace_path": trace_path or "",
    }]


def _trace_path(smoke: bool) -> str:
    # Chrome traces land in artifacts/bench/ next to the BENCH_*.json
    # envelopes (gitignored, uploaded by CI) — not the repo root.
    os.makedirs(BENCH_DIR, exist_ok=True)
    return os.path.join(BENCH_DIR,
                        "trace_profile" + ("_smoke" if smoke else "") + ".json")


def run(quick: bool = True, smoke: bool = False, fresh: bool = False):
    """Raw benchmark rows. ``smoke``: tiny CI shapes, never cached.
    ``fresh``: bypass the artifact cache (a regression gate must measure
    this run, not a stale artifact)."""
    if smoke:
        return (run_static(n_agents=4, episodes=2)
                + run_tracing(n_agents=4, episodes=4, n_steps=6000, iters=3,
                              trace_path=_trace_path(True)))
    if not fresh:
        cached = load_rows("fig_profile")
        if cached:
            return cached
    rows = (run_static()
            + run_tracing(iters=5 if quick else 11,
                          trace_path=_trace_path(False)))
    save_rows("fig_profile", rows)
    return rows


def attach_prev(rows, prev_envelope):
    """Attach ``prev_<metric>`` / ``delta_<metric>`` fields from the
    previous envelope's same-named rows (None envelope: no-op)."""
    if not prev_envelope:
        return rows
    by_name = {r.get("name"): r for r in prev_envelope.get("results", [])
               if isinstance(r, dict)}
    for r in rows:
        p = by_name.get(r.get("name"))
        if not p:
            continue
        for m in DELTA_METRICS:
            try:
                prev, new = float(p[m]), float(r[m])
            except (KeyError, TypeError, ValueError):
                continue
            r[f"prev_{m}"] = prev
            r[f"delta_{m}"] = new - prev
    return rows


def format_rows(rows):
    out = []
    for r in rows:
        if "overhead_frac" in r:
            derived = (f"A={r['agents']} eps={r['episodes']} "
                       f"overhead={r['overhead_frac'] * 100:+.2f}% "
                       f"bit_identical={r['bit_identical']} "
                       f"no_recompile={r['no_recompile']} "
                       f"slices={r['trace_slices']} "
                       f"schema_problems={r['trace_problems']}")
        else:
            derived = (f"flops={r['flops']:.3g} "
                       f"bytes={r['bytes_accessed']:.3g} "
                       f"peak={r['peak_bytes'] / 1e6:.2f}MB")
            if "donation_ok" in r:
                derived += (f" donated={r['donated_leaves']:.0f} "
                            f"aliased={r['aliased_args']:.0f} "
                            f"donation_ok={bool(r['donation_ok'])}")
            if "delta_peak_bytes" in r:
                derived += f" dpeak={r['delta_peak_bytes'] / 1e6:+.2f}MB"
        out.append({"name": r["name"],
                    "us_per_call": f"{r['us_per_call']:.0f}",
                    "derived": derived})
    return out


def _run_and_save(quick: bool = True, smoke: bool = False,
                  fresh: bool = False):
    from repro.eval.leaderboard import sanitize_envelope
    name = "profile" + ("_smoke" if smoke else "")
    rows = run(quick, smoke=smoke, fresh=fresh)
    prev = sanitize_envelope(load_bench(name), warn=print)
    attach_prev(rows, prev)
    save_bench(name, rows)
    return rows


def main(quick: bool = True, smoke: bool = False):
    return format_rows(_run_and_save(quick, smoke=smoke))


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit_csv

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI perf-path regression checks")
    ap.add_argument("--gate", action="store_true",
                    help="exit nonzero unless the donation audit passes, "
                         "tracing is bit-identical / recompile-free / "
                         "within the overhead budget, and the Chrome "
                         "trace validates (always re-measures)")
    args = ap.parse_args()
    raw = _run_and_save(smoke=args.smoke, fresh=args.gate)
    emit_csv(format_rows(raw))
    if args.gate:
        scan = next(r for r in raw if r["name"] == "profile_fleet_scan")
        assert scan["donation_ok"], (
            f"donation audit failed: {scan['aliased_args']:.0f} aliased "
            f"outputs for {scan['donated_leaves']:.0f} donated fleet "
            f"leaves — a donated buffer is no longer reused in-place and "
            f"training peak memory roughly doubles")
        tr = next(r for r in raw if r["name"] == "profile_tracing_overhead")
        assert tr["bit_identical"], (
            "traced run diverged from the untraced run — a span callback "
            "is feeding the numerics; tracing must never change results")
        assert tr["no_recompile"], (
            "attaching a different tracer/sampling recompiled the scan — "
            "trace id and sample period must stay operands, not statics")
        assert tr["trace_problems"] == 0, (
            f"Chrome trace failed schema validation "
            f"({tr['trace_problems']} problems) — see "
            f"obs.validate_chrome_trace")
        assert tr["overhead_frac"] <= MAX_OVERHEAD_FRAC, (
            f"tracing overhead {tr['overhead_frac'] * 100:.2f}% exceeds "
            f"the {MAX_OVERHEAD_FRAC * 100:.0f}% budget at default "
            f"sampling — span emission is too hot for an always-on "
            f"flight recorder")
