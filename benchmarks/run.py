# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: each ``fig*`` module reproduces one figure/table of the
FCPO paper on this host (quick mode by default; ``--full`` for paper-scale
episode counts); ``roofline`` reports the §Roofline table from the dry-run
delta-method artifacts (see benchmarks/roofline.py)."""
import argparse
import sys
import traceback

from benchmarks import (fig7_end2end, fig7b_fl_latency, fig8_learning,
                        fig9_slo, fig10_warmstart, fig11_overhead,
                        fig12_ablation_heads, fig13_crl, fig14_frl_scaling,
                        fig_buffer_perf, fig_fl_comm, fig_sim_fidelity,
                        fig_twin_training, roofline)
from benchmarks.common import emit_csv

BENCHES = [
    ("fig7_end2end", fig7_end2end.main),
    ("fig8_learning", fig8_learning.main),
    ("fig7b_fl_latency", fig7b_fl_latency.main),
    ("fig9_slo", fig9_slo.main),
    ("fig10_warmstart", fig10_warmstart.main),
    ("fig11_overhead", fig11_overhead.main),
    ("fig12_ablation_heads", fig12_ablation_heads.main),
    ("fig13_crl", fig13_crl.main),
    ("fig14_frl_scaling", fig14_frl_scaling.main),
    ("fig_buffer_perf", fig_buffer_perf.main),
    ("fig_sim_fidelity", fig_sim_fidelity.main),
    ("fig_twin_training", fig_twin_training.main),
    ("fig_fl_comm", fig_fl_comm.main),
    ("roofline", roofline.main),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale episode counts (slow)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in BENCHES:
        if args.only and args.only not in name:
            continue
        try:
            emit_csv(fn(quick=not args.full))
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},ERROR,", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
