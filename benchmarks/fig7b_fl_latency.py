"""Fig. 7b / §V-B2: federated-learning round latency.

Measures (a) the real wall time of one Algorithm-1 aggregation + head
fine-tune over an n-agent fleet on this host and (b) the modeled on-wire
round trip: agent payload (53 KB-class) over the paper's 5G links vs this
framework's ICI all-reduce (the collective replaces the parameter server)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import load_rows, save_rows, time_call
from repro.configs.fcpo import FCPOConfig
from repro.core.agent import param_bytes
from repro.core.fleet import fl_round, fleet_episode, fleet_init
from repro.data.workload import fleet_traces


def run(quick: bool = True):
    cached = load_rows("fig7b")
    if cached:
        return cached
    cfg = FCPOConfig(fl_every=1)
    rows = []
    for n in (8, 32, 128):
        key = jax.random.PRNGKey(0)
        fleet = fleet_init(cfg, n, key, n_pods=max(1, n // 16))
        traces = fleet_traces(key, n, cfg.n_steps)
        fleet, rollouts, _ = fleet_episode(cfg, fleet, traces)
        us = time_call(lambda: fl_round(cfg, fleet, rollouts), iters=5)

        one_agent = jax.tree.map(lambda x: x[0], fleet.astate.params)
        payload = param_bytes(one_agent)
        # paper transport: 5G up+down per client, serialized at the server
        t_5g = 2 * payload * 8 / 10e6 * n
        # this framework: ring all-reduce over ICI links
        t_ici = 2 * payload * n / 50e9
        rows.append({
            "name": f"fig7b_fl_round_n{n}",
            "agents": n,
            "agent_kb": payload / 1024,
            "wall_us": us,
            "modeled_5g_ms": t_5g * 1e3,
            "modeled_ici_us": t_ici * 1e6,
        })
    save_rows("fig7b", rows)
    return rows


def main(quick: bool = True):
    return [{
        "name": r["name"], "us_per_call": f"{r['wall_us']:.0f}",
        "derived": (f"agent={r['agent_kb']:.1f}KB 5G={r['modeled_5g_ms']:.0f}ms "
                    f"ici={r['modeled_ici_us']:.1f}us"),
    } for r in run(quick)]


if __name__ == "__main__":
    from benchmarks.common import emit_csv
    emit_csv(main())
