"""Fig. 7b / §V-B2: federated-learning round latency.

Measures (a) the real wall time of one Algorithm-1 aggregation + head
fine-tune over an n-agent fleet on this host, (b) the modeled on-wire
round trip: agent payload (53 KB-class) over the paper's 5G links vs this
framework's ICI all-reduce (the collective replaces the parameter server),
and (c) the encoded per-round uplink payload per FL transport codec
(``repro.fl``) — the concrete artifact row behind the paper's §VI
"up to 10x less memory consumption" claim (the top-k codec's 8 B/kept
coordinate is what crosses 10x; int8 is ~4x on the uplink alone and >=8x
on the whole round once the broadcast downlink is counted — see
benchmarks/fig_fl_comm.py)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import load_rows, save_rows, time_call
from repro.configs.fcpo import FCPOConfig
from repro.core.agent import agent_init, param_bytes
from repro.core.fleet import fl_round, fleet_episode, fleet_init
from repro.data.workload import fleet_traces
from repro.fl import CODECS, TransportConfig, agent_payload_bytes


def payload_rows():
    """Measured encoded uplink bytes per client per round, per codec."""
    cfg = FCPOConfig()
    params = agent_init(cfg, jax.random.PRNGKey(0))
    base = agent_payload_bytes(params, TransportConfig(codec="float32"))
    rows = []
    for codec in CODECS:
        b = agent_payload_bytes(params, TransportConfig(codec=codec))
        rows.append({
            "name": f"fig7b_payload_{codec}",
            "wall_us": 0.0,
            "agents": 1,
            "agent_kb": b / 1024,
            "modeled_5g_ms": 2 * b * 8 / 10e6 * 1e3,
            "modeled_ici_us": 2 * b / 50e9 * 1e6,
            "uplink_bytes": b,
            "uplink_reduction_vs_float32": base / b,
        })
    return rows


def run(quick: bool = True):
    cached = load_rows("fig7b")
    # pre-transport caches lack the per-codec payload rows — re-measure
    if cached and any(r["name"].startswith("fig7b_payload") for r in cached):
        return cached
    cfg = FCPOConfig(fl_every=1)
    rows = payload_rows()
    for n in (8, 32, 128):
        key = jax.random.PRNGKey(0)
        fleet = fleet_init(cfg, n, key, n_pods=max(1, n // 16))
        traces = fleet_traces(key, n, cfg.n_steps)
        fleet, rollouts, _ = fleet_episode(cfg, fleet, traces)
        us = time_call(lambda: fl_round(cfg, fleet, rollouts), iters=5)

        one_agent = jax.tree.map(lambda x: x[0], fleet.astate.params)
        payload = param_bytes(one_agent)
        # paper transport: 5G up+down per client, serialized at the server
        t_5g = 2 * payload * 8 / 10e6 * n
        # this framework: ring all-reduce over ICI links
        t_ici = 2 * payload * n / 50e9
        rows.append({
            "name": f"fig7b_fl_round_n{n}",
            "agents": n,
            "agent_kb": payload / 1024,
            "wall_us": us,
            "modeled_5g_ms": t_5g * 1e3,
            "modeled_ici_us": t_ici * 1e6,
        })
    save_rows("fig7b", rows)
    return rows


def main(quick: bool = True):
    out = []
    for r in run(quick):
        derived = (f"agent={r['agent_kb']:.1f}KB 5G={r['modeled_5g_ms']:.0f}ms "
                   f"ici={r['modeled_ici_us']:.1f}us")
        if "uplink_reduction_vs_float32" in r:
            derived += f" reduction={r['uplink_reduction_vs_float32']:.1f}x"
        out.append({"name": r["name"], "us_per_call": f"{r['wall_us']:.0f}",
                    "derived": derived})
    return out


if __name__ == "__main__":
    from benchmarks.common import emit_csv
    emit_csv(main())
