"""Standing-eval leaderboard CLI: score a checkpoint on the scenario ×
backend × codec grid and gate CI on regressions.

Thin driver over ``repro.eval.leaderboard``: builds (or restores) a fleet,
runs every grid cell through the real production cadence
(``train_fleet_scan`` + held-out ``eval_fleet`` on the request-level twin),
and writes a ``BENCH_leaderboard[_smoke].json`` envelope (``save_bench``
provenance: git SHA, jax version, backend) with per-cell mean±std metrics
and deltas against the previous envelope at the same path. ``--gate`` turns
those deltas into an exit code: non-zero when reward or effective
throughput drops beyond the per-cell tolerance.

Examples:
  PYTHONPATH=src python benchmarks/leaderboard.py --smoke --gate
  PYTHONPATH=src python benchmarks/leaderboard.py --ckpt-dir /ckpts/run17 \
      --replicates 3 --n-jobs 4
  PYTHONPATH=src python benchmarks/leaderboard.py --scenarios drift,ood \
      --codecs topk --episodes 10
"""
from __future__ import annotations

import argparse
import sys
import time

import jax

from benchmarks.common import load_bench, save_bench
from repro.configs.fcpo import FCPOConfig
from repro.core.backends import BACKENDS
from repro.core.fleet import fleet_init
from repro.eval.leaderboard import (DEFAULT_TOL, GRID_CODECS, REPLICATES,
                                    attach_deltas, check_regressions,
                                    grid_cells, load_fleet, run_leaderboard,
                                    sanitize_envelope)
from repro.sim import SCENARIOS

# CI smoke slice: 2 scenarios x 2 backends x 2 codecs, 1 replicate — one
# steady cell and one distribution-shift cell, both env backends, the
# lossless codec and one compressed codec. Small but spans every axis.
SMOKE_SCENARIOS = ("steady", "ood")
SMOKE_BACKENDS = BACKENDS
SMOKE_CODECS = ("float32", "int8")


def _csv(choices):
    def parse(s):
        vals = tuple(v for v in s.split(",") if v)
        bad = [v for v in vals if v not in choices]
        if bad:
            raise argparse.ArgumentTypeError(
                f"unknown {bad}; choices: {', '.join(choices)}")
        return vals
    return parse


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI grid (2 scenarios x 2 backends x "
                         "2 codecs, 1 replicate) written to "
                         "BENCH_leaderboard_smoke.json")
    ap.add_argument("--gate", action="store_true",
                    help="exit non-zero when any cell regresses beyond "
                         "--tol vs the previous envelope")
    ap.add_argument("--ckpt-dir", type=str, default=None,
                    help="restore the fleet from this checkpoint dir "
                         "(training.checkpoint layout); default: a fresh "
                         "seed-0 fleet_init")
    ap.add_argument("--ckpt-step", type=int, default=None,
                    help="checkpoint step (default: latest)")
    ap.add_argument("--scenarios", type=_csv(SCENARIOS), default=None,
                    help="comma list overriding the scenario axis")
    ap.add_argument("--backends", type=_csv(BACKENDS), default=None,
                    help="comma list overriding the backend axis")
    ap.add_argument("--codecs", type=_csv(GRID_CODECS), default=None,
                    help="comma list overriding the FL codec axis")
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--episodes", type=int, default=None,
                    help="training episodes per cell (default 6; smoke 4)")
    ap.add_argument("--eval-intervals", type=int, default=None,
                    help="held-out twin eval intervals (default 30; "
                         "smoke 16)")
    ap.add_argument("--replicates", type=int, default=None,
                    help=f"seeds per cell (default {REPLICATES}; smoke 1)")
    ap.add_argument("--tol", type=float, default=DEFAULT_TOL,
                    help="per-cell relative regression tolerance")
    ap.add_argument("--n-jobs", type=int, default=1,
                    help="round-robin shards (result order and values are "
                         "independent of this — determinism is tested)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", type=str, default=None,
                    help="envelope directory (default: repo root)")
    args = ap.parse_args(argv)

    name = "leaderboard_smoke" if args.smoke else "leaderboard"
    scenarios = args.scenarios or (SMOKE_SCENARIOS if args.smoke
                                   else SCENARIOS)
    backends = args.backends or (SMOKE_BACKENDS if args.smoke else BACKENDS)
    codecs = args.codecs or (SMOKE_CODECS if args.smoke else GRID_CODECS)
    replicates = args.replicates or (1 if args.smoke else REPLICATES)
    episodes = args.episodes or (4 if args.smoke else 6)
    eval_intervals = args.eval_intervals or (16 if args.smoke else 30)

    cfg = FCPOConfig()
    if args.ckpt_dir:
        fleet = load_fleet(cfg, args.ckpt_dir, args.ckpt_step,
                           n_agents=args.agents)
        source = f"checkpoint {args.ckpt_dir}"
    else:
        fleet = fleet_init(cfg, args.agents, jax.random.PRNGKey(args.seed))
        source = f"fleet_init(seed={args.seed})"

    cells = grid_cells(scenarios, backends, codecs)
    print(f"leaderboard: {len(cells)} cells "
          f"({len(scenarios)} scenarios x {len(backends)} backends x "
          f"{len(codecs)} codecs), {replicates} replicate(s), "
          f"A={args.agents}, {source}")
    t0 = time.time()
    rows = run_leaderboard(cfg, fleet, cells, episodes=episodes,
                           eval_intervals=eval_intervals,
                           replicates=replicates, seed=args.seed,
                           n_jobs=args.n_jobs, log=print)
    print(f"grid wall {time.time() - t0:.1f}s")

    try:
        prev = load_bench(name, out_dir=args.out_dir)
    except Exception as e:  # truncated/corrupt previous envelope
        print(f"warning: previous envelope unreadable ({e}) — "
              f"treating as no baseline")
        prev = None
    prev = sanitize_envelope(prev, warn=print)
    attach_deltas(rows, prev, warn=print)
    path = save_bench(name, rows, out_dir=args.out_dir, extra={
        "grid": {"scenarios": list(scenarios), "backends": list(backends),
                 "codecs": list(codecs)},
        "agents": args.agents, "episodes": episodes,
        "eval_intervals": eval_intervals, "replicates": replicates,
        "seed": args.seed, "source": source,
        "prev_git_sha": (prev or {}).get("git_sha"),
    })
    print(f"envelope: {path}" + ("" if prev is None else
          f"  (deltas vs git_sha={(prev or {}).get('git_sha', '?')[:12]})"))

    if args.gate:
        fails = check_regressions(rows, tol=args.tol)
        if prev is None:
            print("gate: no previous envelope — nothing to compare, pass")
        elif fails:
            print(f"gate: {len(fails)} regression(s) beyond tol="
                  f"{args.tol:.0%}:", file=sys.stderr)
            for f in fails:
                print(f"  {f}", file=sys.stderr)
            return 1
        else:
            print(f"gate: pass ({len(rows)} cells within tol={args.tol:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
