"""Chaos benchmark: reward under injected faults, with and without defenses.

Three measurements over the chaos layer (``repro.resilience``):

  * ``byzantine`` — the headline grid: an A=8 fleet trained fault-free (the
    envelope) vs the same fleet under sign-flip byzantine uploads, once per
    Algorithm 1 statistic (``mean`` / ``trimmed`` / ``median``). Acceptance:
    the robust statistics hold final reward within tolerance of the
    fault-free envelope while plain mean degrades out of the band — the
    concrete artifact for "robust aggregation holds where mean collapses".
    The trimmed arm doubles as the structural gate: fault injection must not
    break the ONE-jitted-scan property (no per-episode host entries, and a
    same-shaped rerun hits the compiled executable).
  * ``crash`` — reward vs crash-rate sweep: agents drop for a recovery
    window and rejoin warm-started from their pod base network (the paper's
    step-(1) warm start). Gate: training survives — finite params, finite
    reward at every crash rate.
  * ``nan`` — NaN-poisoned uploads against the non-finite rejection guard,
    per codec (the poison is applied post-codec, so every wire format is
    exercised). Gate: rejections are counted, the fleet's params stay
    finite, and reward stays within the robust tolerance of the envelope.

``--smoke --gate`` is the CI regression gate: asserts all of the above on
tiny shapes and writes ``BENCH_chaos_smoke.json`` (full runs write
``BENCH_chaos.json``).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import load_rows, save_bench, save_rows
from repro.configs.fcpo import FCPOConfig
from repro.core.fleet import _scan_fn, fleet_episode, fleet_init, train_fleet
from repro.data.workload import fleet_traces
from repro.fl import TransportConfig
from repro.resilience import FaultConfig, GuardConfig

# Robust arms must stay within this relative band of the fault-free
# envelope; the absolute floor keeps the band meaningful when the envelope
# reward sits near zero.
ROBUST_TOL = 0.10
ROBUST_FLOOR = 0.05
# Reward alone can saturate on short horizons, so collapse is ALSO gated on
# parameter-norm divergence: robust arms must stay within NORM_RATIO_MAX of
# the fault-free final param norm, mean must blow up past MEAN_MARGIN x the
# worst robust arm (measured ~16x vs ~1.8x at the smoke shapes).
NORM_RATIO_MAX = 3.0
MEAN_MARGIN = 3.0
# The byzantine grid runs one pod: Algorithm 1 aggregates per pod segment,
# and a robust statistic needs enough valid participants per segment
# (selected clients + the base network) for trimming to engage at all.
TRIM_FRAC = 0.4
NAN_CODECS = ("float32", "int8", "topk")


def _train(n_agents, episodes, seed, faults=None, guards=None,
           transport=None, n_pods=1):
    cfg = FCPOConfig()
    traces = fleet_traces(jax.random.PRNGKey(seed + 1), n_agents,
                          episodes * cfg.n_steps)
    fleet = fleet_init(cfg, n_agents, jax.random.PRNGKey(seed),
                       n_pods=n_pods)
    fleet, hist = train_fleet(cfg, fleet, traces, faults=faults,
                              guards=guards, transport=transport)
    return fleet, hist


def _param_norm(fleet):
    return float(np.sqrt(sum(
        np.sum(np.square(np.asarray(x, dtype=np.float64)))
        for x in jax.tree_util.tree_leaves(fleet.astate.params))))


def _final(hist, tail):
    r = np.asarray(hist["reward"][-tail:], dtype=np.float64)
    # a collapsed run can go non-finite; report it as -inf so the gate
    # sees "degraded", not a crash in the benchmark itself
    return float(np.mean(r)) if np.all(np.isfinite(r)) else float("-inf")


def _params_finite(fleet):
    return bool(all(np.all(np.isfinite(np.asarray(x)))
                    for x in jax.tree_util.tree_leaves(fleet.astate.params)))


def run_byzantine(n_agents=8, episodes=20, tail=6, seed=0, byz_frac=0.2,
                  scale=25.0):
    """Fault-free envelope + one arm per aggregation statistic under
    sign-flip byzantine uploads. The trimmed arm carries the structural
    scan gates."""
    fleet_env, hist_env = _train(n_agents, episodes, seed)
    env = _final(hist_env, tail)
    env_norm = _param_norm(fleet_env)
    faults = FaultConfig(byzantine_frac=byz_frac, byzantine_mode="sign_flip",
                         byzantine_scale=scale, seed=seed)
    rows = [{
        "name": "chaos_byzantine_envelope",
        "us_per_call": 0.0,
        "agents": n_agents, "episodes": episodes,
        "final_reward": env, "gap_vs_envelope": 0.0,
        "tol": max(ROBUST_TOL * abs(env), ROBUST_FLOOR),
        "param_norm": env_norm, "norm_vs_envelope": 1.0,
    }]
    for agg in ("mean", "trimmed", "median"):
        guards = GuardConfig(agg=agg, trim_frac=TRIM_FRAC)
        ep_before = fleet_episode._cache_size()
        fleet, hist = _train(n_agents, episodes, seed, faults=faults,
                             guards=guards)
        host_compiles = fleet_episode._cache_size() - ep_before
        compiled_once = None
        if agg == "trimmed":  # rerun the asserted arm alone — compile gate
            size = _scan_fn(False)._cache_size()
            _train(n_agents, episodes, seed, faults=faults, guards=guards)
            compiled_once = _scan_fn(False)._cache_size() == size
        r = _final(hist, tail)
        rows.append({
            "name": f"chaos_byzantine_{agg}",
            "us_per_call": 0.0,
            "agents": n_agents, "episodes": episodes,
            "byzantine_frac": byz_frac, "byzantine_scale": scale,
            "final_reward": r,
            "gap_vs_envelope": env - r,
            "tol": max(ROBUST_TOL * abs(env), ROBUST_FLOOR),
            "param_norm": _param_norm(fleet),
            "norm_vs_envelope": _param_norm(fleet) / env_norm,
            "params_finite": _params_finite(fleet),
            "one_jitted_scan": host_compiles == 0,
            "compiled_once": compiled_once,
        })
    return rows


def run_crash(crash_probs=(0.1, 0.3), n_agents=8, episodes=20, tail=6,
              seed=0):
    """Reward vs crash rate: multi-episode outages + warm-start rejoin."""
    rows = []
    for p in crash_probs:
        faults = FaultConfig(crash_prob=p, crash_recovery=2, seed=seed)
        # two pods: rejoin warm-starts from the POD base network, so the
        # sweep exercises the hierarchical tier too
        fleet, hist = _train(n_agents, episodes, seed, faults=faults,
                             n_pods=2)
        rows.append({
            "name": f"chaos_crash_p{p:g}",
            "us_per_call": 0.0,
            "agents": n_agents, "episodes": episodes, "crash_prob": p,
            "final_reward": _final(hist, tail),
            "params_finite": _params_finite(fleet),
        })
    return rows


def run_nan(n_agents=8, episodes=20, tail=6, seed=0, byz_frac=0.25):
    """NaN-poisoned uploads vs the non-finite rejection guard, per codec
    (the corruption lands post-codec, so each wire format is poisoned)."""
    _, hist_env = _train(n_agents, episodes, seed)
    env = _final(hist_env, tail)
    faults = FaultConfig(byzantine_frac=byz_frac, byzantine_mode="nan",
                         seed=seed)
    rows = []
    for codec in NAN_CODECS:
        t = TransportConfig(codec=codec)
        fleet, hist = _train(n_agents, episodes, seed, faults=faults,
                             transport=t)
        rows.append({
            "name": f"chaos_nan_reject_{codec}",
            "us_per_call": 0.0,
            "agents": n_agents, "episodes": episodes,
            "byzantine_frac": byz_frac, "codec": codec,
            "final_reward": _final(hist, tail),
            "gap_vs_envelope": env - _final(hist, tail),
            "tol": max(ROBUST_TOL * abs(env), ROBUST_FLOOR),
            "fl_rejected": float(np.asarray(hist["fl_rejected"]).sum()),
            "params_finite": _params_finite(fleet),
        })
    return rows


def run(quick: bool = True, smoke: bool = False, fresh: bool = False):
    """Raw benchmark rows. ``smoke``: tiny CI shapes, never cached.
    ``fresh``: bypass the artifact cache (the gate must measure this run)."""
    if smoke:
        # keep the headline A=8 fleet (the acceptance criterion names it);
        # only episode counts shrink
        return (run_byzantine(episodes=16, tail=5)
                + run_crash(episodes=12, tail=4)
                + run_nan(episodes=12, tail=4))
    if not fresh:
        cached = load_rows("fig_chaos")
        if cached:
            return cached
    eps = 40 if quick else 80
    rows = (run_byzantine(episodes=eps, tail=10)
            + run_crash(crash_probs=(0.05, 0.1, 0.2, 0.3), episodes=eps,
                        tail=10)
            + run_nan(episodes=eps, tail=10))
    save_rows("fig_chaos", rows)
    return rows


def format_rows(rows):
    out = []
    for r in rows:
        derived = (f"A={r['agents']} eps={r['episodes']} "
                   f"reward={r['final_reward']:.3f}")
        if "gap_vs_envelope" in r:
            derived += (f" gap={r['gap_vs_envelope']:+.3f} "
                        f"(tol {r['tol']:.3f})")
        if "norm_vs_envelope" in r:
            derived += f" norm_ratio={r['norm_vs_envelope']:.2f}x"
        if "crash_prob" in r:
            derived += f" crash_p={r['crash_prob']:g}"
        if "fl_rejected" in r:
            derived += f" rejected={r['fl_rejected']:.0f}"
        if "params_finite" in r:
            derived += f" finite={r['params_finite']}"
        if r.get("one_jitted_scan") is not None:
            derived += f" one_jitted_scan={r['one_jitted_scan']}"
        if r.get("compiled_once") is not None:
            derived += f" compiled_once={r['compiled_once']}"
        out.append({"name": r["name"], "us_per_call": "0",
                    "derived": derived})
    return out


def _run_and_save(quick: bool = True, smoke: bool = False,
                  fresh: bool = False):
    rows = run(quick, smoke=smoke, fresh=fresh)
    save_bench("chaos" + ("_smoke" if smoke else ""), rows)
    return rows


def main(quick: bool = True, smoke: bool = False):
    return format_rows(_run_and_save(quick, smoke=smoke))


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit_csv

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI regression checks")
    ap.add_argument("--gate", action="store_true",
                    help="exit nonzero unless trimmed/median hold reward "
                         "within tolerance of the fault-free envelope while "
                         "mean degrades out of the band, NaN poison is "
                         "rejected under every codec, crash sweeps stay "
                         "finite, and fault runs stay one compiled scan "
                         "(always re-measures)")
    args = ap.parse_args()
    raw = _run_and_save(smoke=args.smoke, fresh=args.gate)
    emit_csv(format_rows(raw))
    if args.gate:
        by = {r["name"]: r for r in raw}
        for agg in ("trimmed", "median"):
            r = by[f"chaos_byzantine_{agg}"]
            assert r["params_finite"], f"{agg} arm produced non-finite params"
            assert abs(r["gap_vs_envelope"]) <= r["tol"], (
                f"{agg} aggregation no longer holds the line under "
                f"byzantine uploads: reward gap {r['gap_vs_envelope']:+.3f} "
                f"vs envelope exceeds tol {r['tol']:.3f}")
            assert r["norm_vs_envelope"] <= NORM_RATIO_MAX, (
                f"{agg} arm's params drifted {r['norm_vs_envelope']:.1f}x "
                f"from the fault-free norm (max {NORM_RATIO_MAX}x) — the "
                f"robust statistic is letting byzantine mass through")
        mean_row = by["chaos_byzantine_mean"]
        worst_robust = max(by["chaos_byzantine_trimmed"]["norm_vs_envelope"],
                           by["chaos_byzantine_median"]["norm_vs_envelope"],
                           1.0)
        assert mean_row["norm_vs_envelope"] >= MEAN_MARGIN * worst_robust, (
            f"plain-mean arm did not degrade (param-norm ratio "
            f"{mean_row['norm_vs_envelope']:.1f}x vs worst robust "
            f"{worst_robust:.1f}x, margin {MEAN_MARGIN}x) — the byzantine "
            f"injection has lost its teeth and the robust-aggregation "
            f"comparison is vacuous")
        tr = by["chaos_byzantine_trimmed"]
        assert tr["one_jitted_scan"], (
            "fault-injected run touched the per-episode host entry point — "
            "chaos must stay inside the ONE jitted scan")
        assert tr["compiled_once"], (
            "fault-injected scan recompiled on a same-shaped rerun — the "
            "fault plan must stay trace-level data, not a new static")
        for r in raw:
            if r["name"].startswith("chaos_crash"):
                assert r["params_finite"] and np.isfinite(r["final_reward"]), (
                    f"{r['name']}: crash/rejoin cycle destabilized training")
        for codec in NAN_CODECS:
            r = by[f"chaos_nan_reject_{codec}"]
            assert r["fl_rejected"] > 0, (
                f"{codec}: NaN poison was injected but nothing was rejected "
                f"— the non-finite guard is not seeing the uploads")
            assert r["params_finite"], (
                f"{codec}: NaN poison reached the aggregate")
            assert abs(r["gap_vs_envelope"]) <= r["tol"], (
                f"{codec}: rejecting poisoned uploads should leave reward "
                f"near the envelope; gap {r['gap_vs_envelope']:+.3f} "
                f"exceeds tol {r['tol']:.3f}")
        print("chaos gate: pass")
