"""Roofline analysis (§g): compute / memory / collective terms per
(arch × shape) on the production mesh, from compiled dry-run artifacts.

Methodology — the scan-correction *delta method*: XLA:CPU ``cost_analysis``
counts ``lax.scan`` bodies ONCE (verified in EXPERIMENTS.md §Dry-run), so the
full-L scanned lowering undercounts per-layer FLOPs/bytes/collectives by ~L×.
Fully unrolled lowerings are exact but compile in O(minutes-hours) per 7B
cell on this host. Instead we lower each cell UNROLLED at two (or four) small
layer counts and extrapolate linearly — exact for homogeneous stacks:

    dense/moe/encoder/vlm:  f(L0), f(L0+1);  X(L) = f(L0) + (L - L0)·Δ
    deepseek (1 dense + 26 moe):  f(2), f(3)
    zamba2 (6 groups of 6 + 2 tail):  f(6), f(12), f(8)
    xlstm (sLSTM@{0,8}, mLSTM elsewhere):  f(2), f(3), f(8), f(9)

Known residual undercounts (documented, small): per-chunk/time-step scan
*bodies* that are pure elementwise state updates (mamba2/mLSTM state carry,
sLSTM recurrent core ≈3% of xlstm FLOPs).

Usage:
  python -m benchmarks.roofline --compute   # runs the delta lowerings (512-dev)
  python -m benchmarks.roofline             # prints the table from artifacts
"""
import os
import sys

if "--compute" in sys.argv:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import subprocess

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
ROOF = os.path.join(ART, "roofline")

PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _lin(f, arch, full_layers):
    """Combine point-measurements into full-L counts per the plan."""
    def mix(coeffs):
        out = {}
        for key in ("flops", "bytes", "coll"):
            out[key] = sum(c * f[n][key] for n, c in coeffs)
        return out

    if arch == "zamba2-1.2b":
        # X(38) = f6 + 5*(f12 - f6) + (f8 - f6) = -5*f6 + 5*f12 + f8
        return mix([(6, -5.0), (12, 5.0), (8, 1.0)])
    if arch == "xlstm-125m":
        # X = f2 + (f9-f8) + 9*(f3-f2)  [one extra sLSTM + 9 extra mLSTM]
        return mix([(2, 1.0 - 9.0), (3, 9.0), (8, -1.0), (9, 1.0)])
    if arch == "deepseek-v2-lite-16b":
        l0 = 2
        return mix([(2, 1.0 - (full_layers - l0)), (3, float(full_layers - l0))])
    l0 = 1
    return mix([(1, 1.0 - (full_layers - l0)), (2, float(full_layers - l0))])


def compute(archs=None, shapes=None):
    """Run the delta lowerings (requires the 512-device override)."""
    from repro.configs.base import SHAPES, get_config, shape_applicable
    from repro.launch.dryrun import build_cell, collective_bytes, model_flops
    from repro.launch.mesh import make_production_mesh
    import jax

    os.makedirs(ROOF, exist_ok=True)
    mesh = make_production_mesh(multi_pod=False)
    archs = archs or sorted(
        __import__("repro.configs", fromlist=["ARCH_IDS"]).ARCH_IDS)
    shapes = shapes or list(SHAPES)

    for arch in archs:
        cfg_full = get_config(arch)
        for shape_name in shapes:
            ok, _ = shape_applicable(cfg_full, shape_name)
            if not ok:
                continue
            out_path = os.path.join(ROOF, f"{arch}__{shape_name}.json")
            if os.path.exists(out_path):
                print(f"cached {arch} {shape_name}", flush=True)
                continue
            if arch == "zamba2-1.2b":
                points = [6, 12, 8]
            elif arch == "xlstm-125m":
                points = [2, 3, 8, 9]
            elif arch == "deepseek-v2-lite-16b":
                points = [2, 3]
            else:
                points = [1, 2]
            f = {}
            try:
                for n in points:
                    fn, args, in_sh, out_sh, cfg, pspecs, shape = build_cell(
                        arch, shape_name, mesh, unroll=True,
                        overrides={"n_layers": n})
                    with mesh:
                        compiled = jax.jit(fn, in_shardings=in_sh,
                                           out_shardings=out_sh).lower(*args).compile()
                    ca = compiled.cost_analysis()
                    if isinstance(ca, (list, tuple)):
                        ca = ca[0]
                    f[n] = {
                        "flops": float(ca.get("flops", 0.0)),
                        "bytes": float(ca.get("bytes accessed", 0.0)),
                        "coll": float(collective_bytes(
                            compiled.as_text())["total"]),
                    }
                    print(f"  {arch} {shape_name} L={n}: "
                          f"flops={f[n]['flops']:.3e}", flush=True)
                corrected = _lin(f, arch, cfg_full.n_layers)
                # MODEL_FLOPS for the FULL config
                from repro.models.registry import get_model
                full_model = get_model(cfg_full.replace(
                    param_dtype="float32"
                    if SHAPES[shape_name].kind == "train" else "bfloat16"))
                pspecs_full = jax.eval_shape(full_model.init,
                                             jax.random.PRNGKey(0))
                mflops, n_tot, n_act = model_flops(cfg_full, pspecs_full,
                                                   SHAPES[shape_name])
                rec = {
                    "arch": arch, "shape": shape_name, "points": f,
                    "flops_per_device": corrected["flops"],
                    "bytes_per_device": corrected["bytes"],
                    "collective_bytes_total": corrected["coll"],
                    "model_flops": mflops,
                    "params_total": n_tot, "params_active": n_act,
                    "chips": mesh.size,
                }
                with open(out_path, "w") as fh:
                    json.dump(rec, fh, indent=1)
                print(f"{arch:24s} {shape_name:12s} corrected "
                      f"flops/dev={corrected['flops']:.3e}", flush=True)
            except Exception as e:  # noqa: BLE001
                print(f"{arch} {shape_name} ERROR {e}", flush=True)


def report(emit_rows=False):
    rows = []
    if not os.path.isdir(ROOF):
        return []
    for fn in sorted(os.listdir(ROOF)):
        with open(os.path.join(ROOF, fn)) as fh:
            r = json.load(fh)
        chips = r["chips"]
        t_comp = r["flops_per_device"] / PEAK_FLOPS_BF16
        t_mem = r["bytes_per_device"] / HBM_BW
        t_coll = r["collective_bytes_total"] / (chips * ICI_BW)
        terms = {"compute_s": t_comp, "memory_s": t_mem,
                 "collective_s": t_coll}
        dominant = max(terms, key=terms.get)
        bound = max(terms.values())
        model_t = r["model_flops"] / (chips * PEAK_FLOPS_BF16)
        useful = r["model_flops"] / (r["flops_per_device"] * chips + 1e-30)
        rows.append({
            "name": f"roofline_{r['arch']}_{r['shape']}",
            "arch": r["arch"], "shape": r["shape"],
            **{k: float(v) for k, v in terms.items()},
            "dominant": dominant,
            "useful_flops_ratio": useful,
            "roofline_fraction": model_t / bound if bound else 0.0,
            "model_flops": r["model_flops"],
        })
    if emit_rows:
        return [{
            "name": r["name"], "us_per_call": f"{max(r['compute_s'], r['memory_s'], r['collective_s']) * 1e6:.0f}",
            "derived": (f"dom={r['dominant']} comp={r['compute_s']:.2e}s "
                        f"mem={r['memory_s']:.2e}s coll={r['collective_s']:.2e}s "
                        f"roofline_frac={r['roofline_fraction']:.3f}"),
        } for r in rows]
    return rows


def main(quick: bool = True):
    if not os.path.isdir(ROOF) or not os.listdir(ROOF):
        # compute in a subprocess so the 512-device override never leaks
        subprocess.run([sys.executable, "-m", "benchmarks.roofline",
                        "--compute"], check=False,
                       env={**os.environ,
                            "PYTHONPATH": os.environ.get("PYTHONPATH", "src")})
    return report(emit_rows=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--compute", action="store_true")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    a = ap.parse_args()
    if a.compute:
        compute([a.arch] if a.arch else None, [a.shape] if a.shape else None)
    from benchmarks.common import emit_csv
    emit_csv(report(emit_rows=True))
