"""FL contribution attribution: who moved the aggregate, and should we
trust them.

At each ``fl_round`` every selected client ships a delta; the aggregate is
their (staleness-weighted) combination. This module scores each client's
contribution *against a robust reference direction* and produces a
per-agent ``suspicion`` in [0, 1] — observability that closes into action
when ``GuardConfig.susp_threshold`` gates selection on it.

Why not plain cosine-to-aggregate: under fig_chaos's fault plan (20% of
clients sign-flipped at 25x) the byzantine mass is ~5x the honest mass,
so the naive aggregate points *with* the attackers and honest clients
score as outliers. The fix is the same insight as norm-clipping defenses:
build the reference from norm-downweighted deltas (squared clip — see
``robust_reference_weights`` for why linear clipping is not enough), so
no client can buy direction with magnitude, then score raw deltas
against that reference.

Three evidence terms per client i (all from one O(A) pass of tree-wise
reductions — no (A, A) pairwise matrix, no per-client aggregate rebuild):

* ``cos_i`` — cosine of d_i to the robust reference r;
* ``cos_loo_i`` — cosine of d_i to the leave-one-out reference
  r - w_i d_i, computed in closed form from the same dot products
  (removing yourself from the reference is the classic self-alignment
  correction: a client should not get credit for agreeing with its own
  contribution);
* ``norm_term_i`` — a saturating penalty on norm ratio to the median,
  ``log(r)+ / (1 + log(r)+)``: 25x inflation scores ~0.76, honest
  (ratio ~1) scores ~0.

The weighted blend lands sign-flip byzantine clients at suspicion ~0.9
and honest clients near 0 — clean top-k separation, which
``benchmarks/fig_health.py`` gates under the fig_chaos fault plan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def _masked_lower_median(x: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Lower median (the order statistic at rank (n-1)//2) over the masked
    entries. NOT the interpolated median guards use: at even counts the
    interpolated median *averages the two middle values*, and with half
    the selected clients running a norm-inflation attack the upper middle
    IS an attacker — 2 byzantine of 4 selected at 25x drags the clip
    scale to (1+25)/2 = 13x honest and the squared clip stops vanishing.
    The lower order statistic stays at an honest norm for any byzantine
    fraction up to (and including) half of the selected set, because
    inflated norms sort to the top."""
    n = jnp.sum(mask.astype(jnp.int32))
    s = jnp.sort(jnp.where(mask, x, jnp.inf))
    med = s[jnp.maximum((n - 1) // 2, 0)]
    return jnp.where(n > 0, med, 0.0)

# Evidence blend: leave-one-out alignment is the sharpest discriminator,
# raw alignment confirms it, the norm term catches magnitude attacks that
# point the right way.
W_COS_LOO = 0.45
W_COS = 0.25
W_NORM = 0.30


def _axes_but_first(leaf):
    return tuple(range(1, leaf.ndim))


def _per_client_sq_norms(deltas) -> jnp.ndarray:
    """(A,) sum of squares of each client's delta across all leaves."""
    leaves = jax.tree.leaves(deltas)
    tot = jnp.zeros((leaves[0].shape[0],), jnp.float32)
    for leaf in leaves:
        f = leaf.astype(jnp.float32)
        tot = tot + jnp.sum(f * f, axis=_axes_but_first(f))
    return tot


def robust_reference_weights(norms: jnp.ndarray,
                             sel: jnp.ndarray) -> jnp.ndarray:
    """Squared norm-clip weights: w_i = sel_i * min(1, (med / norm_i)^2)
    with med the masked median norm over selected clients; the weighted
    sum sum_i w_i d_i is the robust reference.

    The square matters. A linear clip (min(1, med/norm)) caps each
    client at median-norm worth of *direction* — so a sign-flipped delta
    at 25x re-enters the reference at FULL honest scale, negated, and
    two such clients among four selected cancel the honest mass to ~0
    (the reference direction collapses exactly when attribution is
    needed most). Squaring makes the re-entered mass
    norm * (med/norm)^2 = med^2/norm -> 0 as the attack scales up:
    honest clients (norm ~ med) still weigh ~1, magnitude attackers
    contribute vanishing direction instead of a constant negative
    one. ``med`` is the *lower* median — see ``_masked_lower_median``
    for why the interpolated median breaks at even selection counts."""
    med = _masked_lower_median(norms, sel.astype(bool))
    ratio = med / jnp.maximum(norms, _EPS)
    return sel.astype(jnp.float32) * jnp.minimum(1.0, ratio * ratio)


def attribution_scores(deltas, sel: jnp.ndarray) -> dict:
    """Score every client's delta against the robust reference.

    ``deltas``: pytree with leading client axis A (the post-codec wire
    deltas ``fl_round`` aggregates). ``sel``: (A,) selection mask.
    Returns (A,) arrays: ``norm``, ``cos``, ``cos_loo``, ``susp``;
    unselected clients score 0 suspicion (they contributed nothing).
    """
    sq = _per_client_sq_norms(deltas)
    norms = jnp.sqrt(sq)
    w = robust_reference_weights(norms, sel)

    # reference r = sum_i w_i d_i, and per-client dot_i = <d_i, r>,
    # accumulated leaf-wise so r never materializes per client.
    dot = jnp.zeros_like(sq)
    ref_sq = jnp.zeros((), jnp.float32)
    for leaf in jax.tree.leaves(deltas):
        f = leaf.astype(jnp.float32)
        r = jnp.einsum("a,a...->...", w, f)
        ref_sq = ref_sq + jnp.sum(r * r)
        dot = dot + jnp.sum(f * r, axis=_axes_but_first(f))

    cos = dot / jnp.maximum(norms * jnp.sqrt(ref_sq), _EPS)

    # leave-one-out in closed form: r_-i = r - w_i d_i
    dot_loo = dot - w * sq
    loo_sq = jnp.maximum(ref_sq - 2.0 * w * dot + w * w * sq, 0.0)
    cos_loo = dot_loo / jnp.maximum(norms * jnp.sqrt(loo_sq), _EPS)

    med = _masked_lower_median(norms, sel.astype(bool))
    log_r = jnp.maximum(jnp.log(jnp.maximum(norms, _EPS)
                                / jnp.maximum(med, _EPS)), 0.0)
    norm_term = log_r / (1.0 + log_r)

    susp = (W_COS_LOO * (1.0 - jnp.clip(cos_loo, -1.0, 1.0)) / 2.0
            + W_COS * (1.0 - jnp.clip(cos, -1.0, 1.0)) / 2.0
            + W_NORM * norm_term)
    susp = jnp.clip(susp, 0.0, 1.0) * sel.astype(jnp.float32)
    return {"norm": norms, "cos": cos, "cos_loo": cos_loo, "susp": susp}
