"""Branchless change-point detectors carried as scan state.

FCPO's premise is that edge MDPs drift — the CRL machinery exists because
workload shifts invalidate the current policy. This module gives every
agent a live drift signal *inside* the jitted scan: two classic sequential
detectors over a standardized residual, all ``jnp.where`` (no data-
dependent control flow), so the state vmaps over agents and scans over
control intervals.

Per monitored channel (reward, arrival rate) each agent carries:

* slow EMA mean/variance — the "what normal looks like" baseline
  (bootstrap as a running mean for the first ``warmup`` observations,
  then exponential with rate ``ema_slow``);
* fast EMA mean/variance — the "what now looks like" estimate the
  detector re-anchors to after an alarm, so a detected shift becomes the
  new normal instead of alarming forever;
* **CUSUM** (two-sided): ``g+ <- max(0, g+ + z - k)``,
  ``g- <- max(0, g- - z - k)``; alarm at ``h``. With the defaults
  (k=0.5, h=10) the i.i.d. false-alarm probability per run is roughly
  ``exp(-2kh) ~ 5e-5`` — the property test in
  tests/test_health_properties.py leans on that margin;
* **Page–Hinkley** (two-sided) on the same z: ``m <- m + z - delta``,
  alarm when ``m - min(m)`` exceeds ``lambda`` — catches slow ramps
  CUSUM's per-step drift allowance eats.

``z`` is clipped to ``±zclip`` so one corrupt interval cannot fire the
detector alone, and the variance is floored so a constant warmup stream
does not produce infinite z. Detection is gated until ``warmup``
observations have been seen (the baseline means nothing before that).

``score``/``flag`` are episode-max accumulators (reset by
``drift_reset_episode`` at each episode start) so the per-episode metrics
stream reports "did this agent see a change-point this episode" even
though the detector steps per interval.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class DriftState(NamedTuple):
    """One detector channel for one agent (all leaves scalar; vmapped to
    (A,) in the fleet). ``mu/var``: slow baseline; ``mu_f/var_f``: fast
    re-anchor estimate; ``g_pos/g_neg``: CUSUM; ``m_up/m_up_min/m_dn/
    m_dn_max``: Page–Hinkley accumulators and their running extrema;
    ``score``/``flag``: episode-max normalized statistic / alarm."""
    mu: jnp.ndarray
    var: jnp.ndarray
    mu_f: jnp.ndarray
    var_f: jnp.ndarray
    count: jnp.ndarray
    g_pos: jnp.ndarray
    g_neg: jnp.ndarray
    m_up: jnp.ndarray
    m_up_min: jnp.ndarray
    m_dn: jnp.ndarray
    m_dn_max: jnp.ndarray
    score: jnp.ndarray
    flag: jnp.ndarray


def drift_init() -> DriftState:
    z = jnp.zeros((), jnp.float32)
    return DriftState(mu=z, var=z, mu_f=z, var_f=z, count=z, g_pos=z,
                      g_neg=z, m_up=z, m_up_min=z, m_dn=z, m_dn_max=z,
                      score=z, flag=z)


def drift_reset_episode(s: DriftState) -> DriftState:
    """Zero the episode-max outputs (call once per episode, before the
    interval scan). Baselines and accumulators persist across episodes —
    drift has no reason to respect episode boundaries."""
    return s._replace(score=jnp.zeros_like(s.score),
                      flag=jnp.zeros_like(s.flag))


def drift_update(s: DriftState, x, *, k: float, h: float, ph_delta: float,
                 ph_lambda: float, ema_slow: float, ema_fast: float,
                 warmup: int, zclip: float, var_floor: float) -> DriftState:
    """One observation through both detectors. Branchless; safe under
    vmap/scan. On alarm the baseline re-anchors to the fast EMA and the
    accumulators reset, so the shifted regime becomes the new normal."""
    x = jnp.asarray(x, jnp.float32)
    armed = (s.count >= warmup).astype(jnp.float32)

    sd = jnp.sqrt(jnp.maximum(s.var, var_floor))
    z = jnp.clip((x - s.mu) / sd, -zclip, zclip) * armed

    g_pos = jnp.maximum(0.0, s.g_pos + z - k) * armed
    g_neg = jnp.maximum(0.0, s.g_neg - z - k) * armed
    m_up = (s.m_up + z - ph_delta) * armed
    m_up_min = jnp.minimum(s.m_up_min, m_up)
    m_dn = (s.m_dn + z + ph_delta) * armed
    m_dn_max = jnp.maximum(s.m_dn_max, m_dn)
    ph_up = m_up - m_up_min
    ph_dn = m_dn_max - m_dn

    stat = jnp.maximum(jnp.maximum(g_pos, g_neg) / h,
                       jnp.maximum(ph_up, ph_dn) / ph_lambda)
    alarm = (stat >= 1.0).astype(jnp.float32) * armed

    # Baseline update: running mean during warmup, then slow EMA; the fast
    # channel tracks the same recursion at ema_fast. Welford-style EW
    # variance: var' = (1 - r)(var + r * delta^2).
    boot = 1.0 / (s.count + 1.0)
    r_s = jnp.where(s.count < warmup, boot, ema_slow)
    d_s = x - s.mu
    mu_s = s.mu + r_s * d_s
    var_s = (1.0 - r_s) * (s.var + r_s * d_s * d_s)
    r_f = jnp.maximum(ema_fast, boot)
    d_f = x - s.mu_f
    mu_f = s.mu_f + r_f * d_f
    var_f = (1.0 - r_f) * (s.var_f + r_f * d_f * d_f)

    return DriftState(
        mu=jnp.where(alarm > 0, mu_f, mu_s),
        var=jnp.where(alarm > 0, jnp.maximum(var_f, var_floor), var_s),
        mu_f=mu_f, var_f=var_f, count=s.count + 1.0,
        g_pos=jnp.where(alarm > 0, 0.0, g_pos),
        g_neg=jnp.where(alarm > 0, 0.0, g_neg),
        m_up=jnp.where(alarm > 0, 0.0, m_up),
        m_up_min=jnp.where(alarm > 0, 0.0, m_up_min),
        m_dn=jnp.where(alarm > 0, 0.0, m_dn),
        m_dn_max=jnp.where(alarm > 0, 0.0, m_dn_max),
        score=jnp.maximum(s.score, stat),
        flag=jnp.maximum(s.flag, alarm))
