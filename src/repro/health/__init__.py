"""Fleet health observatory: learning-dynamics state inside the jitted scan.

The contract mirrors PR 8's tracer: health is an *optional* field of the
``Fleet`` pytree. ``None`` (the default) flattens to an empty subtree, so
disabled runs stage the exact pre-PR program — bit-identical histories,
unchanged golden tests, unchanged donation audit. Enabled, the state is a
``HealthState`` of agent-leading float32 leaves updated by pure pytree ops
(no host callbacks on the hot path):

* per-episode, inside ``run_episode``'s metrics tail: telemetry sketches
  (``sketch.py``) + drift detectors (``drift.py``) consume the episode's
  per-interval telemetry (batched sketch updates + a vmapped-over-agents
  detector ``lax.scan``);
* per-``fl_round``: contribution attribution (``attribution.py``) scores
  each selected client's wire delta and folds it into a suspicion EMA
  that ``resilience/guards.py`` can gate selection on;
* per-episode, host-side: O(bins) summaries ride the existing metrics
  stream, where ``alerts.py`` evaluates declarative rules into
  ``ALERTS.jsonl`` and ``launch/watch.py`` renders them live.

``HealthConfig`` is a frozen dataclass threaded through the drivers as a
jit-static argument, like ``TransportConfig``/``FaultConfig``/
``GuardConfig``: presence means on, ``None`` means off.

The episode update is engineered for the <=5% overhead budget
(benchmarks/fig_health.py gates it): the order-independent sketches
(histogram counts, action marginals) consume every interval through
batched scatter-adds/reductions OUTSIDE the sequential path, and only the
inherently sequential detectors (the P² marker and the CUSUM/Page-Hinkley
channels) run in a ``lax.scan`` — over ``stride``-mean samples, so the
scan is ``n_steps / stride`` long instead of ``n_steps``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.health.attribution import attribution_scores
from repro.health.drift import (DriftState, drift_init, drift_reset_episode,
                                drift_update)
from repro.health.sketch import (P2State, hist_init, hist_merge,
                                 hist_quantile, hist_update,
                                 hist_update_batch, p2_init, p2_update,
                                 p2_value)

__all__ = [
    "HealthConfig", "HealthState", "DEFAULT_HEALTH", "HEALTH_METRIC_KEYS",
    "health_init", "update_episode", "episode_summaries", "update_round",
    "attribution_scores", "DriftState", "P2State", "hist_merge",
]


@dataclass(frozen=True)
class HealthConfig:
    """Jit-static knob block for the observatory. ``bins``: histogram
    resolution (quantile error <= one bin width); ``cusum_k``/``cusum_h``
    and ``ph_delta``/``ph_lambda``: detector thresholds (defaults sized so
    an i.i.d. stream false-alarms with probability ~exp(-2kh) ~ 5e-5 per
    run); ``stride``: intervals per detector sample — the sequential
    detectors consume ``stride``-mean telemetry, which shortens the
    in-scan sequential chain by that factor (``n_steps`` must be a
    multiple); ``warmup``: detector *samples* (not intervals) before the
    detectors arm; ``susp_beta``: EMA weight on the newest round's
    attribution score."""
    bins: int = 16
    stride: int = 10
    reward_lo: float = -1.0
    reward_hi: float = 1.0
    cusum_k: float = 0.5
    cusum_h: float = 10.0
    ph_delta: float = 0.2
    ph_lambda: float = 25.0
    ema_slow: float = 0.02
    ema_fast: float = 0.3
    warmup: int = 10
    zclip: float = 8.0
    var_floor: float = 1e-3
    susp_beta: float = 0.5

    def __post_init__(self):
        if self.bins < 2:
            raise ValueError("bins must be >= 2")
        if self.reward_hi <= self.reward_lo:
            raise ValueError("reward_hi must exceed reward_lo")
        for name in ("cusum_k", "cusum_h", "ph_delta", "ph_lambda",
                     "zclip", "var_floor"):
            if getattr(self, name) <= 0.0:
                raise ValueError(f"{name} must be > 0")
        for name in ("ema_slow", "ema_fast", "susp_beta"):
            if not (0.0 < getattr(self, name) <= 1.0):
                raise ValueError(f"{name} must be in (0, 1]")
        if self.warmup < 1:
            raise ValueError("warmup must be >= 1")
        if self.stride < 1:
            raise ValueError("stride must be >= 1")


DEFAULT_HEALTH = HealthConfig()

# Per-episode summary keys merged into the metrics stream (all (A,) on
# device, fleet-reduced by the drivers like every other episode metric).
HEALTH_METRIC_KEYS = (
    "health_reward_p50", "health_reward_p10", "health_reward_p90",
    "health_miss_p90", "health_act_entropy", "health_drift_score",
    "health_drift_flag", "health_susp",
)


class HealthState(NamedTuple):
    """All leaves agent-leading float32 — sharded by the same
    ``agent_spec`` rule as every other per-agent fleet leaf, donated with
    the rest of the fleet state."""
    reward_hist: jnp.ndarray   # (A, bins)
    miss_hist: jnp.ndarray     # (A, bins)
    reward_p2: P2State         # leaves (A, 5) / (A,)
    act_sum: jnp.ndarray       # (A, K) running sum of action marginals
    n_obs: jnp.ndarray         # (A,) intervals observed
    drift_reward: DriftState   # leaves (A,)
    drift_rate: DriftState     # leaves (A,)
    susp: jnp.ndarray          # (A,) attribution suspicion EMA
    susp_last: jnp.ndarray     # (A,) raw suspicion from the last FL round
    sel_last: jnp.ndarray      # (A,) selection mask at that round


def health_init(hcfg: HealthConfig, n_agents: int,
                n_actions: int) -> HealthState:
    def bcast(x):
        return jnp.broadcast_to(x, (n_agents,) + jnp.shape(x)).copy()
    zeros = jnp.zeros((n_agents,), jnp.float32)
    return HealthState(
        reward_hist=jnp.zeros((n_agents, hcfg.bins), jnp.float32),
        miss_hist=jnp.zeros((n_agents, hcfg.bins), jnp.float32),
        reward_p2=jax.tree.map(bcast, p2_init(0.5)),
        act_sum=jnp.zeros((n_agents, n_actions), jnp.float32),
        n_obs=zeros,
        drift_reward=jax.tree.map(bcast, drift_init()),
        drift_rate=jax.tree.map(bcast, drift_init()),
        susp=zeros, susp_last=zeros, sel_last=zeros)


def _detector_kwargs(hcfg: HealthConfig) -> dict:
    return dict(k=hcfg.cusum_k, h=hcfg.cusum_h, ph_delta=hcfg.ph_delta,
                ph_lambda=hcfg.ph_lambda, ema_slow=hcfg.ema_slow,
                ema_fast=hcfg.ema_fast, warmup=hcfg.warmup,
                zclip=hcfg.zclip, var_floor=hcfg.var_floor)


def update_episode(hcfg: HealthConfig, state: HealthState, reward, miss,
                   probs, rate) -> HealthState:
    """Advance every agent's sketches and detectors through one episode of
    per-interval telemetry. ``reward``/``miss``/``rate``: (A, T);
    ``probs``: (A, T, K). Engineered for the overhead budget: histogram
    counts and action marginals commute, so the full episode lands in two
    batched scatter-adds and one reduction; only the order-dependent
    detectors scan — over ``stride``-mean samples, with the two drift
    channels stepping as ONE stacked (2,)-leaf update. Everything stays
    inside the compiled program."""
    dk = _detector_kwargs(hcfg)
    t = reward.shape[1]
    s = hcfg.stride
    if t % s != 0:
        raise ValueError(
            f"episode length {t} is not a multiple of HealthConfig.stride="
            f"{s}; pick a stride that divides cfg.n_steps")

    def per_agent(st: HealthState, r, m, p, ra) -> HealthState:
        st = st._replace(
            reward_hist=hist_update_batch(st.reward_hist, r,
                                          hcfg.reward_lo, hcfg.reward_hi),
            miss_hist=hist_update_batch(st.miss_hist, m, 0.0, 1.0),
            act_sum=st.act_sum + jnp.sum(p.astype(jnp.float32), axis=0),
            n_obs=st.n_obs + float(t),
            drift_reward=drift_reset_episode(st.drift_reward),
            drift_rate=drift_reset_episode(st.drift_rate))

        # the P² marker tracks the median of stride-mean reward (the raw-
        # sample quantiles live in the histogram sketch); the detectors
        # standardize per-sample, so the stride only trades detection
        # granularity, not sensitivity to sustained shifts
        rs = jnp.mean(r.reshape(t // s, s), axis=1)
        ras = jnp.mean(ra.reshape(t // s, s), axis=1)
        drift2 = jax.tree.map(lambda a, b: jnp.stack([a, b]),
                              st.drift_reward, st.drift_rate)

        def step(carry, x):
            p2, d2 = carry
            r_t, ra_t = x
            return (p2_update(p2, r_t, 0.5),
                    drift_update(d2, jnp.stack([r_t, ra_t]), **dk)), None

        (p2, d2), _ = lax.scan(step, (st.reward_p2, drift2), (rs, ras))
        return st._replace(
            reward_p2=p2,
            drift_reward=jax.tree.map(lambda x: x[0], d2),
            drift_rate=jax.tree.map(lambda x: x[1], d2))

    return jax.vmap(per_agent)(state, reward, miss, probs, rate)


def episode_summaries(hcfg: HealthConfig, state: HealthState) -> dict:
    """O(bins) per-agent digests of the sketch/detector state — the (A,)
    arrays merged into the episode metrics (keys ``HEALTH_METRIC_KEYS``)."""
    def rq(p):
        return jax.vmap(lambda c: hist_quantile(
            c, p, hcfg.reward_lo, hcfg.reward_hi))(state.reward_hist)

    marg = state.act_sum / jnp.maximum(state.n_obs, 1.0)[:, None]
    pm = marg / jnp.maximum(jnp.sum(marg, axis=1, keepdims=True), 1e-9)
    entropy = -jnp.sum(pm * jnp.log(pm + 1e-9), axis=1)
    return {
        "health_reward_p50": jax.vmap(p2_value)(state.reward_p2),
        "health_reward_p10": rq(0.10),
        "health_reward_p90": rq(0.90),
        "health_miss_p90": jax.vmap(lambda c: hist_quantile(
            c, 0.90, 0.0, 1.0))(state.miss_hist),
        "health_act_entropy": entropy,
        "health_drift_score": jnp.maximum(state.drift_reward.score,
                                          state.drift_rate.score),
        "health_drift_flag": jnp.maximum(state.drift_reward.flag,
                                         state.drift_rate.flag),
        "health_susp": state.susp,
    }


def update_round(hcfg: HealthConfig, state: HealthState, susp_new,
                 sel) -> HealthState:
    """Fold one FL round's attribution scores into the suspicion EMA.
    Unselected clients keep their EMA (no evidence either way);
    ``susp_last``/``sel_last`` snapshot the raw round for benchmarks and
    the stream."""
    sel32 = sel.astype(jnp.float32)
    beta = hcfg.susp_beta
    ema = jnp.where(sel32 > 0,
                    (1.0 - beta) * state.susp + beta * susp_new,
                    state.susp)
    return state._replace(susp=ema, susp_last=susp_new * sel32,
                          sel_last=sel32)
