"""Declarative alert rules over the metrics stream -> ``ALERTS.jsonl``.

The sketches and detectors live on-device; *acting* on them is a host
concern. ``AlertEngine`` is a duck-typed metrics sink (same ``append`` /
``close`` surface as ``eval.stream.MetricsSink``) that sits in front of
the real sink: every per-episode record passes through unchanged to the
forwarded sink, and on the way each ``AlertRule`` predicate is evaluated
host-side. A rule that holds for ``window`` consecutive records fires
once (one ``{"kind": "alert", ...}`` JSONL line) and stays latched until
its predicate clears, which writes a matching ``"resolve"`` line — so a
10k-episode incident is two lines, not 10k.

Rules are data, not code: ``(name, metric, op, threshold, window,
severity)`` — the schema ``docs/observability.md`` documents and
``launch/watch.py --alerts`` renders. Records missing the rule's metric
(pre-PR-10 files, device records, FL-only episodes) simply don't advance
the rule — mixed-schema streams degrade to fewer evaluations, never to a
crash.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

ALERT_KIND = "alert"
RESOLVE_KIND = "resolve"
_OPS = ("gt", "lt")
_SEVERITIES = ("info", "warn", "crit")


@dataclass(frozen=True)
class AlertRule:
    """``metric op threshold`` sustained for ``window`` consecutive
    records fires the rule."""
    name: str
    metric: str
    op: str
    threshold: float
    window: int = 1
    severity: str = "warn"

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unknown op {self.op!r}; expected {_OPS}")
        if self.severity not in _SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}; "
                             f"expected {_SEVERITIES}")
        if self.window < 1:
            raise ValueError("window must be >= 1")

    def holds(self, value: float) -> bool:
        return value > self.threshold if self.op == "gt" \
            else value < self.threshold


# The standing rulebook: a drift flag is an event worth one line the
# moment it happens; suspicion and SLO-miss need to *persist* before they
# page anyone; a reward collapse is the one that matters most and is the
# noisiest, hence the longest window.
DEFAULT_RULES: Tuple[AlertRule, ...] = (
    AlertRule("drift-detected", "health_drift_flag", "gt", 0.5, 1, "warn"),
    AlertRule("suspect-clients", "health_susp", "gt", 0.5, 2, "crit"),
    AlertRule("slo-miss-p90", "health_miss_p90", "gt", 0.9, 3, "warn"),
    AlertRule("reward-collapse", "health_reward_p50", "lt", -0.5, 4, "crit"),
)


class AlertEngine:
    """Tee sink: forwards every record downstream, evaluates the rulebook,
    appends fire/resolve lines to ``path``. Use in place of (or wrapping)
    a ``MetricsSink`` wherever the drivers take ``metrics_sink=``."""

    def __init__(self, path: str, rules: Tuple[AlertRule, ...] = DEFAULT_RULES,
                 forward: Optional[Any] = None):
        self.path = path
        self.rules = tuple(rules)
        self.forward = forward
        self._streak = {r.name: 0 for r in self.rules}
        self._active = {r.name: False for r in self.rules}
        self.n_alerts = 0
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        self._f = open(path, "w")

    def _emit(self, kind: str, rule: AlertRule, record: Dict[str, Any],
              value: float):
        self._f.write(json.dumps({
            "kind": kind, "rule": rule.name, "metric": rule.metric,
            "op": rule.op, "threshold": rule.threshold,
            "severity": rule.severity, "value": float(value),
            "episode": record.get("episode"),
        }, sort_keys=True, default=float) + "\n")
        self._f.flush()

    def append(self, record: Dict[str, Any]):
        if self.forward is not None:
            self.forward.append(record)
        num = lambda v: isinstance(v, (int, float)) \
            and not isinstance(v, bool)
        for rule in self.rules:
            value = record.get(rule.metric)
            if not num(value):
                continue  # record predates the metric, or isn't an episode
            if rule.holds(value):
                self._streak[rule.name] += 1
                if (self._streak[rule.name] >= rule.window
                        and not self._active[rule.name]):
                    self._active[rule.name] = True
                    self.n_alerts += 1
                    self._emit(ALERT_KIND, rule, record, value)
            else:
                self._streak[rule.name] = 0
                if self._active[rule.name]:
                    self._active[rule.name] = False
                    self._emit(RESOLVE_KIND, rule, record, value)

    @property
    def n_records(self):
        return getattr(self.forward, "n_records", 0)

    def close(self):
        if not self._f.closed:
            self._f.close()
        if self.forward is not None:
            self.forward.close()

    def __enter__(self) -> "AlertEngine":
        return self

    def __exit__(self, *exc):
        self.close()


def read_alerts(path: str) -> List[Dict[str, Any]]:
    """Parse an ALERTS.jsonl file; tolerates a torn live tail like
    ``eval.stream.read_metrics``. Missing file reads as no alerts."""
    if not os.path.exists(path):
        return []
    out: List[Dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return out
