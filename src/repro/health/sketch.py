"""Telemetry sketches: fixed-size streaming summaries of per-interval signals.

The fleet drivers run as ONE jitted donated scan; at A=2048 streaming every
per-episode record off-device to see a distribution is exactly the host
traffic the scan exists to avoid. A sketch is the fix: O(bins) pure pytree
state per agent, rank-1 updated once per control interval *inside* the
scan, queried as a handful of scalars per episode. Two sketch families:

* **Fixed-bin histograms** (``hist_*``) over signals with a known range —
  reward is ``tanh``-bounded in (-1, 1), the SLO-miss rate lives in
  [0, 1]. Quantile queries invert the CDF with in-bin interpolation; the
  estimate is guaranteed within ONE bin width of the exact inverted-CDF
  empirical quantile of the stream (the bound tests/test_health*.py lock).
* **P² marker sketches** (``p2_*``) — Jain & Chlamtac's five-marker
  streaming quantile estimator: five heights + five positions + five
  desired positions, updated per observation with the parabolic (P²)
  interpolation formula, linear fallback when the parabola would break
  marker monotonicity. Range-free (no bin bounds needed), O(1) state.

Both are branchless (``jnp.where`` everywhere, no data-dependent control
flow) so they vmap over the agent axis and scan over intervals without
leaving the compiled program.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Fixed-bin histogram sketch
# ---------------------------------------------------------------------------


def hist_init(bins: int) -> jnp.ndarray:
    """All-empty (bins,) float32 count vector."""
    return jnp.zeros((bins,), jnp.float32)


def hist_update(counts: jnp.ndarray, x, lo: float, hi: float) -> jnp.ndarray:
    """Rank-1 update: drop one observation into its bin (out-of-range
    values clamp to the edge bins, so the total count stays exact)."""
    b = counts.shape[0]
    i = jnp.clip(((x - lo) / (hi - lo) * b).astype(jnp.int32), 0, b - 1)
    return counts.at[i].add(1.0)


def hist_update_batch(counts: jnp.ndarray, xs: jnp.ndarray, lo: float,
                      hi: float) -> jnp.ndarray:
    """Whole-episode update: histogram counts commute, so a (T,) batch of
    observations lands in ONE scatter-add — identical result to T
    ``hist_update`` calls, with no sequential dependency for the compiler
    to respect."""
    b = counts.shape[0]
    i = jnp.clip(((xs - lo) / (hi - lo) * b).astype(jnp.int32), 0, b - 1)
    return counts.at[i].add(1.0)


def hist_quantile(counts: jnp.ndarray, p: float, lo: float, hi: float):
    """Inverted-CDF quantile with in-bin linear interpolation.

    The exact empirical quantile (smallest x with CDF(x) >= p) lies in the
    first bin whose cumulative count reaches ``p * total``; the returned
    value lies in that same bin, so the value error is bounded by one bin
    width for in-range streams. Returns ``lo`` on an empty sketch."""
    b = counts.shape[0]
    c = jnp.cumsum(counts)
    total = c[-1]
    target = p * total
    i = jnp.clip(jnp.sum((c < target).astype(jnp.int32)), 0, b - 1)
    prev = jnp.where(i > 0, c[jnp.maximum(i - 1, 0)], 0.0)
    frac = jnp.clip((target - prev) / jnp.maximum(counts[i], 1e-9), 0.0, 1.0)
    return lo + (hi - lo) * (i.astype(jnp.float32) + frac) / b


def hist_merge(stacked_counts: jnp.ndarray) -> jnp.ndarray:
    """Merge per-agent sketches (A, bins) into one fleet sketch (bins,) —
    histograms over a shared range merge by addition, which is what makes
    the per-agent state a fleet-watchable summary."""
    return jnp.sum(stacked_counts, axis=0)


# ---------------------------------------------------------------------------
# P² streaming quantile sketch (Jain & Chlamtac 1985)
# ---------------------------------------------------------------------------
class P2State(NamedTuple):
    """Five-marker P² state. ``q``: marker heights; ``n``: actual marker
    positions (0-indexed ranks); ``npos``: desired positions; ``count``:
    observations seen. Heights start at +inf so the warmup sort (first five
    observations fill the markers) keeps empty slots at the top."""
    q: jnp.ndarray      # (5,) f32 marker heights
    n: jnp.ndarray      # (5,) f32 marker positions
    npos: jnp.ndarray   # (5,) f32 desired marker positions
    count: jnp.ndarray  # () f32


def p2_init(p: float) -> P2State:
    return P2State(
        q=jnp.full((5,), jnp.inf, jnp.float32),
        n=jnp.arange(5, dtype=jnp.float32),
        npos=jnp.asarray([0.0, 2 * p, 4 * p, 2 + 2 * p, 4.0], jnp.float32),
        count=jnp.zeros((), jnp.float32))


def _safe_div(a, b):
    return a / jnp.where(b == 0, 1.0, b)


def p2_update(s: P2State, x, p: float) -> P2State:
    """One observation, branchless. Warmup (count < 5): insert + sort (the
    +inf fill keeps unfilled slots ordered above every real value). After:
    the textbook P² step — locate the cell, shift marker positions, move
    interior markers by the parabolic formula with linear fallback."""
    x = jnp.asarray(x, jnp.float32)
    c = s.count
    in_warm = c < 5.0

    # --- warmup: place x in the next free slot, keep heights sorted
    slot = jnp.minimum(c, 4.0).astype(jnp.int32)
    q_warm = jnp.sort(s.q.at[slot].set(x))

    # --- steady state
    q = s.q.at[0].min(x).at[4].max(x)
    k = jnp.clip(jnp.sum((x >= q).astype(jnp.int32)) - 1, 0, 3)
    n = s.n + (jnp.arange(5) > k).astype(jnp.float32)
    npos = s.npos + jnp.asarray([0.0, p / 2, p, (1 + p) / 2, 1.0],
                                jnp.float32)
    for i in (1, 2, 3):
        d = npos[i] - n[i]
        up = (d >= 1.0) & (n[i + 1] - n[i] > 1.0)
        dn = (d <= -1.0) & (n[i - 1] - n[i] < -1.0)
        ds = jnp.where(up, 1.0, jnp.where(dn, -1.0, 0.0))
        qp = q[i] + _safe_div(ds, n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + ds) * _safe_div(q[i + 1] - q[i],
                                               n[i + 1] - n[i])
            + (n[i + 1] - n[i] - ds) * _safe_div(q[i] - q[i - 1],
                                                 n[i] - n[i - 1]))
        q_nb = jnp.where(ds > 0, q[i + 1], q[i - 1])
        n_nb = jnp.where(ds > 0, n[i + 1], n[i - 1])
        ql = q[i] + ds * _safe_div(q_nb - q[i], n_nb - n[i])
        use_lin = (qp <= q[i - 1]) | (qp >= q[i + 1])
        q = q.at[i].set(jnp.where(ds != 0,
                                  jnp.where(use_lin, ql, qp), q[i]))
        n = n.at[i].set(n[i] + ds)

    return P2State(
        q=jnp.where(in_warm, q_warm, q),
        n=jnp.where(in_warm, s.n, n),
        npos=jnp.where(in_warm, s.npos, npos),
        count=c + 1.0)


def p2_value(s: P2State):
    """The current quantile estimate (the middle marker). During warmup
    (< 5 observations) falls back to the median of the filled slots."""
    filled = jnp.isfinite(s.q)
    n_f = jnp.maximum(jnp.sum(filled.astype(jnp.int32)), 1)
    # pad unfilled slots HIGH (+inf, matching the warmup sort) so the
    # lower-median index lands on a real observation
    mid = jnp.sort(jnp.where(filled, s.q, jnp.inf))[(n_f - 1) // 2]
    return jnp.where(s.count >= 5.0, s.q[2], mid)
