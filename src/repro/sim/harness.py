"""Closed-loop twin harness: trained FCPO policies driving the request-level
data plane.

``simulate_fleet`` runs a whole fleet evaluation as ONE jitted program:
a ``lax.scan`` over control intervals where each interval observes the twin
state, samples the iAgent actions (policy applied every k_ticks microticks,
exactly the paper's 1 s control cadence), decodes them to service caps, and
advances K microticks through ``sim_interval`` (vmapped jnp oracle or the
fused Pallas kernel). There is zero host-side Python per microtick — the
host dispatches once and fetches the per-interval history once.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.fcpo import FCPOConfig
from repro.core.agent import ActionMask, sample_actions
from repro.core.env import EnvParams, observe_vector
from repro.sim import metrics as sim_metrics
from repro.sim.state import (SimParams, SimState, action_caps,
                             effective_queue_cap, sim_init, spread_arrivals,
                             warn_if_ring_clamps)
from repro.sim.step import sim_interval, sim_interval_recorded


def sim_observe(cfg: FCPOConfig, sp: SimParams, ep: EnvParams,
                state: SimState, drops_prev, cur_action, rate):
    """The 8-dim iAgent state vector (§IV-B) read off the twin instead of
    the fluid MDP. The normalization is ``core.env.observe_vector`` — the
    ONE definition every backend shares — so a policy trained on the fluid
    env transfers without retargeting (parity: tests/test_backends.py)."""
    return observe_vector(cfg, rate=rate, cur_action=cur_action,
                          drops=drops_prev, pre_q=state.pre_q,
                          post_q=state.post_q,
                          queue_cap=effective_queue_cap(sp, ep),
                          slo_s=ep.slo_s)


@partial(jax.jit, static_argnums=(0, 1),
         static_argnames=("use_pallas", "record_ticks"))
def _simulate(cfg: FCPOConfig, sp: SimParams, params, masks: ActionMask,
              env_params: EnvParams, traces, key, use_pallas: bool = False,
              record_ticks: bool = False):
    a = traces.shape[0]
    state0 = jax.vmap(lambda _: sim_init(sp))(jnp.arange(a))

    def interval(carry, rate):
        state, drops_prev, cur_action, phase, rng = carry
        rng, k = jax.random.split(rng)
        obs = jax.vmap(
            lambda e, s, d, ca, r: sim_observe(cfg, sp, e, s, d, ca, r)
        )(env_params, state, drops_prev, cur_action, rate)
        actions, _, _ = jax.vmap(
            lambda p, o, m, kk: sample_actions(cfg, p, o, m, kk)
        )(params, obs, masks, jax.random.split(k, a))
        caps = jax.vmap(
            lambda e, ac: action_caps(cfg, sp, e, ac))(env_params, actions)
        arrivals, phase = jax.vmap(
            lambda r, ph: spread_arrivals(sp, r, ph))(rate, phase)
        if record_ticks:
            state2, ticks = jax.vmap(sim_interval_recorded)(state, arrivals,
                                                            caps)
        else:
            state2 = sim_interval(state, arrivals, caps, use_pallas)

        d_comp = (state2.completed - state.completed).astype(jnp.float32)
        d_drop = state2.dropped - state.dropped
        ys = {
            "throughput": d_comp / sp.interval_s,
            "effective_throughput":
                (state2.effective - state.effective).astype(jnp.float32)
                / sp.interval_s,
            "drops": d_drop.astype(jnp.float32),
            "latency": (state2.lat_sum - state.lat_sum)
                / jnp.maximum(d_comp, 1.0) * sp.dt,
            "pre_q": state2.pre_q.astype(jnp.float32),
            "post_q": state2.post_q.astype(jnp.float32),
        }
        if record_ticks:
            ys["tick_counters"] = ticks  # (A, K, SIM_NCOUNTERS) int32
            ys["caps"] = caps            # (A, SIM_NCAPS) — slo at the tick
        return (state2, d_drop, actions, phase, rng), ys

    init = (state0, jnp.zeros((a,), jnp.int32),
            jnp.zeros((a, 3), jnp.int32), jnp.zeros((a,), jnp.float32), key)
    (state, *_), history = jax.lax.scan(interval, init, traces.T)
    return state, history


def simulate_fleet(cfg: FCPOConfig, sp: SimParams, params,
                   masks: ActionMask, env_params: EnvParams, traces, key,
                   use_pallas: bool = False, record_ticks: bool = False
                   ) -> Tuple[SimState, Dict, Dict]:
    """Drive a fleet of trained policies through the request-level twin.

    params/masks/env_params: agent-stacked (A, ...) pytrees (e.g. a trained
    ``Fleet``'s ``astate.params`` / ``masks`` / ``env_params``); traces:
    (A, T) control-interval arrival rates (requests/s). Returns
    (final SimState (A, ...), per-interval history dict of (T, A) arrays,
    per-agent request-grade summary incl. p50/p99 latency).

    ``record_ticks``: additionally emit the per-microtick counter series
    (``history["tick_counters"]``: (T, A, K, SIM_NCOUNTERS) int32) and the
    held interval caps (``history["caps"]``) — the raw material
    ``repro.obs.requests`` turns into per-request stage stamps. jnp oracle
    path only (the fused Pallas kernel advances a whole interval per call,
    so there is no per-tick state to observe); the carried twin state is
    bit-identical to the unrecorded run."""
    if record_ticks and use_pallas:
        raise ValueError("record_ticks requires the jnp oracle path "
                         "(use_pallas=False): the fused kernel has no "
                         "per-tick state to record")
    warn_if_ring_clamps(sp, jax.device_get(env_params.queue_cap),
                        stacklevel=2)
    state, history = _simulate(cfg, sp, params, masks, env_params,
                               jnp.asarray(traces, jnp.float32), key,
                               use_pallas=use_pallas,
                               record_ticks=record_ticks)
    summary = sim_metrics.summarize(state, sp)
    sim_metrics.warn_if_censored(summary, sp, stacklevel=3)
    return state, history, summary


def eval_fleet(cfg: FCPOConfig, sp: SimParams, fleet, traces, key,
               use_pallas: bool = False, record_ticks: bool = False
               ) -> Tuple[SimState, Dict, Dict]:
    """``simulate_fleet`` for a trained fleet object: reads the stacked
    policy/mask/device-profile leaves off anything Fleet-shaped
    (``.astate.params`` / ``.masks`` / ``.env_params`` — duck-typed, so this
    module never imports ``core.fleet``). The one request-grade evaluation
    entry the leaderboard (``repro.eval``) and the benchmarks share."""
    return simulate_fleet(cfg, sp, fleet.astate.params, fleet.masks,
                          fleet.env_params, traces, key,
                          use_pallas=use_pallas, record_ticks=record_ticks)
