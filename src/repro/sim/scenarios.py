"""Canned twin evaluation scenarios over the workload-trace generators."""
from __future__ import annotations

import jax.numpy as jnp

from repro.data import workload


def make_scenario(name: str, key, n_agents: int, n_intervals: int
                  ) -> jnp.ndarray:
    """(A, T) control-interval arrival-rate traces for a named scenario."""
    if name == "steady":
        return workload.fleet_traces(key, n_agents, n_intervals,
                                     **workload.PROFILING)
    if name == "dynamic":
        return workload.fleet_traces(key, n_agents, n_intervals,
                                     **workload.DYNAMIC)
    if name == "switching":
        return workload.switching_traces(key, n_agents, n_intervals,
                                         segment=max(n_intervals // 5, 1))
    if name == "ood":
        return workload.ood_traces(key, n_agents, n_intervals)
    raise ValueError(f"unknown scenario {name!r}; "
                     f"choose from {sorted(SCENARIOS)}")


SCENARIOS = ("steady", "dynamic", "switching", "ood")
