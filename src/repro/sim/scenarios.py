"""Scenario library: named workload scenarios over the trace generators.

One registry used by BOTH training and evaluation — ``make_scenario`` feeds
the fleet training CLI (``launch/train_fleet.py --scenario``), twin
evaluations (``launch/simulate.py``), and the fluid-trained-vs-twin-trained
benchmark (``benchmarks/fig_twin_training.py``), so "train on scenario X,
evaluate on scenario Y" is a pair of names.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.data import workload


def make_scenario(name: str, key, n_agents: int, n_intervals: int
                  ) -> jnp.ndarray:
    """(A, T) control-interval arrival-rate traces for a named scenario."""
    if name == "nominal":
        # make_trace defaults: the historical training workload of the fleet
        # CLI/examples — same key => same traces as pre-scenario-library runs
        return workload.fleet_traces(key, n_agents, n_intervals)
    if name == "steady":
        return workload.fleet_traces(key, n_agents, n_intervals,
                                     **workload.PROFILING)
    if name == "dynamic":
        return workload.fleet_traces(key, n_agents, n_intervals,
                                     **workload.DYNAMIC)
    if name == "burst":
        return workload.fleet_traces(key, n_agents, n_intervals,
                                     **workload.BURST)
    if name == "diurnal":
        return workload.diurnal_traces(key, n_agents, n_intervals)
    if name == "flash-crowd":
        return workload.flash_crowd_traces(key, n_agents, n_intervals)
    if name == "drift":
        return workload.drift_traces(key, n_agents, n_intervals)
    if name == "switching":
        return workload.switching_traces(key, n_agents, n_intervals,
                                         segment=max(n_intervals // 5, 1))
    if name == "ood":
        return workload.ood_traces(key, n_agents, n_intervals)
    raise ValueError(f"unknown scenario {name!r}; "
                     f"choose from {sorted(SCENARIOS)}")


SCENARIOS = ("nominal", "steady", "dynamic", "burst", "diurnal",
             "flash-crowd", "drift", "switching", "ood")
