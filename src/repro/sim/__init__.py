"""Request-level data-plane simulator (digital twin) — see
docs/architecture.md, "Request-level simulator" and "Environment
backends"."""
from repro.sim.harness import eval_fleet, sim_observe, simulate_fleet
from repro.sim.metrics import hist_percentile, summarize, warn_if_censored
from repro.sim.scenarios import SCENARIOS, make_scenario
from repro.sim.state import (SimParams, SimState, action_caps,
                             effective_queue_cap, sim_init, spread_arrivals)
from repro.sim.step import sim_interval, sim_interval_agent, sim_interval_ref

__all__ = [
    "SCENARIOS", "SimParams", "SimState", "action_caps",
    "effective_queue_cap", "eval_fleet", "hist_percentile", "make_scenario",
    "sim_init", "sim_interval", "sim_interval_agent", "sim_interval_ref",
    "sim_observe", "simulate_fleet", "spread_arrivals", "summarize",
    "warn_if_censored",
]
