"""Interval advance: the twin's data plane for one K-microtick interval.

``sim_interval_ref`` is the single-agent jnp oracle (a ``lax.scan`` over the
shared ``kernels.ref.sim_microtick``); ``sim_interval`` is the fleet-batched
entry point that either vmaps the oracle or routes the whole agent batch
through the fused Pallas ``queue_advance`` kernel — bit-identical paths
(tests/test_sim.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.sim.state import SimState


def sim_interval_ref(state: SimState, arrivals: jnp.ndarray,
                     caps: jnp.ndarray) -> SimState:
    """Advance ONE agent k_ticks microticks. arrivals: (K,) int32; caps:
    (SIM_NCAPS,) float32 (one action decode held for the interval)."""
    return SimState(*kref.queue_advance_ref(*state, arrivals, caps))


def sim_interval_agent(state: SimState, arrivals: jnp.ndarray,
                       caps: jnp.ndarray,
                       use_pallas: bool = False) -> SimState:
    """Advance ONE agent (the training-backend entry point — vmapped over
    the fleet by ``fleet_episode``): the jnp oracle scan, or the fused
    Pallas kernel, which accepts unbatched operands and carries a batching
    rule, so this call is legal under ``vmap`` on either path."""
    if use_pallas:
        return SimState(*kops.queue_advance(*state, arrivals, caps))
    return sim_interval_ref(state, arrivals, caps)


def sim_interval(state: SimState, arrivals: jnp.ndarray, caps: jnp.ndarray,
                 use_pallas: bool = False) -> SimState:
    """Fleet-batched advance: state leaves (A, ...), arrivals (A, K), caps
    (A, SIM_NCAPS). ``use_pallas`` fuses the whole interval per agent into
    one kernel call for the batch."""
    if use_pallas:
        return SimState(*kops.queue_advance(*state, arrivals, caps))
    return jax.vmap(sim_interval_ref)(state, arrivals, caps)


def sim_interval_recorded(state: SimState, arrivals: jnp.ndarray,
                          caps: jnp.ndarray):
    """Single-agent jnp advance that ALSO returns the counters vector after
    every microtick — the request-attribution tap (``repro.obs.requests``
    reconstructs per-request stage stamps from these monotone series).

    Same ``lax.scan`` of ``sim_microtick`` as ``sim_interval_ref`` with a
    per-tick ys output added, so the carried state is bit-identical to the
    unrecorded path (int32 counters — no float reassociation to worry
    about). Returns (new_state, (K, SIM_NCOUNTERS) int32)."""
    def tick(carry, n_arr):
        out = kref.sim_microtick(*carry, n_arr, caps)
        return out, out[1]

    carry, ticks = jax.lax.scan(tick, tuple(state), arrivals)
    return SimState(*carry), ticks
