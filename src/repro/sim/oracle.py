"""Host-side discrete-event oracle for the tensorized twin.

Mirrors ``kernels.ref.sim_microtick`` request-for-request using the plain
Python data-plane classes from ``serving/slo.py`` (``BoundedQueue`` /
``Request`` / ``SLOTracker``) — the reference the twin is equivalence-tested
against (tests/test_sim.py) and the baseline the fig_sim_fidelity benchmark
times. All times are in MICROTICKS (the tracker's ``slo_s`` is the deadline
in ticks), so with integer-representable service capacities the two
implementations agree exactly: same completions, drops, and effective
throughput.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.kernels.ref import (CAP_BATCH, CAP_POST, CAP_PRE, CAP_QCAP,
                               CAP_SLO, CAP_TBATCH)
from repro.serving.slo import BoundedQueue, Request, SLOTracker
from repro.sim.state import SimParams


def simulate_python_agent(arrivals: np.ndarray, caps: np.ndarray,
                          sp: SimParams) -> Dict[str, float]:
    """One agent through the Python data plane. arrivals: (T, K) int
    per-tick arrival counts; caps: (T, SIM_NCAPS) float (one action decode
    per control interval; queue_cap and slo must be constant — they are
    device properties, not actions). Returns the same request totals the
    twin accumulates."""
    arrivals = np.asarray(arrivals)
    caps = np.asarray(caps, np.float64)
    qcap = int(caps[0, CAP_QCAP])
    slo_ticks = int(caps[0, CAP_SLO])

    pre = BoundedQueue(capacity=qcap)
    ready: List[Request] = []       # batch-formation queue
    in_service: List[Request] = []  # the one in-flight inference batch
    post: List[Request] = []
    tracker = SLOTracker(slo_s=slo_ticks)
    busy, done_at = False, 0
    pre_credit = post_credit = 0.0
    rid, m = 0, 0

    for t in range(arrivals.shape[0]):
        c_pre, c_post = caps[t, CAP_PRE], caps[t, CAP_POST]
        batch_slots = int(caps[t, CAP_BATCH])
        t_batch = int(caps[t, CAP_TBATCH])
        for j in range(arrivals.shape[1]):
            # (1) inference completion -> post queue
            if busy and m >= done_at:
                post.extend(in_service)
                in_service, busy = [], False
            # (2) post-processing completes the n oldest
            post_credit = min(post_credit + c_post, c_post + 1.0)
            n = min(int(post_credit), len(post))
            if n:
                tracker.complete(post[:n], now=m + 1)
                post = post[n:]
            post_credit -= n
            # (3) batch launch, backpressured by post room
            if not busy:
                room = qcap - (len(post) + len(in_service))
                nl = min(len(ready), batch_slots, room)
                if nl > 0:
                    in_service, ready = ready[:nl], ready[nl:]
                    busy, done_at = True, m + t_batch
            # (4) pre-processing, backpressured by batch-formation room
            pre_credit = min(pre_credit + c_pre, c_pre + 1.0)
            n = min(int(pre_credit), len(pre), max(qcap - len(ready), 0))
            ready.extend(pre.pop_batch(n))
            pre_credit -= n
            # (5) admission; BoundedQueue counts the drops
            for _ in range(int(arrivals[t, j])):
                pre.push(Request(rid, arrival_t=m))
                rid += 1
            m += 1

    eff = sum(1 for _, lat, _ in tracker.completed if lat <= slo_ticks)
    return {
        "arrived": rid,
        "dropped": pre.drops,
        "completed": len(tracker.completed),
        "effective": eff,
        "lat_sum": float(sum(lat for _, lat, _ in tracker.completed)),
        "in_flight": len(pre.q) + len(ready) + len(in_service) + len(post),
        "effective_throughput": eff / max(m * sp.dt, 1e-9),
    }


def simulate_python_fleet(arrivals: np.ndarray, caps: np.ndarray,
                          sp: SimParams) -> List[Dict[str, float]]:
    """A agents sequentially through the Python oracle (this IS the
    baseline cost model: host-side per-agent loops). arrivals: (A, T, K);
    caps: (A, T, SIM_NCAPS)."""
    return [simulate_python_agent(arrivals[i], caps[i], sp)
            for i in range(arrivals.shape[0])]
