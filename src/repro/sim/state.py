"""State layout + action decode for the request-level data-plane twin.

A ``SimState`` is ONE agent's discrete-event pipeline (stack the leaves to
(A, ...) for a fleet): a power-of-two ring of arrival microticks plus the
monotone stage counters, token-bucket service credits, and request-grade
accumulators defined in ``repro.kernels.ref`` (the shared microtick math).
Stage membership is positional — each pipeline stage's occupants are a
contiguous ring segment between two counters — so queue lengths are counter
differences, a request's deadline is ``arrive + slo_ticks``, and sizes are
uniformly one object per request (the accumulators are the hook if
objects-per-frame weighting is ever needed).

``action_caps`` decodes an iAgent action (RES, BS, MT) into the per-tick
service capacities of the twin with the SAME formulas as the fluid
``core/env.py`` MDP (contention, frame packing, the t0 + t1·bs·area batch
curve), which is what makes fluid-vs-twin fidelity checks meaningful.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.configs.fcpo import FCPOConfig
from repro.core.env import EnvParams
from repro.kernels import ref as kref


@dataclass(frozen=True)
class SimParams:
    """Static twin geometry (hashable — a jit static argument)."""
    dt: float = 0.05     # microtick length (s); k_ticks*dt = control interval
    k_ticks: int = 20    # microticks per control interval (1 s in the paper)
    ring: int = 512      # ring capacity; power of two, >= 3 * queue_cap
    hist_n: int = 64     # latency histogram buckets (ticks)

    def __post_init__(self):
        assert self.ring > 0 and self.ring & (self.ring - 1) == 0, \
            "ring capacity must be a positive power of two"
        assert self.k_ticks >= 1 and self.hist_n >= 2

    @property
    def interval_s(self) -> float:
        return self.k_ticks * self.dt


class SimState(NamedTuple):
    """Per-agent twin state; all views below work batched (A, ...)."""
    arrive: jnp.ndarray    # (R,) int32 — arrival microtick per ring slot
    counters: jnp.ndarray  # (SIM_NCOUNTERS,) int32 — pointers + accumulators
    credits: jnp.ndarray   # (2,) float32 — pre/post fractional service credit
    lat_sum: jnp.ndarray   # () float32 — summed completed latency (ticks)
    hist: jnp.ndarray      # (H,) int32 — completed-latency histogram (ticks)

    # queue lengths are differences of the monotone stage counters
    @property
    def pre_q(self):
        return (self.counters[..., kref.SIM_TAIL]
                - self.counters[..., kref.SIM_PPRE])

    @property
    def batch_q(self):
        return (self.counters[..., kref.SIM_PPRE]
                - self.counters[..., kref.SIM_LAUNCH])

    @property
    def post_q(self):
        return (self.counters[..., kref.SIM_PINF]
                - self.counters[..., kref.SIM_HEAD])

    @property
    def in_flight(self):
        return (self.counters[..., kref.SIM_TAIL]
                - self.counters[..., kref.SIM_HEAD])

    @property
    def arrived(self):
        return self.counters[..., kref.SIM_ARRIVED]

    @property
    def dropped(self):
        return self.counters[..., kref.SIM_DROPPED]

    @property
    def completed(self):
        return self.counters[..., kref.SIM_COMPLETED]

    @property
    def effective(self):
        return self.counters[..., kref.SIM_EFFECTIVE]

    @property
    def tick(self):
        return self.counters[..., kref.SIM_TICK]


def sim_init(sp: SimParams) -> SimState:
    """One agent's empty pipeline (vmap over a dummy axis for a fleet)."""
    return SimState(
        arrive=jnp.zeros((sp.ring,), jnp.int32),
        counters=jnp.zeros((kref.SIM_NCOUNTERS,), jnp.int32),
        credits=jnp.zeros((2,), jnp.float32),
        lat_sum=jnp.zeros((), jnp.float32),
        hist=jnp.zeros((sp.hist_n,), jnp.int32),
    )


def effective_queue_cap(sp: SimParams, ep: EnvParams) -> jnp.ndarray:
    """Per-stage queue capacity, clamped so the ring can never overflow
    (each of the three stage queues is bounded by it)."""
    return jnp.minimum(ep.queue_cap, float(sp.ring // 3))


def warn_if_ring_clamps(sp: SimParams, queue_cap, stacklevel: int = 2) -> None:
    """THE host-side guard on the ``effective_queue_cap`` clamp (one
    definition for the evaluation harness and the training backend): warn
    when the ring cannot hold 3x the device queue_cap, because the clamp
    then changes twin dynamics, observation normalization, and — during
    twin-backed training — ``fl_round``'s Eq. 7 memory-availability stat
    (which normalizes ``pre_q`` by the *unclamped* cap). Call on concrete
    params, never under ``jit``."""
    qcap = np.asarray(queue_cap)
    if (qcap > sp.ring // 3).any():
        warnings.warn(
            f"SimParams.ring={sp.ring} clamps queue_cap "
            f"{float(qcap.max()):.0f} -> {sp.ring // 3} (ring must be >= "
            f"3*queue_cap); twin dynamics, observation normalization, and "
            f"the Eq. 7 memory-availability stat (twin-backed training) "
            f"will differ from the fluid env — raise `ring` to match the "
            f"device profile", stacklevel=stacklevel)


def action_caps(cfg: FCPOConfig, sp: SimParams, ep: EnvParams,
                action: jnp.ndarray) -> jnp.ndarray:
    """Decode one agent's (RES, BS, MT) action into a (SIM_NCAPS,) float32
    caps vector for the microtick kernel — same latency surface as
    ``core.env.env_step`` (mt contention, 1/area frame packing,
    t_batch = t0 + t1·bs·area), discretized to ticks."""
    res_scale = jnp.asarray(cfg.res_scales)[action[..., 0]]
    bs = jnp.asarray(cfg.bs_values, jnp.float32)[action[..., 1]]
    mt = jnp.asarray(cfg.mt_values, jnp.float32)[action[..., 2]]

    area = res_scale ** 2
    mt_eff = mt * jnp.maximum(1.0 - ep.contention * (mt - 1.0), 0.3)
    rate_pre = ep.pre_rate * mt_eff / jnp.maximum(area, 0.05)
    rate_post = ep.post_rate * mt_eff
    t_batch_s = ep.t0 + ep.t1 * bs * area

    return jnp.stack([
        rate_pre * sp.dt,
        rate_post * sp.dt,
        jnp.maximum(jnp.round(bs / area), 1.0),      # requests per batch
        jnp.maximum(jnp.ceil(t_batch_s / sp.dt), 1.0),
        jnp.round(effective_queue_cap(sp, ep)),
        jnp.maximum(jnp.round(ep.slo_s / sp.dt), 1.0),
    ]).astype(jnp.float32)


def spread_arrivals(sp: SimParams, rate, phase=0.0):
    """Deterministic per-tick arrival counts for one control interval.

    Cumulative-floor spreading of ``rate`` requests/s over k_ticks, with
    ``phase`` carrying the fractional request left over from previous
    intervals — so a steady 30.9 req/s admits 30.9 requests/s on average
    instead of a permanent floor(rate) deficit. Returns ((K,) int32 counts,
    new phase in [0, 1)); the interval total is
    floor(phase + rate * k_ticks * dt)."""
    phase = jnp.asarray(phase, jnp.float32)
    j = jnp.arange(1 + sp.k_ticks, dtype=jnp.float32)
    cum = jnp.floor(phase + rate * sp.dt * j)
    counts = (cum[1:] - cum[:-1]).astype(jnp.int32)
    end = phase + rate * sp.dt * sp.k_ticks
    return counts, end - jnp.floor(end)
