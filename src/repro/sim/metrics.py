"""Request-grade metrics from twin state: throughput, effective throughput,
drops, and latency percentiles from the on-device histogram."""
from __future__ import annotations

import warnings

import jax.numpy as jnp
import numpy as np

from repro.sim.state import SimParams, SimState

CENSORED_WARN_FRACTION = 0.01


def hist_percentile(hist: jnp.ndarray, q: float) -> jnp.ndarray:
    """q-quantile (in ticks) of a completed-latency histogram (..., H):
    the first bucket where the cumulative count reaches ceil(q * total).
    Empty histograms return 0. The histogram is right-censored at H-1
    ticks, so the result is a lower bound whenever the top bucket is
    populated (see ``summarize``'s ``hist_censored``)."""
    total = jnp.sum(hist, axis=-1, keepdims=True)
    cum = jnp.cumsum(hist, axis=-1)
    target = jnp.maximum(jnp.ceil(q * total), 1)
    idx = jnp.argmax(cum >= target, axis=-1)
    return jnp.where(total[..., 0] > 0, idx, 0)


def summarize(state: SimState, sp: SimParams) -> dict:
    """Per-agent request-grade summary (works batched): rates are per
    second over the simulated horizon; latencies in seconds.

    The histogram is right-censored: latencies beyond (hist_n-1) ticks all
    land in the top bucket, so the percentiles are capped at
    (hist_n-1) * dt. ``hist_censored`` reports the fraction of completions
    in that bucket — if it is non-negligible, re-run with a larger
    ``SimParams.hist_n`` before trusting p99 (``mean_latency_s`` comes from
    the unclipped latency sum and is never censored)."""
    secs = jnp.maximum(state.tick.astype(jnp.float32) * sp.dt, 1e-9)
    completed = state.completed.astype(jnp.float32)
    return {
        "hist_censored": (state.hist[..., -1].astype(jnp.float32)
                          / jnp.maximum(completed, 1.0)),
        "throughput": completed / secs,
        "effective_throughput": state.effective.astype(jnp.float32) / secs,
        # fraction of completions inside their per-request deadline — the
        # leaderboard's SLO-attainment column (1.0 when nothing completed:
        # an idle agent met every SLO it was given)
        "slo_attainment": (state.effective.astype(jnp.float32)
                           / jnp.maximum(completed, 1.0)),
        "drop_rate": (state.dropped.astype(jnp.float32)
                      / jnp.maximum(state.arrived.astype(jnp.float32), 1.0)),
        "mean_latency_s": (state.lat_sum / jnp.maximum(completed, 1.0)
                           * sp.dt),
        "p50_latency_s": hist_percentile(state.hist, 0.50)
        .astype(jnp.float32) * sp.dt,
        "p99_latency_s": hist_percentile(state.hist, 0.99)
        .astype(jnp.float32) * sp.dt,
        "arrived": state.arrived,
        "completed": state.completed,
        "dropped": state.dropped,
        "effective": state.effective,
        "in_flight": state.in_flight,
    }


def stage_breakdown_table(decomposition: dict) -> str:
    """Render a per-stage latency decomposition (the dict
    ``repro.obs.requests.stage_decomposition`` returns: stage ->
    {mean_s, p50_s, p99_s, p99_tail_mean_s}) as an aligned table — the
    "where does the tail go" block ``launch/simulate.py --attribution``
    prints. Takes a plain dict so this module stays free of any
    dependency on the observability layer."""
    lines = [f"{'stage':12s}{'mean':>10s}{'p50':>10s}{'p99':>10s}"
             f"{'p99-tail':>10s}"]
    for stage, row in decomposition.items():
        lines.append(
            f"{stage:12s}"
            f"{row['mean_s'] * 1e3:9.1f}ms{row['p50_s'] * 1e3:9.1f}ms"
            f"{row['p99_s'] * 1e3:9.1f}ms"
            f"{row['p99_tail_mean_s'] * 1e3:9.1f}ms")
    return "\n".join(lines)


def warn_if_censored(summary: dict, sp: SimParams,
                     threshold: float = CENSORED_WARN_FRACTION,
                     stacklevel: int = 2) -> float:
    """Host-side guard on histogram right-censoring: warn when the fraction
    of completions in the top (censored) bucket exceeds ``threshold`` on any
    agent — the reported p50/p99 are then lower bounds capped at
    ``(hist_n - 1) * dt``. Returns the worst per-agent censored fraction.
    Call on a concrete (fetched) ``summarize`` dict, never under ``jit``."""
    frac = float(np.asarray(summary["hist_censored"]).max())
    if frac > threshold:
        warnings.warn(
            f"latency histogram is right-censored: {frac * 100:.1f}% of "
            f"completions landed in the top bucket (cap "
            f"{(sp.hist_n - 1) * sp.dt * 1e3:.0f} ms) — p50/p99 are lower "
            f"bounds; re-run with a larger SimParams.hist_n",
            stacklevel=stacklevel)
    return frac
