"""Continual RL driver (§IV-C): episode rollout + gated online update.

``run_episode`` scans ``n_steps`` control intervals: observe -> sample
cascaded actions -> env step -> diversity-buffer insert. ``crl_episode``
additionally performs the online update from the episode rollout through the
loss gate. Everything is a pure function of (params, opt, buffer, env_state,
rng) so a fleet of agents is just a ``vmap`` over stacked states.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.fcpo import FCPOConfig
from repro.core import env as env_mod
from repro.core.agent import ActionMask, sample_actions
from repro.core.buffer import DiversityBuffer, buffer_insert
from repro.core.ppo import Rollout, agent_update


class AgentState(NamedTuple):
    params: Any
    opt: Any
    buffer: DiversityBuffer
    env_state: env_mod.EnvState
    rng: jnp.ndarray


def run_episode(cfg: FCPOConfig, ep: env_mod.EnvParams, astate: AgentState,
                rates: jnp.ndarray, mask: ActionMask
                ) -> Tuple[AgentState, Rollout, Dict[str, jnp.ndarray]]:
    """Collect one episode (rates: (n_steps,) arrivals per interval)."""

    def step(carry, rate):
        est, buf, rng = carry
        rng, krng = jax.random.split(rng)
        obs = env_mod.observe(cfg, ep, est, rate)
        actions, logp, out = sample_actions(cfg, astate.params, obs, mask, krng)
        est2, reward, info = env_mod.env_step(cfg, ep, est, actions, rate)
        probs = jnp.concatenate([jnp.exp(out["res"]), jnp.exp(out["bs"]),
                                 jnp.exp(out["mt"])], axis=-1)
        buf = buffer_insert(cfg, buf, obs, actions, logp, reward,
                            out["value"], probs)
        ys = (obs, actions, logp, reward, out["value"], info)
        return (est2, buf, rng), ys

    (env_state, buffer, rng), ys = jax.lax.scan(
        step, (astate.env_state, astate.buffer, astate.rng), rates)
    obs, actions, logp, rewards, values, infos = ys
    rollout = Rollout(states=obs, actions=actions, logp_old=logp,
                      rewards=rewards, values_old=values)
    metrics = {
        "reward": rewards.mean(),
        "throughput": infos["throughput"].mean(),
        "effective_throughput": infos["effective_throughput"].mean(),
        "latency": infos["latency"].mean(),
        "drops": infos["drops"].mean(),
        "accuracy_proxy": infos["accuracy_proxy"].mean(),
    }
    new_state = AgentState(astate.params, astate.opt, buffer, env_state, rng)
    return new_state, rollout, metrics


def crl_episode(cfg: FCPOConfig, ep: env_mod.EnvParams, astate: AgentState,
                rates: jnp.ndarray, mask: ActionMask, learn: bool = True
                ) -> Tuple[AgentState, Rollout, Dict[str, jnp.ndarray]]:
    """Episode + gated online update (the CRL inner loop)."""
    astate, rollout, metrics = run_episode(cfg, ep, astate, rates, mask)
    if learn:
        params, opt, lm = agent_update(cfg, astate.params, astate.opt,
                                       rollout, mask)
        astate = astate._replace(params=params, opt=opt)
        metrics = {**metrics, **lm}
    else:
        metrics = {**metrics, "loss": jnp.zeros(()), "l_p": jnp.zeros(()),
                   "l_v": jnp.zeros(()), "l_pen": jnp.zeros(()),
                   "gated": jnp.ones(())}
    return astate, rollout, metrics
