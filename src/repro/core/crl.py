"""Continual RL driver (§IV-C): episode rollout + gated online update.

``run_episode`` scans ``n_steps`` control intervals: observe -> sample
cascaded actions -> env step, all through a pluggable ``EnvBackend``
(``core.backends``): the fluid MDP (default) or the request-level twin,
whose control-interval step nests K data-plane microticks — same episode
loop, same scanned fleet driver, "train where you serve". The
diversity-buffer maintenance is hoisted
OUT of the scan body: the buffer is write-only during a rollout, so the
whole episode's candidates are ingested after the scan with ONE
``buffer_insert_batch`` call through the streaming-moment engine — the scan
body stays env+policy only and the per-step O(N·D²+D³) covariance rebuild of
the old insert path disappears from the hot loop (benchmarks/
fig_buffer_perf.py measures the A/B). ``crl_episode`` additionally performs
the online update from the episode rollout through the loss gate. Everything
is a pure function of (params, opt, buffer, env_state, rng) so a fleet of
agents is just a ``vmap`` over stacked states.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.fcpo import FCPOConfig
from repro.core import dtypes as dtp
from repro.core import env as env_mod
from repro.core.agent import ActionMask, sample_actions
from repro.core.backends import FLUID, EnvBackend
from repro.core.buffer import (DiversityBuffer, buffer_insert_batch,
                               buffer_insert_reference)
from repro.core.ppo import Rollout, agent_update


class AgentState(NamedTuple):
    params: Any
    opt: Any
    buffer: DiversityBuffer
    env_state: env_mod.EnvState
    rng: jnp.ndarray


def run_episode(cfg: FCPOConfig, ep: env_mod.EnvParams, astate: AgentState,
                rates: jnp.ndarray, mask: ActionMask,
                use_pallas: bool = False, backend: EnvBackend = FLUID,
                health: bool = False
                ) -> Tuple[AgentState, Rollout, Dict[str, jnp.ndarray]]:
    """Collect one episode (rates: (n_steps,) arrivals per interval).

    The buffer never feeds back into the policy or env within an episode, so
    the scan collects the candidate experiences and a single
    ``buffer_insert_batch`` ingests them afterwards — trajectory-identical to
    per-step inserts (tests/test_buffer.py) but with the diversity scoring
    off the step critical path. ``use_pallas`` routes the batch insert
    through the fused Pallas kernel instead of the jnp streaming scan.
    ``backend`` selects the environment (``core.backends``): the fluid MDP
    or the request-level twin; ``astate.env_state`` must be that backend's
    state pytree (``fleet_init(..., env_backend=...)``). ``health`` adds a
    ``"_health"`` entry of raw per-interval telemetry ((T,)/(T, K) arrays:
    reward, SLO-miss rate, action marginals, arrival rate) to the metrics
    for the fleet health observatory — the scalar metrics and every other
    output are unchanged, so health-off stages the identical program."""

    def step(carry, rate):
        est, rng = carry
        rng, krng = jax.random.split(rng)
        # Observations/rewards enter the learner in float32 even when the
        # carried env state is stored bf16 (StatePolicy.env); the stepped
        # state is cast back to the carry's storage dtypes so the scan
        # carry stays dtype-stable. All identities under the f32 default.
        obs = backend.observe(cfg, ep, est, rate).astype(jnp.float32)
        actions, logp, out = sample_actions(cfg, astate.params, obs, mask, krng)
        est2, reward, info = backend.step(cfg, ep, est, actions, rate)
        est2 = dtp.tree_cast_like(est2, est)
        reward = reward.astype(jnp.float32)
        info = dtp.tree_f32(info)
        probs = jnp.concatenate([jnp.exp(out["res"]), jnp.exp(out["bs"]),
                                 jnp.exp(out["mt"])], axis=-1)
        ys = (obs, actions, logp, reward, out["value"], probs, info)
        return (est2, rng), ys

    (env_state, rng), ys = jax.lax.scan(
        step, (astate.env_state, astate.rng), rates)
    obs, actions, logp, rewards, values, probs, infos = ys
    buffer = buffer_insert_batch(cfg, astate.buffer, obs, actions, logp,
                                 rewards, values, probs,
                                 use_pallas=use_pallas)
    rollout = Rollout(states=obs, actions=actions, logp_old=logp,
                      rewards=rewards, values_old=values)
    metrics = {
        "reward": rewards.mean(),
        "throughput": infos["throughput"].mean(),
        "effective_throughput": infos["effective_throughput"].mean(),
        "latency": infos["latency"].mean(),
        "drops": infos["drops"].mean(),
        "accuracy_proxy": infos["accuracy_proxy"].mean(),
    }
    if health:
        thr = infos["throughput"]
        miss = (thr - infos["effective_throughput"]) / jnp.maximum(thr, 1e-9)
        metrics["_health"] = {"reward": rewards, "miss": miss,
                              "probs": probs, "rate": rates}
    new_state = AgentState(astate.params, astate.opt, buffer, env_state, rng)
    return new_state, rollout, metrics


def run_episode_reference(cfg: FCPOConfig, ep: env_mod.EnvParams,
                          astate: AgentState, rates: jnp.ndarray,
                          mask: ActionMask, backend: EnvBackend = FLUID
                          ) -> Tuple[AgentState, Rollout,
                                     Dict[str, jnp.ndarray]]:
    """The seed episode loop: per-step recompute-oracle buffer inserts
    sequentially inside the scan. Kept as the equivalence oracle for the
    restructured ``run_episode`` (tests/test_buffer.py) and the A/B baseline
    for benchmarks/fig_buffer_perf.py — one definition so both measure the
    same loop."""

    def step(carry, rate):
        est, buf, rng = carry
        rng, krng = jax.random.split(rng)
        # Same dtype discipline as run_episode: f32 into the learner, env
        # carry cast back to its storage dtypes (no-ops under f32 default).
        obs = backend.observe(cfg, ep, est, rate).astype(jnp.float32)
        actions, logp, out = sample_actions(cfg, astate.params, obs, mask, krng)
        est2, reward, info = backend.step(cfg, ep, est, actions, rate)
        est2 = dtp.tree_cast_like(est2, est)
        reward = reward.astype(jnp.float32)
        info = dtp.tree_f32(info)
        probs = jnp.concatenate([jnp.exp(out["res"]), jnp.exp(out["bs"]),
                                 jnp.exp(out["mt"])], axis=-1)
        buf = buffer_insert_reference(cfg, buf, obs, actions, logp, reward,
                                      out["value"], probs)
        ys = (obs, actions, logp, reward, out["value"], info)
        return (est2, buf, rng), ys

    (env_state, buffer, rng), ys = jax.lax.scan(
        step, (astate.env_state, astate.buffer, astate.rng), rates)
    obs, actions, logp, rewards, values, infos = ys
    rollout = Rollout(states=obs, actions=actions, logp_old=logp,
                      rewards=rewards, values_old=values)
    metrics = {
        "reward": rewards.mean(),
        "throughput": infos["throughput"].mean(),
        "effective_throughput": infos["effective_throughput"].mean(),
        "latency": infos["latency"].mean(),
        "drops": infos["drops"].mean(),
        "accuracy_proxy": infos["accuracy_proxy"].mean(),
    }
    new_state = AgentState(astate.params, astate.opt, buffer, env_state, rng)
    return new_state, rollout, metrics


def crl_episode(cfg: FCPOConfig, ep: env_mod.EnvParams, astate: AgentState,
                rates: jnp.ndarray, mask: ActionMask, learn: bool = True,
                backend: EnvBackend = FLUID, health: bool = False
                ) -> Tuple[AgentState, Rollout, Dict[str, jnp.ndarray]]:
    """Episode + gated online update (the CRL inner loop)."""
    astate, rollout, metrics = run_episode(cfg, ep, astate, rates, mask,
                                           backend=backend, health=health)
    if learn:
        params, opt, lm = agent_update(cfg, astate.params, astate.opt,
                                       rollout, mask)
        astate = astate._replace(params=params, opt=opt)
        metrics = {**metrics, **lm}
    else:
        metrics = {**metrics, "loss": jnp.zeros(()), "l_p": jnp.zeros(()),
                   "l_v": jnp.zeros(()), "l_pen": jnp.zeros(()),
                   "gated": jnp.ones(()), "update_rejected": jnp.zeros(())}
    return astate, rollout, metrics
