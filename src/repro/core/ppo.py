"""FCPO losses (Eqs. 3–5), GAE, loss gate, and the iAgent update.

Faithful notes:
  * Eq. 4: ``l_p = mean(min(ε·ratio, ratio) · (GAE + e^{-r}))``. The paper's
    total loss ``l`` is *minimized*; with the advantage entering positively the
    literal equation would reinforce low-reward actions, so — consistent with
    the paper's observed behavior — we read "GAE" as the advantage *deficit*
    (−Â). The ``e^{-r}`` term survives literally: low reward ⇒ larger factor
    ⇒ stronger push away from the taken action ("more direct feedback of the
    total reward value", §IV-C). ``policy_mode="ppo"`` switches to the
    standard clipped-surrogate objective as a beyond-paper stability option.
  * Eq. 5: ``l_v = mse(Q(s,a)_n, r_n)`` — targets are the γ=0.1 discounted
    returns (at γ=0.1 these are within 10% of the immediate reward, matching
    the paper's near-myopic setting).
  * Eq. 3: the direct penalty ``ω·mean(a[0]+a[2])`` uses the *normalized*
    RES and MT action indices, so batch size is optimized first and the other
    actions must "pay for themselves" — exactly the paper's rationale.
  * Loss gate (§IV-C Overhead Minimization): backprop is skipped when |l| is
    below a threshold; implemented with ``lax.cond`` so it also saves compute
    inside jit (the grad branch is not executed when gated).
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.fcpo import FCPOConfig
from repro.core.agent import ActionMask, action_logp


class Rollout(NamedTuple):
    """One episode of experience for a single agent (leading dim = steps)."""
    states: jnp.ndarray    # (T, 8)
    actions: jnp.ndarray   # (T, 3) int32
    logp_old: jnp.ndarray  # (T,)
    rewards: jnp.ndarray   # (T,)
    values_old: jnp.ndarray  # (T,)


def gae(cfg: FCPOConfig, rewards, values):
    """Generalized Advantage Estimation (γ=λ=0.1). values: (T,) with a
    bootstrap of 0 after the last step (episodes are short horizons)."""
    v_next = jnp.concatenate([values[1:], jnp.zeros((1,))])
    deltas = rewards + cfg.gamma * v_next - values

    def scan_fn(carry, delta):
        adv = delta + cfg.gamma * cfg.lam * carry
        return adv, adv

    _, advs = jax.lax.scan(scan_fn, 0.0, deltas[::-1])
    return advs[::-1]


def returns(cfg: FCPOConfig, rewards):
    def scan_fn(carry, r):
        ret = r + cfg.gamma * carry
        return ret, ret

    _, rets = jax.lax.scan(scan_fn, 0.0, rewards[::-1])
    return rets[::-1]


def fcpo_loss(cfg: FCPOConfig, params, rollout: Rollout, mask: ActionMask):
    """Total loss l = l_p + l_v + ω·mean(a[0]+a[2])  (Eq. 3)."""
    logp, values, _ = action_logp(cfg, params, rollout.states, rollout.actions, mask)
    ratio = jnp.exp(logp - rollout.logp_old)
    adv = gae(cfg, rollout.rewards, rollout.values_old)
    adv = (adv - adv.mean()) / (adv.std() + 1e-6)

    if cfg.policy_mode == "ppo":  # beyond-paper: standard clipped surrogate
        clipped = jnp.clip(ratio, 1 - (1 - cfg.eps_clip), 1 + (1 - cfg.eps_clip))
        l_p = -jnp.mean(jnp.minimum(ratio * adv, clipped * adv))
    else:  # Eq. 4, with GAE read as the advantage deficit (see module doc)
        factor = -adv + jnp.exp(-rollout.rewards)
        l_p = jnp.mean(jnp.minimum(cfg.eps_clip * ratio, ratio) * factor)

    l_v = jnp.mean(jnp.square(values - returns(cfg, rollout.rewards)))  # Eq. 5

    # Eq. 3 penalty: normalized RES / MT indices
    a_res = rollout.actions[..., 0].astype(jnp.float32) / max(cfg.n_res - 1, 1)
    a_mt = rollout.actions[..., 2].astype(jnp.float32) / max(cfg.n_mt - 1, 1)
    l_pen = cfg.omega * jnp.mean(a_res + a_mt)

    total = l_p + l_v + l_pen
    return total, {"l_p": l_p, "l_v": l_v, "l_pen": l_pen, "loss": total}


# ---------------------------------------------------------------------------
# iAgent optimizer (tiny Adam, LR from Table II) + loss gate
# ---------------------------------------------------------------------------
def agent_opt_init(params):
    z = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def _adam(cfg: FCPOConfig, params, grads, opt, lr_scale=1.0, freeze=None):
    t = opt["t"] + 1
    b1, b2, eps = 0.9, 0.999, 1e-8

    def upd(path_frozen, p, g, m, v):
        # Moment math runs in float32 regardless of the storage dtype
        # (StatePolicy may hold m/v — and the params/grads — in bf16);
        # results are cast back to each leaf's own dtype, which is the
        # identity under the default all-float32 policy.
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mh = m32 / (1 - b1 ** t)
        vh = v32 / (1 - b2 ** t)
        step = cfg.lr * lr_scale * mh / (jnp.sqrt(vh) + eps)
        p32 = p.astype(jnp.float32)
        new_p = jnp.where(path_frozen, p32, p32 - step).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    frozen_tree = (freeze if freeze is not None
                   else jax.tree.map(lambda _: False, params))
    out = jax.tree.map(lambda fz, p, g, m, v: upd(fz, p, g, m, v),
                       frozen_tree, params, grads, opt["m"], opt["v"])
    pick = lambda i: jax.tree.map(lambda t_: t_[i], out,
                                  is_leaf=lambda t_: isinstance(t_, tuple))
    return pick(0), {"m": pick(1), "v": pick(2), "t": t}


def agent_update(cfg: FCPOConfig, params, opt, rollout: Rollout, mask: ActionMask):
    """One CRL update with the loss gate. Returns (params, opt, metrics).

    The backward pass lives *inside* the cond branch, so when the gate fires
    backprop is genuinely skipped (§IV-C: "executes back-propagation only
    when the improvement is significant")."""
    loss, metrics = fcpo_loss(cfg, params, rollout, mask)

    def do_update(_):
        grads = jax.grad(lambda p: fcpo_loss(cfg, p, rollout, mask)[0])(params)
        return _adam(cfg, params, grads, opt)

    def skip(_):
        return params, opt

    gated = jnp.abs(loss) < cfg.loss_gate
    new_params, new_opt = jax.lax.cond(gated, skip, do_update, None)
    # Self-healing non-finite guard: a NaN/Inf loss or a blown-up update
    # (e.g. from a poisoned reward stream) rejects the whole step — previous
    # params AND optimizer state are kept, so one bad episode cannot wedge
    # the agent. Branchless (one ``where`` per leaf): bit-transparent on
    # healthy steps, and a NaN loss gates to False above so the grad branch
    # still runs — the rejection happens here, after the fact.
    ok = jnp.isfinite(loss)
    for leaf in jax.tree_util.tree_leaves(new_params):
        ok = ok & jnp.all(jnp.isfinite(leaf))
    keep = lambda new, old: jnp.where(ok, new, old)
    new_params = jax.tree.map(keep, new_params, params)
    new_opt = jax.tree.map(keep, new_opt, opt)
    metrics = dict(metrics, gated=gated.astype(jnp.float32),
                   update_rejected=(~ok).astype(jnp.float32))
    return new_params, new_opt, metrics


def finetune_heads(cfg: FCPOConfig, params, opt, rollout: Rollout,
                   mask: ActionMask, steps: int = None):
    """Alg. 2 lines 6–9: after FL aggregation, fine-tune ONLY the action
    heads on local experiences with the policy loss (backbone + value head
    frozen)."""
    steps = steps if steps is not None else cfg.finetune_steps
    freeze = {k: jax.tree.map(lambda _: k in ("backbone", "value"), v)
              for k, v in params.items()}

    # The rollout is constant across fine-tune steps and the advantage term
    # carries no parameter dependence, so GAE runs once here instead of
    # inside every scanned grad step.
    adv = gae(cfg, rollout.rewards, rollout.values_old)
    adv = (adv - adv.mean()) / (adv.std() + 1e-6)
    factor = -adv + jnp.exp(-rollout.rewards)

    def policy_only_loss(p):
        logp, _, _ = action_logp(cfg, p, rollout.states, rollout.actions, mask)
        ratio = jnp.exp(logp - rollout.logp_old)
        return jnp.mean(jnp.minimum(cfg.eps_clip * ratio, ratio) * factor)

    def body(carry, _):
        p, o = carry
        grads = jax.grad(policy_only_loss)(p)
        p, o = _adam(cfg, p, grads, o, freeze=freeze)
        return (p, o), None

    (params, opt), _ = jax.lax.scan(body, (params, opt), None, length=steps)
    return params, opt
