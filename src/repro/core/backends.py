"""Pluggable environment backends — "train where you serve".

An ``EnvBackend`` is the per-agent environment contract of the CRL loop:
``init`` builds one agent's environment state, ``observe`` reads the 8-dim
iAgent state vector (one definition for every backend —
``core.env.observe_vector``), ``step`` advances one control interval and
returns (state, reward, info). Everything is a pure function of per-agent
pytrees, so a fleet is still ``vmap`` over the agent axis and the scanned
driver (``core.fleet.train_fleet_scan``) stays ONE jitted program regardless
of backend.

Two interchangeable implementations:

* ``FluidBackend`` — the original fluid MDP (``core/env.py``): rates flow
  through Little's-law queues, one env step per control interval, the SLO
  enters the reward as a binary per-interval cutoff. Cheap, differentiable
  intuition — but benchmarks/fig_sim_fidelity.py measured an ~80% effective
  -throughput gap against per-request reality.
* ``TwinBackend`` — the request-level digital twin (``repro.sim``): each
  control-interval step nests K microticks of the discrete-event data plane
  through the shared ``kernels/ref.py: sim_microtick`` math (jnp scan, or
  the fused Pallas ``queue_advance`` kernel with ``use_pallas=True``), and
  the reward is computed from request-grade completions and *per-request
  deadline misses* instead of the fluid binary interval cutoff. Training on
  this backend closes the sim-to-real gap the twin exposed
  (benchmarks/fig_twin_training.py measures the A/B).

Backends are frozen dataclasses — hashable, so they ride through ``jit`` as
static arguments next to ``FCPOConfig``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, NamedTuple, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.fcpo import FCPOConfig
from repro.core import env as env_mod
from repro.sim.state import (SimParams, SimState, action_caps,
                             effective_queue_cap, sim_init, spread_arrivals,
                             warn_if_ring_clamps)
from repro.sim.step import sim_interval_agent


@dataclass(frozen=True)
class EnvBackend:
    """Interface: one agent's environment over control intervals."""

    name = "abstract"

    def init(self, cfg: FCPOConfig) -> Any:
        raise NotImplementedError

    def observe(self, cfg: FCPOConfig, ep: env_mod.EnvParams, state: Any,
                rate) -> jnp.ndarray:
        raise NotImplementedError

    def step(self, cfg: FCPOConfig, ep: env_mod.EnvParams, state: Any,
             action, rate) -> Tuple[Any, jnp.ndarray, Dict[str, jnp.ndarray]]:
        raise NotImplementedError

    def check_env_params(self, ep: env_mod.EnvParams) -> None:
        """Host-side sanity hook (called once by ``fleet_init`` on concrete
        params, never under ``jit``): warn when the backend cannot honor the
        device profile faithfully. Default: nothing to check."""


@dataclass(frozen=True)
class FluidBackend(EnvBackend):
    """The fluid MDP of ``core/env.py`` behind the backend interface."""

    name = "fluid"

    def init(self, cfg):
        return env_mod.env_init(cfg)

    def observe(self, cfg, ep, state, rate):
        return env_mod.observe(cfg, ep, state, rate)

    def step(self, cfg, ep, state, action, rate):
        return env_mod.env_step(cfg, ep, state, action, rate)


class TwinEnvState(NamedTuple):
    """One agent's twin environment state: the request-level pipeline plus
    the control-plane carries the fluid MDP kept in ``EnvState``."""
    sim: SimState            # pointer-segmented ring (repro.sim.state)
    cur_action: jnp.ndarray  # (3,) int32 current (res, bs, mt)
    drops_prev: jnp.ndarray  # () int32 admission drops in the last interval
    phase: jnp.ndarray       # () float32 fractional-arrival carry
    ema_lat: jnp.ndarray     # () float32 EMA of per-request mean latency (s)

    # fl_round's Eq. 7 memory-availability stat reads ``env_state.pre_q``
    # regardless of backend.
    @property
    def pre_q(self):
        return self.sim.pre_q.astype(jnp.float32)

    @property
    def post_q(self):
        return self.sim.post_q.astype(jnp.float32)


@dataclass(frozen=True)
class TwinBackend(EnvBackend):
    """The request-level twin as a *training* environment.

    One ``step`` = one control interval = ``sp.k_ticks`` nested microticks
    of the discrete-event data plane — inside ``jit``/``vmap``/``lax.scan``,
    zero host Python per microtick. ``use_pallas`` routes the interval
    through the fused Pallas ``queue_advance`` kernel (bit-identical to the
    jnp scan, tests/test_sim.py)."""

    name = "twin"
    sp: SimParams = field(default_factory=SimParams)
    use_pallas: bool = False

    def check_env_params(self, ep):
        """The ``effective_queue_cap`` clamp guard on the TRAINING path —
        same check as ``simulate_fleet``'s, one shared definition
        (``sim.state.warn_if_ring_clamps``)."""
        warn_if_ring_clamps(self.sp, jax.device_get(ep.queue_cap),
                            stacklevel=4)

    def init(self, cfg):
        return TwinEnvState(
            sim=sim_init(self.sp),
            cur_action=jnp.zeros((3,), jnp.int32),
            drops_prev=jnp.zeros((), jnp.int32),
            phase=jnp.zeros((), jnp.float32),
            ema_lat=jnp.zeros((), jnp.float32),
        )

    def observe(self, cfg, ep, state, rate):
        return env_mod.observe_vector(
            cfg, rate=rate, cur_action=state.cur_action,
            drops=state.drops_prev, pre_q=state.sim.pre_q,
            post_q=state.sim.post_q,
            queue_cap=effective_queue_cap(self.sp, ep), slo_s=ep.slo_s)

    def step(self, cfg, ep, state, action, rate):
        sp = self.sp
        caps = action_caps(cfg, sp, ep, action)
        arrivals, phase = spread_arrivals(sp, rate, state.phase)
        # sim/step.py owns the jnp-vs-Pallas interval dispatch
        sim2 = sim_interval_agent(state.sim, arrivals, caps, self.use_pallas)

        # request-grade interval deltas (the counters are cumulative)
        d_comp = (sim2.completed - state.sim.completed).astype(jnp.float32)
        d_eff = (sim2.effective - state.sim.effective).astype(jnp.float32)
        d_drop = sim2.dropped - state.sim.dropped
        mean_lat = ((sim2.lat_sum - state.sim.lat_sum)
                    / jnp.maximum(d_comp, 1.0) * sp.dt)
        # carry the EMA through empty intervals instead of decaying to zero
        ema_lat = jnp.where(d_comp > 0,
                            0.7 * state.ema_lat + 0.3 * mean_lat,
                            state.ema_lat)

        throughput = d_comp / sp.interval_s
        effective = d_eff / sp.interval_s
        miss_rate = (d_comp - d_eff) / sp.interval_s   # deadline misses /s
        drop_rate = d_drop.astype(jnp.float32) / sp.interval_s

        res_scale = jnp.asarray(cfg.res_scales)[action[0]]
        bs = jnp.asarray(cfg.bs_values, jnp.float32)[action[1]]

        # Eq. 1 on request-grade quantities: the throughput term counts only
        # completions, the latency term is the EMA of *measured* per-request
        # latency, and the oversize penalty grows with per-request deadline
        # misses and admission drops — not the fluid binary interval cutoff.
        safe_rate = jnp.maximum(rate, 1.0)
        r = 0.5 * (cfg.theta * throughput / safe_rate
                   - cfg.sigma * ema_lat
                   - cfg.phi * (bs + miss_rate + drop_rate) / safe_rate)
        r = jnp.tanh(r)

        new_state = TwinEnvState(sim=sim2, cur_action=action.astype(jnp.int32),
                                 drops_prev=d_drop, phase=phase,
                                 ema_lat=ema_lat)
        info = {
            "throughput": throughput,
            "effective_throughput": effective,
            "latency": jnp.where(d_comp > 0, mean_lat, ema_lat),
            "drops": d_drop.astype(jnp.float32),
            "accuracy_proxy": res_scale ** 0.3,
            "batch_latency": ep.t0 + ep.t1 * bs * res_scale ** 2,
        }
        return new_state, r, info


FLUID = FluidBackend()
BACKENDS = ("fluid", "twin")


def get_backend(spec: Union[str, EnvBackend, None],
                sim_params: SimParams = None,
                use_pallas: bool = False) -> EnvBackend:
    """Resolve a backend: an ``EnvBackend`` passes through; ``"fluid"`` /
    ``"twin"`` / ``None`` (= fluid) build one. ``sim_params``/``use_pallas``
    configure the twin when built here and are meaningless for (ignored by)
    the fluid backend — CLI layers should reject that combination
    (``launch/train_fleet.py`` does)."""
    if isinstance(spec, EnvBackend):
        return spec
    if spec is None or spec == "fluid":
        return FLUID
    if spec == "twin":
        return TwinBackend(sp=sim_params or SimParams(),
                           use_pallas=use_pallas)
    raise ValueError(f"unknown env backend {spec!r}; "
                     f"choose from {BACKENDS}")
