"""Baselines the paper compares against (§V-A4), re-implemented on the same
serving environment so the comparison is apples-to-apples:

* **BCEdge-like** — offline-trained RL, ONE bulky agent per *device* (it
  decides for all replicas hosted there using their mean state — the
  decision bottleneck the paper calls out), frozen at runtime, large replay
  buffer (7000 experiences) and a wider/deeper network (hidden_scale=4 ⇒
  ~16x params); limited to two batch/concurrency configurations per action
  like the paper's deployment.
* **OctopInf-like** — no local RL: every ``period`` intervals a global
  scheduler picks one static configuration by grid search against the
  *average* rate of the last window (workload-aware periodic scheduling).
* **Distream-like** — workload-adaptive placement but no runtime parameter
  optimization: fixed bs=1, full res, 1 thread.

All run on the identical env/traces as FCPO (benchmarks/fig7, fig9, fig10).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.fcpo import FCPOConfig
from repro.core import env as env_mod
from repro.core.agent import ActionMask, agent_init, full_mask, sample_actions
from repro.core.backends import get_backend
from repro.core.crl import AgentState, crl_episode, run_episode
from repro.core.buffer import buffer_init
from repro.core.fleet import Fleet, fleet_init, fleet_episode
from repro.core.ppo import agent_opt_init
from repro.data.workload import fleet_traces


def bcedge_config() -> FCPOConfig:
    """Bulky single-joint-head offline agent (Table I row: no online
    learning, no knowledge fusion, 'Last'-checkpoint warm start)."""
    return FCPOConfig(
        single_head=True,
        hidden_scale=4,          # deeper/wider -> ~10x memory (Fig. 11)
        buffer_size=7000 // 10,  # per-episode slots; 7000-exp replay overall
        loss_gate=0.0,
        policy_mode="ppo",
        # paper §V-A4: concurrency and batch limited to two configurations
        n_mt=2,
    )


def bcedge_masks(cfg: FCPOConfig, n_devices: int) -> ActionMask:
    bs_mask = jnp.zeros((cfg.n_bs,), bool).at[jnp.asarray([2, 4])].set(True)
    return ActionMask(
        res=jnp.broadcast_to(jnp.arange(cfg.n_res) == 0, (n_devices, cfg.n_res)),
        bs=jnp.broadcast_to(bs_mask, (n_devices, cfg.n_bs)),
        mt=jnp.ones((n_devices, cfg.n_mt), bool),
    )


def run_bcedge(n_replicas: int, traces, key, replicas_per_device: int = 4,
               offline_episodes: int = 120, seed: int = 0,
               env_backend=None) -> Dict[str, np.ndarray]:
    """Offline-train one device-agent on profiling traces, then run frozen.
    Device agents act from the mean state of their replicas and broadcast
    one action to all of them. ``env_backend`` selects the environment both
    phases run in (fluid MDP default, request-level twin with ``"twin"``)."""
    cfg = bcedge_config()
    backend = get_backend(env_backend)
    n_dev = max(1, n_replicas // replicas_per_device)
    masks = bcedge_masks(cfg, n_dev)

    # --- offline phase: profiling traces (paper §V-B1: "profiling data is
    # obviously less diverse in workload patterns and cannot capture all the
    # conditions of devices") — narrow distribution, uniform device speed ---
    from repro.data.workload import PROFILING
    dev_fleet = fleet_init(cfg, n_dev, key, masks=masks,
                           speeds=jnp.ones((n_dev,)), env_backend=backend)
    prof = fleet_traces(jax.random.fold_in(key, 1), n_dev,
                        offline_episodes * cfg.n_steps, heterogeneity=0.0,
                        **PROFILING)
    for e in range(offline_episodes):
        r = prof[:, e * cfg.n_steps:(e + 1) * cfg.n_steps]
        dev_fleet, _, _ = fleet_episode(cfg, dev_fleet, r, learn=True,
                                        backend=backend)

    # --- runtime: frozen; device agent drives all its replicas ---
    rep_env = jax.vmap(lambda s: env_mod.default_env_params(s, cfg.slo_s))(
        jnp.asarray(np.random.default_rng(seed).choice(
            [0.5, 0.75, 1.0, 2.0], n_replicas)))
    backend.check_env_params(rep_env)
    rep_states = jax.vmap(lambda _: backend.init(cfg))(jnp.arange(n_replicas))
    dev_of = jnp.arange(n_replicas) % n_dev
    params = dev_fleet.astate.params
    rng = key

    @jax.jit
    def run_step(rep_states, rates, rng):
        obs = jax.vmap(lambda ep, st, r: backend.observe(cfg, ep, st, r))(
            rep_env, rep_states, rates)
        # device agent sees the MEAN state of its replicas (bottleneck)
        dev_obs = jax.ops.segment_sum(obs, dev_of, n_dev) / jnp.maximum(
            jax.ops.segment_sum(jnp.ones(n_replicas), dev_of, n_dev), 1)[:, None]
        rng, k = jax.random.split(rng)
        dev_actions, _, _ = jax.vmap(
            lambda p, o, m, kk: sample_actions(cfg, p, o, m, kk)
        )(params, dev_obs, dev_fleet.masks, jax.random.split(k, n_dev))
        actions = dev_actions[dev_of]
        rep_states, r, info = jax.vmap(
            lambda ep, st, a, rt: backend.step(cfg, ep, st, a, rt)
        )(rep_env, rep_states, actions, rates)
        return rep_states, rng, r, info

    hist: Dict[str, list] = {}
    t_total = traces.shape[1]
    for t in range(t_total):
        rep_states, rng, r, info = run_step(rep_states, traces[:, t], rng)
        for kname, v in (("reward", r), ("throughput", info["throughput"]),
                         ("effective_throughput", info["effective_throughput"]),
                         ("latency", info["latency"])):
            hist.setdefault(kname, []).append(float(jnp.mean(v)))
    # aggregate to episode granularity for comparability
    n_eps = t_total // cfg.n_steps
    return {k: np.asarray(v)[: n_eps * cfg.n_steps].reshape(n_eps, -1).mean(1)
            for k, v in hist.items()}


def _static_policy_run(cfg: FCPOConfig, n_replicas: int, traces, seed,
                       pick_action, env_backend=None) -> Dict[str, np.ndarray]:
    """Run a non-RL policy: ``pick_action(avg_rates (A,), t) -> (A,3)``."""
    backend = get_backend(env_backend)
    rep_env = jax.vmap(lambda s: env_mod.default_env_params(s, cfg.slo_s))(
        jnp.asarray(np.random.default_rng(seed).choice(
            [0.5, 0.75, 1.0, 2.0], n_replicas)))
    backend.check_env_params(rep_env)
    states = jax.vmap(lambda _: backend.init(cfg))(jnp.arange(n_replicas))

    @jax.jit
    def step(states, actions, rates):
        return jax.vmap(lambda ep, st, a, rt: backend.step(cfg, ep, st, a, rt)
                        )(rep_env, states, actions, rates)

    hist: Dict[str, list] = {}
    t_total = traces.shape[1]
    traces_np = np.asarray(traces)
    for t in range(t_total):
        actions = pick_action(traces_np, t, rep_env)
        states, r, info = step(states, jnp.asarray(actions, jnp.int32),
                               traces[:, t])
        for kname, v in (("reward", r), ("throughput", info["throughput"]),
                         ("effective_throughput", info["effective_throughput"]),
                         ("latency", info["latency"])):
            hist.setdefault(kname, []).append(float(jnp.mean(v)))
    n_eps = t_total // cfg.n_steps
    return {k: np.asarray(v)[: n_eps * cfg.n_steps].reshape(n_eps, -1).mean(1)
            for k, v in hist.items()}


def run_octopinf(n_replicas: int, traces, seed: int = 0, period: int = 300,
                 cfg: FCPOConfig = None,
                 env_backend=None) -> Dict[str, np.ndarray]:
    """Periodic global scheduling: grid-search the best static config for the
    trailing-window average rate, re-plan every ``period`` intervals."""
    cfg = cfg or FCPOConfig()
    cache = {}

    def best_static(rate, ep_t0, ep_t1):
        key = (round(float(rate), 0), round(float(ep_t0), 4))
        if key in cache:
            return cache[key]
        best, best_r = (0, 2, 1), -np.inf
        for ir, rs in enumerate(cfg.res_scales):
            for ib, bs in enumerate(cfg.bs_values):
                for im, mt in enumerate(cfg.mt_values):
                    area = rs ** 2
                    t_b = ep_t0 + ep_t1 * bs * area
                    thr = min(rate, bs / area / t_b)
                    lat = 0.015 + 0.5 * bs / area / max(rate, 1) + t_b
                    r = (cfg.theta * thr / max(rate, 1) - cfg.sigma * lat
                         - cfg.phi * bs / max(rate, 1))
                    if r > best_r:
                        best_r, best = r, (ir, ib, im)
        cache[key] = best
        return best

    def pick(traces_np, t, rep_env):
        w0 = (t // period) * period
        avg = traces_np[:, max(w0 - period, 0): w0 + 1].mean(1)
        return np.stack([
            best_static(avg[i], float(rep_env.t0[i]), float(rep_env.t1[i]))
            for i in range(len(avg))])

    return _static_policy_run(cfg, n_replicas, traces, seed, pick,
                              env_backend=env_backend)


def run_distream(n_replicas: int, traces, seed: int = 0,
                 cfg: FCPOConfig = None,
                 env_backend=None) -> Dict[str, np.ndarray]:
    """No runtime parameter optimization: bs=1, full res, 1 thread."""
    cfg = cfg or FCPOConfig()
    fixed = np.tile(np.asarray([[0, 0, 0]]), (n_replicas, 1))
    return _static_policy_run(cfg, n_replicas, traces, seed,
                              lambda tr, t, ep: fixed,
                              env_backend=env_backend)
