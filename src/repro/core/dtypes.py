"""Fleet state dtype policies: memory-lean storage, full-precision math.

At A=2048 the Fleet pytree is memory-bound before it is compute-bound: the
Adam moments alone are 2x the parameter bytes, and the transport state
(error-feedback residuals + parked async deltas) another 2x. A
``StatePolicy`` names the *storage* dtype of each state family; all math
stays float32 — every consumer casts up on read and back to the stored
dtype on write (``tree_cast_like``), so the compute program is unchanged
and only the bytes at rest (and the scan carry) shrink.

The contract that keeps this safe:

  * ``float32`` (the default) is the identity: ``astype`` to the same dtype
    is a no-op in JAX, so the traced program — and therefore every
    pre-policy run — is bit-for-bit unchanged.
  * Both fleet drivers (``train_fleet_scan`` / ``train_fleet_reference``)
    run the SAME dtype-preserving functions, so scan==reference
    equivalence holds under every policy (tests/test_state_dtype.py locks
    it per policy).
  * int8 buffer slots use *fixed* quantization scales (no per-tensor scale
    leaves), so the pytree structure — and the donation audit's leaf
    count — is identical across policies. Quantization is idempotent
    (requantizing a stored slot is the identity), so repeated
    insert/resync passes do not drift.

Policy families (what each field governs):
  * ``opt``       — Adam first/second moments (``astate.opt["m"|"v"]``)
  * ``env``       — float leaves of the per-agent env state
  * ``transport`` — codec residuals + parked async deltas
  * ``buffer``    — diversity-buffer payload; ``int8`` packs the stored
                    states/probs slots (fixed scales below) and keeps the
                    small payload vectors bfloat16; scores and streaming
                    moments stay float32 (eviction precision, Cholesky)
  * ``model``     — agent params + per-pod base networks (the aggressive
                    end: Alg. 1 aggregation still computes in float32)
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

# Fixed int8 quantization scales for buffer slots. Observation coordinates
# are non-negative and O(1) (rate/100, utilizations, normalized queue
# depths — see core.env.observe_vector): 1/32 covers [0, 3.97] at 0.031
# resolution. Policy probabilities live in [0, 1]: 1/127 is exact at the
# endpoints. Fixed (not per-tensor) scales keep the pytree leaf count
# policy-invariant.
STATE_SCALE = 1.0 / 32.0
PROB_SCALE = 1.0 / 127.0


@dataclass(frozen=True)
class StatePolicy:
    """Storage dtypes for the Fleet state families. Hashable/frozen so it
    can ride jit-static arguments, but nothing needs to: the policy is
    applied by casting the state once (``fleet_cast``) and every update
    path preserves leaf dtypes from there."""
    name: str = "float32"
    opt: str = "float32"
    env: str = "float32"
    transport: str = "float32"
    buffer: str = "float32"      # "float32" | "bfloat16" | "int8"
    model: str = "float32"


POLICIES = {
    # the default: bit-identical to every pre-policy run
    "float32": StatePolicy(),
    # conservative lean state: moments/env/transport/buffer in bf16,
    # model weights untouched (~1.7x state-bytes cut)
    "bf16": StatePolicy(name="bf16", opt="bfloat16", env="bfloat16",
                        transport="bfloat16", buffer="bfloat16"),
    # full lean state: bf16 everywhere + int8 buffer slots (>= 2x cut)
    "lean": StatePolicy(name="lean", opt="bfloat16", env="bfloat16",
                        transport="bfloat16", buffer="int8",
                        model="bfloat16"),
}


def get_policy(policy) -> StatePolicy:
    """Resolve a policy name / StatePolicy / None (-> default float32)."""
    if policy is None:
        return POLICIES["float32"]
    if isinstance(policy, StatePolicy):
        return policy
    if policy not in POLICIES:
        raise ValueError(f"unknown state policy {policy!r}; expected one of "
                         f"{tuple(POLICIES)} or a StatePolicy")
    return POLICIES[policy]


def _is_float(x) -> bool:
    return jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)


def cast_floats(tree, dtype):
    """astype every floating leaf of ``tree`` to ``dtype`` (ints, bools and
    rng keys pass through). The float32->float32 case is the identity —
    JAX's ``convert_element_type`` to the same dtype returns its operand."""
    dt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda x: x.astype(dt) if _is_float(x) else x, tree)


def tree_cast_like(tree, like):
    """astype each leaf of ``tree`` to the matching leaf dtype of ``like``
    — the write-back half of compute-in-f32/store-in-policy-dtype. Identity
    (same arrays, same program) when the dtypes already match."""
    return jax.tree.map(lambda x, l: x.astype(jnp.asarray(l).dtype),
                        tree, like)


def tree_f32(tree):
    """Cast every floating leaf up to float32 (identity on float32)."""
    return cast_floats(tree, jnp.float32)


def quant8(x, scale):
    """Fixed-scale symmetric int8 quantization. Idempotent composed with
    ``dequant8``: quant(dequant(q)) == q."""
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def dequant8(q, scale):
    return q.astype(jnp.float32) * scale


def tree_bytes(tree) -> int:
    """Total storage bytes of a pytree of arrays."""
    return int(sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(tree)
                   if hasattr(x, "dtype")))
