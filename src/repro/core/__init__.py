"""FCPO core — the paper's contribution: iAgent + CRL + FRL."""
from repro.configs.fcpo import FCPOConfig, DEFAULT  # noqa: F401
from repro.core.agent import (ActionMask, agent_forward, agent_init,  # noqa: F401
                              full_mask, sample_actions)
from repro.core.backends import (BACKENDS, EnvBackend, FluidBackend,  # noqa: F401
                                 TwinBackend, TwinEnvState, get_backend)
from repro.core.buffer import (DiversityBuffer, buffer_init, buffer_insert,  # noqa: F401
                               buffer_insert_batch, buffer_insert_reference)
from repro.core.crl import AgentState, crl_episode, run_episode  # noqa: F401
from repro.core.env import EnvParams, EnvState, default_env_params, env_init, env_step  # noqa: F401
from repro.core.federated import aggregate, select_clients  # noqa: F401
from repro.core.fleet import (Fleet, fl_round, fleet_episode, fleet_init,  # noqa: F401
                              fleet_shardings, train_fleet,
                              train_fleet_reference, train_fleet_scan)
from repro.core.ppo import Rollout, agent_update, fcpo_loss, finetune_heads  # noqa: F401
from repro.fl import TransportConfig  # noqa: F401
