"""Diversity-aware fixed-size experience buffer (Eq. 6, §IV-C).

``d = α·D_M(s_n, s_{n-1}, …, s_0) + β·D_KL(π)`` — D_M is the Mahalanobis
distance of the new state against the stored states (novelty), D_KL the
KL divergence between the new policy distribution and the buffer's mean
policy (action-space deviation).

Implementation is fully tensorial (jit/vmap-able across thousands of agents):
fixed arrays of capacity N; a new experience replaces the *lowest-diversity*
slot iff its own diversity exceeds that slot's score (until the buffer is
full, it always inserts). Memory is therefore hard-bounded — the paper's
answer to BCEdge-style 5000+-experience replay buffers.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.fcpo import FCPOConfig


class DiversityBuffer(NamedTuple):
    states: jnp.ndarray   # (N, 8)
    actions: jnp.ndarray  # (N, 3) int32
    logp: jnp.ndarray     # (N,)
    rewards: jnp.ndarray  # (N,)
    values: jnp.ndarray   # (N,)
    probs: jnp.ndarray    # (N, n_res+n_bs+n_mt) policy dists at insert time
    score: jnp.ndarray    # (N,) stored diversity score
    filled: jnp.ndarray   # (N,) bool
    count: jnp.ndarray    # () int32 total insertions attempted


def buffer_init(cfg: FCPOConfig) -> DiversityBuffer:
    n = cfg.buffer_size
    na = cfg.n_res + cfg.n_bs + cfg.n_mt
    return DiversityBuffer(
        states=jnp.zeros((n, cfg.state_dim)),
        actions=jnp.zeros((n, 3), jnp.int32),
        logp=jnp.zeros((n,)),
        rewards=jnp.zeros((n,)),
        values=jnp.zeros((n,)),
        probs=jnp.full((n, na), 1.0 / na),
        score=jnp.full((n,), -jnp.inf),
        filled=jnp.zeros((n,), bool),
        count=jnp.zeros((), jnp.int32),
    )


def mahalanobis(state, states, filled):
    """D_M of ``state`` against the filled subset of ``states`` with a
    regularized covariance (ε·I keeps it defined before the buffer fills)."""
    w = filled.astype(jnp.float32)
    n = jnp.maximum(w.sum(), 1.0)
    mu = (states * w[:, None]).sum(0) / n
    diff_all = (states - mu) * w[:, None]
    cov = diff_all.T @ diff_all / n + 0.1 * jnp.eye(state.shape[-1])
    diff = state - mu
    return jnp.sqrt(jnp.maximum(diff @ jnp.linalg.solve(cov, diff), 0.0))


def kl_divergence(p, q, eps=1e-8):
    p = jnp.clip(p, eps, 1.0)
    q = jnp.clip(q, eps, 1.0)
    return jnp.sum(p * jnp.log(p / q), axis=-1)


def diversity(cfg: FCPOConfig, buf: DiversityBuffer, state, probs):
    """Eq. 6 for one candidate experience."""
    d_m = mahalanobis(state, buf.states, buf.filled)
    w = buf.filled.astype(jnp.float32)
    mean_probs = ((buf.probs * w[:, None]).sum(0)
                  / jnp.maximum(w.sum(), 1.0)[None])
    mean_probs = jnp.where(w.sum() > 0, mean_probs, probs)
    d_kl = kl_divergence(probs, mean_probs)
    return cfg.alpha * d_m + cfg.beta * d_kl


def buffer_insert(cfg: FCPOConfig, buf: DiversityBuffer, state, action, logp,
                  reward, value, probs) -> DiversityBuffer:
    """Insert by diversity: empty slot if any, else evict the min-score slot
    when the candidate is more diverse."""
    d = diversity(cfg, buf, state, probs)
    has_empty = ~jnp.all(buf.filled)
    empty_idx = jnp.argmin(buf.filled)            # first False
    min_idx = jnp.argmin(jnp.where(buf.filled, buf.score, jnp.inf))
    idx = jnp.where(has_empty, empty_idx, min_idx)
    do_insert = has_empty | (d > buf.score[min_idx])

    def set_at(arr, val):
        return jnp.where(do_insert, arr.at[idx].set(val), arr)

    return DiversityBuffer(
        states=set_at(buf.states, state),
        actions=set_at(buf.actions, action),
        logp=set_at(buf.logp, logp),
        rewards=set_at(buf.rewards, reward),
        values=set_at(buf.values, value),
        probs=set_at(buf.probs, probs),
        score=set_at(buf.score, d),
        filled=set_at(buf.filled, True),
        count=buf.count + 1,
    )


def buffer_clear(buf: DiversityBuffer) -> DiversityBuffer:
    """Emptied frequently under online CRL (§IV-C) — keeps memory small and
    experiences fresh after each training consumption."""
    return buf._replace(filled=jnp.zeros_like(buf.filled),
                        score=jnp.full_like(buf.score, -jnp.inf))


def buffer_memory_bytes(cfg: FCPOConfig) -> int:
    buf = buffer_init(cfg)
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(buf))
