"""Diversity-aware fixed-size experience buffer (Eq. 6, §IV-C).

``d = α·D_M(s_n, s_{n-1}, …, s_0) + β·D_KL(π)`` — D_M is the Mahalanobis
distance of the new state against the stored states (novelty), D_KL the
KL divergence between the new policy distribution and the buffer's mean
policy (action-space deviation).

Implementation is fully tensorial (jit/vmap-able across thousands of agents):
fixed arrays of capacity N; a new experience replaces the *lowest-diversity*
slot iff its own diversity exceeds that slot's score (until the buffer is
full, it always inserts). Memory is therefore hard-bounded — the paper's
answer to BCEdge-style 5000+-experience replay buffers.

Two scoring engines share those eviction semantics:

  * **Streaming moments** (the production path — ``buffer_insert`` /
    ``buffer_insert_batch``): the buffer carries running sufficient
    statistics (state sum, outer-product sum, probs sum, filled count) that
    are rank-1 updated on every insert/evict, so Eq. 6 is O(D²) per
    candidate and never touches the N stored slots. The covariance solve is
    a LAPACK-free unrolled Cholesky (``repro.kernels.ref``), which keeps the
    whole engine legal inside lax.scan, vmap, and the fused Pallas
    ``diversity_insert`` kernel.
  * **Recompute oracle** (``buffer_insert_reference``): the original
    O(N·D²+D³) per-insert implementation that rebuilds the covariance from
    the stored slots and runs a dense ``linalg.solve`` — kept slot-for-slot
    equivalence-tested against the streaming engine (tests/test_buffer.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.fcpo import FCPOConfig
from repro.core import dtypes as dtp
from repro.kernels import ref as kref

RIDGE = 0.1  # ε·I covariance regularizer (keeps D_M defined before fill-up)


class DiversityBuffer(NamedTuple):
    states: jnp.ndarray   # (N, 8)
    actions: jnp.ndarray  # (N, 3) int32
    logp: jnp.ndarray     # (N,)
    rewards: jnp.ndarray  # (N,)
    values: jnp.ndarray   # (N,)
    probs: jnp.ndarray    # (N, n_res+n_bs+n_mt) policy dists at insert time
    score: jnp.ndarray    # (N,) stored diversity score
    filled: jnp.ndarray   # (N,) bool
    count: jnp.ndarray    # () int32 total insertions attempted
    # --- streaming sufficient statistics over the filled slots ---
    s_sum: jnp.ndarray    # (8,)   Σ s
    s_outer: jnp.ndarray  # (8, 8) Σ s sᵀ
    p_sum: jnp.ndarray    # (n_res+n_bs+n_mt,) Σ probs
    n_filled: jnp.ndarray  # () int32 number of filled slots


def buffer_init(cfg: FCPOConfig) -> DiversityBuffer:
    n = cfg.buffer_size
    na = cfg.n_res + cfg.n_bs + cfg.n_mt
    return DiversityBuffer(
        states=jnp.zeros((n, cfg.state_dim)),
        actions=jnp.zeros((n, 3), jnp.int32),
        logp=jnp.zeros((n,)),
        rewards=jnp.zeros((n,)),
        values=jnp.zeros((n,)),
        probs=jnp.full((n, na), 1.0 / na, jnp.float32),
        score=jnp.full((n,), -jnp.inf, jnp.float32),
        filled=jnp.zeros((n,), bool),
        count=jnp.zeros((), jnp.int32),
        s_sum=jnp.zeros((cfg.state_dim,)),
        s_outer=jnp.zeros((cfg.state_dim, cfg.state_dim)),
        p_sum=jnp.zeros((na,)),
        n_filled=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Storage-dtype layer (StatePolicy.buffer). The scoring engines always run in
# float32: every public entry point unpacks the stored payload to f32, runs
# the unchanged math, and repacks to the stored dtype — a no-op chain under
# the default all-float32 policy, so the traced program is bit-identical.
# int8 slots use the fixed scales from core.dtypes (quantization is
# idempotent, so insert→insert never drifts a surviving slot); the score and
# the streaming moments are precision-critical (argmin eviction, Cholesky)
# and stay float32 under every policy.
# ---------------------------------------------------------------------------
_F32_PAYLOAD = ("logp", "rewards", "values")


def _payload_f32(buf: DiversityBuffer) -> DiversityBuffer:
    """Dequantize/upcast the stored payload to float32 (identity on f32)."""
    if buf.states.dtype == jnp.int8:
        states = dtp.dequant8(buf.states, dtp.STATE_SCALE)
        probs = dtp.dequant8(buf.probs, dtp.PROB_SCALE)
    else:
        states = buf.states.astype(jnp.float32)
        probs = buf.probs.astype(jnp.float32)
    return buf._replace(
        states=states, probs=probs,
        **{k: getattr(buf, k).astype(jnp.float32) for k in _F32_PAYLOAD})


def _payload_like(buf: DiversityBuffer, like: DiversityBuffer
                  ) -> DiversityBuffer:
    """Repack a float32-payload buffer to ``like``'s storage dtypes."""
    if like.states.dtype == jnp.int8:
        states = dtp.quant8(buf.states, dtp.STATE_SCALE)
        probs = dtp.quant8(buf.probs, dtp.PROB_SCALE)
    else:
        states = buf.states.astype(like.states.dtype)
        probs = buf.probs.astype(like.probs.dtype)
    return buf._replace(
        states=states, probs=probs,
        **{k: getattr(buf, k).astype(getattr(like, k).dtype)
           for k in _F32_PAYLOAD})


def buffer_cast(buf: DiversityBuffer, dtype: str) -> DiversityBuffer:
    """Cast the stored payload to a ``StatePolicy.buffer`` dtype:
    ``float32`` | ``bfloat16`` (all five payload arrays) | ``int8``
    (fixed-scale states/probs, bfloat16 scalars)."""
    f32 = _payload_f32(buf)
    if dtype == "float32":
        return f32
    if dtype == "bfloat16":
        bf = jnp.bfloat16
        return f32._replace(
            states=f32.states.astype(bf), probs=f32.probs.astype(bf),
            **{k: getattr(f32, k).astype(bf) for k in _F32_PAYLOAD})
    if dtype == "int8":
        bf = jnp.bfloat16
        return f32._replace(
            states=dtp.quant8(f32.states, dtp.STATE_SCALE),
            probs=dtp.quant8(f32.probs, dtp.PROB_SCALE),
            **{k: getattr(f32, k).astype(bf) for k in _F32_PAYLOAD})
    raise ValueError(f"unknown buffer storage dtype {dtype!r}")


def mahalanobis(state, states, filled):
    """Recompute-oracle D_M of ``state`` against the filled subset of
    ``states`` with a regularized covariance (ε·I keeps it defined before
    the buffer fills)."""
    w = filled.astype(jnp.float32)
    n = jnp.maximum(w.sum(), 1.0)
    mu = (states * w[:, None]).sum(0) / n
    diff_all = (states - mu) * w[:, None]
    cov = diff_all.T @ diff_all / n + RIDGE * jnp.eye(state.shape[-1])
    diff = state - mu
    return jnp.sqrt(jnp.maximum(diff @ jnp.linalg.solve(cov, diff), 0.0))


def kl_divergence(p, q, eps=1e-8):
    p = jnp.clip(p, eps, 1.0)
    q = jnp.clip(q, eps, 1.0)
    return jnp.sum(p * jnp.log(p / q), axis=-1)


def diversity(cfg: FCPOConfig, buf: DiversityBuffer, state, probs):
    """Eq. 6 for one candidate experience — recompute oracle (rebuilds the
    covariance and mean policy from the N stored slots)."""
    d_m = mahalanobis(state, buf.states, buf.filled)
    w = buf.filled.astype(jnp.float32)
    mean_probs = ((buf.probs * w[:, None]).sum(0)
                  / jnp.maximum(w.sum(), 1.0)[None])
    mean_probs = jnp.where(w.sum() > 0, mean_probs, probs)
    d_kl = kl_divergence(probs, mean_probs)
    return cfg.alpha * d_m + cfg.beta * d_kl


def _scatter_payload(buf: DiversityBuffer, idx, do, action, logp, reward,
                     value) -> DiversityBuffer:
    """Write the non-scored payload of one accepted candidate to slot idx."""
    def set_at(arr, val):
        return jnp.where(do, arr.at[idx].set(val), arr)

    return buf._replace(actions=set_at(buf.actions, action),
                        logp=set_at(buf.logp, logp),
                        rewards=set_at(buf.rewards, reward),
                        values=set_at(buf.values, value),
                        count=buf.count + 1)


def buffer_insert(cfg: FCPOConfig, buf: DiversityBuffer, state, action, logp,
                  reward, value, probs) -> DiversityBuffer:
    """Streaming-moment insert: Eq. 6 scored from the running statistics
    (O(D²), never touches the N stored slots), then empty-slot /
    min-score-evict placement identical to the recompute oracle."""
    stored, buf = buf, _payload_f32(buf)
    state = state.astype(jnp.float32)
    probs = probs.astype(jnp.float32)
    (states, probs_b, score, filled, s_sum, s_outer, p_sum, n_filled), \
        (idx, do, _d) = kref.diversity_insert_step(
            buf.states, buf.probs, buf.score, buf.filled, buf.s_sum,
            buf.s_outer, buf.p_sum, buf.n_filled, state, probs,
            alpha=cfg.alpha, beta=cfg.beta, ridge=RIDGE)
    buf = buf._replace(states=states, probs=probs_b, score=score,
                       filled=filled, s_sum=s_sum, s_outer=s_outer,
                       p_sum=p_sum, n_filled=n_filled)
    buf = _scatter_payload(buf, idx, do, action, logp, reward, value)
    return _payload_like(buf, stored)


def buffer_insert_reference(cfg: FCPOConfig, buf: DiversityBuffer, state,
                            action, logp, reward, value, probs
                            ) -> DiversityBuffer:
    """The original recompute-everything insert (equivalence oracle): builds
    the full covariance from the stored slots and solves it per candidate.
    Maintains the streaming moments too, so reference-built buffers stay
    valid inputs for the streaming engine."""
    stored, buf = buf, _payload_f32(buf)
    state = state.astype(jnp.float32)
    probs = probs.astype(jnp.float32)
    d = diversity(cfg, buf, state, probs)
    has_empty = ~jnp.all(buf.filled)
    empty_idx = jnp.argmin(buf.filled)            # first False
    min_idx = jnp.argmin(jnp.where(buf.filled, buf.score, jnp.inf))
    idx = jnp.where(has_empty, empty_idx, min_idx)
    do = has_empty | (d > buf.score[min_idx])

    old_s, old_p = buf.states[idx], buf.probs[idx]
    evict = do & buf.filled[idx]
    add = do.astype(buf.s_sum.dtype)
    sub = evict.astype(buf.s_sum.dtype)

    def set_at(arr, val):
        return jnp.where(do, arr.at[idx].set(val), arr)

    buf = buf._replace(
        states=set_at(buf.states, state),
        probs=set_at(buf.probs, probs),
        score=set_at(buf.score, d),
        filled=set_at(buf.filled, True),
        s_sum=buf.s_sum + add * state - sub * old_s,
        s_outer=(buf.s_outer + add * jnp.outer(state, state)
                 - sub * jnp.outer(old_s, old_s)),
        p_sum=buf.p_sum + add * probs - sub * old_p,
        n_filled=(buf.n_filled + do.astype(buf.n_filled.dtype)
                  - evict.astype(buf.n_filled.dtype)),
    )
    buf = _scatter_payload(buf, idx, do, action, logp, reward, value)
    return _payload_like(buf, stored)


def buffer_insert_batch(cfg: FCPOConfig, buf: DiversityBuffer, states,
                        actions, logp, rewards, values, probs,
                        use_pallas: bool = False) -> DiversityBuffer:
    """Ingest a whole episode of T candidates in one call (leading dim T on
    every candidate array). The sequential score → argmin-evict → scatter
    chain runs through the streaming engine — the jnp scan oracle by
    default, the fused Pallas kernel with ``use_pallas=True`` — and the
    non-scored payload is scattered afterwards by last-writer-wins on the
    decision trace, which is embarrassingly parallel."""
    stored, buf = buf, _payload_f32(buf)
    states = states.astype(jnp.float32)
    probs = probs.astype(jnp.float32)
    logp, rewards, values = (x.astype(jnp.float32)
                             for x in (logp, rewards, values))
    t_steps, n = states.shape[0], buf.score.shape[0]
    if use_pallas:
        from repro.kernels import ops as kops
        out = kops.diversity_insert(buf.states, buf.probs, buf.score,
                                    buf.filled, buf.s_sum, buf.s_outer,
                                    buf.p_sum, buf.n_filled, states, probs,
                                    alpha=cfg.alpha, beta=cfg.beta,
                                    ridge=RIDGE)
    else:
        out = kref.diversity_insert_ref(buf.states, buf.probs, buf.score,
                                        buf.filled, buf.s_sum, buf.s_outer,
                                        buf.p_sum, buf.n_filled, states,
                                        probs, alpha=cfg.alpha, beta=cfg.beta,
                                        ridge=RIDGE)
    (new_states, new_probs, new_score, new_filled, s_sum, s_outer, p_sum,
     n_filled, slot, do, _d) = out

    # Last writer per slot: the highest t with do[t] & slot[t]==n wins.
    ts = jnp.arange(t_steps)
    hits = (slot[None, :] == jnp.arange(n)[:, None]) & do[None, :]  # (N, T)
    last = jnp.max(jnp.where(hits, ts[None, :], -1), axis=1)        # (N,)

    def scatter(old, cand):
        gathered = cand[jnp.clip(last, 0, t_steps - 1)]
        keep = (last < 0).reshape((-1,) + (1,) * (old.ndim - 1))
        return jnp.where(keep, old, gathered)

    buf = buf._replace(
        states=new_states, probs=new_probs, score=new_score,
        filled=new_filled, s_sum=s_sum, s_outer=s_outer, p_sum=p_sum,
        n_filled=n_filled,
        actions=scatter(buf.actions, actions),
        logp=scatter(buf.logp, logp),
        rewards=scatter(buf.rewards, rewards),
        values=scatter(buf.values, values),
        count=buf.count + t_steps,
    )
    return _payload_like(buf, stored)


def buffer_resync(buf: DiversityBuffer) -> DiversityBuffer:
    """Recompute the streaming moments from the stored slots — the periodic
    resync that bounds float32 rank-1 add/subtract drift over long runs.
    O(N·D²) per agent, so it belongs on the FL-round cadence (``fl_round``
    calls it), never on the per-step hot path. Works on fleet-stacked
    buffers (vmapped callers see unbatched leaves)."""
    f32 = _payload_f32(buf)  # moments are built from the *dequantized* slots
    w = f32.filled.astype(f32.s_sum.dtype)
    return buf._replace(
        s_sum=(f32.states * w[:, None]).sum(0),
        s_outer=jnp.einsum("nd,ne->de", f32.states * w[:, None], f32.states),
        p_sum=(f32.probs * w[:, None]).sum(0),
        n_filled=f32.filled.sum().astype(f32.n_filled.dtype),
    )


def buffer_diversity_mean(buf: DiversityBuffer) -> jnp.ndarray:
    """Mean stored diversity over capacity — the Eq. 7 "data diversity"
    client-selection stat read by ``fl_round``. Works on fleet-stacked
    buffers (reduces the trailing slot axis)."""
    return jnp.where(buf.filled, buf.score, 0.0).mean(-1)


def buffer_clear(buf: DiversityBuffer) -> DiversityBuffer:
    """Emptied frequently under online CRL (§IV-C) — keeps memory small and
    experiences fresh after each training consumption. Resets the streaming
    moments along with the slot metadata."""
    return buf._replace(filled=jnp.zeros_like(buf.filled),
                        score=jnp.full_like(buf.score, -jnp.inf),
                        s_sum=jnp.zeros_like(buf.s_sum),
                        s_outer=jnp.zeros_like(buf.s_outer),
                        p_sum=jnp.zeros_like(buf.p_sum),
                        n_filled=jnp.zeros_like(buf.n_filled))


def buffer_memory_bytes(cfg: FCPOConfig) -> int:
    buf = buffer_init(cfg)
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(buf))
