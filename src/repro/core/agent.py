"""iAgent — the paper's per-model actor-critic network (Fig. 4).

Input (8): [request_rate, cur_res, cur_bs, cur_mt, queue_drops, pre_queue,
post_queue, slo]. Backbone: 8 -> 64 -> 48 (ReLU). One value head; three
*cascaded* action heads: the resolution head reads the backbone features, and
its softmax output is concatenated onto the features for the batch-size and
multi-threading heads (Faster-R-CNN-style cascade) so inter-action
dependencies are learnable.

Heterogeneous action spaces (§II-C4) are represented with *masks*: every
agent's heads are padded to the fleet-maximum dimensions and a per-agent
boolean mask disables invalid options (masked logits -> -inf). This keeps the
whole fleet as ONE stacked pytree (vmap/shard_map over the agent axis) while
agents keep genuinely different action spaces — the JAX-native replacement
for the paper's per-device LibTorch agents.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.fcpo import FCPOConfig


class ActionMask(NamedTuple):
    """Per-agent valid-action masks (True = allowed)."""
    res: jnp.ndarray  # (n_res,)
    bs: jnp.ndarray   # (n_bs,)
    mt: jnp.ndarray   # (n_mt,)


def full_mask(cfg: FCPOConfig) -> ActionMask:
    return ActionMask(jnp.ones(cfg.n_res, bool), jnp.ones(cfg.n_bs, bool),
                      jnp.ones(cfg.n_mt, bool))


def _linear_init(key, d_in, d_out):
    k1, k2 = jax.random.split(key)
    lim = 1.0 / math.sqrt(d_in)
    return {"w": jax.random.uniform(k1, (d_in, d_out), jnp.float32, -lim, lim),
            "b": jax.random.uniform(k2, (d_out,), jnp.float32, -lim, lim)}


def _linear(p, x):
    return x @ p["w"] + p["b"]


def agent_init(cfg: FCPOConfig, key) -> Dict:
    ks = jax.random.split(key, 6)
    hd = cfg.hidden_dim * cfg.hidden_scale
    fd = cfg.feat_dim * cfg.hidden_scale
    p = {
        "backbone": {
            "l1": _linear_init(ks[0], cfg.state_dim, hd),
            "l2": _linear_init(ks[1], hd, fd),
        },
        "value": _linear_init(ks[2], fd, 1),
    }
    if cfg.single_head:  # Fig. 12 ablation: one joint head over A_res×A_bs×A_mt
        p["head_res"] = _linear_init(ks[3], fd, cfg.n_res * cfg.n_bs * cfg.n_mt)
    else:
        p["head_res"] = _linear_init(ks[3], fd, cfg.n_res)
        p["head_bs"] = _linear_init(ks[4], fd + cfg.n_res, cfg.n_bs)
        p["head_mt"] = _linear_init(ks[5], fd + cfg.n_res, cfg.n_mt)
    return p


BACKBONE_KEYS = ("backbone", "value")     # equally-aggregated layers (Alg. 1)
HEAD_KEYS = ("head_res", "head_bs", "head_mt")  # loss-weighted layers


def agent_forward(cfg: FCPOConfig, params, state, mask: ActionMask):
    """state: (..., 8) -> dict of masked log-probs per head + value."""
    h = jax.nn.relu(_linear(params["backbone"]["l1"], state))
    feat = jax.nn.relu(_linear(params["backbone"]["l2"], h))
    value = _linear(params["value"], feat)[..., 0]

    if cfg.single_head:  # joint factorization for the Fig. 12 ablation
        joint_mask = (mask.res[..., :, None, None]
                      & mask.bs[..., None, :, None]
                      & mask.mt[..., None, None, :]).reshape(
                          mask.res.shape[:-1] + (-1,))
        logits = jnp.where(joint_mask, _linear(params["head_res"], feat), -1e30)
        logp = jax.nn.log_softmax(logits, axis=-1)
        lp = logp.reshape(logp.shape[:-1] + (cfg.n_res, cfg.n_bs, cfg.n_mt))
        # marginals keep the downstream interface identical
        return {
            "res": jax.nn.logsumexp(lp, axis=(-2, -1)),
            "bs": jax.nn.logsumexp(lp, axis=(-3, -1)),
            "mt": jax.nn.logsumexp(lp, axis=(-3, -2)),
            "joint": logp,
            "value": value,
        }

    res_logits = jnp.where(mask.res, _linear(params["head_res"], feat), -1e30)
    res_probs = jax.nn.softmax(res_logits, axis=-1)
    # cascade: resolution distribution feeds the other two heads
    feat_c = jnp.concatenate([feat, res_probs], axis=-1)
    bs_logits = jnp.where(mask.bs, _linear(params["head_bs"], feat_c), -1e30)
    mt_logits = jnp.where(mask.mt, _linear(params["head_mt"], feat_c), -1e30)

    return {
        "res": jax.nn.log_softmax(res_logits, axis=-1),
        "bs": jax.nn.log_softmax(bs_logits, axis=-1),
        "mt": jax.nn.log_softmax(mt_logits, axis=-1),
        "value": value,
    }


def sample_actions(cfg: FCPOConfig, params, state, mask: ActionMask, key):
    """Sample (res, bs, mt) and return (actions (...,3), logp, out-dict)."""
    out = agent_forward(cfg, params, state, mask)
    if "joint" in out:
        aj = jax.random.categorical(key, out["joint"])
        a_res = aj // (cfg.n_bs * cfg.n_mt)
        a_bs = (aj // cfg.n_mt) % cfg.n_bs
        a_mt = aj % cfg.n_mt
        logp = jnp.take_along_axis(out["joint"], aj[..., None], -1)[..., 0]
        return jnp.stack([a_res, a_bs, a_mt], axis=-1), logp, out
    kr, kb, km = jax.random.split(key, 3)
    a_res = jax.random.categorical(kr, out["res"])
    a_bs = jax.random.categorical(kb, out["bs"])
    a_mt = jax.random.categorical(km, out["mt"])
    logp = (jnp.take_along_axis(out["res"], a_res[..., None], -1)[..., 0]
            + jnp.take_along_axis(out["bs"], a_bs[..., None], -1)[..., 0]
            + jnp.take_along_axis(out["mt"], a_mt[..., None], -1)[..., 0])
    actions = jnp.stack([a_res, a_bs, a_mt], axis=-1)
    return actions, logp, out


def action_logp(cfg: FCPOConfig, params, state, actions, mask: ActionMask):
    """Log-prob of given actions (...,3) under current params; also value and
    the concatenated policy distribution (for diversity KL)."""
    out = agent_forward(cfg, params, state, mask)
    if "joint" in out:
        aj = (actions[..., 0] * cfg.n_bs * cfg.n_mt
              + actions[..., 1] * cfg.n_mt + actions[..., 2])
        logp = jnp.take_along_axis(out["joint"], aj[..., None], -1)[..., 0]
    else:
        logp = (jnp.take_along_axis(out["res"], actions[..., 0:1], -1)[..., 0]
                + jnp.take_along_axis(out["bs"], actions[..., 1:2], -1)[..., 0]
                + jnp.take_along_axis(out["mt"], actions[..., 2:3], -1)[..., 0])
    probs = jnp.concatenate([jnp.exp(out["res"]), jnp.exp(out["bs"]),
                             jnp.exp(out["mt"])], axis=-1)
    return logp, out["value"], probs


def num_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def param_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
