"""Fleet driver: the full FCPO loop over a fleet of iAgents.

One fleet = stacked agent pytrees (A on the leading axis) + stacked env
params/states + per-pod base networks. The CRL inner loop is ``vmap``'d;
the FL round is Algorithm 1 over the stacked axis. Under the production
mesh the agent axis is sharded over ``data`` (and ``pod`` maps to the FL
hierarchy) via ``fleet_shardings``, making the entire federated-continual
system one SPMD program.

Two drivers:
  * ``train_fleet_scan`` — the production path: ONE jitted, donated
    ``lax.scan`` over episodes. The FL cadence (``fl_every``, the
    ``hierarchical_period`` pod merge, straggler masking from pre-drawn
    availability bits) lives inside the scanned body as ``lax.cond``s, and
    per-episode metrics accumulate as stacked device arrays — a whole
    training run is O(1) host dispatches instead of O(n_episodes).
  * ``train_fleet_reference`` — the original Python loop (one dispatch per
    episode, per-metric host syncs), kept as the equivalence oracle.
``train_fleet`` is the compatibility entry point and delegates to the scan
driver.
"""
from __future__ import annotations

from contextlib import nullcontext
from functools import partial
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.fcpo import FCPOConfig
from repro.core import dtypes as dtp
from repro.core import env as env_mod
from repro.core import federated as fed
from repro.core.agent import ActionMask, agent_init, full_mask
from repro.core.backends import FLUID, EnvBackend, get_backend
from repro.core.buffer import (buffer_cast, buffer_diversity_mean,
                               buffer_init, buffer_resync)
from repro.core.crl import AgentState, crl_episode
from repro.core.ppo import agent_opt_init, finetune_heads
from repro.distributed import sharding as shd
from repro.fl import codec as fl_codec
from repro.fl import staleness as fl_stale
from repro.fl import transport as fl_transport
from repro.fl.transport import DEFAULT_TRANSPORT, TransportConfig
# the health observatory (repro.health) is a leaf layer like obs.trace:
# pure pytree state + jnp ops, imports nothing from core, so the sketch /
# drift / attribution updates stay inside the donated scan; health is a
# jit-static config and the default (None) keeps the Fleet pytree and the
# traced program exactly the pre-health ones
from repro.health import HealthConfig
from repro.health import attribution_scores as health_attribution
from repro.health import episode_summaries as health_summaries
from repro.health import health_init
from repro.health import update_episode as health_update_episode
from repro.health import update_round as health_update_round
# the flight-recorder span layer (repro.obs.trace) is a leaf utility —
# imports jax only, so `core` stays cycle-free; tracing is a jit-static
# flag and the default (off) path traces the exact span-free program
from repro.obs import trace as obs_trace
from repro.resilience import faults as rfaults
from repro.resilience.faults import FaultConfig
from repro.resilience.guards import DEFAULT_GUARDS, GuardConfig
from repro.resilience.guards import clip_deltas as guard_clip_deltas
from repro.resilience.guards import finite_mask as guard_finite_mask


@jax.tree_util.register_pytree_node_class
class Fleet:
    """Stacked fleet state. ``n_pods`` and the head-group *counts* are static
    (pytree aux data); everything else is traced leaves."""

    FIELDS = ("astate", "base_params", "env_params", "masks", "group_ids",
              "pod_ids", "bandwidth", "speeds", "episode", "residuals",
              "pending", "crash_timer", "partition_timer", "health")

    def __init__(self, astate, base_params, env_params, masks, group_ids,
                 pod_ids, bandwidth, speeds, episode, residuals, pending,
                 crash_timer, partition_timer, health=None, *, n_pods,
                 group_counts):
        self.astate: AgentState = astate
        self.base_params = base_params
        self.env_params: env_mod.EnvParams = env_params
        self.masks: ActionMask = masks
        self.group_ids: Dict[str, jnp.ndarray] = group_ids  # per head key
        self.pod_ids = pod_ids
        self.bandwidth = bandwidth
        self.speeds = speeds
        self.episode = episode
        # FL transport state: per-agent error-feedback residuals of the
        # lossy delta codec, and the staleness buffer of parked uploads —
        # both live in the pytree so the whole transport path stays inside
        # the donated scan (zero host work per round).
        self.residuals = residuals
        self.pending: fl_stale.PendingDeltas = pending
        # Chaos layer state: per-agent crash-recovery countdown (episodes a
        # crashed agent stays down) and per-pod partition countdown (merge
        # events a partitioned pod skips) — in the pytree so fault injection
        # stays inside the donated scan. All-zeros when faults are off.
        self.crash_timer = crash_timer
        self.partition_timer = partition_timer
        # Health observatory state (repro.health.HealthState): per-agent
        # telemetry sketches, drift detectors, and attribution suspicion.
        # None (the default) flattens to an EMPTY subtree — the pytree, the
        # donation audit, and every traced program are bit-identical to
        # pre-health fleets, the same mechanism the tracer used.
        self.health = health
        self.n_pods: int = n_pods
        self.group_counts: Dict[str, int] = group_counts

    @property
    def head_groups(self) -> Dict[str, Any]:
        out: Dict[str, Any] = dict(self.group_ids)
        for k, v in self.group_counts.items():
            out[f"{k}_count"] = v
        return out

    def _replace(self, **kw) -> "Fleet":
        vals = {f: getattr(self, f) for f in self.FIELDS}
        vals.update(kw)
        return Fleet(**vals, n_pods=self.n_pods, group_counts=self.group_counts)

    def tree_flatten(self):
        leaves = tuple(getattr(self, f) for f in self.FIELDS)
        aux = (self.n_pods, tuple(sorted(self.group_counts.items())))
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        n_pods, gc = aux
        return cls(*leaves, n_pods=n_pods, group_counts=dict(gc))


def fleet_shardings(fleet: Fleet, mesh) -> Fleet:
    """A Fleet of ``NamedSharding``s mirroring ``fleet``: agent-stacked
    leaves over the mesh's (pod, data) / data axes, per-pod base networks
    over the FL hierarchy, the episode counter replicated. Indivisible dims
    fall through to replication (``greedy_spec``), so any fleet size works
    on any mesh."""
    agent = lambda x: NamedSharding(mesh, shd.agent_spec(jnp.shape(x), mesh))
    pod = lambda x: NamedSharding(mesh, shd.pod_spec(jnp.shape(x), mesh))
    vals = {}
    for f in Fleet.FIELDS:
        v = getattr(fleet, f)
        if f in ("base_params", "partition_timer"):
            vals[f] = jax.tree.map(pod, v)
        elif f == "episode":
            vals[f] = NamedSharding(mesh, P())
        else:
            vals[f] = jax.tree.map(agent, v)
    return Fleet(**vals, n_pods=fleet.n_pods, group_counts=fleet.group_counts)


def fleet_cast(fleet: Fleet, state_policy) -> Fleet:
    """Cast the fleet's state families to a ``repro.core.dtypes.StatePolicy``
    (name / instance / None -> float32). Storage-only: every training path
    computes in float32 and writes back at the stored leaf dtype, so the
    policy is fully encoded in the leaves — no static flags, no retrace keys
    beyond the dtype change itself. Casting to ``"float32"`` recovers a
    full-precision fleet from a lean one (int8 buffer slots dequantize)."""
    pol = dtp.get_policy(state_policy)
    astate = fleet.astate
    opt = dict(fleet.astate.opt)
    opt["m"] = dtp.cast_floats(opt["m"], pol.opt)
    opt["v"] = dtp.cast_floats(opt["v"], pol.opt)
    astate = astate._replace(
        params=dtp.cast_floats(astate.params, pol.model),
        opt=opt,
        buffer=buffer_cast(astate.buffer, pol.buffer),
        env_state=dtp.cast_floats(astate.env_state, pol.env),
    )
    return fleet._replace(
        astate=astate,
        base_params=dtp.cast_floats(fleet.base_params, pol.model),
        env_params=dtp.cast_floats(fleet.env_params, pol.env),
        residuals=dtp.cast_floats(fleet.residuals, pol.transport),
        pending=fleet.pending._replace(
            delta=dtp.cast_floats(fleet.pending.delta, pol.transport)),
    )


def fleet_state_bytes(fleet: Fleet) -> Dict[str, float]:
    """Storage bytes of the fleet pytree by state family (plus ``total`` and
    ``per_agent``) — the quantity the lean policies shrink and the scaling
    benchmark curves. Pure host-side accounting from shapes/dtypes."""
    a = int(fleet.pod_ids.shape[0])
    fam = {
        "model": (fleet.astate.params, fleet.base_params),
        "opt": fleet.astate.opt,
        "buffer": fleet.astate.buffer,
        "env": (fleet.astate.env_state, fleet.env_params),
        "transport": (fleet.residuals, fleet.pending),
        "health": fleet.health,
        "misc": (fleet.masks, fleet.group_ids,
                 fleet.pod_ids, fleet.bandwidth, fleet.speeds,
                 fleet.astate.rng, fleet.crash_timer, fleet.partition_timer),
    }
    out = {k: float(dtp.tree_bytes(v)) for k, v in fam.items()}
    out["total"] = float(sum(out.values()))
    out["per_agent"] = out["total"] / max(a, 1)
    return out


def fleet_device_bytes(fleet: Fleet) -> Dict[int, float]:
    """Actual per-device placement of the fleet pytree: ``{device_id:
    bytes}`` summed over every leaf's addressable shards. On a fleet mesh
    the agent-sharded leaves split across the ``data`` axis, so a balanced
    placement shows near-equal rows — the quantity the watcher's scaling
    rows stream."""
    per: Dict[int, float] = {}
    for leaf in jax.tree.leaves(fleet):
        for sh in getattr(leaf, "addressable_shards", ()):
            d = int(sh.device.id)
            per[d] = per.get(d, 0.0) + float(sh.data.nbytes)
    return per


def fleet_init(cfg: FCPOConfig, n_agents: int, key, *, n_pods: int = 1,
               masks: Optional[ActionMask] = None,
               speeds: Optional[jnp.ndarray] = None,
               bandwidth: Optional[jnp.ndarray] = None,
               slo_s: Optional[float] = None, mesh=None,
               env_backend=None, state_policy=None,
               health: Optional[HealthConfig] = None) -> Fleet:
    """``env_backend``: ``"fluid"`` (default) / ``"twin"`` / an
    ``EnvBackend`` — the per-agent ``astate.env_state`` leaves are that
    backend's state pytree, so pass the SAME backend to the training
    drivers. ``state_policy``: a ``repro.core.dtypes`` policy name /
    ``StatePolicy`` — storage dtypes for the fleet state families
    (``fleet_cast``); the default (None) keeps the all-float32 layout,
    bit-identical to pre-policy fleets. ``health``: a
    ``repro.health.HealthConfig`` — attaches the observatory state
    (sketches, drift detectors, suspicion) to the pytree; None (default)
    keeps the pre-health fleet exactly."""
    backend = get_backend(env_backend)
    kp, kb, ke, kr = jax.random.split(key, 4)
    agent_keys = jax.random.split(kp, n_agents)
    params = jax.vmap(lambda k: agent_init(cfg, k))(agent_keys)
    opt = jax.vmap(agent_opt_init)(params)
    buffers = jax.vmap(lambda _: buffer_init(cfg))(jnp.arange(n_agents))
    env_states = jax.vmap(lambda _: backend.init(cfg))(jnp.arange(n_agents))
    rngs = jax.random.split(kr, n_agents)

    if speeds is None:  # heterogeneous device mix (Orin/NX/AGX/server-like)
        speeds = jnp.asarray(
            np.random.default_rng(0).choice([0.5, 0.75, 1.0, 2.0], n_agents))
    if bandwidth is None:
        bandwidth = jnp.asarray(
            np.random.default_rng(1).uniform(2.0, 40.0, n_agents))
    env_params = jax.vmap(lambda s: env_mod.default_env_params(
        s, cfg.slo_s if slo_s is None else slo_s))(speeds)
    backend.check_env_params(env_params)

    if masks is None:
        masks = jax.tree.map(lambda m: jnp.broadcast_to(m, (n_agents,) + m.shape),
                             full_mask(cfg))
    hg = fed.head_group_ids(masks)
    group_ids = {k: v for k, v in hg.items() if not k.endswith("_count")}
    group_counts = {k[:-len("_count")]: v for k, v in hg.items()
                    if k.endswith("_count")}
    pod_ids = jnp.asarray(np.arange(n_agents) % n_pods, jnp.int32)

    base = agent_init(cfg, kb)
    base_params = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_pods,) + x.shape), base)

    astate = AgentState(params=params, opt=opt, buffer=buffers,
                        env_state=env_states, rng=rngs)
    fleet = Fleet(astate, base_params, env_params, masks, group_ids,
                  pod_ids, bandwidth, speeds, jnp.zeros((), jnp.int32),
                  fl_codec.residuals_init(params),
                  fl_stale.pending_init(params),
                  jnp.zeros((n_agents,), jnp.int32),
                  jnp.zeros((n_pods,), jnp.int32),
                  health_init(health, n_agents, cfg.n_res + cfg.n_bs
                              + cfg.n_mt) if health is not None else None,
                  n_pods=n_pods, group_counts=group_counts)
    if state_policy is not None:
        fleet = fleet_cast(fleet, state_policy)
    if mesh is not None:
        fleet = jax.device_put(fleet, fleet_shardings(fleet, mesh))
    return fleet


@partial(jax.jit, static_argnums=0,
         static_argnames=("learn", "backend", "health"))
def fleet_episode(cfg: FCPOConfig, fleet: Fleet, rates: jnp.ndarray,
                  learn: bool = True, backend: EnvBackend = FLUID,
                  health: Optional[HealthConfig] = None):
    """One CRL episode for all agents. rates: (A, n_steps).
    Returns (fleet, rollouts, metrics). ``backend`` (static, hashable)
    selects the environment the episodes run in. ``health`` (static)
    advances every agent's telemetry sketches and drift detectors through
    the episode's raw per-interval telemetry and merges their O(bins)
    summaries into the metrics as (A,) arrays (``repro.health.
    HEALTH_METRIC_KEYS``); the fleet must carry matching health state
    (``fleet_init(..., health=...)``). None (default) stages the exact
    pre-health program."""
    astate, rollouts, metrics = jax.vmap(
        lambda ep, st, r, m: crl_episode(cfg, ep, st, r, m, learn, backend,
                                         health=health is not None)
    )(fleet.env_params, fleet.astate, rates, fleet.masks)
    hstate = fleet.health
    if health is not None:
        if hstate is None:
            raise ValueError("fleet_episode(health=...) needs a fleet with "
                             "health state (fleet_init(..., health=...))")
        tele = metrics.pop("_health")
        hstate = health_update_episode(health, hstate, tele["reward"],
                                       tele["miss"], tele["probs"],
                                       tele["rate"])
        metrics.update(health_summaries(health, hstate))
    fleet = fleet._replace(astate=astate, episode=fleet.episode + 1,
                           health=hstate)
    return fleet, rollouts, metrics


@partial(jax.jit, static_argnums=0,
         static_argnames=("transport", "guards", "faults", "trace",
                          "health"))
def fl_round(cfg: FCPOConfig, fleet: Fleet, rollouts, available=None,
             transport: Optional[TransportConfig] = None,
             guards: Optional[GuardConfig] = None,
             faults: Optional[FaultConfig] = None,
             byzantine=None, fault_key=None, *, trace: bool = False,
             trace_id=None, trace_when=None, trace_token=None,
             health: Optional[HealthConfig] = None):
    """One federated round: transport -> Eq. 7 selection -> Alg. 1
    aggregation -> Alg. 2 head fine-tuning.

    ``available`` masks out Bernoulli stragglers/offline agents (the legacy
    fault-tolerance path). ``transport`` (jit-static) adds the communication
    model on top: clients ship ``params - base`` deltas encoded per-leaf
    with error feedback (``fleet.residuals``); a configured round deadline
    makes stragglers *emergent* — an agent participates iff it is
    Bernoulli-available AND its encoded upload fits the deadline — and with
    ``async_rounds`` a missed upload parks in ``fleet.pending`` to join a
    later round staleness-discounted. The default transport (float32 codec,
    no deadline, sync) compiles to the exact pre-transport round.

    ``guards`` (jit-static, ``repro.resilience.GuardConfig``) selects the
    Algorithm 1 statistic (mean / trimmed / median), an optional per-leaf
    delta norm clip, and the non-finite contribution rejection. ``faults``
    + ``byzantine`` ((A,) bool) + ``fault_key`` inject byzantine corruption
    into the decoded deltas, post-codec. The defaults (no faults, mean
    aggregation, guards on) compile to the exact pre-chaos round.

    ``trace`` (jit-static) + ``trace_id`` (plain operand — the registered
    ``repro.obs.trace.Tracer`` id, so swapping tracers never recompiles)
    bracket the round's phases (uplink model, codec encode/decode,
    Algorithm 1 aggregation, Algorithm 2 fine-tuning) with flight-recorder
    spans; ``trace_when`` optionally samples emission at runtime. The
    default (trace off) compiles to the exact span-free round.

    ``health`` (jit-static, ``repro.health.HealthConfig``) attributes the
    round: every selected client's wire delta is scored against a
    norm-clipped robust reference (per-client norm, cosine, leave-one-out
    cosine -> suspicion in [0, 1], ``repro.health.attribution``), folded
    into the fleet's suspicion EMA. With ``guards.susp_threshold`` > 0 the
    *previous* round's EMA additionally gates Eq. 7 selection (scores for
    this round's deltas cannot exist before aggregation, so the gate is
    one round behind by construction). On the plain-transport path the
    deltas are computed as a pure readout on the side — the aggregation
    shortcut (and its bit-identical numerics) is preserved.

    Returns (fleet, sel, fl_metrics) where ``sel`` is the (A,) aggregation
    mask and ``fl_metrics`` the per-round communication/defense metrics
    (``repro.fl.transport.FL_METRIC_KEYS``)."""
    transport = DEFAULT_TRANSPORT if transport is None else transport
    if trace and trace_id is None:
        raise ValueError("fl_round(trace=True) needs a trace_id operand "
                         "(a registered repro.obs.trace.Tracer id)")
    if health is not None and fleet.health is None:
        raise ValueError("fl_round(health=...) needs a fleet with health "
                         "state (fleet_init(..., health=...))")
    tok = None
    guards = DEFAULT_GUARDS if guards is None else guards
    byz_on = faults is not None and faults.byzantine_active
    a = fleet.pod_ids.shape[0]
    if available is None:
        available = jnp.ones((a,), bool)
    if byz_on and byzantine is None:
        byzantine = jnp.zeros((a,), bool)
    legacy_avail = available
    params = fleet.astate.params
    pending = fleet.pending
    rejected = jnp.zeros((), jnp.float32)
    clipped = jnp.zeros((), jnp.float32)

    # Parked uploads are validated before anything reads them (selection
    # included): a poisoned delta parked in an earlier round must not make
    # its offline owner selectable nor resurface into aggregation.
    if guards.reject_nonfinite and transport.async_rounds:
        pending, n_dropped = fl_stale.validate_pending(pending)
        rejected = rejected + n_dropped

    # --- communication model: payload sizes are static, links are per-agent
    if trace:
        # trace_token: the caller's enclosing span-begin token — making it a
        # dep of the first inner begin orders the callbacks outer-begin ->
        # inner-begin (unordered io_callbacks only order by data flow)
        tok = obs_trace.span_begin("fl/uplink", trace_id, fleet.bandwidth,
                                   trace_token, when=trace_when)
    up_bytes = fl_transport.agent_payload_bytes(params, transport,
                                               stacked=True)
    full_bytes = fl_transport.full_param_bytes(params, stacked=True)
    down_bytes = fl_transport.downlink_bytes(transport, a, fleet.n_pods,
                                             up_bytes, full_bytes)
    uplink_s = fl_transport.uplink_seconds(up_bytes, fleet.bandwidth)
    on_time = fl_transport.on_time_mask(uplink_s, transport.deadline_s)
    fresh_ok = legacy_avail & on_time
    if trace:
        tok = obs_trace.span_end("fl/uplink", trace_id, tok, fresh_ok,
                                 when=trace_when)

    # --- Eq. 7 selection. Sync rounds: a slow link emergently drops out of
    # selection. Async rounds: slow-but-alive clients stay selectable (they
    # park for the next round) and parked deltas are selectable even if
    # their owner is offline now (the server already holds them).
    if transport.async_rounds:
        selectable = legacy_avail | pending.has
    else:
        selectable = fresh_ok
    div = buffer_diversity_mean(fleet.astate.buffer)
    stats = fed.ClientStats(
        mem_avail=jnp.clip(1.0 - fleet.astate.env_state.pre_q
                           / fleet.env_params.queue_cap, 0, 1),
        compute_avail=jnp.clip(fleet.speeds / 2.0, 0, 1),
        diversity=div,
        bandwidth=fleet.bandwidth,
        available=selectable,
    )
    if health is not None and guards.susp_threshold > 0.0:
        # the attribution evidence stream closes into action: clients the
        # PREVIOUS round scored suspect lose their selection slot to the
        # next-best honest candidate
        sel = fed.select_clients(cfg, stats, suspicion=fleet.health.susp,
                                 susp_threshold=guards.susp_threshold)
    else:
        sel = fed.select_clients(cfg, stats)
    health_rej = jnp.zeros((a,), bool)  # nonfinite-rejected => suspicion 1

    head_losses = jax.vmap(
        lambda p, r, m: fed.per_head_losses(cfg, p, r, m)
    )(params, rollouts, fleet.masks)

    # --- reconstruct the server-side view of each client's parameters
    if transport.plain and not byz_on and guards.clip_factor <= 0:
        # lossless codec, nothing parked, nothing corrupted or clipped in
        # transit: base + (params - base) == params identically — skip the
        # delta machinery so the default config is bit-for-bit the
        # pre-transport program.
        recon, sel_agg = params, sel
        residuals, new_pending = fleet.residuals, pending
        transmitted = sel
        stale_used = jnp.zeros((), jnp.float32)
        if guards.reject_nonfinite:
            # identity on healthy params; a wedged client (NaN'd by its own
            # training) drops out of aggregation instead of poisoning it
            ok = guard_finite_mask(params)
            rejected = rejected + jnp.sum(sel & ~ok).astype(jnp.float32)
            sel_agg = sel & ok
            health_rej = sel & ~ok
        if health is not None:
            # pure readout on the side: the shortcut above still aggregates
            # the raw params, so the plain-path numerics stay bit-identical
            # to health-off — the deltas vs the downlinked base exist only
            # to be scored
            base_h = jax.tree.map(
                lambda b: shd.agent_hint(b[fleet.pod_ids]
                                         .astype(jnp.float32)),
                fleet.base_params)
            delta_h = jax.tree.map(
                lambda p, b: jnp.subtract(p.astype(jnp.float32), b),
                params, base_h)
            susp_new = health_attribution(delta_h, sel_agg)["susp"]
    else:
        if trace:
            tok = obs_trace.span_begin("fl/encode", trace_id, params, tok,
                                       when=trace_when)
        # The (P,...)->(A,...) gather is the round's downlink broadcast: the
        # agent hint lets a meshed run materialize it shard-local instead of
        # full-replica. Deltas are formed in float32 whatever the storage
        # policy (bf16 params would otherwise difference at bf16). Both are
        # no-ops under the default f32/no-mesh config.
        base_g = jax.tree.map(
            lambda b: shd.agent_hint(b[fleet.pod_ids].astype(jnp.float32)),
            fleet.base_params)
        delta = jax.tree.map(
            lambda p, b: jnp.subtract(p.astype(jnp.float32), b),
            params, base_g)
        # bind the trace-id operand so a Pallas codec kernel called in here
        # (transport.use_pallas) emits its kernel span against the same
        # tracer — binding None (trace off) is a no-op
        with obs_trace.bind_tid(trace_id if trace else None):
            decoded, res_next = fl_codec.codec_roundtrip(
                delta, fleet.residuals, transport)
        if byz_on:
            # corruption happens in transit, AFTER the honest client
            # encoded its delta and committed error feedback — the server
            # sees garbage, the client's own state stays consistent
            key = (fault_key if fault_key is not None
                   else jax.random.PRNGKey(faults.seed))
            decoded = rfaults.corrupt_deltas(faults, decoded, byzantine, key)
        if transport.async_rounds:
            w_stale = fl_stale.stale_weights(pending,
                                             transport.staleness_decay)
            contrib = fl_stale.merge_contributions(decoded, pending,
                                                   fresh_ok, w_stale)
            sel_agg = sel & (fresh_ok | pending.has)
            parked = sel & legacy_avail & ~on_time
            consumed = sel & pending.has & ~fresh_ok
            fresh_sent = sel & fresh_ok
            transmitted = fresh_sent | parked
            new_pending = fl_stale.update_pending(pending, decoded, parked,
                                                  consumed, fresh_sent)
            stale_used = jnp.sum(consumed).astype(jnp.float32)
        else:
            contrib = decoded
            sel_agg = sel            # selection already required on-time
            transmitted = sel
            new_pending = pending
            stale_used = jnp.zeros((), jnp.float32)
        # --- server-side defenses on the merged wire contributions ---
        if guards.reject_nonfinite:
            ok = guard_finite_mask(contrib)
            rejected = rejected + jnp.sum(sel_agg & ~ok).astype(jnp.float32)
            health_rej = sel_agg & ~ok
            sel_agg = sel_agg & ok
        if health is not None:
            # score the post-corruption wire deltas BEFORE clipping — the
            # clip would erase exactly the magnitude evidence the norm
            # term keys on
            susp_new = health_attribution(contrib, sel_agg)["susp"]
        if guards.clip_factor > 0:
            contrib, clipped = guard_clip_deltas(contrib, sel_agg,
                                                 guards.clip_factor)
        # only selected contributors are seen through the wire; everyone
        # else enters aggregation with their TRUE params, so Alg. 1's
        # no-contributor fallback ("groups with no contributor keep the
        # agent's own head") keeps real heads, not a lossy reconstruction
        # whose error feedback was never committed.
        recon = jax.tree.map(
            lambda rc, p: jnp.where(
                sel_agg.reshape((-1,) + (1,) * (rc.ndim - 1)), rc,
                p.astype(rc.dtype)),
            jax.tree.map(jnp.add, base_g, contrib), params)
        # error feedback commits only for deltas that actually went (or
        # will go, parked) over the wire; everyone else re-derives a fresh
        # delta against the moved base next round. The codec returns f32
        # residuals; they are stored back at StatePolicy.transport precision.
        residuals = jax.tree.map(
            lambda nr, r: jnp.where(
                transmitted.reshape((-1,) + (1,) * (nr.ndim - 1)),
                nr.astype(r.dtype), r),
            res_next, fleet.residuals)
        if trace:
            tok = obs_trace.span_end("fl/encode", trace_id, tok, recon,
                                     when=trace_when)

    if trace:
        tok = obs_trace.span_begin("fl/aggregate", trace_id, recon, tok,
                                   when=trace_when)
    # Algorithm 1 computes in float32 (recon may arrive bf16 off the plain
    # path under a lean model policy); the new fleet/base params are stored
    # back at the policy dtype — all astype identities under the default.
    new_params, new_base = fed.aggregate(
        cfg, dtp.tree_f32(recon), dtp.tree_f32(fleet.base_params), sel_agg,
        head_losses, fleet.head_groups, fleet.pod_ids, fleet.n_pods,
        method=guards.agg, trim_frac=guards.trim_frac)
    new_params = dtp.tree_cast_like(new_params, params)
    new_base = dtp.tree_cast_like(new_base, fleet.base_params)
    if trace:
        tok = obs_trace.span_end("fl/aggregate", trace_id, tok, new_params,
                                 when=trace_when)
        tok = obs_trace.span_begin("fl/finetune", trace_id, new_params, tok,
                                   when=trace_when)

    # Algorithm 2: local action-head fine-tuning on local experiences
    params, opt = jax.vmap(
        lambda p, o, r, m: finetune_heads(cfg, p, o, r, m)
    )(new_params, fleet.astate.opt, rollouts, fleet.masks)
    if trace:
        tok = obs_trace.span_end("fl/finetune", trace_id, tok, params,
                                 when=trace_when)

    # FL-round cadence is the off-hot-path slot to resync the buffers'
    # streaming moments from their slots, bounding rank-1 float32 drift.
    buffers = jax.vmap(buffer_resync)(fleet.astate.buffer)
    astate = fleet.astate._replace(params=params, opt=opt, buffer=buffers)

    n_up = jnp.sum(transmitted).astype(jnp.float32)
    fl_metrics = {
        "fl_payload_bytes": n_up * up_bytes + down_bytes,
        "fl_uplink_s": jnp.sum(jnp.where(transmitted, uplink_s, 0.0))
        / jnp.maximum(n_up, 1.0),
        "fl_missed": jnp.sum(legacy_avail & ~on_time).astype(jnp.float32),
        "fl_stale_used": stale_used,
        "fl_rejected": rejected,
        "fl_clipped": clipped,
    }
    if trace:
        # hand the final inner token back so the caller's enclosing span_end
        # is ordered after the last inner end callback (popped before the
        # metrics dict reaches the history)
        fl_metrics["_trace_tok"] = tok
    new_health = fleet.health
    if health is not None:
        # a rejected contribution is maximal evidence — the client shipped
        # garbage, whatever its direction would have scored
        susp_new = jnp.where(health_rej, 1.0, susp_new)
        new_health = health_update_round(health, fleet.health, susp_new,
                                         sel_agg | health_rej)
    fleet = fleet._replace(astate=astate, base_params=new_base,
                           residuals=residuals, pending=new_pending,
                           health=new_health)
    return fleet, sel_agg, fl_metrics


@partial(jax.jit, static_argnums=0, static_argnames=("faults",))
def pod_merge(cfg: FCPOConfig, fleet: Fleet, partition=None,
              faults: Optional[FaultConfig] = None):
    """Hierarchical cross-pod exchange (cloud tier).

    With partition faults active, ``partition`` ((P,) bool) is this merge
    event's fresh partition draws: a newly partitioned pod drops off the
    cloud tier for ``faults.partition_merges`` merge events (its base
    network drifts alone — only active pods average and redistribute),
    then rejoins. The default (no faults) is the original all-pods merge."""
    if faults is None or not faults.partition_active or partition is None:
        return fleet._replace(base_params=fed.merge_pods(fleet.base_params))
    timer = jnp.maximum(fleet.partition_timer - 1, 0)
    timer = jnp.where(partition, faults.partition_merges, timer)
    active = timer == 0
    return fleet._replace(base_params=fed.merge_pods(fleet.base_params,
                                                     active),
                          partition_timer=timer)


def _normalize_chaos(faults, guards):
    """Map inactive fault configs to None and a None guard config to the
    default — maximizes jit-cache identity with pre-chaos call sites."""
    if faults is not None and not faults.active:
        faults = None
    guards = DEFAULT_GUARDS if guards is None else guards
    return faults, guards


def _ensure_health(cfg: FCPOConfig, fleet: Fleet,
                   health: Optional[HealthConfig]) -> Fleet:
    """Attach fresh observatory state when a health config is given but the
    fleet predates it (e.g. a pre-health checkpoint) — a fleet that already
    carries state keeps it (chunked runs accumulate across restores)."""
    if health is not None and fleet.health is None:
        a = int(fleet.pod_ids.shape[0])
        fleet = fleet._replace(health=health_init(
            health, a, cfg.n_res + cfg.n_bs + cfg.n_mt))
    return fleet


def train_fleet_reference(cfg: FCPOConfig, fleet: Fleet, traces: jnp.ndarray,
                          learn: bool = True, federated: bool = True,
                          straggler_prob: float = 0.0, seed: int = 0,
                          env_backend=None,
                          transport: Optional[TransportConfig] = None,
                          metrics_sink=None,
                          faults: Optional[FaultConfig] = None,
                          guards: Optional[GuardConfig] = None,
                          episode_offset: int = 0,
                          total_episodes: Optional[int] = None,
                          tracer=None,
                          health: Optional[HealthConfig] = None):
    """The original Python-loop driver: one host dispatch per episode plus a
    per-metric host sync — O(n_episodes) dispatches. Kept as the equivalence
    oracle for ``train_fleet_scan`` (same seeds => same straggler draws,
    same fault plan). ``metrics_sink`` gets the same per-episode records as
    the scan driver's streaming tap, appended directly from the loop.
    ``faults``/``guards``/``episode_offset``/``total_episodes``/``health``
    mirror ``train_fleet_scan``. ``tracer`` records host-side episode /
    fl_round spans (this driver dispatches per episode, so plain wall
    bracketing is already phase-accurate; sampling follows
    ``span_sample_every``)."""
    backend = get_backend(env_backend)
    transport = DEFAULT_TRANSPORT if transport is None else transport
    faults, guards = _normalize_chaos(faults, guards)
    fleet = _ensure_health(cfg, fleet, health)
    a, total = traces.shape
    n_eps = total // cfg.n_steps
    total_eps = (episode_offset + n_eps if total_episodes is None
                 else total_episodes)
    if total_eps < episode_offset + n_eps:
        raise ValueError(f"total_episodes={total_eps} < episode_offset="
                         f"{episode_offset} + {n_eps} trace episodes")
    schedule = fed.fl_schedule(cfg, total_eps, federated=federated,
                               learn=learn)
    plan = rfaults.draw_fault_plan(schedule, a, fleet.n_pods, faults)
    crash_on = faults is not None and faults.crash_active
    byz_on = faults is not None and faults.byzantine_active
    part_on = faults is not None and faults.partition_active
    rng = np.random.default_rng(seed)
    history: Dict[str, list] = {}
    rounds = int(schedule[:episode_offset].sum())

    def hspan(name, e):  # sampled host-side span, no-op without a tracer
        if tracer is not None and e % tracer.span_sample_every == 0:
            return tracer.span(name, cat="phase")
        return nullcontext()

    for e in range(episode_offset):  # burn the pre-offset straggler draws
        if schedule[e]:
            rng.random(a)
    for e in range(episode_offset, episode_offset + n_eps):
        i = e - episode_offset
        rates = traces[:, i * cfg.n_steps:(i + 1) * cfg.n_steps]
        prev_astate = fleet.astate
        with hspan("episode", e):
            fleet, rollouts, metrics = fleet_episode(cfg, fleet, rates,
                                                     learn=learn,
                                                     backend=backend,
                                                     health=health)
            jax.block_until_ready(metrics)
        ran = None
        if crash_on:
            fleet, ran, down = rfaults.apply_crashes(
                faults, prev_astate, fleet, jnp.asarray(plan.crash[e]))
        fl_metrics = fl_transport.fl_zero_metrics()
        if schedule[e]:
            avail = jnp.asarray(rng.random(a) >= straggler_prob)
            if crash_on:
                avail = avail & ~down
            fkey = (jax.random.fold_in(jax.random.PRNGKey(faults.seed), e)
                    if byz_on else None)
            pre_round = fleet.astate
            with hspan("fl_round", e):
                fleet, _, fl_metrics = fl_round(
                    cfg, fleet, rollouts, avail, transport=transport,
                    guards=guards, faults=faults,
                    byzantine=(jnp.asarray(plan.byzantine[e]) if byz_on
                               else None),
                    fault_key=fkey, health=health)
                jax.block_until_ready(fl_metrics)
            if crash_on:
                # a down agent is offline: it must not receive the round's
                # new model (it rejoins later via the step-① warm start)
                fleet = fleet._replace(astate=rfaults.freeze_astate(
                    down, pre_round, fleet.astate))
            rounds += 1
            if rounds % cfg.hierarchical_period == 0 and fleet.n_pods > 1:
                fleet = pod_merge(
                    cfg, fleet,
                    jnp.asarray(plan.partition[e]) if part_on else None,
                    faults=faults if part_on else None)
        if ran is None:
            ep_metrics = {k: float(np.asarray(v).mean())
                          for k, v in metrics.items()}
        else:  # alive-weighted: a frozen agent's episode did not happen
            w = np.asarray(ran, np.float64)
            d = max(w.sum(), 1.0)
            ep_metrics = {k: float((np.asarray(v) * w).sum() / d)
                          for k, v in metrics.items()}
        ep_metrics.update({k: float(np.asarray(v))
                           for k, v in fl_metrics.items()})
        for k, v in ep_metrics.items():
            history.setdefault(k, []).append(v)
        if metrics_sink is not None:
            metrics_sink.append({"episode": e, **ep_metrics})
    return fleet, {k: np.asarray(v) for k, v in history.items()}


# ---------------------------------------------------------------------------
# Streaming metrics: a host-side sink tap on the per-episode metrics
# ---------------------------------------------------------------------------
# Sinks are registered here and addressed by an integer id passed to the
# compiled scan as a plain (non-static) operand, so attaching a different
# sink object to a same-shaped run NEVER recompiles — only the stream
# on/off bit is part of the jit cache key. The sink itself is duck-typed
# (anything with ``.append(record)``; ``repro.eval.stream.MetricsSink`` is
# the JSONL file implementation), which keeps ``core`` free of any
# dependency on the eval/observability layer.
_METRIC_SINKS: Dict[int, Any] = {}
_NEXT_SINK_ID = [1]


def _register_sink(sink) -> int:
    sid = _NEXT_SINK_ID[0]
    _NEXT_SINK_ID[0] += 1
    _METRIC_SINKS[sid] = sink
    return sid


def _sink_emit(names, sink_id, episode, values):
    """Host callback target (ordered ``jax.debug.callback`` from the scan
    body / plain call from the reference loop): one record per episode."""
    sink = _METRIC_SINKS.get(int(sink_id))
    if sink is not None:
        sink.append({"episode": int(episode),
                     **{k: float(v) for k, v in zip(names, values)}})


# ---------------------------------------------------------------------------
# Scanned driver — the whole episodes -> FL round -> pod merge cadence is one
# compiled program
# ---------------------------------------------------------------------------
def _scan_driver(cfg: FCPOConfig, fleet: Fleet, rates_eps: jnp.ndarray,
                 avail: jnp.ndarray, do_fl: jnp.ndarray, ep_idx: jnp.ndarray,
                 sink_id: jnp.ndarray, crash_eps: jnp.ndarray,
                 byz_eps: jnp.ndarray, part_eps: jnp.ndarray,
                 rounds0: jnp.ndarray, trace_id: jnp.ndarray,
                 trace_sample: jnp.ndarray, learn: bool,
                 backend: EnvBackend, transport: TransportConfig,
                 faults: Optional[FaultConfig],
                 guards: GuardConfig, stream: bool, trace: bool,
                 health: Optional[HealthConfig]):
    """Scan body host fn. rates_eps: (n_eps, A, n_steps); avail/do_fl/ep_idx:
    pre-drawn availability bits, FL schedule, and (absolute) episode
    indices, consumed as scan xs. crash_eps/byz_eps/part_eps: the pre-drawn
    fault plan (``resilience.draw_fault_plan``), also scan xs — dead code
    when ``faults`` (static) is None. ``rounds0`` seeds the FL-round
    counter so a resumed chunk keeps the hierarchical-merge cadence.
    ``stream`` (static: False / "ordered" / "unordered") taps every
    episode's metrics out to the registered sink ``sink_id`` via a host
    callback — the run is still ONE dispatch, but the sink's JSONL file
    tails live. Meshed runs use the unordered flavor (ordered effects are
    single-device-only); the scan's sequential data dependence still
    fires it once per episode. ``trace`` (static) +
    ``trace_id``/``trace_sample`` (operands) bracket the episode / FL-round
    / pod-merge phases with flight-recorder spans on every
    ``trace_sample``-th episode — same one-dispatch run, and the trace-off
    program is the exact span-free one. ``health`` (static) advances the
    observatory state through every episode and FL round (sketches, drift
    detectors, attribution) — all pure pytree ops inside the scan; None
    stages the exact health-free program."""
    crash_on = faults is not None and faults.crash_active
    byz_on = faults is not None and faults.byzantine_active
    part_on = faults is not None and faults.partition_active

    def body(carry, xs):
        flt, rounds = carry
        rates, av, fl, ep_i, crash, byz, px = xs
        when = (ep_i % trace_sample == 0) if trace else None
        if trace:
            tok_ep = obs_trace.span_begin("episode", trace_id, rates,
                                          when=when)
        prev_astate = flt.astate
        flt, rollouts, metrics = fleet_episode(cfg, flt, rates, learn=learn,
                                               backend=backend,
                                               health=health)
        if trace:
            tok_ep = obs_trace.span_end("episode", trace_id, tok_ep,
                                        metrics, when=when)
        ran = down = None
        if crash_on:
            flt, ran, down = rfaults.apply_crashes(faults, prev_astate, flt,
                                                   crash)
            av = av & ~down

        def with_fl(op):
            f, rnd = op
            fkey = (jax.random.fold_in(jax.random.PRNGKey(faults.seed), ep_i)
                    if byz_on else None)
            pre_round = f.astate
            if trace:
                tok_fl = obs_trace.span_begin("fl_round", trace_id,
                                              f.bandwidth, tok_ep, when=when)
            f, _, flm = fl_round(cfg, f, rollouts, av, transport=transport,
                                 guards=guards, faults=faults,
                                 byzantine=byz if byz_on else None,
                                 fault_key=fkey, trace=trace,
                                 trace_id=trace_id if trace else None,
                                 trace_when=when,
                                 trace_token=tok_fl if trace else None,
                                 health=health)
            if trace:
                # the popped inner token orders this end after the round's
                # last inner end callback (and keeps the metrics dict shapes
                # identical across the fl/no-fl cond branches)
                tok_fl = obs_trace.span_end("fl_round", trace_id, tok_fl,
                                            flm.pop("_trace_tok"),
                                            flm["fl_payload_bytes"],
                                            when=when)
            if crash_on:
                # a down agent is offline: it must not receive the round's
                # new model (it rejoins later via the step-① warm start)
                f = f._replace(astate=rfaults.freeze_astate(
                    down, pre_round, f.astate))
            rnd = rnd + 1
            if f.n_pods > 1:
                def merge(g):
                    if trace:
                        tm = obs_trace.span_begin("pod_merge", trace_id,
                                                  g.base_params, tok_fl,
                                                  when=when)
                    g = (pod_merge(cfg, g, px, faults=faults) if part_on
                         else pod_merge(cfg, g))
                    if trace:
                        obs_trace.span_end("pod_merge", trace_id, tm,
                                           g.base_params, when=when)
                    return g
                f = jax.lax.cond(rnd % cfg.hierarchical_period == 0,
                                 merge, lambda g: g, f)
            return (f, rnd), flm

        def no_fl(op):
            return op, fl_transport.fl_zero_metrics()

        (flt, rounds), flm = jax.lax.cond(fl, with_fl, no_fl, (flt, rounds))
        if ran is None:
            ep_metrics = {k: v.mean() for k, v in metrics.items()}
        else:  # alive-weighted: a frozen agent's episode did not happen
            w = ran.astype(jnp.float32)
            d = jnp.maximum(jnp.sum(w), 1.0)
            ep_metrics = {k: jnp.sum(v * w) / d for k, v in metrics.items()}
        ep_metrics.update(flm)
        if stream:
            names = tuple(sorted(ep_metrics))
            jax.debug.callback(partial(_sink_emit, names), sink_id, ep_i,
                               tuple(ep_metrics[k] for k in names),
                               ordered=(stream == "ordered"))
        return (flt, rounds), ep_metrics

    (fleet, _), history = jax.lax.scan(
        body, (fleet, rounds0),
        (rates_eps, avail, do_fl, ep_idx, crash_eps, byz_eps, part_eps))
    return fleet, history


_SCAN_FNS: Dict[bool, Any] = {}


def _scan_fn(donate: bool):
    if donate not in _SCAN_FNS:
        kw = dict(static_argnums=(0, 13, 14, 15, 16, 17, 18, 19, 20))
        if donate:
            kw["donate_argnums"] = (1,)
        _SCAN_FNS[donate] = jax.jit(_scan_driver, **kw)
    return _SCAN_FNS[donate]


def _prep_scan_args(cfg: FCPOConfig, fleet: Fleet, traces: jnp.ndarray,
                    learn, federated, straggler_prob, seed, mesh,
                    env_backend, transport, faults, guards,
                    episode_offset, total_episodes,
                    sink_id, stream, tracer, health=None):
    """Host-side argument prep shared by ``train_fleet_scan`` and
    ``lower_fleet_scan``: FL schedule, availability draws, fault plan,
    episode-major rate reshape, optional mesh sharding — returns the exact
    positional argument tuple for ``_scan_driver``/``_scan_fn``."""
    backend = get_backend(env_backend)
    transport = DEFAULT_TRANSPORT if transport is None else transport
    faults, guards = _normalize_chaos(faults, guards)
    fleet = _ensure_health(cfg, fleet, health)
    a, total = traces.shape
    n_eps = total // cfg.n_steps
    total_eps = (episode_offset + n_eps if total_episodes is None
                 else total_episodes)
    if total_eps < episode_offset + n_eps:
        raise ValueError(f"total_episodes={total_eps} < episode_offset="
                         f"{episode_offset} + {n_eps} trace episodes")
    schedule = fed.fl_schedule(cfg, total_eps, federated=federated,
                               learn=learn)
    avail = fed.draw_availability(schedule, a, straggler_prob, seed)
    plan = rfaults.draw_fault_plan(schedule, a, fleet.n_pods, faults)
    sl = slice(episode_offset, episode_offset + n_eps)
    rounds0 = int(schedule[:episode_offset].sum())

    rates_eps = jnp.asarray(traces[:, :n_eps * cfg.n_steps]).reshape(
        a, n_eps, cfg.n_steps).transpose(1, 0, 2)
    avail = jnp.asarray(avail[sl])
    do_fl = jnp.asarray(schedule[sl])
    ep_idx = jnp.arange(episode_offset, episode_offset + n_eps,
                        dtype=jnp.int32)
    crash_eps = jnp.asarray(plan.crash[sl])
    byz_eps = jnp.asarray(plan.byzantine[sl])
    part_eps = jnp.asarray(plan.partition[sl])

    if mesh is not None:
        fleet = jax.device_put(fleet, fleet_shardings(fleet, mesh))
        xs_shard = lambda x: jax.device_put(
            x, NamedSharding(mesh, shd.agent_batch_spec(x.shape, mesh)))
        rates_eps, avail = xs_shard(rates_eps), xs_shard(avail)

    trace = tracer is not None
    tid = tracer.tid if trace else 0
    tsamp = tracer.span_sample_every if trace else 1
    return (cfg, fleet, rates_eps, avail, do_fl, ep_idx,
            jnp.asarray(sink_id, jnp.int32), crash_eps, byz_eps, part_eps,
            jnp.asarray(rounds0, jnp.int32), jnp.asarray(tid, jnp.int32),
            jnp.asarray(tsamp, jnp.int32), learn, backend, transport,
            faults, guards, stream, trace, health)


def lower_fleet_scan(cfg: FCPOConfig, fleet: Fleet, traces: jnp.ndarray,
                     learn: bool = True, federated: bool = True,
                     straggler_prob: float = 0.0, seed: int = 0,
                     mesh=None, donate: bool = True, env_backend=None,
                     transport: Optional[TransportConfig] = None,
                     faults: Optional[FaultConfig] = None,
                     guards: Optional[GuardConfig] = None,
                     episode_offset: int = 0,
                     total_episodes: Optional[int] = None,
                     health: Optional[HealthConfig] = None):
    """Lower (without running) the exact scanned-driver program that
    ``train_fleet_scan`` would dispatch for these arguments — including
    buffer donation — and return the ``jax.stages.Lowered``. This is the
    entry point ``repro.obs.profile`` uses for XLA cost/memory accounting
    and the donation audit: the program analyzed is the program trained."""
    args = _prep_scan_args(cfg, fleet, traces, learn, federated,
                           straggler_prob, seed, mesh, env_backend,
                           transport, faults, guards, episode_offset,
                           total_episodes, sink_id=0, stream=False,
                           tracer=None, health=health)
    # trace under the mesh's resource env so the in-graph sharding hints
    # (sharding.ambient_mesh) resolve — the analyzed program is the meshed
    # program train_fleet_scan would run
    with (mesh if mesh is not None else nullcontext()):
        return _scan_fn(bool(donate)).lower(*args)


def train_fleet_scan(cfg: FCPOConfig, fleet: Fleet, traces: jnp.ndarray,
                     learn: bool = True, federated: bool = True,
                     straggler_prob: float = 0.0, seed: int = 0,
                     mesh=None, donate: Optional[bool] = None,
                     env_backend=None,
                     transport: Optional[TransportConfig] = None,
                     metrics_sink=None,
                     faults: Optional[FaultConfig] = None,
                     guards: Optional[GuardConfig] = None,
                     episode_offset: int = 0,
                     total_episodes: Optional[int] = None,
                     tracer=None,
                     health: Optional[HealthConfig] = None):
    """Scanned fleet driver: episodes over ``traces`` (A, total_steps), FL
    every ``fl_every`` episodes (stragglers masked by pre-drawn availability
    bits), cross-pod merge every ``hierarchical_period`` rounds — all inside
    ONE jitted ``lax.scan``; O(1) host dispatches per run.

    ``mesh``: install fleet shardings (agents over data, pods over the FL
    hierarchy) on inputs before the call AND enter the mesh for the
    dispatch, so the in-graph hints turn the Alg. 1 segment-sums, the
    base-network downlink gather, and the pod merge into real collectives
    over the mesh — the scan then runs SPMD (``launch.mesh.make_fleet_mesh``
    builds the (pod, data) mesh; tests/test_mesh.py locks meshed == single-
    device seed-for-seed).
    ``donate``: donate the input fleet's buffers to the compiled call
    (defaults to on except on CPU, where XLA cannot donate).
    ``env_backend``: ``"fluid"`` / ``"twin"`` / an ``EnvBackend`` — with the
    twin, every control interval nests K data-plane microticks *inside* the
    same single scan (no host Python per microtick; ``fleet`` must have been
    built with the same backend).
    ``transport``: a jit-static ``repro.fl.TransportConfig`` — delta codec,
    round deadline (emergent stragglers compose with the Bernoulli
    ``straggler_prob`` mask), and async staleness semantics; the per-round
    communication metrics (``fl_payload_bytes``/``fl_uplink_s``/
    ``fl_missed``/``fl_stale_used``) appear in the history, zero on
    episodes without a round.
    ``metrics_sink``: any object with ``.append(record)`` (e.g.
    ``repro.eval.stream.MetricsSink``) — every episode's metrics are tapped
    out of the scan through an ordered host callback as they complete, so a
    long run is observable live (``launch/watch.py``) while still being ONE
    dispatch. Off (None) by default, in which case the traced program is
    exactly the sink-free one.
    ``faults``: a jit-static ``repro.resilience.FaultConfig`` — injected
    crashes / byzantine deltas / pod partitions, pre-drawn on host
    (``draw_fault_plan``) and consumed as scan xs, so the chaos run is
    still ONE jitted scan. ``guards``: a jit-static
    ``repro.resilience.GuardConfig`` — robust aggregation / delta clipping
    / non-finite rejection. The defaults compile to the exact pre-chaos
    program, bit-for-bit seed-for-seed.
    ``episode_offset``/``total_episodes``: run episodes
    [offset, offset + traces-episodes) of a ``total_episodes``-long
    schedule — straggler draws, fault plans, FL cadence, and the
    hierarchical-merge counter all follow the *absolute* episode index, so
    a run chunked across checkpoint save/restore boundaries is
    value-identical to the uninterrupted run.
    ``tracer``: a ``repro.obs.trace.Tracer`` — flight-recorder spans for
    the episode / FL-round (encode, uplink, aggregate, finetune) /
    pod-merge phases, emitted from inside the single dispatch by host
    callbacks on every ``tracer.span_sample_every``-th episode. Off (None)
    by default, in which case the traced program is exactly the span-free
    one; the tracer object is addressed by a non-static integer id, so
    re-tracing the same-shaped run with a fresh tracer never recompiles.
    ``health``: a jit-static ``repro.health.HealthConfig`` — the fleet
    health observatory: per-agent telemetry sketches + drift detectors
    advanced per control interval, FL contribution attribution per round,
    all as pure pytree state inside the same single scan; the per-episode
    summaries (``repro.health.HEALTH_METRIC_KEYS``) join the history and
    the metrics stream. A fleet without health state gets fresh state
    attached (``_ensure_health``). Off (None) by default, in which case
    the traced program is exactly the health-free one — bit-identical
    histories, unchanged donation audit.
    Returns (fleet, history) with history as per-episode numpy arrays,
    fetched in a single device->host transfer."""
    if donate is None:
        donate = jax.default_backend() != "cpu"
    # ordered callbacks are a single-device-only effect in XLA; on a multi-
    # device mesh the tap switches to an unordered callback, which the scan's
    # sequential data dependence still fires once per episode, in order
    stream = False if metrics_sink is None else \
        ("ordered" if mesh is None or mesh.size == 1 else "unordered")
    sid = _register_sink(metrics_sink) if stream else 0
    args = _prep_scan_args(cfg, fleet, traces, learn, federated,
                           straggler_prob, seed, mesh, env_backend,
                           transport, faults, guards, episode_offset,
                           total_episodes, sink_id=sid, stream=stream,
                           tracer=tracer, health=health)
    try:
        # entering the mesh's resource env activates the in-graph sharding
        # hints (agents over (pod, data), pods over the FL hierarchy): the
        # Alg. 1 segment-sums and the pod merge lower to real collectives.
        # Without a mesh the hints are no-ops and the traced program is the
        # exact single-device one.
        with obs_trace.activate(tracer), \
                (mesh if mesh is not None else nullcontext()):
            fleet, history = _scan_fn(bool(donate))(*args)
            history = jax.device_get(history)
    finally:
        if stream:
            # the history fetch blocks on the compute; the callback effects
            # drain behind it — barrier before releasing the sink slot
            jax.effects_barrier()
            _METRIC_SINKS.pop(sid, None)
        if tracer is not None:
            tracer.drain()
    return fleet, history


def train_fleet(cfg: FCPOConfig, fleet: Fleet, traces: jnp.ndarray,
                learn: bool = True, federated: bool = True,
                straggler_prob: float = 0.0, seed: int = 0,
                env_backend=None, transport: Optional[TransportConfig] = None,
                metrics_sink=None, faults: Optional[FaultConfig] = None,
                guards: Optional[GuardConfig] = None, tracer=None,
                health: Optional[HealthConfig] = None):
    """Compatibility entry point — delegates to the scanned driver. Buffer
    donation stays off so callers may keep using the input fleet (forking a
    fleet into warm/cold copies is a common pattern in the benchmarks)."""
    return train_fleet_scan(cfg, fleet, traces, learn=learn,
                            federated=federated,
                            straggler_prob=straggler_prob, seed=seed,
                            donate=False, env_backend=env_backend,
                            transport=transport, metrics_sink=metrics_sink,
                            faults=faults, guards=guards, tracer=tracer,
                            health=health)
