"""Fleet driver: the full FCPO loop over a fleet of iAgents.

One fleet = stacked agent pytrees (A on the leading axis) + stacked env
params/states + per-pod base networks. The CRL inner loop is ``vmap``'d;
the FL round is Algorithm 1 over the stacked axis. Under the production
mesh the agent axis is sharded over ``data`` (and ``pod`` maps to the FL
hierarchy), making the entire federated-continual system one SPMD program.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.fcpo import FCPOConfig
from repro.core import env as env_mod
from repro.core import federated as fed
from repro.core.agent import ActionMask, agent_init, full_mask
from repro.core.buffer import buffer_init
from repro.core.crl import AgentState, crl_episode
from repro.core.ppo import agent_opt_init, finetune_heads


@jax.tree_util.register_pytree_node_class
class Fleet:
    """Stacked fleet state. ``n_pods`` and the head-group *counts* are static
    (pytree aux data); everything else is traced leaves."""

    FIELDS = ("astate", "base_params", "env_params", "masks", "group_ids",
              "pod_ids", "bandwidth", "speeds", "episode")

    def __init__(self, astate, base_params, env_params, masks, group_ids,
                 pod_ids, bandwidth, speeds, episode, *, n_pods,
                 group_counts):
        self.astate: AgentState = astate
        self.base_params = base_params
        self.env_params: env_mod.EnvParams = env_params
        self.masks: ActionMask = masks
        self.group_ids: Dict[str, jnp.ndarray] = group_ids  # per head key
        self.pod_ids = pod_ids
        self.bandwidth = bandwidth
        self.speeds = speeds
        self.episode = episode
        self.n_pods: int = n_pods
        self.group_counts: Dict[str, int] = group_counts

    @property
    def head_groups(self) -> Dict[str, Any]:
        out: Dict[str, Any] = dict(self.group_ids)
        for k, v in self.group_counts.items():
            out[f"{k}_count"] = v
        return out

    def _replace(self, **kw) -> "Fleet":
        vals = {f: getattr(self, f) for f in self.FIELDS}
        vals.update(kw)
        return Fleet(**vals, n_pods=self.n_pods, group_counts=self.group_counts)

    def tree_flatten(self):
        leaves = tuple(getattr(self, f) for f in self.FIELDS)
        aux = (self.n_pods, tuple(sorted(self.group_counts.items())))
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        n_pods, gc = aux
        return cls(*leaves, n_pods=n_pods, group_counts=dict(gc))


def fleet_init(cfg: FCPOConfig, n_agents: int, key, *, n_pods: int = 1,
               masks: Optional[ActionMask] = None,
               speeds: Optional[jnp.ndarray] = None,
               bandwidth: Optional[jnp.ndarray] = None,
               slo_s: Optional[float] = None) -> Fleet:
    kp, kb, ke, kr = jax.random.split(key, 4)
    agent_keys = jax.random.split(kp, n_agents)
    params = jax.vmap(lambda k: agent_init(cfg, k))(agent_keys)
    opt = jax.vmap(agent_opt_init)(params)
    buffers = jax.vmap(lambda _: buffer_init(cfg))(jnp.arange(n_agents))
    env_states = jax.vmap(lambda _: env_mod.env_init(cfg))(jnp.arange(n_agents))
    rngs = jax.random.split(kr, n_agents)

    if speeds is None:  # heterogeneous device mix (Orin/NX/AGX/server-like)
        speeds = jnp.asarray(
            np.random.default_rng(0).choice([0.5, 0.75, 1.0, 2.0], n_agents))
    if bandwidth is None:
        bandwidth = jnp.asarray(
            np.random.default_rng(1).uniform(2.0, 40.0, n_agents))
    env_params = jax.vmap(lambda s: env_mod.default_env_params(
        s, cfg.slo_s if slo_s is None else slo_s))(speeds)

    if masks is None:
        masks = jax.tree.map(lambda m: jnp.broadcast_to(m, (n_agents,) + m.shape),
                             full_mask(cfg))
    hg = fed.head_group_ids(masks)
    group_ids = {k: v for k, v in hg.items() if not k.endswith("_count")}
    group_counts = {k[:-len("_count")]: v for k, v in hg.items()
                    if k.endswith("_count")}
    pod_ids = jnp.asarray(np.arange(n_agents) % n_pods, jnp.int32)

    base = agent_init(cfg, kb)
    base_params = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_pods,) + x.shape), base)

    astate = AgentState(params=params, opt=opt, buffer=buffers,
                        env_state=env_states, rng=rngs)
    return Fleet(astate, base_params, env_params, masks, group_ids,
                 pod_ids, bandwidth, speeds, jnp.zeros((), jnp.int32),
                 n_pods=n_pods, group_counts=group_counts)


@partial(jax.jit, static_argnums=0, static_argnames=("learn",))
def fleet_episode(cfg: FCPOConfig, fleet: Fleet, rates: jnp.ndarray,
                  learn: bool = True):
    """One CRL episode for all agents. rates: (A, n_steps).
    Returns (fleet, rollouts, metrics)."""
    astate, rollouts, metrics = jax.vmap(
        lambda ep, st, r, m: crl_episode(cfg, ep, st, r, m, learn)
    )(fleet.env_params, fleet.astate, rates, fleet.masks)
    fleet = fleet._replace(astate=astate, episode=fleet.episode + 1)
    return fleet, rollouts, metrics


@partial(jax.jit, static_argnums=0)
def fl_round(cfg: FCPOConfig, fleet: Fleet, rollouts, available=None):
    """One federated round: Eq. 7 selection -> Alg. 1 aggregation ->
    Alg. 2 head fine-tuning. ``available`` masks out stragglers/offline
    agents (fault tolerance)."""
    a = fleet.pod_ids.shape[0]
    if available is None:
        available = jnp.ones((a,), bool)

    div = jnp.where(fleet.astate.buffer.filled, fleet.astate.buffer.score,
                    0.0).mean(-1)
    stats = fed.ClientStats(
        mem_avail=jnp.clip(1.0 - fleet.astate.env_state.pre_q
                           / fleet.env_params.queue_cap, 0, 1),
        compute_avail=jnp.clip(fleet.speeds / 2.0, 0, 1),
        diversity=div,
        bandwidth=fleet.bandwidth,
        available=available,
    )
    sel = fed.select_clients(cfg, stats)

    head_losses = jax.vmap(
        lambda p, r, m: fed.per_head_losses(cfg, p, r, m)
    )(fleet.astate.params, rollouts, fleet.masks)

    new_params, new_base = fed.aggregate(
        cfg, fleet.astate.params, fleet.base_params, sel, head_losses,
        fleet.head_groups, fleet.pod_ids, fleet.n_pods)

    # Algorithm 2: local action-head fine-tuning on local experiences
    params, opt = jax.vmap(
        lambda p, o, r, m: finetune_heads(cfg, p, o, r, m)
    )(new_params, fleet.astate.opt, rollouts, fleet.masks)

    astate = fleet.astate._replace(params=params, opt=opt)
    return fleet._replace(astate=astate, base_params=new_base), sel


@partial(jax.jit, static_argnums=0)
def pod_merge(cfg: FCPOConfig, fleet: Fleet):
    """Hierarchical cross-pod exchange (cloud tier)."""
    return fleet._replace(base_params=fed.merge_pods(fleet.base_params))


def train_fleet(cfg: FCPOConfig, fleet: Fleet, traces: jnp.ndarray,
                learn: bool = True, federated: bool = True,
                straggler_prob: float = 0.0, seed: int = 0):
    """Run episodes over ``traces`` (A, total_steps); FL every ``fl_every``
    episodes; cross-pod merge every ``hierarchical_period`` rounds.
    Returns (fleet, history dict of per-episode metric arrays)."""
    a, total = traces.shape
    n_eps = total // cfg.n_steps
    rng = np.random.default_rng(seed)
    history: Dict[str, list] = {}
    rounds = 0
    for e in range(n_eps):
        rates = traces[:, e * cfg.n_steps:(e + 1) * cfg.n_steps]
        fleet, rollouts, metrics = fleet_episode(cfg, fleet, rates, learn=learn)
        if federated and learn and (e + 1) % cfg.fl_every == 0:
            avail = jnp.asarray(rng.random(a) >= straggler_prob)
            fleet, _ = fl_round(cfg, fleet, rollouts, avail)
            rounds += 1
            if rounds % cfg.hierarchical_period == 0 and fleet.n_pods > 1:
                fleet = pod_merge(cfg, fleet)
        for k, v in metrics.items():
            history.setdefault(k, []).append(np.asarray(v).mean())
    return fleet, {k: np.asarray(v) for k, v in history.items()}
