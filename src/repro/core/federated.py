"""Agent-specific Federated RL (§IV-D): Algorithms 1 & 2, Eq. 7 selection,
hierarchical rounds — expressed over *stacked* fleet pytrees.

The fleet's parameters live in one pytree with a leading agent axis (A, ...),
sharded over the mesh's ``data`` axis at scale. Algorithm 1 then becomes a
handful of masked segment-means — no parameter server, no per-agent RPCs —
which is the JAX-native answer to the paper's §VI scalability concern.

Faithful mapping of Algorithm 1:
  * backbone + value head: *equal* aggregation over selected clients AND the
    server's base network, divided by |M|+1 (lines 3-7, 12, 17).
  * action heads: aggregated only within groups of agents whose head output
    dimensionality (action-space mask) matches (line 8: "across all agents
    with the same output dimensions"), weighted by head loss (line 9).
    The pseudo-code's centered factor ``LOSS_l − LOSS_TOTAL/|M|`` makes the
    client contributions cancel to zero when losses are equal; we implement
    the evident intent — lower-loss heads get more weight — via
    ``w_i = exp(−(loss_i − mean(loss)))`` renormalized to |M_g| (reduces to
    equal aggregation for equal losses). Deviation documented here and in
    DESIGN.md.
  * after aggregation all agents receive the new backbone/value and their
    group's head (system step ① — helps cold starts), then fine-tune heads
    locally per Algorithm 2 (``ppo.finetune_heads``).

Client selection (Eq. 7): ``TotalUtil(c) = Util(c)·sqrt(Bandwidth/10)`` with
FedHybrid-style ``Util`` = memory availability + compute availability + data
diversity (the buffer's mean diversity score). Stragglers enter as an
availability mask — a timed-out client simply drops out of this round's
selection (fault tolerance for free: aggregation is defined for any subset,
including the empty one, which degenerates to keeping the base network).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.fcpo import FCPOConfig
from repro.core.agent import BACKBONE_KEYS, HEAD_KEYS, ActionMask
from repro.core.ppo import Rollout, action_logp, gae
from repro.distributed.sharding import agent_hint, pod_hint


# ---------------------------------------------------------------------------
# Per-head policy losses (Alg. 1's LOSS_l)
# ---------------------------------------------------------------------------
def per_head_losses(cfg: FCPOConfig, params, rollout: Rollout,
                    mask: ActionMask) -> jnp.ndarray:
    """(3,) policy-loss per action head on this agent's experiences."""
    from repro.core.agent import agent_forward  # local import to avoid cycle

    out = agent_forward(cfg, params, rollout.states, mask)
    adv = gae(cfg, rollout.rewards, rollout.values_old)
    adv = (adv - adv.mean()) / (adv.std() + 1e-6)
    factor = -adv + jnp.exp(-rollout.rewards)

    losses = []
    for i, head in enumerate(("res", "bs", "mt")):
        logp = jnp.take_along_axis(out[head], rollout.actions[..., i:i + 1],
                                   -1)[..., 0]
        ratio = jnp.exp(logp - jax.lax.stop_gradient(logp))  # =1 at eval point
        l = jnp.mean(jnp.minimum(cfg.eps_clip * ratio, ratio) * factor)
        losses.append(l)
    return jnp.stack(losses)


# ---------------------------------------------------------------------------
# Client selection (Eq. 7)
# ---------------------------------------------------------------------------
class ClientStats(NamedTuple):
    mem_avail: jnp.ndarray      # (A,) in [0,1]
    compute_avail: jnp.ndarray  # (A,) in [0,1]
    diversity: jnp.ndarray      # (A,) mean buffer diversity score
    bandwidth: jnp.ndarray      # (A,) Mbit/s
    available: jnp.ndarray      # (A,) bool — False = straggler/offline


def total_utility(stats: ClientStats) -> jnp.ndarray:
    div = stats.diversity / (1.0 + jnp.abs(stats.diversity))  # squash
    util = (stats.mem_avail + stats.compute_avail + div) / 3.0
    return util * jnp.sqrt(jnp.maximum(stats.bandwidth, 1e-3) / 10.0)


def select_clients(cfg: FCPOConfig, stats: ClientStats,
                   suspicion=None, susp_threshold: float = 0.0
                   ) -> jnp.ndarray:
    """Top-⌈frac·A⌉ by TotalUtil among available clients -> (A,) bool mask.
    Exactly k are chosen (argsort tie-break), minus any unavailable.

    ``suspicion`` ((A,) in [0, 1], the health observatory's attribution
    EMA from the previous round) with ``susp_threshold`` > 0 removes
    suspect clients from the candidate pool *before* the top-k, so an
    excluded attacker frees its slot for an honest client instead of
    shrinking the round."""
    a = stats.available.shape[0]
    k = max(1, int(round(cfg.clients_per_round * a)))
    available = stats.available
    if suspicion is not None and susp_threshold > 0.0:
        available = available & (suspicion <= susp_threshold)
    utils = jnp.where(available, total_utility(stats), -jnp.inf)
    order = jnp.argsort(-utils)
    sel = jnp.zeros((a,), bool).at[order[:k]].set(True)
    return sel & available


# ---------------------------------------------------------------------------
# Algorithm 1 — agent-specific aggregation over stacked fleets
# ---------------------------------------------------------------------------
AGG_METHODS = ("mean", "trimmed", "median")


def _gather_rank(srt, rank):
    """srt: (S, M, ...) sorted along axis 1; rank: (S,) int. Returns the
    rank-th entry of each segment row, shape (S, ...)."""
    idx = rank.reshape((rank.shape[0], 1) + (1,) * (srt.ndim - 2))
    idx = jnp.broadcast_to(idx, (rank.shape[0], 1) + srt.shape[2:])
    return jnp.take_along_axis(srt, idx, axis=1)[:, 0]


def _robust_stat(vals, valid, method: str, trim_frac: float):
    """Coordinate-wise robust statistic over each segment row.

    vals: (S, M, ...) candidate contributions; valid: (S, M) bool. Invalid
    entries are pushed to +inf, so after the per-coordinate sort ranks
    [0, n) with n = valid-count are exactly the valid entries. ``median``
    is the usual odd/even-average; ``trimmed`` is the mean of ranks
    [t, n − t) with t = floor(trim_frac · n) (t < n − t for any
    trim_frac < 0.5 and n ≥ 1). Callers guarantee n ≥ 1 per segment (the
    base network is always a valid participant)."""
    vb = valid.reshape(valid.shape + (1,) * (vals.ndim - 2))
    srt = jnp.sort(jnp.where(vb, vals, jnp.inf), axis=1)
    n = jnp.sum(valid, axis=1)
    if method == "median":
        lo = _gather_rank(srt, jnp.maximum((n - 1) // 2, 0))
        hi = _gather_rank(srt, n // 2)
        return 0.5 * (lo + hi)
    if method == "trimmed":
        t = jnp.floor(trim_frac * n).astype(n.dtype)
        ranks = jnp.arange(vals.shape[1])
        inc = (ranks[None, :] >= t[:, None]) & (ranks[None, :] < (n - t)[:, None])
        incb = inc.reshape(inc.shape + (1,) * (vals.ndim - 2))
        kept = jnp.maximum(n - 2 * t, 1).astype(vals.dtype)
        denom = kept.reshape((n.shape[0],) + (1,) * (vals.ndim - 2))
        return jnp.sum(jnp.where(incb, srt, 0.0), axis=1) / denom
    raise ValueError(f"unknown robust method {method!r}")


def _robust_masked_with_base(stacked, base, sel, pod_ids, n_pods,
                             method: str, trim_frac: float):
    """Robust counterpart of ``_masked_mean_with_base``: the per-pod
    coordinate-wise statistic over {selected clients of the pod} ∪ {the
    pod's base network}. Degenerates to the base network for an empty
    selection, like the mean path."""
    valid = sel[None, :] & (pod_ids[None, :] == jnp.arange(n_pods)[:, None])
    vals = jnp.concatenate(
        [jnp.broadcast_to(stacked[None], (n_pods,) + stacked.shape),
         base[:, None]], axis=1)
    valid = jnp.concatenate(
        [valid, jnp.ones((n_pods, 1), bool)], axis=1)
    agg = pod_hint(_robust_stat(vals, valid, method, trim_frac))
    return agent_hint(agg[pod_ids]), agg


def _masked_mean_with_base(stacked, base, sel, pod_ids, n_pods):
    """(base + Σ_sel m) / (n_sel + 1), per pod segment.

    stacked: (A, ...); base: (P, ...); sel: (A,) bool; pod_ids: (A,) int.
    Returns (per-agent broadcast (A, ...), new base (P, ...)).
    """
    w = sel.astype(stacked.dtype)
    wsum = jax.ops.segment_sum(w, pod_ids, n_pods)                 # (P,)
    ssum = jax.ops.segment_sum(stacked * w.reshape((-1,) + (1,) * (stacked.ndim - 1)),
                               pod_ids, n_pods)                    # (P, ...)
    denom = (wsum + 1.0).reshape((n_pods,) + (1,) * (stacked.ndim - 1))
    # Sharding hints (no-ops without an ambient mesh): the segment-sum is a
    # reduce over agent shards into the pod placement, and the gather back
    # to agents is the redistribution — under a mesh XLA lowers this to
    # real collectives instead of gathering a full replica per device.
    agg = pod_hint((base + ssum) / denom)                          # (P, ...)
    return agent_hint(agg[pod_ids]), agg


def _head_weights(sel, losses_h, group_ids, n_groups):
    """Loss-centered exponential weights, renormalized within (pod×group)."""
    w = sel.astype(jnp.float32)
    cnt = jax.ops.segment_sum(w, group_ids, n_groups)
    lsum = jax.ops.segment_sum(losses_h * w, group_ids, n_groups)
    mean_l = lsum / jnp.maximum(cnt, 1.0)
    raw = jnp.exp(-(losses_h - mean_l[group_ids])) * w
    rsum = jax.ops.segment_sum(raw, group_ids, n_groups)
    # renormalize so weights sum to the group count (equal-loss ⇒ all 1)
    return raw * (cnt / jnp.maximum(rsum, 1e-9))[group_ids]


def aggregate(cfg: FCPOConfig, fleet_params, base_params, sel: jnp.ndarray,
              head_losses: jnp.ndarray, head_groups: Dict[str, jnp.ndarray],
              pod_ids: Optional[jnp.ndarray] = None, n_pods: int = 1,
              method: str = "mean", trim_frac: float = 0.2
              ) -> Tuple[Any, Any]:
    """Run Algorithm 1. Returns (new_fleet_params, new_base_params).

    fleet_params: stacked (A, ...); base_params: (P, ...) per-pod base
    networks; head_losses: (A, 3); head_groups: per head key -> (A,) int32
    group ids (agents sharing an action-space signature); pod_ids: (A,).

    ``method`` (static): ``"mean"`` is the paper's equal/loss-weighted
    aggregation — the exact pre-chaos code path, bit-for-bit.
    ``"trimmed"``/``"median"`` replace every segment mean with the
    coordinate-wise robust statistic over {selected clients} ∪ {base}
    (byzantine tolerance: any f corrupt clients with f ≤ the trim budget
    cannot push a coordinate outside the honest range). Robust head
    aggregation drops the loss weighting — rank statistics already bound
    influence, and a byzantine client could game reported losses anyway.
    """
    if method not in AGG_METHODS:
        raise ValueError(f"unknown aggregation method {method!r}; expected "
                         f"one of {AGG_METHODS}")
    a = sel.shape[0]
    if pod_ids is None:
        pod_ids = jnp.zeros((a,), jnp.int32)
    robust = method != "mean"

    new_fleet = {}
    new_base = {}

    # --- backbone + value: equal aggregation (lines 3-7, 12) ---
    for key in BACKBONE_KEYS:
        if robust:
            out = jax.tree.map(
                lambda st, b: _robust_masked_with_base(
                    st, b, sel, pod_ids, n_pods, method, trim_frac),
                fleet_params[key], base_params[key])
        else:
            out = jax.tree.map(
                lambda st, b: _masked_mean_with_base(st, b, sel, pod_ids,
                                                     n_pods),
                fleet_params[key], base_params[key])
        new_fleet[key] = jax.tree.map(lambda t: t[0], out,
                                      is_leaf=lambda t: isinstance(t, tuple))
        new_base[key] = jax.tree.map(lambda t: t[1], out,
                                     is_leaf=lambda t: isinstance(t, tuple))

    # --- action heads: loss-weighted within (pod × output-dim group) ---
    for h_idx, key in enumerate(HEAD_KEYS):
        if key not in fleet_params:  # single-head ablation variant
            continue
        groups = head_groups[key]                          # (A,) int32
        n_groups_local = int(head_groups[f"{key}_count"])
        seg = pod_ids * n_groups_local + groups            # pod×group segments
        n_seg = n_pods * n_groups_local
        wts = _head_weights(sel, head_losses[:, h_idx], seg, n_seg)

        def agg_leaf(st, b):
            wshape = (-1,) + (1,) * (st.ndim - 1)
            cnt = jax.ops.segment_sum(sel.astype(jnp.float32), seg, n_seg)
            # base head is per pod; broadcast to every group in that pod
            b_seg = jnp.repeat(b, n_groups_local, axis=0)
            if robust:
                valid = (sel[None, :]
                         & (seg[None, :] == jnp.arange(n_seg)[:, None]))
                vals = jnp.concatenate(
                    [jnp.broadcast_to(st[None], (n_seg,) + st.shape),
                     b_seg[:, None]], axis=1)
                v2 = jnp.concatenate(
                    [valid, jnp.ones((n_seg, 1), bool)], axis=1)
                agg = _robust_stat(vals, v2, method, trim_frac)
            else:
                ssum = jax.ops.segment_sum(st * wts.reshape(wshape), seg,
                                           n_seg)
                denom = (cnt + 1.0).reshape((n_seg,) + (1,) * (st.ndim - 1))
                agg = (b_seg + ssum) / denom                # (n_seg, ...)
            agg = pod_hint(agg)  # pod-major segments follow the pod placement
            per_agent = agent_hint(agg[seg])
            # groups with no contributor keep the agent's own head
            has = (cnt[seg] > 0).reshape(wshape)
            per_agent = jnp.where(has, per_agent, st)
            # new base per pod: mean over that pod's groups
            nb = agg.reshape((n_pods, n_groups_local) + st.shape[1:]).mean(1)
            return per_agent, nb

        out = jax.tree.map(agg_leaf, fleet_params[key], base_params[key])
        new_fleet[key] = jax.tree.map(lambda t: t[0], out,
                                      is_leaf=lambda t: isinstance(t, tuple))
        new_base[key] = jax.tree.map(lambda t: t[1], out,
                                     is_leaf=lambda t: isinstance(t, tuple))

    return new_fleet, new_base


def merge_pods(base_params, active=None):
    """Hierarchical FL (§IV-D Large-Scale): cross-cluster exchange through
    the cloud — pods' base networks are averaged and redistributed.

    ``active`` ((P,) bool, optional) models network partitions: only active
    pods contribute to and receive the cloud average; a partitioned pod
    keeps its own base network until it rejoins. ``active=None`` is the
    original all-pods merge (identical program).

    The cross-pod mean runs in float32 even when the base networks are
    stored bf16 (StatePolicy.model), and the pod-sharding hints let XLA
    express the merge as an all-reduce over the pod placement instead of a
    full-replica broadcast — both no-ops under the default f32/no-mesh
    config."""
    if active is None:
        def mix(b):
            m = pod_hint(b).astype(jnp.float32).mean(0, keepdims=True)
            return pod_hint(jnp.broadcast_to(m, b.shape).astype(b.dtype))
        return jax.tree.map(mix, base_params)

    n_act = jnp.maximum(jnp.sum(active), 1)

    def mix(b):
        b32 = pod_hint(b).astype(jnp.float32)
        w = active.reshape((-1,) + (1,) * (b.ndim - 1))
        m = jnp.sum(jnp.where(w, b32, 0.0), axis=0, keepdims=True) \
            / n_act.astype(jnp.float32)
        out = jnp.where(w, jnp.broadcast_to(m, b32.shape), b32)
        return pod_hint(out.astype(b.dtype))

    return jax.tree.map(mix, base_params)


# ---------------------------------------------------------------------------
# FL cadence — host-side schedule shared by the scanned and reference drivers
# ---------------------------------------------------------------------------
def fl_schedule(cfg: FCPOConfig, n_episodes: int, *, federated: bool = True,
                learn: bool = True):
    """(n_episodes,) bool numpy array: True where an FL round runs after the
    episode (every ``fl_every``-th). Static fleet topology -> computed on host
    once and fed to the scanned driver as per-episode xs."""
    import numpy as np

    if not (federated and learn):
        return np.zeros((n_episodes,), bool)
    if cfg.fl_every < 1:
        raise ValueError(f"fl_every must be >= 1, got {cfg.fl_every}")
    return (np.arange(1, n_episodes + 1) % cfg.fl_every) == 0


def draw_availability(schedule, n_agents: int, straggler_prob: float = 0.0,
                      seed: int = 0):
    """(n_episodes, A) bool availability bits, pre-drawn on host so straggler
    masking can live inside the scanned body. Draws one ``rng.random(A)``
    vector per *scheduled FL round*, in episode order — bit-identical to the
    reference driver's lazy per-round draws. Non-FL episodes are all-True
    (never read)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    avail = np.ones((len(schedule), n_agents), bool)
    for e in np.flatnonzero(schedule):
        avail[e] = rng.random(n_agents) >= straggler_prob
    return avail


def head_group_ids(masks_stacked: ActionMask) -> Dict[str, Any]:
    """Group agents by identical action-space masks, per head.

    masks_stacked: ActionMask of (A, n_*) bool arrays. Returns {head_key:
    (A,) int32, head_key+"_count": int} — computed on host (static fleet
    topology), used as constants inside jit.
    """
    import numpy as np

    out: Dict[str, Any] = {}
    for key, m in zip(HEAD_KEYS, (masks_stacked.res, masks_stacked.bs,
                                  masks_stacked.mt)):
        m = np.asarray(m)
        uniq, inv = np.unique(m, axis=0, return_inverse=True)
        out[key] = jnp.asarray(inv.astype(np.int32))
        out[f"{key}_count"] = int(uniq.shape[0])
    return out
