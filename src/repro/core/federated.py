"""Agent-specific Federated RL (§IV-D): Algorithms 1 & 2, Eq. 7 selection,
hierarchical rounds — expressed over *stacked* fleet pytrees.

The fleet's parameters live in one pytree with a leading agent axis (A, ...),
sharded over the mesh's ``data`` axis at scale. Algorithm 1 then becomes a
handful of masked segment-means — no parameter server, no per-agent RPCs —
which is the JAX-native answer to the paper's §VI scalability concern.

Faithful mapping of Algorithm 1:
  * backbone + value head: *equal* aggregation over selected clients AND the
    server's base network, divided by |M|+1 (lines 3-7, 12, 17).
  * action heads: aggregated only within groups of agents whose head output
    dimensionality (action-space mask) matches (line 8: "across all agents
    with the same output dimensions"), weighted by head loss (line 9).
    The pseudo-code's centered factor ``LOSS_l − LOSS_TOTAL/|M|`` makes the
    client contributions cancel to zero when losses are equal; we implement
    the evident intent — lower-loss heads get more weight — via
    ``w_i = exp(−(loss_i − mean(loss)))`` renormalized to |M_g| (reduces to
    equal aggregation for equal losses). Deviation documented here and in
    DESIGN.md.
  * after aggregation all agents receive the new backbone/value and their
    group's head (system step ① — helps cold starts), then fine-tune heads
    locally per Algorithm 2 (``ppo.finetune_heads``).

Client selection (Eq. 7): ``TotalUtil(c) = Util(c)·sqrt(Bandwidth/10)`` with
FedHybrid-style ``Util`` = memory availability + compute availability + data
diversity (the buffer's mean diversity score). Stragglers enter as an
availability mask — a timed-out client simply drops out of this round's
selection (fault tolerance for free: aggregation is defined for any subset,
including the empty one, which degenerates to keeping the base network).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.fcpo import FCPOConfig
from repro.core.agent import BACKBONE_KEYS, HEAD_KEYS, ActionMask
from repro.core.ppo import Rollout, action_logp, gae


# ---------------------------------------------------------------------------
# Per-head policy losses (Alg. 1's LOSS_l)
# ---------------------------------------------------------------------------
def per_head_losses(cfg: FCPOConfig, params, rollout: Rollout,
                    mask: ActionMask) -> jnp.ndarray:
    """(3,) policy-loss per action head on this agent's experiences."""
    from repro.core.agent import agent_forward  # local import to avoid cycle

    out = agent_forward(cfg, params, rollout.states, mask)
    adv = gae(cfg, rollout.rewards, rollout.values_old)
    adv = (adv - adv.mean()) / (adv.std() + 1e-6)
    factor = -adv + jnp.exp(-rollout.rewards)

    losses = []
    for i, head in enumerate(("res", "bs", "mt")):
        logp = jnp.take_along_axis(out[head], rollout.actions[..., i:i + 1],
                                   -1)[..., 0]
        ratio = jnp.exp(logp - jax.lax.stop_gradient(logp))  # =1 at eval point
        l = jnp.mean(jnp.minimum(cfg.eps_clip * ratio, ratio) * factor)
        losses.append(l)
    return jnp.stack(losses)


# ---------------------------------------------------------------------------
# Client selection (Eq. 7)
# ---------------------------------------------------------------------------
class ClientStats(NamedTuple):
    mem_avail: jnp.ndarray      # (A,) in [0,1]
    compute_avail: jnp.ndarray  # (A,) in [0,1]
    diversity: jnp.ndarray      # (A,) mean buffer diversity score
    bandwidth: jnp.ndarray      # (A,) Mbit/s
    available: jnp.ndarray      # (A,) bool — False = straggler/offline


def total_utility(stats: ClientStats) -> jnp.ndarray:
    div = stats.diversity / (1.0 + jnp.abs(stats.diversity))  # squash
    util = (stats.mem_avail + stats.compute_avail + div) / 3.0
    return util * jnp.sqrt(jnp.maximum(stats.bandwidth, 1e-3) / 10.0)


def select_clients(cfg: FCPOConfig, stats: ClientStats) -> jnp.ndarray:
    """Top-⌈frac·A⌉ by TotalUtil among available clients -> (A,) bool mask.
    Exactly k are chosen (argsort tie-break), minus any unavailable."""
    a = stats.available.shape[0]
    k = max(1, int(round(cfg.clients_per_round * a)))
    utils = jnp.where(stats.available, total_utility(stats), -jnp.inf)
    order = jnp.argsort(-utils)
    sel = jnp.zeros((a,), bool).at[order[:k]].set(True)
    return sel & stats.available


# ---------------------------------------------------------------------------
# Algorithm 1 — agent-specific aggregation over stacked fleets
# ---------------------------------------------------------------------------
def _masked_mean_with_base(stacked, base, sel, pod_ids, n_pods):
    """(base + Σ_sel m) / (n_sel + 1), per pod segment.

    stacked: (A, ...); base: (P, ...); sel: (A,) bool; pod_ids: (A,) int.
    Returns (per-agent broadcast (A, ...), new base (P, ...)).
    """
    w = sel.astype(stacked.dtype)
    wsum = jax.ops.segment_sum(w, pod_ids, n_pods)                 # (P,)
    ssum = jax.ops.segment_sum(stacked * w.reshape((-1,) + (1,) * (stacked.ndim - 1)),
                               pod_ids, n_pods)                    # (P, ...)
    denom = (wsum + 1.0).reshape((n_pods,) + (1,) * (stacked.ndim - 1))
    agg = (base + ssum) / denom                                    # (P, ...)
    return agg[pod_ids], agg


def _head_weights(sel, losses_h, group_ids, n_groups):
    """Loss-centered exponential weights, renormalized within (pod×group)."""
    w = sel.astype(jnp.float32)
    cnt = jax.ops.segment_sum(w, group_ids, n_groups)
    lsum = jax.ops.segment_sum(losses_h * w, group_ids, n_groups)
    mean_l = lsum / jnp.maximum(cnt, 1.0)
    raw = jnp.exp(-(losses_h - mean_l[group_ids])) * w
    rsum = jax.ops.segment_sum(raw, group_ids, n_groups)
    # renormalize so weights sum to the group count (equal-loss ⇒ all 1)
    return raw * (cnt / jnp.maximum(rsum, 1e-9))[group_ids]


def aggregate(cfg: FCPOConfig, fleet_params, base_params, sel: jnp.ndarray,
              head_losses: jnp.ndarray, head_groups: Dict[str, jnp.ndarray],
              pod_ids: Optional[jnp.ndarray] = None, n_pods: int = 1
              ) -> Tuple[Any, Any]:
    """Run Algorithm 1. Returns (new_fleet_params, new_base_params).

    fleet_params: stacked (A, ...); base_params: (P, ...) per-pod base
    networks; head_losses: (A, 3); head_groups: per head key -> (A,) int32
    group ids (agents sharing an action-space signature); pod_ids: (A,).
    """
    a = sel.shape[0]
    if pod_ids is None:
        pod_ids = jnp.zeros((a,), jnp.int32)

    new_fleet = {}
    new_base = {}

    # --- backbone + value: equal aggregation (lines 3-7, 12) ---
    for key in BACKBONE_KEYS:
        out = jax.tree.map(
            lambda st, b: _masked_mean_with_base(st, b, sel, pod_ids, n_pods),
            fleet_params[key], base_params[key])
        new_fleet[key] = jax.tree.map(lambda t: t[0], out,
                                      is_leaf=lambda t: isinstance(t, tuple))
        new_base[key] = jax.tree.map(lambda t: t[1], out,
                                     is_leaf=lambda t: isinstance(t, tuple))

    # --- action heads: loss-weighted within (pod × output-dim group) ---
    for h_idx, key in enumerate(HEAD_KEYS):
        if key not in fleet_params:  # single-head ablation variant
            continue
        groups = head_groups[key]                          # (A,) int32
        n_groups_local = int(head_groups[f"{key}_count"])
        seg = pod_ids * n_groups_local + groups            # pod×group segments
        n_seg = n_pods * n_groups_local
        wts = _head_weights(sel, head_losses[:, h_idx], seg, n_seg)

        def agg_leaf(st, b):
            wshape = (-1,) + (1,) * (st.ndim - 1)
            ssum = jax.ops.segment_sum(st * wts.reshape(wshape), seg, n_seg)
            cnt = jax.ops.segment_sum(sel.astype(jnp.float32), seg, n_seg)
            # base head is per pod; broadcast to every group in that pod
            b_seg = jnp.repeat(b, n_groups_local, axis=0)
            denom = (cnt + 1.0).reshape((n_seg,) + (1,) * (st.ndim - 1))
            agg = (b_seg + ssum) / denom                    # (n_seg, ...)
            per_agent = agg[seg]
            # groups with no contributor keep the agent's own head
            has = (cnt[seg] > 0).reshape(wshape)
            per_agent = jnp.where(has, per_agent, st)
            # new base per pod: mean over that pod's groups
            nb = agg.reshape((n_pods, n_groups_local) + st.shape[1:]).mean(1)
            return per_agent, nb

        out = jax.tree.map(agg_leaf, fleet_params[key], base_params[key])
        new_fleet[key] = jax.tree.map(lambda t: t[0], out,
                                      is_leaf=lambda t: isinstance(t, tuple))
        new_base[key] = jax.tree.map(lambda t: t[1], out,
                                     is_leaf=lambda t: isinstance(t, tuple))

    return new_fleet, new_base


def merge_pods(base_params):
    """Hierarchical FL (§IV-D Large-Scale): cross-cluster exchange through
    the cloud — pods' base networks are averaged and redistributed."""
    def mix(b):
        return jnp.broadcast_to(b.mean(0, keepdims=True), b.shape)
    return jax.tree.map(mix, base_params)


# ---------------------------------------------------------------------------
# FL cadence — host-side schedule shared by the scanned and reference drivers
# ---------------------------------------------------------------------------
def fl_schedule(cfg: FCPOConfig, n_episodes: int, *, federated: bool = True,
                learn: bool = True):
    """(n_episodes,) bool numpy array: True where an FL round runs after the
    episode (every ``fl_every``-th). Static fleet topology -> computed on host
    once and fed to the scanned driver as per-episode xs."""
    import numpy as np

    if not (federated and learn):
        return np.zeros((n_episodes,), bool)
    if cfg.fl_every < 1:
        raise ValueError(f"fl_every must be >= 1, got {cfg.fl_every}")
    return (np.arange(1, n_episodes + 1) % cfg.fl_every) == 0


def draw_availability(schedule, n_agents: int, straggler_prob: float = 0.0,
                      seed: int = 0):
    """(n_episodes, A) bool availability bits, pre-drawn on host so straggler
    masking can live inside the scanned body. Draws one ``rng.random(A)``
    vector per *scheduled FL round*, in episode order — bit-identical to the
    reference driver's lazy per-round draws. Non-FL episodes are all-True
    (never read)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    avail = np.ones((len(schedule), n_agents), bool)
    for e in np.flatnonzero(schedule):
        avail[e] = rng.random(n_agents) >= straggler_prob
    return avail


def head_group_ids(masks_stacked: ActionMask) -> Dict[str, Any]:
    """Group agents by identical action-space masks, per head.

    masks_stacked: ActionMask of (A, n_*) bool arrays. Returns {head_key:
    (A,) int32, head_key+"_count": int} — computed on host (static fleet
    topology), used as constants inside jit.
    """
    import numpy as np

    out: Dict[str, Any] = {}
    for key, m in zip(HEAD_KEYS, (masks_stacked.res, masks_stacked.bs,
                                  masks_stacked.mt)):
        m = np.asarray(m)
        uniq, inv = np.unique(m, axis=0, return_inverse=True)
        out[key] = jnp.asarray(inv.astype(np.int32))
        out[f"{key}_count"] = int(uniq.shape[0])
    return out
