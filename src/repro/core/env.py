"""Serving-environment MDP for iAgents (§IV-B), fully tensorial.

Models one inference replica's pipeline: arrivals -> bounded pre-processing
queue -> batched inference -> bounded post-processing queue -> sink, with:

  * RES action: resolution bucket / frame packing — lower resolution packs
    ``(1/scale)²`` requests per inference slot and speeds pre-processing;
  * BS action: inference batch size — classic batching curve
    ``t_batch = t0 + t1·bs·area`` (throughput up, per-request latency up);
  * MT action: pre/post concurrency with a contention penalty on constrained
    devices (threads help until they fight for cores);
  * bounded queues drop on overflow (drops are in the state vector);
  * reward Eq. 1 with the oversize penalty increased per SLO violation.

Every quantity is a scalar per agent, so the entire fleet steps as one
``vmap``'d program; heterogeneity (Jetson NX / AGX / Orin / server GPU →
their TPU-slice analogues) enters through ``EnvParams`` leaves which are
stacked per agent. ``LatencyModel.from_roofline`` calibrates t0/t1 from a
compiled model's cost analysis so the simulator's latency surface matches
the real data plane (DESIGN.md §2).

One env step = one control interval (1 s in the paper).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.fcpo import FCPOConfig


class EnvParams(NamedTuple):
    """Per-agent device/model characteristics (stack to (A, ...) for fleets)."""
    t0: jnp.ndarray            # fixed per-batch latency (s) — kernel/launch floor
    t1: jnp.ndarray            # per-item compute time at full res (s)
    pre_rate: jnp.ndarray      # pre-proc throughput at 1 thread, full res (req/s)
    post_rate: jnp.ndarray     # post-proc throughput at 1 thread (req/s)
    contention: jnp.ndarray    # thread-contention coefficient (0 = free scaling)
    queue_cap: jnp.ndarray     # bounded queue capacity (requests)
    slo_s: jnp.ndarray         # end-to-end SLO (s) — also a state input
    net_lat: jnp.ndarray       # network/base latency offset (s)


def default_env_params(speed=1.0, slo_s=0.25) -> EnvParams:
    f = lambda x: jnp.asarray(x, jnp.float32)
    speed = f(speed)
    return EnvParams(
        t0=0.012 / speed, t1=0.0022 / speed,
        pre_rate=220.0 * speed, post_rate=260.0 * speed,
        contention=0.18 / jnp.maximum(speed, 0.25), queue_cap=f(128.0),
        slo_s=jnp.broadcast_to(f(slo_s), speed.shape), net_lat=jnp.broadcast_to(f(0.015), speed.shape),
    )


class LatencyModel:
    """Calibrate (t0, t1) from roofline terms of a compiled serving step."""

    @staticmethod
    def from_roofline(flops_per_item: float, bytes_per_step: float,
                      peak_flops: float = 197e12, hbm_bw: float = 819e9,
                      overhead_s: float = 2e-3) -> tuple:
        t0 = bytes_per_step / hbm_bw + overhead_s   # weight-streaming floor
        t1 = flops_per_item / peak_flops            # compute per request
        return t0, t1


class EnvState(NamedTuple):
    pre_q: jnp.ndarray     # requests waiting for pre-processing
    post_q: jnp.ndarray    # requests waiting for post-processing
    drops: jnp.ndarray     # drops in the last step
    cur_action: jnp.ndarray  # (3,) int32 current (res, bs, mt)
    ema_lat: jnp.ndarray   # weighted average local latency (paper: "lat")
    t: jnp.ndarray         # step counter


def env_init(cfg: FCPOConfig) -> EnvState:
    return EnvState(
        pre_q=jnp.zeros(()), post_q=jnp.zeros(()), drops=jnp.zeros(()),
        cur_action=jnp.zeros((3,), jnp.int32), ema_lat=jnp.zeros(()),
        t=jnp.zeros((), jnp.int32),
    )


def observe_vector(cfg: FCPOConfig, *, rate, cur_action, drops, pre_q,
                   post_q, queue_cap, slo_s) -> jnp.ndarray:
    """THE 8-dim iAgent state vector of §IV-B — the single definition.

    Every environment backend (the fluid MDP here, the request-level twin in
    ``repro.sim``) reads its raw quantities off its own state and normalizes
    them through this one function, so a policy trained on one backend
    transfers to the other without retargeting and the two observation paths
    cannot drift (tests/test_backends.py asserts field-for-field parity)."""
    return jnp.stack([
        rate / 100.0,
        cur_action[0].astype(jnp.float32) / max(cfg.n_res - 1, 1),
        cur_action[1].astype(jnp.float32) / max(cfg.n_bs - 1, 1),
        cur_action[2].astype(jnp.float32) / max(cfg.n_mt - 1, 1),
        jnp.asarray(drops, jnp.float32) / 50.0,
        jnp.asarray(pre_q, jnp.float32) / queue_cap,
        jnp.asarray(post_q, jnp.float32) / queue_cap,
        slo_s / 0.5,
    ])


def observe(cfg: FCPOConfig, ep: EnvParams, s: EnvState, rate) -> jnp.ndarray:
    """The 8-dim state vector read off the fluid MDP state."""
    return observe_vector(cfg, rate=rate, cur_action=s.cur_action,
                          drops=s.drops, pre_q=s.pre_q, post_q=s.post_q,
                          queue_cap=ep.queue_cap, slo_s=ep.slo_s)


def env_step(cfg: FCPOConfig, ep: EnvParams, s: EnvState, action, rate):
    """One control interval. action: (3,) int32. rate: arrivals this step.

    Returns (new_state, reward, info)."""
    res_scale = jnp.asarray(cfg.res_scales)[action[0]]
    bs = jnp.asarray(cfg.bs_values, jnp.float32)[action[1]]
    mt = jnp.asarray(cfg.mt_values, jnp.float32)[action[2]]

    area = res_scale ** 2
    pack = 1.0 / area                      # frames packed per inference slot

    # --- pre-processing: threads scale throughput, contention bites back ---
    mt_eff = mt * jnp.maximum(1.0 - ep.contention * (mt - 1.0), 0.3)
    rate_pre = ep.pre_rate * mt_eff / jnp.maximum(area, 0.05)

    pre_in = s.pre_q + rate
    pre_done = jnp.minimum(pre_in, rate_pre)
    pre_q = pre_in - pre_done
    drops_pre = jnp.maximum(pre_q - ep.queue_cap, 0.0)
    pre_q = jnp.minimum(pre_q, ep.queue_cap)

    # --- batched inference: t_batch = t0 + t1·bs·area; packing multiplies
    #     requests per slot ---
    t_batch = ep.t0 + ep.t1 * bs * area
    rate_inf = (bs * pack) / t_batch       # req/s capacity
    inf_done = jnp.minimum(pre_done + 0.0, rate_inf)
    # unprocessed spill returns to the pre queue (bottleneck visibility)
    spill = pre_done - inf_done
    pre_q = jnp.minimum(pre_q + spill, ep.queue_cap)

    # --- post-processing ---
    rate_post = ep.post_rate * mt_eff
    post_in = s.post_q + inf_done
    post_done = jnp.minimum(post_in, rate_post)
    post_q = post_in - post_done
    drops_post = jnp.maximum(post_q - ep.queue_cap, 0.0)
    post_q = jnp.minimum(post_q, ep.queue_cap)

    drops = drops_pre + drops_post

    # --- latency estimate: queue wait (Little) + batch fill + service ---
    wait_pre = pre_q / jnp.maximum(rate_pre, 1.0)
    wait_fill = 0.5 * bs * pack / jnp.maximum(rate, 1.0)  # first-in-batch wait
    wait_post = post_q / jnp.maximum(rate_post, 1.0)
    lat = ep.net_lat + wait_pre + wait_fill + t_batch + wait_post
    ema_lat = 0.7 * s.ema_lat + 0.3 * lat

    throughput = post_done
    slo_viol = jnp.where(lat > ep.slo_s, throughput, 0.0)
    effective = throughput - slo_viol

    # --- reward (Eq. 1): oversize penalty bs grows by SLO violations.
    # Normalized to (-1, 1) via tanh: a hard clip saturates under bad
    # configurations (every action looks equally bad -> zero learning
    # signal); tanh keeps the ordering differentiable while matching the
    # paper's "normalized between -1 and 1".
    safe_rate = jnp.maximum(rate, 1.0)
    r = 0.5 * (cfg.theta * throughput / safe_rate
               - cfg.sigma * ema_lat
               - cfg.phi * (bs + slo_viol) / safe_rate)
    r = jnp.tanh(r)

    new_state = EnvState(pre_q=pre_q, post_q=post_q, drops=drops,
                         cur_action=action.astype(jnp.int32), ema_lat=ema_lat,
                         t=s.t + 1)
    info = {
        "throughput": throughput,
        "effective_throughput": effective,
        "latency": lat,
        "drops": drops,
        "accuracy_proxy": res_scale ** 0.3,   # resolution-accuracy trade-off
        "batch_latency": t_batch,
    }
    return new_state, r, info
