"""Mixture-of-Experts layer (granite top-8/40e, deepseek 64e top-6 + shared).

Dispatch is sort-based with a static per-expert capacity: tokens are routed
to (expert, slot) coordinates via argsort over expert ids, scattered into an
(E, C, d) buffer, processed with one batched einsum per projection, and
scatter-added back with their gate weights. This keeps FLOPs at
2*E*C*d*ff (≈ 2*T*k*d*ff*capacity_factor) and avoids the O(T*E*C) one-hot
dispatch matmuls that blow up the memory-roofline term at 1M-token batches.

Expert tensors are stacked on a leading E axis so expert parallelism is a
plain NamedSharding on that axis when E divides the mesh's model axis
(deepseek: 64/16 ✓); otherwise the sharder falls back to tensor-parallel
experts over the ff dim (granite: 40 experts, ff 512/16 ✓).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import _activate, _normal, dense, dense_init, mlp, mlp_init


def moe_init(key, cfg: ArchConfig, dtype):
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(kr, d, e, dtype, scale=scale),
        "gate": _normal(kg, (e, d, f), scale, dtype),
        "up": _normal(ku, (e, d, f), scale, dtype),
        "down": _normal(kd, (e, f, d), 1.0 / math.sqrt(f), dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks, d, cfg.moe_d_ff * cfg.n_shared_experts, dtype)
    return p


def moe_capacity(cfg: ArchConfig, n_tokens: int) -> int:
    cap = int(math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, ((cap + 7) // 8) * 8)  # pad to multiple of 8 for layout


def moe_apply(p, cfg: ArchConfig, x):
    """x: (B, S, d) -> (B, S, d). Returns (y, aux) with load-balance aux loss."""
    b, s, d = x.shape
    if cfg.moe_impl == "batched" and b > 1:
        # per-row dispatch: batch stays data-sharded end to end (zero
        # cross-data traffic; capacity is per row — device-local capacity,
        # as real EP systems provision it)
        y, aux = jax.vmap(lambda row: _moe_tokens(p, cfg, row))(
            x.reshape(b, s, d))
        if cfg.n_shared_experts:
            y = y + mlp(p["shared"], x, cfg.act)
        return y, aux.mean()
    y, aux = _moe_tokens(p, cfg, x.reshape(b * s, d))
    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], x.reshape(b * s, d), cfg.act)
    return y.reshape(b, s, d), aux


def _moe_tokens(p, cfg: ArchConfig, xf):
    """Core dispatch/compute/combine over a flat token axis. xf: (T, d)."""
    t, d = xf.shape
    k, e = cfg.top_k, cfg.n_experts

    logits = dense(p["router"], xf).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)  # (T, k)
    topw = topw / jnp.clip(topw.sum(-1, keepdims=True), 1e-9)  # renormalize
    topw = topw.astype(xf.dtype)

    # ---- sort-based dispatch -------------------------------------------------
    cap = moe_capacity(cfg, t)
    flat_e = topi.reshape(-1)                       # (T*k,)
    order = jnp.argsort(flat_e, stable=True)        # slots sorted by expert
    sorted_e = flat_e[order]
    token_of = order // k                           # originating token per slot
    # position of each slot within its expert's contiguous run
    seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - seg_start.astype(jnp.int32)
    keep = pos_in_e < cap                           # capacity drop mask
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # overflow -> OOB

    buf = jnp.zeros((e * cap, d), xf.dtype)
    buf = buf.at[slot].set(xf[token_of], mode="drop")
    buf = buf.reshape(e, cap, d)
    if cfg.shard_activations and cfg.moe_impl != "batched":
        # Pin the capacity buffer: experts->model when divisible, else the
        # capacity dim rides data. Stops GSPMD replicating the full (E,C,d)
        # buffer per device and all-reducing partial scatters (§Perf).
        from repro.distributed.sharding import shard_hint
        buf = shard_hint(buf, ["model"], ["data"], [])

    # ---- expert computation (batched over E) --------------------------------
    h = _activate(jnp.einsum("ecd,edf->ecf", buf, p["gate"].astype(xf.dtype)),
                  cfg.act)
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["up"].astype(xf.dtype))
    if cfg.shard_activations and cfg.moe_impl != "batched":
        # 2D-sharded expert compute: capacity rides data, ff rides model —
        # no full-buffer gather; the down-proj contraction psums over model.
        from repro.distributed.sharding import shard_hint
        h = shard_hint(h, ["model"], ["data"], ["model"])
    out = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(xf.dtype))
    if cfg.shard_activations and cfg.moe_impl != "batched":
        out = shard_hint(out, ["model"], ["data"], [])

    # ---- combine -------------------------------------------------------------
    gathered = out.reshape(e * cap, d)[jnp.clip(slot, 0, e * cap - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    w_slot = topw.reshape(-1)[order][:, None]
    y = jnp.zeros((t, d), xf.dtype).at[token_of].add(gathered * w_slot)

    # Switch-style load-balance auxiliary loss.
    me = probs.mean(0)                                      # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)
    return y, aux
