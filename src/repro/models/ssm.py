"""State-space / recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

Each block exposes a chunkwise-parallel training/prefill form (matmul-heavy,
MXU-friendly) and an O(1)-per-token recurrent decode form with an explicit
state cache — the latter is what makes the ``long_500k`` decode shape
runnable for the ssm/hybrid architectures.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import _normal, dense, dense_init, rmsnorm, rmsnorm_init


# ===========================================================================
# Mamba2 (scalar-A SSD, n_groups = 1)
# ===========================================================================
def mamba2_init(key, cfg: ArchConfig, dtype):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_n_heads
    kin, kout, kconv, kdt = jax.random.split(key, 4)
    conv_ch = di + 2 * n
    return {
        "in_proj": dense_init(kin, d, 2 * di + 2 * n + h, dtype),
        "conv_w": _normal(kconv, (cfg.d_conv, conv_ch), 1.0 / math.sqrt(cfg.d_conv), dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),          # A = -exp(A_log) = -1
        "dt_bias": jnp.full((h,), math.log(math.e - 1), jnp.float32),  # softplus->1
        "D": jnp.ones((h,), jnp.float32),
        "norm": rmsnorm_init(di, dtype),
        "out_proj": dense_init(kout, di, d, dtype),
    }


def _causal_conv(x, w, b):
    """x: (B, S, C) depthwise causal conv, width K. w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    return out + b


def _split_mamba(p, cfg, u):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_n_heads
    zxbcdt = dense(p["in_proj"], u)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n :]
    return z, xbc, dt


def mamba2_apply(p, cfg: ArchConfig, u, cache=None):
    """u: (B, S, d). cache: None or {"h": (B,H,P,N), "conv": (B,K-1,C)}."""
    if cache is not None and u.shape[1] == 1:
        return _mamba2_step(p, cfg, u, cache)
    y, final_state, conv_tail = _mamba2_chunked(p, cfg, u, return_state=cache is not None)
    new_cache = None
    if cache is not None:
        new_cache = {"h": final_state, "conv": conv_tail.astype(cache["conv"].dtype)}
    return y, new_cache


def _mamba2_chunked(p, cfg: ArchConfig, u, return_state=False):
    b, s, _ = u.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_n_heads
    hd = cfg.ssm_head_dim
    cl = min(cfg.ssm_chunk, s)
    if s % cl:  # pad to a chunk multiple; tail output is sliced off below.
        assert not return_state, "prefill-with-state requires chunk-multiple seq"
        pad = cl - s % cl
        out, _, _ = _mamba2_chunked(
            p, cfg, jnp.pad(u, ((0, 0), (0, pad), (0, 0))), False)
        return out[:, :s], None, None
    nc = s // cl

    z, xbc_raw, dt = _split_mamba(p, cfg, u)
    xbc = jax.nn.silu(_causal_conv(xbc_raw, p["conv_w"].astype(u.dtype),
                                   p["conv_b"].astype(u.dtype)))
    x = xbc[..., :di].reshape(b, s, h, hd)
    B = xbc[..., di : di + n]
    C = xbc[..., di + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])        # (B,S,H)
    a = (-jnp.exp(p["A_log"]))[None, None, :] * dt                     # (B,S,H) <= 0

    xr = (x.astype(jnp.float32) * dt[..., None]).reshape(b, nc, cl, h, hd)
    Br = B.astype(jnp.float32).reshape(b, nc, cl, n)
    Cr = C.astype(jnp.float32).reshape(b, nc, cl, n)
    ar = a.reshape(b, nc, cl, h)
    a_cum = jnp.cumsum(ar, axis=2)                                     # (b,nc,L,H)

    # ---- intra-chunk (quadratic within chunk) ----
    lmat = jnp.exp(a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :])  # (b,nc,L,S,H)
    tri = jnp.tril(jnp.ones((cl, cl), bool))
    lmat = jnp.where(tri[None, None, :, :, None], lmat, 0.0)
    cb = jnp.einsum("bcln,bcsn->bcls", Cr, Br)
    y_intra = jnp.einsum("bcls,bclsh,bcshp->bclhp", cb, lmat, xr)

    # ---- inter-chunk state passing ----
    decay_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)                   # (b,nc,L,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Br, decay_end, xr)   # (b,nc,H,P,N)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])                          # (b,nc,H)

    def scan_fn(carry, inp):
        st, dec = inp                                                  # (b,H,P,N), (b,H)
        new = carry * dec[:, :, None, None] + st
        return new, carry

    init = jnp.zeros((b, h, hd, n), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)                 # (b,nc,H,P,N)

    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp", Cr, jnp.exp(a_cum), prev_states)
    y = (y_intra + y_inter).reshape(b, s, h, hd)
    y = y + p["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(u.dtype)

    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = dense(p["out_proj"], y)
    if not return_state:
        return out, None, None
    conv_tail = xbc_raw[:, s - (cfg.d_conv - 1):, :]  # last K-1 pre-conv inputs
    return out, final_state, conv_tail


def _mamba2_step(p, cfg: ArchConfig, u, cache):
    """Single-token recurrent decode. u: (B, 1, d)."""
    b = u.shape[0]
    di, n, h, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_n_heads, cfg.ssm_head_dim
    z, xbc, dt = _split_mamba(p, cfg, u)
    # conv over the cached window
    win = jnp.concatenate([cache["conv"], xbc], axis=1)                # (B, K, C)
    xbc1 = jax.nn.silu(jnp.einsum("bkc,kc->bc", win,
                                  p["conv_w"].astype(u.dtype)) + p["conv_b"].astype(u.dtype))
    new_conv = win[:, 1:, :]
    x = xbc1[:, :di].reshape(b, h, hd).astype(jnp.float32)
    B = xbc1[:, di : di + n].astype(jnp.float32)
    C = xbc1[:, di + n :].astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    decay = jnp.exp((-jnp.exp(p["A_log"]))[None] * dt)                 # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, x, B)
    hstate = cache["h"] * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", C, hstate) + p["D"][None, :, None] * x
    y = y.reshape(b, 1, di).astype(u.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense(p["out_proj"], y), {"h": hstate, "conv": new_conv}


def mamba2_cache_spec(cfg: ArchConfig, batch, dtype=jnp.bfloat16):
    h, hd, n = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_ch = cfg.d_inner + 2 * n
    return {
        "h": jax.ShapeDtypeStruct((batch, h, hd, n), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.d_conv - 1, conv_ch), dtype),
    }


# ===========================================================================
# xLSTM — mLSTM (matrix memory) and sLSTM (scalar memory)
# ===========================================================================
def mlstm_init(key, cfg: ArchConfig, dtype):
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 8)
    return {
        "wq": dense_init(ks[0], d, d, dtype),
        "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype),
        "wi": dense_init(ks[3], d, h, dtype, bias=True),
        "wf": dense_init(ks[4], d, h, dtype, bias=True),
        "wo_gate": dense_init(ks[5], d, d, dtype),
        "norm": rmsnorm_init(d, dtype),
        "out_proj": dense_init(ks[6], d, d, dtype),
    }


def mlstm_apply(p, cfg: ArchConfig, x, cache=None):
    if cache is not None and x.shape[1] == 1:
        return _mlstm_step(p, cfg, x, cache)
    if cache is not None:
        # prefill with state handoff: pad to a chunk multiple if needed
        out, (c, n, m) = _mlstm_chunkwise(p, cfg, x, return_state=True)
        return out, {"C": c, "n": n, "m": m}
    if x.shape[1] > cfg.ssm_chunk:
        return _mlstm_chunkwise(p, cfg, x), None
    return _mlstm_parallel(p, cfg, x), None


def _mlstm_parallel(p, cfg: ArchConfig, x):
    """Stabilized quadratic parallel form (xLSTM paper, eqs. 23-27)."""
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    q = dense(p["wq"], x).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = dense(p["wk"], x).reshape(b, s, h, dh).transpose(0, 2, 1, 3) / math.sqrt(dh)
    v = dense(p["wv"], x).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    ig = dense(p["wi"], x).astype(jnp.float32).transpose(0, 2, 1)       # (B,H,S)
    fg = jax.nn.log_sigmoid(dense(p["wf"], x).astype(jnp.float32)).transpose(0, 2, 1)

    fcum = jnp.cumsum(fg, axis=-1)                                      # (B,H,S)
    # logD[i,j] = fcum[i] - fcum[j] + ig[j], lower-triangular
    logd = fcum[..., :, None] - fcum[..., None, :] + ig[..., None, :]
    tri = jnp.tril(jnp.ones((s, s), bool))
    logd = jnp.where(tri[None, None], logd, -jnp.inf)
    m = jnp.max(logd, axis=-1, keepdims=True)                           # (B,H,S,1)
    m = jnp.maximum(m, -1e30)
    dmat = jnp.exp(logd - m)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * dmat
    norm = jnp.maximum(jnp.abs(scores.sum(-1, keepdims=True)), jnp.exp(-m))
    hout = jnp.einsum("bhqk,bhkd->bhqd", scores / norm, v.astype(jnp.float32))
    hout = hout.transpose(0, 2, 1, 3).reshape(b, s, d).astype(x.dtype)
    hout = rmsnorm(p["norm"], hout, cfg.norm_eps)
    hout = hout * jax.nn.silu(dense(p["wo_gate"], x))
    return dense(p["out_proj"], hout)


def _mlstm_chunkwise(p, cfg: ArchConfig, x, return_state=False):
    """Chunkwise-parallel mLSTM: quadratic only within chunks, matrix state
    (C, n, m) carried across chunks. Matches ``_mlstm_parallel`` (tested) but
    keeps the gate matrix at O(S*L) instead of O(S^2) — required for the
    32k-prefill / 4k-train shapes.
    """
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    cl = min(cfg.ssm_chunk, s)
    if s % cl:  # pad to a chunk multiple; tail output is sliced off below.
        assert not return_state, "prefill-with-state requires chunk-multiple seq"
        pad = cl - s % cl
        out = _mlstm_chunkwise(p, cfg, jnp.pad(x, ((0, 0), (0, pad), (0, 0))), False)
        return out[:, :s]
    nc = s // cl

    q = dense(p["wq"], x).reshape(b, s, h, dh).transpose(0, 2, 1, 3).astype(jnp.float32)
    k = (dense(p["wk"], x).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
         / math.sqrt(dh)).astype(jnp.float32)
    v = dense(p["wv"], x).reshape(b, s, h, dh).transpose(0, 2, 1, 3).astype(jnp.float32)
    ig = dense(p["wi"], x).astype(jnp.float32).transpose(0, 2, 1)
    fg = jax.nn.log_sigmoid(dense(p["wf"], x).astype(jnp.float32)).transpose(0, 2, 1)

    # chunked views: (B,H,nc,L,...)
    qc = q.reshape(b, h, nc, cl, dh)
    kc = k.reshape(b, h, nc, cl, dh)
    vc = v.reshape(b, h, nc, cl, dh)
    igc = ig.reshape(b, h, nc, cl)
    fgc = fg.reshape(b, h, nc, cl)
    lcum = jnp.cumsum(fgc, axis=-1)                    # inclusive decay-from-start
    lsum = lcum[..., -1]                               # (B,H,nc)

    tri = jnp.tril(jnp.ones((cl, cl), bool))
    # intra-chunk log decays: logd[i,j] = lcum[i] - lcum[j] + ig[j]
    logd = lcum[..., :, None] - lcum[..., None, :] + igc[..., None, :]
    logd = jnp.where(tri[None, None, None], logd, -jnp.inf)
    m_intra = jnp.max(logd, axis=-1)                   # (B,H,nc,L)
    # state-update log weights: w[j] = lsum - lcum[j] + ig[j]
    logw = lsum[..., None] - lcum + igc                # (B,H,nc,L)
    m_w = jnp.max(logw, axis=-1)                       # (B,H,nc)

    # All heavy einsums run BATCHED over chunks (MXU-friendly, and visible to
    # cost_analysis); the scan only carries the cheap (C, n, m) recurrence.
    w_add = jnp.exp(logw - m_w[..., None])             # (B,H,nc,L)
    add_c = jnp.einsum("bhcl,bhcld,bhclp->bhcdp", w_add, kc, vc)
    add_n = jnp.einsum("bhcl,bhcld->bhcd", w_add, kc)

    def chunk_step(carry, inp):
        c_prev, n_prev, m_prev = carry
        lsum_i, m_w_i, add_c_i, add_n_i = inp
        m_new = jnp.maximum(lsum_i + m_prev, m_w_i)
        decay = jnp.exp(lsum_i + m_prev - m_new)
        sc = jnp.exp(m_w_i - m_new)
        c_new = c_prev * decay[..., None, None] + sc[..., None, None] * add_c_i
        n_new = n_prev * decay[..., None] + sc[..., None] * add_n_i
        return (c_new, n_new, m_new), (c_prev, n_prev, m_prev)

    init = (jnp.zeros((b, h, dh, dh), jnp.float32),
            jnp.zeros((b, h, dh), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32))
    xs = (lsum.transpose(2, 0, 1), m_w.transpose(2, 0, 1),
          add_c.transpose(2, 0, 1, 3, 4), add_n.transpose(2, 0, 1, 3))
    final, (c_prevs, n_prevs, m_prevs) = jax.lax.scan(chunk_step, init, xs)
    c_prev = c_prevs.transpose(1, 2, 0, 3, 4)          # (B,H,nc,dh,dh)
    n_prev = n_prevs.transpose(1, 2, 0, 3)             # (B,H,nc,dh)
    m_prev = m_prevs.transpose(1, 2, 0)                # (B,H,nc)

    # per-query stabilizer and both contributions, batched over chunks
    m_inter = lcum + m_prev[..., None]                 # (B,H,nc,L)
    m_i = jnp.maximum(jnp.maximum(m_intra, m_inter), -1e30)
    dec_in = jnp.exp(m_inter - m_i)                    # (B,H,nc,L)
    h_inter = jnp.einsum("bhcld,bhcdp->bhclp", qc, c_prev) * dec_in[..., None]
    n_inter = jnp.einsum("bhcld,bhcd->bhcl", qc, n_prev) * dec_in
    dmat = jnp.exp(logd - m_i[..., None])              # (B,H,nc,L,L)
    scores = jnp.einsum("bhcld,bhcsd->bhcls", qc, kc) * dmat
    h_intra = jnp.einsum("bhcls,bhcsp->bhclp", scores, vc)
    n_intra = scores.sum(-1)
    denom = jnp.maximum(jnp.abs(n_inter + n_intra), jnp.exp(-m_i))[..., None]
    hs = (h_inter + h_intra) / denom                   # (B,H,nc,L,dh)
    hout = hs.reshape(b, h, s, dh)
    hout = hout.transpose(0, 2, 1, 3).reshape(b, s, d).astype(x.dtype)
    hout = rmsnorm(p["norm"], hout, cfg.norm_eps)
    hout = hout * jax.nn.silu(dense(p["wo_gate"], x))
    out = dense(p["out_proj"], hout)
    if return_state:
        return out, final
    return out


def _mlstm_step(p, cfg: ArchConfig, x, cache):
    """Recurrent decode: C <- f C + i v k^T. cache: C (B,H,P,P), n (B,H,P), m (B,H)."""
    b, _, d = x.shape
    h = cfg.n_heads
    dh = d // h
    q = dense(p["wq"], x).reshape(b, h, dh).astype(jnp.float32)
    k = (dense(p["wk"], x).reshape(b, h, dh) / math.sqrt(dh)).astype(jnp.float32)
    v = dense(p["wv"], x).reshape(b, h, dh).astype(jnp.float32)
    ig = dense(p["wi"], x).astype(jnp.float32).reshape(b, h)
    fg = jax.nn.log_sigmoid(dense(p["wf"], x).astype(jnp.float32)).reshape(b, h)

    m_new = jnp.maximum(fg + cache["m"], ig)
    f_sc = jnp.exp(fg + cache["m"] - m_new)[..., None]
    i_sc = jnp.exp(ig - m_new)[..., None]
    # state convention matches the chunkwise form: C[d, p] = sum_j k_d v_p
    c_new = cache["C"] * f_sc[..., None] + i_sc[..., None] * k[..., :, None] * v[..., None, :]
    n_new = cache["n"] * f_sc + i_sc * k
    num = jnp.einsum("bhdp,bhd->bhp", c_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n_new, q)),
                      jnp.exp(-m_new))[..., None]
    hout = (num / den).reshape(b, 1, d).astype(x.dtype)
    hout = rmsnorm(p["norm"], hout, cfg.norm_eps)
    hout = hout * jax.nn.silu(dense(p["wo_gate"], x))
    return dense(p["out_proj"], hout), {"C": c_new, "n": n_new, "m": m_new}


def mlstm_cache_spec(cfg: ArchConfig, batch):
    h = cfg.n_heads
    dh = cfg.d_model // h
    return {
        "C": jax.ShapeDtypeStruct((batch, h, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, h, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, h), jnp.float32),
    }


def slstm_init(key, cfg: ArchConfig, dtype):
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 3)
    # input projections for 4 gates + head-block-diagonal recurrent weights
    return {
        "w_in": dense_init(ks[0], d, 4 * d, dtype, bias=True),
        "r": _normal(ks[1], (4, h, dh, dh), 1.0 / math.sqrt(dh), dtype),
        "norm": rmsnorm_init(d, dtype),
        "out_proj": dense_init(ks[2], d, d, dtype),
    }


def slstm_apply(p, cfg: ArchConfig, x, cache=None):
    """sLSTM with exponential gating + stabilizer; lax.scan over time.

    cache: {"c","n","h" (B,H,dh), "m" (B,H,dh)} or None (zeros).
    """
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    wx = dense(p["w_in"], x).reshape(b, s, 4, h, dh).astype(jnp.float32)
    r = p["r"].astype(jnp.float32)

    if cache is None:
        zeros = jnp.zeros((b, h, dh), jnp.float32)
        state = {"c": zeros, "n": zeros + 1e-6, "h": zeros, "m": zeros}
    else:
        state = cache

    def step(st, wxt):  # wxt: (B, 4, H, dh)
        rec = jnp.einsum("bhq,ghpq->bghp", st["h"], r)                 # (B,4,H,dh)
        g = wxt + rec
        zt = jnp.tanh(g[:, 0])
        it = g[:, 1]
        ft = g[:, 2]
        ot = jax.nn.sigmoid(g[:, 3])
        m_new = jnp.maximum(jax.nn.log_sigmoid(ft) + st["m"], it)
        i_sc = jnp.exp(it - m_new)
        f_sc = jnp.exp(jax.nn.log_sigmoid(ft) + st["m"] - m_new)
        c_new = f_sc * st["c"] + i_sc * zt
        n_new = f_sc * st["n"] + i_sc
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}, h_new

    final, hs = jax.lax.scan(step, state, wx.transpose(1, 0, 2, 3, 4))
    hout = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    hout = rmsnorm(p["norm"], hout, cfg.norm_eps)
    out = dense(p["out_proj"], hout)
    return out, (final if cache is not None else None)


def slstm_cache_spec(cfg: ArchConfig, batch):
    h = cfg.n_heads
    dh = cfg.d_model // h
    z = jax.ShapeDtypeStruct((batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}
