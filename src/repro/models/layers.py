"""Core functional layers: norms, RoPE, embeddings, MLPs, GQA attention.

Pure-functional style: ``*_init(key, ...) -> params`` and ``*_apply(params,
x, ...) -> y``. Params are plain nested dicts of jnp arrays so they stay
trivially pjit-shardable and checkpointable.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype, bias=False, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": _normal(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_init(d, dtype):
    return {"g": jnp.zeros((d,), dtype)}  # stored as (1 + g), gemma-style


def rmsnorm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["g"].astype(jnp.float32))).astype(x.dtype)


def layernorm_init(d, dtype):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------
def mlp_init(key, d_model, d_ff, dtype, gated=True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "gate": dense_init(k1, d_model, d_ff, dtype),
        "down": dense_init(k3, d_ff, d_model, dtype),
    }
    if gated:
        p["up"] = dense_init(k2, d_model, d_ff, dtype)
    return p


def _activate(x, act):
    if act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def mlp(p, x, act="silu"):
    h = _activate(dense(p["gate"], x), act)
    if "up" in p:
        h = h * dense(p["up"], x)
    return dense(p["down"], h)


# ---------------------------------------------------------------------------
# Embeddings / heads
# ---------------------------------------------------------------------------
def embedding_init(key, vocab, d_model, dtype):
    # 1/sqrt(d) keeps tied-unembedding logits O(1); archs with
    # ``embed_scale`` (gemma) multiply the residual stream back to O(1) norm.
    return {"table": _normal(key, (vocab, d_model), d_model ** -0.5, dtype)}


def embed(p, tokens, scale=None):
    y = jnp.take(p["table"], tokens, axis=0)
    if scale is not None:
        y = y * jnp.asarray(scale, y.dtype)
    return y


def unembed(p, x):
    return x @ p["table"].T.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA / MHA), reference jnp path + optional Pallas dispatch
# ---------------------------------------------------------------------------
def attention_init(key, cfg: ArchConfig, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, cfg.d_model, cfg.q_dim, dtype, bias=cfg.qkv_bias),
        "wk": dense_init(kk, cfg.d_model, cfg.kv_dim, dtype, bias=cfg.qkv_bias),
        "wv": dense_init(kv, cfg.d_model, cfg.kv_dim, dtype, bias=cfg.qkv_bias),
        "wo": dense_init(ko, cfg.q_dim, cfg.d_model, dtype),
    }


def repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def sdpa(q, k, v, *, causal, q_offset=0, kv_len=None, softcap=0.0,
         gqa_impl="repeat"):
    """Reference scaled-dot-product attention.

    q: (B, Sq, Hq, D), k/v: (B, Sk, Hkv, D).  ``kv_len`` masks cache slots
    beyond the valid length (decode).  ``q_offset`` is the absolute position
    of q[0] for causal masking against a longer kv.  ``gqa_impl="grouped"``
    contracts the shared kv heads directly instead of materializing them G×
    (the decode memory-term optimization; identical math).
    """
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    if gqa_impl == "grouped" and g > 1:
        qg = q.reshape(b, sq, hkv, g, d)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
        expand = lambda m: m[:, None, None, :, :]
    else:
        k = repeat_kv(k, g)
        v = repeat_kv(v, g)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
        expand = lambda m: m[:, None, :, :]
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    mask = None
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = jnp.broadcast_to(qpos[:, None] >= kpos[None, :], (1, sq, sk))
    if kv_len is not None:
        valid = jnp.arange(sk)[None, :] < jnp.asarray(kv_len).reshape(-1, 1)  # (B, Sk)
        vmask = valid[:, None, :]
        mask = vmask if mask is None else (mask & vmask)
    if mask is not None:
        logits = jnp.where(expand(mask), logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    if gqa_impl == "grouped" and g > 1:
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
        return out.reshape(b, sq, hq, d)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def sdpa_chunked(q, k, v, *, causal, chunk=1024, unroll=True):
    """Flash-style streaming attention: identical math to ``sdpa`` but the
    (Sq, Sk) score matrix never materializes — KV is consumed in ``chunk``-
    sized blocks with a running (max, denom, acc) online softmax. This is the
    jnp twin of kernels/flash_attention.py and the §Perf "memory term"
    optimization for the train/prefill shapes (the O(S²) temp disappears).

    ``unroll=True`` keeps every block in the HLO so cost_analysis stays exact
    (XLA:CPU counts scan bodies once).
    """
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    k = repeat_kv(k, hq // hkv)
    v = repeat_kv(v, hq // hkv)
    scale = 1.0 / math.sqrt(d)
    chunk = min(chunk, sk)
    assert sk % chunk == 0
    nk = sk // chunk
    qf = q.astype(jnp.float32).transpose(0, 2, 1, 3)            # (B,H,Sq,D)
    kc = k.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(b, hq, nk, chunk, d)
    vc = v.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(b, hq, nk, chunk, d)
    qpos = jnp.arange(sq)

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        ki, vi, ik = inp
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, ki) * scale       # (B,H,Sq,C)
        if causal:
            kpos = ik * chunk + jnp.arange(chunk)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        if causal:
            p = jnp.where(mask[None, None], p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.einsum("bhqk,bhkd->bhqd", p, vi)
        return (m_new, l_new, acc), None

    init = (jnp.full((b, hq, sq, 1), -1e30, jnp.float32),
            jnp.zeros((b, hq, sq, 1), jnp.float32),
            jnp.zeros((b, hq, sq, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        body, init,
        (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4),
         jnp.arange(nk)),
        unroll=nk if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention_apply(p, cfg: ArchConfig, x, positions, cache=None, layer_idx=None,
                    use_pallas=False):
    """Full attention with optional KV cache (decode).

    cache: None for train/prefill-without-cache, or a dict
      {"k": (B, S_max, Hkv, D), "v": ..., } plus caller-managed offset.
    Returns (out, new_kv) where new_kv is (k, v) written at the offset.
    """
    b, s, _ = x.shape
    q = dense(p["wq"], x).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = dense(p["wk"], x).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = dense(p["wv"], x).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.shard_activations:
        # Pin batch->data, heads->model (when divisible), and KEEP head_dim /
        # kv replicated: stops GSPMD from sharding the score contraction dim,
        # which otherwise all-reduces fp32 (B,H,Sq,Sk) partial sums (§Perf).
        from repro.distributed.sharding import BATCH, shard_hint
        q = shard_hint(q, list(BATCH), [], ["model"], [])
        k = shard_hint(k, list(BATCH), [], ["model"], [])
        v = shard_hint(v, list(BATCH), [], ["model"], [])

    if cache is None:
        if use_pallas:
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=cfg.causal)
        elif cfg.attn_impl == "chunked":
            out = sdpa_chunked(q, k, v, causal=cfg.causal,
                               chunk=cfg.attn_chunk)
        else:
            out = sdpa(q, k, v, causal=cfg.causal, softcap=cfg.logit_softcap,
                       gqa_impl=cfg.gqa_impl)
        new_kv = None
    else:
        offset = cache["offset"]  # scalar int32: number of valid tokens already in cache
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, offset, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, offset, 0, 0))
        kv_len = offset + s
        if use_pallas and s == 1:
            from repro.kernels import ops as kops
            out = kops.decode_attention(q, ck, cv, kv_len)
        else:
            out = sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), causal=True,
                       q_offset=offset, kv_len=kv_len, softcap=cfg.logit_softcap,
                       gqa_impl=cfg.gqa_impl)
        new_kv = {"k": ck, "v": cv}
    out = out.reshape(b, s, cfg.q_dim)
    return dense(p["wo"], out), new_kv
