"""Hybrid (Zamba2) and xLSTM model assemblies.

Zamba2: a Mamba2 backbone with a single *weight-shared* attention+MLP
transformer block invoked every ``attn_every`` layers (the Zamba signature).
Mamba layers are stacked and scanned in groups of ``attn_every`` so the
shared block sits between scanned groups.

xLSTM: alternating mLSTM / sLSTM blocks (1:7 ratio via ``slstm_every``);
12 small layers — unrolled (no scan needed at this depth).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import ssm
from repro.models.layers import (attention_apply, attention_init, dense,
                                 dense_init, embed, embedding_init, mlp,
                                 mlp_init, rmsnorm, rmsnorm_init, unembed)


# ===========================================================================
# Zamba2
# ===========================================================================
def _mamba_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln": rmsnorm_init(cfg.d_model, dtype),
            "mamba": ssm.mamba2_init(k1, cfg, dtype)}


def zamba2_init(cfg: ArchConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kl, ka, km = jax.random.split(key, 4)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    p: Dict[str, Any] = {
        "embed": embedding_init(ke, cfg.vocab_size, cfg.d_model, dtype),
        "mamba": jax.vmap(partial(_mamba_block_init, cfg=cfg, dtype=dtype))(layer_keys),
        "shared": {
            "ln1": rmsnorm_init(cfg.d_model, dtype),
            "attn": attention_init(ka, cfg, dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(km, cfg.d_model, cfg.d_ff, dtype),
        },
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    return p  # embeddings tied


def _zamba_groups(cfg: ArchConfig):
    g = cfg.attn_every
    n_groups = cfg.n_layers // g
    trailing = cfg.n_layers % g
    return g, n_groups, trailing


def zamba2_apply(cfg: ArchConfig, params, batch, cache=None, use_pallas=False,
                 remat=False):
    x = embed(params["embed"], batch["tokens"]).astype(jnp.dtype(cfg.dtype))
    b, s = x.shape[:2]
    g, n_groups, trailing = _zamba_groups(cfg)

    if cache is None:
        positions = jnp.arange(s, dtype=jnp.int32)
        offset = None
    else:
        offset = cache["offset"]
        positions = jnp.arange(s, dtype=jnp.int32) + offset

    def split(tree, lo, hi, group=None):
        def f(a):
            sl = a[lo:hi]
            if group is not None:
                sl = sl.reshape((group, (hi - lo) // group) + a.shape[1:])
            return sl
        return jax.tree.map(f, tree)

    main_p = split(params["mamba"], 0, n_groups * g, n_groups)
    tail_p = split(params["mamba"], n_groups * g, cfg.n_layers)
    if cache is not None:
        main_c = split(cache["mamba"], 0, n_groups * g, n_groups)
        tail_c = split(cache["mamba"], n_groups * g, cfg.n_layers)
        attn_c = cache["attn"]  # stacked (n_groups, ...)
    else:
        main_c = tail_c = attn_c = None

    shared = params["shared"]

    def mamba_body(h, pc):
        pl, cl = pc
        y, new_state = ssm.mamba2_apply(pl["mamba"], cfg,
                                        rmsnorm(pl["ln"], h, cfg.norm_eps), cl)
        return h + y, new_state

    if remat:
        mamba_body = jax.checkpoint(mamba_body)

    def _layer_loop(h, stack_p, stack_c, n):
        """scan or unrolled python loop over a stacked mamba group."""
        if cfg.scan_layers:
            return jax.lax.scan(mamba_body, h, (stack_p, stack_c))
        states = []
        for i in range(n):
            p_i = jax.tree.map(lambda a: a[i], stack_p)
            c_i = (None if stack_c is None
                   else jax.tree.map(lambda a: a[i], stack_c))
            h, st = mamba_body(h, (p_i, c_i))
            states.append(st)
        stacked = (None if stack_c is None
                   else jax.tree.map(lambda *xs: jnp.stack(xs), *states))
        return h, stacked

    def group_body(h, inp):
        grp_p, grp_c, a_c = inp
        h, new_states = _layer_loop(h, grp_p, grp_c, g)
        if a_c is not None:
            a_c = dict(a_c, offset=offset)
        a, new_kv = attention_apply(shared["attn"], cfg,
                                    rmsnorm(shared["ln1"], h, cfg.norm_eps),
                                    positions, a_c, use_pallas=use_pallas)
        h = h + a
        h = h + mlp(shared["mlp"], rmsnorm(shared["ln2"], h, cfg.norm_eps), cfg.act)
        return h, (new_states, new_kv)

    if cfg.scan_layers:
        x, (new_mamba_main, new_attn) = jax.lax.scan(
            group_body, x, (main_p, main_c, attn_c))
    else:
        mains, attns = [], []
        for gi in range(n_groups):
            pick = lambda t: (None if t is None
                              else jax.tree.map(lambda a: a[gi], t))
            x, (st, kv) = group_body(x, (pick(main_p), pick(main_c),
                                         pick(attn_c)))
            mains.append(st)
            attns.append(kv)
        new_mamba_main = (None if main_c is None
                          else jax.tree.map(lambda *xs: jnp.stack(xs), *mains))
        new_attn = (None if attn_c is None
                    else jax.tree.map(lambda *xs: jnp.stack(xs), *attns))
    new_mamba_tail = None
    if trailing:
        x, new_mamba_tail = _layer_loop(x, tail_p, tail_c, trailing)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)

    new_cache = None
    if cache is not None:
        flat_main = jax.tree.map(
            lambda a: a.reshape((n_groups * g,) + a.shape[2:]), new_mamba_main)
        if trailing:
            new_mamba = jax.tree.map(lambda a, t: jnp.concatenate([a, t], 0),
                                     flat_main, new_mamba_tail)
        else:
            new_mamba = flat_main
        new_cache = {"mamba": new_mamba, "attn": new_attn, "offset": offset + s}
    return logits, new_cache, {"moe_aux": jnp.zeros((), jnp.float32)}


def zamba2_cache_spec(cfg: ArchConfig, batch, max_len, dtype=jnp.bfloat16):
    g, n_groups, _ = _zamba_groups(cfg)
    m = ssm.mamba2_cache_spec(cfg, batch, dtype)

    def stack_l(sds, n):
        return jax.ShapeDtypeStruct((n,) + sds.shape, sds.dtype)

    kv = {
        "k": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
    }
    return {
        "mamba": jax.tree.map(lambda s: stack_l(s, cfg.n_layers), m),
        "attn": jax.tree.map(lambda s: stack_l(s, n_groups), kv),
        "offset": jax.ShapeDtypeStruct((), jnp.int32),
    }


# ===========================================================================
# xLSTM
# ===========================================================================
def _xlstm_kinds(cfg: ArchConfig):
    return ["slstm" if (cfg.slstm_every and i % cfg.slstm_every == 0) else "mlstm"
            for i in range(cfg.n_layers)]


def xlstm_init(cfg: ArchConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kh, kl = jax.random.split(key, 3)
    blocks = []
    for i, (kind, bk) in enumerate(zip(_xlstm_kinds(cfg),
                                       jax.random.split(kl, cfg.n_layers))):
        init = ssm.slstm_init if kind == "slstm" else ssm.mlstm_init
        blocks.append({"ln": rmsnorm_init(cfg.d_model, dtype),
                       "cell": init(bk, cfg, dtype)})
    return {
        "embed": embedding_init(ke, cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "lm_head": dense_init(kh, cfg.d_model, cfg.vocab_size, dtype),
    }


def xlstm_apply(cfg: ArchConfig, params, batch, cache=None, use_pallas=False,
                remat=False):
    x = embed(params["embed"], batch["tokens"]).astype(jnp.dtype(cfg.dtype))
    kinds = _xlstm_kinds(cfg)
    new_layers = []
    for i, (kind, bp) in enumerate(zip(kinds, params["blocks"])):
        cl = None if cache is None else cache["layers"][i]
        h = rmsnorm(bp["ln"], x, cfg.norm_eps)
        if kind == "slstm":
            y, st = ssm.slstm_apply(bp["cell"], cfg, h, cl)
        else:
            y, st = ssm.mlstm_apply(bp["cell"], cfg, h, cl)
        x = x + y
        new_layers.append(st)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = dense(params["lm_head"], x)
    new_cache = None
    if cache is not None:
        new_cache = {"layers": new_layers, "offset": cache["offset"] + x.shape[1]}
    return logits, new_cache, {"moe_aux": jnp.zeros((), jnp.float32)}


def xlstm_cache_spec(cfg: ArchConfig, batch, max_len, dtype=jnp.bfloat16):
    layers = []
    for kind in _xlstm_kinds(cfg):
        spec = (ssm.slstm_cache_spec if kind == "slstm" else ssm.mlstm_cache_spec)
        layers.append(spec(cfg, batch))
    return {"layers": layers, "offset": jax.ShapeDtypeStruct((), jnp.int32)}
