"""Multi-head Latent Attention (MLA), DeepSeek-V2 style.

Two execution paths:
  * prefill/train: naive path (decompress c_kv -> k,v per head).
  * decode: *absorbed* path — queries are projected into the 512-d latent
    space so attention runs directly against the compressed cache
    (c_kv, k_rope). This is what makes the MLA decode cache ~7x smaller
    than GQA and is the efficient serving path.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense, dense_init, rmsnorm, rmsnorm_init, apply_rope


def mla_init(key, cfg: ArchConfig, dtype):
    kq, ka, kb, ko = jax.random.split(key, 4)
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq": dense_init(kq, cfg.d_model, cfg.n_heads * qk_dim, dtype),
        "wkv_a": dense_init(ka, cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_dim, dtype),
        "kv_norm": rmsnorm_init(cfg.kv_lora_rank, dtype),
        "wkv_b": dense_init(kb, cfg.kv_lora_rank,
                            cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim), dtype),
        "wo": dense_init(ko, cfg.n_heads * cfg.v_head_dim, cfg.d_model, dtype),
    }


def _project_q(p, cfg, x, positions):
    b, s, _ = x.shape
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    q = dense(p["wq"], x).reshape(b, s, cfg.n_heads, qk_dim)
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _compress_kv(p, cfg, x, positions):
    b, s, _ = x.shape
    kv_a = dense(p["wkv_a"], x)
    c_kv = rmsnorm(p["kv_norm"], kv_a[..., : cfg.kv_lora_rank], cfg.norm_eps)
    k_rope = kv_a[..., cfg.kv_lora_rank:].reshape(b, s, 1, cfg.qk_rope_dim)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]  # (B,S,rope_dim)
    return c_kv, k_rope


def mla_apply(p, cfg: ArchConfig, x, positions, cache=None, use_pallas=False):
    """Returns (out, new_cache_entries)."""
    b, s, _ = x.shape
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = _project_q(p, cfg, x, positions)
    c_kv, k_rope = _compress_kv(p, cfg, x, positions)

    if cache is None:
        # Naive path: decompress and run standard attention.
        kv = dense(p["wkv_b"], c_kv).reshape(
            b, s, cfg.n_heads, cfg.qk_nope_dim + cfg.v_head_dim)
        k_nope, v = kv[..., : cfg.qk_nope_dim], kv[..., cfg.qk_nope_dim:]
        k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                    (b, s, cfg.n_heads, cfg.qk_rope_dim))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
        qpos = jnp.arange(s)
        mask = qpos[:, None] >= qpos[None, :]
        logits = jnp.where(mask[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
        new_cache = None
    else:
        # Absorbed decode path against the compressed cache.
        offset = cache["offset"]
        c_all = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, offset, 0))
        r_all = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, offset, 0))
        kv_len = offset + s
        w_b = p["wkv_b"]["w"].reshape(cfg.kv_lora_rank, cfg.n_heads,
                                      cfg.qk_nope_dim + cfg.v_head_dim)
        w_uk = w_b[..., : cfg.qk_nope_dim]   # (r, H, nope)
        w_uv = w_b[..., cfg.qk_nope_dim:]    # (r, H, v)
        # absorb W_uk into q: (B,S,H,nope) x (r,H,nope) -> (B,S,H,r)
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk.astype(q_nope.dtype))
        scores = jnp.einsum("bshr,bkr->bhsk", q_lat, c_all.astype(q_lat.dtype))
        scores = scores + jnp.einsum("bshd,bkd->bhsk", q_rope,
                                     r_all.astype(q_rope.dtype))
        scores = scores.astype(jnp.float32) * scale
        kpos = jnp.arange(c_all.shape[1])
        qpos = offset + jnp.arange(s)
        causal = kpos[None, :] <= qpos[:, None]            # (S, S_max)
        valid = (kpos[None, :] < kv_len) & causal          # causal + cache-validity
        scores = jnp.where(valid[None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhsk,bkr->bshr", probs, c_all.astype(probs.dtype))
        out = jnp.einsum("bshr,rhd->bshd", ctx, w_uv.astype(ctx.dtype))
        new_cache = {"c_kv": c_all, "k_rope": r_all}

    out = out.reshape(b, s, cfg.n_heads * cfg.v_head_dim)
    return dense(p["wo"], out), new_cache


def mla_cache_spec(cfg: ArchConfig, batch, max_len, dtype=jnp.bfloat16):
    """Shapes of the per-layer compressed cache."""
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct((batch, max_len, cfg.qk_rope_dim), dtype),
    }
