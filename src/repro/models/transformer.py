"""Transformer assembly for the dense / moe / encoder / vlm families.

Layers are *stacked* (leading layer axis) and driven by ``lax.scan`` so that
48-layer models compile in O(1) layer-count time — essential for the 512-
device dry-run on this host. Param pytrees therefore carry a leading ``L``
dim; sharding rules prepend ``None`` for it.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as mla
from repro.models import moe as moe_mod
from repro.models.layers import (attention_apply, attention_init, dense,
                                 dense_init, embed, embedding_init, mlp,
                                 mlp_init, rmsnorm, rmsnorm_init, unembed)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def _block_init(key, cfg: ArchConfig, dtype, moe: bool):
    ka, km = jax.random.split(key)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.use_mla:
        p["attn"] = mla.mla_init(ka, cfg, dtype)
    else:
        p["attn"] = attention_init(ka, cfg, dtype)
    if moe:
        p["moe"] = moe_mod.moe_init(km, cfg, dtype)
    else:
        p["mlp"] = mlp_init(km, cfg.d_model, cfg.d_ff, dtype, gated=cfg.mlp_gated)
    return p


def _block_apply(p, cfg: ArchConfig, x, positions, cache, use_pallas, moe: bool):
    if cfg.use_mla:
        a, new_kv = mla.mla_apply(p["attn"], cfg, rmsnorm(p["ln1"], x, cfg.norm_eps),
                                  positions, cache)
    else:
        a, new_kv = attention_apply(p["attn"], cfg, rmsnorm(p["ln1"], x, cfg.norm_eps),
                                    positions, cache, use_pallas=use_pallas)
    x = x + a
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if moe:
        m, aux = moe_mod.moe_apply(p["moe"], cfg, h)
    else:
        m, aux = mlp(p["mlp"], h, cfg.act), 0.0
    return x + m, new_kv, aux


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------
def transformer_init(cfg: ArchConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 6)
    n_stack = cfg.n_layers - cfg.first_dense_layers
    p: Dict[str, Any] = {
        "embed": embedding_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    moe = cfg.n_experts > 0
    layer_keys = jax.random.split(keys[1], n_stack)
    p["blocks"] = jax.vmap(partial(_block_init, cfg=cfg, dtype=dtype, moe=moe))(layer_keys)
    if cfg.first_dense_layers:
        fkeys = jax.random.split(keys[2], cfg.first_dense_layers)
        p["first_blocks"] = [
            _block_init(fk, cfg.replace(d_ff=cfg.d_ff), dtype, moe=False)
            for fk in fkeys
        ]
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[3], cfg.d_model, cfg.vocab_size, dtype)
    if cfg.frontend == "patches":
        p["patch_proj"] = dense_init(keys[4], cfg.frontend_dim, cfg.d_model, dtype)
    if cfg.frontend == "frames":
        p["frame_proj"] = dense_init(keys[4], cfg.frontend_dim, cfg.d_model, dtype)
        p["mask_embed"] = jnp.zeros((cfg.d_model,), dtype)
    return p


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def _embed_inputs(params, cfg: ArchConfig, batch):
    scale = float(cfg.d_model) ** 0.5 if cfg.embed_scale else None
    if cfg.frontend == "frames":
        x = dense(params["frame_proj"], batch["embeds"].astype(jnp.dtype(cfg.dtype)))
        if "mask" in batch:  # HuBERT-style masked prediction
            m = batch["mask"][..., None].astype(x.dtype)
            x = x * (1 - m) + params["mask_embed"].astype(x.dtype) * m
        return x
    x = embed(params["embed"], batch["tokens"], scale)
    x = x.astype(jnp.dtype(cfg.dtype))
    if cfg.frontend == "patches" and "patches" in batch:
        pe = dense(params["patch_proj"], batch["patches"].astype(x.dtype))
        n_p = pe.shape[1]
        x = jnp.concatenate([pe, x[:, n_p:]], axis=1)
    return x


def transformer_apply(cfg: ArchConfig, params, batch, cache=None, use_pallas=False,
                      remat=False):
    """Returns (logits, new_cache, aux_dict)."""
    x = _embed_inputs(params, cfg, batch)
    b, s = x.shape[:2]
    moe = cfg.n_experts > 0

    if cache is None:
        positions = jnp.arange(s, dtype=jnp.int32)
        offset = None
    else:
        offset = cache["offset"]
        positions = jnp.arange(s, dtype=jnp.int32) + offset

    aux_total = jnp.zeros((), jnp.float32)
    new_first = []
    for i in range(cfg.first_dense_layers):
        fc = None if cache is None else dict(cache["first"][i], offset=offset)
        x, kv, _ = _block_apply(params["first_blocks"][i], cfg, x, positions, fc,
                                use_pallas, moe=False)
        new_first.append(kv)

    def body(carry, pl_cl):
        h, aux = carry
        pl, cl = pl_cl
        if cl is not None:
            cl = dict(cl, offset=offset)
        h, kv, a = _block_apply(pl, cfg, h, positions, cl, use_pallas, moe=moe)
        return (h, aux + a), kv

    if remat:
        body = jax.checkpoint(body)

    stacked_cache = None if cache is None else cache["layers"]
    if cfg.scan_layers:
        (x, aux_total), new_kv = jax.lax.scan(body, (x, aux_total),
                                              (params["blocks"], stacked_cache))
    else:  # unrolled lowering (exact cost_analysis; slower compile)
        n_stack = cfg.n_layers - cfg.first_dense_layers
        kvs = []
        for i in range(n_stack):
            pl_i = jax.tree.map(lambda a: a[i], params["blocks"])
            cl_i = (None if stacked_cache is None
                    else jax.tree.map(lambda a: a[i], stacked_cache))
            (x, aux_total), kv_i = body((x, aux_total), (pl_i, cl_i))
            kvs.append(kv_i)
        new_kv = (None if stacked_cache is None
                  else jax.tree.map(lambda *xs: jnp.stack(xs), *kvs))

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.shard_activations:
        # Keep activations batch-sharded through the unembed so GSPMD
        # all-gathers the small FSDP table shards, not (B,S,·) activations.
        from repro.distributed.sharding import BATCH, shard_hint
        x = shard_hint(x, list(BATCH))
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = dense(params["lm_head"], x)
    if cfg.shard_activations:
        logits = shard_hint(logits, list(BATCH), [], ["model"])
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap

    new_cache = None
    if cache is not None:
        new_cache = {"layers": new_kv, "offset": offset + s}
        if cfg.first_dense_layers:
            new_cache["first"] = new_first
    return logits, new_cache, {"moe_aux": aux_total / max(cfg.n_layers, 1)}


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------
def transformer_cache_spec(cfg: ArchConfig, batch, max_len, dtype=jnp.bfloat16):
    n_stack = cfg.n_layers - cfg.first_dense_layers
    if cfg.use_mla:
        per_layer = mla.mla_cache_spec(cfg, batch, max_len, dtype)
    else:
        per_layer = {
            "k": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        }

    def stack(sds):
        return jax.ShapeDtypeStruct((n_stack,) + sds.shape, sds.dtype)

    spec = {"layers": jax.tree.map(stack, per_layer),
            "offset": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.first_dense_layers:
        # first dense layers always use plain GQA cache shape (MLA lite's first
        # layer is dense-MLP but still MLA attention; keep MLA cache for it)
        spec["first"] = [per_layer for _ in range(cfg.first_dense_layers)]
    return spec
