"""Architecture registry: id -> (config, init, apply, cache_spec, input_specs).

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every model
input of that (arch, shape) cell — weak-type-correct, shardable, and never
allocated — the dry-run pattern.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ArchConfig, InputShape, SHAPES,  # noqa: F401
                                get_config, list_archs, register)
from repro.models import hybrid, transformer


class Model(NamedTuple):
    cfg: ArchConfig
    init: Callable[..., Any]
    apply: Callable[..., Any]          # (params, batch, cache=None, ...) -> (logits, cache, aux)
    cache_spec: Callable[..., Any]     # (batch, max_len, dtype) -> pytree of SDS


def get_model(cfg: ArchConfig) -> Model:
    if cfg.family == "hybrid":
        return Model(cfg,
                     lambda key: hybrid.zamba2_init(cfg, key),
                     lambda p, b, cache=None, **kw: hybrid.zamba2_apply(cfg, p, b, cache, **kw),
                     lambda batch, max_len, dtype=jnp.bfloat16:
                         hybrid.zamba2_cache_spec(cfg, batch, max_len, dtype))
    if cfg.family == "ssm":
        return Model(cfg,
                     lambda key: hybrid.xlstm_init(cfg, key),
                     lambda p, b, cache=None, **kw: hybrid.xlstm_apply(cfg, p, b, cache, **kw),
                     lambda batch, max_len, dtype=jnp.bfloat16:
                         hybrid.xlstm_cache_spec(cfg, batch, max_len, dtype))
    # dense / moe / encoder / vlm all share the transformer assembly
    return Model(cfg,
                 lambda key: transformer.transformer_init(cfg, key),
                 lambda p, b, cache=None, **kw: transformer.transformer_apply(cfg, p, b, cache, **kw),
                 lambda batch, max_len, dtype=jnp.bfloat16:
                     transformer.transformer_cache_spec(cfg, batch, max_len, dtype))


def input_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the model inputs of one grid cell."""
    b = shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32
    act_dt = jnp.dtype(cfg.dtype)

    if shape.kind == "decode":
        batch = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        if cfg.frontend == "frames":
            batch = {"embeds": jax.ShapeDtypeStruct((b, 1, cfg.frontend_dim), act_dt)}
        return batch

    if cfg.frontend == "frames":  # hubert: precomputed frame embeddings (stub frontend)
        batch = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), act_dt)}
        if shape.kind == "train":
            batch["mask"] = jax.ShapeDtypeStruct((b, s), jnp.bool_)
            batch["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        return batch

    batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    if cfg.frontend == "patches":  # pixtral: precomputed patch embeddings (stub ViT)
        batch["patches"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.frontend_dim), act_dt)
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    return batch


