"""Training launcher.

On real hardware this runs the full config on the production mesh; on this
CPU container use ``--reduced`` for an actually-executing run (the full
configs are exercised via launch/dryrun.py). Supports checkpoint/restart
(``--resume``), microbatching, remat, and int8 gradient compression over the
DP axis (``--grad-compression``, shard_map path).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 50 --resume --ckpt-dir /tmp/ckpt   # restart from latest
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.data.pipeline import TokenPipeline
from repro.distributed import sharding as shd
from repro.models.registry import get_model
from repro.training import checkpoint as ckpt
from repro.training.compression import compress_psum, ef_init
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                          total_steps=args.steps)
    step_fn = make_train_step(model, opt_cfg, microbatches=args.microbatches,
                              remat=not args.no_remat)

    if args.grad_compression:
        step_fn = _wrap_with_compression(model, opt_cfg, args)

    step_fn = jax.jit(step_fn, donate_argnums=0)

    state = init_train_state(model, jax.random.PRNGKey(args.seed))
    start = 0
    if args.resume and args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            like = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            state, manifest = ckpt.restore(args.ckpt_dir, last, like)
            start = last
            print(f"resumed from step {last}")

    pipe = iter(TokenPipeline(cfg, args.batch, args.seq, seed=args.seed))
    t0 = time.time()
    for step in range(start, args.steps):
        batch = next(pipe)
        state, metrics = step_fn(state, batch)
        if (step + 1) % args.log_every == 0 or step == start:
            loss = float(metrics["loss"])
            tok_s = args.batch * args.seq * (step + 1 - start) / (time.time() - t0)
            print(f"step {step + 1:5d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  tok/s {tok_s:,.0f}",
                  flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, state,
                      extra={"arch": args.arch, "reduced": args.reduced})
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, state,
                  extra={"arch": args.arch, "reduced": args.reduced})
    print("done")
    return state


def _wrap_with_compression(model, opt_cfg, args):
    """DP train step with int8 error-feedback gradient all-reduce inside
    shard_map (beyond-paper distributed-optimization option)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.training.optimizer import adamw_update
    from repro.training.train_step import make_loss_fn

    mesh = jax.make_mesh((jax.device_count(),), ("dp",))
    loss_fn = make_loss_fn(model, remat=not args.no_remat)

    def step(state, batch):
        def local(state, batch, residuals):
            (loss, extras), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state["params"], batch)
            grads, new_res = compress_psum(grads, residuals, "dp")
            new_params, new_opt, om = adamw_update(
                opt_cfg, state["params"], grads, state["opt"])
            loss = jax.lax.pmean(loss, "dp")
            return ({"params": new_params, "opt": new_opt, "ef": new_res},
                    {"loss": loss, **extras, **om})

        inner = shard_map(
            local, mesh=mesh,
            in_specs=({"params": P(), "opt": P(), "ef": P()},
                      jax.tree.map(lambda _: P("dp"), batch), P()),
            out_specs=({"params": P(), "opt": P(), "ef": P()}, P()),
            check_vma=False)
        st = dict(state)
        residuals = st.pop("ef", None)
        if residuals is None:
            residuals = ef_init(state["params"])
        return inner(st, batch, residuals)

    return step


if __name__ == "__main__":
    main()
