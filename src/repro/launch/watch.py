"""Live metrics watcher: tail the JSONL stream a fleet run writes.

``train_fleet.py --metrics-out run.jsonl`` streams one record per episode
(from inside the single jitted scan, via an ordered ``jax.debug.callback``);
this CLI reads the same file — once, or continuously with ``--follow`` —
and prints the run header, a per-metric tail summary, and the FL transport
digest. Torn last lines (the writer may be mid-append) are tolerated by
``repro.eval.stream.read_metrics``.

Examples:
  PYTHONPATH=src python -m repro.launch.watch run.jsonl
  PYTHONPATH=src python -m repro.launch.watch run.jsonl --follow --interval 2
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from repro.eval.stream import (device_summary, fl_round_summary,
                               health_summary, read_metrics, tail_summary)
from repro.health.alerts import read_alerts

WATCH_METRICS = ("reward", "throughput", "effective_throughput", "latency",
                 "loss", "gated", "fl_payload_bytes", "fl_missed",
                 "fl_stale_used", "health_reward_p50", "health_miss_p90",
                 "health_drift_score", "health_susp")


def render(path: str, tail_k: int, metrics=WATCH_METRICS,
           alerts_path=None, alerts_k: int = 5) -> str:
    """One status report for the metrics file — the string ``main`` prints.
    Pure function of the file contents so tests can diff it.

    Degrades instead of crashing on the live-file edge cases: a meta-only
    file (run killed before episode 0 landed) renders a "no records yet"
    line, and metric keys this watcher does not know (a newer writer, or
    non-numeric values) are skipped rather than garbling the table. The
    ``health_*`` rows and the health digest line appear only for runs that
    enabled the fleet health observatory (``train_fleet.py --health``) —
    a pre-health metrics file, or one whose early episodes predate the
    observatory, renders exactly as before. ``alerts_path`` appends the
    tail of an ALERTS.jsonl file (``--alerts-out``) when it exists."""
    meta, records = read_metrics(path)
    lines = []
    if meta:
        lines.append("run: " + "  ".join(
            f"{k}={meta[k]}" for k in sorted(meta)))
    if not records:
        lines.append("no records yet (run warming up, or killed before "
                     "episode 0) — retry with --follow")
        return "\n".join(lines)
    n_eps = sum(1 for r in records if "devices" not in r)
    lines.append(f"episodes recorded: {n_eps}")
    summary = tail_summary(records, k=tail_k)
    shown = [m for m in metrics if m in summary]
    if shown:
        lines.append(f"{'metric':24s}{'last':>12s}"
                     f"{f'tail[{tail_k}]':>12s}{'mean':>12s}")
        for m in shown:
            s = summary[m]
            lines.append(f"{m:24s}{s['last']:12.4f}"
                         f"{s['tail_mean']:12.4f}{s['mean']:12.4f}")
    health = health_summary(records)
    if health is not None:
        lines.append(
            f"health: {health['episodes']:.0f} episodes, "
            f"drift flags on {health['drift_flags']:.0f} "
            f"(score last {health['drift_score_last']:.2f}), "
            f"reward p50 {health['reward_p50_last']:.3f}, "
            f"miss p90 {health['miss_p90_mean']:.3f}, "
            f"susp last {health['susp_last']:.2f} "
            f"(max {health['susp_max']:.2f})")
    fl = fl_round_summary(records)
    if fl is not None:
        lines.append(f"FL: {fl['rounds']:.0f} rounds, "
                     f"{fl['payload_bytes'] / 1024:.1f} KB/round, "
                     f"uplink {fl['uplink_s'] * 1e3:.1f} ms, "
                     f"missed {fl['missed']:.2f}/round, "
                     f"stale joins {fl['stale_used']:.2f}/round, "
                     f"rejected {fl.get('rejected', 0.0):.2f}/round, "
                     f"clipped {fl.get('clipped', 0.0):.2f}/round")
    dev = device_summary(records)
    if dev is not None:
        lines.append(
            f"scaling: {dev.get('devices', 1):.0f} devices, "
            f"{dev.get('agents', 0):.0f} agents, "
            f"step {dev.get('step_time_s', 0.0) * 1e3:.1f} ms "
            f"({dev.get('step_time_per_agent_s', 0.0) * 1e6:.1f} us/agent), "
            f"state {dev.get('state_bytes_per_agent', 0.0) / 1024:.1f} "
            f"KB/agent")
        per_dev = [(k, v) for k, v in sorted(dev.items())
                   if k.startswith("dev") and k.endswith("_bytes")]
        if per_dev:
            lines.append("per-device state: " + "  ".join(
                f"{k[:-len('_bytes')]}={v / 1024:.0f}KB"
                for k, v in per_dev))
    if alerts_path is not None:
        alerts = read_alerts(alerts_path)  # missing/torn file -> []
        fired = [a for a in alerts if a.get("kind") == "alert"]
        lines.append(f"alerts: {len(fired)} fired")
        for a in alerts[-alerts_k:]:
            kind = "RESOLVED" if a.get("kind") == "resolve" else \
                a.get("severity", "warn").upper()
            lines.append(
                f"  [{kind:8s}] ep {a.get('episode', -1):>5} "
                f"{a.get('rule', '?')}: {a.get('metric', '?')} "
                f"{a.get('op', '?')} {a.get('threshold', 0.0):g} "
                f"(value {a.get('value', 0.0):.4g})")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("path", help="metrics JSONL file "
                                 "(train_fleet.py --metrics-out)")
    ap.add_argument("--tail", type=int, default=10,
                    help="episodes in the tail-mean window")
    ap.add_argument("--follow", action="store_true",
                    help="keep re-reading until interrupted (like tail -f)")
    ap.add_argument("--interval", type=float, default=5.0,
                    help="seconds between --follow refreshes")
    ap.add_argument("--alerts", default=None, metavar="ALERTS_JSONL",
                    help="also tail this alerts file "
                         "(train_fleet.py --alerts-out)")
    args = ap.parse_args(argv)
    if not os.path.exists(args.path):
        ap.error(f"no metrics file at {args.path}")

    try:
        print(render(args.path, args.tail, alerts_path=args.alerts))
        while args.follow:
            try:
                time.sleep(max(args.interval, 0.1))
            except KeyboardInterrupt:
                break
            print()
            print(render(args.path, args.tail, alerts_path=args.alerts))
    except BrokenPipeError:  # `watch ... | head` closing the pipe is fine
        sys.stderr.close()


if __name__ == "__main__":
    main()
