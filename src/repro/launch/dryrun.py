import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this lowers the real step function (train_step / prefill /
serve_step) against ShapeDtypeStruct stand-ins on the production mesh,
compiles it, and extracts:
  * memory_analysis()      — proves the cell fits per-device HBM,
  * cost_analysis()        — HLO FLOPs / bytes for the roofline terms,
  * collective schedule    — parsed from the post-SPMD HLO text (bytes per
    collective kind, wire-traffic convention documented in
    ``collective_bytes``),
  * roofline terms         — compute / memory / collective seconds +
    dominant bottleneck + MODEL_FLOPS/HLO_FLOPs utilization ratio.

Results are cached as JSON under ``artifacts/dryrun/`` so EXPERIMENTS.md and
``benchmarks/roofline.py`` read from them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, get_config, list_archs, shape_applicable
from repro.distributed import sharding as shd
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.models.registry import get_model, input_specs
from repro.serving.engine import make_prefill_step, make_serve_step
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import make_train_step

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8}

_COLL_RE = re.compile(
    r"=\s*[a-z0-9]+\[[0-9,]*\][^=]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,. ]*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum wire bytes per collective kind from post-SPMD HLO.

    Convention (documented for the roofline): per-op total wire traffic =
    (participants - 1) × payload, where payload = per-device output bytes
    (all-gather) / input bytes (reduce-scatter, all-to-all, permute) /
    2 × input bytes (all-reduce ≈ RS + AG phases).
    """
    out = {}
    count = {}
    ops = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        shapes = _SHAPE_RE.findall(line)
        if not shapes:
            continue
        out_bytes = _shape_bytes(*shapes[0])
        in_bytes = (_shape_bytes(*shapes[1]) if len(shapes) > 1 else out_bytes)
        g = _GROUPS_RE.search(line)
        if g:
            ids = [x for x in g.group(1).replace(" ", "").split(",") if x]
            n_part = max(len(ids), 2)
        else:
            gi = _IOTA_GROUPS_RE.search(line)
            n_part = int(gi.group(2)) if gi else 2
        if kind == "all-gather":
            payload = out_bytes
        elif kind == "all-reduce":
            payload = 2 * in_bytes
        else:
            payload = in_bytes
        wire = (n_part - 1) * payload
        out[kind] = out.get(kind, 0) + wire
        count[kind] = count.get(kind, 0) + 1
        shape_str = f"{shapes[0][0]}[{shapes[0][1]}]"
        ops.append((wire, kind, shape_str, n_part))
    ops.sort(reverse=True)
    top = [{"kind": k, "shape": s, "participants": n, "wire_bytes": w}
           for w, k, s, n in ops[:12]]
    return {"bytes": out, "count": count, "total": sum(out.values()),
            "top_ops": top}


def _count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def model_flops(cfg, params_specs, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference); MoE uses N_active."""
    n_total = _count_params(params_specs)
    n = n_total
    if cfg.n_experts:
        # subtract inactive expert params
        e, f, d = cfg.n_experts, cfg.moe_d_ff, cfg.d_model
        n_moe_layers = cfg.n_layers - cfg.first_dense_layers
        per_layer = 3 * d * f
        n = n_total - n_moe_layers * per_layer * (e - cfg.top_k)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return float(mult) * n * tokens, n_total, n


def build_cell(arch: str, shape_name: str, mesh, serve_dtype=jnp.bfloat16,
               unroll: bool = False, overrides=None, fsdp: bool = True):
    """Returns (fn, args (SDS pytrees), in_shardings, out_shardings).

    ``fsdp=False`` = the serving param profile (TP-only weights, no per-step
    weight re-gather) — a §Perf variant for the inference shapes."""
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    cfg = cfg.replace(param_dtype="float32" if shape.kind == "train" else "bfloat16")
    if unroll:
        cfg = cfg.replace(scan_layers=False)
    if overrides:
        cfg = cfg.replace(**overrides)
    model = get_model(cfg)
    batch = input_specs(cfg, shape)
    batch_sh = shd.input_shardings(batch, mesh)
    params_specs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sh = shd.param_shardings(params_specs, mesh, fsdp=fsdp)

    if shape.kind == "train":
        opt_specs = {
            "m": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                              params_specs),
            "v": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                              params_specs),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        opt_sh = {
            "m": params_sh, "v": jax.tree.map(lambda s: s, params_sh),
            "step": shd.replicated(mesh),
        }
        state = {"params": params_specs, "opt": opt_specs}
        state_sh = {"params": params_sh, "opt": opt_sh}
        fn = make_train_step(model, AdamWConfig(), remat=True)
        return (fn, (state, batch), (state_sh, batch_sh),
                (state_sh, None), cfg, params_specs, shape)

    if shape.kind == "prefill":
        fn = make_prefill_step(model, with_cache=False)
        out_sh = None
        return (fn, (params_specs, batch), (params_sh, batch_sh), out_sh,
                cfg, params_specs, shape)

    # decode
    cache_specs = model.cache_spec(shape.global_batch, shape.seq_len,
                                   serve_dtype)
    cache_sh = shd.cache_shardings(cache_specs, mesh)
    fn = make_serve_step(model)
    return (fn, (params_specs, cache_specs, batch),
            (params_sh, cache_sh, batch_sh), None, cfg, params_specs, shape)


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             save: bool = True, unroll: bool = False, variant: str = "",
             overrides=None) -> dict:
    shape = SHAPES[shape_name]
    cfg0 = get_config(arch)
    ok, reason = shape_applicable(cfg0, shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "variant": variant or ("unroll" if unroll else "")}
    if not ok:
        rec.update(status="skipped", reason=reason)
        _save(rec, save)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    try:
        fn, args, in_sh, out_sh, cfg, params_specs, shape = build_cell(
            arch, shape_name, mesh, unroll=unroll, overrides=overrides)
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = {}
        try:
            ma = compiled.memory_analysis()
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                if hasattr(ma, k):
                    mem[k] = int(getattr(ma, k))
        except Exception as e:  # noqa: BLE001
            mem["error"] = str(e)

        cost = {}
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            cost = {k: float(v) for k, v in ca.items()
                    if k in ("flops", "bytes accessed", "transcendentals",
                             "optimal_seconds")}
        except Exception as e:  # noqa: BLE001
            cost["error"] = str(e)

        coll = collective_bytes(compiled.as_text())

        # cost_analysis() reports the PER-DEVICE SPMD module (verified:
        # argument_size == global params+opt bytes / n_chips), so the
        # compute/memory terms divide by a single chip's peak, while the
        # collective term uses the fleet-total wire bytes over all links.
        hlo_flops = cost.get("flops", 0.0)          # per device
        hlo_bytes = cost.get("bytes accessed", 0.0)  # per device
        mflops, n_total, n_active = model_flops(cfg, params_specs, shape)
        t_comp = hlo_flops / PEAK_FLOPS_BF16
        t_mem = hlo_bytes / HBM_BW
        t_coll = coll["total"] / (n_chips * ICI_BW)
        terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
        dominant = max(terms, key=terms.get)

        rec.update(
            status="ok",
            chips=n_chips,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=mem,
            cost=cost,
            collectives=coll,
            params_total=n_total,
            params_active=n_active,
            model_flops=mflops,
            hlo_flops_global=hlo_flops * n_chips,
            useful_flops_ratio=(mflops / (hlo_flops * n_chips)
                                if hlo_flops else None),
            roofline=terms,
            dominant=dominant,
        )
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    _save(rec, save)
    return rec


def _save(rec, save):
    if not save:
        return
    os.makedirs(ART_DIR, exist_ok=True)
    suffix = f"_{rec['variant']}" if rec.get("variant") else ""
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json"
    with open(os.path.join(ART_DIR, name), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unrolled layer lowering: exact cost_analysis "
                         "(XLA:CPU counts scan bodies once)")
    args = ap.parse_args()

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape_name, multi_pod=mp,
                               unroll=args.unroll)
                dom = rec.get("dominant", "-")
                print(f"{arch:24s} {shape_name:12s} {rec['mesh']:8s} "
                      f"{rec['status']:8s} {dom:13s} "
                      f"compile={rec.get('compile_s', '-')}s "
                      f"{rec.get('reason', rec.get('error', ''))}",
                      flush=True)
                results.append(rec)
    bad = [r for r in results if r["status"] == "error"]
    print(f"\n{len(results)} cells: "
          f"{sum(r['status'] == 'ok' for r in results)} ok, "
          f"{sum(r['status'] == 'skipped' for r in results)} skipped, "
          f"{len(bad)} errors")
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
