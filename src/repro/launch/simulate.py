"""Request-level twin launcher — evaluate FCPO policies on the digital twin.

Builds a fleet (optionally quick-trained on the fluid MDP first), drives it
through the tensorized request-level simulator (``repro.sim``) on a named
workload scenario, and prints request-grade metrics: throughput, effective
throughput, p50/p99 end-to-end latency, and drops. ``--compare-fluid``
additionally evaluates the same policies on the fluid ``core/env.py`` MDP
over the same traces and prints the fidelity gap.

Examples:
  PYTHONPATH=src python -m repro.launch.simulate --agents 8 --intervals 60
  PYTHONPATH=src python -m repro.launch.simulate --agents 16 --scenario ood \
      --train-episodes 40 --compare-fluid
  PYTHONPATH=src python -m repro.launch.simulate --agents 4 --pallas
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.fcpo import FCPOConfig
from repro.core.backends import BACKENDS, get_backend
from repro.core.fleet import fleet_init, train_fleet
from repro.data.workload import fleet_traces
from repro.sim import SCENARIOS, SimParams, make_scenario, simulate_fleet


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--intervals", type=int, default=60,
                    help="control intervals to simulate")
    ap.add_argument("--scenario", choices=SCENARIOS, default="dynamic")
    ap.add_argument("--train-episodes", type=int, default=0,
                    help="warmup training episodes before evaluation "
                         "(0 = untrained policies)")
    ap.add_argument("--train-backend", choices=BACKENDS, default="fluid",
                    help="environment backend the warmup episodes train in "
                         "(twin = 'train where you serve')")
    ap.add_argument("--dt", type=float, default=0.05,
                    help="microtick length in seconds")
    ap.add_argument("--k-ticks", type=int, default=20,
                    help="microticks per control interval")
    ap.add_argument("--ring", type=int, default=512,
                    help="ring capacity (power of two)")
    ap.add_argument("--hist", type=int, default=64,
                    help="latency histogram buckets (ticks)")
    ap.add_argument("--pallas", action="store_true",
                    help="route the data plane through the fused Pallas "
                         "queue_advance kernel")
    ap.add_argument("--compare-fluid", action="store_true",
                    help="also evaluate on the fluid MDP and print the gap")
    ap.add_argument("--attribution", action="store_true",
                    help="record per-microtick counters and print the "
                         "per-request stage latency decomposition "
                         "(jnp path only)")
    ap.add_argument("--attr-sample", type=int, default=16,
                    help="keep every Nth request in the attribution "
                         "records / Chrome trace")
    ap.add_argument("--trace-out", metavar="PATH",
                    help="write the sampled request lifecycles as Chrome "
                         "trace-event JSON (open in Perfetto); implies "
                         "--attribution")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.intervals < 1:
        ap.error("--intervals must be >= 1")
    if args.ring <= 0 or args.ring & (args.ring - 1):
        ap.error("--ring must be a positive power of two")
    if args.trace_out:
        args.attribution = True
    if args.attribution and args.pallas:
        ap.error("--attribution needs the jnp data plane (drop --pallas): "
                 "the fused kernel advances whole intervals per call")

    cfg = FCPOConfig()
    if args.compare_fluid and args.intervals % cfg.n_steps:
        # the fluid plane evaluates in whole episodes; keep both planes on
        # the identical workload window
        args.intervals = max(args.intervals // cfg.n_steps, 1) * cfg.n_steps
        print(f"note: --compare-fluid rounds the horizon to whole episodes "
              f"-> {args.intervals} intervals")
    sp = SimParams(dt=args.dt, k_ticks=args.k_ticks, ring=args.ring,
                   hist_n=args.hist)
    train_be = get_backend(args.train_backend, sim_params=sp,
                           use_pallas=args.pallas)
    fleet = fleet_init(cfg, args.agents, jax.random.PRNGKey(args.seed),
                       env_backend=train_be)
    if args.train_episodes > 0:
        warmup = fleet_traces(jax.random.PRNGKey(args.seed + 1), args.agents,
                              args.train_episodes * cfg.n_steps)
        fleet, _ = train_fleet(cfg, fleet, warmup, env_backend=train_be)
    traces = make_scenario(args.scenario, jax.random.PRNGKey(args.seed + 2),
                           args.agents, args.intervals)

    print(f"twin: {args.agents} agents, {args.intervals} intervals, "
          f"K={sp.k_ticks} microticks of {sp.dt * 1e3:.0f} ms, "
          f"ring={sp.ring}, scenario={args.scenario}, "
          f"pallas={args.pallas}, trained={args.train_episodes} eps "
          f"on {train_be.name}, backend={jax.default_backend()}")
    t0 = time.time()
    state, history, summ = simulate_fleet(cfg, sp, fleet.astate.params,
                                          fleet.masks, fleet.env_params,
                                          traces,
                                          jax.random.PRNGKey(args.seed + 3),
                                          use_pallas=args.pallas,
                                          record_ticks=args.attribution)
    jax.block_until_ready(state.counters)
    wall = time.time() - t0
    ticks = args.intervals * sp.k_ticks
    print(f"wall {wall:.2f}s incl. compile "
          f"({wall / ticks * 1e6:.0f} us/microtick for the fleet)\n")

    rows = [("throughput", "req/s"), ("effective_throughput", "req/s"),
            ("mean_latency_s", "s"), ("p50_latency_s", "s"),
            ("p99_latency_s", "s"), ("drop_rate", ""),
            ("hist_censored", "")]
    print(f"{'metric':24s}{'fleet mean':>12s}{'min':>10s}{'max':>10s}")
    for k, unit in rows:
        v = np.asarray(summ[k])
        print(f"{k:24s}{v.mean():10.3f} {unit:4s}{v.min():9.3f}{v.max():10.3f}")
    print(f"{'requests':24s}arrived={int(np.asarray(summ['arrived']).sum())} "
          f"completed={int(np.asarray(summ['completed']).sum())} "
          f"dropped={int(np.asarray(summ['dropped']).sum())}")
    # >1% right-censored completions triggers warn_if_censored inside
    # simulate_fleet (one shared check); the hist_censored row above is the
    # always-on surface.

    if args.attribution:
        from repro.obs import requests as obs_requests
        from repro.sim.metrics import stage_breakdown_table

        attr = obs_requests.attribute_run(history, state,
                                          sample_every=args.attr_sample)
        bad = [i for i, rep in enumerate(attr["conservation"])
               if not rep["ok"]]
        dec = obs_requests.stage_decomposition(attr["agents"], sp.dt)
        print(f"\nrequest attribution ({len(attr['records'])} sampled "
              f"records, 1/{args.attr_sample}; conservation "
              f"{'FAILED for agents ' + str(bad) if bad else 'exact'})")
        print(stage_breakdown_table(dec))
        if args.trace_out:
            from repro.obs.trace import Tracer

            with Tracer() as tr:
                n = obs_requests.records_to_chrome(tr, attr["records"],
                                                   sp.dt)
                tr.export(args.trace_out)
            print(f"wrote {n} request slices -> {args.trace_out} "
                  f"(open in Perfetto / chrome://tracing)")

    if args.compare_fluid:
        hist = _fluid_eval(cfg, fleet, traces)
        eff_f = float(np.mean(hist["effective_throughput"]))
        eff_t = float(np.asarray(summ["effective_throughput"]).mean())
        gap = abs(eff_f - eff_t) / max(abs(eff_f), 1e-9)
        print(f"\nfluid-vs-twin effective throughput: fluid={eff_f:.2f} "
              f"twin={eff_t:.2f} gap={gap * 100:.1f}%")
    return summ


def _fluid_eval(cfg, fleet, traces):
    """Evaluate (no learning) on the fluid MDP over the same traces. The
    fleet may have been trained on any backend — its env states are swapped
    for fresh fluid ones so the policies (not the env leaves) carry over."""
    from repro.core.env import env_init

    a = traces.shape[0]
    fluid_states = jax.vmap(lambda _: env_init(cfg))(jax.numpy.arange(a))
    fleet = fleet._replace(astate=fleet.astate._replace(
        env_state=fluid_states))
    n_eps = max(traces.shape[1] // cfg.n_steps, 1)
    _, hist = train_fleet(cfg, fleet, traces[:, :n_eps * cfg.n_steps],
                          learn=False, federated=False)
    return hist


if __name__ == "__main__":
    main()
