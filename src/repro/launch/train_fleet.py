"""Fleet training launcher — the scanned FCPO driver from the CLI.

Runs the full federated-continual cadence (CRL episodes -> Eq. 7 selection ->
Alg. 1 aggregation -> Alg. 2 fine-tune -> hierarchical pod merge) as ONE
compiled program via ``train_fleet_scan``. ``--driver reference`` selects the
Python-loop oracle for A/B timing; ``--mesh`` installs the fleet shardings
(agents over ``data``, pods over the FL hierarchy) so the same command is
SPMD on a real mesh; ``--env-backend twin`` trains in the request-level
digital twin ("train where you serve") with K nested microticks per control
interval — still one jitted scan; ``--scenario`` picks the workload from the
scenario library (``repro.sim.scenarios``).

Examples:
  PYTHONPATH=src python -m repro.launch.train_fleet --agents 8 --pods 2 \
      --episodes 200
  PYTHONPATH=src python -m repro.launch.train_fleet --agents 8 --episodes 100 \
      --env-backend twin --scenario switching    # train in the twin
  PYTHONPATH=src python -m repro.launch.train_fleet --agents 16 --episodes 100 \
      --straggler-prob 0.3 --driver reference   # O(n_episodes) dispatches
  PYTHONPATH=src python -m repro.launch.train_fleet --agents 8 --mesh debug
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs.fcpo import FCPOConfig
from repro.core.backends import BACKENDS, get_backend
from repro.core.fleet import (fleet_init, train_fleet_reference,
                              train_fleet_scan)
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.sim import SCENARIOS, SimParams, make_scenario


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--episodes", type=int, default=200)
    ap.add_argument("--fl-every", type=int, default=None,
                    help="override cfg.fl_every")
    ap.add_argument("--straggler-prob", type=float, default=0.0)
    ap.add_argument("--no-federated", action="store_true")
    ap.add_argument("--no-learn", action="store_true")
    ap.add_argument("--driver", choices=("scan", "reference"), default="scan")
    ap.add_argument("--mesh", choices=("none", "debug", "production"),
                    default="none")
    ap.add_argument("--env-backend", choices=BACKENDS, default="fluid",
                    help="environment the CRL episodes run in: the fluid "
                         "MDP or the request-level digital twin")
    ap.add_argument("--scenario", choices=SCENARIOS, default="nominal",
                    help="workload scenario for the training traces "
                         "(default: the historical make_trace workload — "
                         "same seed reproduces pre-scenario-library runs)")
    ap.add_argument("--dt", type=float, default=0.05,
                    help="twin microtick length (s)")
    ap.add_argument("--k-ticks", type=int, default=20,
                    help="twin microticks per control interval")
    ap.add_argument("--ring", type=int, default=512,
                    help="twin ring capacity (power of two)")
    ap.add_argument("--pallas", action="store_true",
                    help="route the twin data plane through the fused "
                         "Pallas queue_advance kernel")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.episodes < 1:
        ap.error("--episodes must be >= 1")
    if args.fl_every is not None and args.fl_every < 1:
        ap.error("--fl-every must be >= 1 (use --no-federated to disable FL)")
    if args.ring <= 0 or args.ring & (args.ring - 1):
        ap.error("--ring must be a positive power of two")
    if args.env_backend == "fluid" and (
            args.pallas or args.dt != 0.05 or args.k_ticks != 20
            or args.ring != 512):
        ap.error("--pallas/--dt/--k-ticks/--ring configure the twin data "
                 "plane and are silent no-ops on the fluid backend; add "
                 "--env-backend twin")

    cfg = FCPOConfig() if args.fl_every is None else \
        FCPOConfig(fl_every=args.fl_every)
    backend = get_backend(args.env_backend,
                          sim_params=SimParams(dt=args.dt,
                                               k_ticks=args.k_ticks,
                                               ring=args.ring),
                          use_pallas=args.pallas)
    mesh = None
    if args.mesh == "debug":
        mesh = make_debug_mesh(jax.device_count(), 1)
    elif args.mesh == "production":
        mesh = make_production_mesh(multi_pod=args.pods > 1)

    fleet = fleet_init(cfg, args.agents, jax.random.PRNGKey(args.seed),
                       n_pods=args.pods, mesh=mesh, env_backend=backend)
    traces = make_scenario(args.scenario, jax.random.PRNGKey(args.seed + 1),
                           args.agents, args.episodes * cfg.n_steps)
    print(f"fleet: {args.agents} iAgents, {args.pods} pods, "
          f"{args.episodes} episodes, driver={args.driver}, "
          f"env={backend.name}, scenario={args.scenario}, "
          f"mesh={args.mesh}, backend={jax.default_backend()}")

    kw = dict(learn=not args.no_learn, federated=not args.no_federated,
              straggler_prob=args.straggler_prob, seed=args.seed,
              env_backend=backend)
    t0 = time.time()
    if args.driver == "scan":
        fleet, hist = train_fleet_scan(cfg, fleet, traces, mesh=mesh, **kw)
    else:
        fleet, hist = train_fleet_reference(cfg, fleet, traces, **kw)
    wall = time.time() - t0

    k = max(args.episodes // 10, 1)
    print(f"\nwall {wall:.2f}s  ({wall / args.episodes * 1e3:.1f} ms/episode "
          f"incl. compile)")
    print(f"{'':24s}{'first ' + str(k) + ' eps':>16s}{'last ' + str(k) + ' eps':>16s}")
    for key, scale, unit in (("reward", 1, ""), ("throughput", 1, "/s"),
                             ("effective_throughput", 1, "/s"),
                             ("latency", 1e3, "ms"), ("gated", 1, "")):
        a, b = hist[key][:k].mean() * scale, hist[key][-k:].mean() * scale
        print(f"{key:24s}{a:12.3f}{unit:4s}{b:12.3f}{unit}")
    return fleet, hist


if __name__ == "__main__":
    main()
