"""Fleet training launcher — the scanned FCPO driver from the CLI.

Runs the full federated-continual cadence (CRL episodes -> Eq. 7 selection ->
Alg. 1 aggregation -> Alg. 2 fine-tune -> hierarchical pod merge) as ONE
compiled program via ``train_fleet_scan``. ``--driver reference`` selects the
Python-loop oracle for A/B timing; ``--mesh`` installs the fleet shardings
(agents over ``data``, pods over the FL hierarchy) so the same command is
SPMD on a real mesh.

Examples:
  PYTHONPATH=src python -m repro.launch.train_fleet --agents 8 --pods 2 \
      --episodes 200
  PYTHONPATH=src python -m repro.launch.train_fleet --agents 16 --episodes 100 \
      --straggler-prob 0.3 --driver reference   # O(n_episodes) dispatches
  PYTHONPATH=src python -m repro.launch.train_fleet --agents 8 --mesh debug
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs.fcpo import FCPOConfig
from repro.core.fleet import (fleet_init, train_fleet_reference,
                              train_fleet_scan)
from repro.data.workload import fleet_traces
from repro.launch.mesh import make_debug_mesh, make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--episodes", type=int, default=200)
    ap.add_argument("--fl-every", type=int, default=None,
                    help="override cfg.fl_every")
    ap.add_argument("--straggler-prob", type=float, default=0.0)
    ap.add_argument("--no-federated", action="store_true")
    ap.add_argument("--no-learn", action="store_true")
    ap.add_argument("--driver", choices=("scan", "reference"), default="scan")
    ap.add_argument("--mesh", choices=("none", "debug", "production"),
                    default="none")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.episodes < 1:
        ap.error("--episodes must be >= 1")
    if args.fl_every is not None and args.fl_every < 1:
        ap.error("--fl-every must be >= 1 (use --no-federated to disable FL)")

    cfg = FCPOConfig() if args.fl_every is None else \
        FCPOConfig(fl_every=args.fl_every)
    mesh = None
    if args.mesh == "debug":
        mesh = make_debug_mesh(jax.device_count(), 1)
    elif args.mesh == "production":
        mesh = make_production_mesh(multi_pod=args.pods > 1)

    fleet = fleet_init(cfg, args.agents, jax.random.PRNGKey(args.seed),
                       n_pods=args.pods, mesh=mesh)
    traces = fleet_traces(jax.random.PRNGKey(args.seed + 1), args.agents,
                          args.episodes * cfg.n_steps)
    print(f"fleet: {args.agents} iAgents, {args.pods} pods, "
          f"{args.episodes} episodes, driver={args.driver}, "
          f"mesh={args.mesh}, backend={jax.default_backend()}")

    kw = dict(learn=not args.no_learn, federated=not args.no_federated,
              straggler_prob=args.straggler_prob, seed=args.seed)
    t0 = time.time()
    if args.driver == "scan":
        fleet, hist = train_fleet_scan(cfg, fleet, traces, mesh=mesh, **kw)
    else:
        fleet, hist = train_fleet_reference(cfg, fleet, traces, **kw)
    wall = time.time() - t0

    k = max(args.episodes // 10, 1)
    print(f"\nwall {wall:.2f}s  ({wall / args.episodes * 1e3:.1f} ms/episode "
          f"incl. compile)")
    print(f"{'':24s}{'first ' + str(k) + ' eps':>16s}{'last ' + str(k) + ' eps':>16s}")
    for key, scale, unit in (("reward", 1, ""), ("throughput", 1, "/s"),
                             ("effective_throughput", 1, "/s"),
                             ("latency", 1e3, "ms"), ("gated", 1, "")):
        a, b = hist[key][:k].mean() * scale, hist[key][-k:].mean() * scale
        print(f"{key:24s}{a:12.3f}{unit:4s}{b:12.3f}{unit}")
    return fleet, hist


if __name__ == "__main__":
    main()
