"""Fleet training launcher — the scanned FCPO driver from the CLI.

Runs the full federated-continual cadence (CRL episodes -> Eq. 7 selection ->
Alg. 1 aggregation -> Alg. 2 fine-tune -> hierarchical pod merge) as ONE
compiled program via ``train_fleet_scan``. ``--driver reference`` selects the
Python-loop oracle for A/B timing; ``--mesh`` installs the fleet shardings
(agents over ``data``, pods over the FL hierarchy) so the same command is
SPMD on a real mesh; ``--env-backend twin`` trains in the request-level
digital twin ("train where you serve") with K nested microticks per control
interval — still one jitted scan; ``--scenario`` picks the workload from the
scenario library (``repro.sim.scenarios``).

Examples:
  PYTHONPATH=src python -m repro.launch.train_fleet --agents 8 --pods 2 \
      --episodes 200
  PYTHONPATH=src python -m repro.launch.train_fleet --agents 8 --episodes 100 \
      --env-backend twin --scenario switching    # train in the twin
  PYTHONPATH=src python -m repro.launch.train_fleet --agents 16 --episodes 100 \
      --straggler-prob 0.3 --driver reference   # O(n_episodes) dispatches
  PYTHONPATH=src python -m repro.launch.train_fleet --agents 8 --episodes 100 \
      --fl-codec int8 --fl-deadline-s 0.02 --fl-async  # compressed async FL
  PYTHONPATH=src python -m repro.launch.train_fleet --agents 8 --mesh debug
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.train_fleet --agents 64 --pods 2 \
      --mesh fleet --state-dtype lean   # SPMD fleet mesh + lean state

``--fl-codec/--fl-deadline-s/--fl-async`` configure the federated transport
subsystem (``repro.fl``): compressed ``params - base`` deltas with error
feedback, uplink-time round deadlines (emergent stragglers), and
staleness-tolerant async rounds — all inside the same single jitted scan.

``--fault-*`` / ``--robust-agg`` configure the chaos layer
(``repro.resilience``): injected crashes / byzantine deltas / pod
partitions and the robust-aggregation defenses. ``--ckpt-dir`` +
``--ckpt-every`` add periodic checkpointing with auto-resume: a killed run
relaunched with the same command restarts from ``latest_step`` and
produces the same numbers as an uninterrupted run (straggler draws, fault
plans, and merge cadence all follow the absolute episode index).

  PYTHONPATH=src python -m repro.launch.train_fleet --agents 8 --episodes 100 \
      --fault-byzantine-frac 0.2 --robust-agg trimmed   # survive poison
  PYTHONPATH=src python -m repro.launch.train_fleet --agents 8 --episodes 100 \
      --ckpt-dir /tmp/run1 --ckpt-every 10              # kill-safe training
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.fcpo import FCPOConfig
from repro.core.backends import BACKENDS, get_backend
from repro.core.fleet import (fleet_device_bytes, fleet_init,
                              fleet_state_bytes, train_fleet_reference,
                              train_fleet_scan)
from repro.eval.stream import MetricsSink
from repro.fl import CODECS, TransportConfig
from repro.health import HealthConfig
from repro.health.alerts import AlertEngine
from repro.core.dtypes import POLICIES
from repro.launch.mesh import (make_debug_mesh, make_fleet_mesh,
                               make_production_mesh)
from repro.resilience import BYZANTINE_MODES, FaultConfig, GuardConfig
from repro.resilience.guards import AGG_METHODS
from repro.sim import SCENARIOS, SimParams, make_scenario
from repro.training import checkpoint as ckpt_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--episodes", type=int, default=200)
    ap.add_argument("--fl-every", type=int, default=None,
                    help="override cfg.fl_every")
    ap.add_argument("--straggler-prob", type=float, default=0.0,
                    help="probability an agent is offline for an FL round "
                         "(Bernoulli draw, the legacy straggler model). "
                         "Composes with the EMERGENT deadline stragglers of "
                         "--fl-deadline-s: an agent joins a round only if it "
                         "is Bernoulli-available AND its encoded upload fits "
                         "the deadline over its own link")
    ap.add_argument("--fl-codec", choices=CODECS, default="float32",
                    help="on-wire FL delta codec (repro.fl): float32 is the "
                         "lossless legacy path; int8/topk compress the "
                         "params-base delta with error feedback")
    ap.add_argument("--fl-topk-frac", type=float, default=0.05,
                    help="fraction of coordinates the topk codec keeps per "
                         "tensor")
    ap.add_argument("--fl-deadline-s", type=float, default=0.0,
                    help="FL round deadline (s); uplink time = encoded "
                         "payload bits / per-agent bandwidth, so slow links "
                         "emergently miss rounds. <= 0 disables")
    ap.add_argument("--fl-async", action="store_true",
                    help="staleness-tolerant rounds: a selected client that "
                         "misses the deadline parks its encoded delta and "
                         "joins the next round staleness-discounted")
    ap.add_argument("--fl-pallas", action="store_true",
                    help="route the delta codec through the fused Pallas "
                         "delta_codec kernel")
    ap.add_argument("--no-federated", action="store_true")
    ap.add_argument("--no-learn", action="store_true")
    ap.add_argument("--driver", choices=("scan", "reference"), default="scan")
    ap.add_argument("--mesh", choices=("none", "debug", "production",
                                       "fleet"),
                    default="none",
                    help="fleet = the scaling mesh: ('pod', 'data') over "
                         "every visible device, pods over the FL-hierarchy "
                         "axis (simulate multi-device on CPU with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    ap.add_argument("--state-dtype", choices=tuple(POLICIES), dest="state_dtype",
                    default="float32",
                    help="per-agent stored-state precision policy "
                         "(repro.core.dtypes): float32 is the bit-identical "
                         "legacy layout; bf16 halves optimizer/env/transport "
                         "state; lean adds int8 replay payloads + bf16 "
                         "params for ~2x peak-memory at A=2048. All math "
                         "still runs in float32")
    ap.add_argument("--env-backend", choices=BACKENDS, default="fluid",
                    help="environment the CRL episodes run in: the fluid "
                         "MDP or the request-level digital twin")
    ap.add_argument("--scenario", choices=SCENARIOS, default="nominal",
                    help="workload scenario for the training traces "
                         "(default: the historical make_trace workload — "
                         "same seed reproduces pre-scenario-library runs)")
    ap.add_argument("--dt", type=float, default=0.05,
                    help="twin microtick length (s)")
    ap.add_argument("--k-ticks", type=int, default=20,
                    help="twin microticks per control interval")
    ap.add_argument("--ring", type=int, default=512,
                    help="twin ring capacity (power of two)")
    ap.add_argument("--pallas", action="store_true",
                    help="route the twin data plane through the fused "
                         "Pallas queue_advance kernel")
    ap.add_argument("--metrics-out", type=str, default=None,
                    help="stream per-episode metrics (reward, "
                         "fl_payload_bytes, miss/stale rates, ...) to this "
                         "JSONL file while training runs; tail it live with "
                         "python -m repro.launch.watch <file> --follow")
    ap.add_argument("--trace-out", type=str, default=None,
                    help="flight recorder: record phase spans (episode, "
                         "fl_round encode/uplink/aggregate, pod merge) "
                         "from inside the compiled run and write Chrome "
                         "trace-event JSON here (open in Perfetto)")
    ap.add_argument("--trace-sample", type=int, default=1,
                    help="record spans only on every Nth episode (runtime "
                         "sampling — changing it never recompiles)")
    # --- fleet health observatory (repro.health) ---
    ap.add_argument("--health", action="store_true",
                    help="attach the fleet health observatory: per-agent "
                         "telemetry sketches + drift detectors advanced "
                         "inside the scan, FL contribution attribution per "
                         "round; per-episode health_* summaries join the "
                         "history and the --metrics-out stream")
    ap.add_argument("--health-bins", type=int, default=16,
                    help="histogram sketch resolution (quantile error is "
                         "bounded by one bin width)")
    ap.add_argument("--susp-threshold", type=float, default=0.0,
                    help="act on the attribution evidence: clients whose "
                         "suspicion EMA exceeds this are dropped from Eq. 7 "
                         "selection (one round behind by construction). "
                         "0 observes without acting; requires --health")
    ap.add_argument("--alerts-out", type=str, default=None,
                    help="evaluate the declarative health alert rules "
                         "(repro.health.alerts.DEFAULT_RULES) over the "
                         "metrics stream and write fire/resolve lines to "
                         "this ALERTS.jsonl; requires --health")
    # --- chaos layer: fault injection (repro.resilience.FaultConfig) ---
    ap.add_argument("--fault-crash-prob", type=float, default=0.0,
                    help="per-agent per-episode crash probability: the "
                         "agent's state freezes (params zeroed), it leaves "
                         "episodes and Eq. 7 selection for "
                         "--fault-crash-recovery episodes, then rejoins "
                         "warm-started from its pod base network. Unlike "
                         "--straggler-prob (one missed FL round, Bernoulli "
                         "per round) a crash is a multi-episode outage")
    ap.add_argument("--fault-crash-recovery", type=int, default=2,
                    help="episodes a crashed agent stays down")
    ap.add_argument("--fault-byzantine-frac", type=float, default=0.0,
                    help="per-agent per-round probability of shipping a "
                         "corrupted delta (applied post-codec, so it "
                         "composes with --fl-codec int8/topk)")
    ap.add_argument("--fault-byzantine-mode", choices=BYZANTINE_MODES,
                    default="sign_flip",
                    help="corruption: sign_flip (scaled negation), noise "
                         "(additive gaussian), nan (poisoned upload)")
    ap.add_argument("--fault-byzantine-scale", type=float, default=10.0,
                    help="magnitude of sign_flip/noise corruption")
    ap.add_argument("--fault-partition-prob", type=float, default=0.0,
                    help="per-pod probability, at each hierarchical merge, "
                         "of dropping off the cloud tier for "
                         "--fault-partition-merges merge events")
    ap.add_argument("--fault-partition-merges", type=int, default=1,
                    help="merge events a partitioned pod skips")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the fault plan (independent of --seed so "
                         "the same workload can be replayed under different "
                         "fault draws)")
    # --- chaos layer: defenses (repro.resilience.GuardConfig) ---
    ap.add_argument("--robust-agg", choices=AGG_METHODS, default="mean",
                    help="Algorithm 1 statistic: mean is the paper's "
                         "aggregation (bit-identical legacy path); trimmed/"
                         "median are coordinate-wise robust variants that "
                         "bound byzantine influence. Composes with "
                         "--straggler-prob and --fl-deadline-s: the robust "
                         "statistic runs over whatever clients survived "
                         "availability + deadline selection")
    ap.add_argument("--trim-frac", type=float, default=0.2,
                    help="per-side trim fraction of the trimmed-mean "
                         "aggregator (in [0, 0.5))")
    ap.add_argument("--clip-factor", type=float, default=0.0,
                    help="clip each client delta leaf to this multiple of "
                         "the selected-client median leaf norm; 0 disables")
    ap.add_argument("--no-reject-nonfinite", action="store_true",
                    help="disable the NaN/Inf contribution rejection "
                         "(on by default; only useful for demonstrating "
                         "what poison does to an unguarded fleet)")
    # --- periodic checkpoint + auto-resume ---
    ap.add_argument("--ckpt-dir", type=str, default=None,
                    help="checkpoint directory (training.checkpoint "
                         "layout). If it already holds checkpoints, the run "
                         "AUTO-RESUMES from latest_step and reproduces the "
                         "uninterrupted run's numbers exactly")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save a checkpoint every N episodes (requires "
                         "--ckpt-dir; 0 saves only at the end of the run)")
    ap.add_argument("--keep-last", type=int, default=3,
                    help="prune all but the newest N checkpoints after "
                         "every save")
    ap.add_argument("--stop-after", type=int, default=0,
                    help="exit after this many episodes of THIS invocation "
                         "(kill-and-resume drills; requires --ckpt-dir). "
                         "0 disables")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.episodes < 1:
        ap.error("--episodes must be >= 1")
    if args.fl_every is not None and args.fl_every < 1:
        ap.error("--fl-every must be >= 1 (use --no-federated to disable FL)")
    if args.ring <= 0 or args.ring & (args.ring - 1):
        ap.error("--ring must be a positive power of two")
    if args.env_backend == "fluid" and (
            args.pallas or args.dt != 0.05 or args.k_ticks != 20
            or args.ring != 512):
        ap.error("--pallas/--dt/--k-ticks/--ring configure the twin data "
                 "plane and are silent no-ops on the fluid backend; add "
                 "--env-backend twin")

    if args.fl_async and args.fl_deadline_s <= 0:
        ap.error("--fl-async parks deadline-missed uploads and needs "
                 "--fl-deadline-s > 0 to ever have one")
    if args.fl_pallas and args.fl_codec == "float32":
        ap.error("--fl-pallas routes the delta codec through the fused "
                 "kernel, but the float32 codec skips the codec entirely "
                 "(lossless identity path); add --fl-codec int8 or topk")
    if args.fl_topk_frac != 0.05 and args.fl_codec != "topk":
        ap.error("--fl-topk-frac only affects the topk codec; add "
                 "--fl-codec topk")
    if args.ckpt_every and not args.ckpt_dir:
        ap.error("--ckpt-every needs --ckpt-dir")
    if args.stop_after and not args.ckpt_dir:
        ap.error("--stop-after simulates a kill mid-run and only makes "
                 "sense with --ckpt-dir (nothing would survive otherwise)")
    if args.ckpt_dir and args.driver == "reference":
        ap.error("--ckpt-dir periodic checkpointing drives the scan "
                 "driver; drop --driver reference")
    if args.ckpt_every < 0 or args.stop_after < 0 or args.keep_last < 1:
        ap.error("--ckpt-every/--stop-after must be >= 0, --keep-last >= 1")
    if args.trace_sample < 1:
        ap.error("--trace-sample must be >= 1")
    if args.susp_threshold and not args.health:
        ap.error("--susp-threshold gates selection on the suspicion EMA "
                 "the observatory maintains; add --health")
    if args.alerts_out and not args.health:
        ap.error("--alerts-out evaluates rules over the health_* metrics; "
                 "add --health")
    if args.health_bins != 16 and not args.health:
        ap.error("--health-bins only affects the observatory; add --health")

    cfg = FCPOConfig() if args.fl_every is None else \
        FCPOConfig(fl_every=args.fl_every)
    faults = FaultConfig(
        crash_prob=args.fault_crash_prob,
        crash_recovery=args.fault_crash_recovery,
        byzantine_frac=args.fault_byzantine_frac,
        byzantine_mode=args.fault_byzantine_mode,
        byzantine_scale=args.fault_byzantine_scale,
        partition_prob=args.fault_partition_prob,
        partition_merges=args.fault_partition_merges,
        seed=args.fault_seed)
    guards = GuardConfig(agg=args.robust_agg, trim_frac=args.trim_frac,
                         clip_factor=args.clip_factor,
                         reject_nonfinite=not args.no_reject_nonfinite,
                         susp_threshold=args.susp_threshold)
    health = HealthConfig(bins=args.health_bins) if args.health else None
    transport = TransportConfig(codec=args.fl_codec,
                                topk_frac=args.fl_topk_frac,
                                deadline_s=args.fl_deadline_s,
                                async_rounds=args.fl_async,
                                use_pallas=args.fl_pallas)
    backend = get_backend(args.env_backend,
                          sim_params=SimParams(dt=args.dt,
                                               k_ticks=args.k_ticks,
                                               ring=args.ring),
                          use_pallas=args.pallas)
    mesh = None
    if args.mesh == "debug":
        mesh = make_debug_mesh(jax.device_count(), 1)
    elif args.mesh == "production":
        mesh = make_production_mesh(multi_pod=args.pods > 1)
    elif args.mesh == "fleet":
        mesh = make_fleet_mesh(jax.device_count(), args.pods)

    fleet = fleet_init(cfg, args.agents, jax.random.PRNGKey(args.seed),
                       n_pods=args.pods, mesh=mesh, env_backend=backend,
                       state_policy=(args.state_dtype
                                     if args.state_dtype != "float32"
                                     else None),
                       health=health)
    traces = make_scenario(args.scenario, jax.random.PRNGKey(args.seed + 1),
                           args.agents, args.episodes * cfg.n_steps)
    print(f"fleet: {args.agents} iAgents, {args.pods} pods, "
          f"{args.episodes} episodes, driver={args.driver}, "
          f"env={backend.name}, scenario={args.scenario}, "
          f"mesh={args.mesh}, state_dtype={args.state_dtype}, "
          f"backend={jax.default_backend()} "
          f"({jax.device_count()} devices)")

    kw = dict(learn=not args.no_learn, federated=not args.no_federated,
              straggler_prob=args.straggler_prob, seed=args.seed,
              env_backend=backend, transport=transport,
              faults=faults if faults.active else None, guards=guards,
              health=health)
    # detect the auto-resume BEFORE opening the metrics sink: a resumed run
    # must append to the metrics file, not truncate the pre-kill episodes
    resume_from = (ckpt_mod.latest_step(args.ckpt_dir) or 0) \
        if args.ckpt_dir else 0
    sink = None
    if args.metrics_out:
        sink = MetricsSink(args.metrics_out, meta=dict(
            agents=args.agents, pods=args.pods, episodes=args.episodes,
            driver=args.driver, env_backend=backend.name,
            scenario=args.scenario, fl_codec=args.fl_codec,
            robust_agg=args.robust_agg, seed=args.seed),
            resume=resume_from > 0)
        if resume_from > 0 and sink.n_records:
            print(f"metrics resume: appending to {args.metrics_out} "
                  f"({sink.n_records} episodes already recorded)")
        kw["metrics_sink"] = sink
    engine = None
    if args.alerts_out:
        # the alert engine tees in front of the JSONL sink (or runs
        # standalone without --metrics-out): every streamed record is
        # forwarded AND evaluated against the rulebook
        engine = AlertEngine(args.alerts_out, forward=sink)
        kw["metrics_sink"] = engine
    tracer = None
    if args.trace_out:
        from repro.obs.trace import Tracer

        tracer = Tracer(span_sample_every=args.trace_sample)
        kw["tracer"] = tracer
    t0 = time.time()
    try:
        if args.ckpt_dir:
            # Periodic checkpointing + auto-resume. The full traces cover
            # [0, episodes); each chunk replays its slice with the absolute
            # episode_offset so straggler draws, fault plans, and merge
            # cadence match the uninterrupted run exactly.
            start = resume_from
            if start >= args.episodes:
                print(f"checkpoint step {start} >= --episodes "
                      f"{args.episodes}: run already complete, nothing to do")
                return fleet, {}
            if start > 0:
                fleet, _ = ckpt_mod.restore(args.ckpt_dir, start, fleet)
                print(f"auto-resume: restored episode {start} from "
                      f"{args.ckpt_dir}")
            chunk = args.ckpt_every or (args.episodes - start)
            hists, e, done_here = [], start, 0
            while e < args.episodes:
                n = min(chunk, args.episodes - e)
                if args.stop_after:
                    n = min(n, args.stop_after - done_here)
                tr = traces[:, e * cfg.n_steps:(e + n) * cfg.n_steps]
                fleet, h = train_fleet_scan(cfg, fleet, tr, mesh=mesh,
                                            episode_offset=e,
                                            total_episodes=args.episodes,
                                            **kw)
                hists.append(h)
                e += n
                done_here += n
                ckpt_mod.save(args.ckpt_dir, e, fleet, extra=dict(
                    episodes=args.episodes, agents=args.agents,
                    pods=args.pods, seed=args.seed,
                    scenario=args.scenario))
                ckpt_mod.keep_last(args.ckpt_dir, args.keep_last)
                if args.stop_after and done_here >= args.stop_after:
                    print(f"--stop-after {args.stop_after}: stopping at "
                          f"episode {e}/{args.episodes} (rerun the same "
                          f"command to resume)")
                    break
            hist = {k: np.concatenate([np.asarray(h[k]) for h in hists])
                    for k in hists[0]}
        elif args.driver == "scan":
            fleet, hist = train_fleet_scan(cfg, fleet, traces, mesh=mesh,
                                           **kw)
        else:
            fleet, hist = train_fleet_reference(cfg, fleet, traces, **kw)
        wall = time.time() - t0
        if sink is not None:
            # one trailing scaling record (same sink, same JSONL protocol):
            # wall-clock step time + where the fleet state actually landed,
            # device by device — launch/watch.py renders it as the scaling row
            n_rec = len(np.asarray(hist["reward"]))
            row = {"devices": float(mesh.size if mesh is not None else 1),
                   "agents": float(args.agents),
                   "step_time_s": wall / max(n_rec, 1),
                   "step_time_per_agent_s":
                       wall / max(n_rec, 1) / max(args.agents, 1),
                   "state_bytes_per_agent":
                       fleet_state_bytes(fleet)["per_agent"]}
            for d, b in sorted(fleet_device_bytes(fleet).items()):
                row[f"dev{d}_bytes"] = b
            sink.append(row)
    finally:
        if engine is not None:
            engine.close()  # closes the forwarded sink too
        elif sink is not None:
            sink.close()
        if tracer is not None:
            tracer.export(args.trace_out)
            print(f"flight recorder: "
                  f"{len(tracer.chrome_events())} span events -> "
                  f"{args.trace_out} (open in Perfetto)")
            tracer.close()

    n_run = len(np.asarray(hist["reward"]))
    k = max(n_run // 10, 1)
    print(f"\nwall {wall:.2f}s  ({wall / n_run * 1e3:.1f} ms/episode "
          f"incl. compile)")
    print(f"{'':24s}{'first ' + str(k) + ' eps':>16s}{'last ' + str(k) + ' eps':>16s}")
    for key, scale, unit in (("reward", 1, ""), ("throughput", 1, "/s"),
                             ("effective_throughput", 1, "/s"),
                             ("latency", 1e3, "ms"), ("gated", 1, "")):
        a, b = hist[key][:k].mean() * scale, hist[key][-k:].mean() * scale
        print(f"{key:24s}{a:12.3f}{unit:4s}{b:12.3f}{unit}")

    fl_eps = np.flatnonzero(hist.get("fl_payload_bytes", np.zeros(1)))
    if fl_eps.size:
        print(f"\nFL transport (codec={args.fl_codec}, "
              f"deadline={args.fl_deadline_s}s, async={args.fl_async}): "
              f"{fl_eps.size} rounds, "
              f"{hist['fl_payload_bytes'][fl_eps].mean() / 1024:.1f} KB/round, "
              f"uplink {hist['fl_uplink_s'][fl_eps].mean() * 1e3:.1f} ms, "
              f"missed {hist['fl_missed'][fl_eps].mean():.2f}/round, "
              f"stale joins {hist['fl_stale_used'][fl_eps].mean():.2f}/round, "
              f"rejected {np.asarray(hist.get('fl_rejected', 0.0)).sum():.0f}, "
              f"clipped {np.asarray(hist.get('fl_clipped', 0.0)).sum():.0f}")
    if health is not None and "health_drift_score" in hist:
        flags = np.asarray(hist["health_drift_flag"])
        print(f"\nhealth: drift flags on {np.count_nonzero(flags)} of "
              f"{flags.size} episodes, "
              f"drift score last {hist['health_drift_score'][-1]:.2f}, "
              f"reward p50 last {hist['health_reward_p50'][-1]:.3f}, "
              f"susp last {hist['health_susp'][-1]:.3f}"
              + (f"; {engine.n_alerts} alerts -> {args.alerts_out}"
                 if engine is not None else ""))
    if faults.active:
        print(f"\nchaos: crash_prob={faults.crash_prob}, "
              f"byzantine={faults.byzantine_frac} "
              f"({faults.byzantine_mode} x{faults.byzantine_scale}), "
              f"partition={faults.partition_prob}; defenses: "
              f"agg={guards.agg}, clip={guards.clip_factor}, "
              f"reject_nonfinite={guards.reject_nonfinite}; "
              f"update_rejected "
              f"{np.asarray(hist.get('update_rejected', 0.0)).sum():.0f}")
    return fleet, hist


if __name__ == "__main__":
    main()
