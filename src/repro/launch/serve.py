"""FCPO-controlled serving launcher — the paper's full system, end to end.

One process = one cluster: N replica engines (reduced model configs on CPU;
full configs on real pods), each piggybacked with an iAgent. Every control
interval the iAgent picks (RES bucket, BS bucket, MT in-flight); the engine
serves that configuration; metrics feed the reward; CRL updates run online;
an agent-specific FL round executes every ``fl_every`` episodes.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --replicas 4 --episodes 30
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.configs.fcpo import FCPOConfig
from repro.core.fleet import fleet_episode, fleet_init, fl_round
from repro.data.workload import fleet_traces
from repro.models.registry import get_model
from repro.serving.engine import ServingEngine


def calibrate_env_from_engine(engine: ServingEngine, cfg_f: FCPOConfig,
                              seq: int = 32):
    """Measure the engine's real (t0, t1) batching curve on this host and
    return EnvParams matching it — so the MDP the agents learn on IS this
    data plane's latency surface."""
    from repro.core.env import EnvParams

    vocab = engine.model.cfg.vocab_size
    times = {}
    for bs in (1, max(engine.batch_buckets)):
        tokens = jnp.zeros((bs, seq), jnp.int32) % vocab
        engine.prefill(tokens)  # warm compile
        t0 = time.perf_counter()
        for _ in range(3):
            engine.prefill(tokens)
        times[bs] = (time.perf_counter() - t0) / 3
    b_lo, b_hi = sorted(times)
    t1 = max((times[b_hi] - times[b_lo]) / (b_hi - b_lo), 1e-5)
    t0_fixed = max(times[b_lo] - t1 * b_lo, 1e-4)
    f = lambda x: jnp.asarray(x, jnp.float32)
    return EnvParams(t0=f(t0_fixed), t1=f(t1), pre_rate=f(400.0),
                     post_rate=f(500.0), contention=f(0.15),
                     queue_cap=f(128.0), slo_s=f(cfg_f.slo_s), net_lat=f(0.01))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--episodes", type=int, default=30)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--slo-ms", type=float, default=250.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServingEngine(model, params, max_cache_len=256,
                           batch_buckets=(1, 2, 4, 8), seq_buckets=(16, 32))

    cfg_f = FCPOConfig(slo_s=args.slo_ms / 1000.0)
    fleet = fleet_init(cfg_f, args.replicas, jax.random.PRNGKey(args.seed),
                       n_pods=args.pods, slo_s=cfg_f.slo_s)
    env_params = calibrate_env_from_engine(engine, cfg_f)
    fleet = fleet._replace(env_params=jax.tree.map(
        lambda x: jnp.broadcast_to(x, (args.replicas,)), env_params))
    print(f"calibrated latency model: t0={float(env_params.t0)*1e3:.1f}ms "
          f"t1={float(env_params.t1)*1e6:.0f}us/item")

    traces = fleet_traces(jax.random.PRNGKey(1), args.replicas,
                          args.episodes * cfg_f.n_steps)
    for e in range(args.episodes):
        rates = traces[:, e * cfg_f.n_steps:(e + 1) * cfg_f.n_steps]
        fleet, rollouts, metrics = fleet_episode(cfg_f, fleet, rates)
        if (e + 1) % cfg_f.fl_every == 0:
            fleet, sel, _ = fl_round(cfg_f, fleet, rollouts)
        # serve one real batch at the fleet's current best configuration
        a = np.asarray(rollouts.actions[0, -1])
        bs = cfg_f.bs_values[int(a[1])]
        bs = min(bs, max(engine.batch_buckets))
        tokens = jnp.zeros((bs, 16), jnp.int32)
        out = engine.generate(tokens, steps=2)
        print(f"ep {e + 1:3d} reward {float(metrics['reward'].mean()):+.3f} "
              f"eff_thr {float(metrics['effective_throughput'].mean()):6.1f} "
              f"lat {float(metrics['latency'].mean()) * 1e3:6.1f}ms "
              f"| served real batch bs={bs} -> {out.shape}", flush=True)
    print("done")


if __name__ == "__main__":
    main()
