"""Production mesh builders (functions, not module constants — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12      # per chip, FLOP/s
HBM_BW = 819e9                # per chip, B/s
ICI_BW = 50e9                 # per link, B/s


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (possibly fake) devices exist — for tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_fleet_mesh(n_devices: int = None, n_pods: int = 1):
    """The fleet-training (pod, data) mesh over ``n_devices`` (default: all
    visible — e.g. 8 under ``XLA_FLAGS=--xla_force_host_platform_device_count
    =8``). The ``pod`` axis mirrors the FL hierarchy: it takes ``n_pods``
    devices when that divides the device count (per-pod base networks then
    live one-pod-per-shard and the cloud merge is a cross-pod all-reduce);
    otherwise pods replicate and agents shard over ``data`` alone —
    ``greedy_spec`` falls through safely either way."""
    n = jax.device_count() if n_devices is None else n_devices
    pod = n_pods if n_pods > 0 and n % n_pods == 0 else 1
    return jax.make_mesh((pod, n // pod), ("pod", "data"))


def mesh_axis_size(mesh, name: str) -> int:
    return mesh.shape.get(name, 1)
