"""Standing evaluation harness — see docs/architecture.md, "Standing
evaluation".

``repro.eval.leaderboard`` scores any checkpoint across the full
scenario × backend × codec grid through the real production cadence
(``train_fleet_scan`` + ``sim.harness.eval_fleet``) and turns the results
into diffable ``BENCH_leaderboard.json`` envelopes with regression deltas
(``benchmarks/leaderboard.py`` is the CLI). ``repro.eval.stream`` is the
live-observability side: the JSONL ``MetricsSink`` both fleet drivers
accept and ``launch/watch.py`` reads.
"""
from repro.eval.leaderboard import (Cell, DEFAULT_TOL, GATE_METRICS,  # noqa: F401
                                    GRID_BACKENDS, GRID_CODECS,
                                    GRID_SCENARIOS, REPLICATES,
                                    attach_deltas, cell_seed,
                                    check_regressions, evaluate_cell,
                                    grid_cells, load_fleet, run_leaderboard)
from repro.eval.stream import (MetricsSink, fl_round_summary,  # noqa: F401
                               read_metrics, tail_summary)
