"""Policy leaderboard: every checkpoint scored on every cell of the
scenario × backend × codec grid.

FCPO's headline claims are *grid* claims — 5× effective throughput and 60%
latency reduction only mean something across workloads, environments, and
communication regimes. A leaderboard **cell** is one point of that grid:

    (scenario ∈ repro.sim.SCENARIOS)          — which workload
  × (backend  ∈ {fluid, twin})                — which environment the
                                                continual cadence adapts in
  × (codec    ∈ repro.fl.CODECS)              — which FL transport the
                                                rounds ship deltas over

Evaluating a checkpoint on a cell runs the *real* production cadence, not a
side-channel re-implementation: the checkpoint fleet (env states swapped for
the cell backend's) continually adapts over the cell scenario via
``train_fleet_scan`` — episodes → Eq. 7 selection → Alg. 1 aggregation →
Alg. 2 fine-tune, ONE jitted scan, with the cell codec's ``TransportConfig``
— and the adapted policies are then driven through the request-level twin
(``sim.harness.eval_fleet``) on a held-out trace of the same scenario for
request-grade metrics. Per cell × replicate that yields:

  * ``reward``            — adaptation reward (tail mean of the run history)
  * ``eval_eff``          — held-out twin effective throughput (req/s)
  * ``eval_p99``          — held-out twin p99 end-to-end latency (s)
  * ``eval_slo``          — held-out SLO attainment (effective/completed)
  * ``fl_payload_bytes``  — mean FL round payload under the cell codec

Replicates re-draw the workload and eval keys from deterministic per-cell
seeds (``cell_seed`` — a crc32 fold of the cell name, never Python's
randomized ``hash``), so every cell is a pure function of
(checkpoint, cell, seed, shapes): two runs — or any ``n_jobs`` interleaving
of cells — produce bit-identical metrics (tests/test_leaderboard.py).

``attach_deltas`` diffs a new row set against the previous
``BENCH_leaderboard.json`` envelope and ``check_regressions`` turns those
deltas into a CI gate: a cell whose reward or held-out effective throughput
fell beyond a per-cell tolerance fails the run (``benchmarks/leaderboard.py
--gate``). Reward and perf claims become diffable artifacts instead of
one-off benchmark runs.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.fcpo import FCPOConfig
from repro.core.backends import BACKENDS, get_backend
from repro.core.fleet import Fleet, fleet_init, train_fleet_scan
from repro.fl import CODECS, TransportConfig
from repro.sim import SCENARIOS, SimParams, make_scenario
from repro.sim.harness import eval_fleet
from repro.training import checkpoint as ckpt_mod

GRID_SCENARIOS: Tuple[str, ...] = SCENARIOS          # all 9 named workloads
GRID_BACKENDS: Tuple[str, ...] = BACKENDS            # fluid | twin
GRID_CODECS: Tuple[str, ...] = CODECS                # float32 | int8 | topk
REPLICATES = 3

# higher-is-better metrics the regression gate watches, with an absolute
# floor so near-zero baselines don't turn the relative tolerance into a
# zero-width band (reward sits in [-1, 1]; throughput in req/s).
GATE_METRICS: Dict[str, float] = {"reward_mean": 0.05, "eval_eff_mean": 1.0}
# informational deltas carried in the envelope alongside the gated ones
DELTA_KEYS: Tuple[str, ...] = ("reward_mean", "eval_eff_mean",
                               "eval_p99_mean", "eval_slo_mean",
                               "fl_payload_bytes")
DEFAULT_TOL = 0.10
# hist_n=128 keeps held-out p99 uncensored out to 6.35 s — untrained tails
# on ood/switching exceed the default 3.15 s cap (same as fig_twin_training)
EVAL_SP = SimParams(hist_n=128)


@dataclass(frozen=True)
class Cell:
    scenario: str
    backend: str
    codec: str

    @property
    def name(self) -> str:
        return f"leaderboard_{self.scenario}_{self.backend}_{self.codec}"


def grid_cells(scenarios: Sequence[str] = GRID_SCENARIOS,
               backends: Sequence[str] = GRID_BACKENDS,
               codecs: Sequence[str] = GRID_CODECS) -> List[Cell]:
    """The (dense) grid, scenario-major — the canonical leaderboard order."""
    return [Cell(s, b, c) for s in scenarios for b in backends
            for c in codecs]


def cell_seed(base_seed: int, cell: Cell, rep: int, tag: str = "") -> int:
    """Deterministic per-(cell, replicate, stream) seed. crc32, not
    ``hash()`` — Python string hashing is salted per process, which would
    silently break run-to-run determinism."""
    token = f"{cell.scenario}|{cell.backend}|{cell.codec}|{rep}|{tag}"
    return int((base_seed + zlib.crc32(token.encode())) % (2 ** 31 - 1))


def _with_env_states(cfg: FCPOConfig, fleet: Fleet, backend) -> Fleet:
    """The checkpoint's policies/optimizers/buffers with FRESH env states of
    the cell backend — a fluid-trained checkpoint is evaluable in the twin
    (and vice versa) because the 8-dim observation has one definition."""
    a = fleet.pod_ids.shape[0]
    states = jax.vmap(lambda _: backend.init(cfg))(jnp.arange(a))
    return fleet._replace(astate=fleet.astate._replace(env_state=states))


def evaluate_cell(cfg: FCPOConfig, fleet: Fleet, cell: Cell, *,
                  episodes: int = 6, eval_intervals: int = 30,
                  replicates: int = REPLICATES, seed: int = 0,
                  sim_params: Optional[SimParams] = None,
                  eval_sp: SimParams = EVAL_SP) -> Dict[str, Any]:
    """Score one checkpoint on one grid cell.

    ``episodes`` of the full continual cadence (FL rounds under the cell
    codec included) on the cell scenario/backend, then a held-out
    request-grade twin evaluation — per replicate. Returns the per-cell row:
    mean ± std over replicates for every metric, plus the raw per-replicate
    values (``*_reps``) so downstream tooling can re-aggregate."""
    backend = get_backend(cell.backend, sim_params=sim_params)
    transport = TransportConfig(codec=cell.codec)
    a = fleet.pod_ids.shape[0]
    tail = max(episodes // 2, 1)
    reps: Dict[str, List[float]] = {k: [] for k in
                                    ("reward", "train_eff", "eval_eff",
                                     "eval_p99", "eval_slo", "payload")}
    for r in range(replicates):
        s = cell_seed(seed, cell, r)
        f = _with_env_states(cfg, fleet, backend)
        traces = make_scenario(cell.scenario, jax.random.PRNGKey(s), a,
                               episodes * cfg.n_steps)
        f, hist = train_fleet_scan(cfg, f, traces, env_backend=backend,
                                   transport=transport, seed=s, donate=False)
        fl_eps = np.flatnonzero(hist["fl_payload_bytes"])
        reps["reward"].append(float(np.mean(hist["reward"][-tail:])))
        reps["train_eff"].append(
            float(np.mean(hist["effective_throughput"][-tail:])))
        reps["payload"].append(
            float(hist["fl_payload_bytes"][fl_eps].mean()) if fl_eps.size
            else 0.0)

        ev = make_scenario(cell.scenario,
                           jax.random.PRNGKey(cell_seed(seed, cell, r,
                                                        "eval")),
                           a, eval_intervals)
        _, _, summ = eval_fleet(cfg, eval_sp, f, ev,
                                jax.random.PRNGKey(cell_seed(seed, cell, r,
                                                             "key")))
        reps["eval_eff"].append(
            float(np.asarray(summ["effective_throughput"]).mean()))
        reps["eval_p99"].append(
            float(np.asarray(summ["p99_latency_s"]).mean()))
        reps["eval_slo"].append(
            float(np.asarray(summ["slo_attainment"]).mean()))

    row: Dict[str, Any] = {
        "name": cell.name,
        "scenario": cell.scenario, "env_backend": cell.backend,
        "codec": cell.codec, "agents": a, "episodes": episodes,
        "eval_intervals": eval_intervals, "replicates": replicates,
        "seed": seed,
    }
    for key, out in (("reward", "reward"), ("train_eff", "train_eff"),
                     ("eval_eff", "eval_eff"), ("eval_p99", "eval_p99"),
                     ("eval_slo", "eval_slo")):
        row[f"{out}_mean"] = float(np.mean(reps[key]))
        row[f"{out}_std"] = float(np.std(reps[key]))
        row[f"{out}_reps"] = reps[key]
    row["fl_payload_bytes"] = float(np.mean(reps["payload"]))
    return row


def run_leaderboard(cfg: FCPOConfig, fleet: Fleet,
                    cells: Optional[Iterable[Cell]] = None, *,
                    episodes: int = 6, eval_intervals: int = 30,
                    replicates: int = REPLICATES, seed: int = 0,
                    sim_params: Optional[SimParams] = None,
                    eval_sp: SimParams = EVAL_SP, n_jobs: int = 1,
                    log=None) -> List[Dict[str, Any]]:
    """Score a checkpoint over a cell list (default: the full grid).

    ``n_jobs`` round-robins the cells into that many stripes and evaluates
    stripe-by-stripe — a deterministic *reordering* only (each cell's seeds
    are self-contained, so metrics are bit-identical for any ``n_jobs``;
    asserted in tests/test_leaderboard.py). Rows come back in the input
    cell order regardless."""
    cells = list(grid_cells() if cells is None else cells)
    if n_jobs < 1:
        raise ValueError(f"n_jobs must be >= 1, got {n_jobs}")
    order = [i for j in range(n_jobs) for i in range(j, len(cells), n_jobs)]
    rows: Dict[int, Dict[str, Any]] = {}
    for i in order:
        rows[i] = evaluate_cell(cfg, fleet, cells[i], episodes=episodes,
                                eval_intervals=eval_intervals,
                                replicates=replicates, seed=seed,
                                sim_params=sim_params, eval_sp=eval_sp)
        if log is not None:
            r = rows[i]
            log(f"{r['name']}: reward={r['reward_mean']:+.3f} "
                f"eff={r['eval_eff_mean']:.2f}/s "
                f"p99={r['eval_p99_mean'] * 1e3:.0f}ms "
                f"slo={r['eval_slo_mean'] * 100:.0f}% "
                f"payload={r['fl_payload_bytes'] / 1024:.1f}KB")
    return [rows[i] for i in range(len(cells))]


# ---------------------------------------------------------------------------
# Envelope deltas + the regression gate
# ---------------------------------------------------------------------------
# fields that must agree between a row and its previous measurement for the
# comparison to mean anything — a changed shape (different agent count,
# episode budget, replicate count, ...) is an incompatible grid, and gating
# against it would flag phantom regressions
COMPAT_KEYS: Tuple[str, ...] = ("agents", "episodes", "eval_intervals",
                                "replicates", "seed")


def sanitize_envelope(prev_envelope, warn=None):
    """Defensive read of a previous ``BENCH_leaderboard*.json`` envelope.

    Returns the envelope when it is usable (a dict whose ``results`` is a
    list) and None otherwise — a missing, truncated, or non-envelope file
    degrades the gate to "no baseline" with a warning instead of crashing
    CI. An envelope measured on a DIFFERENT jax backend or device count
    (``save_bench`` stamps both) is also refused: timings and memory moved
    for hardware reasons, so gating against it would flag phantom
    regressions (or hide real ones) on cross-backend noise. ``warn`` is an
    optional ``print``-like callable."""
    if prev_envelope is None:
        return None
    if (not isinstance(prev_envelope, dict)
            or not isinstance(prev_envelope.get("results"), list)):
        if warn is not None:
            warn("leaderboard: previous envelope is not a results envelope "
                 "— treating as no baseline")
        return None
    import jax

    here = {"backend": jax.default_backend(),
            "device_count": jax.device_count()}
    for key, cur in here.items():
        prev = prev_envelope.get(key)
        # legacy envelopes (pre device_count stamp) pass: nothing to refuse
        if prev is not None and prev != cur:
            if warn is not None:
                warn(f"leaderboard: previous envelope is from {key}="
                     f"{prev!r} but this run is {key}={cur!r} — refusing "
                     f"the cross-backend diff, treating as no baseline")
            return None
    return prev_envelope


def _compatible(row, prev) -> bool:
    return all(prev.get(k) == row.get(k) for k in COMPAT_KEYS)


def attach_deltas(rows: List[Dict[str, Any]],
                  prev_envelope: Optional[Dict[str, Any]],
                  warn=None) -> List[Dict[str, Any]]:
    """Fold the previous envelope into ``rows`` (in place): for every cell
    present in both, ``prev_<k>`` and ``delta_<k>`` (new − prev) for each
    ``DELTA_KEYS`` metric. Cells with no previous measurement carry no
    delta fields — a grown grid is not a regression.

    Degrades gracefully: an unusable envelope (``sanitize_envelope``), a
    cell row measured on an incompatible grid (``COMPAT_KEYS`` mismatch),
    or a torn/non-numeric previous value each skip the delta (warn via
    ``warn`` when given) instead of raising — a corrupted baseline must
    not take the CI gate down with it."""
    prev_envelope = sanitize_envelope(prev_envelope, warn)
    prev_rows = {r["name"]: r
                 for r in (prev_envelope or {}).get("results", [])
                 if isinstance(r, dict) and "name" in r}
    for row in rows:
        prev = prev_rows.get(row["name"])
        if prev is None:
            continue
        if not _compatible(row, prev):
            if warn is not None:
                diffs = [k for k in COMPAT_KEYS
                         if prev.get(k) != row.get(k)]
                warn(f"leaderboard: {row['name']} previous row is from an "
                     f"incompatible grid ({', '.join(diffs)} changed) — "
                     f"no baseline for this cell")
            continue
        for k in DELTA_KEYS:
            if k in prev and k in row:
                try:
                    pv, nv = float(prev[k]), float(row[k])
                except (TypeError, ValueError):
                    continue
                if not np.isfinite(pv):
                    continue
                row[f"prev_{k}"] = pv
                row[f"delta_{k}"] = nv - pv
    return rows


def check_regressions(rows: List[Dict[str, Any]], tol: float = DEFAULT_TOL,
                      tolerances: Optional[Dict[str, float]] = None
                      ) -> List[str]:
    """The gate: one failure string per (cell, gated metric) whose new value
    fell more than the tolerance below the previous envelope's.

    Tolerance per cell: ``tolerances[cell_name]`` overrides ``tol``; the
    allowed drop is ``tol * max(|prev|, floor)`` with the metric's absolute
    floor from ``GATE_METRICS``, so noisy near-zero cells don't gate on
    roundoff. Rows without ``prev_*`` fields (first run, new cells,
    incompatible or corrupt baselines — see ``attach_deltas``) never fail.
    Call ``attach_deltas`` first."""
    failures = []
    for row in rows:
        if not isinstance(row, dict) or "name" not in row:
            continue
        cell_tol = (tolerances or {}).get(row["name"], tol)
        for metric, floor in GATE_METRICS.items():
            prev_key = f"prev_{metric}"
            if prev_key not in row or metric not in row:
                continue
            try:
                prev, new = float(row[prev_key]), float(row[metric])
            except (TypeError, ValueError):
                continue
            if not (np.isfinite(prev) and np.isfinite(new)):
                continue
            allowed = cell_tol * max(abs(prev), floor)
            if prev - new > allowed:
                failures.append(
                    f"{row['name']}: {metric} regressed {prev:.4f} -> "
                    f"{new:.4f} (drop {prev - new:.4f} > allowed "
                    f"{allowed:.4f} at tol {cell_tol:.0%})")
    return failures


# ---------------------------------------------------------------------------
# Checkpoint loading
# ---------------------------------------------------------------------------
def load_fleet(cfg: FCPOConfig, ckpt_dir: str, step: Optional[int] = None, *,
               n_agents: int, n_pods: int = 1, env_backend=None) -> Fleet:
    """Restore a ``Fleet`` checkpoint (training/checkpoint.py format) for
    leaderboard evaluation. The template fleet supplies structure + static
    aux; ``env_backend`` must match the backend the checkpoint was saved
    with (its env-state leaves are part of the on-disk structure)."""
    if step is None:
        step = ckpt_mod.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint manifests in {ckpt_dir}")
    template = fleet_init(cfg, n_agents, jax.random.PRNGKey(0),
                          n_pods=n_pods, env_backend=env_backend)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype),
        template)
    fleet, _manifest = ckpt_mod.restore(ckpt_dir, step, like)
    return fleet
