"""Streaming metrics: a JSONL sink for live fleet observability.

Long training runs used to be black boxes: the scanned driver is ONE jitted
dispatch, so the per-episode history only materializes when the whole run
returns. ``MetricsSink`` is the observability tap both fleet drivers accept
(``train_fleet_scan(..., metrics_sink=...)`` /
``train_fleet_reference(..., metrics_sink=...)``): one JSON line per
episode — reward, throughput, the FL transport metrics
(``fl_payload_bytes`` / ``fl_missed`` / ``fl_stale_used``), everything in
the run history — appended and flushed *as the episode completes*. Inside
the scanned driver the records are emitted by an ordered
``jax.debug.callback`` from the scan body, so the file tails live even
though the host dispatched only once; the default (no sink) path traces
the exact pre-sink program.

File format: line 1 is a ``{"kind": "meta", ...}`` header (run shape,
backend, scenario — whatever the writer stamps); every further line is
``{"episode": int, "<metric>": float, ...}``. ``launch/watch.py`` is the
reader CLI; ``read_metrics`` / ``tail_summary`` are the library surface it
(and the tests) share.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Tuple

META_KIND = "meta"


class MetricsSink:
    """Append-only JSONL metrics writer. Records are flushed per line so a
    reader (``launch/watch.py --follow``) sees them while the run is live.
    Usable as a context manager; ``append`` after ``close`` raises.

    ``resume=True`` continues an existing file instead of truncating it —
    the checkpoint auto-resume path (``train_fleet.py --ckpt-dir``) relies
    on this to keep the episodes recorded before a kill. The existing meta
    header is validated against ``meta``: every key both sides share must
    agree (a resumed run with a different shape/seed would silently splice
    incomparable records), and the header must exist and parse. A missing
    file resumes as a fresh write."""

    def __init__(self, path: str, meta: Optional[Dict[str, Any]] = None,
                 resume: bool = False):
        self.path = path
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        header = {"kind": META_KIND}
        header.update(meta or {})
        if resume and os.path.exists(path):
            old_meta, records = read_metrics(path)
            if not old_meta:
                raise ValueError(
                    f"cannot resume metrics file {path}: no parseable "
                    f"{META_KIND} header on line 1")
            for k in set(old_meta) & set(meta or {}):
                if old_meta[k] != (meta or {})[k]:
                    raise ValueError(
                        f"cannot resume metrics file {path}: meta mismatch "
                        f"on {k!r} (file has {old_meta[k]!r}, run has "
                        f"{(meta or {})[k]!r})")
            with open(path, "rb") as f:
                f.seek(0, os.SEEK_END)
                size = f.tell()
                f.seek(max(size - 1, 0))
                torn_tail = size > 0 and f.read(1) != b"\n"
            self._f = open(path, "a")
            if torn_tail:
                # a kill mid-append left a partial line with no newline;
                # without this the next record would merge into it and BOTH
                # lines would be lost to the reader
                self._f.write("\n")
            self.n_records = len(records)
        else:
            self._f = open(path, "w")
            self.n_records = 0
            self._write(header)

    def _write(self, obj: Dict[str, Any]):
        self._f.write(json.dumps(obj, sort_keys=True, default=float) + "\n")
        self._f.flush()

    def append(self, record: Dict[str, Any]):
        """One per-episode record: plain scalars only (the fleet drivers
        pass ``{"episode": int, **metric_floats}``)."""
        self._write(record)
        self.n_records += 1

    def close(self):
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "MetricsSink":
        return self

    def __exit__(self, *exc):
        self.close()


def read_metrics(path: str) -> Tuple[Dict[str, Any], List[Dict[str, Any]]]:
    """Parse a metrics JSONL file -> (meta, records). Tolerates a torn last
    line (the writer may be mid-append) by dropping it."""
    meta: Dict[str, Any] = {}
    records: List[Dict[str, Any]] = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError:
                continue  # torn tail of a live file
            if i == 0 and obj.get("kind") == META_KIND:
                meta = {k: v for k, v in obj.items() if k != "kind"}
            else:
                records.append(obj)
    return meta, records


def tail_summary(records: List[Dict[str, Any]], k: int = 10
                 ) -> Dict[str, Dict[str, float]]:
    """Per-metric {"last": newest value, "tail_mean": mean over the last k
    records, "mean": run mean} for every numeric key except ``episode``."""
    out: Dict[str, Dict[str, float]] = {}
    if not records:
        return out
    num = lambda v: isinstance(v, (int, float)) and not isinstance(v, bool)
    # keys from ANY record that held a numeric value (first-seen order): a
    # garbled newest record must not hide a metric the run has been logging
    keys, seen = [], set()
    for r in records:
        for key, v in r.items():
            if key != "episode" and key not in seen and num(v):
                seen.add(key)
                keys.append(key)
    tail = records[-k:]
    for key in keys:
        # a newer writer may emit non-numeric values for a key an older
        # record held as a float (or vice versa) — skip those, never crash
        vals = [r[key] for r in records if num(r.get(key))]
        tvals = [r[key] for r in tail if num(r.get(key))]
        if not vals:
            continue
        out[key] = {"last": float(vals[-1]),
                    "tail_mean": float(sum(tvals) / max(len(tvals), 1)),
                    "mean": float(sum(vals) / max(len(vals), 1))}
    return out


def device_summary(records: List[Dict[str, Any]]
                   ) -> Optional[Dict[str, float]]:
    """Scaling digest from the trailing device records the launcher appends
    (``train_fleet.py --metrics-out`` with a mesh): mesh size, per-agent
    step time, stored-state bytes per agent, and one ``dev<i>_bytes`` row
    per device showing where the fleet pytree actually landed. Same JSONL
    protocol as every other record — a device record is just an episode-less
    line carrying a ``devices`` key. None when the run wrote none (yet)."""
    rows = [r for r in records if "devices" in r]
    if not rows:
        return None
    last = rows[-1]
    num = lambda v: isinstance(v, (int, float)) and not isinstance(v, bool)
    out = {k: float(v) for k, v in last.items() if num(v)}
    out["rows"] = float(len(rows))
    return out


def health_summary(records: List[Dict[str, Any]]) -> Optional[Dict[str, float]]:
    """Fleet-health digest over the episodes that carried health metrics
    (``health_*`` keys exist only when the run enabled the observatory, so
    mixed pre-/post-PR-10 files reduce to the episodes that have them).
    None when no record holds any health key (yet)."""
    rows = [r for r in records if "health_drift_score" in r]
    if not rows:
        return None
    mean = lambda key: float(sum(r.get(key, 0.0) for r in rows) / len(rows))
    last = rows[-1]
    return {
        "episodes": float(len(rows)),
        "drift_flags": float(sum(r.get("health_drift_flag", 0.0) > 0.0
                                 for r in rows)),
        "drift_score_last": float(last.get("health_drift_score", 0.0)),
        "susp_last": float(last.get("health_susp", 0.0)),
        "susp_max": float(max(r.get("health_susp", 0.0) for r in rows)),
        "reward_p50_last": float(last.get("health_reward_p50", 0.0)),
        "miss_p90_mean": mean("health_miss_p90"),
        "act_entropy_last": float(last.get("health_act_entropy", 0.0)),
    }


def fl_round_summary(records: List[Dict[str, Any]]) -> Optional[Dict[str, float]]:
    """FL transport digest over the episodes that actually held a round
    (``fl_payload_bytes > 0``); None when the run had no rounds (yet)."""
    rounds = [r for r in records if r.get("fl_payload_bytes", 0.0) > 0.0]
    if not rounds:
        return None
    mean = lambda key: float(sum(r.get(key, 0.0) for r in rounds) / len(rounds))
    return {
        "rounds": float(len(rounds)),
        "payload_bytes": mean("fl_payload_bytes"),
        "uplink_s": mean("fl_uplink_s"),
        "missed": mean("fl_missed"),
        "stale_used": mean("fl_stale_used"),
        "rejected": mean("fl_rejected"),
        "clipped": mean("fl_clipped"),
    }
