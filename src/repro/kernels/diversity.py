"""Pallas fused diversity-insert kernel — the CRL buffer hot path (Eq. 6).

One grid step per agent ingests a whole episode of T candidate experiences
into that agent's diversity buffer: score from the streaming moments ->
argmin-evict slot choice -> scatter + rank-1 moment update, fused into a
single kernel so the per-candidate sequential chain never leaves on-chip
memory. The buffer slots (N, D), the moments, and the T candidates all live
in VMEM for the duration of the episode — the only HBM traffic is one load
and one store of the agent's buffer state (≈ N·(D+NA) floats) per episode
instead of T round trips.

The scoring math is imported from ``repro.kernels.ref`` — the same unrolled
LAPACK-free Cholesky the jnp oracle uses — so kernel and oracle agree to
float32 roundoff (equivalence-tested in tests/test_buffer.py). On this CPU
container the kernel executes with ``interpret=True`` (same body,
XLA-CPU execution); on TPU the same call site compiles to Mosaic.

Booleans cross the kernel boundary as int32 (0/1) masks — TPU vector memory
has no i1 lanes; the ops wrapper converts at the edges.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as kref


def _diversity_kernel(states_ref, probs_ref, score_ref, filled_ref, ssum_ref,
                      souter_ref, psum_ref, nfill_ref, cs_ref, cp_ref,
                      o_states, o_probs, o_score, o_filled, o_ssum, o_souter,
                      o_psum, o_nfill, o_slot, o_do, o_d,
                      *, alpha, beta, ridge, t_steps):
    # Seed the in-place slot state once; the candidate loop mutates it.
    o_states[...] = states_ref[...]
    o_probs[...] = probs_ref[...]
    o_score[...] = score_ref[...]
    o_filled[...] = filled_ref[...]

    def body(t, carry):
        s_sum, s_outer, p_sum, n_filled = carry
        s = cs_ref[0, pl.ds(t, 1), :][0]            # (D,)
        p = cp_ref[0, pl.ds(t, 1), :][0]            # (NA,)
        score = o_score[0, :]                        # (N,)

        d = kref.diversity_score_from_moments(
            s, p, s_sum, s_outer, p_sum, n_filled,
            alpha=alpha, beta=beta, ridge=ridge)

        # Score invariant (see diversity_insert_ref): empty slots hold -inf,
        # so one argmin picks first-empty-else-min-filled and d > min(score)
        # is the insert test in both regimes.
        minval = jnp.min(score)
        idx = jnp.argmin(score).astype(jnp.int32)
        do = d > minval
        evict = do & (minval != -jnp.inf)

        old_s = o_states[0, pl.ds(idx, 1), :][0]
        old_p = o_probs[0, pl.ds(idx, 1), :][0]
        add = do.astype(s_sum.dtype)
        sub = evict.astype(s_sum.dtype)
        carry = (
            s_sum + add * s - sub * old_s,
            s_outer + add * jnp.outer(s, s) - sub * jnp.outer(old_s, old_s),
            p_sum + add * p - sub * old_p,
            n_filled + do.astype(n_filled.dtype) - evict.astype(n_filled.dtype),
        )

        @pl.when(do)
        def _scatter():
            o_states[0, pl.ds(idx, 1), :] = s[None]
            o_probs[0, pl.ds(idx, 1), :] = p[None]
            o_score[0, pl.ds(idx, 1)] = d[None]
            o_filled[0, pl.ds(idx, 1)] = jnp.ones((1,), jnp.int32)

        o_slot[0, pl.ds(t, 1)] = idx[None]
        o_do[0, pl.ds(t, 1)] = do.astype(jnp.int32)[None]
        o_d[0, pl.ds(t, 1)] = d[None]
        return carry

    init = (ssum_ref[0, :], souter_ref[0], psum_ref[0, :], nfill_ref[0])
    s_sum, s_outer, p_sum, n_filled = jax.lax.fori_loop(
        0, t_steps, body, init)
    o_ssum[0, :] = s_sum
    o_souter[0] = s_outer
    o_psum[0, :] = p_sum
    o_nfill[0] = n_filled


def diversity_insert(states, probs, score, filled, s_sum, s_outer, p_sum,
                     n_filled, cand_states, cand_probs, *, alpha, beta,
                     ridge=0.1, interpret=False):
    """Fused batch insert over the agent axis.

    states: (A, N, D) [or unbatched (N, D) — a singleton agent axis is added
    and squeezed]; cand_states: (A, T, D); filled: bool. Returns the same
    tuple as ``ref.diversity_insert_ref`` batched over A: updated
    (states, probs, score, filled, s_sum, s_outer, p_sum, n_filled) plus the
    per-candidate decision trace (slot, do_insert, d)."""
    unbatched = states.ndim == 2
    if unbatched:
        (states, probs, score, filled, s_sum, s_outer, p_sum, n_filled,
         cand_states, cand_probs) = jax.tree.map(
            lambda x: x[None], (states, probs, score, filled, s_sum, s_outer,
                                p_sum, n_filled, cand_states, cand_probs))
    a, n, dim = states.shape
    t_steps, na = cand_probs.shape[1], cand_probs.shape[2]
    f32, i32 = jnp.float32, jnp.int32

    kernel = functools.partial(_diversity_kernel, alpha=alpha, beta=beta,
                               ridge=ridge, t_steps=t_steps)
    spec = lambda *shape: pl.BlockSpec(
        (1,) + shape, lambda a_: (a_,) + (0,) * len(shape))
    out = pl.pallas_call(
        kernel,
        grid=(a,),
        in_specs=[spec(n, dim), spec(n, na), spec(n), spec(n), spec(dim),
                  spec(dim, dim), spec(na), spec(), spec(t_steps, dim),
                  spec(t_steps, na)],
        out_specs=[spec(n, dim), spec(n, na), spec(n), spec(n), spec(dim),
                   spec(dim, dim), spec(na), spec(), spec(t_steps),
                   spec(t_steps), spec(t_steps)],
        out_shape=[
            jax.ShapeDtypeStruct((a, n, dim), f32),
            jax.ShapeDtypeStruct((a, n, na), f32),
            jax.ShapeDtypeStruct((a, n), f32),
            jax.ShapeDtypeStruct((a, n), i32),
            jax.ShapeDtypeStruct((a, dim), f32),
            jax.ShapeDtypeStruct((a, dim, dim), f32),
            jax.ShapeDtypeStruct((a, na), f32),
            jax.ShapeDtypeStruct((a,), i32),
            jax.ShapeDtypeStruct((a, t_steps), i32),
            jax.ShapeDtypeStruct((a, t_steps), i32),
            jax.ShapeDtypeStruct((a, t_steps), f32),
        ],
        interpret=interpret,
    )(states.astype(f32), probs.astype(f32), score.astype(f32),
      filled.astype(i32), s_sum.astype(f32), s_outer.astype(f32),
      p_sum.astype(f32), n_filled.astype(i32), cand_states.astype(f32),
      cand_probs.astype(f32))

    (n_states, n_probs, n_score, n_filled_i, n_ssum, n_souter, n_psum,
     n_nfill, slot, do, d) = out
    result = (n_states, n_probs, n_score, n_filled_i.astype(bool), n_ssum,
              n_souter, n_psum, n_nfill, slot, do.astype(bool), d)
    if unbatched:
        result = jax.tree.map(lambda x: x[0], result)
    return result
