"""Pallas fused queue-advance kernel — the digital-twin data-plane hot path.

One grid step per agent advances that agent's request-level pipeline state
(admit -> pre-process -> batch-form -> inference service -> post-process ->
deadline check) K microticks in a single kernel: the arrival ring, the stage
counters, the service credits, and the latency histogram all stay in VMEM
for the whole control interval, so the only HBM traffic is one load and one
store of the agent's ~(R + H + 20)-word state per K ticks instead of K round
trips. A fleet of A agents is one kernel call over grid (A,).

The per-tick math is imported from ``repro.kernels.ref.sim_microtick`` — the
same function the jnp oracle (``queue_advance_ref``) scans — so kernel and
oracle agree bit-for-bit (equivalence-tested in tests/test_sim.py, including
under ``vmap``). On this CPU container the kernel executes with
``interpret=True`` (same body, XLA-CPU execution); on TPU the same call site
compiles to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as kref


def _queue_kernel(arrive_ref, counters_ref, credits_ref, latsum_ref,
                  hist_ref, arrivals_ref, caps_ref,
                  o_arrive, o_counters, o_credits, o_latsum, o_hist,
                  *, k_ticks):
    caps = caps_ref[0]

    def tick(t, carry):
        n_arr = arrivals_ref[0, pl.ds(t, 1)][0]
        return kref.sim_microtick(*carry, n_arr, caps)

    init = (arrive_ref[0], counters_ref[0], credits_ref[0], latsum_ref[0],
            hist_ref[0])
    arrive, counters, credits, lat_sum, hist = jax.lax.fori_loop(
        0, k_ticks, tick, init)
    o_arrive[0] = arrive
    o_counters[0] = counters
    o_credits[0] = credits
    o_latsum[0] = lat_sum
    o_hist[0] = hist


def queue_advance(arrive, counters, credits, lat_sum, hist, arrivals, caps,
                  *, interpret=False):
    """Fused K-microtick advance over the agent axis.

    arrive: (A, R) int32 [or unbatched (R,) — a singleton agent axis is
    added and squeezed]; counters: (A, SIM_NCOUNTERS) int32; credits: (A, 2)
    float32; lat_sum: (A,) float32; hist: (A, H) int32; arrivals: (A, K)
    int32; caps: (A, SIM_NCAPS) float32. Returns the updated state tuple
    (arrive, counters, credits, lat_sum, hist), identical to
    ``vmap(ref.queue_advance_ref)``."""
    unbatched = arrive.ndim == 1
    if unbatched:
        (arrive, counters, credits, lat_sum, hist, arrivals, caps) = \
            jax.tree.map(lambda x: x[None],
                         (arrive, counters, credits, lat_sum, hist,
                          arrivals, caps))
    a, ring = arrive.shape
    assert ring > 0 and ring & (ring - 1) == 0, \
        "ring capacity must be a positive power of two"
    k_ticks, hist_n = arrivals.shape[1], hist.shape[1]
    f32, i32 = jnp.float32, jnp.int32

    kernel = functools.partial(_queue_kernel, k_ticks=k_ticks)
    spec = lambda *shape: pl.BlockSpec(
        (1,) + shape, lambda a_: (a_,) + (0,) * len(shape))
    out = pl.pallas_call(
        kernel,
        grid=(a,),
        in_specs=[spec(ring), spec(kref.SIM_NCOUNTERS), spec(2), spec(),
                  spec(hist_n), spec(k_ticks), spec(kref.SIM_NCAPS)],
        out_specs=[spec(ring), spec(kref.SIM_NCOUNTERS), spec(2), spec(),
                   spec(hist_n)],
        out_shape=[
            jax.ShapeDtypeStruct((a, ring), i32),
            jax.ShapeDtypeStruct((a, kref.SIM_NCOUNTERS), i32),
            jax.ShapeDtypeStruct((a, 2), f32),
            jax.ShapeDtypeStruct((a,), f32),
            jax.ShapeDtypeStruct((a, hist_n), i32),
        ],
        interpret=interpret,
    )(arrive.astype(i32), counters.astype(i32), credits.astype(f32),
      lat_sum.astype(f32), hist.astype(i32), arrivals.astype(i32),
      caps.astype(f32))

    if unbatched:
        out = jax.tree.map(lambda x: x[0], out)
    return tuple(out)
