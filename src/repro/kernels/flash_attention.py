"""Pallas TPU flash attention (prefill / training), causal + GQA.

Grid: (batch, q_heads, q_blocks, kv_blocks) with the kv axis innermost so the
(m, l, acc) online-softmax state lives in VMEM scratch across kv iterations
(the classic TPU revisiting pattern — the output block index is independent
of the kv grid index).

BlockSpecs pull (bq, D) query tiles and (bk, D) key/value tiles into VMEM;
with bq = bk = 128 and D ∈ {64, 80, 128, 192, 256} both matmuls hit the MXU
with 128-aligned contraction/output dims. VMEM footprint per step ≈
(bq·D + 2·bk·D + bq·bk + 2·bq·D) · 4 B ≈ 0.6 MB at D=128 — well inside the
~16 MB/core budget, leaving room for double buffering.

GQA is handled in the k/v index_map (query head h reads kv head
h // group) — no repeated kv materialization in HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, bq, bk, n_kv):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal:
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = qpos >= kpos
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1)[:, None]           # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                        # masked lanes: exp(NEG_INF - m) ≈ 0
    if causal:
        p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)[:, None]
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True, bq=128, bk=128,
                         interpret=False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) -> (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0
    nq, nk = sq // bq, sk // bk
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, n_kv=nk)
    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def flash_attention(q, k, v, *, causal=True, bq=128, bk=128, interpret=False):
    """Layout adapter: q (B, Sq, Hq, D), k/v (B, Sk, Hkv, D) — model layout."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, bq=bq, bk=bk,
                               interpret=interpret)
    return out.transpose(0, 2, 1, 3)
