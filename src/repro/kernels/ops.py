"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute with ``interpret=True`` (Pallas
interpreter — same kernel body, Python/XLA-CPU execution); on TPU the same
call sites compile to Mosaic. ``REPRO_PALLAS_INTERPRET=0`` flips to compiled
mode. The model code defaults to the jnp reference path under dry-run
(identical math — see DESIGN.md §6) and switches to these via
``use_pallas=True``.
"""
from __future__ import annotations

import functools
import os

import jax

from repro.kernels import decode_attention as _dec
from repro.kernels import delta_codec as _codec
from repro.kernels import diversity as _div
from repro.kernels import flash_attention as _fa
from repro.kernels import packing as _pack
from repro.kernels import queue_advance as _qa


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, *, causal=True, bq=128, bk=128):
    return _fa.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("bk",))
def decode_attention(q, k_cache, v_cache, kv_len, *, bk=512):
    return _dec.decode_attention(q, k_cache, v_cache, kv_len, bk=bk,
                                 interpret=_interpret_default())


@jax.jit
def pack(tokens, indices):
    return _pack.pack(tokens, indices, interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "ridge"))
def diversity_insert(states, probs, score, filled, s_sum, s_outer, p_sum,
                     n_filled, cand_states, cand_probs, *, alpha, beta,
                     ridge=0.1):
    """Fused streaming diversity-buffer insert (Eq. 6): score ->
    argmin-evict -> scatter over T candidates per agent, one kernel call for
    the whole agent batch. Oracle: ``repro.kernels.ref.diversity_insert_ref``."""
    return _div.diversity_insert(states, probs, score, filled, s_sum,
                                 s_outer, p_sum, n_filled, cand_states,
                                 cand_probs, alpha=alpha, beta=beta,
                                 ridge=ridge, interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("codec", "k"))
def delta_codec(delta, residual, *, codec, k=1):
    """Fused FL transport codec (error feedback + encode + decode): one
    kernel call per fleet turns the flat (A, L) parameter deltas into their
    lossy on-wire round trip plus the carried residuals. Oracle:
    ``repro.kernels.ref.delta_codec_ref``."""
    return _codec.delta_codec(delta, residual, codec=codec, k=k,
                              interpret=_interpret_default())


@jax.jit
def queue_advance(arrive, counters, credits, lat_sum, hist, arrivals, caps):
    """Fused request-level data-plane advance (digital twin): admit ->
    pre-process -> batch-form -> inference -> post-process -> deadline check,
    K microticks per agent in one kernel call for the whole agent batch.
    Oracle: ``repro.kernels.ref.queue_advance_ref``."""
    return _qa.queue_advance(arrive, counters, credits, lat_sum, hist,
                             arrivals, caps, interpret=_interpret_default())
