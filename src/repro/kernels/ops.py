"""jit'd public wrappers for the Pallas kernels.

Dispatch is backend-aware: on CPU the kernels execute with
``interpret=True`` (Pallas interpreter — same kernel body, Python/XLA-CPU
execution); on any accelerator backend the same call sites compile (TPU ->
Mosaic, GPU -> Triton). ``REPRO_PALLAS_INTERPRET=1/0`` force-overrides in
either direction. The model code defaults to the jnp reference path under dry-run
(identical math — see DESIGN.md §6) and switches to these via
``use_pallas=True``.

Flight-recorder hook: every wrapper consults
``repro.obs.trace.kernel_trace_tid()``. When it returns None (the default:
no active tracer, or inside an un-instrumented trace) the call goes through
the same cached jit wrapper as before this layer existed — the exact
pre-observability program. When a tracer with ``kernel_spans=True`` is
active at the top level (or an instrumented caller has bound a trace-id via
``bind_tid``), the call routes to a *traced twin* — same kernel, bracketed
by ``kernel/<name>`` spans — jitted separately with the trace-id as a plain
operand, so per-kernel timing never recompiles per tracer and never leaks
into the untraced cache.
"""
from __future__ import annotations

import functools
import os

import jax

from repro.kernels import decode_attention as _dec
from repro.kernels import delta_codec as _codec
from repro.kernels import diversity as _div
from repro.kernels import flash_attention as _fa
from repro.kernels import packing as _pack
from repro.kernels import queue_advance as _qa
from repro.obs import trace as obs_trace


def _interpret_default() -> bool:
    """Backend-aware kernel dispatch: interpret on CPU (no Pallas lowering
    there), compiled Pallas on every accelerator backend (TPU -> Mosaic,
    GPU -> Triton). ``REPRO_PALLAS_INTERPRET=1/0`` force-overrides either
    way (e.g. interpret-on-TPU for kernel debugging, or compiled-on-CPU to
    reproduce a lowering error report)."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "cpu"


def _twins(name, impl, static_argnames=()):
    """Build (untraced, traced) jitted variants of kernel ``impl``. The
    untraced one is the original wrapper; the traced one takes the trace-id
    as its first (non-static) operand and brackets the kernel with
    ``kernel/<name>`` spans."""
    untraced = functools.partial(jax.jit, static_argnames=static_argnames)(
        impl) if static_argnames else jax.jit(impl)

    def traced_impl(tid, *args, **kw):
        tok = obs_trace.span_begin(f"kernel/{name}", tid, args,
                                   cat="kernel")
        out = impl(*args, **kw)
        obs_trace.span_end(f"kernel/{name}", tid, tok, out)
        return out

    traced = (functools.partial(jax.jit, static_argnames=static_argnames)(
        traced_impl) if static_argnames else jax.jit(traced_impl))
    return untraced, traced


def _flash_impl(q, k, v, *, causal=True, bq=128, bk=128):
    return _fa.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=_interpret_default())


def _decode_impl(q, k_cache, v_cache, kv_len, *, bk=512):
    return _dec.decode_attention(q, k_cache, v_cache, kv_len, bk=bk,
                                 interpret=_interpret_default())


def _pack_impl(tokens, indices):
    return _pack.pack(tokens, indices, interpret=_interpret_default())


def _diversity_impl(states, probs, score, filled, s_sum, s_outer, p_sum,
                    n_filled, cand_states, cand_probs, *, alpha, beta,
                    ridge=0.1):
    return _div.diversity_insert(states, probs, score, filled, s_sum,
                                 s_outer, p_sum, n_filled, cand_states,
                                 cand_probs, alpha=alpha, beta=beta,
                                 ridge=ridge, interpret=_interpret_default())


def _delta_codec_impl(delta, residual, *, codec, k=1):
    return _codec.delta_codec(delta, residual, codec=codec, k=k,
                              interpret=_interpret_default())


def _queue_advance_impl(arrive, counters, credits, lat_sum, hist, arrivals,
                        caps):
    return _qa.queue_advance(arrive, counters, credits, lat_sum, hist,
                             arrivals, caps, interpret=_interpret_default())


_FLASH = _twins("flash_attention", _flash_impl, ("causal", "bq", "bk"))
_DECODE = _twins("decode_attention", _decode_impl, ("bk",))
_PACK = _twins("pack", _pack_impl)
_DIVERSITY = _twins("diversity_insert", _diversity_impl,
                    ("alpha", "beta", "ridge"))
_DELTA_CODEC = _twins("delta_codec", _delta_codec_impl, ("codec", "k"))
_QUEUE_ADVANCE = _twins("queue_advance", _queue_advance_impl)


def _dispatch(twins, args, kw):
    tid = obs_trace.kernel_trace_tid()
    if tid is None:
        return twins[0](*args, **kw)
    return twins[1](tid, *args, **kw)


def flash_attention(q, k, v, *, causal=True, bq=128, bk=128):
    return _dispatch(_FLASH, (q, k, v),
                     dict(causal=causal, bq=bq, bk=bk))


def decode_attention(q, k_cache, v_cache, kv_len, *, bk=512):
    return _dispatch(_DECODE, (q, k_cache, v_cache, kv_len), dict(bk=bk))


def pack(tokens, indices):
    return _dispatch(_PACK, (tokens, indices), {})


def diversity_insert(states, probs, score, filled, s_sum, s_outer, p_sum,
                     n_filled, cand_states, cand_probs, *, alpha, beta,
                     ridge=0.1):
    """Fused streaming diversity-buffer insert (Eq. 6): score ->
    argmin-evict -> scatter over T candidates per agent, one kernel call for
    the whole agent batch. Oracle: ``repro.kernels.ref.diversity_insert_ref``."""
    return _dispatch(_DIVERSITY,
                     (states, probs, score, filled, s_sum, s_outer, p_sum,
                      n_filled, cand_states, cand_probs),
                     dict(alpha=alpha, beta=beta, ridge=ridge))


def delta_codec(delta, residual, *, codec, k=1):
    """Fused FL transport codec (error feedback + encode + decode): one
    kernel call per fleet turns the flat (A, L) parameter deltas into their
    lossy on-wire round trip plus the carried residuals. Oracle:
    ``repro.kernels.ref.delta_codec_ref``."""
    return _dispatch(_DELTA_CODEC, (delta, residual),
                     dict(codec=codec, k=k))


def queue_advance(arrive, counters, credits, lat_sum, hist, arrivals, caps):
    """Fused request-level data-plane advance (digital twin): admit ->
    pre-process -> batch-form -> inference -> post-process -> deadline check,
    K microticks per agent in one kernel call for the whole agent batch.
    Oracle: ``repro.kernels.ref.queue_advance_ref``."""
    return _dispatch(_QUEUE_ADVANCE,
                     (arrive, counters, credits, lat_sum, hist, arrivals,
                      caps), {})


# name -> untraced jit wrapper — the profiler (repro.obs.profile) uses
# these to lower and cost/memory-account every kernel variant; they are the
# exact objects the dispatchers call, so the analyzed program is the one
# that runs.
KERNEL_JITS = {
    "flash_attention": _FLASH[0],
    "decode_attention": _DECODE[0],
    "pack": _PACK[0],
    "diversity_insert": _DIVERSITY[0],
    "delta_codec": _DELTA_CODEC[0],
    "queue_advance": _QUEUE_ADVANCE[0],
}
