"""Pallas fused delta-codec kernel — the FL transport hot path.

One grid step per agent runs that agent's whole error-feedback encode/decode
chain in a single kernel: the flat parameter delta and the carried residual
are pulled into VMEM once, the error-compensated delta ``xf = delta + r`` is
encoded (per-tensor int8 round trip or exact top-k sparsification, jit-static
choice) and decoded in place, and the new residual ``xf - decoded`` is
written back — one load and one store of the agent's 2·L-word codec state
per FL round instead of separate quantize/dequantize/residual passes. A
fleet of A agents is one kernel call over grid (A,).

The per-coordinate math is imported from ``repro.kernels.ref``
(``delta_codec_step`` — the same function the jnp oracle ``delta_codec_ref``
calls), so kernel and oracle agree bit-for-bit (equivalence-tested in
tests/test_fl.py, including under ``vmap``). On this CPU container the
kernel executes with ``interpret=True`` (same body, XLA-CPU execution); on
TPU the float32/int8 bodies (element-wise + reductions) compile to Mosaic,
while topk's sort-based exact-k selection is currently only exercised in
interpret mode — a Mosaic-native selection (threshold refinement instead of
a full sort) is the known follow-up before enabling ``use_pallas`` topk on
real TPU hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as kref


def _codec_kernel(delta_ref, res_ref, o_dec, o_res, *, codec, k):
    xf = delta_ref[0] + res_ref[0]
    dec, new_res = kref.delta_codec_step(xf, codec=codec, k=k)
    o_dec[0] = dec
    o_res[0] = new_res


def delta_codec(delta, residual, *, codec: str, k: int = 1, interpret=False):
    """Fused error-feedback encode/decode over the agent axis.

    delta, residual: (A, L) float32 flat per-agent parameter deltas [or
    unbatched (L,) — a singleton agent axis is added and squeezed]. ``codec``
    in ``ref.DELTA_CODECS`` and ``k`` (top-k budget) are jit-static. Returns
    (decoded, new_residual), identical to ``vmap(ref.delta_codec_ref)``."""
    if codec not in kref.DELTA_CODECS:
        raise ValueError(f"unknown codec {codec!r}; expected one of "
                         f"{kref.DELTA_CODECS}")
    unbatched = delta.ndim == 1
    if unbatched:
        delta, residual = delta[None], residual[None]
    a, l = delta.shape
    f32 = jnp.float32

    kernel = functools.partial(_codec_kernel, codec=codec, k=k)
    spec = lambda *shape: pl.BlockSpec(
        (1,) + shape, lambda a_: (a_,) + (0,) * len(shape))
    out = pl.pallas_call(
        kernel,
        grid=(a,),
        in_specs=[spec(l), spec(l)],
        out_specs=[spec(l), spec(l)],
        out_shape=[
            jax.ShapeDtypeStruct((a, l), f32),
            jax.ShapeDtypeStruct((a, l), f32),
        ],
        interpret=interpret,
    )(delta.astype(f32), residual.astype(f32))

    if unbatched:
        out = jax.tree.map(lambda x: x[0], out)
    return tuple(out)
