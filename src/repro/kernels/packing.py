"""Pallas TPU token/frame packing kernel — the paper's RES action data path.

Frame packing combines small inputs into one fixed compiled shape (§II-B
"Resolution Adjustments"); for the LM data plane that is a gather of
variable-length request segments into a padded bucket. The index vector
arrives via scalar prefetch, so each grid step's input block index is
computed *before* its DMA — the gather happens at the BlockSpec level (one
HBM->VMEM row copy per step), not as an in-kernel load loop.

Rows with index < 0 are padding: the copy is skipped under ``pl.when`` and
the slot is zeroed, so a bucket's cost scales with its *real* payload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pack_kernel(idx_ref, tok_ref, o_ref):
    i = pl.program_id(0)
    idx = idx_ref[i]

    @pl.when(idx >= 0)
    def _copy():
        o_ref[...] = tok_ref[...]

    @pl.when(idx < 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)


def pack(tokens, indices, *, interpret=False):
    """tokens: (T, D); indices: (N,) int32, negative = padding.

    Returns (N, D) with out[i] = tokens[indices[i]] (0 for padding)."""
    t, d = tokens.shape
    n = indices.shape[0]
    return pl.pallas_call(
        _pack_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n,),
            in_specs=[
                pl.BlockSpec((1, d),
                             lambda i, idx_ref: (jnp.maximum(idx_ref[i], 0), 0)),
            ],
            out_specs=pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, d), tokens.dtype),
        interpret=interpret,
    )(indices.astype(jnp.int32), tokens)
