"""Pallas TPU decode attention: one query token vs. a long KV cache.

This is the memory-bound hot spot of the ``decode_32k`` / ``long_500k``
shapes: arithmetic intensity ≈ 1 FLOP/byte, so the kernel is designed so the
ONLY HBM traffic is one streaming pass over the (valid prefix of the) cache.

Grid: (batch, kv_heads, kv_blocks). Each step loads a (bk, D) k/v tile and
the (group, D) query-head group that shares this kv head, updating the
online-softmax state in VMEM scratch. Blocks entirely beyond ``kv_len`` are
skipped with ``pl.when`` (no wasted bandwidth on the invalid cache tail —
this is what makes the 512k-cache cell stream only ``kv_len`` bytes).

The valid length arrives via scalar prefetch (PrefetchScalarGridSpec) so the
skip decision is available before the DMA is issued.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale, bk, n_kv):
    ik = pl.program_id(2)
    kv_len = len_ref[0]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ik * bk < kv_len)
    def _work():
        q = q_ref[0, 0].astype(jnp.float32)       # (group, D)
        k = k_ref[0, :, 0].astype(jnp.float32)    # (bk, D)
        v = v_ref[0, :, 0].astype(jnp.float32)    # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = kpos < kv_len
        s = jnp.where(valid, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1)[:, None])
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1)[:, None]
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def decode_attention_bhd(q, k_cache, v_cache, kv_len, *, bk=512,
                         interpret=False):
    """q: (B, Hq, D); caches: (B, S_max, Hkv, D); kv_len scalar int32.

    Returns (B, Hq, D)."""
    b, hq, d = q.shape
    s_max, hkv = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    bk = min(bk, s_max)
    assert s_max % bk == 0
    nk = s_max // bk
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, group, d)

    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk, n_kv=nk)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, hkv, nk),
            in_specs=[
                pl.BlockSpec((1, 1, group, d),
                             lambda b_, h, ik, len_ref: (b_, h, 0, 0)),
                pl.BlockSpec((1, bk, 1, d),
                             lambda b_, h, ik, len_ref: (b_, ik, h, 0)),
                pl.BlockSpec((1, bk, 1, d),
                             lambda b_, h, ik, len_ref: (b_, ik, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, group, d),
                                   lambda b_, h, ik, len_ref: (b_, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(kv_len, jnp.int32).reshape(1), qg, k_cache, v_cache)
    return out.reshape(b, hq, d)


def decode_attention(q, k_cache, v_cache, kv_len, *, bk=512, interpret=False):
    """Model-layout adapter: q (B, 1, Hq, D) -> (B, 1, Hq, D)."""
    out = decode_attention_bhd(q[:, 0], k_cache, v_cache, kv_len, bk=bk,
                               interpret=interpret)
    return out[:, None]
