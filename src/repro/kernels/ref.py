"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, causal=True):
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D). Returns (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(k.shape[1])[None, :]
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, kv_len):
    """q: (B, 1, Hq, D); caches: (B, S_max, Hkv, D); kv_len: () or (B,).

    Single-query attention over the valid prefix of the cache."""
    b, _, hq, d = q.shape
    s_max, hkv = k_cache.shape[1], k_cache.shape[2]
    rep = hq // hkv
    k = jnp.repeat(k_cache, rep, axis=2) if rep > 1 else k_cache
    v = jnp.repeat(v_cache, rep, axis=2) if rep > 1 else v_cache
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    valid = jnp.arange(s_max)[None, :] < jnp.asarray(kv_len).reshape(-1, 1)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def pack_ref(tokens, indices):
    """tokens: (T, D); indices: (N,) int32 (negative = padding slot -> 0).

    The frame/token-packing gather: out[i] = tokens[indices[i]] or 0."""
    safe = jnp.clip(indices, 0, tokens.shape[0] - 1)
    out = tokens[safe]
    return jnp.where((indices >= 0)[:, None], out, 0).astype(tokens.dtype)


# ---------------------------------------------------------------------------
# Streaming-moment diversity insert (Eq. 6 engine) — shared math + jnp oracle
# ---------------------------------------------------------------------------
# The helpers below are the single source of truth for the streaming buffer
# math: the jnp batch path (``diversity_insert_ref``), the single-insert path
# in ``repro.core.buffer``, and the Pallas kernel body all call them, so the
# three implementations cannot drift. Everything is unrolled over the static
# state dimension D (= 8), which keeps the math LAPACK-free: it compiles to a
# fixed chain of vector ops that is legal inside jit, vmap, lax.scan, and a
# Pallas kernel alike (``jnp.linalg`` custom calls are none of those).

def chol_small(cov, eps=1e-12):
    """Cholesky factor of a small static-D SPD matrix, unrolled over D."""
    d = cov.shape[0]
    l = jnp.zeros_like(cov)
    for j in range(d):
        acc = jnp.sum(l[j, :j] * l[j, :j]) if j else 0.0
        ljj = jnp.sqrt(jnp.maximum(cov[j, j] - acc, eps))
        l = l.at[j, j].set(ljj)
        if j + 1 < d:
            dots = jnp.sum(l[j + 1:, :j] * l[j, :j][None, :], -1) if j else 0.0
            l = l.at[j + 1:, j].set((cov[j + 1:, j] - dots) / ljj)
    return l


def tri_solve_small(l, b):
    """Solve L y = b (L lower-triangular) by unrolled forward substitution."""
    d = l.shape[0]
    y = jnp.zeros_like(b)
    for i in range(d):
        acc = jnp.sum(l[i, :i] * y[:i]) if i else 0.0
        y = y.at[i].set((b[i] - acc) / l[i, i])
    return y


def diversity_score_from_moments(state, probs, s_sum, s_outer, p_sum,
                                 n_filled, *, alpha, beta, ridge=0.1,
                                 eps=1e-8):
    """Eq. 6 score of one candidate from running sufficient statistics only.

    Mahalanobis: cov = E[ssᵀ] − μμᵀ + ridge·I from (s_sum, s_outer), then
    d_M² = ‖L⁻¹(s−μ)‖² with L the Cholesky factor — O(D²) and never touches
    the N stored slots. KL uses the running probs sum the same way.
    Mathematically identical to the recompute-everything oracle
    (``repro.core.buffer.diversity``)."""
    dim = state.shape[-1]
    n = jnp.maximum(n_filled.astype(jnp.float32), 1.0)
    mu = s_sum / n
    cov = (s_outer / n - jnp.outer(mu, mu)
           + ridge * jnp.eye(dim, dtype=s_sum.dtype))
    y = tri_solve_small(chol_small(cov), state - mu)
    d_m = jnp.sqrt(jnp.maximum(jnp.sum(y * y), 0.0))
    mean_p = jnp.where(n_filled > 0, p_sum / n, probs)
    pc = jnp.clip(probs, eps, 1.0)
    qc = jnp.clip(mean_p, eps, 1.0)
    d_kl = jnp.sum(pc * jnp.log(pc / qc))
    return alpha * d_m + beta * d_kl


def diversity_insert_step(states, probs, score, filled, s_sum, s_outer,
                          p_sum, n_filled, cand_state, cand_probs, *,
                          alpha, beta, ridge=0.1):
    """One streaming insert: score -> slot choice -> rank-1 moment update.

    Eviction semantics match the recompute oracle exactly: first empty slot
    if any, else the min-score filled slot iff the candidate scores higher.
    On insert the moments gain the candidate's rank-1 contribution; on
    eviction of a filled slot they lose the old occupant's.

    Returns ((states, probs, score, filled, s_sum, s_outer, p_sum,
    n_filled), (slot, do_insert, score_of_candidate))."""
    d = diversity_score_from_moments(cand_state, cand_probs, s_sum, s_outer,
                                     p_sum, n_filled, alpha=alpha, beta=beta,
                                     ridge=ridge)
    has_empty = ~jnp.all(filled)
    empty_idx = jnp.argmin(filled)                # first unfilled slot
    min_idx = jnp.argmin(jnp.where(filled, score, jnp.inf))
    idx = jnp.where(has_empty, empty_idx, min_idx)
    do = has_empty | (d > score[min_idx])

    old_s, old_p = states[idx], probs[idx]
    evict = do & filled[idx]
    add = do.astype(s_sum.dtype)
    sub = evict.astype(s_sum.dtype)
    s_sum = s_sum + add * cand_state - sub * old_s
    s_outer = (s_outer + add * jnp.outer(cand_state, cand_state)
               - sub * jnp.outer(old_s, old_s))
    p_sum = p_sum + add * cand_probs - sub * old_p
    n_filled = (n_filled + do.astype(n_filled.dtype)
                - evict.astype(n_filled.dtype))

    states = jnp.where(do, states.at[idx].set(cand_state), states)
    probs = jnp.where(do, probs.at[idx].set(cand_probs), probs)
    score = jnp.where(do, score.at[idx].set(d), score)
    filled = jnp.where(do, filled.at[idx].set(True), filled)
    return (states, probs, score, filled, s_sum, s_outer, p_sum, n_filled), \
        (idx, do, d)


# ---------------------------------------------------------------------------
# Federated delta codec (fl transport) — shared math + jnp oracle
# ---------------------------------------------------------------------------
# Single source of truth for every int8/top-k encode/decode in the repo: the
# FL transport subsystem (``repro.fl.codec``), the DP gradient compression
# (``repro.training.compression`` re-exports ``quantize_int8`` /
# ``dequantize_int8`` from here), the jnp oracle (``delta_codec_ref``), and
# the fused Pallas ``delta_codec`` kernel body all call these helpers, so the
# implementations cannot drift. Everything is plain vector ops (no gather-
# heavy argsort) so the same code is legal inside jit, vmap, lax.scan, and a
# Pallas kernel.

DELTA_CODECS = ("float32", "int8", "topk")


def int8_scale(xf):
    """Per-tensor symmetric int8 scale: max|x|/127, floored away from 0.

    Written as an explicit multiply by the reciprocal constant: XLA applies
    the div-by-constant -> mul-by-reciprocal rewrite in some compilation
    contexts (e.g. inside a Pallas kernel) but not others, which would put
    the kernel and the op-by-op oracle one ulp apart on the scale and break
    bit-identity everywhere downstream."""
    return jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) * (1.0 / 127.0)


def quantize_int8(x):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = int8_scale(xf)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def int8_roundtrip(xf):
    """quantize -> dequantize without materializing the int8 array (the
    values stay integer-valued float32, bit-identical to casting through
    int8 — asserted in tests/test_fl.py). Returns (decoded, scale)."""
    scale = int8_scale(xf)
    return jnp.clip(jnp.round(xf / scale), -127.0, 127.0) * scale, scale


def topk_mask(mag, k: int):
    """(n,) bool mask selecting EXACTLY the k largest-magnitude entries,
    ties broken by lowest index. Sort + cumsum only — no argsort scatter —
    so the same code runs inside the Pallas kernel body."""
    n = mag.shape[0]
    if k >= n:
        return jnp.ones((n,), bool)
    thresh = jnp.sort(mag)[n - k]                 # k-th largest value
    above = mag > thresh
    n_above = jnp.sum(above.astype(jnp.int32))
    eq = mag == thresh
    take_eq = eq & (jnp.cumsum(eq.astype(jnp.int32)) <= k - n_above)
    return above | take_eq


def delta_codec_step(xf, *, codec: str, k: int = 1):
    """Encode->decode one flat error-compensated delta ``xf = delta + r``.

    Returns (decoded, new_residual) with ``decoded + new_residual == xf``
    — the telescoping identity error feedback relies on; bit-exact for
    float32/topk, within one ulp of the quantization scale for int8:
      * ``float32`` — lossless: decoded = xf, residual 0.
      * ``int8``    — per-tensor symmetric quantization round trip.
      * ``topk``    — keep the k largest-|.| coordinates exactly, zero the
        rest; the untransmitted mass is the residual.
    """
    if codec == "float32":
        return xf, jnp.zeros_like(xf)
    if codec == "int8":
        # The residual is (frac - q) * scale, NOT xf - q*scale: the latter
        # is an FMA-contractible a*b-c pattern that XLA fuses inside the
        # Pallas kernel but not in the op-by-op oracle, breaking
        # kernel==oracle bit-identity. (frac - q)*scale has the subtract
        # before the multiply — no contraction applies — and equals
        # xf - dec to one ulp of xf (frac*scale == xf up to two roundings).
        scale = int8_scale(xf)
        frac = xf / scale
        q = jnp.clip(jnp.round(frac), -127.0, 127.0)
        return q * scale, (frac - q) * scale
    if codec == "topk":
        mask = topk_mask(jnp.abs(xf), k)
        # residual via select, not subtraction: exact in both regimes
        return jnp.where(mask, xf, 0.0), jnp.where(mask, 0.0, xf)
    raise ValueError(f"unknown codec {codec!r}; expected one of {DELTA_CODECS}")


def delta_codec_ref(delta, residual, *, codec: str, k: int = 1):
    """jnp oracle for the fused Pallas ``delta_codec`` kernel: one agent's
    flat (L,) parameter delta through error feedback + encode + decode
    (vmap for a fleet). Returns (decoded, new_residual)."""
    return delta_codec_step(delta + residual, codec=codec, k=k)


# ---------------------------------------------------------------------------
# Request-level data-plane microtick (digital twin) — shared math + jnp oracle
# ---------------------------------------------------------------------------
# The twin keeps each agent's in-flight requests in a power-of-two ring whose
# occupancy is described by MONOTONE int32 request counters rather than mod-R
# pointers: because every request passes admit -> pre -> batch-form ->
# inference -> post in order and every stage serves FIFO, each stage's
# occupants are a CONTIGUOUS ring segment and the whole per-agent queue state
# is five counters (head <= p_inf <= launch <= p_pre <= tail). Stage
# membership is positional, a request's deadline is arrive + slo_ticks, and
# ring slot ``i`` holds request number ``q`` iff q ≡ i (mod R) — so admission
# and completion are mask writes/reads over ((i - ptr) & (R-1)) < n, never a
# sort or a scatter. ``sim_microtick`` below is the single source of truth:
# the jnp oracle (``queue_advance_ref``), the Pallas ``queue_advance`` kernel
# body, and the harness all call it, so the implementations cannot drift.

# counters vector layout (int32): five stage pointers (monotone request
# counts), the inference-server occupancy flag + completion tick, four
# request accumulators, and the global microtick counter.
(SIM_TAIL, SIM_PPRE, SIM_LAUNCH, SIM_PINF, SIM_HEAD, SIM_BUSY, SIM_DONE_AT,
 SIM_ARRIVED, SIM_DROPPED, SIM_COMPLETED, SIM_EFFECTIVE, SIM_TICK) = range(12)
SIM_NCOUNTERS = 12

# caps vector layout (float32; integer-valued entries cast inside the tick):
# pre/post service capacity per tick, requests per inference batch, batch
# service time in ticks, per-stage queue capacity, SLO deadline in ticks.
CAP_PRE, CAP_POST, CAP_BATCH, CAP_TBATCH, CAP_QCAP, CAP_SLO = range(6)
SIM_NCAPS = 6


def _iota(n):
    # 1D iota via broadcasted_iota — a plain 1D ``jax.lax.iota`` fails to
    # lower inside a Pallas TPU kernel (vector lanes want >= 2D).
    return jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)[:, 0]


def sim_microtick(arrive, counters, credits, lat_sum, hist, n_arrive, caps):
    """One microtick of the request-level pipeline, pure array ops.

    arrive: (R,) int32 ring of arrival ticks; counters: (SIM_NCOUNTERS,)
    int32; credits: (2,) float32 fractional pre/post service tokens;
    lat_sum: () float32; hist: (H,) int32 completed-latency histogram in
    ticks; n_arrive: () int32 arrivals this tick; caps: (SIM_NCAPS,) float32.

    Stage order is a backward sweep (complete -> post -> launch -> pre ->
    admit) so a request spends >= 1 tick per stage; pre/post are token-bucket
    servers (bucket depth = capacity + 1 so idle periods cannot bank
    unbounded service); the inference server runs ONE batch at a time and
    launches work-conserving (whatever is ready, up to the batch size and
    the post-queue room — backpressure instead of post drops, which keeps
    the ring segments contiguous); admission drops overflow beyond the
    bounded pre queue. Deadline check: a completion at end-of-tick m has
    latency m + 1 - arrive ticks and counts as effective iff it is within
    slo_ticks. Python mirror: ``repro.sim.oracle`` (built on serving/slo.py).
    """
    ring = arrive.shape[0]
    assert ring > 0 and ring & (ring - 1) == 0, \
        "ring capacity must be a positive power of two"
    hist_n = hist.shape[0]
    idx = _iota(ring)
    c = counters
    m = c[SIM_TICK]

    c_pre, c_post = caps[CAP_PRE], caps[CAP_POST]
    batch_slots = caps[CAP_BATCH].astype(jnp.int32)
    t_batch = caps[CAP_TBATCH].astype(jnp.int32)
    qcap = caps[CAP_QCAP].astype(jnp.int32)
    slo_ticks = caps[CAP_SLO].astype(jnp.int32)

    # (1) inference completion: the in-flight batch lands in the post queue.
    done = (c[SIM_BUSY] > 0) & (m >= c[SIM_DONE_AT])
    p_inf = jnp.where(done, c[SIM_LAUNCH], c[SIM_PINF])
    busy = jnp.where(done, 0, c[SIM_BUSY])

    # (2) post-processing serves the n oldest post-queue requests; their
    # latencies feed the accumulators and the histogram.
    # (credits stay >= 0, so the int32 cast truncates == floor)
    post_credit = jnp.minimum(credits[1] + c_post, c_post + 1.0)
    n_post = jnp.minimum(post_credit.astype(jnp.int32),
                         p_inf - c[SIM_HEAD])
    post_credit = post_credit - n_post.astype(jnp.float32)
    comp = ((idx - c[SIM_HEAD]) & (ring - 1)) < n_post
    lat = m + 1 - arrive
    lat_sum = lat_sum + jnp.sum(jnp.where(comp, lat, 0)).astype(jnp.float32)
    n_eff = jnp.sum(comp & (lat <= slo_ticks), dtype=jnp.int32)
    # non-completed slots bucket to the out-of-range sentinel hist_n
    bucket = jnp.where(comp, jnp.clip(lat, 0, hist_n - 1), hist_n)
    hist = hist + jnp.sum(bucket[:, None] == _iota(hist_n)[None, :],
                          axis=0, dtype=jnp.int32)
    head = c[SIM_HEAD] + n_post

    # (3) batch launch: work-conserving, backpressured by post-queue room
    # (room counts everything at/after inference not yet post-completed, so
    # the post queue can never exceed qcap and never needs to drop).
    ready = c[SIM_PPRE] - c[SIM_LAUNCH]
    room = qcap - (c[SIM_LAUNCH] - head)
    n_launch = jnp.maximum(
        jnp.minimum(jnp.minimum(ready, batch_slots), room), 0)
    do_launch = (busy == 0) & (n_launch > 0)
    launch = jnp.where(do_launch, c[SIM_LAUNCH] + n_launch, c[SIM_LAUNCH])
    done_at = jnp.where(do_launch, m + t_batch, c[SIM_DONE_AT])
    busy = jnp.where(do_launch, 1, busy)

    # (4) pre-processing, backpressured by batch-formation queue room.
    pre_credit = jnp.minimum(credits[0] + c_pre, c_pre + 1.0)
    n_pre = jnp.minimum(
        pre_credit.astype(jnp.int32),
        jnp.minimum(c[SIM_TAIL] - c[SIM_PPRE],
                    jnp.maximum(qcap - (c[SIM_PPRE] - launch), 0)))
    n_pre = jnp.maximum(n_pre, 0)
    pre_credit = pre_credit - n_pre.astype(jnp.float32)
    p_pre = c[SIM_PPRE] + n_pre

    # (5) admission into the bounded pre queue; overflow drops. Each stage
    # queue is <= qcap, so with ring >= 3*qcap the ring bound never binds.
    free = jnp.minimum(qcap - (c[SIM_TAIL] - p_pre),
                       ring - (c[SIM_TAIL] - head))
    admit = jnp.clip(jnp.minimum(n_arrive, free), 0, n_arrive)
    adm = ((idx - c[SIM_TAIL]) & (ring - 1)) < admit
    arrive = jnp.where(adm, m, arrive)
    tail = c[SIM_TAIL] + admit

    counters = jnp.stack([
        tail, p_pre, launch, p_inf, head, busy, done_at,
        c[SIM_ARRIVED] + n_arrive, c[SIM_DROPPED] + (n_arrive - admit),
        c[SIM_COMPLETED] + n_post, c[SIM_EFFECTIVE] + n_eff, m + 1])
    credits = jnp.stack([pre_credit, post_credit])
    return arrive, counters, credits, lat_sum, hist


def queue_advance_ref(arrive, counters, credits, lat_sum, hist, arrivals,
                      caps):
    """jnp oracle for the fused Pallas ``queue_advance`` kernel: advance ONE
    agent's data plane K microticks (vmap for a fleet).

    arrivals: (K,) int32 per-tick arrival counts; caps: (SIM_NCAPS,) float32
    (one action decode, held for the whole control interval). Returns the
    updated (arrive, counters, credits, lat_sum, hist)."""

    def tick(carry, n_arr):
        return sim_microtick(*carry, n_arr, caps), None

    carry, _ = jax.lax.scan(
        tick, (arrive, counters, credits, lat_sum, hist), arrivals)
    return carry


def diversity_insert_ref(states, probs, score, filled, s_sum, s_outer, p_sum,
                         n_filled, cand_states, cand_probs, *, alpha, beta,
                         ridge=0.1):
    """jnp oracle for the fused Pallas ``diversity_insert`` kernel: ingest T
    candidates sequentially (single agent; vmap for a fleet).

    cand_states: (T, D); cand_probs: (T, NA). Returns the updated
    (states, probs, score, filled, s_sum, s_outer, p_sum, n_filled) plus the
    per-candidate decision trace (slot (T,), do_insert (T,), d (T,)) the
    caller uses to scatter the non-scored payload (actions/rewards/...).

    The sequential scan carries only O(N) metadata — score and a per-slot
    *source map* (``-1`` = original occupant, ``t`` = candidate t) — plus
    the O(D²) moments. A slot's current occupant is gathered from the source
    map when its rank-1 contribution must be subtracted on eviction, and the
    (N, D)/(N, NA) slot arrays are materialized ONCE after the scan from the
    final map, instead of being copied through every scan step.

    The slot choice exploits the score invariant — empty slots hold −inf,
    filled slots a finite Eq. 6 value — so ``argmin(score)`` alone picks the
    first empty slot if any (all −inf ties resolve to the lowest index,
    matching ``argmin(filled)``) else the min-score filled slot, and
    ``d > min(score)`` is the insert test in both regimes (−inf accepts
    everything). ``filled`` therefore never enters the scan at all.
    Decision-for-decision identical to ``diversity_insert_step`` chained T
    times (tests/test_buffer.py)."""
    n = score.shape[0]

    def step(carry, x):
        score, src, s_sum, s_outer, p_sum, n_filled = carry
        s, p, t = x
        d = diversity_score_from_moments(s, p, s_sum, s_outer, p_sum,
                                         n_filled, alpha=alpha, beta=beta,
                                         ridge=ridge)
        minval = jnp.min(score)
        idx = jnp.argmin(score)
        do = d > minval                  # -inf (empty slot) accepts always
        evict = do & (minval != -jnp.inf)

        si = src[idx]
        old_s = jnp.where(si < 0, states[idx], cand_states[jnp.maximum(si, 0)])
        old_p = jnp.where(si < 0, probs[idx], cand_probs[jnp.maximum(si, 0)])
        add = do.astype(s_sum.dtype)
        sub = evict.astype(s_sum.dtype)
        carry = (
            score.at[idx].set(jnp.where(do, d, minval)),
            src.at[idx].set(jnp.where(do, t, si)),
            s_sum + add * s - sub * old_s,
            s_outer + add * jnp.outer(s, s) - sub * jnp.outer(old_s, old_s),
            p_sum + add * p - sub * old_p,
            n_filled + do.astype(n_filled.dtype)
            - evict.astype(n_filled.dtype),
        )
        return carry, (idx, do, d)

    init = (score, jnp.full((n,), -1, jnp.int32), s_sum, s_outer, p_sum,
            n_filled)
    xs = (cand_states, cand_probs, jnp.arange(cand_states.shape[0]))
    (score, src, s_sum, s_outer, p_sum, n_filled), (slot, do, d) = \
        jax.lax.scan(step, init, xs)

    written = src >= 0
    keep = (~written)[:, None]
    states = jnp.where(keep, states, cand_states[jnp.maximum(src, 0)])
    probs = jnp.where(keep, probs, cand_probs[jnp.maximum(src, 0)])
    filled = filled | written
    return states, probs, score, filled, s_sum, s_outer, p_sum, n_filled, \
        slot, do, d
