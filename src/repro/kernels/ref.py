"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, causal=True):
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D). Returns (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(k.shape[1])[None, :]
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, kv_len):
    """q: (B, 1, Hq, D); caches: (B, S_max, Hkv, D); kv_len: () or (B,).

    Single-query attention over the valid prefix of the cache."""
    b, _, hq, d = q.shape
    s_max, hkv = k_cache.shape[1], k_cache.shape[2]
    rep = hq // hkv
    k = jnp.repeat(k_cache, rep, axis=2) if rep > 1 else k_cache
    v = jnp.repeat(v_cache, rep, axis=2) if rep > 1 else v_cache
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    valid = jnp.arange(s_max)[None, :] < jnp.asarray(kv_len).reshape(-1, 1)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def pack_ref(tokens, indices):
    """tokens: (T, D); indices: (N,) int32 (negative = padding slot -> 0).

    The frame/token-packing gather: out[i] = tokens[indices[i]] or 0."""
    safe = jnp.clip(indices, 0, tokens.shape[0] - 1)
    out = tokens[safe]
    return jnp.where((indices >= 0)[:, None], out, 0).astype(tokens.dtype)
