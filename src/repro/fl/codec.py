"""Per-leaf delta codec over stacked fleet pytrees.

Clients transmit ``params - base`` deltas, encoded per-leaf with a
jit-static codec choice and per-agent error-feedback residuals: the
residual of every lossy round is carried in the Fleet pytree
(``fleet.residuals``) and added back before the next encode, which keeps
the *cumulative* transmitted delta unbiased (the telescoping identity
``Σ decoded_t + r_N == Σ delta_t + r_0`` holds to float roundoff per round,
bit-exact for topk — property-tested in tests/test_properties.py).

The per-coordinate math lives ONCE in ``repro.kernels.ref``
(``delta_codec_step`` / ``delta_codec_ref``); this module only reshapes
stacked (A, ...) leaves to flat (A, L) vectors and routes them through the
jnp oracle (default) or the fused Pallas ``delta_codec`` kernel
(``TransportConfig.use_pallas`` — bit-identical, interpret mode on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.fl.transport import TransportConfig, topk_k
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def codec_roundtrip(delta, residual, transport: TransportConfig):
    """Encode->decode a fleet's deltas with error feedback.

    delta, residual: matching pytrees of stacked (A, ...) float32 leaves.
    Returns (decoded, new_residual) pytrees of the same structure, with
    ``decoded + new_residual == delta + residual`` per leaf (to float
    roundoff; bit-exact for float32/topk)."""
    def one(d, r):
        a = d.shape[0]
        df = d.reshape(a, -1).astype(jnp.float32)
        rf = r.reshape(a, -1).astype(jnp.float32)
        k = topk_k(df.shape[1], transport.topk_frac)
        if transport.use_pallas:
            dec, nr = kops.delta_codec(df, rf, codec=transport.codec, k=k)
        else:
            dec, nr = jax.vmap(lambda x, y: kref.delta_codec_ref(
                x, y, codec=transport.codec, k=k))(df, rf)
        return dec.reshape(d.shape), nr.reshape(d.shape)

    # flatten/unflatten instead of an isinstance(tuple) is_leaf split so any
    # interior tuple/NamedTuple node in the params tree stays intact
    leaves_d, treedef = jax.tree.flatten(delta)
    pairs = [one(d, r) for d, r in zip(leaves_d, jax.tree.leaves(residual))]
    return (jax.tree.unflatten(treedef, [p[0] for p in pairs]),
            jax.tree.unflatten(treedef, [p[1] for p in pairs]))


def residuals_init(params):
    """Zero error-feedback residuals matching a (stacked) params pytree."""
    return jax.tree.map(lambda p: jnp.zeros(jnp.shape(p), jnp.float32),
                        params)
