"""Staleness-tolerant (async-FL) round semantics.

With a round deadline but *sync* rounds, a slow client simply drops out of
Eq. 7 selection — its work is wasted. Async rounds instead let the upload
finish late: a selected client that misses the deadline **parks** its
encoded delta in a server-side pending buffer (``fleet.pending``, a Fleet
pytree field — zero host work, lives inside the donated scan) and joins a
later round with a staleness-discounted weight
``staleness_decay ** staleness`` (FedAsync-style: the discounted delta is
folded into Algorithm 1 as a shrunk client contribution
``base + w · delta``, so the aggregation code itself is unchanged).

Bookkeeping per round (all masks are (A,) bool, resolved inside jit):

* ``fresh_sent`` — selected, Bernoulli-available AND on time: its fresh
  decoded delta actually crossed the wire, so any pending delta it still
  had is *superseded* (dropped — the upload carries strictly newer
  information).
* ``parked``     — selected, available, missed the deadline: its decoded
  delta (error feedback already applied) is parked with staleness 1.
* ``consumed``   — selected with a pending delta and no fresh arrival: the
  parked delta is used, discounted, and cleared.
* otherwise a pending delta ages: staleness += 1 — including when its
  owner is online and on time but simply lost Eq. 7 selection (nothing
  was uploaded, so there is nothing newer to supersede it).

A parked delta is expressed against the base network at park time; by
consumption the base has moved one-or-more aggregation steps — the
staleness discount is exactly the async-FL damping that keeps that drift
bounded.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class PendingDeltas(NamedTuple):
    """Server-side parked uploads, stacked over the agent axis."""
    delta: Any               # pytree like params, (A, ...) decoded deltas
    staleness: jnp.ndarray   # (A,) int32 — rounds the delta has waited
    has: jnp.ndarray         # (A,) bool — a delta is parked


def pending_init(params) -> PendingDeltas:
    a = jnp.shape(jax.tree.leaves(params)[0])[0]
    return PendingDeltas(
        delta=jax.tree.map(lambda p: jnp.zeros(jnp.shape(p), jnp.float32),
                           params),
        staleness=jnp.zeros((a,), jnp.int32),
        has=jnp.zeros((a,), bool),
    )


def _bmask(m, leaf):
    return m.reshape(m.shape + (1,) * (leaf.ndim - 1))


def validate_pending(pending: PendingDeltas):
    """Drop parked deltas that fail the finiteness check before anything
    consumes them (a poisoned upload parked in an earlier round must not
    resurface into aggregation later). Returns ``(pending, n_dropped)`` —
    the invalid slots are cleared from ``has`` so they are neither
    selectable nor consumable and age out of the buffer on the next
    ``update_pending``. The check is the identity on a healthy buffer."""
    from repro.resilience.guards import finite_mask

    ok = finite_mask(pending.delta)
    dropped = pending.has & ~ok
    return (pending._replace(has=pending.has & ok),
            jnp.sum(dropped).astype(jnp.float32))


def stale_weights(pending: PendingDeltas, decay: float) -> jnp.ndarray:
    """(A,) discount applied to a parked delta when it is consumed."""
    return jnp.asarray(decay, jnp.float32) ** pending.staleness


def merge_contributions(decoded, pending: PendingDeltas, fresh_ok,
                        w_stale):
    """Per-agent round contribution: the fresh decoded delta where it
    arrived, else the staleness-discounted parked delta."""
    return jax.tree.map(
        lambda d, p: jnp.where(_bmask(fresh_ok, d), d,
                               _bmask(w_stale, p) * p),
        decoded, pending.delta)


def update_pending(pending: PendingDeltas, decoded, parked, consumed,
                   fresh_sent) -> PendingDeltas:
    """Advance the pending buffer past one round (see module docstring).
    ``fresh_sent`` = selected AND on time — only an upload that actually
    happened supersedes a parked delta; an on-time owner that merely lost
    selection keeps (and ages) its pending delta."""
    kept = pending.has & ~consumed & ~fresh_sent
    return PendingDeltas(
        # decoded deltas come out of the codec in float32; parked copies are
        # stored at StatePolicy.transport precision (astype is the identity
        # under the f32 default)
        delta=jax.tree.map(
            lambda d, p: jnp.where(_bmask(parked, p), d.astype(p.dtype), p),
            decoded, pending.delta),
        staleness=jnp.where(parked, 1,
                            jnp.where(kept, pending.staleness + 1, 0)
                            ).astype(jnp.int32),
        has=parked | kept,
    )
