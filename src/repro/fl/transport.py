"""FL communication model: payload accounting, uplink times, round deadlines.

The pre-transport repo modeled FL communication as free — ``fed.aggregate``
read every client's full float32 parameters as if they had teleported to the
server, and stragglers were Bernoulli draws unrelated to any device
property. This module makes the wire explicit:

* **Payload accounting** — per-leaf encoded sizes for the three codecs
  (``repro.kernels.ref.DELTA_CODECS``): float32 (4 B/param), int8
  (1 B/param + one float32 scale per tensor), top-k (8 B per kept
  coordinate: float32 value + int32 index). Sizes are static given the
  codec and the parameter shapes, so they fold into the jitted round as
  constants.
* **Uplink model** — a client's upload takes ``payload_bits /
  bandwidth`` seconds against its per-agent link (``fleet.bandwidth``,
  Mbit/s). With a round deadline configured, a slow link *emergently*
  misses the round — it drops out of Eq. 7 selection (or, async mode,
  parks its delta: ``repro.fl.staleness``) — instead of being a coin flip.
  The legacy ``--straggler-prob`` Bernoulli mask composes on top: an agent
  participates iff it is Bernoulli-available AND on time.
* **Downlink model** — the float32 codec is the pre-transport
  parameter-server semantics (nothing tracks a shared base, so the server
  unicasts full fresh float32 parameters to every agent: A messages). The
  compressed codecs maintain a synchronized per-pod base network on both
  ends by construction, which is exactly what enables the downlink to be
  ONE encoded base-delta broadcast per pod (P messages; the per-group head
  deltas ride in the same envelope and are a small constant factor). This
  asymmetry is the systems payoff of delta coding and is what the
  ``fig_fl_comm`` ≥8× int8 round-payload reduction measures.

``TransportConfig`` is a frozen (hashable) dataclass so it threads through
``fl_round`` / ``train_fleet_scan`` as a jit-static argument; the default
config (float32 codec, no deadline, sync rounds) compiles to the exact
pre-transport program, reproducing earlier training runs seed-for-seed.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels.ref import DELTA_CODECS

CODECS = DELTA_CODECS


@dataclass(frozen=True)
class TransportConfig:
    """Jit-static description of one FL round's communication path.

    codec: on-wire delta encoding (``float32`` is lossless = the legacy
    path). topk_frac: fraction of coordinates kept per tensor by the top-k
    codec. deadline_s: round deadline in seconds; <= 0 disables the
    deadline (every upload makes it). async_rounds: staleness-tolerant
    semantics — a selected client that misses the deadline parks its
    encoded delta and joins the next round discounted by
    ``staleness_decay ** staleness``. use_pallas: route the codec through
    the fused Pallas ``delta_codec`` kernel instead of the jnp oracle."""
    codec: str = "float32"
    topk_frac: float = 0.05
    deadline_s: float = 0.0
    async_rounds: bool = False
    staleness_decay: float = 0.5
    use_pallas: bool = False

    def __post_init__(self):
        if self.codec not in CODECS:
            raise ValueError(f"unknown codec {self.codec!r}; expected one "
                             f"of {CODECS}")
        if not (0.0 < self.topk_frac <= 1.0):
            raise ValueError("topk_frac must be in (0, 1]")

    @property
    def plain(self) -> bool:
        """True when the round is semantically the legacy path: lossless
        codec and no parked deltas, so the server reconstruction
        ``base + decode(encode(params - base))`` is *identically* ``params``
        and the whole delta machinery is skipped (bit-for-bit pre-transport
        aggregation; a deadline may still shrink the selection)."""
        return self.codec == "float32" and not self.async_rounds


DEFAULT_TRANSPORT = TransportConfig()


# ---------------------------------------------------------------------------
# Payload accounting (static)
# ---------------------------------------------------------------------------
def topk_k(size: int, frac: float) -> int:
    """Per-tensor top-k budget: ceil(frac * size), at least 1."""
    return max(1, int(math.ceil(frac * size)))


def leaf_payload_bytes(size: int, codec: str, topk_frac: float) -> float:
    if codec == "float32":
        return 4.0 * size
    if codec == "int8":
        return float(size) + 4.0          # int8 values + one float32 scale
    if codec == "topk":
        return 8.0 * topk_k(size, topk_frac)   # float32 value + int32 index
    raise ValueError(f"unknown codec {codec!r}")


def _leaf_sizes(params, stacked: bool):
    return [int(math.prod(jnp.shape(p)[1:]) if stacked
                else math.prod(jnp.shape(p)))
            for p in jax.tree.leaves(params)]


def agent_payload_bytes(params, transport: TransportConfig, *,
                        stacked: bool = False) -> float:
    """Encoded uplink bytes for ONE agent's delta under ``transport``.
    ``stacked=True`` when ``params`` carries a leading agent axis."""
    return sum(leaf_payload_bytes(s, transport.codec, transport.topk_frac)
               for s in _leaf_sizes(params, stacked))


def full_param_bytes(params, *, stacked: bool = False) -> float:
    """Raw float32 size of one agent's parameters (the downlink unit for
    the legacy/float32 parameter-server path)."""
    return 4.0 * sum(_leaf_sizes(params, stacked))


def downlink_bytes(transport: TransportConfig, n_agents: int, n_pods: int,
                   up_bytes: float, full_bytes: float) -> float:
    """Server->client bytes per round. float32 codec: per-agent unicast of
    full fresh parameters (pre-transport parameter-server semantics).
    Compressed codecs: one encoded base-delta broadcast per pod."""
    if transport.codec == "float32":
        return n_agents * full_bytes
    return n_pods * up_bytes


# ---------------------------------------------------------------------------
# Uplink / deadline model (traced)
# ---------------------------------------------------------------------------
def uplink_seconds(payload_bytes: float, bandwidth_mbps) -> jnp.ndarray:
    """(A,) upload time of one encoded delta over each agent's link."""
    return payload_bytes * 8.0 / (jnp.maximum(bandwidth_mbps, 1e-6) * 1e6)


def on_time_mask(uplink_s, deadline_s: float) -> jnp.ndarray:
    """(A,) bool: upload fits inside the round deadline. ``deadline_s <= 0``
    disables the deadline (static branch — no compute in the jitted round)."""
    if deadline_s <= 0:
        return jnp.ones(uplink_s.shape, bool)
    return uplink_s <= deadline_s


# ---------------------------------------------------------------------------
# Per-round metrics surfaced into the training history
# ---------------------------------------------------------------------------
FL_METRIC_KEYS = ("fl_payload_bytes", "fl_uplink_s", "fl_missed",
                  "fl_stale_used", "fl_rejected", "fl_clipped")


def fl_zero_metrics() -> Dict[str, jnp.ndarray]:
    """The all-zeros FL metric dict emitted on episodes without a round
    (both drivers emit the same structure so histories stay comparable)."""
    return {k: jnp.zeros((), jnp.float32) for k in FL_METRIC_KEYS}
