"""Federated transport subsystem: compressed, communication-aware,
staleness-tolerant FL rounds.

Makes FL communication a first-class, simulated part of every round of the
scanned fleet driver: clients transmit ``params - base`` deltas encoded
per-leaf (float32 / int8 / top-k, jit-static) with error-feedback residuals
carried in the Fleet pytree (``repro.fl.codec``); uplink time = encoded
payload bits / per-agent bandwidth against a configurable round deadline,
so stragglers are *emergent* (``repro.fl.transport``); and a missed
deadline can park the delta for a staleness-discounted join next round
(``repro.fl.staleness``). Wired through ``core.fleet.fl_round`` /
``train_fleet_scan`` — the whole cadence stays ONE jitted donated scan —
and benchmarked by ``benchmarks/fig_fl_comm.py``.
"""
from repro.fl.codec import codec_roundtrip, residuals_init  # noqa: F401
from repro.fl.staleness import (PendingDeltas, merge_contributions,  # noqa: F401
                                pending_init, stale_weights,
                                update_pending, validate_pending)
from repro.fl.transport import (CODECS, DEFAULT_TRANSPORT,  # noqa: F401
                                FL_METRIC_KEYS, TransportConfig,
                                agent_payload_bytes, downlink_bytes,
                                fl_zero_metrics, full_param_bytes,
                                leaf_payload_bytes, on_time_mask, topk_k,
                                uplink_seconds)
