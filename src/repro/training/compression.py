"""Error-feedback int8 gradient compression for the DP all-reduce.

Beyond-paper distributed-optimization trick: inside a ``shard_map`` train
step, per-tensor-scaled int8 quantization is applied before the data-parallel
``psum`` and the quantization residual is carried in the optimizer state
(error feedback), which keeps SGD/Adam convergence unbiased to first order.
This cuts DP gradient all-reduce bytes 4x (fp32) / 2x (bf16).

Used by ``launch/train.py --grad-compression`` and benchmarked in
EXPERIMENTS.md §Perf (collective-bytes term).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# The int8 scalar math lives ONCE in ``repro.kernels.ref`` — shared verbatim
# with the FL transport delta codec (``repro.fl.codec``) and the fused Pallas
# ``delta_codec`` kernel, so the two compression paths cannot drift
# (equivalence regression: tests/test_fl.py). Note: the shared scale is
# computed as ``max|x| * (1/127)`` (kernel/oracle bit-identity), one ulp off
# the pre-unification ``max|x| / 127`` — gradient trajectories from older
# DP-compressed runs reproduce to that tolerance, not bit-for-bit.
from repro.kernels.ref import dequantize_int8, quantize_int8  # noqa: F401


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_psum(grads, residuals, axis_name):
    """int8 + error-feedback all-reduce over ``axis_name``.

    Returns (mean_grads, new_residuals). Call inside shard_map.
    """
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale = quantize_int8(gf)
        deq = dequantize_int8(q, scale)
        new_r = gf - deq  # local quantization error, fed back next step
        # int8 payloads sum on the wire; scales are tiny fp32 scalars
        summed = jax.lax.psum(q.astype(jnp.float32) * scale, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return summed / n, new_r

    out = jax.tree.map(one, grads, residuals)
    mean = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return mean, res
