"""Error-feedback int8 gradient compression for the DP all-reduce.

Beyond-paper distributed-optimization trick: inside a ``shard_map`` train
step, per-tensor-scaled int8 quantization is applied before the data-parallel
``psum`` and the quantization residual is carried in the optimizer state
(error feedback), which keeps SGD/Adam convergence unbiased to first order.
This cuts DP gradient all-reduce bytes 4x (fp32) / 2x (bf16).

Used by ``launch/train.py --grad-compression`` and benchmarked in
EXPERIMENTS.md §Perf (collective-bytes term).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(x):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_psum(grads, residuals, axis_name):
    """int8 + error-feedback all-reduce over ``axis_name``.

    Returns (mean_grads, new_residuals). Call inside shard_map.
    """
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale = quantize_int8(gf)
        deq = dequantize_int8(q, scale)
        new_r = gf - deq  # local quantization error, fed back next step
        # int8 payloads sum on the wire; scales are tiny fp32 scalars
        summed = jax.lax.psum(q.astype(jnp.float32) * scale, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return summed / n, new_r

    out = jax.tree.map(one, grads, residuals)
    mean = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return mean, res
