"""Training step: loss, remat, microbatch gradient accumulation.

``make_train_step`` builds a pure function ``(state, batch) -> (state,
metrics)`` suitable for ``jax.jit`` with in/out shardings from
``distributed/sharding.py``. Microbatching splits the global batch on the
leading axis and accumulates grads with ``lax.scan`` (activation memory /
throughput trade-off — a §Perf knob).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.registry import Model
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def cross_entropy(logits, labels, mask=None, impl="gather"):
    logits = logits.astype(jnp.float32)
    if impl == "sharded":
        # Vocab-shard-friendly CE: no take_along_axis over the sharded vocab
        # dim (which makes GSPMD all-gather the full (B,S,V) logits). The
        # gold logit comes from a fused compare+select+reduce that contracts
        # the vocab dim locally; only (B,S)-sized partials cross the wire.
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        logz = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
        idx = jnp.arange(logits.shape[-1], dtype=labels.dtype)
        hit = labels[..., None] == idx
        gold = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    else:
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)


def make_loss_fn(model: Model, moe_aux_weight: float = 0.01, remat: bool = True):
    cfg = model.cfg

    def loss_fn(params, batch):
        logits, _, aux = model.apply(params, batch, remat=remat)
        if cfg.shard_activations:
            from repro.distributed.sharding import BATCH, shard_hint
            logits = shard_hint(logits, list(BATCH), [], ["model"])
        if cfg.causal and "labels" in batch:
            # next-token prediction: shift
            loss = cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                                 impl=cfg.ce_impl)
        elif "mask" in batch:  # masked-unit prediction (hubert)
            loss = cross_entropy(logits, batch["labels"], batch["mask"],
                                 impl=cfg.ce_impl)
        else:
            loss = cross_entropy(logits, batch["labels"], impl=cfg.ce_impl)
        total = loss + moe_aux_weight * aux["moe_aux"]
        return total, {"ce": loss, "moe_aux": aux["moe_aux"]}

    return loss_fn


def init_train_state(model: Model, key):
    params = model.init(key)
    return {"params": params, "opt": adamw_init(params)}


def make_train_step(model: Model, opt_cfg: AdamWConfig = AdamWConfig(),
                    microbatches: int = 1, remat: bool = True,
                    moe_aux_weight: float = 0.01):
    loss_fn = make_loss_fn(model, moe_aux_weight, remat)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: Dict[str, Any], batch: Dict[str, Any]):
        params = state["params"]
        if microbatches == 1:
            (loss, extras), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0
                return x.reshape((microbatches, b // microbatches) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, l_sum), _ = jax.lax.scan(acc_body, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: g / microbatches, g_sum)
            loss = l_sum / microbatches
            extras = {"ce": loss, "moe_aux": jnp.zeros((), jnp.float32)}

        new_params, new_opt, om = adamw_update(opt_cfg, params, grads, state["opt"])
        # Self-healing: a non-finite loss or a NaN/Inf anywhere in the updated
        # params rejects the whole step — params AND opt state keep their old
        # values (branchless, so the jitted graph is unchanged) and the
        # rejection is counted instead of poisoning every later step.
        ok = jnp.isfinite(loss)
        for leaf in jax.tree_util.tree_leaves(new_params):
            ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(leaf)))
        keep = lambda new, old: jnp.where(ok, new, old)
        new_params = jax.tree.map(keep, new_params, params)
        new_opt = jax.tree.map(keep, new_opt, state["opt"])
        metrics = {"loss": loss, **extras, **om,
                   "update_rejected": (~ok).astype(jnp.float32)}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
