"""AdamW in pure JAX (no optax dependency).

Optimizer state mirrors the param pytree (same shapes ⇒ same shardings ⇒
ZeRO-style fully-sharded optimizer states for free under the param sharding
rules). Includes global-norm clipping and a cosine-with-warmup schedule.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, 0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
