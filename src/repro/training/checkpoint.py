"""Fault-tolerant checkpointing with elastic restore.

Format: one ``.npz`` per save (arrays keyed by pytree path) plus a JSON
manifest (step, arch, mesh shape, partition specs). ``restore`` device_puts
onto *whatever mesh the restoring job has* — the mesh shape at save time does
not constrain the mesh at restore time (elastic rescale: checkpoints are
logical, sharding is re-applied from the current rules).

Saves are atomic (tmp file + rename) so a crash mid-save never corrupts the
latest checkpoint; ``latest_step`` scans for the newest complete manifest.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, state, extra: Optional[Dict[str, Any]] = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(state)
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    arrays_path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    os.replace(tmp, arrays_path)
    manifest = {
        "step": step,
        "arrays": os.path.basename(arrays_path),
        "keys": sorted(flat),
        # np.savez stores non-native dtypes (bf16 lean-state leaves) as raw
        # void bytes; the true dtypes ride the manifest so restore can view
        # them back even into a different target dtype (elastic restore
        # across state policies)
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
    }
    mtmp = arrays_path + ".manifest.tmp"
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(mtmp, os.path.join(ckpt_dir, f"step_{step:08d}.json"))
    return arrays_path


def _complete_steps(ckpt_dir: str):
    """Step numbers of every *complete* checkpoint: a parseable manifest
    whose ``.npz`` arrays file exists, is non-empty, and starts with a zip
    header. Half-deleted or torn checkpoint dirs (a crash mid-prune, a
    full disk) simply don't list."""
    steps = []
    for f in os.listdir(ckpt_dir):
        if not (f.startswith("step_") and f.endswith(".json")):
            continue
        try:
            step = int(f[len("step_"):-len(".json")])
        except ValueError:
            continue
        try:
            with open(os.path.join(ckpt_dir, f)) as fh:
                manifest = json.load(fh)
            arrays = os.path.join(ckpt_dir, manifest["arrays"])
            with open(arrays, "rb") as fh:
                magic = fh.read(4)
        except (OSError, json.JSONDecodeError, KeyError, TypeError):
            continue
        if magic != b"PK\x03\x04":  # npz is a zip; torn writes fail here
            continue
        steps.append(step)
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest complete checkpoint step, or None. Manifests whose arrays
    file is missing or unreadable are skipped, so auto-resume after a crash
    lands on the newest checkpoint that can actually be restored."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = _complete_steps(ckpt_dir)
    return steps[-1] if steps else None


def keep_last(ckpt_dir: str, n: int) -> int:
    """Prune all but the newest ``n`` complete checkpoints (manifest +
    arrays). Long chaos runs checkpoint frequently; this bounds the disk
    footprint. Returns the number of checkpoints removed."""
    if n < 1:
        raise ValueError(f"keep_last needs n >= 1, got {n}")
    if not os.path.isdir(ckpt_dir):
        return 0
    doomed = _complete_steps(ckpt_dir)[:-n]
    for step in doomed:
        for suffix in (".npz", ".json"):
            try:
                os.remove(os.path.join(ckpt_dir, f"step_{step:08d}{suffix}"))
            except FileNotFoundError:
                pass
    return len(doomed)


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). If ``shardings`` (a matching pytree of NamedSharding)
    is given, arrays are placed sharded — onto the *current* mesh, which may
    differ from the mesh at save time (elastic restore).

    Corrupt checkpoints raise a ``ValueError`` naming the offending file
    (instead of a raw ``zipfile``/``np.load`` exception from deep inside
    numpy); a missing manifest raises ``FileNotFoundError``."""
    mpath = os.path.join(ckpt_dir, f"step_{step:08d}.json")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except FileNotFoundError:
        raise FileNotFoundError(
            f"no checkpoint manifest at {mpath} — wrong step or dir? "
            f"(latest complete step: {latest_step(ckpt_dir)})")
    except json.JSONDecodeError as e:
        raise ValueError(f"corrupt checkpoint manifest {mpath}: {e}")
    if not isinstance(manifest, dict) or "arrays" not in manifest:
        raise ValueError(f"corrupt checkpoint manifest {mpath}: missing "
                         f"'arrays' entry")
    apath = os.path.join(ckpt_dir, manifest["arrays"])
    try:
        data = np.load(apath)
        data.keys()  # force the zip directory read so corruption fails HERE
    except FileNotFoundError:
        raise ValueError(
            f"checkpoint arrays file {apath} is missing (named by manifest "
            f"{mpath}; the dir is half-deleted) — restore an older step or "
            f"re-save")
    except Exception as e:  # zipfile.BadZipFile, OSError, pickle errors, ...
        raise ValueError(f"corrupt checkpoint arrays file {apath}: {e}")

    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = []
    for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]:
        keys.append("/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path))
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(keys))

    missing = [k for k in keys if k not in data]
    if missing:
        raise ValueError(
            f"checkpoint/model structure mismatch: {len(missing)} leaves of "
            f"the restore target are absent from the checkpoint (e.g. "
            f"{missing[:3]}) — the checkpoint likely predates fields added "
            f"to the state pytree (such as the FL transport residuals/"
            f"pending buffers); re-save from a current run")

    out = []
    for key, leaf, shd in zip(keys, leaves_like, shard_leaves):
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"checkpoint/model shape mismatch at {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        want = np.dtype(leaf.dtype)
        if arr.dtype.kind == "V":
            # np.savez round-trips non-numpy-native dtypes (ml_dtypes
            # bfloat16 from a lean-state fleet) as raw void bytes; a view
            # under the true dtype (manifest "dtypes", falling back to the
            # target dtype for same-width pre-manifest saves) recovers the
            # values exactly, where astype would fail
            saved = manifest.get("dtypes", {}).get(key)
            true_dt = (np.dtype(jax.numpy.dtype(saved)) if saved
                       else want if arr.dtype.itemsize == want.itemsize
                       else None)
            if true_dt is None:
                raise ValueError(
                    f"cannot decode void-dtype leaf {key} ({arr.dtype}) "
                    f"into {want}: checkpoint predates dtype manifests")
            arr = arr.view(true_dt)
        arr = arr.astype(want)
        out.append(jax.device_put(arr, shd) if shd is not None else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest
