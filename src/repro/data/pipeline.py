"""Token/batch pipeline: deterministic synthetic streams for training and
serving (offline container — no external corpora).

Sequences are Zipf-distributed token streams with Markov locality so the
loss surface is non-trivial (a model must learn bigram structure to beat the
unigram floor); hubert gets frame embeddings + mask spans; pixtral gets
patch embeddings ahead of text. The pipeline is an infinite iterator of
ready-to-jit batches with a fixed host->device layout.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


def _zipf_probs(vocab: int, alpha: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return (p / p.sum()).astype(np.float64)


class TokenPipeline:
    """Markov-Zipf synthetic LM stream."""

    def __init__(self, cfg: ArchConfig, batch: int, seq: int, seed: int = 0,
                 locality: float = 0.3):
        self.cfg = cfg
        self.batch, self.seq = batch, seq
        self.rng = np.random.default_rng(seed)
        self.probs = _zipf_probs(min(cfg.vocab_size, 65536))
        self.vocab = len(self.probs)
        self.locality = locality

    def _sample_tokens(self, n) -> np.ndarray:
        flat = self.rng.choice(self.vocab, size=n, p=self.probs)
        # Markov locality: with prob `locality`, repeat/shift the previous token
        rep = self.rng.random(n) < self.locality
        shifted = np.roll(flat, 1)
        flat = np.where(rep, (shifted + 1) % self.vocab, flat)
        return flat.astype(np.int32)

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        return self

    def __next__(self) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        b, s = self.batch, self.seq
        batch: Dict[str, jnp.ndarray] = {}
        if cfg.frontend == "frames":
            emb = self.rng.standard_normal((b, s, cfg.frontend_dim)).astype(np.float32)
            mask = self.rng.random((b, s)) < 0.15
            # span masking (hubert masks ~10-frame spans)
            for _ in range(2):
                mask |= np.roll(mask, 1, axis=1)
            labels = self._sample_tokens(b * s).reshape(b, s) % cfg.vocab_size
            batch = {"embeds": jnp.asarray(emb), "mask": jnp.asarray(mask),
                     "labels": jnp.asarray(labels)}
            return batch
        toks = self._sample_tokens(b * s).reshape(b, s) % self.cfg.vocab_size
        batch["tokens"] = jnp.asarray(toks)
        batch["labels"] = jnp.asarray(toks)
        if cfg.frontend == "patches":
            patches = self.rng.standard_normal(
                (b, cfg.n_patches, cfg.frontend_dim)).astype(np.float32)
            batch["patches"] = jnp.asarray(patches)
        return batch


def request_stream(cfg: ArchConfig, rate_trace, max_len: int = 64,
                   seed: int = 0):
    """Serving request generator: at step t yields ~rate_trace[t] requests of
    random prompt lengths (video-frame analogue for the LM data plane)."""
    rng = np.random.default_rng(seed)
    probs = _zipf_probs(min(cfg.vocab_size, 8192))
    rid = 0
    for rate in np.asarray(rate_trace):
        n = rng.poisson(max(rate, 0.0))
        reqs = []
        for _ in range(int(n)):
            ln = int(rng.integers(4, max_len))
            toks = rng.choice(len(probs), size=ln, p=probs).astype(np.int32)
            reqs.append((rid, toks))
            rid += 1
        yield reqs
