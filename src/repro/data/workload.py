"""Workload (arrival-rate) trace generation — the video-stream analogue.

Traces model the paper's content dynamics (Fig. 2a): a base request rate per
stream (15 FPS × objects-per-frame), slow diurnal drift, scene-dependent
regimes that switch on context changes (road construction, camera pans), and
short bursts. ``switching_traces`` produces the Fig. 13-style concatenation
of 5-minute segments from different sources; ``ood_traces`` produces the
Fig. 10 out-of-distribution switch (AI-City-style different rate statistics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def smooth_noise(key, n, scale=1.0, corr=0.9):
    """AR(1) noise — smooth rate wander."""
    eps = jax.random.normal(key, (n,)) * scale

    def step(carry, e):
        x = corr * carry + (1 - corr) * e
        return x, x

    _, xs = jax.lax.scan(step, 0.0, eps)
    return xs


def make_trace(key, n_steps: int, base_rate: float = 30.0,
               regime_period: int = 120, regime_scale: float = 0.5,
               burst_prob: float = 0.02, burst_scale: float = 3.0):
    """One stream's arrival-rate trace (requests per control interval)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    t = jnp.arange(n_steps)
    # scene regimes: piecewise-constant multipliers
    n_regimes = n_steps // regime_period + 1
    regime_mult = 1.0 + regime_scale * (
        jax.random.uniform(k1, (n_regimes,)) * 2 - 1)
    regimes = regime_mult[t // regime_period]
    # diurnal-ish slow sine
    slow = 1.0 + 0.25 * jnp.sin(2 * jnp.pi * t / max(n_steps, 1) * 2.0)
    # AR noise
    noise = 1.0 + smooth_noise(k2, n_steps, scale=0.4)
    # bursts (event spikes)
    bursts = jnp.where(jax.random.uniform(k3, (n_steps,)) < burst_prob,
                       burst_scale, 1.0)
    rate = base_rate * regimes * slow * noise * bursts
    return jnp.clip(rate, 1.0, 400.0)


def fleet_traces(key, n_agents: int, n_steps: int, base_rate: float = 30.0,
                 heterogeneity: float = 0.5, **trace_kw):
    """(A, n_steps) traces with per-agent base rates (workload heterogeneity).
    Extra kwargs flow to ``make_trace`` (regime/burst dynamics)."""
    kb, kt = jax.random.split(key)
    bases = base_rate * (1.0 + heterogeneity * (
        jax.random.uniform(kb, (n_agents,)) * 2 - 1))
    keys = jax.random.split(kt, n_agents)
    return jax.vmap(lambda k, b: make_trace(k, n_steps, b, **trace_kw))(keys, bases)


# Fig. 2a-grade content dynamics (3-10x swings): used by the fig7/9/10
# benchmarks so runtime conditions genuinely differ from profiling data.
DYNAMIC = dict(regime_scale=0.9, burst_prob=0.05, burst_scale=4.0)
# Narrow profiling distribution (what an offline-trained agent sees).
PROFILING = dict(regime_scale=0.05, burst_prob=0.0)


def switching_traces(key, n_agents: int, n_steps: int, segment: int = 60,
                     base_rates=(15.0, 45.0, 90.0)):
    """Fig. 13: concatenated segments from drastically different sources.
    Every ``segment`` steps the underlying distribution switches."""
    rates = jnp.asarray(base_rates)
    k1, k2 = jax.random.split(key)
    n_seg = n_steps // segment + 1
    seg_src = jax.random.randint(k1, (n_agents, n_seg), 0, len(base_rates))
    t = jnp.arange(n_steps)
    base = rates[seg_src[:, t // segment]]                  # (A, n_steps)
    keys = jax.random.split(k2, n_agents)
    noise = jax.vmap(lambda k: 1.0 + smooth_noise(k, n_steps, 0.3))(keys)
    return jnp.clip(base * noise, 1.0, 400.0)


def ood_traces(key, n_agents: int, n_steps: int):
    """Fig. 10: out-of-distribution workload (different rate stats + burst
    structure, AI-City-style 10 FPS vehicle-tracking)."""
    kb, kt = jax.random.split(key)
    bases = 60.0 * (1.0 + 0.8 * (jax.random.uniform(kb, (n_agents,)) * 2 - 1))
    keys = jax.random.split(kt, n_agents)
    return jax.vmap(lambda k, b: make_trace(
        k, n_steps, b, regime_period=30, regime_scale=1.0,
        burst_prob=0.08, burst_scale=2.0))(keys, bases)


# Spiky event-camera workload: frequent short multi-x spikes on a moderate
# base — stresses admission control and the deadline tail.
BURST = dict(regime_scale=0.3, burst_prob=0.15, burst_scale=5.0)


def diurnal_traces(key, n_agents: int, n_steps: int, base_rate: float = 40.0,
                   amplitude: float = 0.7, cycles: float = 1.0):
    """Day/night load cycle: a deep sinusoid (peak ≈ (1+amplitude)·base,
    trough ≈ (1-amplitude)·base) with a per-agent phase offset (cameras in
    different timezones / street orientations) plus AR(1) wander."""
    kp, kb, kt = jax.random.split(key, 3)
    phases = jax.random.uniform(kp, (n_agents,)) * 2 * jnp.pi
    bases = base_rate * (1.0 + 0.3 * (
        jax.random.uniform(kb, (n_agents,)) * 2 - 1))
    t = jnp.arange(n_steps, dtype=jnp.float32)
    keys = jax.random.split(kt, n_agents)

    def one(k, b, ph):
        cycle = 1.0 + amplitude * jnp.sin(
            2 * jnp.pi * cycles * t / max(n_steps, 1) + ph)
        noise = 1.0 + smooth_noise(k, n_steps, scale=0.2)
        return jnp.clip(b * cycle * noise, 1.0, 400.0)

    return jax.vmap(one)(keys, bases, phases)


def flash_crowd_traces(key, n_agents: int, n_steps: int,
                       base_rate: float = 25.0, surge_mult: float = 6.0,
                       surge_frac: float = 0.25):
    """Flash crowd: steady load, then a sudden *sustained* surge (a viral
    event / accident on camera) of ``surge_frac`` of the horizon at
    ``surge_mult``× the base rate, starting at a per-agent random step —
    the regime an interval-granular scheduler reacts to a whole period
    late."""
    ks, kb, kt = jax.random.split(key, 3)
    surge_len = max(int(n_steps * surge_frac), 1)
    starts = jax.random.randint(ks, (n_agents,), n_steps // 8,
                                max(n_steps - surge_len, n_steps // 8 + 1))
    bases = base_rate * (1.0 + 0.3 * (
        jax.random.uniform(kb, (n_agents,)) * 2 - 1))
    t = jnp.arange(n_steps)
    keys = jax.random.split(kt, n_agents)

    def one(k, b, s0):
        in_surge = (t >= s0) & (t < s0 + surge_len)
        mult = jnp.where(in_surge, surge_mult, 1.0)
        noise = 1.0 + smooth_noise(k, n_steps, scale=0.25)
        return jnp.clip(b * mult * noise, 1.0, 400.0)

    return jax.vmap(one)(keys, bases, starts)


def drift_traces(key, n_agents: int, n_steps: int, start_rate: float = 15.0,
                 end_rate: float = 90.0):
    """Slow non-stationary drift: the base rate ramps monotonically from
    ``start_rate`` to ``end_rate`` over the horizon (seasonal content
    drift) — no single static configuration is right for the whole trace,
    and a frozen policy degrades monotonically."""
    kb, kt = jax.random.split(key)
    jitter = 1.0 + 0.25 * (jax.random.uniform(kb, (n_agents,)) * 2 - 1)
    t = jnp.arange(n_steps, dtype=jnp.float32)
    ramp = start_rate + (end_rate - start_rate) * t / max(n_steps - 1, 1)
    keys = jax.random.split(kt, n_agents)

    def one(k, j):
        noise = 1.0 + smooth_noise(k, n_steps, scale=0.25)
        return jnp.clip(ramp * j * noise, 1.0, 400.0)

    return jax.vmap(one)(keys, jitter)
