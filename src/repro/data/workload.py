"""Workload (arrival-rate) trace generation — the video-stream analogue.

Traces model the paper's content dynamics (Fig. 2a): a base request rate per
stream (15 FPS × objects-per-frame), slow diurnal drift, scene-dependent
regimes that switch on context changes (road construction, camera pans), and
short bursts. ``switching_traces`` produces the Fig. 13-style concatenation
of 5-minute segments from different sources; ``ood_traces`` produces the
Fig. 10 out-of-distribution switch (AI-City-style different rate statistics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def smooth_noise(key, n, scale=1.0, corr=0.9):
    """AR(1) noise — smooth rate wander."""
    eps = jax.random.normal(key, (n,)) * scale

    def step(carry, e):
        x = corr * carry + (1 - corr) * e
        return x, x

    _, xs = jax.lax.scan(step, 0.0, eps)
    return xs


def make_trace(key, n_steps: int, base_rate: float = 30.0,
               regime_period: int = 120, regime_scale: float = 0.5,
               burst_prob: float = 0.02, burst_scale: float = 3.0):
    """One stream's arrival-rate trace (requests per control interval)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    t = jnp.arange(n_steps)
    # scene regimes: piecewise-constant multipliers
    n_regimes = n_steps // regime_period + 1
    regime_mult = 1.0 + regime_scale * (
        jax.random.uniform(k1, (n_regimes,)) * 2 - 1)
    regimes = regime_mult[t // regime_period]
    # diurnal-ish slow sine
    slow = 1.0 + 0.25 * jnp.sin(2 * jnp.pi * t / max(n_steps, 1) * 2.0)
    # AR noise
    noise = 1.0 + smooth_noise(k2, n_steps, scale=0.4)
    # bursts (event spikes)
    bursts = jnp.where(jax.random.uniform(k3, (n_steps,)) < burst_prob,
                       burst_scale, 1.0)
    rate = base_rate * regimes * slow * noise * bursts
    return jnp.clip(rate, 1.0, 400.0)


def fleet_traces(key, n_agents: int, n_steps: int, base_rate: float = 30.0,
                 heterogeneity: float = 0.5, **trace_kw):
    """(A, n_steps) traces with per-agent base rates (workload heterogeneity).
    Extra kwargs flow to ``make_trace`` (regime/burst dynamics)."""
    kb, kt = jax.random.split(key)
    bases = base_rate * (1.0 + heterogeneity * (
        jax.random.uniform(kb, (n_agents,)) * 2 - 1))
    keys = jax.random.split(kt, n_agents)
    return jax.vmap(lambda k, b: make_trace(k, n_steps, b, **trace_kw))(keys, bases)


# Fig. 2a-grade content dynamics (3-10x swings): used by the fig7/9/10
# benchmarks so runtime conditions genuinely differ from profiling data.
DYNAMIC = dict(regime_scale=0.9, burst_prob=0.05, burst_scale=4.0)
# Narrow profiling distribution (what an offline-trained agent sees).
PROFILING = dict(regime_scale=0.05, burst_prob=0.0)


def switching_traces(key, n_agents: int, n_steps: int, segment: int = 60,
                     base_rates=(15.0, 45.0, 90.0)):
    """Fig. 13: concatenated segments from drastically different sources.
    Every ``segment`` steps the underlying distribution switches."""
    rates = jnp.asarray(base_rates)
    k1, k2 = jax.random.split(key)
    n_seg = n_steps // segment + 1
    seg_src = jax.random.randint(k1, (n_agents, n_seg), 0, len(base_rates))
    t = jnp.arange(n_steps)
    base = rates[seg_src[:, t // segment]]                  # (A, n_steps)
    keys = jax.random.split(k2, n_agents)
    noise = jax.vmap(lambda k: 1.0 + smooth_noise(k, n_steps, 0.3))(keys)
    return jnp.clip(base * noise, 1.0, 400.0)


def ood_traces(key, n_agents: int, n_steps: int):
    """Fig. 10: out-of-distribution workload (different rate stats + burst
    structure, AI-City-style 10 FPS vehicle-tracking)."""
    kb, kt = jax.random.split(key)
    bases = 60.0 * (1.0 + 0.8 * (jax.random.uniform(kb, (n_agents,)) * 2 - 1))
    keys = jax.random.split(kt, n_agents)
    return jax.vmap(lambda k, b: make_trace(
        k, n_steps, b, regime_period=30, regime_scale=1.0,
        burst_prob=0.08, burst_scale=2.0))(keys, bases)
