"""Request-grade latency attribution: the flight recorder's per-request
layer.

The twin's data plane is positional: every agent's five stage pointers
(``SIM_TAIL``/``SIM_PPRE``/``SIM_LAUNCH``/``SIM_PINF``/``SIM_HEAD``) are
monotone request counts, so admitted request ``q`` crossed stage ``S`` at
the first microtick whose post-tick pointer exceeds ``q``. Given the
per-tick counter series a ``simulate_fleet(..., record_ticks=True)`` run
emits, this module reconstructs every request's lifecycle stamps — admit ->
pre-done -> batch-launch -> infer-done -> complete — with a vectorized
``searchsorted`` per stage, no per-request Python.

From the stamps fall out the per-stage delay decomposition (queueing +
service at pre, batch-formation wait, inference, post) that explains WHERE
p99 goes, exact conservation checks against the twin's own aggregate
counters (completed / effective / lat_sum / histogram — property-tested in
tests/test_obs.py), and Chrome-trace slices on the twin's virtual
timeline (one ``pid`` per agent, one lane per pipeline stage).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

from repro.kernels.ref import (CAP_SLO, SIM_ARRIVED, SIM_COMPLETED,
                               SIM_DROPPED, SIM_EFFECTIVE, SIM_HEAD,
                               SIM_LAUNCH, SIM_PINF, SIM_PPRE, SIM_TAIL)

# lifecycle stamp columns (flat microtick index of each stage crossing)
STAGES = ("admit", "pre", "batch", "infer", "post")
_PTRS = (SIM_TAIL, SIM_PPRE, SIM_LAUNCH, SIM_PINF, SIM_HEAD)
# delay segments between consecutive stamps (ticks; +1 on the last for the
# end-of-tick completion convention: latency = head + 1 - tail)
SEGMENTS = ("pre_wait", "batch_wait", "infer", "post")


def request_stamps(counters_seq: np.ndarray) -> np.ndarray:
    """Stage-crossing stamps for ONE agent. ``counters_seq``: (N_ticks,
    SIM_NCOUNTERS) int32 post-tick counter series (flattened over
    intervals). Returns (n_admitted, 5) int64 flat-tick stamps in STAGES
    order; -1 where the request never crossed that stage (still in
    flight)."""
    seq = np.asarray(counters_seq)
    n = int(seq[-1, SIM_TAIL]) if len(seq) else 0
    q = np.arange(n)
    stamps = np.empty((n, len(_PTRS)), np.int64)
    for j, ptr in enumerate(_PTRS):
        s = np.searchsorted(seq[:, ptr], q, side="right")
        stamps[:, j] = np.where(s < len(seq), s, -1)
    return stamps


def attribute_agent(counters_seq: np.ndarray, caps_seq: np.ndarray,
                    k_ticks: int) -> Dict[str, np.ndarray]:
    """Per-request attribution for ONE agent.

    ``counters_seq``: (T*K, SIM_NCOUNTERS) flat post-tick series;
    ``caps_seq``: (T, SIM_NCAPS) the held caps per control interval (the
    deadline check reads the SLO in force at the *completion* tick, exactly
    as ``sim_microtick`` does); ``k_ticks``: microticks per interval.

    Returns arrays over admitted requests: ``stamps`` (n, 5), ``completed``
    (bool), ``latency_ticks`` (−1 while in flight), ``effective`` (bool),
    and one ``<segment>_ticks`` array per SEGMENTS entry (−1 where the
    segment has not finished)."""
    stamps = request_stamps(counters_seq)
    caps_seq = np.asarray(caps_seq)
    completed = stamps[:, 4] >= 0
    lat = np.where(completed, stamps[:, 4] + 1 - stamps[:, 0], -1)
    slo = np.zeros(len(stamps), np.int64)
    if len(stamps) and len(caps_seq):
        iv = np.clip(stamps[:, 4] // k_ticks, 0, len(caps_seq) - 1)
        slo = caps_seq[iv, CAP_SLO].astype(np.int64)
    out: Dict[str, np.ndarray] = {
        "stamps": stamps,
        "completed": completed,
        "latency_ticks": lat,
        "effective": completed & (lat <= slo),
    }
    for j, seg in enumerate(SEGMENTS):
        a, b = stamps[:, j], stamps[:, j + 1]
        done = b >= 0
        # the completion segment lands end-of-tick: +1 (latency convention)
        d = b - a + (1 if seg == "post" else 0)
        out[seg + "_ticks"] = np.where(done, d, -1)
    return out


def conservation_report(attr: Dict[str, np.ndarray],
                        final_counters: np.ndarray,
                        final_lat_sum: float,
                        final_hist: Optional[np.ndarray] = None
                        ) -> Dict[str, Any]:
    """Check the reconstruction against the twin's own aggregates for one
    agent: admitted/completed/effective counts, the latency sum, and (when
    given) the completed-latency histogram must match EXACTLY — the stamps
    are a lossless decomposition, not an estimate."""
    c = np.asarray(final_counters)
    lat = attr["latency_ticks"][attr["completed"]]
    checks = {
        "admitted": (len(attr["stamps"]),
                     int(c[SIM_ARRIVED] - c[SIM_DROPPED])),
        "tail": (len(attr["stamps"]), int(c[SIM_TAIL])),
        "completed": (int(attr["completed"].sum()), int(c[SIM_COMPLETED])),
        "effective": (int(attr["effective"].sum()), int(c[SIM_EFFECTIVE])),
        "lat_sum": (int(lat.sum()), int(round(float(final_lat_sum)))),
    }
    if final_hist is not None:
        h = np.asarray(final_hist)
        got = np.bincount(np.clip(lat, 0, len(h) - 1), minlength=len(h))
        checks["hist"] = (got.tolist(), h.astype(np.int64).tolist())
    report = {k: {"reconstructed": a, "twin": b, "ok": a == b}
              for k, (a, b) in checks.items()}
    report["ok"] = all(v["ok"] for v in report.values())
    return report


def attribute_run(history: Dict[str, Any], state,
                  sample_every: int = 1) -> Dict[str, Any]:
    """Attribution for a whole ``simulate_fleet(..., record_ticks=True)``
    run. ``history`` must carry ``tick_counters`` (T, A, K, NCOUNTERS) and
    ``caps`` (T, A, NCAPS); ``state`` is the final (A,)-batched SimState.

    Returns ``{"agents": [per-agent attr dicts], "records": [sampled
    request dicts], "conservation": [per-agent reports]}`` — ``records``
    keeps every ``sample_every``-th admitted request per agent as a flat
    dict (CLI/JSON-friendly); the conservation checks always run on the
    full population."""
    ticks = np.asarray(history["tick_counters"])  # (T, A, K, C)
    caps = np.asarray(history["caps"])            # (T, A, NCAPS)
    t, a, k, c = ticks.shape
    agents, records, reports = [], [], []
    for i in range(a):
        seq = ticks[:, i].reshape(t * k, c)
        attr = attribute_agent(seq, caps[:, i], k)
        agents.append(attr)
        reports.append(conservation_report(
            attr, seq[-1] if len(seq) else np.zeros(c, np.int64),
            float(np.asarray(state.lat_sum)[i]),
            np.asarray(state.hist)[i]))
        for q in range(0, len(attr["stamps"]), max(int(sample_every), 1)):
            rec = {"agent": i, "request": q,
                   "completed": bool(attr["completed"][q]),
                   "effective": bool(attr["effective"][q]),
                   "latency_ticks": int(attr["latency_ticks"][q])}
            for j, s in enumerate(STAGES):
                rec[s + "_tick"] = int(attr["stamps"][q, j])
            for seg in SEGMENTS:
                rec[seg + "_ticks"] = int(attr[seg + "_ticks"][q])
            records.append(rec)
    return {"agents": agents, "records": records, "conservation": reports}


def stage_decomposition(agents: List[Dict[str, np.ndarray]],
                        dt: float) -> Dict[str, Dict[str, float]]:
    """Fleet-wide per-stage delay decomposition in SECONDS over completed
    requests: mean/p50/p99 of each segment, plus ``p99_tail_mean`` — the
    segment's mean over the requests at/beyond the p99 total latency (the
    "where does the tail go" column ``launch/simulate.py`` prints)."""
    segs = {s: [] for s in SEGMENTS}
    lats = []
    for attr in agents:
        done = attr["completed"]
        lats.append(attr["latency_ticks"][done])
        for s in SEGMENTS:
            segs[s].append(attr[s + "_ticks"][done])
    lat = (np.concatenate(lats) if lats else np.zeros(0, np.int64))
    out: Dict[str, Dict[str, float]] = {}
    tail = (lat >= np.percentile(lat, 99)) if len(lat) else None
    for s in SEGMENTS:
        v = (np.concatenate(segs[s]) if segs[s] else np.zeros(0, np.int64))
        if len(v) == 0:
            out[s] = {"mean_s": 0.0, "p50_s": 0.0, "p99_s": 0.0,
                      "p99_tail_mean_s": 0.0}
            continue
        out[s] = {
            "mean_s": float(v.mean() * dt),
            "p50_s": float(np.percentile(v, 50) * dt),
            "p99_s": float(np.percentile(v, 99) * dt),
            "p99_tail_mean_s": float(v[tail].mean() * dt) if tail is not None
            and tail.any() else 0.0,
        }
    return out


def records_to_chrome(tracer, records: List[Dict[str, Any]],
                      dt: float) -> int:
    """Append the sampled request lifecycles to ``tracer`` as Chrome-trace
    complete slices on the twin's VIRTUAL timeline (ts = microtick * dt,
    exported in µs): one trace pid per agent, one lane (tid) per pipeline
    segment. Returns the number of slices added."""
    n = 0
    for rec in records:
        if not rec["completed"]:
            continue
        pid = 1000 + rec["agent"]
        t0 = rec["admit_tick"]
        for lane, seg in enumerate(SEGMENTS):
            d = rec[seg + "_ticks"]
            if d < 0:
                continue
            tracer.add_complete(
                f"req{rec['request']}/{seg}",
                ts_us=t0 * dt * 1e6, dur_us=d * dt * 1e6, cat="request",
                pid=pid, tid=lane,
                args={"agent": rec["agent"], "request": rec["request"],
                      "effective": rec["effective"]})
            t0 += d
            n += 1
    return n
