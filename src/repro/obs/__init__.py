"""Flight recorder: span tracing, XLA cost/memory accounting, and
request-grade latency attribution.

Three coordinated layers over the same run:

* ``repro.obs.trace`` — phase-level spans (episode -> fl_round
  encode/uplink/aggregate -> pod merge, plus per-kernel spans) emitted
  from inside the single jitted scan by host callbacks, exported as
  Chrome trace-event JSON (Perfetto / chrome://tracing).
* ``repro.obs.profile`` — ``cost_analysis``/``memory_analysis`` of the
  compiled fleet scan and each kernel variant, plus the donation audit;
  persisted via ``benchmarks.common.save_bench`` as ``BENCH_profile``.
* ``repro.obs.requests`` — sampled per-request lifecycle records
  reconstructed from the twin's monotone stage counters, decomposing
  tail latency into per-stage queueing / service / batching delay.

``core`` may import ``repro.obs.trace`` (a leaf, jax-only module); the
other two layers sit above ``core``/``sim`` and must not be imported
from them.
"""
from repro.obs.trace import (Tracer, active_tracer, bind_tid,
                             kernel_trace_tid, span_begin, span_end,
                             validate_chrome_trace)

__all__ = ["Tracer", "active_tracer", "bind_tid", "kernel_trace_tid",
           "span_begin", "span_end", "validate_chrome_trace"]
