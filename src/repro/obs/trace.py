"""Span tracing: the flight recorder's timeline layer.

A ``Tracer`` collects phase-level spans — host ``perf_counter_ns``
timestamps bracketing regions of the compiled program — and exports them
as Chrome trace-event JSON that opens directly in Perfetto /
``chrome://tracing``. Spans are emitted from INSIDE jitted code (the
single scanned fleet driver, ``fl_round``'s transport phases, the Pallas
kernel wrappers) through ``jax.experimental.io_callback`` pairs whose
float tokens chain begin -> compute -> end by *data dependency*, so the
recorded intervals bracket the real execution order without ordered
effects (which ``lax.cond`` branches — where the FL phases live — do not
admit).

Two invariants the rest of the repo leans on:

* **Off = the exact pre-trace program.** Tracing is a jit-static flag
  threaded through the instrumented entry points (``train_fleet_scan``'s
  ``tracer=``, ``fl_round``'s ``trace=``); with it off (the default) no
  callback is traced and the compiled program — and therefore the run
  history — is bit-identical to the pre-observability code
  (golden-checked in tests/test_obs.py).
* **No recompile per tracer.** The tracer is addressed by an integer id
  passed to the compiled program as a plain (non-static) operand — the
  same registry trick as the metrics-sink tap in ``core/fleet.py`` — so
  attaching a different ``Tracer`` object to a same-shaped run reuses
  the cached executable.

The callback outputs never feed back into the numeric computation: a
begin token flows only into its end callback (and into nested begins),
so the traced-with-spans program computes bit-identical values to the
span-free one — tracing ON changes wall-clock, never numerics.

``span_sample_every`` thins emission *at runtime*: the
``episode % sample_every == 0`` predicate rides into each callback as a
data operand and the HOST drops sampled-out events. (A traced ``lax.cond``
around the callback was measured slower than the callback it skips — the
effect-carrying cond blocks XLA:CPU fusion at every span site.) The
predicate is data, not a static, so dialing sampling up or down never
recompiles.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback

# ---------------------------------------------------------------------------
# Tracer registry: id -> Tracer, addressed from compiled code by operand
# ---------------------------------------------------------------------------
_TRACERS: Dict[int, "Tracer"] = {}
_NEXT_ID = [1]
_LOCK = threading.Lock()

# trace-time binding of the *current* trace-id value (a jax tracer while a
# traced function body executes, a concrete array at the top level). The
# kernel wrappers in ``kernels/ops.py`` read it so a kernel called inside a
# traced ``fl_round(trace=True)`` emits spans against the SAME operand id
# as the enclosing phases — never a baked-in constant.
_BOUND_TID: List[Any] = []
_ACTIVE: List["Tracer"] = []

_F32 = jax.ShapeDtypeStruct((), jnp.float32)


def register_tracer(tracer: "Tracer") -> int:
    with _LOCK:
        tid = _NEXT_ID[0]
        _NEXT_ID[0] += 1
        _TRACERS[tid] = tracer
    return tid


def release_tracer(tid: int) -> None:
    with _LOCK:
        _TRACERS.pop(int(tid), None)


def get_tracer(tid: int) -> Optional["Tracer"]:
    return _TRACERS.get(int(tid))


@contextmanager
def bind_tid(tid):
    """Trace-time context: make ``tid`` (operand value) visible to nested
    instrumentation (the kernel wrappers) during tracing of an instrumented
    function body."""
    _BOUND_TID.append(tid)
    try:
        yield
    finally:
        _BOUND_TID.pop()


def bound_tid():
    return _BOUND_TID[-1] if _BOUND_TID else None


@contextmanager
def activate(tracer: "Tracer"):
    """Host-level context: mark ``tracer`` active so eager (non-traced)
    instrumentation — the kernel wrappers called at the top level, the
    reference driver's host spans — records into it. ``None`` is a no-op
    so callers can thread an optional tracer straight through."""
    if tracer is None:
        yield None
        return
    _ACTIVE.append(tracer)
    try:
        yield tracer
    finally:
        _ACTIVE.pop()


def active_tracer() -> Optional["Tracer"]:
    return _ACTIVE[-1] if _ACTIVE else None


def kernel_trace_tid():
    """The trace-id the kernel wrappers should emit against, or None when
    kernel spans must stay off this call.

    Inside a traced instrumented body (``bind_tid``): the bound operand.
    At the top level (``jax.core.trace_state_clean()``): the active
    tracer's id, if it opted into kernel spans. Inside any OTHER trace
    (e.g. an un-instrumented jitted fn compiled while a tracer happens to
    be active): None — spans must never bake into a cached program whose
    jit key does not know about them."""
    b = bound_tid()
    if b is not None:
        return b
    t = active_tracer()
    if (t is not None and t.kernel_spans
            and jax.core.trace_state_clean()):
        return jnp.asarray(t.tid, jnp.int32)
    return None


# ---------------------------------------------------------------------------
# Host callback targets
# ---------------------------------------------------------------------------
def _now_us() -> float:
    return time.perf_counter_ns() / 1e3


def _cb_begin(name: str, cat: str, tid_arr, when_arr, *_probes) -> np.float32:
    if not bool(when_arr):
        return np.float32(0.0)
    tracer = get_tracer(int(tid_arr))
    if tracer is not None:
        tracer._begin(name, cat)
    return np.float32(1.0)


def _cb_end(name: str, tid_arr, when_arr, _tok, *_probes) -> np.float32:
    if not bool(when_arr):
        return np.float32(0.0)
    tracer = get_tracer(int(tid_arr))
    if tracer is not None:
        tracer._end(name)
    return np.float32(1.0)


def _cb_instant(name: str, cat: str, tid_arr, when_arr, *_probes) -> np.float32:
    if not bool(when_arr):
        return np.float32(0.0)
    tracer = get_tracer(int(tid_arr))
    if tracer is not None:
        tracer.instant(name, cat)
    return np.float32(1.0)


def _probe(x):
    """A 0-d float32 window into ``x`` — the data dependency that pins a
    span callback into the execution order (first leaf, first element)."""
    leaves = jax.tree.leaves(x)
    if not leaves:
        return jnp.float32(0.0)
    leaf = leaves[0]
    if jnp.ndim(leaf) == 0:
        return jnp.asarray(leaf, jnp.float32)
    return jnp.asarray(jnp.ravel(leaf)[0], jnp.float32)


def _when_operand(when):
    """The sampling predicate as a callback operand. A traced ``lax.cond``
    wrapper was measured SLOWER than just making the host call and letting
    it drop the sampled-out event: the effect-carrying cond blocks XLA:CPU
    fusion around every span site (~14% on the fleet scan even with the
    predicate always false), while the bare callback costs ~0.1 ms. So the
    predicate rides INTO the callback as data and the host filters."""
    return jnp.asarray(True if when is None else when, jnp.bool_)


def span_begin(name: str, tid, *deps, cat: str = "phase", when=None):
    """Open span ``name`` from inside jitted code. ``tid``: the trace-id
    operand. ``deps``: values the span's phase consumes — their probes
    order the begin callback after the phase inputs exist. Returns a float
    token: thread it into ``span_end`` (and into nested ``span_begin``
    deps) to enforce begin -> body -> end ordering. ``when``: optional
    traced bool — emission sampled at runtime (host-filtered), no
    recompile."""
    probes = [_probe(d) for d in deps]
    return io_callback(partial(_cb_begin, name, cat), _F32,
                       tid, _when_operand(when), *probes)


def span_end(name: str, tid, token, *outputs, when=None):
    """Close span ``name``: ``token`` is the matching ``span_begin``'s
    return; ``outputs`` are values the phase produced — their probes order
    the end callback after the phase completes. Returns a token usable as
    a dep of the next phase."""
    probes = [_probe(o) for o in outputs]
    return io_callback(partial(_cb_end, name), _F32,
                       tid, _when_operand(when), token, *probes)


def instant(name: str, tid, *deps, cat: str = "mark", when=None):
    """A zero-duration instant event (Chrome ``ph: "i"``)."""
    probes = [_probe(d) for d in deps]
    return io_callback(partial(_cb_instant, name, cat), _F32,
                       tid, _when_operand(when), *probes)


# ---------------------------------------------------------------------------
# The recorder
# ---------------------------------------------------------------------------
class Tracer:
    """Flight-recorder event collector + Chrome trace-event exporter.

    ``span_sample_every``: emit the per-episode spans of the scanned fleet
    driver only on every N-th episode (runtime-sampled — the predicate is
    data, so changing it never recompiles). ``kernel_spans``: let the
    ``kernels/ops.py`` wrappers record per-kernel spans when called at the
    top level or inside an instrumented trace.

    Events live in memory as (name, cat, ph, ts_us, dur_us) tuples; begin/
    end pairs are folded into complete ``X`` slices at ``_end`` time via a
    per-tracer span stack (tolerant: an end that skips stack levels closes
    the inner spans at the same timestamp instead of corrupting the file).
    Host-side phases (compile, device fetch) bracket with ``span()``.
    """

    def __init__(self, span_sample_every: int = 1,
                 kernel_spans: bool = False, pid: int = 1):
        assert span_sample_every >= 1
        self.span_sample_every = int(span_sample_every)
        self.kernel_spans = bool(kernel_spans)
        self.pid = pid
        self.events: List[Dict[str, Any]] = []
        self._stack: List[Tuple[str, str, float]] = []
        self._lock = threading.Lock()
        self.tid = register_tracer(self)

    # -- recording (called from the jax callback thread / host code) ------
    def _begin(self, name: str, cat: str):
        with self._lock:
            self._stack.append((name, cat, _now_us()))

    def _end(self, name: str):
        now = _now_us()
        with self._lock:
            while self._stack:
                n, cat, t0 = self._stack.pop()
                self.events.append({"name": n, "cat": cat, "ph": "X",
                                    "ts": t0, "dur": max(now - t0, 0.0),
                                    "pid": self.pid, "tid": 0})
                if n == name:
                    return
            # unmatched end: record an instant so the anomaly is visible
            self.events.append({"name": name, "cat": "unmatched-end",
                                "ph": "i", "ts": now, "s": "t",
                                "pid": self.pid, "tid": 0})

    def instant(self, name: str, cat: str = "mark"):
        self.events.append({"name": name, "cat": cat, "ph": "i",
                            "ts": _now_us(), "s": "t",
                            "pid": self.pid, "tid": 0})

    def add_complete(self, name: str, ts_us: float, dur_us: float,
                     cat: str = "request", pid: Optional[int] = None,
                     tid: int = 0, args: Optional[Dict] = None):
        """Append a pre-formed complete slice (the request-attribution
        exporter uses this with virtual twin-time timestamps)."""
        ev = {"name": name, "cat": cat, "ph": "X", "ts": float(ts_us),
              "dur": float(max(dur_us, 0.0)),
              "pid": self.pid if pid is None else pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    @contextmanager
    def span(self, name: str, cat: str = "host"):
        """Host-side span (compile, fetch, file IO): plain wall bracketing,
        no callbacks involved."""
        self._begin(name, cat)
        try:
            yield
        finally:
            self._end(name)

    # -- export -----------------------------------------------------------
    def drain(self):
        """Flush any still-open spans (e.g. the run was interrupted) as
        zero-duration instants so the export is always well-formed."""
        jax.effects_barrier()
        with self._lock:
            while self._stack:
                n, cat, t0 = self._stack.pop()
                self.events.append({"name": n, "cat": cat + "-open",
                                    "ph": "i", "ts": t0, "s": "t",
                                    "pid": self.pid, "tid": 0})

    def chrome_events(self) -> List[Dict[str, Any]]:
        self.drain()
        return sorted(self.events, key=lambda e: e["ts"])

    def chrome_trace(self) -> Dict[str, Any]:
        """The Chrome trace-event JSON object (``traceEvents`` container
        format) — opens directly in Perfetto / chrome://tracing."""
        return {"traceEvents": self.chrome_events(),
                "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, default=float)
        return path

    def close(self):
        release_tracer(self.tid)

    def __enter__(self) -> "Tracer":
        return self

    def __exit__(self, *exc):
        self.close()


# ---------------------------------------------------------------------------
# Schema validation (shared by the tests and the fig_profile gate)
# ---------------------------------------------------------------------------
REQUIRED_KEYS = ("name", "ph", "ts", "pid", "tid")
VALID_PH = {"X", "B", "E", "i", "I", "M", "C", "b", "e", "s", "t", "f"}


def validate_chrome_trace(trace: Any) -> List[str]:
    """Structural check of a Chrome trace-event JSON object. Returns a list
    of problems (empty == valid): container shape, per-event required keys,
    known phase codes, numeric non-negative timestamps, ``X`` events carry
    a non-negative ``dur``."""
    problems: List[str] = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["not a {'traceEvents': [...]} container"]
    events = trace["traceEvents"]
    if not isinstance(events, list):
        return ["traceEvents is not a list"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        missing = [k for k in REQUIRED_KEYS if k not in ev]
        if missing:
            problems.append(f"event {i}: missing {missing}")
            continue
        if ev["ph"] not in VALID_PH:
            problems.append(f"event {i}: unknown phase {ev['ph']!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            problems.append(f"event {i}: bad ts {ev['ts']!r}")
        if ev["ph"] == "X" and (not isinstance(ev.get("dur"), (int, float))
                                or ev["dur"] < 0):
            problems.append(f"event {i}: X event without valid dur")
    return problems
