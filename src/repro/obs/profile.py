"""XLA cost & memory accounting: the flight recorder's static layer.

Where ``repro.obs.trace`` records when phases ran, this module records what
the compiled programs *are*: FLOPs and bytes accessed from
``Compiled.cost_analysis()``, argument/output/temp/alias sizes from
``Compiled.memory_analysis()``, and a donation audit that checks the fleet
pytree's donated buffers are actually aliased to outputs in the lowered
program (``tf.aliasing_output`` annotations — present in the stablehlo text
even on CPU, where the runtime itself cannot reuse donated buffers and
``alias_size_in_bytes`` reads 0).

Everything here analyzes the EXACT objects the training path runs:
``core.fleet.lower_fleet_scan`` lowers the same ``_scan_fn`` the driver
dispatches, and the kernel table is ``kernels.ops.KERNEL_JITS`` — the same
jit wrappers the dispatchers call. ``benchmarks/fig_profile.py`` persists
these stats via ``save_bench`` as the ``BENCH_profile`` envelope and gates
regressions on them.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

_MEM_FIELDS = ("argument_size_in_bytes", "output_size_in_bytes",
               "temp_size_in_bytes", "alias_size_in_bytes",
               "generated_code_size_in_bytes")

_ALIAS_RE = re.compile(r"tf\.aliasing_output")


def compiled_stats(lowered) -> Dict[str, float]:
    """Cost/memory accounting of one lowered program: compile it and read
    XLA's analyses. Returns a flat float dict (envelope-friendly):
    ``flops``, ``bytes_accessed``, the ``*_size_in_bytes`` memory fields,
    and ``peak_bytes`` (arguments + outputs + temps − aliased: the
    high-water estimate once donation is honored)."""
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # one entry per partition
        cost = cost[0] if cost else {}
    cost = cost or {}
    out: Dict[str, float] = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
    }
    mem = compiled.memory_analysis()
    for f in _MEM_FIELDS:
        out[f] = float(getattr(mem, f, 0.0) or 0.0)
    out["peak_bytes"] = (out["argument_size_in_bytes"]
                         + out["output_size_in_bytes"]
                         + out["temp_size_in_bytes"]
                         - out["alias_size_in_bytes"])
    return out


def donation_audit(lowered, expected_donated: Optional[int] = None
                   ) -> Dict[str, Any]:
    """Check donated buffers are wired for reuse in the lowered program.

    Counts ``tf.aliasing_output`` argument annotations in the stablehlo
    text — XLA pairs each usable donated input with an output buffer at
    lowering, so the count is the number of donations that will actually
    be honored (the annotation exists on every backend; the *runtime*
    reuse shows up in ``alias_size_in_bytes``, which CPU reports as 0).
    ``expected_donated``: the number of buffers the caller donated (e.g.
    the fleet pytree's leaf count); the audit passes when every one of
    them got an aliased output."""
    text = lowered.as_text()
    aliased = len(_ALIAS_RE.findall(text))
    ok = True if expected_donated is None else aliased >= expected_donated
    return {"aliased_args": aliased,
            "expected_donated": (-1 if expected_donated is None
                                 else int(expected_donated)),
            "ok": bool(ok)}


def profile_fleet_scan(cfg, fleet, traces, donate: bool = True,
                       **lower_kw) -> Dict[str, Any]:
    """Lower the scanned fleet driver exactly as ``train_fleet_scan`` would
    (donation included) and return its cost/memory stats + donation audit.
    ``lower_kw`` forwards to ``core.fleet.lower_fleet_scan``."""
    from repro.core.fleet import lower_fleet_scan
    lowered = lower_fleet_scan(cfg, fleet, traces, donate=donate,
                               **lower_kw)
    stats = compiled_stats(lowered)
    n_leaves = len(jax.tree.leaves(fleet))
    audit = donation_audit(lowered, n_leaves if donate else None)
    stats["donated_leaves"] = float(n_leaves if donate else 0)
    stats["aliased_args"] = float(audit["aliased_args"])
    stats["donation_ok"] = float(audit["ok"])
    return stats


def fleet_memory_report(cfg, n_agents: int, *, n_pods: int = 8,
                        n_episodes: int = 2, state_policies=("float32",
                                                             "lean"),
                        donate: bool = True, seed: int = 0,
                        **lower_kw) -> Dict[str, Dict[str, float]]:
    """Peak-memory accounting of the fleet scan at scale, per state policy.

    For each policy: build an ``n_agents`` fleet (``fleet_init(...,
    state_policy=...)``), lower the exact donated scan, and report XLA's
    ``peak_bytes`` alongside the stored-state byte breakdown
    (``fleet_state_bytes``) and the donation audit — the A=2048-shape
    audit the scaling work gates on. Keys are policy names; each row holds
    ``peak_bytes`` / ``peak_bytes_per_agent`` / ``state_*`` bytes /
    ``donation_ok``. ``lower_kw`` forwards to ``lower_fleet_scan``
    (e.g. ``mesh=...``)."""
    from repro.core.dtypes import get_policy
    from repro.core.fleet import fleet_init, fleet_state_bytes

    key = jax.random.PRNGKey(seed)
    rng = np.random.default_rng(seed)
    traces = jnp.asarray(
        rng.uniform(10.0, 50.0, (n_agents, n_episodes * cfg.n_steps)),
        jnp.float32)
    out: Dict[str, Dict[str, float]] = {}
    for pol in state_policies:
        name = get_policy(pol).name
        fleet = fleet_init(cfg, n_agents, key, n_pods=n_pods,
                           state_policy=pol)
        stats = profile_fleet_scan(cfg, fleet, traces, donate=donate,
                                   **lower_kw)
        sb = fleet_state_bytes(fleet)
        row = {f"state_{k}": v for k, v in sb.items()}
        row.update(stats)
        row["peak_bytes_per_agent"] = stats["peak_bytes"] / n_agents
        out[name] = row
    return out


# ---------------------------------------------------------------------------
# Canonical kernel workloads: one representative shape per Pallas kernel,
# matching the sizes the fleet actually runs (tests/test_kernels.py cases).
# ---------------------------------------------------------------------------
def _kernel_args(name: str):
    key = jax.random.PRNGKey(0)
    f32 = jnp.float32
    if name == "flash_attention":
        k1, k2, k3 = jax.random.split(key, 3)
        q = jax.random.normal(k1, (2, 128, 4, 64), f32)
        k = jax.random.normal(k2, (2, 128, 4, 64), f32)
        v = jax.random.normal(k3, (2, 128, 4, 64), f32)
        return (q, k, v), dict(causal=True, bq=64, bk=64)
    if name == "decode_attention":
        k1, k2, k3 = jax.random.split(key, 3)
        q = jax.random.normal(k1, (2, 1, 4, 64), f32)
        kc = jax.random.normal(k2, (2, 256, 4, 64), f32)
        vc = jax.random.normal(k3, (2, 256, 4, 64), f32)
        return (q, kc, vc, jnp.asarray(256, jnp.int32)), dict(bk=128)
    if name == "pack":
        tok = jax.random.normal(key, (64, 128), f32)
        idx = jnp.asarray([0, 63, -1, 5, 5, -1, 17, 2], jnp.int32)
        return (tok, idx), {}
    if name == "diversity_insert":
        from repro.configs.fcpo import FCPOConfig
        from repro.core.buffer import buffer_init
        cfg = FCPOConfig(buffer_size=8)
        na = cfg.n_res + cfg.n_bs + cfg.n_mt
        a, t = 4, 20
        k1, k2 = jax.random.split(key)
        cs = jax.random.normal(k1, (a, t, cfg.state_dim), f32)
        cp = jax.nn.softmax(jax.random.normal(k2, (a, t, na), f32), -1)
        buf = buffer_init(cfg)
        batched = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (a,) + x.shape),
            (buf.states, buf.probs, buf.score, buf.filled, buf.s_sum,
             buf.s_outer, buf.p_sum, buf.n_filled))
        return (*batched, cs, cp), dict(alpha=cfg.alpha, beta=cfg.beta)
    if name == "delta_codec":
        k1, k2 = jax.random.split(key)
        d = jax.random.normal(k1, (8, 3121), f32)
        r = jax.random.normal(k2, (8, 3121), f32) * 0.1
        return (d, r), dict(codec="topk", k=156)
    if name == "queue_advance":
        from repro.sim.state import SimParams, sim_init
        sp = SimParams()
        a = 4
        state = jax.vmap(lambda _: sim_init(sp))(jnp.arange(a))
        k1 = jax.random.fold_in(key, 1)
        arrivals = jax.random.randint(k1, (a, sp.k_ticks), 0, 7)
        caps = jnp.broadcast_to(
            jnp.asarray([2.5, 3.0, 4.0, 2.0, 8.0, 5.0], f32), (a, 6))
        return (*state, arrivals, caps), {}
    raise KeyError(name)


def profile_kernels(names=None) -> Dict[str, Dict[str, float]]:
    """Cost/memory stats for each Pallas kernel's jit wrapper at its
    canonical workload shape. ``names``: subset to profile (default: all of
    ``kernels.ops.KERNEL_JITS``)."""
    from repro.kernels.ops import KERNEL_JITS
    out: Dict[str, Dict[str, float]] = {}
    for name, fn in KERNEL_JITS.items():
        if names is not None and name not in names:
            continue
        args, kw = _kernel_args(name)
        out[name] = compiled_stats(fn.lower(*args, **kw))
    return out
