"""Chaos layer: deterministic fault injection + self-healing defenses.

``faults`` describes what goes wrong (crashes, byzantine deltas, pod
partitions) as a jit-static ``FaultConfig`` plus host-side pre-drawn fault
plans consumed as scan xs — the injected-fault cadence stays ONE jitted
scan. ``guards`` describes the defenses (robust aggregation, delta
clipping, non-finite rejection) as a jit-static ``GuardConfig``. The
default ``GuardConfig()`` with no faults compiles to the exact pre-chaos
program, bit-for-bit.
"""
from repro.resilience.faults import (BYZANTINE_MODES, NO_FAULTS, FaultConfig,
                                     FaultPlan, apply_crashes, corrupt_deltas,
                                     draw_fault_plan, freeze_astate)
from repro.resilience.guards import (DEFAULT_GUARDS, GuardConfig, clip_deltas,
                                     finite_mask)

__all__ = [
    "FaultConfig", "FaultPlan", "NO_FAULTS", "BYZANTINE_MODES",
    "draw_fault_plan", "apply_crashes", "corrupt_deltas", "freeze_astate",
    "GuardConfig", "DEFAULT_GUARDS", "finite_mask", "clip_deltas",
]
