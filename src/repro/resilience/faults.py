"""Deterministic fault injection for the fleet drivers.

Three fault families, matching what real edge fleets (EdgeVision-style
distributed deployments) actually suffer:

* **Agent crashes** — an agent goes down for ``crash_recovery`` episodes:
  its entire ``AgentState`` is frozen (params zeroed or stale at crash
  time), it leaves episode training and Eq. 7 selection, and on expiry it
  rejoins via the paper's step-① warm start: params <- its pod's base
  network, optimizer state zeroed.
* **Byzantine clients** — a selected client's *decoded* delta is corrupted
  post-codec (sign-flip, scaled noise, or NaN-poison), i.e. in transit on
  the server side of the wire, so the injection composes with every codec
  (float32/int8/topk) and with error feedback exactly as a real corrupted
  upload would.
* **Pod partitions** — a partitioned pod skips the hierarchical cross-pod
  merge for ``partition_merges`` merge events (its base network drifts
  alone), then rejoins the cloud tier.

Determinism: ``draw_fault_plan`` pre-draws every fault bit on the host from
one seeded numpy generator, in a fixed episode order shared by the scanned
and reference drivers — the plan arrays are consumed as scan xs, so an
injected-fault run is still ONE jitted scan with zero per-round host work,
and ``train_fleet_scan == train_fleet_reference`` holds under faults.
Byzantine noise is drawn *inside* jit from a key folded with the absolute
episode index, so it too is identical across drivers and across resumed
chunks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

BYZANTINE_MODES = ("sign_flip", "noise", "nan")


@dataclass(frozen=True)
class FaultConfig:
    """Jit-static fault model. All probabilities are per-draw Bernoulli
    rates; ``seed`` drives both the host-side plan and the in-jit noise.

    crash_prob: per-agent per-episode crash probability. A crashed agent is
    frozen for ``crash_recovery`` episodes (params zeroed when
    ``crash_zero_params``, else stale) and rejoins warm-started from its
    pod's base network. byzantine_frac: per-agent per-round probability of
    shipping a corrupted delta (``byzantine_mode`` selects the corruption,
    scaled by ``byzantine_scale``). partition_prob: per-pod probability *at
    each hierarchical merge* of dropping off the cloud tier for
    ``partition_merges`` merges."""
    crash_prob: float = 0.0
    crash_recovery: int = 2
    crash_zero_params: bool = True
    byzantine_frac: float = 0.0
    byzantine_mode: str = "sign_flip"
    byzantine_scale: float = 10.0
    partition_prob: float = 0.0
    partition_merges: int = 1
    seed: int = 0

    def __post_init__(self):
        if self.byzantine_mode not in BYZANTINE_MODES:
            raise ValueError(f"unknown byzantine_mode "
                             f"{self.byzantine_mode!r}; expected one of "
                             f"{BYZANTINE_MODES}")
        for name in ("crash_prob", "byzantine_frac", "partition_prob"):
            v = getattr(self, name)
            if not (0.0 <= v <= 1.0):
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.crash_recovery < 1:
            raise ValueError("crash_recovery must be >= 1")
        if self.partition_merges < 1:
            raise ValueError("partition_merges must be >= 1")

    @property
    def crash_active(self) -> bool:
        return self.crash_prob > 0.0

    @property
    def byzantine_active(self) -> bool:
        return self.byzantine_frac > 0.0

    @property
    def partition_active(self) -> bool:
        return self.partition_prob > 0.0

    @property
    def active(self) -> bool:
        return (self.crash_active or self.byzantine_active
                or self.partition_active)


NO_FAULTS = FaultConfig()


class FaultPlan(NamedTuple):
    """Host-side pre-drawn fault bits, one row per episode (scan xs)."""
    crash: np.ndarray      # (n_eps, A) bool — crash fires after episode e
    byzantine: np.ndarray  # (n_eps, A) bool — corrupt upload in round e
    partition: np.ndarray  # (n_eps, P) bool — pod drops at a merge in ep e


def draw_fault_plan(schedule, n_agents: int, n_pods: int,
                    faults: Optional[FaultConfig]) -> FaultPlan:
    """Pre-draw the whole run's fault bits from ``faults.seed``.

    Draw order is fixed — per episode: crash bits (every episode when
    crashes are active), then byzantine and partition bits (FL episodes
    only) — so a plan drawn over ``total_episodes`` and sliced at an
    ``episode_offset`` is identical to the uninterrupted run's plan
    (checkpoint resume keeps the same faults)."""
    n = len(schedule)
    crash = np.zeros((n, n_agents), bool)
    byz = np.zeros((n, n_agents), bool)
    part = np.zeros((n, n_pods), bool)
    if faults is not None and faults.active:
        rng = np.random.default_rng(faults.seed)
        for e in range(n):
            if faults.crash_active:
                crash[e] = rng.random(n_agents) < faults.crash_prob
            if schedule[e]:
                if faults.byzantine_active:
                    byz[e] = rng.random(n_agents) < faults.byzantine_frac
                if faults.partition_active:
                    part[e] = rng.random(n_pods) < faults.partition_prob
    return FaultPlan(crash, byz, part)


def _bmask(m, leaf):
    return m.reshape(m.shape + (1,) * (leaf.ndim - 1))


def freeze_astate(down, old_astate, new_astate):
    """Carry a down agent's entire AgentState unchanged (SPMD-friendly: the
    dead agent's episode/round still computes, its results are discarded
    here with one ``where`` per leaf)."""
    return jax.tree.map(
        lambda o, n: jnp.where(_bmask(down, n), o, n), old_astate, new_astate)


def apply_crashes(faults: FaultConfig, prev_astate, fleet, crash_now):
    """Advance the crash state machine past one episode.

    Called after ``fleet_episode`` ran for every agent:
      1. agents already down (timer > 0 at episode entry) have their whole
         ``AgentState`` restored to the pre-episode value — they did not run;
      2. timers age; an agent whose window just expired rejoins via the
         paper's step-① warm start (params <- pod base network, optimizer
         zeroed, buffer/env kept);
      3. fresh ``crash_now`` draws take the agent down starting now: timer
         set to ``crash_recovery``; params+opt zeroed when
         ``crash_zero_params`` (a wiped device), else left stale.

    Returns ``(fleet, ran, down)`` — ``ran`` marks agents whose episode
    counted toward metrics, ``down`` marks agents that must sit out the FL
    round that may follow this episode."""
    timer = fleet.crash_timer
    was_down = timer > 0
    astate = freeze_astate(was_down, prev_astate, fleet.astate)

    timer = jnp.maximum(timer - 1, 0)
    rejoin = was_down & (timer == 0)
    base_g = jax.tree.map(lambda b: b[fleet.pod_ids], fleet.base_params)
    params = jax.tree.map(
        lambda p, b: jnp.where(_bmask(rejoin, p), b, p), astate.params, base_g)
    opt = jax.tree.map(
        lambda o: jnp.where(_bmask(rejoin, o), jnp.zeros_like(o), o),
        astate.opt)

    new_crash = crash_now & (timer == 0)
    if faults.crash_zero_params:
        params = jax.tree.map(
            lambda p: jnp.where(_bmask(new_crash, p), jnp.zeros_like(p), p),
            params)
        opt = jax.tree.map(
            lambda o: jnp.where(_bmask(new_crash, o), jnp.zeros_like(o), o),
            opt)
    timer = jnp.where(new_crash, faults.crash_recovery, timer)

    fleet = fleet._replace(astate=astate._replace(params=params, opt=opt),
                           crash_timer=timer)
    return fleet, ~was_down, timer > 0


def corrupt_deltas(faults: FaultConfig, decoded, byzantine, key):
    """Corrupt the post-codec decoded deltas of the agents in ``byzantine``
    (server-side of the wire — composes with any codec and with error
    feedback exactly like a real corrupted upload). ``key`` feeds the
    ``noise`` mode; fold it with the absolute episode index so scanned,
    reference, and resumed runs corrupt identically."""
    mode = faults.byzantine_mode
    leaves, treedef = jax.tree_util.tree_flatten(decoded)
    keys = jax.random.split(key, len(leaves))

    def one(k, d):
        if mode == "sign_flip":
            bad = -faults.byzantine_scale * d
        elif mode == "noise":
            bad = d + faults.byzantine_scale * jax.random.normal(
                k, d.shape, d.dtype)
        else:  # nan — a poisoned upload
            bad = jnp.full_like(d, jnp.nan)
        return jnp.where(_bmask(byzantine, d), bad, d)

    return jax.tree_util.tree_unflatten(
        treedef, [one(k, d) for k, d in zip(keys, leaves)])
