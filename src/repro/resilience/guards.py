"""Self-healing defenses for Algorithm 1 and the PPO update.

``GuardConfig`` is a frozen (hashable) dataclass threaded through
``fl_round`` / the fleet drivers as a jit-static argument:

* ``agg`` — the Algorithm 1 aggregation statistic: ``"mean"`` is the
  paper's masked segment-mean (the exact pre-chaos code path, bit-for-bit);
  ``"trimmed"`` / ``"median"`` are coordinate-wise robust variants computed
  over {selected clients} ∪ {base network} that bound the influence of any
  f byzantine clients (f ≤ trim budget) to the honest coordinate range.
* ``clip_factor`` — per-leaf L2 norm clip of client deltas against
  ``clip_factor ×`` the selected-client median leaf norm (0 disables;
  a scaled-up byzantine delta is shrunk back to honest magnitude).
* ``reject_nonfinite`` — drop contributions (fresh or staleness-parked)
  containing NaN/Inf from the aggregation mask before they touch any pod
  member. On by default: the check is the identity on healthy rounds, so
  the default config stays bit-identical seed-for-seed.
* ``susp_threshold`` — evidence stream from the health observatory
  (``health/attribution.py``): when > 0 *and* health state is enabled,
  clients whose suspicion EMA from the previous round exceeds the
  threshold are dropped from selection before aggregation
  (``suspicion_gate``). 0 disables; attribution then still *scores*
  clients (observability) without acting on them.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

AGG_METHODS = ("mean", "trimmed", "median")


@dataclass(frozen=True)
class GuardConfig:
    agg: str = "mean"
    trim_frac: float = 0.2
    clip_factor: float = 0.0
    reject_nonfinite: bool = True
    susp_threshold: float = 0.0

    def __post_init__(self):
        if self.agg not in AGG_METHODS:
            raise ValueError(f"unknown agg {self.agg!r}; expected one of "
                             f"{AGG_METHODS}")
        if not (0.0 <= self.trim_frac < 0.5):
            raise ValueError("trim_frac must be in [0, 0.5)")
        if self.clip_factor < 0.0:
            raise ValueError("clip_factor must be >= 0")
        if not (0.0 <= self.susp_threshold <= 1.0):
            raise ValueError("susp_threshold must be in [0, 1]")


DEFAULT_GUARDS = GuardConfig()


def finite_mask(tree) -> jnp.ndarray:
    """(A,) bool — True where every leaf of agent i is entirely finite."""
    leaves = jax.tree_util.tree_leaves(tree)
    ok = jnp.ones((jnp.shape(leaves[0])[0],), bool)
    for leaf in leaves:
        flat = leaf.reshape(leaf.shape[0], -1)
        ok = ok & jnp.all(jnp.isfinite(flat), axis=1)
    return ok


def _masked_median_1d(x, mask):
    """Median of ``x[mask]`` (scalar); +inf when the mask is empty."""
    n = jnp.sum(mask)
    srt = jnp.sort(jnp.where(mask, x, jnp.inf))
    lo = srt[jnp.maximum((n - 1) // 2, 0)]
    hi = srt[jnp.maximum(n // 2, 0)]
    return 0.5 * (lo + hi)


def suspicion_gate(sel, suspicion, threshold: float):
    """Drop clients whose suspicion exceeds ``threshold`` from the
    selection mask. Returns ``(gated_sel, n_gated)``. Suspicion is the
    previous round's attribution EMA (scores for *this* round's deltas do
    not exist until after aggregation), so the gate reacts one round late
    by construction — documented in docs/observability.md."""
    hit = sel & (suspicion > threshold)
    return sel & ~hit, jnp.sum(hit).astype(jnp.float32)


def clip_deltas(contrib, sel, clip_factor: float):
    """Per-leaf L2 norm clip against ``clip_factor ×`` the selected-client
    median norm of that leaf. Returns ``(clipped_tree, n_clipped)`` where
    ``n_clipped`` counts agents with at least one clipped leaf. Unselected
    agents are never scaled (their entries are ignored downstream)."""
    a = jnp.shape(sel)[0]
    any_clip = jnp.zeros((a,), bool)

    def one(d, any_c):
        flat = d.reshape(d.shape[0], -1)
        nrm = jnp.sqrt(jnp.sum(flat * flat, axis=1))
        lim = clip_factor * _masked_median_1d(nrm, sel)
        hit = sel & (nrm > lim)
        scale = jnp.where(hit, lim / jnp.maximum(nrm, 1e-12), 1.0)
        return d * scale.reshape((-1,) + (1,) * (d.ndim - 1)), any_c | hit

    leaves, treedef = jax.tree_util.tree_flatten(contrib)
    out = []
    for leaf in leaves:
        clipped, any_clip = one(leaf, any_clip)
        out.append(clipped)
    n_clipped = jnp.sum(any_clip).astype(jnp.float32)
    return jax.tree_util.tree_unflatten(treedef, out), n_clipped
