"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.

Encoder-only (same trunk as wav2vec2-XL) [arXiv:2106.07447]. The conv
waveform frontend is a STUB per the assignment: ``input_specs`` provides
precomputed 512-d frame embeddings; the model projects them to d_model and
applies HuBERT-style masked-unit prediction over the 504-unit codebook.

Deviations (documented): RoPE replaces the conv positional embedding (keeps
the compute class identical without a max-length pos table); RMSNorm replaces
LayerNorm; FFN is classic (non-gated) GELU, matching HuBERT's 2-matmul FFN
FLOPs exactly.
"""
from repro.configs.base import ArchConfig
from repro.configs.base import register

CONFIG = register(ArchConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    act="gelu",
    mlp_gated=False,
    qkv_bias=True,
    causal=False,
    frontend="frames",
    frontend_dim=512,
    skip_shapes=(
        ("decode_32k", "encoder-only: no autoregressive decode step"),
        ("long_500k", "encoder-only: no decode step"),
    ),
))
