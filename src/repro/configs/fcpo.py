"""FCPO hyperparameters — paper Table II, plus action-space definition.

| param                         | paper | here |
|-------------------------------|-------|------|
| n_s   steps/episode           | 10    | 10   |
| LR    iAgent learning rate    | 1e-3  | 1e-3 |
| θ, ς, φ reward weights (Eq.1) | 1.1, 10, 2 | same |
| γ, λ  discount / GAE (Eq.2)   | 0.1   | same |
| ω     loss penalty (Eq.3)     | 0.2   | same |
| ε     policy clip (Eq.4)      | 0.9   | same |
| α, β  diversity weights (Eq.6)| 0.5   | same |

Action space (§II-B): RES — input-resolution bucket / frame-packing factor;
BS — inference batch size; MT — pre/post-processing concurrency. On the TPU
data plane these select the compiled seq/patch bucket, the batch bucket, and
the number of in-flight microbatches respectively (DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class FCPOConfig:
    # --- iAgent network (Fig. 4) ---
    state_dim: int = 8
    hidden_dim: int = 64
    feat_dim: int = 48
    n_res: int = 4            # resolution buckets: x1, x0.75, x0.5, x0.25
    n_bs: int = 7             # batch sizes: 1,2,4,8,16,32,64
    n_mt: int = 4             # threads: 1..4

    # --- RL (Table II) ---
    n_steps: int = 10         # steps per episode
    lr: float = 1e-3
    theta: float = 1.1        # ϑ reward throughput weight
    sigma: float = 10.0       # ς reward latency weight
    phi: float = 2.0          # φ reward oversize weight
    gamma: float = 0.1        # discount
    lam: float = 0.1          # GAE lambda
    omega: float = 0.2        # loss penalty weight (Eq. 3)
    eps_clip: float = 0.9     # ε in Eq. 4
    alpha: float = 0.5        # diversity: Mahalanobis weight (Eq. 6)
    beta: float = 0.5         # diversity: KL weight (Eq. 6)

    # --- CRL overhead minimization (§IV-C) ---
    buffer_size: int = 64     # small fixed-size experience buffer
    loss_gate: float = 0.05   # skip backprop when |loss| below this
    policy_mode: str = "fcpo"  # "fcpo" = Eq.4 literal; "ppo" = standard clip
    single_head: bool = False  # ablation (Fig. 12): one joint action head
    hidden_scale: int = 1      # BCEdge-style bulky agent multiplier

    # --- FL (§IV-D) ---
    fl_every: int = 2         # aggregate every 2nd episode (Fig. 14 setup)
    finetune_steps: int = 2   # action-head fine-tune steps after aggregation
    clients_per_round: float = 0.5   # fraction selected by Eq. 7 utility
    hierarchical_period: int = 4     # cross-pod exchange every N cluster rounds

    # --- action values ---
    res_scales: Tuple[float, ...] = (1.0, 0.75, 0.5, 0.25)
    bs_values: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64)
    mt_values: Tuple[int, ...] = (1, 2, 3, 4)

    # --- environment ---
    slo_s: float = 0.25       # 250 ms end-to-end SLO


DEFAULT = FCPOConfig()
