"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64 — Mamba2 backbone + weight-shared attention block
[arXiv:2411.15242].

The shared transformer block (attention + 8192-wide MLP) is invoked every 6
Mamba2 layers with tied weights — the Zamba signature. Embeddings tied.
Simplification noted in DESIGN.md: the real model concatenates the original
embedding to the shared block input and uses per-invocation LoRA deltas; we
invoke the shared block directly (identical compute class, minus the small
LoRA matmuls).

SSM decode is O(1)/token, so the long_500k cell runs (sub-quadratic except
the shared block's attention reads over the KV cache, which is linear in
context per decoded token).
"""
from repro.configs.base import ArchConfig
from repro.configs.base import register

CONFIG = register(ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    attn_every=6,
    expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
))
