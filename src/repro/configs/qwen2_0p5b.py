"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.

GQA kv=2, QKV bias, tied embeddings, rope_theta=1e6 [arXiv:2407.10671].
"""
from repro.configs.base import ArchConfig
from repro.configs.base import register

CONFIG = register(ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
    skip_shapes=(("long_500k", "full quadratic attention; no sub-quadratic path"),),
))
