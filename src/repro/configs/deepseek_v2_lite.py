"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff(moe)=1408
vocab=102400, MLA kv_lora=512, 64 routed experts top-6 + 2 shared
[arXiv:2405.04434].

Spec-discrepancy note (DESIGN.md): the assignment line says both "MoE 64e
top-6" and "2 shared+160 routed"; 160 routed is DeepSeek-V2-*full* — the Lite
model is 64 routed + 2 shared top-6, which we implement (consistent with
"MoE 64e top-6"). First layer is dense (d_ff=10944) per the HF config; the
remaining 26 are MoE. MLA: kv_lora_rank=512, qk_nope=128, qk_rope=64,
v_head=128 — decode runs the *absorbed* path against the compressed
(c_kv, k_rope) cache (576 B/token/layer vs 4096 for GQA).
"""
from repro.configs.base import ArchConfig
from repro.configs.base import register

CONFIG = register(ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=192,  # qk_nope + qk_rope
    d_ff=10944,    # first dense layer width (HF config)
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    skip_shapes=(("long_500k", "MLA is still quadratic attention"),),
))
