"""Config package: importing it registers every assigned architecture."""
from repro.configs.base import ArchConfig, InputShape, SHAPES, shape_applicable  # noqa: F401

# assigned architectures (registration side effect)
from repro.configs import (  # noqa: F401
    deepseek_v2_lite,
    gemma_7b,
    granite_moe_3b,
    hubert_xlarge,
    pixtral_12b,
    qwen1p5_0p5b,
    qwen2_0p5b,
    qwen2_7b,
    xlstm_125m,
    zamba2_1p2b,
)

ARCH_IDS = [
    "hubert-xlarge",
    "zamba2-1.2b",
    "qwen1.5-0.5b",
    "gemma-7b",
    "qwen2-7b",
    "qwen2-0.5b",
    "granite-moe-3b-a800m",
    "deepseek-v2-lite-16b",
    "pixtral-12b",
    "xlstm-125m",
]
