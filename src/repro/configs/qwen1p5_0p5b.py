"""qwen1.5-0.5b [dense]: 24L d_model=1024 16H (kv=16) d_ff=2816 vocab=151936.

QKV bias, tied embeddings, rope_theta=1e6 [hf:Qwen/Qwen1.5-0.5B].
"""
from repro.configs.base import ArchConfig
from repro.configs.base import register

CONFIG = register(ArchConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
    skip_shapes=(("long_500k", "full quadratic attention; no sub-quadratic path"),),
))
