"""gemma-7b [dense]: 28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000.

GeGLU activation, head_dim=256 (q/kv projections are 3072 -> 4096), embedding
scaled by sqrt(d_model), tied embeddings [arXiv:2403.08295].
"""
from repro.configs.base import ArchConfig
from repro.configs.base import register

CONFIG = register(ArchConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    act="gelu",
    embed_scale=True,
    tie_embeddings=True,
    skip_shapes=(("long_500k", "full quadratic attention; no sub-quadratic path"),),
))
