"""Architecture configuration system.

Every assigned architecture is described by an ``ArchConfig``. Configs are
immutable dataclasses; ``reduced()`` derives a CPU-smoke-test-sized variant of
the same family (same code paths, small dims).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention / block options
    qkv_bias: bool = False
    act: str = "silu"  # silu -> SwiGLU, gelu -> GeGLU
    mlp_gated: bool = True  # False -> classic 2-matmul FFN (hubert)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    causal: bool = True  # False -> bidirectional encoder
    logit_softcap: float = 0.0
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scaling

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    # "global": one capacity pool over all tokens (baseline); "batched":
    # per-batch-row dispatch (vmapped) — tokens never cross the data axis
    # since every data shard holds all experts' TP ff-slices (§Perf)
    moe_impl: str = "global"

    # MLA (deepseek)
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # SSM / hybrid
    ssm_state: int = 0
    attn_every: int = 0  # zamba2: shared attention block period (0 = none)
    slstm_every: int = 0  # xlstm: sLSTM block period (0 = none)
    d_conv: int = 4
    expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256

    # modality frontend stub
    frontend: str = "none"  # none | patches | frames
    n_patches: int = 0
    frontend_dim: int = 0  # raw embedding dim provided by the (stubbed) frontend

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # lowering: scan over layers (fast compile) vs unrolled (exact
    # cost_analysis — XLA:CPU counts scan bodies once, see EXPERIMENTS.md)
    scan_layers: bool = True

    # attention implementation: "ref" materializes the (S, S) score matrix
    # (paper-faithful baseline); "chunked" streams KV blocks with an online
    # softmax (flash-style, beyond-paper §Perf optimization — same math)
    attn_impl: str = "ref"
    attn_chunk: int = 1024

    # cross-entropy: "gather" computes from full logits; "sharded" keeps the
    # vocab dim sharded through logsumexp (collective-term optimization)
    ce_impl: str = "gather"

    # pin activation shardings (batch->data; prevents GSPMD contraction-dim
    # partial-sum pathologies in attention — §Perf optimization)
    shard_activations: bool = False

    # GQA reference path: "repeat" materializes kv heads G× (naive baseline);
    # "grouped" contracts against the shared kv heads directly — the decode
    # memory-term optimization (cache read once, like the Pallas kernel)
    gqa_impl: str = "repeat"

    # which input shapes are inapplicable for this arch ({shape_name: reason})
    skip_shapes: Tuple[Tuple[str, str], ...] = ()

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test-sized variant of the same family (same code paths)."""
        kw = dict(
            n_layers=min(self.n_layers, 2 if self.attn_every == 0 else self.attn_every + 1),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=0 if self.d_ff == 0 else 256,
            vocab_size=min(self.vocab_size, 512),
            dtype="float32",
            param_dtype="float32",
        )
        if self.n_experts:
            kw.update(n_experts=min(self.n_experts, 8), top_k=min(self.top_k, 2),
                      moe_d_ff=64, first_dense_layers=min(self.first_dense_layers, 1),
                      n_shared_experts=min(self.n_shared_experts, 1))
        if self.use_mla:
            kw.update(kv_lora_rank=64, qk_rope_dim=16, qk_nope_dim=32, v_head_dim=32,
                      head_dim=48)  # qk_nope + qk_rope
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=32)
        if self.attn_every:
            kw.update(attn_every=2, n_layers=5)
        if self.slstm_every:
            kw.update(slstm_every=2, n_layers=4)
        if self.frontend == "patches":
            kw.update(n_patches=8, frontend_dim=64)
        if self.frontend == "frames":
            kw.update(frontend_dim=64)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Input shape grid assigned to this paper (LM family): name -> (seq, batch, kind)
# kind: train | prefill | decode
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape_name: str) -> Tuple[bool, str]:
    """Return (applicable, reason-if-not) for an (arch, shape) cell."""
    for name, reason in cfg.skip_shapes:
        if name == shape_name:
            return False, reason
    return True, ""


# ---------------------------------------------------------------------------
# Registry (populated by the config modules at import)
# ---------------------------------------------------------------------------
_REGISTRY = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        import repro.configs  # noqa: F401  (registers all arch configs)
    return _REGISTRY[name]


def list_archs():
    if not _REGISTRY:
        import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
