"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.

Mistral-NeMo-style decoder backbone [hf:mistralai/Pixtral-12B-2409]. The
Pixtral ViT frontend is a STUB per the assignment: ``input_specs`` provides
precomputed 1024-d patch embeddings for n_patches=1024 leading positions
(≈4 images); the model projects them into the sequence ahead of text tokens.
head_dim=128 (q proj 5120 -> 4096).

The patch-resolution bucket is the literal analogue of FCPO's resolution
action for this arch (fewer/more patches per image).
"""
from repro.configs.base import ArchConfig
from repro.configs.base import register

CONFIG = register(ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1e6,
    frontend="patches",
    n_patches=1024,
    frontend_dim=1024,
    skip_shapes=(("long_500k", "full quadratic attention; no sub-quadratic path"),),
))
