"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8 [hf:ibm-granite/granite-3.0-3b-a800m-base].

Spec-discrepancy note (DESIGN.md): the assignment line says both "MoE 40e
top-8" and "32 experts top-8"; we implement 40 experts / top-8 (the inline
shape spec, which also matches the granite-3.0-3b-a800m card). Every layer is
MoE; expert ffn width is 512 (SwiGLU). Embeddings tied.

40 experts do not divide the 16-way model axis, so the sharder falls back to
tensor-parallel experts (ff 512/16=32 per shard) — see distributed/sharding.
"""
from repro.configs.base import ArchConfig
from repro.configs.base import register

CONFIG = register(ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    n_experts=40,
    top_k=8,
    moe_d_ff=512,
    tie_embeddings=True,
    skip_shapes=(("long_500k", "full quadratic attention; no sub-quadratic path"),),
))
