"""qwen2-7b [dense]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

GQA with 4 kv heads, QKV bias, rope_theta=1e6, untied embeddings
[arXiv:2407.10671].
"""
from repro.configs.base import ArchConfig
from repro.configs.base import register

CONFIG = register(ArchConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    skip_shapes=(("long_500k", "full quadratic attention; no sub-quadratic path"),),
))
