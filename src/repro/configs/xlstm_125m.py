"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks [arXiv:2405.04517].

xLSTM[7:1]-style mix: sLSTM blocks at every 8th layer (indices 0, 8), mLSTM
elsewhere. d_ff=0 ⇒ no separate FFN (the cells carry their own projections).
mLSTM trains in the chunkwise-parallel stabilized form (chunk=128); decode is
the O(1) recurrent form with (C, n, m) matrix-memory state, so both
decode_32k and long_500k run with a constant-size cache.
"""
from repro.configs.base import ArchConfig
from repro.configs.base import register

CONFIG = register(ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,
    ssm_chunk=128,
))
